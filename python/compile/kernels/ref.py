"""Pure-jnp oracles for the OGASCHED compute step.

These are the correctness references the Pallas kernels (and, transitively,
the Rust-native implementation through the AOT parity tests) are checked
against.  Everything here is written for clarity, not speed.

Shapes
------
    L : number of job types (ports)
    R : number of computing instances
    K : number of resource types

    x     : f32[L]      arrival indicator (0/1; >=2 in the multi-arrival ext.)
    y     : f32[L, R, K] allocation decision
    mask  : f32[L, R]   bipartite edge mask (1 iff (l, r) in E)
    alpha : f32[R, K]   utility coefficient of f_r^k
    kind  : i32[R, K]   utility family per (r, k)  (see KIND_*)
    beta  : f32[K]      communication-overhead coefficients
    a     : f32[L, K]   per-channel request cap a_l^k
    c     : f32[R, K]   instance capacity c_r^k
    eta   : f32[]       OGA step size
"""

from __future__ import annotations

import jax.numpy as jnp

# Utility families of Eq. (51) in the paper.
KIND_LINEAR = 0
KIND_LOG = 1
KIND_RECIPROCAL = 2
KIND_POLY = 3


def utility(y, alpha, kind):
    """f_r^k(y) for each element (Eq. 51). `y`, `alpha`, `kind` broadcast."""
    lin = alpha * y
    log = alpha * jnp.log1p(y)
    rec = 1.0 / alpha - 1.0 / (y + alpha)
    poly = alpha * jnp.sqrt(y + 1.0) - alpha
    out = jnp.where(kind == KIND_LINEAR, lin, 0.0)
    out = jnp.where(kind == KIND_LOG, log, out)
    out = jnp.where(kind == KIND_RECIPROCAL, rec, out)
    out = jnp.where(kind == KIND_POLY, poly, out)
    return out


def utility_grad(y, alpha, kind):
    """(f_r^k)'(y) for each element."""
    lin = alpha * jnp.ones_like(y)
    log = alpha / (y + 1.0)
    rec = 1.0 / jnp.square(y + alpha)
    poly = alpha / (2.0 * jnp.sqrt(y + 1.0))
    out = jnp.where(kind == KIND_LINEAR, lin, 0.0)
    out = jnp.where(kind == KIND_LOG, log, out)
    out = jnp.where(kind == KIND_RECIPROCAL, rec, out)
    out = jnp.where(kind == KIND_POLY, poly, out)
    return out


def utility_grad_at_zero(alpha, kind):
    """The bound \\varpi_r^k = (f_r^k)'(0) of Def. 1 (iii)."""
    return utility_grad(jnp.zeros_like(alpha), alpha, kind)


def reward_parts_ref(x, y, mask, alpha, kind, beta):
    """Per-port (gain_l, penalty_l) of Eq. (7) under the nice setup.

    Returns (gain[L], penalty[L]); the port reward is
    q_l = x_l * (gain_l - penalty_l).
    """
    m = mask[:, :, None]  # [L,R,1]
    f = utility(y, alpha[None], kind[None]) * m  # [L,R,K]
    gain = jnp.sum(f, axis=(1, 2))  # [L]
    s = jnp.sum(y * m, axis=1)  # [L,K] allocated quota per resource type
    penalty = jnp.max(beta[None, :] * s, axis=1)  # [L]
    return gain, penalty


def reward_ref(x, y, mask, alpha, kind, beta):
    """(q, total_gain, total_penalty) of Eq. (8), arrivals applied."""
    gain, penalty = reward_parts_ref(x, y, mask, alpha, kind, beta)
    q = jnp.sum(x * (gain - penalty))
    return q, jnp.sum(x * gain), jnp.sum(x * penalty)


def grad_ref(x, y, mask, alpha, kind, beta):
    """The reward gradient of Eq. (30), including the k* penalty branch."""
    m = mask[:, :, None]
    s = jnp.sum(y * m, axis=1)  # [L,K]
    kstar = jnp.argmax(beta[None, :] * s, axis=1)  # [L]
    fp = utility_grad(y, alpha[None], kind[None])  # [L,R,K]
    k_idx = jnp.arange(y.shape[2])
    pen = jnp.where(k_idx[None, None, :] == kstar[:, None, None],
                    beta[None, None, :], 0.0)
    return x[:, None, None] * m * (fp - pen)


def ascent_ref(x, y, mask, alpha, kind, beta, eta):
    """One un-projected OGA ascent step: z = y + eta * grad q."""
    return y + eta * grad_ref(x, y, mask, alpha, kind, beta)


def project_ref(z, mask, a, c, iters: int = 64):
    """Euclidean projection of z onto Y (Eqs. 5-6), via water-filling.

    For each (r, k) independently the problem is
        min ||v - z[:, r, k]||^2  s.t. 0 <= v_l <= a[l, k], sum_l v_l <= c[r, k]
    whose exact solution is v_l = clip(z_l - tau, 0, a_l) with tau = 0 if the
    clipped point is feasible, else the unique root of
    g(tau) = sum_l clip(z_l - tau, 0, a_l) - c.  We find tau by bisection,
    which vectorizes over every (r, k) pair at once (the jnp analogue of the
    per-(r,k)-parallel Algorithm 1 in the paper; tau = rho_r^k / 2 in the
    paper's KKT notation, Eq. 35).

    Off-edge channels (mask == 0) are forced to zero and do not consume
    capacity.
    """
    m = mask[:, :, None]
    z = z * m  # off-edge -> 0
    cap = a[:, None, :] * m  # effective per-channel cap, 0 off-edge

    def g(tau):
        # tau: [R,K] water level; returns capacity usage at that level [R,K]
        v = jnp.clip(z - tau[None], 0.0, cap)
        return jnp.sum(v, axis=0)

    need = g(jnp.zeros_like(c)) > c  # [R,K] is the capacity constraint binding?
    lo = jnp.zeros_like(c)
    hi = jnp.max(z, axis=0) + 1e-6  # at tau >= max z_l, g = 0 <= c
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        too_big = g(mid) > c
        lo = jnp.where(too_big, mid, lo)
        hi = jnp.where(too_big, hi, mid)
    tau = jnp.where(need, hi, 0.0)
    return jnp.clip(z - tau[None], 0.0, cap)


def oga_step_ref(x, y, mask, alpha, kind, beta, a, c, eta):
    """Full reference OGA step: reward at (x, y), then y(t+1).

    Returns (y_next, q, gain, penalty) — the same signature the AOT'd
    model exports, so the Rust parity tests can reuse it.
    """
    q, gain, penalty = reward_ref(x, y, mask, alpha, kind, beta)
    z = ascent_ref(x, y, mask, alpha, kind, beta, eta)
    y_next = project_ref(z, mask, a, c)
    return y_next, q, gain, penalty
