"""Layer-1 Pallas kernel: per-port reward decomposition (Eq. 7).

For every port l computes, over its (R, K) allocation slab,

    gain_l    = sum_{r,k} mask_lr * f_r^k(y[l,r,k])
    penalty_l = max_k beta_k * sum_r mask_lr * y[l,r,k]

The slot reward is then q = sum_l x_l * (gain_l - penalty_l), reduced at
Layer 2.  Same tiling story as oga_step.py: grid over ports, one
(1, R, K) VMEM slab per instance, element-wise utility evaluation on the
VPU lanes, slab-local reductions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import KIND_LINEAR, KIND_LOG, KIND_POLY, KIND_RECIPROCAL


def _utility_lanes(y, alpha, kind):
    """f_r^k(y) as a vectorized 4-way select over the (R, K) lanes."""
    lin = alpha * y
    log = alpha * jnp.log1p(y)
    rec = 1.0 / alpha - 1.0 / (y + alpha)
    poly = alpha * jnp.sqrt(y + 1.0) - alpha
    out = jnp.where(kind == KIND_LINEAR, lin, 0.0)
    out = jnp.where(kind == KIND_LOG, log, out)
    out = jnp.where(kind == KIND_RECIPROCAL, rec, out)
    out = jnp.where(kind == KIND_POLY, poly, out)
    return out


def _reward_kernel(y_ref, mask_ref, alpha_ref, kind_ref, beta_ref,
                   gain_ref, pen_ref):
    y = y_ref[0]              # (R, K)
    m = mask_ref[0][:, None]  # (R, 1)
    f = _utility_lanes(y, alpha_ref[...], kind_ref[...]) * m
    gain_ref[0] = jnp.sum(f)
    s = jnp.sum(y * m, axis=0)            # (K,)
    pen_ref[0] = jnp.max(beta_ref[...] * s)


@functools.partial(jax.jit, static_argnames=("interpret",))
def reward_parts(y, mask, alpha, kind, beta, *, interpret=True):
    """Per-port (gain[L], penalty[L]) via the Pallas reward kernel."""
    L, R, K = y.shape
    return pl.pallas_call(
        _reward_kernel,
        grid=(L,),
        in_specs=[
            pl.BlockSpec((1, R, K), lambda l: (l, 0, 0)),  # y
            pl.BlockSpec((1, R), lambda l: (l, 0)),        # mask
            pl.BlockSpec((R, K), lambda l: (0, 0)),        # alpha
            pl.BlockSpec((R, K), lambda l: (0, 0)),        # kind
            pl.BlockSpec((K,), lambda l: (0,)),            # beta
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda l: (l,)),
            pl.BlockSpec((1,), lambda l: (l,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L,), y.dtype),
            jax.ShapeDtypeStruct((L,), y.dtype),
        ],
        interpret=interpret,
    )(y, mask.astype(y.dtype), alpha.astype(y.dtype), kind,
      beta.astype(y.dtype))
