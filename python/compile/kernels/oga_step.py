"""Layer-1 Pallas kernel: fused OGA gradient + ascent step.

The hot-spot of OGASCHED's slot loop is computing, for every port l,

    z[l, r, k] = y + eta * x_l * mask_lr * ( (f_r^k)'(y) - beta_k * 1{k = k*_l} )

with k*_l = argmax_k beta_k * sum_r y[l, r, k]   (Eq. 30 of the paper).

Kernel design (TPU mindset, executed here with interpret=True — the CPU
PJRT plugin cannot run Mosaic custom-calls):

  * grid = (L,): one program instance per port.  Each instance owns a
    (1, R, K) slab of `y` in VMEM via BlockSpec — the reduction over r and
    the argmax over k needed for k* are slab-local, so `y` is read from HBM
    exactly once per step.
  * alpha/kind/beta are small broadcast operands replicated to every
    program instance (index_map -> block 0); they stay VMEM-resident.
  * All the utility derivatives are computed as one vectorized select over
    the (R, K) lanes — pure VPU element-wise work; this op has no
    contraction so the MXU is intentionally idle (see DESIGN.md
    §Hardware-Adaptation and EXPERIMENTS.md §Perf for the bandwidth
    roofline argument).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import KIND_LINEAR, KIND_LOG, KIND_POLY, KIND_RECIPROCAL


def _utility_grad_lanes(y, alpha, kind):
    """(f_r^k)'(y) as a vectorized 4-way select over the (R, K) lanes."""
    lin = alpha
    log = alpha / (y + 1.0)
    rec = 1.0 / jnp.square(y + alpha)
    poly = alpha / (2.0 * jnp.sqrt(y + 1.0))
    out = jnp.where(kind == KIND_LINEAR, lin, 0.0)
    out = jnp.where(kind == KIND_LOG, log, out)
    out = jnp.where(kind == KIND_RECIPROCAL, rec, out)
    out = jnp.where(kind == KIND_POLY, poly, out)
    return out


def _oga_ascent_kernel(x_ref, y_ref, mask_ref, alpha_ref, kind_ref,
                       beta_ref, eta_ref, z_ref):
    """One program instance == one port l (grid axis 0)."""
    y = y_ref[0]            # (R, K) slab
    m = mask_ref[0][:, None]  # (R, 1)
    alpha = alpha_ref[...]  # (R, K)
    kind = kind_ref[...]    # (R, K)
    beta = beta_ref[...]    # (K,)
    x_l = x_ref[0]
    eta = eta_ref[0]

    # k* = argmax_k beta_k * sum_r y  (slab-local reduction, Eq. 27)
    s = jnp.sum(y * m, axis=0)              # (K,)
    kstar = jnp.argmax(beta * s)
    k_iota = jax.lax.iota(jnp.int32, y.shape[1])
    pen = jnp.where(k_iota == kstar, beta, 0.0)[None, :]  # (1, K)

    fp = _utility_grad_lanes(y, alpha, kind)             # (R, K)
    grad = x_l * m * (fp - pen)
    z_ref[0] = y + eta * grad


@functools.partial(jax.jit, static_argnames=("interpret",))
def oga_ascent(x, y, mask, alpha, kind, beta, eta, *, interpret=True):
    """z = y + eta * grad q(x, y), as a Pallas call tiled over ports.

    Args match ref.py conventions; `eta` is a scalar (reshaped to (1,)).
    """
    L, R, K = y.shape
    eta_v = jnp.reshape(eta, (1,)).astype(y.dtype)
    return pl.pallas_call(
        _oga_ascent_kernel,
        grid=(L,),
        in_specs=[
            pl.BlockSpec((1,), lambda l: (l,)),        # x
            pl.BlockSpec((1, R, K), lambda l: (l, 0, 0)),  # y
            pl.BlockSpec((1, R), lambda l: (l, 0)),    # mask
            pl.BlockSpec((R, K), lambda l: (0, 0)),    # alpha
            pl.BlockSpec((R, K), lambda l: (0, 0)),    # kind
            pl.BlockSpec((K,), lambda l: (0,)),        # beta
            pl.BlockSpec((1,), lambda l: (0,)),        # eta
        ],
        out_specs=pl.BlockSpec((1, R, K), lambda l: (l, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, R, K), y.dtype),
        interpret=interpret,
    )(x.astype(y.dtype), y, mask.astype(y.dtype), alpha.astype(y.dtype),
      kind, beta.astype(y.dtype), eta_v)
