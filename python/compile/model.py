"""Layer-2 JAX model: one full OGASCHED step.

Composes the Layer-1 Pallas kernels (gradient+ascent, reward) with the
vectorized feasibility projection into a single jittable function.  This
is what `aot.py` lowers to HLO text for the Rust runtime — Python never
runs on the request path; the Rust coordinator executes the compiled
artifact each slot.

The projection is the jnp formulation of the paper's Algorithm 1
(steps 6-31): for each (r, k) independently, project onto
{0 <= v_l <= a_l^k, sum_l v_l <= c_r^k}.  The paper finds the KKT
multiplier rho_r^k by sorting and water-filling; the vectorized
equivalent here finds tau = rho/2 by bisection over all (R, K) pairs at
once, which fuses into the same XLA module (no host round trips).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.oga_step import oga_ascent
from .kernels.reward import reward_parts

# Bisection depth for the projection water level.  48 halvings of an
# interval of width max(z) <= a few hundred gives ~1e-12 relative
# precision — far below f32 resolution, so the projection is exact at
# working precision.
_PROJ_ITERS = 48


def project(z, mask, a, c, iters: int = _PROJ_ITERS):
    """Euclidean projection of z onto the feasible polytope Y (Eqs. 5-6)."""
    m = mask[:, :, None]
    z = z * m
    cap = a[:, None, :] * m  # per-channel cap; 0 off-edge

    def usage(tau):
        return jnp.sum(jnp.clip(z - tau[None], 0.0, cap), axis=0)  # [R,K]

    need = usage(jnp.zeros_like(c)) > c
    lo = jnp.zeros_like(c)
    hi = jnp.max(z, axis=0) + 1e-6

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        too_big = usage(mid) > c
        return jnp.where(too_big, mid, lo), jnp.where(too_big, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    tau = jnp.where(need, hi, 0.0)
    return jnp.clip(z - tau[None], 0.0, cap)


@functools.partial(jax.jit, static_argnames=("interpret",))
def oga_step(x, y, mask, alpha, kind, beta, a, c, eta, *, interpret=True):
    """One OGASCHED slot: reward at (x, y), then the projected ascent.

    Returns (y_next, q, gain, penalty).  `q/gain/penalty` are the Eq. 8
    slot aggregates for the *current* decision y(t); `y_next` is y(t+1).
    """
    gain_l, pen_l = reward_parts(y, mask, alpha, kind, beta,
                                 interpret=interpret)
    q = jnp.sum(x * (gain_l - pen_l))
    gain = jnp.sum(x * gain_l)
    penalty = jnp.sum(x * pen_l)
    z = oga_ascent(x, y, mask, alpha, kind, beta, eta, interpret=interpret)
    y_next = project(z, mask, a, c)
    return y_next, q, gain, penalty


def oga_step_export(L: int, R: int, K: int):
    """The (pytree-free, fixed-shape) function `aot.py` lowers.

    Parameter order here defines the artifact's calling convention; the
    Rust runtime (`rust/src/runtime/executor.rs`) must marshal literals in
    exactly this order:
        x[L] f32, y[L,R,K] f32, mask[L,R] f32, alpha[R,K] f32,
        kind[R,K] i32, beta[K] f32, a[L,K] f32, c[R,K] f32, eta[] f32
    Outputs (as a tuple): y_next[L,R,K], q[], gain[], penalty[].
    """

    def fn(x, y, mask, alpha, kind, beta, a, c, eta):
        return oga_step(x, y, mask, alpha, kind, beta, a, c, eta,
                        interpret=True)

    args = (
        jax.ShapeDtypeStruct((L,), jnp.float32),
        jax.ShapeDtypeStruct((L, R, K), jnp.float32),
        jax.ShapeDtypeStruct((L, R), jnp.float32),
        jax.ShapeDtypeStruct((R, K), jnp.float32),
        jax.ShapeDtypeStruct((R, K), jnp.int32),
        jax.ShapeDtypeStruct((K,), jnp.float32),
        jax.ShapeDtypeStruct((L, K), jnp.float32),
        jax.ShapeDtypeStruct((R, K), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return fn, args
