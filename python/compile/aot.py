"""AOT export: lower the Layer-2 OGA step to HLO *text* artifacts.

HLO text (not `.serialize()`d HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser on
the Rust side reassigns ids and round-trips cleanly.

One artifact per shape bucket (HLO is fixed-shape).  Scenarios smaller
than a bucket are zero-padded by the Rust runtime: padded ports get
x = 0 / mask = 0 (no gradient, no reward) and padded instances get
mask = 0 / c = 0, so padding is exactly reward- and decision-neutral.

Usage:  python -m compile.aot --out-dir ../artifacts [--buckets small,default]
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import oga_step_export

# (name, L, R, K).  `default` matches the paper's Tab. 2 cluster;
# `large` matches the Sec. 4.3 large-scale validation; `small` keeps CI
# and the quickstart example fast.
BUCKETS = {
    "small": (4, 16, 4),
    "default": (10, 128, 6),
    "large": (100, 1024, 6),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_bucket(name: str, out_dir: str) -> str:
    L, R, K = BUCKETS[name]
    fn, args = oga_step_export(L, R, K)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"oga_step_{name}_L{L}_R{R}_K{K}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", default="small,default,large",
                    help="comma-separated bucket names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = []
    for name in args.buckets.split(","):
        name = name.strip()
        if name not in BUCKETS:
            raise SystemExit(f"unknown bucket {name!r}; have {list(BUCKETS)}")
        path = export_bucket(name, args.out_dir)
        L, R, K = BUCKETS[name]
        manifest_lines.append(
            f"{name} L={L} R={R} K={K} file={os.path.basename(path)}"
        )
        print(f"wrote {path}")

    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("# bucket L= R= K= file=   (parsed by rust/src/runtime/artifact.rs)\n")
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
