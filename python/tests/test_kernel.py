"""Kernel-vs-reference correctness: the CORE numeric signal.

The Pallas kernels (interpret=True) must agree with the pure-jnp oracles
in ref.py across a hypothesis sweep of shapes, masks, utility mixes and
dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.oga_step import oga_ascent
from compile.kernels.reward import reward_parts

jax.config.update("jax_platform_name", "cpu")


def make_problem(rng, L, R, K, density=1.0, dtype=jnp.float32):
    x = (rng.random(L) < 0.7).astype(np.float32)
    y = rng.random((L, R, K)).astype(np.float32) * 4.0
    mask = (rng.random((L, R)) < density).astype(np.float32)
    # every port keeps at least one edge so rewards are non-degenerate
    mask[np.arange(L), rng.integers(0, R, size=L)] = 1.0
    alpha = (1.0 + 0.5 * rng.random((R, K))).astype(np.float32)
    kind = rng.integers(0, 4, size=(R, K)).astype(np.int32)
    beta = (0.3 + 0.2 * rng.random(K)).astype(np.float32)
    a = (1.0 + 3.0 * rng.random((L, K))).astype(np.float32)
    c = (2.0 + 6.0 * rng.random((R, K))).astype(np.float32)
    y = np.minimum(y, a[:, None, :]) * mask[:, :, None]
    to = lambda v: jnp.asarray(v, dtype) if v.dtype == np.float32 else jnp.asarray(v)
    return tuple(map(to, (x, y, mask, alpha, kind, beta, a, c)))


@settings(max_examples=25, deadline=None)
@given(
    L=st.integers(1, 12),
    R=st.integers(1, 24),
    K=st.integers(1, 6),
    density=st.sampled_from([0.4, 0.8, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ascent_kernel_matches_ref(L, R, K, density, seed):
    rng = np.random.default_rng(seed)
    x, y, mask, alpha, kind, beta, a, c = make_problem(rng, L, R, K, density)
    eta = jnp.float32(0.37)
    got = oga_ascent(x, y, mask, alpha, kind, beta, eta)
    want = ref.ascent_ref(x, y, mask, alpha, kind, beta, eta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    L=st.integers(1, 12),
    R=st.integers(1, 24),
    K=st.integers(1, 6),
    density=st.sampled_from([0.4, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_reward_kernel_matches_ref(L, R, K, density, seed):
    rng = np.random.default_rng(seed)
    x, y, mask, alpha, kind, beta, a, c = make_problem(rng, L, R, K, density)
    gain, pen = reward_parts(y, mask, alpha, kind, beta)
    want_gain, want_pen = ref.reward_parts_ref(x, y, mask, alpha, kind, beta)
    np.testing.assert_allclose(np.asarray(gain), np.asarray(want_gain),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(pen), np.asarray(want_pen),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ascent_kernel_dtypes(dtype):
    rng = np.random.default_rng(7)
    x, y, mask, alpha, kind, beta, a, c = make_problem(
        rng, 6, 16, 4, dtype=dtype)
    eta = jnp.asarray(0.25, dtype)
    got = np.asarray(oga_ascent(x, y, mask, alpha, kind, beta, eta),
                     np.float32)
    want = np.asarray(
        ref.ascent_ref(*(jnp.asarray(np.asarray(v, np.float32))
                         if v.dtype != jnp.int32 else v
                         for v in (x, y, mask, alpha, kind, beta)),
                       0.25), np.float32)
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_kstar_penalty_branch_only_on_argmax():
    """Eq. 30: exactly one k per port carries the -beta_k penalty term."""
    rng = np.random.default_rng(3)
    x, y, mask, alpha, kind, beta, a, c = make_problem(rng, 5, 8, 4)
    x = jnp.ones_like(x)
    eta = jnp.float32(1.0)
    z = np.asarray(oga_ascent(x, y, mask, alpha, kind, beta, eta))
    fp = np.asarray(ref.utility_grad(y, alpha[None], kind[None]))
    m = np.asarray(mask)[:, :, None]
    diff = (z - np.asarray(y)) - fp * m  # = -beta_{k*} on (masked) k* lanes
    s = np.asarray(jnp.sum(y * mask[:, :, None], axis=1))
    kstar = np.argmax(np.asarray(beta)[None] * s, axis=1)
    for l in range(5):
        for k in range(4):
            lane = diff[l, :, k][np.asarray(mask)[l] > 0]
            if k == kstar[l]:
                np.testing.assert_allclose(lane, -float(beta[k]), atol=1e-5)
            else:
                np.testing.assert_allclose(lane, 0.0, atol=1e-5)


def test_zero_arrivals_zero_gradient():
    rng = np.random.default_rng(11)
    x, y, mask, alpha, kind, beta, a, c = make_problem(rng, 4, 8, 3)
    x = jnp.zeros_like(x)
    z = oga_ascent(x, y, mask, alpha, kind, beta, jnp.float32(5.0))
    np.testing.assert_allclose(np.asarray(z), np.asarray(y), atol=1e-7)


def test_utility_values_match_eq51():
    """Spot-check the four utility families at hand-computed points."""
    alpha = jnp.float32(2.0)
    y = jnp.float32(3.0)
    assert np.isclose(float(ref.utility(y, alpha, ref.KIND_LINEAR)), 6.0)
    assert np.isclose(float(ref.utility(y, alpha, ref.KIND_LOG)),
                      2.0 * np.log(4.0))
    assert np.isclose(float(ref.utility(y, alpha, ref.KIND_RECIPROCAL)),
                      0.5 - 1.0 / 5.0)
    assert np.isclose(float(ref.utility(y, alpha, ref.KIND_POLY)),
                      2.0 * 2.0 - 2.0)
    # zero-startup: f(0) = 0 for all families
    for kind in range(4):
        assert np.isclose(float(ref.utility(jnp.float32(0.0), alpha, kind)),
                          0.0, atol=1e-7)


def test_varpi_bounds_derivative():
    """Def. 1 (iii): f' is maximized at 0 (concavity) for every family."""
    rng = np.random.default_rng(5)
    alpha = jnp.asarray(1.0 + 0.5 * rng.random((8, 4)), jnp.float32)
    kind = jnp.asarray(rng.integers(0, 4, (8, 4)), jnp.int32)
    w0 = np.asarray(ref.utility_grad_at_zero(alpha, kind))
    for yval in [0.1, 1.0, 7.5, 100.0]:
        fp = np.asarray(ref.utility_grad(jnp.full((8, 4), yval), alpha, kind))
        assert (fp <= w0 + 1e-6).all()
