"""Layer-2 model tests: full oga_step composition + AOT export."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.kernels import ref
from compile.model import oga_step, oga_step_export, project


def make_problem(seed=0, L=6, R=12, K=4):
    rng = np.random.default_rng(seed)
    x = (rng.random(L) < 0.7).astype(np.float32)
    mask = (rng.random((L, R)) < 0.8).astype(np.float32)
    mask[np.arange(L), rng.integers(0, R, size=L)] = 1.0
    alpha = (1.0 + 0.5 * rng.random((R, K))).astype(np.float32)
    kind = rng.integers(0, 4, size=(R, K)).astype(np.int32)
    beta = (0.3 + 0.2 * rng.random(K)).astype(np.float32)
    a = (1.0 + 3.0 * rng.random((L, K))).astype(np.float32)
    c = (2.0 + 4.0 * rng.random((R, K))).astype(np.float32)
    y0 = np.zeros((L, R, K), np.float32)
    return tuple(map(jnp.asarray, (x, y0, mask, alpha, kind, beta, a, c)))


def test_oga_step_matches_ref():
    x, y, mask, alpha, kind, beta, a, c = make_problem(3)
    eta = jnp.float32(0.5)
    for _ in range(5):  # run a few slots so y leaves the origin
        y_next, q, gain, pen = oga_step(x, y, mask, alpha, kind, beta, a, c, eta)
        ref_next, ref_q, ref_gain, ref_pen = ref.oga_step_ref(
            x, y, mask, alpha, kind, beta, a, c, eta)
        np.testing.assert_allclose(np.asarray(y_next), np.asarray(ref_next),
                                   atol=5e-4)
        np.testing.assert_allclose(float(q), float(ref_q), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(gain), float(ref_gain), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(float(pen), float(ref_pen), rtol=1e-4,
                                   atol=1e-4)
        y = y_next


def test_oga_step_improves_reward_on_stationary_arrivals():
    """Sanity: under fixed arrivals the projected ascent should climb."""
    x, y, mask, alpha, kind, beta, a, c = make_problem(7)
    x = jnp.ones_like(x)
    eta = jnp.float32(0.3)
    rewards = []
    for _ in range(40):
        y, q, _, _ = oga_step(x, y, mask, alpha, kind, beta, a, c, eta)
        rewards.append(float(q))
    assert rewards[-1] > rewards[0]
    # late-phase rewards should be near-monotone (small oscillation ok)
    late = rewards[25:]
    assert max(late) - min(late) < 0.2 * abs(max(late)) + 1e-3


def test_oga_step_output_always_feasible():
    x, y, mask, alpha, kind, beta, a, c = make_problem(11)
    eta = jnp.float32(2.0)  # aggressive step to stress the projection
    for _ in range(10):
        y, *_ = oga_step(x, y, mask, alpha, kind, beta, a, c, eta)
        v = np.asarray(y)
        assert (v >= -1e-4).all()
        assert (v <= np.asarray(a)[:, None, :] + 1e-4).all()
        assert (v.sum(axis=0) <= np.asarray(c) + 1e-3).all()
        assert (np.abs(v * (1 - np.asarray(mask)[:, :, None])) < 1e-6).all()


def test_export_shapes_and_padding_neutrality():
    """Padded ports/instances must not change reward or real decisions."""
    x, y, mask, alpha, kind, beta, a, c = make_problem(5, L=4, R=8, K=3)
    eta = jnp.float32(0.4)
    yn, q, g, p = oga_step(x, y, mask, alpha, kind, beta, a, c, eta)

    # pad L 4->6, R 8->10 with x=0, mask=0, c=0
    def pad(arr, shape):
        out = np.zeros(shape, np.asarray(arr).dtype)
        sl = tuple(slice(0, s) for s in np.asarray(arr).shape)
        out[sl] = np.asarray(arr)
        return jnp.asarray(out)

    L2, R2, K2 = 6, 10, 3
    x2 = pad(x, (L2,))
    y2 = pad(y, (L2, R2, K2))
    mask2 = pad(mask, (L2, R2))
    # pad alpha with 1.0 (not 0) to avoid division by zero in the
    # reciprocal family on padded lanes; padded lanes are masked anyway.
    alpha2 = np.ones((R2, K2), np.float32)
    alpha2[:8, :3] = np.asarray(alpha)
    alpha2 = jnp.asarray(alpha2)
    kind2 = pad(kind, (R2, K2))
    beta2 = pad(beta, (K2,))
    a2 = pad(a, (L2, K2))
    c2 = pad(c, (R2, K2))
    yn2, q2, g2, p2 = oga_step(x2, y2, mask2, alpha2, kind2, beta2, a2, c2, eta)
    np.testing.assert_allclose(float(q2), float(q), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(yn2)[:4, :8, :], np.asarray(yn),
                               atol=1e-5)
    assert np.abs(np.asarray(yn2)[4:, :, :]).max() == 0.0
    assert np.abs(np.asarray(yn2)[:, 8:, :]).max() == 0.0


def test_aot_export_emits_parseable_hlo():
    fn, args = oga_step_export(4, 16, 4)
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # calling convention: 9 parameters, tuple of 4 results
    assert text.count("parameter(") >= 9


def test_aot_main_writes_manifest(tmp_path=None):
    out = tempfile.mkdtemp()
    path = aot.export_bucket("small", out)
    assert os.path.exists(path)
    L, R, K = aot.BUCKETS["small"]
    assert f"L{L}_R{R}_K{K}" in path
