"""Properties of the feasibility projection (Eqs. 5-6, Alg. 1).

The projection is the part of OGASCHED the regret proof leans on (the
non-expansiveness step (i) of Eq. 37), so we check it hard:
feasibility, idempotence, non-expansiveness, KKT optimality, and
agreement between the L2 `project` (fused, fori_loop) and the ref.py
bisection oracle.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import project

ATOL = 2e-4  # f32 bisection resolution at ~48-64 halvings


def make_problem(rng, L, R, K, density=1.0):
    z = (rng.random((L, R, K)) * 8.0 - 2.0).astype(np.float32)
    mask = (rng.random((L, R)) < density).astype(np.float32)
    mask[np.arange(L), rng.integers(0, R, size=L)] = 1.0
    a = (0.5 + 3.0 * rng.random((L, K))).astype(np.float32)
    # keep capacities small enough that the sum constraint actually binds
    c = (0.5 + 2.0 * rng.random((R, K))).astype(np.float32)
    return jnp.asarray(z), jnp.asarray(mask), jnp.asarray(a), jnp.asarray(c)


def feasible(v, mask, a, c, tol=ATOL):
    v = np.asarray(v)
    m = np.asarray(mask)[:, :, None]
    if (v < -tol).any():
        return False
    if (v > np.asarray(a)[:, None, :] + tol).any():
        return False
    if (np.abs(v * (1 - m)) > tol).any():
        return False
    return (v.sum(axis=0) <= np.asarray(c) + tol * v.shape[0]).all()


@settings(max_examples=30, deadline=None)
@given(L=st.integers(1, 10), R=st.integers(1, 12), K=st.integers(1, 5),
       density=st.sampled_from([0.5, 1.0]), seed=st.integers(0, 2**31 - 1))
def test_projection_feasible(L, R, K, density, seed):
    rng = np.random.default_rng(seed)
    z, mask, a, c = make_problem(rng, L, R, K, density)
    v = project(z, mask, a, c)
    assert feasible(v, mask, a, c)


@settings(max_examples=20, deadline=None)
@given(L=st.integers(1, 8), R=st.integers(1, 10), K=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_projection_idempotent(L, R, K, seed):
    rng = np.random.default_rng(seed)
    z, mask, a, c = make_problem(rng, L, R, K)
    v = project(z, mask, a, c)
    v2 = project(v, mask, a, c)
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v), atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(L=st.integers(1, 8), R=st.integers(1, 10), K=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_projection_nonexpansive(L, R, K, seed):
    """||P(z1) - P(z2)|| <= ||z1 - z2|| — the crux of the regret proof."""
    rng = np.random.default_rng(seed)
    z1, mask, a, c = make_problem(rng, L, R, K)
    z2 = z1 + jnp.asarray((rng.random(z1.shape) - 0.5).astype(np.float32))
    # compare on-edge coordinates only (off-edge are clamped to 0 anyway)
    m = np.asarray(mask)[:, :, None]
    d_in = np.linalg.norm((np.asarray(z1) - np.asarray(z2)) * m)
    d_out = np.linalg.norm(np.asarray(project(z1, mask, a, c)) -
                           np.asarray(project(z2, mask, a, c)))
    assert d_out <= d_in + 1e-3


@settings(max_examples=20, deadline=None)
@given(L=st.integers(1, 8), R=st.integers(1, 10), K=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_projection_matches_ref(L, R, K, seed):
    rng = np.random.default_rng(seed)
    z, mask, a, c = make_problem(rng, L, R, K)
    np.testing.assert_allclose(np.asarray(project(z, mask, a, c)),
                               np.asarray(ref.project_ref(z, mask, a, c)),
                               atol=5e-4)


def test_projection_interior_point_untouched():
    """A point already in the interior of Y must be returned unchanged."""
    rng = np.random.default_rng(0)
    L, R, K = 4, 6, 3
    mask = jnp.ones((L, R), jnp.float32)
    a = jnp.full((L, K), 10.0, jnp.float32)
    c = jnp.full((R, K), 100.0, jnp.float32)
    z = jnp.asarray(rng.random((L, R, K)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(project(z, mask, a, c)),
                               np.asarray(z), atol=1e-6)


def test_projection_kkt_optimality():
    """Check v is the *closest* feasible point, not just feasible:
    compare against a dense random sample of feasible alternatives."""
    rng = np.random.default_rng(42)
    L, R, K = 5, 4, 3
    z, mask, a, c = make_problem(rng, L, R, K)
    v = np.asarray(project(z, mask, a, c))
    dist_v = np.linalg.norm(v - np.asarray(z) * np.asarray(mask)[:, :, None])
    for _ in range(200):
        w = rng.random((L, R, K)).astype(np.float32) * np.asarray(a)[:, None, :]
        w *= np.asarray(mask)[:, :, None]
        # rescale columns to satisfy capacity
        use = w.sum(axis=0)
        scale = np.minimum(1.0, np.asarray(c) / np.maximum(use, 1e-9))
        w *= scale[None]
        assert feasible(w, mask, a, c)
        assert np.linalg.norm(w - np.asarray(z) * np.asarray(mask)[:, :, None]) \
            >= dist_v - 1e-3


def test_water_level_matches_paper_rho():
    """On an interior-free instance, tau must equal rho/2 of Eq. 35."""
    # Single (r, k), 3 ports, no a-cap binding, capacity binding:
    z = jnp.asarray(np.array([[[3.0]], [[2.0]], [[1.0]]], np.float32))
    mask = jnp.ones((3, 1), jnp.float32)
    a = jnp.full((3, 1), 10.0, jnp.float32)
    c = jnp.full((1, 1), 3.0, jnp.float32)
    v = np.asarray(project(z, mask, a, c))[:, 0, 0]
    # B3 = {all}; rho/2 = (sum z - c)/|B3| = (6-3)/3 = 1  =>  v = z - 1
    np.testing.assert_allclose(v, [2.0, 1.0, 0.0], atol=1e-4)
