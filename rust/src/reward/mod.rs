//! The reward model of Sec. 2.2–2.3: per-port reward (Eq. 7), slot
//! aggregation (Eq. 8), and the Thm. 1 quantities used by the regret
//! experiments.
//!
//! §Perf-5: [`slot_reward_ports_sharded`] is the pool-scattered form of
//! [`slot_reward_kinds`] — per-port kernels fan out, the (gain, pen)
//! components merge serially in ascending port order, so the sharded
//! evaluation is bit-identical to the serial loop.  It serves both the
//! sharded leader's per-slot scoring (`coordinator::sharded`) and the
//! per-iteration objective of the sharded Eq. 50 oracle solve
//! (`regret::solve_oracle`).

use crate::model::{KindIndex, Problem};
use crate::obs;
use crate::oga::kernels;
use crate::utils::pool::{self, SyncSlice};

thread_local! {
    /// Per-thread [K] quota scratch for pool-scattered per-port kernels
    /// (the sharded reward/objective and the sharded phase-A quota/k*
    /// reductions).
    static QUOTA_TLS: std::cell::RefCell<Vec<f64>> = std::cell::RefCell::new(Vec::new());
}

/// Run `f` on this thread's [K] quota scratch (grown on demand, handed
/// out at exactly `k_n` — the length the per-port kernels assert).
pub(crate) fn with_quota<R>(k_n: usize, f: impl FnOnce(&mut [f64]) -> R) -> R {
    QUOTA_TLS.with(|q| {
        let quota = &mut *q.borrow_mut();
        if quota.len() < k_n {
            quota.resize(k_n, 0.0);
        }
        f(&mut quota[..k_n])
    })
}

/// Decomposed slot reward: q = gain − penalty summed over arrived ports.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SlotReward {
    /// Σ_l x_l (gain_l − penalty_l) — Eq. 8.
    pub q: f64,
    /// Σ_l x_l gain_l (parallel-computation gain term of Eq. 7).
    pub gain: f64,
    /// Σ_l x_l penalty_l (dominant communication overhead term).
    pub penalty: f64,
}

/// Per-port reward decomposition for one port (Eq. 7, without the x_l
/// arrival factor).  `y` is edge-major [E, K], so port l's coordinates
/// are one contiguous slice.  Convenience wrapper that allocates its
/// quota scratch; loop-called code must use [`port_reward_scratch`]
/// (the seed's version heap-allocated per call inside the slot loop).
pub fn port_reward(problem: &Problem, l: usize, y: &[f64]) -> (f64, f64) {
    let mut quota = vec![0.0; problem.num_resources];
    port_reward_scratch(problem, l, y, &mut quota)
}

/// Allocation-free per-port reward: caller supplies the [K] quota
/// scratch.  Returns (gain_l, penalty_l).
pub fn port_reward_scratch(
    problem: &Problem,
    l: usize,
    y: &[f64],
    quota: &mut [f64],
) -> (f64, f64) {
    let k_n = problem.num_resources;
    let g = &problem.graph;
    debug_assert_eq!(quota.len(), k_n);
    let mut gain = 0.0;
    quota.fill(0.0);
    for e in g.port_edges(l) {
        let base = e * k_n;
        let rk = g.edge_instance[e] * k_n;
        for k in 0..k_n {
            let v = y[base + k];
            gain += problem.kind[rk + k].value(v, problem.alpha[rk + k]);
            quota[k] += v;
        }
    }
    let penalty = (0..k_n)
        .map(|k| problem.beta[k] * quota[k])
        .fold(f64::NEG_INFINITY, f64::max)
        .max(0.0);
    (gain, penalty)
}

/// Slot reward q(x(t), y(t)) with gain/penalty breakdown (Eqs. 7–8).
/// Convenience wrapper (one scratch allocation per call).
pub fn slot_reward(problem: &Problem, x: &[f64], y: &[f64]) -> SlotReward {
    let mut quota = vec![0.0; problem.num_resources];
    slot_reward_scratch(problem, x, y, &mut quota)
}

/// Allocation-free slot reward: caller supplies the [K] quota scratch.
/// This is the plain per-coordinate form, kept as the reference for the
/// kind-batched [`slot_reward_kinds`] the engine runs.
pub fn slot_reward_scratch(
    problem: &Problem,
    x: &[f64],
    y: &[f64],
    quota: &mut [f64],
) -> SlotReward {
    let mut out = SlotReward::default();
    for l in 0..problem.num_ports() {
        if x[l] == 0.0 {
            continue;
        }
        let (gain, penalty) = port_reward_scratch(problem, l, y, quota);
        out.gain += x[l] * gain;
        out.penalty += x[l] * penalty;
        out.q += x[l] * (gain - penalty);
    }
    out
}

/// Kind-batched per-port reward (gain_l, penalty_l) — the per-port body
/// of [`slot_reward_kinds`], exposed so the sharded leader can fan the
/// ports out over the pool and still merge the *identical* per-port
/// floats the serial loop accumulates (`coordinator::sharded`).
pub fn port_reward_kinds(
    problem: &Problem,
    kinds: &KindIndex,
    l: usize,
    y: &[f64],
    quota: &mut [f64],
) -> (f64, f64) {
    let k_n = problem.num_resources;
    let g = &problem.graph;
    debug_assert_eq!(quota.len(), k_n);
    let mut gain = 0.0;
    for run in kinds.port_runs(l) {
        gain += run
            .kind
            .value_sum(&y[run.lo..run.hi], &kinds.alpha_flat[run.lo..run.hi]);
    }
    quota.fill(0.0);
    for e in g.port_edges(l) {
        let base = e * k_n;
        kernels::accumulate(quota, &y[base..base + k_n]);
    }
    let mut penalty = 0.0f64;
    for k in 0..k_n {
        penalty = penalty.max(problem.beta[k] * quota[k]);
    }
    (gain, penalty)
}

/// Kind-batched slot reward (§Perf-2) — the engine's hot-path variant.
/// The Eq. 51 gain is summed run-by-run through the [`KindIndex`] (one
/// utility-family dispatch per same-kind run, branch-free contiguous
/// passes); the quota/penalty term is the same strided accumulation as
/// the scratch variant.  Cost is O(Σ_{l: x_l>0} |R_l|·K) with no
/// per-coordinate `match`.
pub fn slot_reward_kinds(
    problem: &Problem,
    kinds: &KindIndex,
    x: &[f64],
    y: &[f64],
    quota: &mut [f64],
) -> SlotReward {
    let mut out = SlotReward::default();
    for l in 0..problem.num_ports() {
        if x[l] == 0.0 {
            continue;
        }
        let (gain, penalty) = port_reward_kinds(problem, kinds, l, y, quota);
        out.gain += x[l] * gain;
        out.penalty += x[l] * penalty;
        out.q += x[l] * (gain - penalty);
    }
    out
}

/// Reusable scratch of [`slot_reward_ports_sharded`]: per-arrived-
/// position (gain, penalty) slots the scatter writes into before the
/// serial merge.
#[derive(Clone, Debug, Default)]
pub struct PortRewardScratch {
    gain: Vec<f64>,
    pen: Vec<f64>,
}

/// Pool-scattered [`slot_reward_kinds`] (§Perf-5): the per-port reward
/// kernels fan out over up to `workers` pool workers (dispatch follows
/// the calling thread's scope — global crew, or a leased shard group
/// inside a budgeted lane), then the components merge serially in
/// ascending port order — the exact accumulation sequence of the serial
/// loop, so the result is **bit-identical** to
/// `slot_reward_kinds(problem, kinds, x, y, ..)` by construction
/// (pinned by `tests/shard_parity.rs`).
///
/// `arrived` must be exactly the ports with `x[l] != 0`, ascending —
/// the caller owns the list because both users already have it (the
/// sharded leader rebuilds it per slot; the oracle solve's counts are
/// fixed, so it is computed once per solve).
pub fn slot_reward_ports_sharded(
    problem: &Problem,
    kinds: &KindIndex,
    x: &[f64],
    y: &[f64],
    arrived: &[usize],
    workers: usize,
    scratch: &mut PortRewardScratch,
) -> SlotReward {
    debug_assert!(arrived.windows(2).all(|w| w[0] < w[1]), "arrived ports must ascend");
    debug_assert!(arrived.iter().all(|&l| x[l] != 0.0));
    if arrived.is_empty() {
        return SlotReward::default();
    }
    let n = arrived.len();
    scratch.gain.resize(n, 0.0);
    scratch.pen.resize(n, 0.0);
    {
        let gains = SyncSlice::new(&mut scratch.gain);
        let pens = SyncSlice::new(&mut scratch.pen);
        let k_n = problem.num_resources;
        // slot context for the per-task reward spans (the scatter runs
        // on pool workers, whose thread-local slot tag is unset)
        let slot = pool::current_slot();
        pool::parallel_for(n, workers, |i| {
            obs::with_span(obs::SpanKind::ShardReward, slot, i as u32, || {
                let (gain, pen) = with_quota(k_n, |quota| {
                    port_reward_kinds(problem, kinds, arrived[i], y, quota)
                });
                // SAFETY: each arrived position is handed to exactly one task.
                unsafe {
                    gains.write(i, gain);
                    pens.write(i, pen);
                }
            });
        });
    }
    let mut out = SlotReward::default();
    for (i, &l) in arrived.iter().enumerate() {
        let x_l = x[l];
        let gain = scratch.gain[i];
        let penalty = scratch.pen[i];
        out.gain += x_l * gain;
        out.penalty += x_l * penalty;
        out.q += x_l * (gain - penalty);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::graph::Bipartite;
    use crate::oga::utilities::UtilityKind;
    use crate::traces::synthesize;
    use crate::utils::rng::Rng;

    fn tiny() -> Problem {
        Problem::new(
            Bipartite::full(1, 2),
            2,
            vec![10.0, 10.0],
            vec![10.0; 4],
            vec![1.0, 2.0, 1.5, 0.5],
            vec![
                UtilityKind::Linear,
                UtilityKind::Log,
                UtilityKind::Poly,
                UtilityKind::Reciprocal,
            ],
            vec![0.5, 0.25],
        )
    }

    #[test]
    fn hand_computed_reward() {
        let p = tiny();
        let mut y = vec![0.0; p.decision_len()];
        y[p.idx(0, 0, 0)] = 2.0; // linear alpha=1 -> 2.0
        y[p.idx(0, 0, 1)] = 3.0; // log alpha=2 -> 2 ln 4
        y[p.idx(0, 1, 0)] = 1.0; // poly alpha=1.5 -> 1.5(sqrt2 - 1)
        y[p.idx(0, 1, 1)] = 0.5; // reciprocal alpha=0.5 -> 2 - 1/1 = 1
        let gain = 2.0 + 2.0 * 4.0f64.ln() + 1.5 * (2.0f64.sqrt() - 1.0) + 1.0;
        // quotas: k0 = 3.0, k1 = 3.5 -> penalty = max(1.5, 0.875) = 1.5
        let r = slot_reward(&p, &[1.0], &y);
        assert!((r.gain - gain).abs() < 1e-12);
        assert!((r.penalty - 1.5).abs() < 1e-12);
        assert!((r.q - (gain - 1.5)).abs() < 1e-12);
    }

    #[test]
    fn zero_allocation_zero_reward() {
        let p = tiny();
        let y = vec![0.0; p.decision_len()];
        let r = slot_reward(&p, &[1.0], &y);
        assert_eq!(r, SlotReward { q: 0.0, gain: 0.0, penalty: 0.0 });
    }

    #[test]
    fn arrivals_gate_reward() {
        let p = tiny();
        let mut y = vec![0.0; p.decision_len()];
        y[p.idx(0, 0, 0)] = 1.0;
        assert_eq!(slot_reward(&p, &[0.0], &y).q, 0.0);
        assert!(slot_reward(&p, &[1.0], &y).q > 0.0);
        // multi-arrival (Sec. 3.4): x_l = 2 doubles the port contribution
        let r1 = slot_reward(&p, &[1.0], &y);
        let r2 = slot_reward(&p, &[2.0], &y);
        assert!((r2.q - 2.0 * r1.q).abs() < 1e-12);
    }

    #[test]
    fn scratch_variant_matches() {
        let p = synthesize(&Scenario::small());
        let mut rng = Rng::new(5);
        let y: Vec<f64> = (0..p.decision_len())
            .map(|_| rng.uniform(0.0, 0.5))
            .collect();
        let x: Vec<f64> =
            (0..p.num_ports()).map(|_| if rng.bernoulli(0.7) { 1.0 } else { 0.0 }).collect();
        let a = slot_reward(&p, &x, &y);
        let mut quota = vec![0.0; p.num_resources];
        let b = slot_reward_scratch(&p, &x, &y, &mut quota);
        assert!((a.q - b.q).abs() < 1e-12);
        assert!((a.gain - b.gain).abs() < 1e-12);
        assert!((a.penalty - b.penalty).abs() < 1e-12);
    }

    #[test]
    fn kind_batched_variant_matches() {
        // mixed utility families per (r, k) so every run kind is hit
        let p = synthesize(&Scenario::small());
        let kinds = KindIndex::build(&p);
        kinds.validate(&p).unwrap();
        let mut rng = Rng::new(9);
        let y: Vec<f64> = (0..p.decision_len())
            .map(|_| rng.uniform(0.0, 0.8))
            .collect();
        let x: Vec<f64> =
            (0..p.num_ports()).map(|_| if rng.bernoulli(0.5) { 2.0 } else { 0.0 }).collect();
        let a = slot_reward(&p, &x, &y);
        let mut quota = vec![0.0; p.num_resources];
        let b = slot_reward_kinds(&p, &kinds, &x, &y, &mut quota);
        assert!((a.q - b.q).abs() < 1e-9 * (1.0 + a.q.abs()));
        assert!((a.gain - b.gain).abs() < 1e-9 * (1.0 + a.gain.abs()));
        assert!((a.penalty - b.penalty).abs() < 1e-9 * (1.0 + a.penalty.abs()));
    }

    #[test]
    fn sharded_slot_reward_matches_serial_bitwise() {
        // the §Perf-5 pool-scattered evaluation merges per-port floats
        // in the serial accumulation order — results are identical, not
        // merely close (the full property matrix is in shard_parity)
        let p = synthesize(&Scenario::small());
        let kinds = p.kinds();
        let mut rng = Rng::new(31);
        let y: Vec<f64> = (0..p.decision_len()).map(|_| rng.uniform(0.0, 1.2)).collect();
        for rho in [0.0, 0.3, 1.0] {
            let x: Vec<f64> = (0..p.num_ports())
                .map(|_| if rng.bernoulli(rho) { (1 + rng.below(40)) as f64 } else { 0.0 })
                .collect();
            let arrived: Vec<usize> =
                (0..p.num_ports()).filter(|&l| x[l] != 0.0).collect();
            let mut quota = vec![0.0; p.num_resources];
            let want = slot_reward_kinds(&p, kinds, &x, &y, &mut quota);
            for workers in [1, 2, 3, 7] {
                let mut scratch = PortRewardScratch::default();
                let got = slot_reward_ports_sharded(
                    &p, kinds, &x, &y, &arrived, workers, &mut scratch,
                );
                assert_eq!(got, want, "rho={rho} workers={workers}");
            }
        }
    }

    #[test]
    fn port_reward_scratch_matches_convenience() {
        let p = synthesize(&Scenario::small());
        let mut rng = Rng::new(3);
        let y: Vec<f64> =
            (0..p.decision_len()).map(|_| rng.uniform(0.0, 1.5)).collect();
        let mut quota = vec![0.0; p.num_resources];
        for l in 0..p.num_ports() {
            let (g1, p1) = port_reward(&p, l, &y);
            let (g2, p2) = port_reward_scratch(&p, l, &y, &mut quota);
            assert_eq!(g1, g2);
            assert_eq!(p1, p2);
        }
    }

    #[test]
    fn reward_monotone_in_capacity_gain() {
        // more allocation (feasible) should not decrease the gain term
        let p = tiny();
        let mut y1 = vec![0.0; p.decision_len()];
        y1[p.idx(0, 0, 0)] = 1.0;
        let mut y2 = y1.clone();
        y2[p.idx(0, 1, 0)] = 1.0;
        let r1 = slot_reward(&p, &[1.0], &y1);
        let r2 = slot_reward(&p, &[1.0], &y2);
        assert!(r2.gain > r1.gain);
    }
}
