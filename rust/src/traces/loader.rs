//! CSV trace loader: build a [`Problem`] from machine/job spec files in
//! the schema our Alibaba extraction would produce.  An embedded sample
//! (data/machines_sample.csv, data/jobs_sample.csv) doubles as format
//! documentation and as a fixture for tests and the quickstart.
//!
//! machines.csv:  instance,class,cpu,mem,gpu,npu,tpu,fpga
//! jobs.csv:      job_type,class,cpu,mem,gpu,npu,tpu,fpga,weight

use crate::config::{GraphSpec, Scenario};
use crate::graph::Bipartite;
use crate::model::Problem;
use crate::oga::utilities::{UtilityKind, UtilityMix};
use crate::utils::csv::Csv;
use crate::utils::rng::Rng;

pub const MACHINES_SAMPLE: &str = include_str!("data/machines_sample.csv");
pub const JOBS_SAMPLE: &str = include_str!("data/jobs_sample.csv");

const DEVICE_COLS: [&str; 6] = ["cpu", "mem", "gpu", "npu", "tpu", "fpga"];

/// Parsed machine rows: capacities [R, 6].
pub fn parse_machines(text: &str) -> Result<Vec<[f64; 6]>, String> {
    let csv = Csv::parse(text)?;
    let cols: Vec<Vec<f64>> = DEVICE_COLS
        .iter()
        .map(|c| csv.col_f64(c).ok_or_else(|| format!("machines csv missing column {c}")))
        .collect::<Result<_, _>>()?;
    let n = csv.rows.len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut row = [0.0; 6];
        for (k, col) in cols.iter().enumerate() {
            if col[i].is_nan() || col[i] < 0.0 {
                return Err(format!("machines row {i}: bad {}", DEVICE_COLS[k]));
            }
            row[k] = col[i];
        }
        out.push(row);
    }
    Ok(out)
}

/// Parsed job rows: (demands [L, 6], arrival weights [L]).
pub fn parse_jobs(text: &str) -> Result<(Vec<[f64; 6]>, Vec<f64>), String> {
    let csv = Csv::parse(text)?;
    let cols: Vec<Vec<f64>> = DEVICE_COLS
        .iter()
        .map(|c| csv.col_f64(c).ok_or_else(|| format!("jobs csv missing column {c}")))
        .collect::<Result<_, _>>()?;
    let weights = csv.col_f64("weight").ok_or("jobs csv missing column weight")?;
    let n = csv.rows.len();
    let mut demands = Vec::with_capacity(n);
    for i in 0..n {
        let mut row = [0.0; 6];
        for (k, col) in cols.iter().enumerate() {
            if col[i].is_nan() || col[i] < 0.0 {
                return Err(format!("jobs row {i}: bad {}", DEVICE_COLS[k]));
            }
            row[k] = col[i];
        }
        if weights[i].is_nan() || weights[i] <= 0.0 {
            return Err(format!("jobs row {i}: bad weight"));
        }
        demands.push(row);
    }
    Ok((demands, weights))
}

/// Build a Problem from explicit machine/job CSV text.  The scenario's
/// |L|/|R| are taken from the files (cycled if the scenario asks for
/// more); contention, graph spec, utilities and seeding come from the
/// scenario as usual.
pub fn problem_from_csv(
    scenario: &Scenario,
    machines_csv: &str,
    jobs_csv: &str,
) -> Result<Problem, String> {
    let machines = parse_machines(machines_csv)?;
    let (jobs, _weights) = parse_jobs(jobs_csv)?;
    if machines.is_empty() || jobs.is_empty() {
        return Err("empty machines/jobs csv".into());
    }
    let k_n = scenario.num_resources.min(6);
    let (l_n, r_n) = (scenario.num_ports, scenario.num_instances);
    let mut rng = Rng::new(scenario.seed);

    let mut graph_rng = rng.fork(0x67726170);
    let graph = match scenario.graph {
        GraphSpec::Full => Bipartite::full(l_n, r_n),
        GraphSpec::RightRegular(d) => Bipartite::right_regular(l_n, r_n, d, &mut graph_rng),
        GraphSpec::Density(d) => Bipartite::random_density(l_n, r_n, d, &mut graph_rng),
    };

    let mut capacity = vec![0.0; r_n * k_n];
    for r in 0..r_n {
        let m = &machines[r % machines.len()];
        for k in 0..k_n {
            capacity[r * k_n + k] = m[k].max(1.0);
        }
    }
    let mut demand = vec![0.0; l_n * k_n];
    for l in 0..l_n {
        let j = &jobs[l % jobs.len()];
        for k in 0..k_n {
            demand[l * k_n + k] = (j[k] * scenario.contention).max(0.25);
        }
    }

    let mut util_rng = rng.fork(0x7574696c);
    let (alo, ahi) = scenario.alpha_range;
    let alpha: Vec<f64> = (0..r_n * k_n).map(|_| util_rng.uniform(alo, ahi)).collect();
    let kind: Vec<UtilityKind> = (0..r_n * k_n)
        .map(|_| match scenario.utility_mix {
            UtilityMix::All(kind) => kind,
            UtilityMix::Mixed => UtilityKind::ALL[util_rng.below(4)],
        })
        .collect();
    let (blo, bhi) = scenario.beta_range;
    let beta: Vec<f64> = (0..k_n).map(|_| util_rng.uniform(blo, bhi)).collect();

    Ok(Problem::new(graph, k_n, demand, capacity, alpha, kind, beta))
}

/// Arrival weights from the sample jobs file (used by the trace-driven
/// arrival model).  Errors name the port count so a bad embedded sample
/// surfaces as a diagnosable failure rather than a panic deep in a run.
pub fn sample_arrival_weights(num_ports: usize) -> Result<Vec<f64>, String> {
    let (_, w) = parse_jobs(JOBS_SAMPLE)
        .map_err(|e| format!("embedded jobs sample invalid (need weights for {num_ports} ports): {e}"))?;
    if w.is_empty() {
        return Err(format!("embedded jobs sample has no rows (need weights for {num_ports} ports)"));
    }
    Ok((0..num_ports).map(|l| w[l % w.len()]).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedded_samples_parse() {
        let m = parse_machines(MACHINES_SAMPLE).unwrap();
        let (j, w) = parse_jobs(JOBS_SAMPLE).unwrap();
        assert!(m.len() >= 8);
        assert!(j.len() >= 5);
        assert_eq!(j.len(), w.len());
    }

    #[test]
    fn problem_from_samples() {
        let mut s = Scenario::small();
        s.contention = 1.0;
        let p = problem_from_csv(&s, MACHINES_SAMPLE, JOBS_SAMPLE).unwrap();
        assert_eq!(p.capacity.len(), s.num_instances * s.num_resources);
        assert_eq!(p.demand.len(), s.num_ports * s.num_resources);
        assert!(p.demand.iter().all(|&d| d > 0.0));
        p.graph.validate().unwrap();
    }

    #[test]
    fn bad_csv_rejected() {
        assert!(parse_machines("instance,cpu\nm1,4\n").is_err()); // missing cols
        assert!(parse_jobs("job_type,cpu,mem,gpu,npu,tpu,fpga\nj,1,1,0,0,0,0\n").is_err()); // no weight
        let bad = "instance,class,cpu,mem,gpu,npu,tpu,fpga\nm1,c,-1,1,0,0,0,0\n";
        assert!(parse_machines(bad).is_err());
    }

    #[test]
    fn scenario_larger_than_file_cycles() {
        let mut s = Scenario::small();
        s.num_instances = 64; // sample has fewer machines; must cycle
        let p = problem_from_csv(&s, MACHINES_SAMPLE, JOBS_SAMPLE).unwrap();
        assert_eq!(p.capacity.len(), 64 * s.num_resources);
    }
}
