//! Workload synthesis and trace loading (the paper's "trace-driven
//! simulation" substrate).  `alibaba` synthesizes clusters shaped like
//! the Alibaba cluster-trace extraction the paper uses; `loader` reads
//! real extractions from CSV.

pub mod alibaba;
pub mod loader;

pub use alibaba::synthesize;
pub use loader::problem_from_csv;
