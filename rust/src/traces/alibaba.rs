//! Alibaba-like cluster synthesis.
//!
//! The paper hybridizes `cluster-trace-v2018` and `cluster-trace-gpu-v2020`
//! "leveraging the specifications of the machines, the arrival patterns,
//! and the resource requirements of different kinds of jobs".  Those raw
//! traces are not redistributable inside this offline image, so this
//! module synthesizes a cluster with the same *shape* (see DESIGN.md §3):
//!
//!  * heterogeneous instance classes mirroring the trace's machine mix
//!    (CPU-heavy web tier, balanced batch tier, GPU boxes of the v2020
//!    trace, and accelerator-rich nodes standing in for NPU/TPU/FPGA
//!    pools — the paper's K = 6 device types);
//!  * job families with distinct dominant resources and log-normal size
//!    spread (batch analytics, DNN training, graph computation,
//!    federated learning, inference serving);
//!  * arrival stochasticity applied as Bernoulli(ρ) thinning on top of a
//!    per-port base intensity, exactly how Tab. 2's ρ knob works.
//!
//! Real trace extractions in the same CSV schema can be loaded instead
//! via [`super::loader`].

use crate::config::{GraphSpec, Scenario};
use crate::graph::Bipartite;
use crate::model::Problem;
use crate::oga::utilities::{UtilityKind, UtilityMix};
use crate::utils::rng::Rng;

/// An instance class: capacity ranges per device type
/// [CPU, MEM, GPU, NPU, TPU, FPGA] and a population weight.
#[derive(Clone, Debug)]
pub struct InstanceClass {
    pub name: &'static str,
    pub capacity_lo: [f64; 6],
    pub capacity_hi: [f64; 6],
    pub weight: f64,
}

/// A job family: per-device demand ranges and a popularity weight
/// (port base intensity).
#[derive(Clone, Debug)]
pub struct JobClass {
    pub name: &'static str,
    pub demand_lo: [f64; 6],
    pub demand_hi: [f64; 6],
    pub weight: f64,
}

/// Machine mix modeled on the v2018 (CPU/MEM) + gpu-v2020 (GPU) traces,
/// extended with accelerator pools for the paper's K = 6 device types.
///
/// Capacities are in *allocation units*, normalized so the six device
/// types live on comparable scales (CPU in cores, MEM in 8-GiB blocks,
/// accelerators in quarter-device shares).  The normalization matters:
/// the Eq. 7 penalty takes a max over beta_k * quota_k, so a device type
/// whose raw unit is 100x larger (e.g. MEM in GiB) would own the penalty
/// for every job and drown the remaining five types' gains — an artifact
/// of units, not of scheduling.  Classes still specialize (a web tier
/// has ~4x the CPU of an FPGA box, GPU boxes own the GPUs).
pub fn instance_classes() -> Vec<InstanceClass> {
    vec![
        InstanceClass {
            name: "web-cpu",
            capacity_lo: [48.0, 32.0, 2.0, 2.0, 2.0, 2.0],
            capacity_hi: [96.0, 64.0, 4.0, 4.0, 4.0, 4.0],
            weight: 0.35,
        },
        InstanceClass {
            name: "batch-balanced",
            capacity_lo: [32.0, 24.0, 8.0, 4.0, 4.0, 4.0],
            capacity_hi: [64.0, 48.0, 16.0, 8.0, 8.0, 8.0],
            weight: 0.30,
        },
        InstanceClass {
            name: "gpu-v100-box",
            capacity_lo: [24.0, 16.0, 32.0, 4.0, 4.0, 4.0],
            capacity_hi: [48.0, 32.0, 64.0, 8.0, 8.0, 8.0],
            weight: 0.15,
        },
        InstanceClass {
            name: "npu-pool",
            capacity_lo: [16.0, 12.0, 4.0, 32.0, 4.0, 4.0],
            capacity_hi: [32.0, 24.0, 8.0, 64.0, 8.0, 8.0],
            weight: 0.08,
        },
        InstanceClass {
            name: "tpu-pod-slice",
            capacity_lo: [16.0, 12.0, 4.0, 4.0, 32.0, 4.0],
            capacity_hi: [32.0, 24.0, 8.0, 8.0, 64.0, 8.0],
            weight: 0.07,
        },
        InstanceClass {
            name: "fpga-smartnic",
            capacity_lo: [16.0, 12.0, 4.0, 4.0, 4.0, 32.0],
            capacity_hi: [32.0, 24.0, 8.0, 8.0, 8.0, 64.0],
            weight: 0.05,
        },
    ]
}

/// Job families with distinct dominant resources (the workloads the
/// paper's introduction motivates).  Same allocation units as
/// [`instance_classes`]; demands are per-channel maxima a_l^k *before*
/// the contention multiplier.
pub fn job_classes() -> Vec<JobClass> {
    vec![
        JobClass {
            name: "batch-analytics",
            demand_lo: [1.0, 0.8, 0.1, 0.1, 0.1, 0.1],
            demand_hi: [4.0, 3.0, 0.4, 0.4, 0.4, 0.4],
            weight: 0.30,
        },
        JobClass {
            name: "dnn-training",
            demand_lo: [0.5, 0.4, 1.0, 0.1, 0.5, 0.1],
            demand_hi: [2.0, 1.5, 4.0, 0.4, 2.0, 0.4],
            weight: 0.20,
        },
        JobClass {
            name: "graph-compute",
            demand_lo: [2.0, 1.5, 0.1, 0.1, 0.1, 0.1],
            demand_hi: [6.0, 4.0, 0.4, 0.4, 0.4, 0.4],
            weight: 0.15,
        },
        JobClass {
            name: "federated-learning",
            demand_lo: [0.5, 0.4, 0.2, 1.0, 0.1, 0.1],
            demand_hi: [2.0, 1.5, 1.0, 4.0, 0.4, 0.4],
            weight: 0.15,
        },
        JobClass {
            name: "inference-serving",
            demand_lo: [0.5, 0.4, 0.2, 0.2, 0.1, 0.5],
            demand_hi: [1.5, 1.2, 1.0, 1.0, 0.4, 2.0],
            weight: 0.20,
        },
    ]
}

/// Synthesize a full [`Problem`] from a [`Scenario`].
///
/// Deterministic in `scenario.seed`.  Capacities/demands are sampled per
/// class with log-normal jitter; demands are scaled by the contention
/// level; a floor keeps every (l, k) demand strictly positive so the
/// gradient is defined everywhere (a zero-demand channel is representable
/// but makes several baselines degenerate at no benefit).
pub fn synthesize(scenario: &Scenario) -> Problem {
    let mut rng = Rng::new(scenario.seed);
    let k_n = scenario.num_resources;
    let (l_n, r_n) = (scenario.num_ports, scenario.num_instances);

    // --- graph ---
    let mut graph_rng = rng.fork(0x67726170);
    let graph = match scenario.graph {
        GraphSpec::Full => Bipartite::full(l_n, r_n),
        GraphSpec::RightRegular(d) => Bipartite::right_regular(l_n, r_n, d, &mut graph_rng),
        GraphSpec::Density(d) => Bipartite::random_density(l_n, r_n, d, &mut graph_rng),
    };

    // --- instances: class mix -> capacities [R, K] ---
    let classes = instance_classes();
    let weights: Vec<f64> = classes.iter().map(|c| c.weight).collect();
    let mut capacity = vec![0.0f64; r_n * k_n];
    let mut cap_rng = rng.fork(0x63617073);
    for r in 0..r_n {
        let class = &classes[cap_rng.categorical(&weights)];
        for k in 0..k_n {
            let (lo, hi) = (class.capacity_lo[k % 6], class.capacity_hi[k % 6]);
            let base = cap_rng.uniform(lo, hi);
            // log-normal jitter (sigma 0.2) reproduces the heavy spread of
            // machine SKUs in the trace; floor keeps capacity >= 1.
            capacity[r * k_n + k] = (base * cap_rng.log_normal(0.0, 0.2)).max(1.0);
        }
    }

    // --- jobs: family mix -> demands [L, K], scaled by contention ---
    let families = job_classes();
    let fam_weights: Vec<f64> = families.iter().map(|f| f.weight).collect();
    let mut demand = vec![0.0f64; l_n * k_n];
    let mut dem_rng = rng.fork(0x64656d73);
    for l in 0..l_n {
        let fam = &families[dem_rng.categorical(&fam_weights)];
        for k in 0..k_n {
            let (lo, hi) = (fam.demand_lo[k % 6], fam.demand_hi[k % 6]);
            let base = dem_rng.uniform(lo, hi) * dem_rng.log_normal(0.0, 0.3);
            // Contention multiplies requirements (Tab. 2); keep a small
            // floor so every (l, k) pair stays schedulable.
            demand[l * k_n + k] = (base * scenario.contention).max(0.25);
        }
    }

    // --- utilities: alpha, family kind per (r, k); beta per k ---
    let mut util_rng = rng.fork(0x7574696c);
    let (alo, ahi) = scenario.alpha_range;
    let alpha: Vec<f64> = (0..r_n * k_n).map(|_| util_rng.uniform(alo, ahi)).collect();
    let kind: Vec<UtilityKind> = (0..r_n * k_n)
        .map(|_| match scenario.utility_mix {
            UtilityMix::All(kind) => kind,
            UtilityMix::Mixed => UtilityKind::ALL[util_rng.below(4)],
        })
        .collect();
    let (blo, bhi) = scenario.beta_range;
    let beta: Vec<f64> = (0..k_n).map(|_| util_rng.uniform(blo, bhi)).collect();

    Problem::new(graph, k_n, demand, capacity, alpha, kind, beta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oga::utilities::UtilityMix;

    #[test]
    fn deterministic_in_seed() {
        let s = Scenario::small();
        let a = synthesize(&s);
        let b = synthesize(&s);
        assert_eq!(a.demand, b.demand);
        assert_eq!(a.capacity, b.capacity);
        assert_eq!(a.alpha, b.alpha);
        assert_eq!(a.graph.mask, b.graph.mask);
    }

    #[test]
    fn different_seed_different_cluster() {
        let mut s2 = Scenario::small();
        s2.seed = 999;
        let a = synthesize(&Scenario::small());
        let b = synthesize(&s2);
        assert_ne!(a.capacity, b.capacity);
    }

    #[test]
    fn shapes_and_positivity() {
        let s = Scenario::default();
        let p = synthesize(&s);
        assert_eq!(p.demand.len(), 10 * 6);
        assert_eq!(p.capacity.len(), 128 * 6);
        assert_eq!(p.alpha.len(), 128 * 6);
        assert_eq!(p.beta.len(), 6);
        assert!(p.demand.iter().all(|&d| d > 0.0));
        assert!(p.capacity.iter().all(|&c| c >= 1.0));
        assert!(p.alpha.iter().all(|&a| (1.0..=1.5).contains(&a)));
        assert!(p.beta.iter().all(|&b| (0.3..=0.5).contains(&b)));
        p.graph.validate().unwrap();
    }

    #[test]
    fn contention_scales_demand() {
        let mut lo = Scenario::small();
        lo.contention = 1.0;
        let mut hi = lo.clone();
        hi.contention = 10.0;
        let p_lo = synthesize(&lo);
        let p_hi = synthesize(&hi);
        let sum_lo: f64 = p_lo.demand.iter().sum();
        let sum_hi: f64 = p_hi.demand.iter().sum();
        assert!(sum_hi > 5.0 * sum_lo, "contention should scale demands");
    }

    #[test]
    fn all_utility_mix_applies() {
        let mut s = Scenario::small();
        s.utility_mix = UtilityMix::All(UtilityKind::Log);
        let p = synthesize(&s);
        assert!(p.kind.iter().all(|&k| k == UtilityKind::Log));
    }

    #[test]
    fn class_tables_are_normalized_enough() {
        let iw: f64 = instance_classes().iter().map(|c| c.weight).sum();
        let jw: f64 = job_classes().iter().map(|c| c.weight).sum();
        assert!((iw - 1.0).abs() < 1e-9);
        assert!((jw - 1.0).abs() < 1e-9);
    }
}
