//! Figure/table harnesses: one module per experiment in the paper's
//! evaluation section (see DESIGN.md §5 for the index).  Each harness
//! runs the relevant sweep, prints the paper-style table, and writes the
//! plotted series as CSV under `results/`.

pub mod churn;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod regret_fig;
pub mod sparse;
pub mod table3;

use std::path::PathBuf;

/// Where figure CSVs land.
pub fn results_dir() -> PathBuf {
    PathBuf::from("results")
}

/// Shared output bundle: rendered text + the CSV files written.
#[derive(Clone, Debug, Default)]
pub struct FigureOutput {
    pub title: String,
    pub rendered: String,
    pub csv_paths: Vec<PathBuf>,
}

impl std::fmt::Display for FigureOutput {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "== {} ==", self.title)?;
        write!(f, "{}", self.rendered)?;
        for p in &self.csv_paths {
            writeln!(f, "csv: {}", p.display())?;
        }
        Ok(())
    }
}

/// Run a figure by id ("fig2" ... "fig7", "table3", "regret").
/// `horizon_override` shrinks T for quick runs (0 = paper scale).
pub fn run_by_id(id: &str, horizon_override: usize) -> Result<FigureOutput, String> {
    match id {
        "fig2" => Ok(fig2::run(horizon_override)),
        "fig3" => Ok(fig3::run(horizon_override)),
        "fig4" => Ok(fig4::run(horizon_override)),
        "fig5" => Ok(fig5::run(horizon_override)),
        "fig6" => Ok(fig6::run(horizon_override)),
        "fig7" => Ok(fig7::run(horizon_override)),
        "table3" => Ok(table3::run(horizon_override)),
        "regret" => Ok(regret_fig::run(horizon_override)),
        "sparse" => Ok(sparse::run(horizon_override)),
        "churn" => Ok(churn::run(horizon_override)),
        other => Err(format!(
            "unknown figure id `{other}` (have fig2..fig7, table3, regret, sparse, churn)"
        )),
    }
}

pub const ALL_IDS: [&str; 10] =
    ["fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "table3", "regret", "sparse", "churn"];
