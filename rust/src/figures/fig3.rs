//! Fig. 3 — scalability: cumulative reward and OGASCHED/baseline ratio
//! under (a) |R| ∈ {32..512}, (b) |L| ∈ {5..50}, (c) contention level
//! ∈ {0.1..20}.  Expected shapes (Sec. 4.2): rewards grow with |R|;
//! |L| has a weaker effect than |R| (regret sublinear in |L|);
//! contention raises rewards up to ~1 then degrades them; OGASCHED
//! leads everywhere.

use crate::config::Scenario;
use crate::figures::{results_dir, FigureOutput};
use crate::sim;
use crate::utils::csv::Csv;
use crate::utils::pool;
use crate::utils::pool::ExecBudget;
use crate::utils::table::Table;

const INSTANCES: [usize; 5] = [32, 64, 128, 256, 512];
const PORTS: [usize; 4] = [5, 10, 20, 50];
const CONTENTION: [f64; 6] = [0.1, 0.5, 1.0, 5.0, 10.0, 20.0];

fn base(horizon_override: usize) -> Scenario {
    let mut s = Scenario::default();
    s.name = "fig3".into();
    if horizon_override > 0 {
        s.horizon = horizon_override;
    }
    s
}

/// One sweep: vary a scenario knob, return (labels, per-policy curves).
///
/// §Perf-4: sweep points are independent (policy, seed) bundles and fan
/// out under the auto [`ExecBudget`] split — up to `runs` concurrent
/// points, **each owning a private shard group** that the lineup nested
/// inside the point fans its policy runs over (`run_lineup` detects the
/// enclosing scope and keeps each run serial inside — two composed
/// levels, never a third).  Results are identical to the serial sweep:
/// every run is an independent (policy, seed) bundle and each run's
/// floats never depend on which lane or group executed it.
fn sweep(
    scenarios: Vec<(String, Scenario)>,
) -> (Vec<String>, Vec<String>, Vec<Vec<f64>>) {
    let labels: Vec<String> = scenarios.iter().map(|(l, _)| l.clone()).collect();
    let all = pool::scatter_map(scenarios.len(), ExecBudget::auto(), |i| {
        sim::run_paper_lineup(&scenarios[i].1)
    });
    let mut policy_names = Vec::new();
    let mut series: Vec<Vec<f64>> = Vec::new();
    for results in &all {
        if policy_names.is_empty() {
            policy_names = results.iter().map(|r| r.policy.clone()).collect();
            series = vec![Vec::new(); results.len()];
        }
        for (i, r) in results.iter().enumerate() {
            series[i].push(r.cumulative_reward);
        }
    }
    (labels, policy_names, series)
}

fn render_panel(
    title: &str,
    xlabel: &str,
    labels: &[String],
    policy_names: &[String],
    series: &[Vec<f64>],
    csv_file: &str,
    csv_paths: &mut Vec<std::path::PathBuf>,
) -> String {
    let mut header: Vec<&str> = vec![xlabel];
    let names: Vec<&str> = policy_names.iter().map(String::as_str).collect();
    header.extend(&names);
    header.push("OGA/best-baseline");
    let mut table = Table::new(&header);
    let mut csv = Csv::new(&header);
    for (i, label) in labels.iter().enumerate() {
        let mut row: Vec<String> = vec![label.clone()];
        let oga = series[0][i];
        let best_baseline =
            series[1..].iter().map(|s| s[i]).fold(f64::NEG_INFINITY, f64::max);
        for s in series {
            row.push(format!("{:.1}", s[i]));
        }
        let ratio = if best_baseline.abs() > 1e-9 { oga / best_baseline } else { 1.0 };
        row.push(format!("{ratio:.3}"));
        table.push(&row);
        csv.push_row(&row);
    }
    let path = results_dir().join(csv_file);
    let _ = csv.write_file(&path);
    csv_paths.push(path);
    format!("{title}\n{}", table.render())
}

pub fn run(horizon_override: usize) -> FigureOutput {
    let mut csv_paths = Vec::new();

    // (a) vary |R|
    let scenarios_a: Vec<(String, Scenario)> = INSTANCES
        .iter()
        .map(|&r| {
            let mut s = base(horizon_override);
            s.num_instances = r;
            (format!("{r}"), s)
        })
        .collect();
    let (la, pa, sa) = sweep(scenarios_a);
    let panel_a = render_panel(
        "(a) cumulative reward vs |R|",
        "|R|",
        &la,
        &pa,
        &sa,
        "fig3a_instances.csv",
        &mut csv_paths,
    );

    // (b) vary |L|
    let scenarios_b: Vec<(String, Scenario)> = PORTS
        .iter()
        .map(|&l| {
            let mut s = base(horizon_override);
            s.num_ports = l;
            (format!("{l}"), s)
        })
        .collect();
    let (lb, pb, sb) = sweep(scenarios_b);
    let panel_b = render_panel(
        "(b) cumulative reward vs |L|",
        "|L|",
        &lb,
        &pb,
        &sb,
        "fig3b_ports.csv",
        &mut csv_paths,
    );

    // (c) vary contention
    let scenarios_c: Vec<(String, Scenario)> = CONTENTION
        .iter()
        .map(|&c| {
            let mut s = base(horizon_override);
            s.contention = c;
            (format!("{c}"), s)
        })
        .collect();
    let (lc, pc, sc) = sweep(scenarios_c);
    let panel_c = render_panel(
        "(c) cumulative reward vs contention level",
        "contention",
        &lc,
        &pc,
        &sc,
        "fig3c_contention.csv",
        &mut csv_paths,
    );

    FigureOutput {
        title: "Fig. 3 — scalability (|R|, |L|, contention)".into(),
        rendered: format!("{panel_a}\n{panel_b}\n{panel_c}"),
        csv_paths,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig3_runs_small() {
        let out = super::run(60);
        assert!(out.rendered.contains("(a)"));
        assert!(out.rendered.contains("(c)"));
        assert_eq!(out.csv_paths.len(), 3);
    }
}
