//! Fig. 4 — hyper-parameter sensitivity: (a) initial learning rate η₀,
//! (b) decay λ.  Expected shapes (Sec. 4.1): a wrong η₀ can wreck the
//! cumulative reward (even negative slots); decay 0.9999 beats 1.0001
//! (a growing rate fights convergence); the practically good decay band
//! is [0.995, 0.9999].

use crate::config::Scenario;
use crate::figures::{results_dir, FigureOutput};
use crate::metrics;
use crate::schedulers::OgaSched;
use crate::sim;
use crate::traces::synthesize;
use crate::utils::csv::Csv;
use crate::utils::table::Table;

const ETA0: [f64; 5] = [1.0, 5.0, 25.0, 100.0, 400.0];
const DECAY: [f64; 5] = [0.99, 0.995, 0.9999, 1.0, 1.0001];

pub fn run(horizon_override: usize) -> FigureOutput {
    let mut s = Scenario::default();
    s.name = "fig4".into();
    if horizon_override > 0 {
        s.horizon = horizon_override;
    }
    let problem = synthesize(&s);
    let mut csv_paths = Vec::new();

    // (a) sweep eta0 at the default decay
    let mut table_a = Table::new(&["eta0", "avg reward", "cumulative", "min slot reward"]);
    let mut csv_a = Csv::new(&["eta0", "avg_reward", "cumulative", "min_slot"]);
    for &eta0 in &ETA0 {
        let mut pol = OgaSched::new(&problem, eta0, s.decay, s.parallel);
        let run = sim::run_on_problem(&s, &problem, &mut pol);
        let min_slot =
            run.records.iter().map(|r| r.q).fold(f64::INFINITY, f64::min);
        let row = [eta0, run.avg_reward(), run.cumulative_reward, min_slot];
        table_a.push_labeled(&format!("{eta0}"), &row[1..], 2);
        csv_a.push_f64(&row);
    }
    let path_a = results_dir().join("fig4a_eta0.csv");
    let _ = csv_a.write_file(&path_a);
    csv_paths.push(path_a);

    // (b) sweep decay at the default eta0, plus avg-reward curve export
    let mut table_b = Table::new(&["decay", "avg reward", "cumulative", "min slot reward"]);
    let mut csv_b = Csv::new(&["decay", "avg_reward", "cumulative", "min_slot"]);
    let mut curves = Vec::new();
    let mut curve_names = Vec::new();
    for &decay in &DECAY {
        let mut pol = OgaSched::new(&problem, s.eta0, decay, s.parallel);
        let run = sim::run_on_problem(&s, &problem, &mut pol);
        let min_slot =
            run.records.iter().map(|r| r.q).fold(f64::INFINITY, f64::min);
        let row = [decay, run.avg_reward(), run.cumulative_reward, min_slot];
        table_b.push_labeled(&format!("{decay}"), &row[1..], 2);
        csv_b.push_f64(&row);
        curve_names.push(format!("decay={decay}"));
        curves.push(metrics::avg_reward_curve(&run));
    }
    let path_b = results_dir().join("fig4b_decay.csv");
    let _ = csv_b.write_file(&path_b);
    csv_paths.push(path_b);
    let names: Vec<&str> = curve_names.iter().map(String::as_str).collect();
    let path_c = results_dir().join("fig4b_decay_curves.csv");
    let _ = metrics::curves_to_csv(&names, &curves, 400).write_file(&path_c);
    csv_paths.push(path_c);

    let rendered = format!(
        "(a) initial learning rate sweep (decay={})\n{}\n\
         (b) decay sweep (eta0={})\n{}\npaper: best decay band is [0.995, 0.9999]; \
         decay 0.9999 beats 1.0001.\n",
        s.decay,
        table_a.render(),
        s.eta0,
        table_b.render()
    );
    FigureOutput { title: "Fig. 4 — hyper-parameter sensitivity".into(), rendered, csv_paths }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig4_runs_small() {
        let out = super::run(50);
        assert!(out.rendered.contains("eta0"));
        assert_eq!(out.csv_paths.len(), 3);
    }
}
