//! Fig. 6 — average computation gain vs communication-overhead penalty
//! per slot under different contention levels.  Expected shape: the
//! penalty grows *slowly* with the contention level while the gain
//! first grows then saturates/declines as over-allocation sets in.

use crate::config::Scenario;
use crate::figures::{results_dir, FigureOutput};
use crate::metrics;
use crate::schedulers::OgaSched;
use crate::sim;
use crate::traces::synthesize;
use crate::utils::csv::Csv;
use crate::utils::table::Table;

const CONTENTION: [f64; 7] = [0.1, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0];

pub fn run(horizon_override: usize) -> FigureOutput {
    let mut table =
        Table::new(&["contention", "avg gain", "avg penalty", "penalty share %"]);
    let mut csv = Csv::new(&["contention", "avg_gain", "avg_penalty", "penalty_share"]);
    for &c in &CONTENTION {
        let mut s = Scenario::default();
        s.name = "fig6".into();
        s.contention = c;
        if horizon_override > 0 {
            s.horizon = horizon_override;
        }
        let problem = synthesize(&s);
        let mut pol = OgaSched::new(&problem, s.eta0, s.decay, s.parallel);
        let run = sim::run_on_problem(&s, &problem, &mut pol);
        let (gain, penalty) = metrics::gain_penalty_split(&run);
        let share = if gain.abs() > 1e-12 { 100.0 * penalty / gain } else { 0.0 };
        table.push_labeled(&format!("{c}"), &[gain, penalty, share], 2);
        csv.push_f64(&[c, gain, penalty, share]);
    }
    let path = results_dir().join("fig6_gain_penalty.csv");
    let _ = csv.write_file(&path);
    FigureOutput {
        title: "Fig. 6 — gain vs penalty per contention level (OGASCHED)".into(),
        rendered: format!(
            "{}\npaper: the penalty increases with the contention level slowly.\n",
            table.render()
        ),
        csv_paths: vec![path],
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig6_runs_small() {
        let out = super::run(40);
        assert!(out.rendered.contains("penalty"));
    }
}
