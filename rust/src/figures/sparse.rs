//! Arrival-sparse sweep — the fig2-style lineup comparison at
//! Bernoulli(0.1) traffic, the §Perf-2/§Perf-3 bench regime.
//!
//! fig2/fig5 score dense ρ = 0.7 traffic, so the figures never visit
//! the sparse regime the arrival-sparse pipeline (and now the sharded
//! single-slot coordinator) is built for.  This harness runs the same
//! five-policy comparison on the Tab. 2 default cluster at ρ = 0.1:
//! per-slot rewards are ~7× smaller (fewer arrivals score), but the
//! *ordering* — OGASCHED above the reactive heuristics — must survive,
//! and the run itself exercises the zero/sparse-arrival fast paths end
//! to end at figure scale.  CSVs land next to the fig2 series so the
//! same plotting scripts apply.

use crate::config::Scenario;
use crate::coordinator::ShardedLeader;
use crate::figures::{results_dir, FigureOutput};
use crate::metrics;
use crate::schedulers::OgaSched;
use crate::sim;
use crate::sim::arrivals::{ArrivalModel, Bernoulli};
use crate::traces::synthesize;
use crate::utils::csv::Csv;
use crate::utils::table::Table;
use crate::ExecBudget;

/// Bernoulli arrival probability of the sparse regime (the §Perf-2
/// bench setting).
pub const SPARSE_ARRIVAL_PROB: f64 = 0.1;

pub fn scenario(horizon_override: usize) -> Scenario {
    let mut s = Scenario::default();
    s.name = "sparse".into();
    s.horizon = if horizon_override > 0 { horizon_override } else { 8000 };
    s.arrival_prob = SPARSE_ARRIVAL_PROB;
    s
}

/// Shard widths swept by the occupancy columns.
const OCCUPANCY_SHARDS: [usize; 4] = [1, 2, 4, 8];

/// Drive OGASCHED through the sharded leader at each shard width and
/// report the per-shard edges-touched telemetry — how much reward-stage
/// work each shard of the static LPT plan actually sees per slot under
/// the sparse regime (ISSUE 7 satellite; work-stealing groundwork).
/// Since ISSUE 8 the telemetry is an `obs` log₂ histogram, so the sweep
/// also surfaces tail percentiles, not just min/mean/max.
fn occupancy_sweep(s: &Scenario) -> Vec<(usize, crate::obs::HistSnapshot)> {
    let p = synthesize(s);
    OCCUPANCY_SHARDS
        .iter()
        .map(|&shards| {
            let mut leader = ShardedLeader::new(&p, shards);
            let mut pol = OgaSched::new(&p, s.eta0, s.decay, ExecBudget::auto());
            pol.bind_shards(leader.plan());
            let mut arr = Bernoulli::uniform(p.num_ports(), s.arrival_prob, s.seed);
            let mut x = vec![0.0; p.num_ports()];
            let mut y = vec![0.0; p.decision_len()];
            for _ in 0..s.horizon {
                arr.next(&mut x);
                leader.slot(&mut pol, &x, &mut y);
            }
            (shards, leader.occupancy())
        })
        .collect()
}

pub fn run(horizon_override: usize) -> FigureOutput {
    let s = scenario(horizon_override);
    let results = sim::run_paper_lineup(&s);
    let oga = &results[0];

    let names: Vec<&str> = results.iter().map(|r| r.policy.as_str()).collect();
    let avg_curves: Vec<Vec<f64>> = results.iter().map(metrics::avg_reward_curve).collect();
    let cum_curves: Vec<Vec<f64>> = results.iter().map(metrics::cumulative_curve).collect();

    let dir = results_dir();
    let mut csv_paths = Vec::new();
    for (file, curves) in [
        ("sparse_avg_reward.csv", &avg_curves),
        ("sparse_cumulative.csv", &cum_curves),
    ] {
        let path = dir.join(file);
        let _ = metrics::curves_to_csv(&names, curves, 400).write_file(&path);
        csv_paths.push(path);
    }

    // Occupancy columns: the same per-shard edges-touched counters the
    // hot-path bench samples, here at figure scale and horizon.
    let occ = occupancy_sweep(&s);
    let mut occ_csv = Csv::new(&[
        "shards", "slots", "min_edges", "mean_edges", "p50_edges", "p99_edges", "max_edges",
    ]);
    let mut occ_table =
        Table::new(&["shards", "slots", "min", "mean", "p50", "p99", "max"]);
    for (shards, o) in &occ {
        let slots = o.count / *shards as u64;
        let row = [
            shards.to_string(),
            slots.to_string(),
            o.min_or_zero().to_string(),
            format!("{:.2}", o.mean()),
            o.p50().to_string(),
            o.p99().to_string(),
            o.max.to_string(),
        ];
        occ_csv.push_row(&row);
        occ_table.push(&row);
    }
    let occ_path = dir.join("sparse_occupancy.csv");
    let _ = occ_csv.write_file(&occ_path);
    csv_paths.push(occ_path);

    let mut table =
        Table::new(&["policy", "avg reward", "cumulative", "OGA improvement"]);
    for run in &results {
        let imp = if run.policy == "OGASCHED" {
            "-".into()
        } else {
            format!("{:+.2}%", metrics::improvement_pct(oga, run))
        };
        table.push(&[
            run.policy.clone(),
            format!("{:.3}", run.avg_reward()),
            format!("{:.1}", run.cumulative_reward),
            imp,
        ]);
    }
    FigureOutput {
        title: "Sparse traffic — lineup at Bernoulli(0.1) arrivals".into(),
        rendered: format!(
            "T={} rho={} (fig2 defaults otherwise; the §Perf-2 bench regime)\n{}\n\
             per-shard occupancy (reward-stage edges touched per shard-slot):\n{}",
            s.horizon,
            SPARSE_ARRIVAL_PROB,
            table.render(),
            occ_table.render()
        ),
        csv_paths,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_figure_runs_and_oga_leads() {
        let out = run(400);
        assert!(out.rendered.contains("OGASCHED"));
        assert!(out.rendered.contains("per-shard occupancy"));
        assert_eq!(out.csv_paths.len(), 3);
    }

    #[test]
    fn occupancy_sweep_samples_every_width() {
        let mut s = scenario(40);
        s.num_ports = 6;
        s.num_instances = 24;
        let occ = occupancy_sweep(&s);
        assert_eq!(occ.len(), OCCUPANCY_SHARDS.len());
        for (shards, o) in occ {
            assert_eq!(o.count, 40 * shards as u64);
            assert!(o.min_or_zero() <= o.max);
            assert!(o.p50() <= o.p99() && o.p99() <= o.max);
        }
    }

    #[test]
    fn sparse_scenario_is_the_bench_regime() {
        let s = scenario(0);
        assert_eq!(s.arrival_prob, 0.1);
        assert_eq!(s.horizon, 8000);
        s.validate().unwrap();
    }
}
