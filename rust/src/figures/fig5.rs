//! Fig. 5 — large-scale validation (Sec. 4.3): |L| = 100 job types,
//! |R| = 1024 instances, contention 5, T = 10000 in the paper (the
//! harness scales T via the override / bench scale).  β uses the
//! unit-consistent default range — see Scenario::large_scale() for why
//! the paper's raw [0.01, 0.015] degenerates under normalized units.
//! Expected shape: OGASCHED's superiority is preserved at scale.

use crate::config::Scenario;
use crate::figures::{results_dir, FigureOutput};
use crate::metrics;
use crate::sim;
use crate::utils::table::Table;

pub fn scenario(horizon_override: usize) -> Scenario {
    let mut s = Scenario::large_scale();
    s.name = "fig5".into();
    s.horizon = if horizon_override > 0 { horizon_override } else { 10_000 };
    s
}

pub fn run(horizon_override: usize) -> FigureOutput {
    let s = scenario(horizon_override);
    let results = sim::run_paper_lineup(&s);
    let oga = &results[0];

    let names: Vec<&str> = results.iter().map(|r| r.policy.as_str()).collect();
    let curves: Vec<Vec<f64>> = results.iter().map(metrics::avg_reward_curve).collect();
    let path = results_dir().join("fig5_large_scale.csv");
    let _ = metrics::curves_to_csv(&names, &curves, 400).write_file(&path);

    let mut table = Table::new(&["policy", "avg reward", "cumulative", "OGA improvement"]);
    for run in &results {
        let imp = if run.policy == "OGASCHED" {
            "-".into()
        } else {
            format!("{:+.2}%", metrics::improvement_pct(oga, run))
        };
        table.push(&[
            run.policy.clone(),
            format!("{:.2}", run.avg_reward()),
            format!("{:.1}", run.cumulative_reward),
            imp,
        ]);
    }
    FigureOutput {
        title: "Fig. 5 — large-scale validation (|L|=100, |R|=1024)".into(),
        rendered: format!(
            "T={} beta=[{},{}] contention=5 (unit-consistent beta; see EXPERIMENTS.md)\n{}",
            s.horizon,
            s.beta_range.0,
            s.beta_range.1,
            table.render()
        ),
        csv_paths: vec![path],
    }
}

#[cfg(test)]
mod tests {
    #[test]
    #[ignore = "large scenario; run explicitly or via the bench"]
    fn fig5_runs_tiny_horizon() {
        let out = super::run(20);
        assert!(out.rendered.contains("OGASCHED"));
    }
}
