//! Fig. 7 — cumulative rewards under different utility families
//! (all-linear / all-log / all-reciprocal / all-poly and the mixed
//! default).  Expected shape: because of the diminishing marginal
//! effect, poly/log/reciprocal rewards are far below linear; OGASCHED
//! stays on top within every family.

use crate::config::Scenario;
use crate::figures::{results_dir, FigureOutput};
use crate::oga::utilities::{UtilityKind, UtilityMix};
use crate::sim;
use crate::utils::csv::Csv;
use crate::utils::table::Table;

pub fn mixes() -> Vec<UtilityMix> {
    vec![
        UtilityMix::All(UtilityKind::Linear),
        UtilityMix::All(UtilityKind::Log),
        UtilityMix::All(UtilityKind::Reciprocal),
        UtilityMix::All(UtilityKind::Poly),
        UtilityMix::Mixed,
    ]
}

pub fn run(horizon_override: usize) -> FigureOutput {
    let mut policy_names: Vec<String> = Vec::new();
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for mix in mixes() {
        let mut s = Scenario::default();
        s.name = "fig7".into();
        s.utility_mix = mix;
        if horizon_override > 0 {
            s.horizon = horizon_override;
        }
        let results = sim::run_paper_lineup(&s);
        if policy_names.is_empty() {
            policy_names = results.iter().map(|r| r.policy.clone()).collect();
        }
        rows.push((mix.name(), results.iter().map(|r| r.cumulative_reward).collect()));
    }

    let mut header: Vec<&str> = vec!["utility"];
    header.extend(policy_names.iter().map(String::as_str));
    let mut table = Table::new(&header);
    let mut csv = Csv::new(&header);
    for (label, vals) in &rows {
        table.push_labeled(label, vals, 1);
        let mut row = vec![label.clone()];
        row.extend(vals.iter().map(|v| format!("{v}")));
        csv.push_row(&row);
    }
    let path = results_dir().join("fig7_utilities.csv");
    let _ = csv.write_file(&path);

    // sanity highlights for the rendered text
    let linear_oga = rows[0].1[0];
    let rec_oga = rows[2].1[0];
    FigureOutput {
        title: "Fig. 7 — cumulative reward per utility family".into(),
        rendered: format!(
            "{}\nlinear/reciprocal OGASCHED ratio: {:.1}x (diminishing marginal \
             effect)\npaper: linear >> poly/log/reciprocal; OGASCHED best in \
             every family.\n",
            table.render(),
            if rec_oga.abs() > 1e-9 { linear_oga / rec_oga } else { f64::NAN }
        ),
        csv_paths: vec![path],
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig7_runs_small() {
        let out = super::run(40);
        assert!(out.rendered.contains("all-linear"));
        assert!(out.rendered.contains("mixed"));
    }
}
