//! Fig. 2 — performance verification on the Tab. 2 default cluster:
//! (a) average reward until t, (b) cumulative reward, (c) OGASCHED /
//! baseline average-reward ratio; plus the headline improvement
//! percentages of the abstract (11.33 / 7.75 / 13.89 / 13.44 %).
//!
//! Paper setting: T = 8000, β ∈ [0.4, 0.6], contention 11.

use crate::config::Scenario;
use crate::figures::{results_dir, FigureOutput};
use crate::metrics;
use crate::sim;
use crate::utils::table::Table;

pub fn scenario(horizon_override: usize) -> Scenario {
    let mut s = Scenario::default();
    s.name = "fig2".into();
    s.horizon = if horizon_override > 0 { horizon_override } else { 8000 };
    s.beta_range = (0.4, 0.6);
    s.contention = 11.0;
    s
}

pub fn run(horizon_override: usize) -> FigureOutput {
    let s = scenario(horizon_override);
    let results = sim::run_paper_lineup(&s);
    let oga = &results[0];

    // (a)+(b)+(c) series
    let names: Vec<&str> = results.iter().map(|r| r.policy.as_str()).collect();
    let avg_curves: Vec<Vec<f64>> = results.iter().map(metrics::avg_reward_curve).collect();
    let cum_curves: Vec<Vec<f64>> = results.iter().map(metrics::cumulative_curve).collect();
    let ratio_names: Vec<String> =
        results[1..].iter().map(|r| format!("OGA/{}", r.policy)).collect();
    let ratio_curves: Vec<Vec<f64>> =
        results[1..].iter().map(|r| metrics::ratio_curve(oga, r)).collect();

    let dir = results_dir();
    let mut csv_paths = Vec::new();
    for (file, names, curves) in [
        ("fig2a_avg_reward.csv", names.clone(), &avg_curves),
        ("fig2b_cumulative.csv", names.clone(), &cum_curves),
        (
            "fig2c_ratio.csv",
            ratio_names.iter().map(String::as_str).collect::<Vec<_>>(),
            &ratio_curves,
        ),
    ] {
        let path = dir.join(file);
        let _ = metrics::curves_to_csv(&names, curves, 400).write_file(&path);
        csv_paths.push(path);
    }

    let mut table = Table::new(&["policy", "avg reward", "cumulative", "OGA improvement"]);
    for run in &results {
        let imp = if run.policy == "OGASCHED" {
            "-".into()
        } else {
            format!("{:+.2}%", metrics::improvement_pct(oga, run))
        };
        table.push(&[
            run.policy.clone(),
            format!("{:.2}", run.avg_reward()),
            format!("{:.1}", run.cumulative_reward),
            imp,
        ]);
    }
    let rendered = format!(
        "T={} beta=[0.4,0.6] contention=11\n{}\npaper: OGASCHED beats \
         DRF/FAIRNESS/BINPACKING/SPREADING by 11.33/7.75/13.89/13.44 %\n",
        s.horizon,
        table.render()
    );
    FigureOutput { title: "Fig. 2 — performance verification".into(), rendered, csv_paths }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_small_horizon_runs_and_oga_wins() {
        let out = run(400);
        assert!(out.rendered.contains("OGASCHED"));
        assert_eq!(out.csv_paths.len(), 3);
    }
}
