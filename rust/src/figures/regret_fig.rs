//! Thm. 1 ablation (no figure in the paper, but the headline theory):
//! empirical regret vs the offline stationary oracle as a function of T
//! and of |L|.  Expected shape: regret grows ~√T (power-law exponent
//! ≈ 0.5, certainly < 1) and stays below the H_G·√T bound; growth in
//! |L| is sublinear.

use crate::config::Scenario;
use crate::coordinator::Leader;
use crate::figures::{results_dir, FigureOutput};
use crate::regret;
use crate::schedulers::OgaSched;
use crate::sim::arrivals::{record_trajectory, Bernoulli, Replay};
use crate::traces::synthesize;
use crate::utils::csv::Csv;
use crate::utils::stats;
use crate::utils::table::Table;

const HORIZONS: [usize; 5] = [250, 500, 1000, 2000, 4000];
const PORTS: [usize; 4] = [4, 8, 16, 32];
const ORACLE_ITERS: usize = 400;

/// Measure regret of OGASCHED (oracle learning rate, Eq. 50) on one
/// scenario against the offline stationary optimum for the same
/// realized trajectory.  Both the offline `solve_oracle` benchmark and
/// the online oracle-rate run inherit the scenario's `[parallel]`
/// budget — under a multi-shard budget the Eq. 50 two-pass fans out
/// per shard, bit-identically to the serial solve (§Perf-4).
fn measure(scenario: &Scenario) -> (f64, f64) {
    let p = synthesize(scenario);
    let mut src =
        Bernoulli::uniform(p.num_ports(), scenario.arrival_prob, scenario.seed ^ 0x5EED);
    let traj = record_trajectory(&mut src, p.num_ports(), scenario.horizon);
    let counts = regret::arrival_counts(&traj, p.num_ports());
    let oracle = regret::solve_oracle(&p, &counts, ORACLE_ITERS, scenario.parallel);

    let mut leader = Leader::new(&p);
    let mut pol = OgaSched::with_oracle_rate(&p, scenario.horizon, scenario.parallel);
    let mut replay = Replay::new(traj);
    let run = leader.run(&mut pol, &mut replay, scenario.horizon);
    let r = regret::regret(&oracle, run.cumulative_reward).max(0.0);
    (r, regret::theorem1_bound(&p, scenario.horizon))
}

pub fn run(horizon_override: usize) -> FigureOutput {
    let scale = |t: usize| {
        if horizon_override > 0 { (t * horizon_override) / 2000 } else { t }.max(10)
    };

    // (a) regret vs T
    let mut table_t = Table::new(&["T", "regret", "Thm.1 bound", "bound slack x"]);
    let mut csv = Csv::new(&["T", "regret", "bound"]);
    let mut ts = Vec::new();
    let mut rs = Vec::new();
    for t in HORIZONS {
        let mut s = Scenario::small();
        s.name = format!("regret-T{t}");
        s.horizon = scale(t);
        let (r, bound) = measure(&s);
        table_t.push_labeled(
            &format!("{}", s.horizon),
            &[r, bound, if r > 0.0 { bound / r } else { f64::INFINITY }],
            2,
        );
        csv.push_f64(&[s.horizon as f64, r, bound]);
        ts.push(s.horizon as f64);
        rs.push(r.max(1e-9));
    }
    let (c, p_exp, r2) = stats::powerlaw_fit(&ts, &rs);
    let path = results_dir().join("regret_vs_T.csv");
    let _ = csv.write_file(&path);

    // (b) regret vs |L|
    let mut table_l = Table::new(&["|L|", "regret", "regret/|L|"]);
    let mut csv_l = Csv::new(&["L", "regret"]);
    let mut ls = Vec::new();
    let mut rls = Vec::new();
    for l in PORTS {
        let mut s = Scenario::small();
        s.name = format!("regret-L{l}");
        s.num_ports = l;
        s.horizon = scale(800);
        let (r, _) = measure(&s);
        table_l.push_labeled(&format!("{l}"), &[r, r / l as f64], 2);
        csv_l.push_f64(&[l as f64, r]);
        ls.push(l as f64);
        rls.push(r.max(1e-9));
    }
    let (_, l_exp, _) = stats::powerlaw_fit(&ls, &rls);
    let path_l = results_dir().join("regret_vs_L.csv");
    let _ = csv_l.write_file(&path_l);

    let rendered = format!(
        "(a) regret vs T (OGASCHED with the Eq. 50 learning rate)\n{}\n\
         power-law fit: regret ~ {:.2} * T^{:.3} (r^2={:.3}); \
         Thm. 1 predicts exponent 0.5 (sublinear < 1 required)\n\n\
         (b) regret vs |L| at fixed T\n{}\n\
         power-law fit exponent in |L|: {:.3} (sublinear < 1 required)\n",
        table_t.render(),
        c,
        p_exp,
        r2,
        table_l.render(),
        l_exp
    );
    FigureOutput {
        title: "Thm. 1 ablation — sublinear regret".into(),
        rendered,
        csv_paths: vec![path, path_l],
    }
}

#[cfg(test)]
mod tests {
    #[test]
    #[ignore = "oracle solves are slow; exercised by the ablation bench"]
    fn regret_fig_runs_tiny() {
        let out = super::run(60);
        assert!(out.rendered.contains("power-law"));
    }
}
