//! Churn sweep — the fig2 lineup under mid-horizon fault injection
//! (§Churn).
//!
//! Every policy runs twice on the Tab. 2 default cluster: once fault-
//! free and once under the scenario's seeded `FaultPlan` (instance
//! crashes with recovery, port churn, occasional rack bursts), through
//! the incremental arm of `sim::faults::run_churned`.  The table
//! reports the reward each policy gives up to churn; the interesting
//! ordering claim is that OGASCHED's lead over the reactive heuristics
//! survives topology churn — its carried-over coordinates re-project
//! onto every new edition instead of restarting from zero.

use crate::config::{FaultConfig, Scenario};
use crate::figures::{results_dir, FigureOutput};
use crate::metrics;
use crate::schedulers;
use crate::sim::{self, faults};
use crate::traces::synthesize;
use crate::utils::table::Table;

pub fn scenario(horizon_override: usize) -> Scenario {
    let mut s = Scenario::default();
    s.name = "churn".into();
    s.horizon = if horizon_override > 0 { horizon_override } else { 4000 };
    s.faults = FaultConfig {
        instance_rate: 0.01,
        recover_rate: 0.1,
        port_rate: 0.005,
        rack_rate: 0.002,
        rack_size: 4,
        ..FaultConfig::default()
    };
    s
}

pub fn run(horizon_override: usize) -> FigureOutput {
    let s = scenario(horizon_override);
    let clean_s = Scenario { faults: FaultConfig::default(), ..s.clone() };
    let clean = sim::run_paper_lineup(&clean_s);

    let problem = synthesize(&s);
    let mut lineup = schedulers::paper_lineup(&problem, s.eta0, s.decay, s.parallel);
    let churned: Vec<faults::ChurnOutcome> = lineup
        .iter_mut()
        .map(|pol| {
            faults::run_churned_scenario(&s, pol.as_mut(), false)
                .expect("generated fault plans stay in range")
        })
        .collect();

    let names: Vec<&str> = churned.iter().map(|o| o.result.policy.as_str()).collect();
    let avg_curves: Vec<Vec<f64>> =
        churned.iter().map(|o| metrics::avg_reward_curve(&o.result)).collect();
    let dir = results_dir();
    let path = dir.join("churn_avg_reward.csv");
    let _ = metrics::curves_to_csv(&names, &avg_curves, 400).write_file(&path);

    let mut table =
        Table::new(&["policy", "clean avg", "churned avg", "churn cost", "cumulative"]);
    for (out, base) in churned.iter().zip(&clean) {
        let clean_avg = base.avg_reward();
        let churn_avg = out.result.avg_reward();
        let cost = if clean_avg.abs() > 1e-12 {
            format!("{:+.2}%", (churn_avg - clean_avg) / clean_avg * 100.0)
        } else {
            "-".into()
        };
        table.push(&[
            out.result.policy.clone(),
            format!("{clean_avg:.3}"),
            format!("{churn_avg:.3}"),
            cost,
            format!("{:.1}", out.result.cumulative_reward),
        ]);
    }
    let bookkeeping = &churned[0];
    FigureOutput {
        title: "Churn — lineup under instance/port fault injection".into(),
        rendered: format!(
            "T={} faults: instance={} recover={} port={} rack={}x{} \
             (events={} editions={} replans={}, incremental arm)\n{}",
            s.horizon,
            s.faults.instance_rate,
            s.faults.recover_rate,
            s.faults.port_rate,
            s.faults.rack_rate,
            s.faults.rack_size,
            bookkeeping.events,
            bookkeeping.editions,
            bookkeeping.replans,
            table.render()
        ),
        csv_paths: vec![path],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_figure_runs_the_lineup() {
        let out = run(160);
        assert!(out.rendered.contains("OGASCHED"));
        assert!(out.rendered.contains("events="));
        assert_eq!(out.csv_paths.len(), 1);
    }

    #[test]
    fn churn_scenario_arms_fault_injection() {
        let s = scenario(0);
        assert!(s.faults.enabled());
        assert_eq!(s.horizon, 4000);
        s.validate().unwrap();
    }
}
