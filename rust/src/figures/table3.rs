//! Tab. 3 — generality & robustness: average reward per policy under
//! different time-horizon lengths T, job-arrival probabilities ρ, and
//! graph densities.  Expected shapes: OGASCHED always on top; its
//! reward correlates positively with T; ρ peaks around 0.7 (0.9 brings
//! fiercer contention); density raises rewards with slow-growing
//! overhead.  The two largest values per column are emphasized like the
//! paper's bold cells.

use crate::config::{GraphSpec, Scenario};
use crate::figures::{results_dir, FigureOutput};
use crate::sim;
use crate::utils::csv::Csv;
use crate::utils::table::Table;

const HORIZONS: [usize; 4] = [1000, 2000, 5000, 10_000];
const RHOS: [f64; 4] = [0.3, 0.5, 0.7, 0.9];
const DENSITIES: [f64; 3] = [2.0, 2.5, 3.0];

/// Column spec: (label, scenario).
fn columns(horizon_override: usize) -> Vec<(String, Scenario)> {
    let mut cols = Vec::new();
    let scale = |t: usize| -> usize {
        if horizon_override > 0 {
            // keep the relative T ordering while shrinking the work
            (t * horizon_override) / 2000
        } else {
            t
        }
        .max(10)
    };
    for t in HORIZONS {
        let mut s = Scenario::default();
        s.name = format!("table3-T{t}");
        s.horizon = scale(t);
        cols.push((format!("T={t}"), s));
    }
    for rho in RHOS {
        let mut s = Scenario::default();
        s.name = format!("table3-rho{rho}");
        s.arrival_prob = rho;
        s.horizon = scale(2000);
        cols.push((format!("rho={rho}"), s));
    }
    for d in DENSITIES {
        let mut s = Scenario::default();
        s.name = format!("table3-dense{d}");
        s.graph = GraphSpec::Density(d);
        s.horizon = scale(2000);
        cols.push((format!("dense~{d}"), s));
    }
    cols
}

pub fn run(horizon_override: usize) -> FigureOutput {
    let cols = columns(horizon_override);
    let mut policy_names: Vec<String> = Vec::new();
    // rows[policy][column] = avg reward
    let mut cells: Vec<Vec<f64>> = Vec::new();
    for (_, scenario) in &cols {
        let results = sim::run_paper_lineup(scenario);
        if policy_names.is_empty() {
            policy_names = results.iter().map(|r| r.policy.clone()).collect();
            cells = vec![Vec::new(); results.len()];
        }
        for (i, r) in results.iter().enumerate() {
            cells[i].push(r.avg_reward());
        }
    }

    let mut header: Vec<String> = vec!["Avg. Reward".into()];
    header.extend(cols.iter().map(|(l, _)| l.clone()));
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut table = Table::new(&header_refs);
    let mut csv = Csv::new(&header_refs);
    for (i, policy) in policy_names.iter().enumerate() {
        table.push_labeled(policy, &cells[i], 2);
        let mut row = vec![policy.clone()];
        row.extend(cells[i].iter().map(|v| format!("{v:.2}")));
        csv.push_row(&row);
    }
    table.emphasize_top_per_column(2);
    let path = results_dir().join("table3_generality.csv");
    let _ = csv.write_file(&path);

    // check the headline claim for the rendered summary
    let oga_top_everywhere = (0..cols.len()).all(|j| {
        let oga = cells[0][j];
        cells[1..].iter().all(|row| row[j] <= oga + 1e-9)
    });
    FigureOutput {
        title: "Tab. 3 — generality & robustness".into(),
        rendered: format!(
            "{}\nOGASCHED top in every column: {}\n(*top-2 cells per column \
             emphasized, as in the paper*)\n",
            table.render(),
            oga_top_everywhere
        ),
        csv_paths: vec![path],
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn table3_runs_tiny() {
        let out = super::run(40);
        assert!(out.rendered.contains("Avg. Reward"));
        assert!(out.rendered.contains("T=1000"));
        assert!(out.rendered.contains("dense~3"));
    }
}
