//! Figure-grade metric series built from run results: the exact curves
//! the paper plots (average-reward-until-t, cumulative reward, OGASCHED/
//! baseline ratios) plus CSV export used by every figure harness.

use crate::coordinator::RunResult;
use crate::utils::csv::Csv;
use crate::utils::stats;

/// Fig. 2(a): average reward until t for one run.
pub fn avg_reward_curve(run: &RunResult) -> Vec<f64> {
    stats::prefix_mean(&run.rewards())
}

/// Fig. 2(b): cumulative reward over t.
pub fn cumulative_curve(run: &RunResult) -> Vec<f64> {
    stats::cumsum(&run.rewards())
}

/// Fig. 2(c): ratio of OGASCHED's average reward to a baseline's, per t.
/// Slots where the baseline curve is ~0 are clamped to 1.0 (the paper's
/// plots start after the warm-up oscillation for the same reason).
pub fn ratio_curve(oga: &RunResult, baseline: &RunResult) -> Vec<f64> {
    let a = avg_reward_curve(oga);
    let b = avg_reward_curve(baseline);
    a.iter()
        .zip(&b)
        .map(|(&x, &y)| if y.abs() < 1e-9 { 1.0 } else { x / y })
        .collect()
}

/// Headline improvement: (avg(OGA) / avg(baseline) − 1) · 100%.
pub fn improvement_pct(oga: &RunResult, baseline: &RunResult) -> f64 {
    let b = baseline.avg_reward();
    if b.abs() < 1e-12 {
        return 0.0;
    }
    (oga.avg_reward() / b - 1.0) * 100.0
}

/// Mean per-slot gain/penalty split (Fig. 6's bars).
pub fn gain_penalty_split(run: &RunResult) -> (f64, f64) {
    let n = run.records.len().max(1) as f64;
    let g: f64 = run.records.iter().map(|r| r.gain).sum();
    let p: f64 = run.records.iter().map(|r| r.penalty).sum();
    (g / n, p / n)
}

/// Export a set of per-slot curves to CSV (`t` column + one per policy),
/// thinned to at most `max_rows` rows so large-T figures stay plottable.
pub fn curves_to_csv(names: &[&str], curves: &[Vec<f64>], max_rows: usize) -> Csv {
    assert_eq!(names.len(), curves.len());
    let len = curves.iter().map(Vec::len).max().unwrap_or(0);
    let stride = len.div_ceil(max_rows.max(1)).max(1);
    let mut header = vec!["t"];
    header.extend_from_slice(names);
    let mut csv = Csv::new(&header);
    let mut t = 0;
    while t < len {
        let mut row = vec![(t + 1) as f64];
        for c in curves {
            row.push(c.get(t).copied().unwrap_or(f64::NAN));
        }
        csv.push_f64(&row);
        t += stride;
    }
    csv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SlotRecord;

    fn run_with(rewards: &[f64]) -> RunResult {
        RunResult {
            policy: "X".into(),
            records: rewards
                .iter()
                .enumerate()
                .map(|(t, &q)| SlotRecord { t, q, gain: q + 1.0, penalty: 1.0, arrivals: 1.0 })
                .collect(),
            cumulative_reward: rewards.iter().sum(),
            clamped_total: 0,
            elapsed_secs: 0.1,
        }
    }

    #[test]
    fn curves_match_hand_math() {
        let r = run_with(&[2.0, 4.0, 6.0]);
        assert_eq!(avg_reward_curve(&r), vec![2.0, 3.0, 4.0]);
        assert_eq!(cumulative_curve(&r), vec![2.0, 6.0, 12.0]);
    }

    #[test]
    fn ratio_and_improvement() {
        let a = run_with(&[2.0, 2.0]);
        let b = run_with(&[1.0, 1.0]);
        assert_eq!(ratio_curve(&a, &b), vec![2.0, 2.0]);
        assert!((improvement_pct(&a, &b) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn gain_penalty_split_means() {
        let r = run_with(&[2.0, 4.0]);
        let (g, p) = gain_penalty_split(&r);
        assert!((g - 4.0).abs() < 1e-12);
        assert!((p - 1.0).abs() < 1e-12);
    }

    #[test]
    fn csv_thinning() {
        let c = curves_to_csv(&["a"], &[(0..1000).map(|i| i as f64).collect()], 100);
        assert!(c.rows.len() <= 101);
        assert_eq!(c.header, vec!["t", "a"]);
    }
}
