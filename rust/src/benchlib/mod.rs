//! Hand-rolled bench harness (criterion is not available offline).
//!
//! Provides warmup + repeated timing with mean/σ, a row collector that
//! renders paper-style tables, and CSV export under `results/`.  Every
//! file in `benches/` is a `harness = false` binary built on this.

use std::time::Instant;

use crate::utils::csv::Csv;
use crate::utils::stats;
use crate::utils::table::Table;

/// One timed measurement.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub min_secs: f64,
}

impl Timing {
    pub fn per_sec(&self) -> f64 {
        if self.mean_secs > 0.0 {
            1.0 / self.mean_secs
        } else {
            0.0
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured ones.
pub fn time_fn<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing {
        name: name.to_string(),
        iters: iters.max(1),
        mean_secs: stats::mean(&samples),
        std_secs: stats::std(&samples),
        min_secs: stats::min(&samples),
    }
}

/// Collector that renders/persists a bench's output.
pub struct Reporter {
    bench: String,
    timings: Vec<Timing>,
    sections: Vec<(String, String)>,
}

impl Reporter {
    pub fn new(bench: &str) -> Self {
        println!("=== bench: {bench} ===");
        Reporter { bench: bench.to_string(), timings: Vec::new(), sections: Vec::new() }
    }

    pub fn record(&mut self, t: Timing) {
        println!(
            "  {:<44} {:>12.3} ms ±{:>8.3}  ({} iters)",
            t.name,
            t.mean_secs * 1e3,
            t.std_secs * 1e3,
            t.iters
        );
        self.timings.push(t);
    }

    /// Attach a named table/section to the output (figure rows).
    pub fn section(&mut self, title: &str, body: impl std::fmt::Display) {
        let body = body.to_string();
        println!("--- {title} ---\n{body}");
        self.sections.push((title.to_string(), body));
    }

    /// Render the recorded timings as a machine-readable JSON document
    /// (per-section ns/op), so a bench's perf trajectory can be tracked
    /// across PRs instead of only printed to stdout.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", escape_json(&self.bench)));
        out.push_str("  \"entries\": [\n");
        for (i, t) in self.timings.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"ns_per_op\": {:.1}, \
                 \"ns_per_op_min\": {:.1}, \"std_ns\": {:.1}}}{}\n",
                escape_json(&t.name),
                t.iters,
                t.mean_secs * 1e9,
                t.min_secs * 1e9,
                t.std_secs * 1e9,
                if i + 1 < self.timings.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON report to `path` (used by benches that feed the
    /// cross-PR perf record, e.g. hot_path -> BENCH_hot_path.json).
    pub fn write_json(&self, path: impl AsRef<std::path::Path>) {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        match std::fs::write(path, self.to_json()) {
            Ok(()) => println!("json: {}", path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
        }
    }

    /// Persist timings CSV + sections to results/bench/.
    pub fn finish(self) {
        let dir = std::path::Path::new("results/bench");
        let mut csv = Csv::new(&["name", "iters", "mean_secs", "std_secs", "min_secs"]);
        for t in &self.timings {
            csv.push_row(&[
                t.name.clone(),
                t.iters.to_string(),
                format!("{}", t.mean_secs),
                format!("{}", t.std_secs),
                format!("{}", t.min_secs),
            ]);
        }
        let _ = csv.write_file(dir.join(format!("{}_timings.csv", self.bench)));
        let mut all = String::new();
        for (title, body) in &self.sections {
            all.push_str(&format!("--- {title} ---\n{body}\n"));
        }
        if !all.is_empty() {
            let _ = std::fs::create_dir_all(dir);
            let _ = std::fs::write(dir.join(format!("{}_sections.txt", self.bench)), all);
        }
        println!("=== bench {} done ===", self.bench);
    }
}

/// Minimal JSON string escaping (names are ASCII bench labels).
fn escape_json(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            '\n' => vec!['\\', 'n'],
            c => vec![c],
        })
        .collect()
}

/// Render a policy-vs-metric table (common bench output shape).
pub fn policy_table(header: &[&str], rows: &[(String, Vec<f64>)], prec: usize) -> String {
    let mut t = Table::new(header);
    for (label, vals) in rows {
        t.push_labeled(label, vals, prec);
    }
    t.render()
}

/// Benches honor `OGASCHED_BENCH_SCALE` (0 < scale ≤ 1) to shrink
/// horizons for CI; default 1.0 regenerates the paper-scale runs.
pub fn bench_scale() -> f64 {
    std::env::var("OGASCHED_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .map(|v: f64| v.clamp(0.001, 1.0))
        .unwrap_or(1.0)
}

/// Scale a horizon by `bench_scale()`, keeping at least `min`.
pub fn scaled(t: usize, min: usize) -> usize {
    ((t as f64 * bench_scale()) as usize).max(min)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_something() {
        let t = time_fn("noop", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert_eq!(t.iters, 5);
        assert!(t.mean_secs >= 0.0);
        assert!(t.min_secs <= t.mean_secs + 1e-12);
        assert!(t.per_sec() > 0.0);
    }

    #[test]
    fn policy_table_renders() {
        let s = policy_table(
            &["policy", "reward"],
            &[("OGASCHED".into(), vec![123.456])],
            2,
        );
        assert!(s.contains("123.46"));
    }

    #[test]
    fn scaled_floors() {
        assert!(scaled(1000, 50) >= 50);
    }

    #[test]
    fn json_report_shape() {
        let mut rep = Reporter::new("unit");
        rep.record(time_fn("alpha \"x\"", 0, 2, || {
            std::hint::black_box(1 + 1);
        }));
        rep.record(time_fn("beta", 0, 2, || {
            std::hint::black_box(2 + 2);
        }));
        let json = rep.to_json();
        assert!(json.contains("\"bench\": \"unit\""));
        assert!(json.contains("\"name\": \"alpha \\\"x\\\"\""));
        assert!(json.contains("\"ns_per_op\""));
        // two entries, comma-separated exactly once
        assert_eq!(json.matches("\"iters\"").count(), 2);
    }
}
