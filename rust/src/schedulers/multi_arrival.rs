//! Sec. 3.4 extension: multiple jobs per port per slot.
//!
//! The paper re-formulates x(t) ∈ ℕ^|L| and gives each of the up-to-J_l
//! simultaneous type-l jobs its own decision plane y^{j}.  We realize
//! that by *port expansion*: the expanded problem clones port l into J_l
//! ports sharing l's edges and demands, and an arrival of x_l = n jobs
//! activates the first n clones.  Native OGASCHED then runs unchanged on
//! the expanded problem — exactly the paper's "solved by native
//! OGASCHED after transformations".

use crate::graph::Bipartite;
use crate::model::Problem;
use crate::schedulers::oga_sched::OgaSched;
use crate::schedulers::Policy;
use crate::utils::pool::ExecBudget;

/// Expand a problem so port l has `copies[l]` clones (J_l planes).
pub fn expand_problem(problem: &Problem, copies: &[usize]) -> (Problem, Vec<usize>) {
    assert_eq!(copies.len(), problem.num_ports());
    let k_n = problem.num_resources;
    let mut edges = Vec::new();
    let mut demand = Vec::new();
    let mut owner = Vec::new(); // expanded port -> original port
    for (l, &j_l) in copies.iter().enumerate() {
        for _ in 0..j_l.max(1) {
            let lx = owner.len();
            owner.push(l);
            for &r in &problem.graph.ports_to_instances[l] {
                edges.push((lx, r));
            }
            for k in 0..k_n {
                demand.push(problem.demand_at(l, k));
            }
        }
    }
    let graph = Bipartite::from_edges(owner.len(), problem.num_instances(), &edges);
    (
        Problem::new(
            graph,
            k_n,
            demand,
            problem.capacity.clone(),
            problem.alpha.clone(),
            problem.kind.clone(),
            problem.beta.clone(),
        ),
        owner,
    )
}

/// Expand a multi-arrival vector x ∈ ℕ^|L| to per-clone indicators
/// (1{j ≤ x_l} of the Sec. 3.4 reward).
pub fn expand_arrivals(x: &[f64], copies: &[usize], out: &mut Vec<f64>) {
    out.clear();
    for (l, &j_l) in copies.iter().enumerate() {
        let n = x[l].max(0.0).round() as usize;
        for j in 0..j_l.max(1) {
            out.push(if j < n { 1.0 } else { 0.0 });
        }
    }
}

/// OGASCHED over the expanded problem, exposed as a policy on the
/// *original* problem shape (decisions of clone planes are summed back
/// into the original tensor; feasibility is preserved because capacity
/// constraints live per (r, k), which expansion leaves intact).
pub struct MultiArrivalOga {
    expanded: Problem,
    copies: Vec<usize>,
    inner: OgaSched,
    x_buf: Vec<f64>,
    y_buf: Vec<f64>,
}

impl MultiArrivalOga {
    pub fn new(problem: &Problem, copies: &[usize], eta0: f64, decay: f64,
               budget: ExecBudget) -> Self {
        let (expanded, _owner) = expand_problem(problem, copies);
        let inner = OgaSched::new(&expanded, eta0, decay, budget);
        let y_len = expanded.decision_len();
        MultiArrivalOga {
            expanded,
            copies: copies.to_vec(),
            inner,
            x_buf: Vec::new(),
            y_buf: vec![0.0; y_len],
        }
    }
}

impl Policy for MultiArrivalOga {
    fn name(&self) -> &'static str {
        "OGASCHED-MULTI"
    }

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        expand_arrivals(x, &self.copies, &mut self.x_buf);
        self.inner.decide(&self.expanded, &self.x_buf, &mut self.y_buf);
        // fold clone planes back into the original edge-major tensor —
        // every clone replicates l's edge list, so the CSR rows of clone
        // and original walk the same instances in lockstep
        y.fill(0.0);
        let k_n = problem.num_resources;
        let mut lx = 0;
        for (l, &j_l) in self.copies.iter().enumerate() {
            let olo = problem.graph.port_ptr[l];
            let deg = problem.graph.port_ptr[l + 1] - olo;
            for _ in 0..j_l.max(1) {
                let elo = self.expanded.graph.port_ptr[lx];
                debug_assert_eq!(self.expanded.graph.port_ptr[lx + 1] - elo, deg);
                for j in 0..deg {
                    let src = (elo + j) * k_n;
                    let dst = (olo + j) * k_n;
                    for k in 0..k_n {
                        y[dst + k] += self.y_buf[src + k];
                    }
                }
                lx += 1;
            }
        }
    }

    fn reset(&mut self, _problem: &Problem) {
        self.inner.reset(&self.expanded);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::traces::synthesize;

    #[test]
    fn expansion_clones_edges_and_demands() {
        let p = synthesize(&Scenario::small());
        let copies = vec![2; p.num_ports()];
        let (e, owner) = expand_problem(&p, &copies);
        assert_eq!(e.num_ports(), 2 * p.num_ports());
        assert_eq!(owner.len(), e.num_ports());
        for (lx, &l) in owner.iter().enumerate() {
            assert_eq!(
                e.graph.ports_to_instances[lx],
                p.graph.ports_to_instances[l]
            );
            for k in 0..p.num_resources {
                assert_eq!(e.demand_at(lx, k), p.demand_at(l, k));
            }
        }
        e.graph.validate().unwrap();
    }

    #[test]
    fn arrival_expansion_thresholds() {
        let mut out = Vec::new();
        expand_arrivals(&[2.0, 0.0, 1.0], &[3, 2, 2], &mut out);
        assert_eq!(out, vec![1.0, 1.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn capacity_still_respected_after_folding() {
        let p = synthesize(&Scenario::small());
        let copies = vec![3; p.num_ports()];
        let mut pol = MultiArrivalOga::new(&p, &copies, 10.0, 0.999, ExecBudget::auto());
        let x: Vec<f64> = (0..p.num_ports()).map(|l| (l % 4) as f64).collect();
        let mut y = vec![0.0; p.decision_len()];
        let k_n = p.num_resources;
        for _ in 0..10 {
            pol.decide(&p, &x, &mut y);
            // per-channel caps are per *job copy*, so only check capacity
            for r in 0..p.num_instances() {
                for k in 0..k_n {
                    let used: f64 = p
                        .graph
                        .instance_edge_ids(r)
                        .iter()
                        .map(|&e| y[p.edge_idx(e, k)])
                        .sum();
                    assert!(
                        used <= p.capacity_at(r, k) + 1e-6,
                        "capacity violated at ({r},{k})"
                    );
                }
            }
        }
    }
}
