//! The four heuristics of the paper's Sec. 4 comparison plus a random
//! sanity baseline.  All are *reactive*: they see x(t) and place the
//! arrived jobs subject to the per-channel caps (Eq. 5) and instance
//! capacities (Eq. 6); they differ in who wins when capacity is scarce.
//!
//! * DRF — ports ascending by dominant resource share
//!   s_l = max_k a_l^k / Σ_{r∈R_l} c_r^k get resources first (the
//!   YARN/Mesos allocation order).
//! * FAIRNESS — each instance splits each resource proportionally to the
//!   arrived ports' demands: y = c_r^k · a_l^k / Σ_{l'} a_{l'}^k, capped
//!   by a_l^k (bias-free proportional sharing).
//! * BINPACKING — Kubernetes MostAllocated: jobs take capacity from the
//!   *most*-utilized instances first (consolidation).
//! * SPREADING — same scoring with the opposite favor: least-utilized
//!   instances first (isolation / load-balancing).
//!
//! Decisions are written into the edge-major [E, K] tensor (see
//! `model`); each policy walks edge-id ranges rather than dense rows, so
//! a slot costs O(|E_x|·K) in the graph's arrived neighborhood.
//!
//! §Perf-2 — arrival-scoped writes.  The engine reuses one decision
//! buffer across slots, so instead of memsetting the whole [E, K]
//! tensor every `decide`, each baseline runs through a [`Scope`] that
//! zeroes exactly the columns written *last* slot, hands the policy its
//! arrived-port worklist, and reports prev ∪ cur instance neighborhoods
//! as the policy's `Touched` set for the coordinator's incremental
//! ledger.  The internal remaining-capacity [`Ledger`] restores only
//! the rows it actually debited, for the same reason.  Net effect: a
//! baseline slot is O(arrived neighborhood), with nothing proportional
//! to |E| or R.

use crate::model::Problem;
use crate::schedulers::{Policy, Touched};
use crate::utils::rng::Rng;

/// Shared scratch: remaining capacity ledger [R, K].  Rows are restored
/// lazily — `begin` rewinds only the instances `take` debited last slot.
#[derive(Clone, Debug, Default)]
struct Ledger {
    remaining: Vec<f64>,
    /// Instances debited since the last `begin` (restored next slot).
    touched: Vec<usize>,
    flag: Vec<bool>,
}

impl Ledger {
    fn begin(&mut self, problem: &Problem) {
        if self.remaining.len() != problem.capacity.len()
            || self.flag.len() != problem.num_instances()
        {
            self.remaining.clear();
            self.remaining.extend_from_slice(&problem.capacity);
            self.flag.clear();
            self.flag.resize(problem.num_instances(), false);
            self.touched.clear();
            return;
        }
        let k_n = problem.num_resources;
        for &r in &self.touched {
            let base = r * k_n;
            self.remaining[base..base + k_n]
                .copy_from_slice(&problem.capacity[base..base + k_n]);
            self.flag[r] = false;
        }
        self.touched.clear();
    }

    /// Take up to `want` of (r, k); returns the granted amount.
    #[inline]
    fn take(&mut self, problem: &Problem, r: usize, k: usize, want: f64) -> f64 {
        if !self.flag[r] {
            self.flag[r] = true;
            self.touched.push(r);
        }
        let slot = &mut self.remaining[r * problem.num_resources + k];
        let got = want.min(*slot).max(0.0);
        *slot -= got;
        got
    }

    fn reset(&mut self) {
        self.remaining.clear();
    }
}

/// Per-slot write scope shared by the reactive baselines (§Perf-2; see
/// the module docs).  Tracks which port columns the previous `decide`
/// wrote so only those are zeroed, which instances this slot's arrivals
/// reach (`active`), and the prev ∪ cur instance set (`touched`)
/// reported to the engine's incremental commit.
#[derive(Clone, Debug, Default)]
struct Scope {
    /// Arrived ports this slot; policies reorder it in place.
    ports: Vec<usize>,
    prev_ports: Vec<usize>,
    /// Instances adjacent to this slot's arrived ports.
    active: Vec<usize>,
    /// prev ∪ cur instance neighborhoods — the `Touched` set.
    touched: Vec<usize>,
    flag: Vec<bool>,
    len: usize,
    primed: bool,
    full_last: bool,
}

impl Scope {
    /// Prepare `y` for this slot's writes: zero last slot's columns (or
    /// the whole tensor on the first call / after a shape change),
    /// collect the arrived ports and the touched-instance sets.
    fn begin(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        let k_n = problem.num_resources;
        let g = &problem.graph;
        if !self.primed || self.len != y.len() || self.flag.len() != problem.num_instances() {
            y.fill(0.0);
            self.prev_ports.clear();
            self.flag.clear();
            self.flag.resize(problem.num_instances(), false);
            self.len = y.len();
            self.primed = true;
            self.full_last = true;
        } else {
            self.full_last = false;
            for &l in &self.prev_ports {
                let lo = g.port_ptr[l] * k_n;
                let hi = g.port_ptr[l + 1] * k_n;
                y[lo..hi].fill(0.0);
            }
        }
        self.ports.clear();
        self.ports.extend((0..problem.num_ports()).filter(|&l| x[l] > 0.0));
        self.active.clear();
        self.touched.clear();
        for &l in &self.ports {
            for e in g.port_edges(l) {
                let r = g.edge_instance[e];
                if !self.flag[r] {
                    self.flag[r] = true;
                    self.active.push(r);
                }
            }
        }
        self.touched.extend_from_slice(&self.active);
        for &l in &self.prev_ports {
            for e in g.port_edges(l) {
                let r = g.edge_instance[e];
                if !self.flag[r] {
                    self.flag[r] = true;
                    self.touched.push(r);
                }
            }
        }
        for &r in &self.touched {
            self.flag[r] = false;
        }
        self.prev_ports.clear();
        self.prev_ports.extend_from_slice(&self.ports);
    }

    fn touched(&self) -> Touched<'_> {
        if self.full_last {
            Touched::All
        } else {
            Touched::Instances(&self.touched)
        }
    }

    fn reset(&mut self) {
        self.primed = false;
        self.prev_ports.clear();
    }
}

/// Greedy channel-fill in ascending-instance order: for each arrived
/// port (already ordered by the policy), take min(a_l^k, remaining
/// capacity) on every connected channel.
fn greedy_fill(problem: &Problem, ports: &[usize], ledger: &mut Ledger, y: &mut [f64]) {
    let k_n = problem.num_resources;
    let g = &problem.graph;
    for &l in ports {
        for e in g.port_edges(l) {
            let r = g.edge_instance[e];
            let base = e * k_n;
            for k in 0..k_n {
                y[base + k] = ledger.take(problem, r, k, problem.demand_at(l, k));
            }
        }
    }
}

/// Instance utilization score: allocated fraction of capacity, averaged
/// over resource types (the Volcano binpack plugin's scoring shape).
fn utilization(problem: &Problem, r: usize, ledger: &Ledger) -> f64 {
    let k_n = problem.num_resources;
    let mut score = 0.0;
    let mut terms = 0.0;
    for k in 0..k_n {
        let cap = problem.capacity_at(r, k);
        if cap > 0.0 {
            score += 1.0 - ledger.remaining[r * k_n + k] / cap;
            terms += 1.0;
        }
    }
    if terms > 0.0 {
        score / terms
    } else {
        0.0
    }
}

/// Parallelism budget for the packing/spreading heuristics: the job
/// requests its per-channel maximum on about half of its reachable
/// channels (these schedulers place a job, they do not reserve the whole
/// locality set the way the OGA reservation does).
fn budget_channels(n_channels: usize) -> f64 {
    ((n_channels as f64) / 2.0).ceil().max(1.0)
}

// ---------------------------------------------------------------- DRF --

pub struct Drf {
    ledger: Ledger,
    scope: Scope,
    /// Dominant shares per port, cached on first decide — they depend
    /// only on the problem's demands/capacities, so recomputing the
    /// O(|R_l|·K) score inside every sort comparison would put a
    /// static quantity on the per-slot hot path.
    shares: Vec<f64>,
}

impl Drf {
    pub fn new() -> Self {
        Drf { ledger: Ledger::default(), scope: Scope::default(), shares: Vec::new() }
    }

    /// Dominant share s_l = max_k a_l^k / Σ_{r∈R_l} c_r^k.
    pub fn dominant_share(problem: &Problem, l: usize) -> f64 {
        let k_n = problem.num_resources;
        let mut worst = 0.0f64;
        for k in 0..k_n {
            let pool: f64 = problem.graph.ports_to_instances[l]
                .iter()
                .map(|&r| problem.capacity_at(r, k))
                .sum();
            if pool > 0.0 {
                worst = worst.max(problem.demand_at(l, k) / pool);
            }
        }
        worst
    }
}

impl Default for Drf {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for Drf {
    fn name(&self) -> &'static str {
        "DRF"
    }

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        self.scope.begin(problem, x, y);
        self.ledger.begin(problem);
        if self.shares.len() != problem.num_ports() {
            self.shares =
                (0..problem.num_ports()).map(|l| Drf::dominant_share(problem, l)).collect();
        }
        let shares = &self.shares;
        self.scope.ports.sort_by(|&a, &b| shares[a].partial_cmp(&shares[b]).unwrap());
        greedy_fill(problem, &self.scope.ports, &mut self.ledger, y);
    }

    fn reset(&mut self, _problem: &Problem) {
        self.scope.reset();
        self.ledger.reset();
        self.shares.clear();
    }

    fn touched(&self) -> Touched<'_> {
        self.scope.touched()
    }
}

// ----------------------------------------------------------- FAIRNESS --

pub struct Fairness {
    scope: Scope,
}

impl Fairness {
    pub fn new() -> Self {
        Fairness { scope: Scope::default() }
    }
}

impl Default for Fairness {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for Fairness {
    fn name(&self) -> &'static str {
        "FAIRNESS"
    }

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        self.scope.begin(problem, x, y);
        let k_n = problem.num_resources;
        let g = &problem.graph;
        // only instances adjacent to an arrived port can receive a
        // share — exactly the scope's active set
        for &r in &self.scope.active {
            let edges = g.instance_edge_ids(r);
            for k in 0..k_n {
                let total_demand: f64 = edges
                    .iter()
                    .filter(|&&e| x[g.edge_port[e]] > 0.0)
                    .map(|&e| problem.demand_at(g.edge_port[e], k))
                    .sum();
                if total_demand <= 0.0 {
                    continue;
                }
                let cap = problem.capacity_at(r, k);
                for &e in edges {
                    let l = g.edge_port[e];
                    if x[l] <= 0.0 {
                        continue;
                    }
                    let want = problem.demand_at(l, k);
                    // proportional share, never above the channel cap
                    let share = cap * want / total_demand;
                    y[e * k_n + k] = share.min(want);
                }
            }
        }
    }

    fn reset(&mut self, _problem: &Problem) {
        self.scope.reset();
    }

    fn touched(&self) -> Touched<'_> {
        self.scope.touched()
    }
}

// --------------------------------------------- BINPACKING / SPREADING --

pub struct BinPacking {
    ledger: Ledger,
    scope: Scope,
    order: Vec<usize>,
}

impl BinPacking {
    pub fn new() -> Self {
        BinPacking { ledger: Ledger::default(), scope: Scope::default(), order: Vec::new() }
    }
}

impl Default for BinPacking {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for BinPacking {
    fn name(&self) -> &'static str {
        "BINPACKING"
    }

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        self.scope.begin(problem, x, y);
        self.ledger.begin(problem);
        let k_n = problem.num_resources;
        let g = &problem.graph;
        for &l in &self.scope.ports {
            let n_channels = g.port_edges(l).len();
            self.order.clear();
            self.order.extend(g.port_edges(l));
            // MostAllocated: highest utilization first (consolidation)
            let ledger = &self.ledger;
            self.order.sort_by(|&a, &b| {
                utilization(problem, g.edge_instance[b], ledger)
                    .partial_cmp(&utilization(problem, g.edge_instance[a], ledger))
                    .unwrap()
            });
            for k in 0..k_n {
                // parallelism budget: the job asks for its per-channel max
                // on about half of its reachable channels
                let mut budget = problem.demand_at(l, k) * budget_channels(n_channels);
                for &e in &self.order {
                    if budget <= 0.0 {
                        break;
                    }
                    let want = problem.demand_at(l, k).min(budget);
                    let got = self.ledger.take(problem, g.edge_instance[e], k, want);
                    y[e * k_n + k] = got;
                    budget -= got;
                }
            }
        }
    }

    fn reset(&mut self, _problem: &Problem) {
        self.scope.reset();
        self.ledger.reset();
    }

    fn touched(&self) -> Touched<'_> {
        self.scope.touched()
    }
}

pub struct Spreading {
    ledger: Ledger,
    scope: Scope,
    order: Vec<usize>,
}

impl Spreading {
    pub fn new() -> Self {
        Spreading { ledger: Ledger::default(), scope: Scope::default(), order: Vec::new() }
    }
}

impl Default for Spreading {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for Spreading {
    fn name(&self) -> &'static str {
        "SPREADING"
    }

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        self.scope.begin(problem, x, y);
        self.ledger.begin(problem);
        let k_n = problem.num_resources;
        let g = &problem.graph;
        for &l in &self.scope.ports {
            let n_channels = g.port_edges(l).len();
            self.order.clear();
            self.order.extend(g.port_edges(l));
            // LeastAllocated: lowest utilization first (isolation)
            let ledger = &self.ledger;
            self.order.sort_by(|&a, &b| {
                utilization(problem, g.edge_instance[a], ledger)
                    .partial_cmp(&utilization(problem, g.edge_instance[b], ledger))
                    .unwrap()
            });
            for k in 0..k_n {
                // same budget as BINPACKING, but spread evenly over every
                // reachable channel instead of packed onto few
                let budget = problem.demand_at(l, k) * budget_channels(n_channels);
                let per_channel = budget / n_channels.max(1) as f64;
                for &e in &self.order {
                    let want = per_channel.min(problem.demand_at(l, k));
                    let got = self.ledger.take(problem, g.edge_instance[e], k, want);
                    y[e * k_n + k] = got;
                }
            }
        }
    }

    fn reset(&mut self, _problem: &Problem) {
        self.scope.reset();
        self.ledger.reset();
    }

    fn touched(&self) -> Touched<'_> {
        self.scope.touched()
    }
}

// -------------------------------------------------------- RandomAlloc --

/// Random feasible allocation — a sanity floor for the figures (any
/// serious policy must beat it).
pub struct RandomAlloc {
    ledger: Ledger,
    scope: Scope,
    rng: Rng,
}

impl RandomAlloc {
    pub fn new(seed: u64) -> Self {
        RandomAlloc { ledger: Ledger::default(), scope: Scope::default(), rng: Rng::new(seed) }
    }
}

impl Policy for RandomAlloc {
    fn name(&self) -> &'static str {
        "RANDOM"
    }

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        self.scope.begin(problem, x, y);
        self.ledger.begin(problem);
        let k_n = problem.num_resources;
        let g = &problem.graph;
        self.rng.shuffle(&mut self.scope.ports);
        for &l in &self.scope.ports {
            for e in g.port_edges(l) {
                let r = g.edge_instance[e];
                let base = e * k_n;
                for k in 0..k_n {
                    let frac = self.rng.f64();
                    let want = problem.demand_at(l, k) * frac;
                    y[base + k] = self.ledger.take(problem, r, k, want);
                }
            }
        }
    }

    fn reset(&mut self, _problem: &Problem) {
        self.scope.reset();
        self.ledger.reset();
    }

    fn touched(&self) -> Touched<'_> {
        self.scope.touched()
    }

    fn snapshot_state(&self, w: &mut crate::utils::codec::Writer) {
        // The only cross-slot state is the RNG stream (ledger and scope
        // rebuild from the arrived neighborhood every decide); `reset`
        // does NOT re-seed it, so a resume must restore the stream
        // position, not the seed.
        let s = self.rng.state();
        w.put_u64s(&s);
    }

    fn restore_state(
        &mut self,
        _problem: &Problem,
        r: &mut crate::utils::codec::Reader,
    ) -> Result<(), String> {
        let s = r.get_u64s()?;
        if s.len() != 4 {
            return Err(format!("random-alloc snapshot: rng state len {}", s.len()));
        }
        self.rng = Rng::from_state([s[0], s[1], s[2], s[3]]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::reward::slot_reward;
    use crate::traces::synthesize;

    fn scarce_problem() -> Problem {
        // capacity scarce enough that ordering matters
        let mut s = Scenario::small();
        s.contention = 20.0;
        synthesize(&s)
    }

    #[test]
    fn drf_orders_by_dominant_share() {
        let p = synthesize(&Scenario::small());
        // shares are computable and finite for every port
        for l in 0..p.num_ports() {
            let s = Drf::dominant_share(&p, l);
            assert!(s.is_finite() && s >= 0.0);
        }
    }

    #[test]
    fn all_baselines_respect_scarcity() {
        let p = scarce_problem();
        let x = vec![1.0; p.num_ports()];
        let mut y = vec![0.0; p.decision_len()];
        let mut policies: Vec<Box<dyn Policy>> = vec![
            Box::new(Drf::new()),
            Box::new(Fairness::new()),
            Box::new(BinPacking::new()),
            Box::new(Spreading::new()),
            Box::new(RandomAlloc::new(3)),
        ];
        for pol in policies.iter_mut() {
            pol.decide(&p, &x, &mut y);
            p.check_feasible(&y, 1e-9)
                .map_err(|e| format!("{}: {e}", pol.name()))
                .unwrap();
            let r = slot_reward(&p, &x, &y);
            assert!(r.gain > 0.0, "{} allocated nothing", pol.name());
        }
    }

    #[test]
    fn no_allocation_to_absent_ports() {
        let p = synthesize(&Scenario::small());
        let mut x = vec![0.0; p.num_ports()];
        x[0] = 1.0;
        let mut y = vec![0.0; p.decision_len()];
        let mut policies: Vec<Box<dyn Policy>> = vec![
            Box::new(Drf::new()),
            Box::new(Fairness::new()),
            Box::new(BinPacking::new()),
            Box::new(Spreading::new()),
        ];
        for pol in policies.iter_mut() {
            pol.decide(&p, &x, &mut y);
            for l in 1..p.num_ports() {
                for e in p.graph.port_edges(l) {
                    for k in 0..p.num_resources {
                        assert_eq!(
                            y[p.edge_idx(e, k)],
                            0.0,
                            "{} allocated to absent port {l}",
                            pol.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn scoped_writes_match_fresh_buffer_decisions() {
        // A decides into one persistent buffer (the engine contract);
        // B — an identical policy — gets a freshly zeroed buffer every
        // slot.  A correct decision has all non-arrived columns at zero,
        // so the two must agree exactly; this pins the scope's
        // zero-last-slot bookkeeping under changing sparse arrivals.
        let p = scarce_problem();
        let mut rng = crate::utils::rng::Rng::new(99);
        let arrivals: Vec<Vec<f64>> = (0..25)
            .map(|_| {
                (0..p.num_ports())
                    .map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 })
                    .collect()
            })
            .collect();
        let pairs: Vec<(Box<dyn Policy>, Box<dyn Policy>)> = vec![
            (Box::new(Drf::new()), Box::new(Drf::new())),
            (Box::new(Fairness::new()), Box::new(Fairness::new())),
            (Box::new(BinPacking::new()), Box::new(BinPacking::new())),
            (Box::new(Spreading::new()), Box::new(Spreading::new())),
            (Box::new(RandomAlloc::new(5)), Box::new(RandomAlloc::new(5))),
        ];
        for (mut a, mut b) in pairs {
            let mut y_a = vec![0.0; p.decision_len()];
            for (t, x) in arrivals.iter().enumerate() {
                a.decide(&p, x, &mut y_a);
                let mut y_b = vec![0.0; p.decision_len()];
                b.decide(&p, x, &mut y_b);
                assert_eq!(y_a, y_b, "{} diverged at t={t}", a.name());
                // the touched set must cover every arrived instance
                if let Touched::Instances(list) = a.touched() {
                    for l in (0..p.num_ports()).filter(|&l| x[l] > 0.0) {
                        for &r in &p.graph.ports_to_instances[l] {
                            assert!(
                                list.contains(&r),
                                "{}: touched set misses instance {r}",
                                a.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn binpacking_concentrates_spreading_balances() {
        let p = scarce_problem();
        let x = vec![1.0; p.num_ports()];
        let mut y_bin = vec![0.0; p.decision_len()];
        let mut y_spr = vec![0.0; p.decision_len()];
        BinPacking::new().decide(&p, &x, &mut y_bin);
        Spreading::new().decide(&p, &x, &mut y_spr);
        // BINPACKING stops once the budget is packed onto few channels;
        // SPREADING touches every reachable channel.  Count the channels
        // each policy actually uses.
        let used_channels = |y: &[f64]| -> usize {
            let mut n = 0;
            for e in 0..p.num_edges() {
                let base = e * p.num_resources;
                if (0..p.num_resources).any(|k| y[base + k] > 1e-9) {
                    n += 1;
                }
            }
            n
        };
        assert!(
            used_channels(&y_bin) < used_channels(&y_spr),
            "binpacking ({}) should use fewer channels than spreading ({})",
            used_channels(&y_bin),
            used_channels(&y_spr)
        );
        assert_ne!(y_bin, y_spr);
    }

    #[test]
    fn fairness_is_proportional_when_uncontended() {
        // single instance, two ports, ample capacity: each gets its demand
        use crate::graph::Bipartite;
        use crate::oga::utilities::UtilityKind;
        let p = Problem::new(
            Bipartite::full(2, 1),
            1,
            vec![2.0, 6.0],
            vec![100.0],
            vec![1.0],
            vec![UtilityKind::Linear],
            vec![0.3],
        );
        let mut y = vec![0.0; 2];
        Fairness::new().decide(&p, &[1.0, 1.0], &mut y);
        // shares: cap*2/8 = 25 -> capped at 2; cap*6/8 = 75 -> capped at 6
        assert!((y[0] - 2.0).abs() < 1e-12);
        assert!((y[1] - 6.0).abs() < 1e-12);
    }
}
