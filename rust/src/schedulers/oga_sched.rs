//! OGASCHED as a [`Policy`]: the paper's Algorithm 1 wrapped for the
//! slot engine.
//!
//! Ordering per Def. 2: the decision scored in slot t is the y(t)
//! computed *before* x(t) was observed; x(t) then drives the gradient
//! ascent toward y(t+1).  `decide` therefore copies the committed y(t)
//! into the output buffer first and steps the internal state afterwards.

use std::sync::Arc;

use crate::coordinator::sharded::ShardPlan;
use crate::model::Problem;
use crate::oga::{LearningRate, OgaState};
use crate::schedulers::{IncrementalPublisher, Policy, Touched};
use crate::utils::pool::ExecBudget;

pub struct OgaSched {
    state: OgaState,
    eta0: f64,
    decay: f64,
    budget: ExecBudget,
    /// Shard plan bound by the sharded coordinator (§Perf-3); re-bound
    /// into the fresh state on `reset`.
    plan: Option<Arc<ShardPlan>>,
    /// Incremental publish into the engine's reused output buffer
    /// (§Perf-2): only the columns the step changed are rewritten, and
    /// they double as the policy's `Touched` report.
    publisher: IncrementalPublisher,
    /// Reservation mode only: the dirty set of the last internal step,
    /// which the *next* decide will publish (decide(t) emits the
    /// pre-step y(t), i.e. the state after step t−1).
    pending: Vec<usize>,
    /// Scoring semantics.  `false` = the literal Def. 2 reading: slot t
    /// is served by the reservation y(t) committed *before* x(t) was
    /// observed (what the regret proof bounds).  `true` = the paper's
    /// *evaluation* semantics: the slot-t gradient step runs after the
    /// arrivals are observed and the resulting y(t+1) serves them —
    /// i.e., Alg. 1 executes at the head of the slot.  The reactive
    /// reading is the only one consistent with Sec. 4's results in
    /// near-penalty-free regimes (Fig. 5's beta ~ 0.01, Fig. 7 linear),
    /// where a pure reservation provably cannot beat reactive
    /// proportional sharing; see EXPERIMENTS.md §Fig5.
    reactive: bool,
}

impl OgaSched {
    /// Reactive-scoring OGASCHED (the paper's evaluation semantics).
    pub fn new(problem: &Problem, eta0: f64, decay: f64, budget: ExecBudget) -> Self {
        OgaSched {
            state: OgaState::new(
                problem,
                LearningRate::Decay { eta0, lambda: decay },
                budget,
            ),
            eta0,
            decay,
            budget,
            plan: None,
            publisher: IncrementalPublisher::default(),
            pending: Vec::new(),
            reactive: true,
        }
    }

    /// Literal Def. 2 reservation scoring (what Thm. 1 bounds); used by
    /// the regret experiments and theory tests.
    pub fn reservation(problem: &Problem, eta0: f64, decay: f64, budget: ExecBudget) -> Self {
        OgaSched { reactive: false, ..Self::new(problem, eta0, decay, budget) }
    }

    /// Use the Eq. 50 oracle learning rate instead of the decay schedule
    /// (reservation scoring — this is the Thm. 1 configuration).  Under
    /// a bound shard plan the two-pass step fans out per shard — since
    /// §Perf-5 including phase A's per-port quota/k* reductions — with
    /// only the ‖∇q‖ reduction replayed serially, so plan-bound runs
    /// stay bit-identical to serial (`tests/shard_parity.rs`).
    pub fn with_oracle_rate(problem: &Problem, horizon: usize, budget: ExecBudget) -> Self {
        OgaSched {
            state: OgaState::new(problem, LearningRate::Oracle { horizon }, budget),
            eta0: 0.0,
            decay: 0.0,
            budget,
            plan: None,
            publisher: IncrementalPublisher::default(),
            pending: Vec::new(),
            reactive: false,
        }
    }

    pub fn current_decision(&self) -> &[f64] {
        &self.state.y
    }
}

impl Policy for OgaSched {
    fn name(&self) -> &'static str {
        "OGASCHED"
    }

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        if self.reactive {
            // Alg. 1 at the head of the slot: observe x(t), step, serve
            // the arrivals with the updated allocation.  The step only
            // perturbs its dirty instances, so publishing the decision
            // copies exactly those columns (§Perf-2).
            self.state.step(problem, x);
            self.publisher.publish(problem, &self.state.y, y, self.state.dirty_instances());
        } else {
            // Def. 2 reservation: commit the pre-arrival y(t), which
            // differs from the previously emitted y(t−1) by the dirty
            // set of the step taken at the end of slot t−1 ...
            self.publisher.publish(problem, &self.state.y, y, &self.pending);
            // ... then learn from x(t) toward y(t+1).
            self.state.step(problem, x);
            self.pending.clear();
            self.pending.extend_from_slice(self.state.dirty_instances());
        }
    }

    fn reset(&mut self, problem: &Problem) {
        let lr = if self.eta0 > 0.0 {
            LearningRate::Decay { eta0: self.eta0, lambda: self.decay }
        } else {
            self.state.lr
        };
        self.state = OgaState::new(problem, lr, self.budget);
        if let Some(plan) = &self.plan {
            self.state.bind_shards(plan.clone());
        }
        self.publisher.reset();
        self.pending.clear();
    }

    fn touched(&self) -> Touched<'_> {
        self.publisher.touched()
    }

    fn bind_shards(&mut self, plan: &Arc<ShardPlan>) {
        self.plan = Some(plan.clone());
        self.state.bind_shards(plan.clone());
    }

    fn remap(&mut self, old_graph: &crate::graph::Bipartite, problem: &Problem) {
        // Carry the learned tensor by (l, r) key; drop the stale plan
        // (edge ids shifted — the next sharded run re-binds) and
        // re-prime the publisher, so the first post-churn decide is a
        // conservative full publish into the new-length buffer.
        self.state.remap(old_graph, problem);
        self.plan = None;
        self.publisher.reset();
        self.pending.clear();
    }

    fn snapshot_state(&self, w: &mut crate::utils::codec::Writer) {
        // `pending` is deliberately absent: a restored policy starts
        // with a re-primed publisher, whose first publish is a full
        // copy — bitwise identical to the incremental publish of the
        // pending set, which is the same equivalence the run-epoch
        // re-prime already relies on every fresh run.
        self.state.snapshot(w);
    }

    fn restore_state(
        &mut self,
        problem: &Problem,
        r: &mut crate::utils::codec::Reader,
    ) -> Result<(), String> {
        self.state.restore(problem, r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::traces::synthesize;

    #[test]
    fn first_decision_is_the_zero_reservation() {
        let p = synthesize(&Scenario::small());
        let mut pol = OgaSched::reservation(&p, 5.0, 0.999, ExecBudget::auto());
        let x = vec![1.0; p.num_ports()];
        let mut y = vec![9.0; p.decision_len()];
        pol.decide(&p, &x, &mut y);
        assert!(y.iter().all(|&v| v == 0.0), "y(1) must be the initial point");
        // second decision reflects the first gradient step
        pol.decide(&p, &x, &mut y);
        assert!(y.iter().any(|&v| v > 0.0));

        // reactive mode serves x(1) with the post-step allocation
        let mut pol = OgaSched::new(&p, 5.0, 0.999, ExecBudget::auto());
        pol.decide(&p, &x, &mut y);
        assert!(y.iter().any(|&v| v > 0.0), "reactive y includes the slot-1 step");
    }

    #[test]
    fn reset_restores_initial_state() {
        let p = synthesize(&Scenario::small());
        let mut pol = OgaSched::reservation(&p, 5.0, 0.999, ExecBudget::auto());
        let x = vec![1.0; p.num_ports()];
        let mut y = vec![0.0; p.decision_len()];
        for _ in 0..5 {
            pol.decide(&p, &x, &mut y);
        }
        pol.reset(&p);
        pol.decide(&p, &x, &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn incremental_publish_matches_raw_state_trajectory() {
        // decide() rewrites only the dirty columns of the reused output
        // buffer; the buffer must still equal the full state trajectory
        // under sparse, changing arrivals
        let p = synthesize(&Scenario::small());
        let mut rng = crate::utils::rng::Rng::new(41);
        let arrivals: Vec<Vec<f64>> = (0..30)
            .map(|_| {
                (0..p.num_ports())
                    .map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 })
                    .collect()
            })
            .collect();
        // reactive: emitted y(t) == state after step t
        let mut pol = OgaSched::new(&p, 5.0, 0.999, ExecBudget::auto());
        let mut shadow = OgaState::new(
            &p,
            LearningRate::Decay { eta0: 5.0, lambda: 0.999 },
            ExecBudget::auto(),
        );
        let mut y = vec![0.0; p.decision_len()];
        for x in &arrivals {
            pol.decide(&p, x, &mut y);
            shadow.step(&p, x);
            assert_eq!(y, shadow.y);
            // Touched::All can legitimately occur at any slot (another
            // test's Leader::run bumping the run epoch forces a
            // conservative full publish); when the publish was
            // incremental, the reported set must be the dirty set.
            if let Touched::Instances(list) = pol.touched() {
                let mut got = list.to_vec();
                got.sort_unstable();
                let mut want = shadow.dirty_instances().to_vec();
                want.sort_unstable();
                assert_eq!(got, want);
            }
        }
        // reservation: emitted y(t) == state *before* step t
        let mut pol = OgaSched::reservation(&p, 5.0, 0.999, ExecBudget::auto());
        let mut shadow = OgaState::new(
            &p,
            LearningRate::Decay { eta0: 5.0, lambda: 0.999 },
            ExecBudget::auto(),
        );
        let mut y = vec![9.0; p.decision_len()];
        for x in &arrivals {
            pol.decide(&p, x, &mut y);
            assert_eq!(y, shadow.y);
            shadow.step(&p, x);
        }
    }

    #[test]
    fn reactive_and_reservation_trajectories_offset_by_one() {
        // reactive(t) decision == reservation(t+1) decision on the same
        // arrival sequence (the step order is the only difference)
        let p = synthesize(&Scenario::small());
        let x = vec![1.0; p.num_ports()];
        let mut ra = OgaSched::new(&p, 5.0, 0.999, ExecBudget::auto());
        let mut rs = OgaSched::reservation(&p, 5.0, 0.999, ExecBudget::auto());
        let mut y_a = vec![0.0; p.decision_len()];
        let mut y_s = vec![0.0; p.decision_len()];
        rs.decide(&p, &x, &mut y_s); // reservation slot 1 -> y(1)=0
        for _ in 0..5 {
            ra.decide(&p, &x, &mut y_a);
            rs.decide(&p, &x, &mut y_s);
            for (a, b) in y_a.iter().zip(&y_s) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }
}
