//! Entropic mirror-ascent variant of OGASCHED.
//!
//! Sec. 3.5 notes that the non-convex gang extension can be attacked
//! "with the subgradient ascent and mirror ascent related techniques
//! which retain a sublinear regret".  This module provides the mirror
//! half as a first-class policy so the claim is testable: the same
//! Eq. 30 gradient drives a multiplicative-weights update
//!
//! ```text
//! ŷ_i    = y_i · exp(η · ∇_i q)   (mirror step, negative-entropy geometry)
//! y(t+1) = Π_Y(ŷ)                 (Euclidean feasibility projection, Alg. 1)
//! ```
//!
//! Multiplicative updates cannot leave the non-negative orthant and
//! concentrate allocation on high-marginal-gain channels faster than the
//! additive step when the polytope is loose; the additive OGA catches up
//! once capacity binds.  `benches/ablation_projection.rs` and the
//! scheduler tests compare the two.
//!
//! Because exp(·) freezes coordinates at exactly 0, the state is seeded
//! at a small ε > 0 on every edge instead of the OGA zero start.

use std::sync::Arc;

use crate::coordinator::sharded::{active_plan, project_dirty_sharded, ArrivedPort, ShardPlan};
use crate::model::Problem;
use crate::oga::kernels;
use crate::oga::projection::{project, project_instances};
use crate::schedulers::{IncrementalPublisher, Policy, Touched};
use crate::utils::pool::{self, ExecBudget, SyncSlice};

/// Seed allocation (fraction of the per-channel cap) so multiplicative
/// updates have something to multiply.
const SEED_FRACTION: f64 = 1e-3;

/// Exponent clamp: keeps exp() finite under aggressive rates.
const MAX_EXPONENT: f64 = 30.0;

pub struct OgaMirror {
    /// Current decision y(t), edge-major [E, K].
    y: Vec<f64>,
    eta0: f64,
    decay: f64,
    budget: ExecBudget,
    /// Slot counter (diagnostic; η is maintained in `eta_run`).
    pub t: usize,
    /// Running η (η_{t+1} = λ·η_t), replacing the per-slot
    /// `decay.powi(t as i32)` re-exponentiation (§Perf-2; the i32 cast
    /// also truncated for horizons beyond i32::MAX).
    eta_run: f64,
    quota: Vec<f64>,
    /// Dirty-instance tracking (same trick as `OgaState::step`).
    dirty: Vec<bool>,
    dirty_list: Vec<usize>,
    /// Incremental publish into the engine's reused output buffer
    /// (shared state machine with `OgaSched`).
    publisher: IncrementalPublisher,
    /// Shard plan bound by the sharded coordinator (§Perf-3): the
    /// multiplicative update and the dirty projection fan out per
    /// shard, bit-identically (disjoint coordinate ownership, same
    /// per-element math).
    plan: Option<Arc<ShardPlan>>,
    /// Phase-A records of the sharded step.
    port_steps: Vec<ArrivedPort>,
    /// Per-shard dirty partitions (projection scatter scratch).
    shard_dirty: Vec<Vec<usize>>,
}

impl OgaMirror {
    pub fn new(problem: &Problem, eta0: f64, decay: f64, budget: ExecBudget) -> Self {
        let mut pol = OgaMirror {
            y: Vec::new(),
            eta0,
            decay,
            budget,
            t: 0,
            eta_run: eta0,
            quota: vec![0.0; problem.num_resources],
            dirty: vec![false; problem.num_instances()],
            dirty_list: Vec::new(),
            publisher: IncrementalPublisher::default(),
            plan: None,
            port_steps: Vec::new(),
            shard_dirty: Vec::new(),
        };
        pol.seed(problem);
        pol
    }

    fn seed(&mut self, problem: &Problem) {
        let k_n = problem.num_resources;
        self.y = vec![0.0; problem.decision_len()];
        for e in 0..problem.num_edges() {
            let l = problem.graph.edge_port[e];
            for k in 0..k_n {
                self.y[e * k_n + k] = SEED_FRACTION * problem.demand_at(l, k);
            }
        }
        // the seed touches every edge, so this one projection is global
        project(problem, &mut self.y, self.budget.shards);
        self.t = 0;
        self.eta_run = self.eta0;
        self.publisher.reset();
    }

    /// One mirror step: multiplicative update on arrived ports' lanes
    /// (Eq. 30 gradient), then the Alg. 1 projection of the perturbed
    /// (dirty) instances only.
    fn step(&mut self, problem: &Problem, x: &[f64]) {
        let eta = self.eta_run;
        self.eta_run *= self.decay;
        for &r in &self.dirty_list {
            self.dirty[r] = false;
        }
        self.dirty_list.clear();
        match active_plan(&self.plan) {
            Some(plan) => {
                self.update_sharded(problem, x, eta, &plan);
                project_dirty_sharded(
                    problem,
                    &mut self.y,
                    &self.dirty_list,
                    &plan,
                    &mut self.shard_dirty,
                );
            }
            None => {
                self.update_serial(problem, x, eta);
                project_instances(problem, &mut self.y, &self.dirty_list, self.budget.shards);
            }
        }
        self.t += 1;
    }

    fn update_serial(&mut self, problem: &Problem, x: &[f64], eta: f64) {
        let g = &problem.graph;
        for l in 0..problem.num_ports() {
            let x_l = x[l];
            if x_l == 0.0 {
                continue;
            }
            let edges = g.port_edges(l);
            let kstar = crate::oga::port_kstar(problem, l, &self.y, &mut self.quota);
            for e in edges {
                let r = g.edge_instance[e];
                if !self.dirty[r] {
                    self.dirty[r] = true;
                    self.dirty_list.push(r);
                }
                mirror_edge(problem, &mut self.y, e, eta * x_l, kstar);
            }
        }
    }

    /// Sharded multiplicative update (§Perf-3): phase A records each
    /// arrived port's (η·x, k*) and marks the dirty set in the serial
    /// discovery order (reads only — ports own disjoint slices, so the
    /// quotas equal the serial interleaved ones bit for bit); phase B
    /// fans the per-edge updates out, each shard touching exactly the
    /// edges it owns through the same [`mirror_edge`] kernel.
    fn update_sharded(&mut self, problem: &Problem, x: &[f64], eta: f64, plan: &ShardPlan) {
        let g = &problem.graph;
        self.port_steps.clear();
        for l in 0..problem.num_ports() {
            let x_l = x[l];
            if x_l == 0.0 {
                continue;
            }
            let edges = g.port_edges(l);
            let kstar = crate::oga::port_kstar(problem, l, &self.y, &mut self.quota);
            self.port_steps.push(ArrivedPort { l, scale: eta * x_l, kstar, pen: 0.0 });
            for e in edges {
                let r = g.edge_instance[e];
                if !self.dirty[r] {
                    self.dirty[r] = true;
                    self.dirty_list.push(r);
                }
            }
        }
        if self.port_steps.is_empty() {
            return;
        }
        let steps = &self.port_steps;
        let view = SyncSlice::new(&mut self.y);
        let y_len = view.len();
        pool::parallel_for(plan.num_shards(), plan.num_shards(), |s| {
            // SAFETY: every edge belongs to exactly one instance and
            // each instance to exactly one shard — disjoint writes.
            let y = unsafe { view.slice_mut(0, y_len) };
            for step in steps {
                for &e in plan.port_edges(s, step.l) {
                    mirror_edge(problem, y, e, step.scale, step.kstar);
                }
            }
        });
    }
}

/// One edge's multiplicative update — thin wrapper over the shared
/// [`kernels::mirror_edge`] (§Perf-5) binding this policy's exponent
/// clamp; the single per-edge kernel of the serial and sharded steps
/// (identical floats by construction).  `scale` is η_t · x_l; β_{k*}
/// is folded into the exponent.
#[inline]
fn mirror_edge(problem: &Problem, y: &mut [f64], e: usize, scale: f64, kstar: usize) {
    kernels::mirror_edge(problem, y, e, scale, kstar, MAX_EXPONENT);
}

impl Policy for OgaMirror {
    fn name(&self) -> &'static str {
        "OGASCHED-MIRROR"
    }

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        // reactive scoring, matching OgaSched::new; the multiplicative
        // update perturbs only the dirty instances, so publishing is an
        // incremental column copy after the first slot (§Perf-2)
        self.step(problem, x);
        self.publisher.publish(problem, &self.y, y, &self.dirty_list);
    }

    fn reset(&mut self, problem: &Problem) {
        self.seed(problem);
    }

    fn touched(&self) -> Touched<'_> {
        self.publisher.touched()
    }

    fn bind_shards(&mut self, plan: &Arc<ShardPlan>) {
        self.shard_dirty = vec![Vec::new(); plan.num_shards()];
        self.plan = Some(plan.clone());
    }

    fn remap(&mut self, old_graph: &crate::graph::Bipartite, problem: &Problem) {
        // Carry surviving channels by (l, r) key; channels new to this
        // edition get the ε seed (exp(·) freezes coordinates at exactly
        // 0, so a recovered channel must restart strictly positive).
        // Seeding can overfill a recovered instance, so exactly the
        // instances that gained edges are re-projected — a deterministic
        // call both churn parity arms share.
        let k_n = problem.num_resources;
        let g = &problem.graph;
        let mut y = vec![0.0; problem.decision_len()];
        let mut fresh = vec![false; problem.num_instances()];
        let mut fresh_list: Vec<usize> = Vec::new();
        for e in 0..g.num_edges() {
            let l = g.edge_port[e];
            let r = g.edge_instance[e];
            match old_graph.edge_id(l, r) {
                Some(old_e) => {
                    y[e * k_n..(e + 1) * k_n]
                        .copy_from_slice(&self.y[old_e * k_n..(old_e + 1) * k_n]);
                }
                None => {
                    for k in 0..k_n {
                        y[e * k_n + k] = SEED_FRACTION * problem.demand_at(l, k);
                    }
                    if !fresh[r] {
                        fresh[r] = true;
                        fresh_list.push(r);
                    }
                }
            }
        }
        self.y = y;
        fresh_list.sort_unstable();
        project_instances(problem, &mut self.y, &fresh_list, self.budget.shards);
        for &r in &self.dirty_list {
            self.dirty[r] = false;
        }
        self.dirty_list.clear();
        self.plan = None;
        self.shard_dirty.clear();
        self.port_steps.clear();
        self.publisher.reset();
        // t and eta_run carry — the learning clock survives the edition
    }

    fn snapshot_state(&self, w: &mut crate::utils::codec::Writer) {
        // Same minimal-sufficiency contract as `OgaState::snapshot`:
        // the learned tensor, the slot clock, the running η.  The dirty
        // tracking is cleared at every step's start, and the restored
        // publisher's first publish is a full copy.
        w.put_f64s(&self.y);
        w.put_u64(self.t as u64);
        w.put_f64(self.eta_run);
    }

    fn restore_state(
        &mut self,
        problem: &Problem,
        r: &mut crate::utils::codec::Reader,
    ) -> Result<(), String> {
        let y = r.get_f64s()?;
        if y.len() != problem.decision_len() {
            return Err(format!(
                "mirror snapshot: y len {} vs decision len {} (wrong edition?)",
                y.len(),
                problem.decision_len()
            ));
        }
        self.y = y;
        self.t = r.get_u64()? as usize;
        self.eta_run = r.get_f64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::reward::slot_reward;
    use crate::schedulers::OgaSched;
    use crate::sim;
    use crate::traces::synthesize;

    #[test]
    fn mirror_decisions_feasible() {
        let s = Scenario::small();
        let p = synthesize(&s);
        let mut pol = OgaMirror::new(&p, 2.0, 0.9999, ExecBudget::auto());
        let x = vec![1.0; p.num_ports()];
        let mut y = vec![0.0; p.decision_len()];
        for _ in 0..30 {
            pol.decide(&p, &x, &mut y);
            p.check_feasible(&y, 1e-7).unwrap();
        }
    }

    #[test]
    fn mirror_climbs_reward() {
        let s = Scenario::small();
        let p = synthesize(&s);
        let mut pol = OgaMirror::new(&p, 2.0, 0.9999, ExecBudget::auto());
        let x = vec![1.0; p.num_ports()];
        let mut y = vec![0.0; p.decision_len()];
        pol.decide(&p, &x, &mut y);
        let early = slot_reward(&p, &x, &y).q;
        for _ in 0..150 {
            pol.decide(&p, &x, &mut y);
        }
        let late = slot_reward(&p, &x, &y).q;
        assert!(late > early, "mirror ascent did not climb: {early} -> {late}");
    }

    #[test]
    fn mirror_competitive_with_additive_oga() {
        // On the default small scenario the two first-order methods land
        // within a modest factor of each other (the point of Sec. 3.5's
        // "related techniques retain sublinear regret").
        let mut s = Scenario::small();
        s.horizon = 400;
        let p = synthesize(&s);
        let mut mirror = OgaMirror::new(&p, s.eta0, s.decay, ExecBudget::auto());
        let mut additive = OgaSched::new(&p, s.eta0, s.decay, ExecBudget::auto());
        let rm = sim::run_on_problem(&s, &p, &mut mirror);
        let ra = sim::run_on_problem(&s, &p, &mut additive);
        assert!(
            rm.avg_reward() > 0.55 * ra.avg_reward(),
            "mirror {} too far below additive {}",
            rm.avg_reward(),
            ra.avg_reward()
        );
    }

    #[test]
    fn reset_reseeds() {
        let s = Scenario::small();
        let p = synthesize(&s);
        let mut pol = OgaMirror::new(&p, 2.0, 0.9999, ExecBudget::auto());
        let x = vec![1.0; p.num_ports()];
        let mut y1 = vec![0.0; p.decision_len()];
        let mut y2 = vec![0.0; p.decision_len()];
        pol.decide(&p, &x, &mut y1);
        pol.reset(&p);
        pol.decide(&p, &x, &mut y2);
        assert_eq!(y1, y2);
    }
}
