//! Entropic mirror-ascent variant of OGASCHED.
//!
//! Sec. 3.5 notes that the non-convex gang extension can be attacked
//! "with the subgradient ascent and mirror ascent related techniques
//! which retain a sublinear regret".  This module provides the mirror
//! half as a first-class policy so the claim is testable: the same
//! Eq. 30 gradient drives a multiplicative-weights update
//!
//! ```text
//! ŷ_i    = y_i · exp(η · ∇_i q)   (mirror step, negative-entropy geometry)
//! y(t+1) = Π_Y(ŷ)                 (Euclidean feasibility projection, Alg. 1)
//! ```
//!
//! Multiplicative updates cannot leave the non-negative orthant and
//! concentrate allocation on high-marginal-gain channels faster than the
//! additive step when the polytope is loose; the additive OGA catches up
//! once capacity binds.  `benches/ablation_projection.rs` and the
//! scheduler tests compare the two.
//!
//! Because exp(·) freezes coordinates at exactly 0, the state is seeded
//! at a small ε > 0 on every edge instead of the OGA zero start.

use crate::model::Problem;
use crate::oga::projection::{project, project_instances};
use crate::schedulers::{IncrementalPublisher, Policy, Touched};

/// Seed allocation (fraction of the per-channel cap) so multiplicative
/// updates have something to multiply.
const SEED_FRACTION: f64 = 1e-3;

/// Exponent clamp: keeps exp() finite under aggressive rates.
const MAX_EXPONENT: f64 = 30.0;

pub struct OgaMirror {
    /// Current decision y(t), edge-major [E, K].
    y: Vec<f64>,
    eta0: f64,
    decay: f64,
    workers: usize,
    /// Slot counter (diagnostic; η is maintained in `eta_run`).
    pub t: usize,
    /// Running η (η_{t+1} = λ·η_t), replacing the per-slot
    /// `decay.powi(t as i32)` re-exponentiation (§Perf-2; the i32 cast
    /// also truncated for horizons beyond i32::MAX).
    eta_run: f64,
    quota: Vec<f64>,
    /// Dirty-instance tracking (same trick as `OgaState::step`).
    dirty: Vec<bool>,
    dirty_list: Vec<usize>,
    /// Incremental publish into the engine's reused output buffer
    /// (shared state machine with `OgaSched`).
    publisher: IncrementalPublisher,
}

impl OgaMirror {
    pub fn new(problem: &Problem, eta0: f64, decay: f64, workers: usize) -> Self {
        let mut pol = OgaMirror {
            y: Vec::new(),
            eta0,
            decay,
            workers,
            t: 0,
            eta_run: eta0,
            quota: vec![0.0; problem.num_resources],
            dirty: vec![false; problem.num_instances()],
            dirty_list: Vec::new(),
            publisher: IncrementalPublisher::default(),
        };
        pol.seed(problem);
        pol
    }

    fn seed(&mut self, problem: &Problem) {
        let k_n = problem.num_resources;
        self.y = vec![0.0; problem.decision_len()];
        for e in 0..problem.num_edges() {
            let l = problem.graph.edge_port[e];
            for k in 0..k_n {
                self.y[e * k_n + k] = SEED_FRACTION * problem.demand_at(l, k);
            }
        }
        // the seed touches every edge, so this one projection is global
        project(problem, &mut self.y, self.workers);
        self.t = 0;
        self.eta_run = self.eta0;
        self.publisher.reset();
    }

    /// One mirror step: multiplicative update on arrived ports' lanes
    /// (Eq. 30 gradient), then the Alg. 1 projection of the perturbed
    /// (dirty) instances only.
    fn step(&mut self, problem: &Problem, x: &[f64]) {
        let k_n = problem.num_resources;
        let g = &problem.graph;
        let eta = self.eta_run;
        self.eta_run *= self.decay;
        for &r in &self.dirty_list {
            self.dirty[r] = false;
        }
        self.dirty_list.clear();
        for l in 0..problem.num_ports() {
            let x_l = x[l];
            if x_l == 0.0 {
                continue;
            }
            let edges = g.port_edges(l);
            self.quota.fill(0.0);
            for e in edges.clone() {
                let base = e * k_n;
                for k in 0..k_n {
                    self.quota[k] += self.y[base + k];
                }
            }
            let mut kstar = 0;
            let mut best = f64::NEG_INFINITY;
            for k in 0..k_n {
                let v = problem.beta[k] * self.quota[k];
                if v > best {
                    best = v;
                    kstar = k;
                }
            }
            for e in edges {
                let r = g.edge_instance[e];
                if !self.dirty[r] {
                    self.dirty[r] = true;
                    self.dirty_list.push(r);
                }
                let base = e * k_n;
                let rk = r * k_n;
                for k in 0..k_n {
                    let yv = self.y[base + k];
                    let fp = problem.kind[rk + k].grad(yv, problem.alpha[rk + k]);
                    let pen = if k == kstar { problem.beta[k] } else { 0.0 };
                    let expo = (eta * x_l * (fp - pen)).clamp(-MAX_EXPONENT, MAX_EXPONENT);
                    self.y[base + k] = yv * expo.exp();
                }
            }
        }
        project_instances(problem, &mut self.y, &self.dirty_list, self.workers);
        self.t += 1;
    }
}

impl Policy for OgaMirror {
    fn name(&self) -> &'static str {
        "OGASCHED-MIRROR"
    }

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        // reactive scoring, matching OgaSched::new; the multiplicative
        // update perturbs only the dirty instances, so publishing is an
        // incremental column copy after the first slot (§Perf-2)
        self.step(problem, x);
        self.publisher.publish(problem, &self.y, y, &self.dirty_list);
    }

    fn reset(&mut self, problem: &Problem) {
        self.seed(problem);
    }

    fn touched(&self) -> Touched<'_> {
        self.publisher.touched()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::reward::slot_reward;
    use crate::schedulers::OgaSched;
    use crate::sim;
    use crate::traces::synthesize;

    #[test]
    fn mirror_decisions_feasible() {
        let s = Scenario::small();
        let p = synthesize(&s);
        let mut pol = OgaMirror::new(&p, 2.0, 0.9999, 0);
        let x = vec![1.0; p.num_ports()];
        let mut y = vec![0.0; p.decision_len()];
        for _ in 0..30 {
            pol.decide(&p, &x, &mut y);
            p.check_feasible(&y, 1e-7).unwrap();
        }
    }

    #[test]
    fn mirror_climbs_reward() {
        let s = Scenario::small();
        let p = synthesize(&s);
        let mut pol = OgaMirror::new(&p, 2.0, 0.9999, 0);
        let x = vec![1.0; p.num_ports()];
        let mut y = vec![0.0; p.decision_len()];
        pol.decide(&p, &x, &mut y);
        let early = slot_reward(&p, &x, &y).q;
        for _ in 0..150 {
            pol.decide(&p, &x, &mut y);
        }
        let late = slot_reward(&p, &x, &y).q;
        assert!(late > early, "mirror ascent did not climb: {early} -> {late}");
    }

    #[test]
    fn mirror_competitive_with_additive_oga() {
        // On the default small scenario the two first-order methods land
        // within a modest factor of each other (the point of Sec. 3.5's
        // "related techniques retain sublinear regret").
        let mut s = Scenario::small();
        s.horizon = 400;
        let p = synthesize(&s);
        let mut mirror = OgaMirror::new(&p, s.eta0, s.decay, 0);
        let mut additive = OgaSched::new(&p, s.eta0, s.decay, 0);
        let rm = sim::run_on_problem(&s, &p, &mut mirror);
        let ra = sim::run_on_problem(&s, &p, &mut additive);
        assert!(
            rm.avg_reward() > 0.55 * ra.avg_reward(),
            "mirror {} too far below additive {}",
            rm.avg_reward(),
            ra.avg_reward()
        );
    }

    #[test]
    fn reset_reseeds() {
        let s = Scenario::small();
        let p = synthesize(&s);
        let mut pol = OgaMirror::new(&p, 2.0, 0.9999, 0);
        let x = vec![1.0; p.num_ports()];
        let mut y1 = vec![0.0; p.decision_len()];
        let mut y2 = vec![0.0; p.decision_len()];
        pol.decide(&p, &x, &mut y1);
        pol.reset(&p);
        pol.decide(&p, &x, &mut y2);
        assert_eq!(y1, y2);
    }
}
