//! Scheduling policies: OGASCHED plus the four baselines the paper
//! compares against (DRF, FAIRNESS, BINPACKING, SPREADING), a random
//! sanity baseline, and the Sec. 3.4/3.5 extensions.

pub mod baselines;
pub mod gang;
pub mod mirror;
pub mod multi_arrival;
pub mod oga_sched;

use crate::model::Problem;

pub use baselines::{BinPacking, Drf, Fairness, RandomAlloc, Spreading};
pub use gang::GangOga;
pub use mirror::OgaMirror;
pub use multi_arrival::MultiArrivalOga;
pub use oga_sched::OgaSched;

/// A per-slot scheduling policy.
///
/// `decide` fills the edge-major decision tensor `y` [E, K] (see
/// `model` for the CSR layout) for the current slot, given the arrival
/// vector `x` [L].  The engine then scores
/// q(x, y) (Eq. 8) — so *reactive* heuristics (the baselines) may use
/// x(t) to place arrived jobs, while *learning* policies (OGASCHED)
/// return the reservation y(t) they committed before seeing x(t) and use
/// x(t) only to update toward y(t+1), exactly as Def. 2 prescribes.
pub trait Policy {
    fn name(&self) -> &'static str;

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]);

    /// Reset internal state between runs (default: nothing).
    fn reset(&mut self, _problem: &Problem) {}
}

/// Construct every policy of the paper's Fig. 2 comparison, OGASCHED
/// first (order matters for the figure legends).
pub fn paper_lineup(problem: &Problem, eta0: f64, decay: f64, workers: usize)
    -> Vec<Box<dyn Policy>> {
    vec![
        Box::new(OgaSched::new(problem, eta0, decay, workers)),
        Box::new(Drf::new()),
        Box::new(Fairness::new()),
        Box::new(BinPacking::new()),
        Box::new(Spreading::new()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::traces::synthesize;
    use crate::utils::rng::Rng;

    /// Every policy must emit feasible decisions on random arrivals.
    #[test]
    fn all_policies_feasible() {
        let scenario = Scenario::small();
        let p = synthesize(&scenario);
        let mut rng = Rng::new(77);
        for mut policy in paper_lineup(&p, 5.0, 0.999, 0) {
            let mut y = vec![0.0; p.decision_len()];
            for _ in 0..30 {
                let x: Vec<f64> = (0..p.num_ports())
                    .map(|_| if rng.bernoulli(0.7) { 1.0 } else { 0.0 })
                    .collect();
                policy.decide(&p, &x, &mut y);
                p.check_feasible(&y, 1e-6)
                    .map_err(|e| format!("{}: {e}", policy.name()))
                    .unwrap();
            }
        }
    }

    #[test]
    fn lineup_names_match_paper() {
        let p = synthesize(&Scenario::small());
        let names: Vec<&str> =
            paper_lineup(&p, 25.0, 0.9999, 0).iter().map(|p| p.name()).collect();
        assert_eq!(names, vec!["OGASCHED", "DRF", "FAIRNESS", "BINPACKING", "SPREADING"]);
    }
}
