//! Scheduling policies: OGASCHED plus the four baselines the paper
//! compares against (DRF, FAIRNESS, BINPACKING, SPREADING), a random
//! sanity baseline, and the Sec. 3.4/3.5 extensions.

pub mod baselines;
pub mod gang;
pub mod mirror;
pub mod multi_arrival;
pub mod oga_sched;

use std::sync::Arc;

use crate::coordinator::sharded::ShardPlan;
use crate::graph::Bipartite;
use crate::model::Problem;
use crate::utils::pool::ExecBudget;

pub use baselines::{BinPacking, Drf, Fairness, RandomAlloc, Spreading};
pub use gang::GangOga;
pub use mirror::OgaMirror;
pub use multi_arrival::MultiArrivalOga;
pub use oga_sched::OgaSched;

/// Which part of the decision tensor the last `decide` call may have
/// changed, relative to the previous decision the policy emitted into
/// the same buffer (§Perf-2).
#[derive(Clone, Copy, Debug)]
pub enum Touched<'a> {
    /// Treat the whole tensor as rewritten (the safe default; forces
    /// the engine's full-sweep ledger commit).
    All,
    /// Only the edge columns of these instances changed.  The engine
    /// then commits O(Σ_r |L_r|·K) over the listed rows instead of the
    /// |E|·K full sweep (`ClusterState::commit_instances`).  A policy
    /// may only report this when every other coordinate of the buffer
    /// it filled is bit-identical to its previous decision.
    Instances(&'a [usize]),
}

/// A per-slot scheduling policy.
///
/// `decide` fills the edge-major decision tensor `y` [E, K] (see
/// `model` for the CSR layout) for the current slot, given the arrival
/// vector `x` [L].  The engine then scores
/// q(x, y) (Eq. 8) — so *reactive* heuristics (the baselines) may use
/// x(t) to place arrived jobs, while *learning* policies (OGASCHED)
/// return the reservation y(t) they committed before seeing x(t) and use
/// x(t) only to update toward y(t+1), exactly as Def. 2 prescribes.
///
/// Buffer contract: the engine passes the *same* output buffer to every
/// `decide` of a run (zero-initialized before the first slot).  Sparse
/// policies exploit that — they rewrite only the columns that changed
/// and report them via [`Policy::touched`]; a policy that writes into
/// fresh buffers per call must keep the `Touched::All` default.
pub trait Policy {
    fn name(&self) -> &'static str;

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]);

    /// Reset internal state between runs (default: nothing).
    fn reset(&mut self, _problem: &Problem) {}

    /// Dirty set of the last `decide` (see [`Touched`]).  OGASCHED
    /// reports its dirty instances, the baselines their arrived
    /// neighborhoods; the default keeps the full-sweep commit.
    fn touched(&self) -> Touched<'_> {
        Touched::All
    }

    /// Bind the sharded coordinator's [`ShardPlan`] (§Perf-3).  The
    /// learning policies route their internal ascent/projection through
    /// the plan's per-shard views so a single slot's decide fans out
    /// over the worker pool; the Touched reporting then arrives
    /// pre-partitionable by the same plan.  Policies whose decide is
    /// inherently sequential (the reactive baselines' capacity ledgers)
    /// keep this default no-op — the engine still shards their commit
    /// and reward stages.  Binding must never change emitted decisions:
    /// `tests/shard_parity.rs` pins bound and unbound runs bit-to-bit.
    fn bind_shards(&mut self, _plan: &Arc<ShardPlan>) {}

    /// Carry internal state across a topology edition (`sim::faults`).
    /// `old_graph` is the pre-churn graph; `problem` the post-churn
    /// problem (same vertex id spaces, different edge set — every edge
    /// id shifted).  Learning policies remap their decision tensors by
    /// `(l, r)` key so surviving channels keep their learned allocation
    /// and no coordinate survives on a dead edge — the graceful-
    /// degradation contract.  The default (the reactive baselines,
    /// which recompute from scratch every slot) just resets, which the
    /// churn parity suite pins as equivalent to a from-scratch rebuild.
    fn remap(&mut self, old_graph: &Bipartite, problem: &Problem) {
        let _ = old_graph;
        self.reset(problem);
    }

    /// Serialize whatever internal state a mid-run resume needs into a
    /// checkpoint blob (`sim::checkpoint`).  The contract is *minimal
    /// sufficiency*: a policy writes exactly the state that the slot
    /// loop cannot re-derive — learned tensors, decayed step sizes, RNG
    /// streams — and nothing it recomputes per slot anyway.  Stateless
    /// reactive policies (the capacity-ledger baselines rebuild from
    /// the arrived neighborhood each slot) keep this default no-op.
    fn snapshot_state(&self, w: &mut crate::utils::codec::Writer) {
        let _ = w;
    }

    /// Rebuild from [`Policy::snapshot_state`].  Called on a policy
    /// that was just `reset` against the restored problem — the restore
    /// overlays the snapshotted state on top of that fresh baseline, so
    /// implementations only touch the fields their snapshot wrote.
    /// Must consume exactly the bytes the snapshot produced (the
    /// checkpoint frames each policy blob as a length-prefixed section
    /// and rejects trailing bytes).
    fn restore_state(
        &mut self,
        problem: &Problem,
        r: &mut crate::utils::codec::Reader,
    ) -> Result<(), String> {
        let _ = (problem, r);
        Ok(())
    }
}

/// Copy the edge columns of the listed instances from `src` to `dst`
/// (both edge-major [E, K]) — the incremental "publish" step of the
/// sparse policies' `decide`.
pub(crate) fn copy_instance_columns(
    problem: &Problem,
    src: &[f64],
    dst: &mut [f64],
    instances: &[usize],
) {
    let k_n = problem.num_resources;
    for &r in instances {
        for &e in problem.graph.instance_edge_ids(r) {
            let base = e * k_n;
            dst[base..base + k_n].copy_from_slice(&src[base..base + k_n]);
        }
    }
}

/// Process-wide run epoch: engines bump it when they start a fresh run
/// with a fresh output buffer (`coordinator::Leader::run` does), and
/// every [`IncrementalPublisher`] re-primes with a full copy when the
/// epoch has moved.  This closes the silent-staleness trap where a new
/// run's buffer lands at the freed address of the old one (allocator
/// reuse) and a pointer-identity check alone would mistake it for the
/// previous buffer.  Spurious bumps from concurrent runs only cost an
/// extra full copy — never correctness.
static RUN_EPOCH: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Declare the start of a fresh engine run (see [`RUN_EPOCH`]).
pub fn begin_run_epoch() {
    RUN_EPOCH.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
}

fn run_epoch() -> u64 {
    RUN_EPOCH.load(std::sync::atomic::Ordering::Relaxed)
}

/// Incremental decision publisher shared by the sparse learning
/// policies (OGASCHED and the mirror variant): copies only the
/// perturbed instances' columns into the engine's reused output buffer
/// and reports them as the policy's [`Touched`] set.
///
/// The output buffer is identified by address + length + run epoch +
/// problem generation; a `decide` into a different buffer — or after a
/// new engine run began ([`begin_run_epoch`]), or against a *different
/// problem* (`Problem::generation`, which closes the last identity
/// hole: a new same-shaped problem whose engine buffer lands at the
/// freed address of the old one) — re-primes with a full copy, so
/// fresh-buffer-per-call tests and policies reused across runs stay
/// correct automatically.
#[derive(Clone, Debug)]
pub(crate) struct IncrementalPublisher {
    touched: Vec<usize>,
    last_ptr: usize,
    last_len: usize,
    last_epoch: u64,
    /// `Problem::generation` of the previous publish (0 = never; real
    /// generations start at 1).
    last_generation: u64,
    full_last: bool,
}

impl Default for IncrementalPublisher {
    fn default() -> Self {
        IncrementalPublisher {
            touched: Vec::new(),
            last_ptr: 0,
            last_len: 0,
            last_epoch: 0,
            last_generation: 0,
            full_last: true,
        }
    }
}

impl IncrementalPublisher {
    /// Publish `src` into `dst`: incremental (only `dirty` instances'
    /// columns) when `dst` is the buffer of the previous publish within
    /// the same run epoch and problem generation, full copy otherwise.
    pub(crate) fn publish(
        &mut self,
        problem: &Problem,
        src: &[f64],
        dst: &mut [f64],
        dirty: &[usize],
    ) {
        let ptr = dst.as_ptr() as usize;
        let epoch = run_epoch();
        let generation = problem.generation();
        if ptr == self.last_ptr
            && dst.len() == self.last_len
            && epoch == self.last_epoch
            && generation == self.last_generation
        {
            self.touched.clear();
            self.touched.extend_from_slice(dirty);
            copy_instance_columns(problem, src, dst, &self.touched);
            self.full_last = false;
        } else {
            dst.copy_from_slice(src);
            self.last_ptr = ptr;
            self.last_len = dst.len();
            self.last_epoch = epoch;
            self.last_generation = generation;
            self.full_last = true;
        }
    }

    pub(crate) fn touched(&self) -> Touched<'_> {
        if self.full_last {
            Touched::All
        } else {
            Touched::Instances(&self.touched)
        }
    }

    pub(crate) fn reset(&mut self) {
        self.touched.clear();
        self.last_ptr = 0;
        self.last_len = 0;
        self.last_generation = 0;
        self.full_last = true;
    }
}

/// Construct every policy of the paper's Fig. 2 comparison, OGASCHED
/// first (order matters for the figure legends).  Boxed `Send` so
/// `coordinator::run_lineup` can fan the runs out under its
/// [`ExecBudget`] split (the budget here seeds the learning policies'
/// own projection/shard bounds; the lineup-level split is the
/// engine's).
pub fn paper_lineup(problem: &Problem, eta0: f64, decay: f64, budget: ExecBudget)
    -> Vec<Box<dyn Policy + Send>> {
    vec![
        Box::new(OgaSched::new(problem, eta0, decay, budget)),
        Box::new(Drf::new()),
        Box::new(Fairness::new()),
        Box::new(BinPacking::new()),
        Box::new(Spreading::new()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::traces::synthesize;
    use crate::utils::rng::Rng;

    /// Every policy must emit feasible decisions on random arrivals.
    #[test]
    fn all_policies_feasible() {
        let scenario = Scenario::small();
        let p = synthesize(&scenario);
        let mut rng = Rng::new(77);
        for mut policy in paper_lineup(&p, 5.0, 0.999, ExecBudget::auto()) {
            let mut y = vec![0.0; p.decision_len()];
            for _ in 0..30 {
                let x: Vec<f64> = (0..p.num_ports())
                    .map(|_| if rng.bernoulli(0.7) { 1.0 } else { 0.0 })
                    .collect();
                policy.decide(&p, &x, &mut y);
                p.check_feasible(&y, 1e-6)
                    .map_err(|e| format!("{}: {e}", policy.name()))
                    .unwrap();
            }
        }
    }

    #[test]
    fn publisher_reprimes_on_new_problem_generation() {
        // Two same-shaped problems publishing into the *same* buffer:
        // ptr/len/epoch all match, so before the generation key an
        // incremental publish with an empty dirty set would have left
        // the previous problem's decision behind.
        let p1 = synthesize(&Scenario::small());
        let p2 = synthesize(&Scenario::small());
        assert_ne!(p1.generation(), p2.generation());
        let mut publisher = IncrementalPublisher::default();
        let src1 = vec![1.0; p1.decision_len()];
        let mut dst = vec![0.0; p1.decision_len()];
        publisher.publish(&p1, &src1, &mut dst, &[]);
        let src2 = vec![2.0; p2.decision_len()];
        publisher.publish(&p2, &src2, &mut dst, &[]);
        assert_eq!(dst, src2, "generation switch must force a full re-prime");
        assert!(matches!(publisher.touched(), Touched::All));
    }

    #[test]
    fn lineup_names_match_paper() {
        let p = synthesize(&Scenario::small());
        let names: Vec<&str> =
            paper_lineup(&p, 25.0, 0.9999, ExecBudget::auto()).iter().map(|p| p.name()).collect();
        assert_eq!(names, vec!["OGASCHED", "DRF", "FAIRNESS", "BINPACKING", "SPREADING"]);
    }
}
