//! Sec. 3.5 extension: Gang Scheduling with the All-Or-Nothing property.
//!
//! Each type-l job is a set Q_l of task components; at least m_l of them
//! must be scheduled for the job to launch.  The feasible set gains the
//! non-convex counting constraint
//!     Σ_q 1{Σ_{r,k} y^{q} > 0} ≥ m_l ,
//! so the paper switches to subgradient ascent plus a feasibility
//! restoration.  We implement that recipe:
//!
//!  1. task expansion — each (l, q) component becomes a port of an
//!     expanded convex problem (like Sec. 3.4's clones, but components
//!     may have distinct demands a_l^{q,k});
//!  2. a projected (sub)gradient step on the convex relaxation;
//!  3. *gang restoration* — for each arrived job, count components with
//!     non-trivial allocations; if fewer than m_l, the whole job's
//!     allocation is withdrawn for the slot (all-or-nothing: the job is
//!     not launched, resources return to the pool implicitly since the
//!     next projection re-spreads them).

use crate::graph::Bipartite;
use crate::model::Problem;
use crate::oga::{LearningRate, OgaState};
use crate::schedulers::Policy;
use crate::utils::pool::ExecBudget;

/// A gang job spec: per-component demand rows [(|Q_l|, K)] and the
/// minimum component count m_l.
#[derive(Clone, Debug)]
pub struct GangSpec {
    /// demands[q][k] = a_l^{q,k}
    pub demands: Vec<Vec<f64>>,
    /// m_l — minimum components that must be scheduled.
    pub min_tasks: usize,
}

/// Allocation threshold below which a component counts as "not scheduled"
/// for the all-or-nothing test.
const ACTIVE_EPS: f64 = 1e-6;

pub struct GangOga {
    /// Expanded convex problem: one port per (l, q) component.
    expanded: Problem,
    /// Component port ranges per original job type: [start, end).
    ranges: Vec<(usize, usize)>,
    specs: Vec<GangSpec>,
    state: OgaState,
    x_buf: Vec<f64>,
}

impl GangOga {
    pub fn new(problem: &Problem, specs: &[GangSpec], eta0: f64, decay: f64,
               budget: ExecBudget) -> Self {
        assert_eq!(specs.len(), problem.num_ports());
        let k_n = problem.num_resources;
        let mut edges = Vec::new();
        let mut demand = Vec::new();
        let mut ranges = Vec::new();
        let mut next = 0usize;
        for (l, spec) in specs.iter().enumerate() {
            assert!(spec.min_tasks <= spec.demands.len(),
                    "m_l > |Q_l| for job type {l}");
            let start = next;
            for comp in &spec.demands {
                assert_eq!(comp.len(), k_n);
                let port = next;
                next += 1;
                for &r in &problem.graph.ports_to_instances[l] {
                    edges.push((port, r));
                }
                demand.extend_from_slice(comp);
            }
            ranges.push((start, next));
        }
        let graph = Bipartite::from_edges(next, problem.num_instances(), &edges);
        let expanded = Problem::new(
            graph,
            k_n,
            demand,
            problem.capacity.clone(),
            problem.alpha.clone(),
            problem.kind.clone(),
            problem.beta.clone(),
        );
        let state = OgaState::new(
            &expanded,
            LearningRate::Decay { eta0, lambda: decay },
            budget,
        );
        GangOga { expanded, ranges, specs: specs.to_vec(), state, x_buf: Vec::new() }
    }

    /// Components of job l with non-trivial allocation in the expanded
    /// decision `y_exp`.  Under the edge-major layout a component port's
    /// coordinates are one contiguous slice.
    fn active_components(&self, l: usize, y_exp: &[f64]) -> usize {
        let (start, end) = self.ranges[l];
        let k_n = self.expanded.num_resources;
        let g = &self.expanded.graph;
        (start..end)
            .filter(|&port| {
                let lo = g.port_ptr[port] * k_n;
                let hi = g.port_ptr[port + 1] * k_n;
                y_exp[lo..hi].iter().any(|&v| v > ACTIVE_EPS)
            })
            .count()
    }
}

impl Policy for GangOga {
    fn name(&self) -> &'static str {
        "OGASCHED-GANG"
    }

    fn decide(&mut self, problem: &Problem, x: &[f64], y: &mut [f64]) {
        // expand arrivals: every component of an arrived job is active
        self.x_buf.clear();
        for (l, spec) in self.specs.iter().enumerate() {
            for _ in 0..spec.demands.len() {
                self.x_buf.push(x[l]);
            }
        }
        // decision y(t) = current reservation, gang-restored
        let y_exp = self.state.y.clone();
        y.fill(0.0);
        let k_n = problem.num_resources;
        for (l, spec) in self.specs.iter().enumerate() {
            // all-or-nothing (footnote 1: Kubernetes minAvailable)
            if self.active_components(l, &y_exp) < spec.min_tasks {
                continue; // job not launched this slot
            }
            // every component port clones l's edge list, so the expanded
            // and original CSR rows walk the same instances in lockstep
            let (start, end) = self.ranges[l];
            let olo = problem.graph.port_ptr[l];
            let deg = problem.graph.port_ptr[l + 1] - olo;
            for port in start..end {
                let elo = self.expanded.graph.port_ptr[port];
                debug_assert_eq!(self.expanded.graph.port_ptr[port + 1] - elo, deg);
                for j in 0..deg {
                    let src = (elo + j) * k_n;
                    let dst = (olo + j) * k_n;
                    for k in 0..k_n {
                        y[dst + k] += y_exp[src + k];
                    }
                }
            }
        }
        // subgradient step on the convex relaxation toward y(t+1)
        self.state.step(&self.expanded, &self.x_buf);
    }

    fn reset(&mut self, _problem: &Problem) {
        self.state = OgaState::new(&self.expanded, self.state.lr, self.state.budget);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::traces::synthesize;

    fn specs_for(p: &Problem, comps: usize, min_tasks: usize) -> Vec<GangSpec> {
        (0..p.num_ports())
            .map(|l| GangSpec {
                demands: (0..comps)
                    .map(|_| {
                        (0..p.num_resources)
                            .map(|k| p.demand_at(l, k) / comps as f64)
                            .collect()
                    })
                    .collect(),
                min_tasks,
            })
            .collect()
    }

    #[test]
    fn expansion_shapes() {
        let p = synthesize(&Scenario::small());
        let gang = GangOga::new(&p, &specs_for(&p, 3, 2), 5.0, 0.999, ExecBudget::auto());
        assert_eq!(gang.expanded.num_ports(), 3 * p.num_ports());
        assert_eq!(gang.ranges.len(), p.num_ports());
        gang.expanded.graph.validate().unwrap();
    }

    #[test]
    fn decisions_feasible_under_gang_restoration() {
        let p = synthesize(&Scenario::small());
        let mut gang = GangOga::new(&p, &specs_for(&p, 3, 2), 10.0, 0.999, ExecBudget::auto());
        let x = vec![1.0; p.num_ports()];
        let mut y = vec![0.0; p.decision_len()];
        for _ in 0..15 {
            gang.decide(&p, &x, &mut y);
            // capacity per (r, k) must hold after component folding
            for r in 0..p.num_instances() {
                for k in 0..p.num_resources {
                    let used: f64 = p
                        .graph
                        .instance_edge_ids(r)
                        .iter()
                        .map(|&e| y[p.edge_idx(e, k)])
                        .sum();
                    assert!(used <= p.capacity_at(r, k) + 1e-6);
                }
            }
        }
        // after warmup the gang jobs actually launch
        let total: f64 = y.iter().sum();
        assert!(total > 0.0, "no gang job ever launched");
    }

    #[test]
    fn all_or_nothing_withholds_partial_jobs() {
        let p = synthesize(&Scenario::small());
        // min_tasks == comps: every component must be active
        let mut gang = GangOga::new(&p, &specs_for(&p, 2, 2), 5.0, 0.999, ExecBudget::auto());
        let x = vec![1.0; p.num_ports()];
        let mut y = vec![0.0; p.decision_len()];
        // first slot: y(1) = 0 so no components active -> nothing launches
        gang.decide(&p, &x, &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "m_l > |Q_l|")]
    fn invalid_spec_rejected() {
        let p = synthesize(&Scenario::small());
        let mut specs = specs_for(&p, 2, 2);
        specs[0].min_tasks = 5;
        GangOga::new(&p, &specs, 5.0, 0.999, ExecBudget::auto());
    }
}
