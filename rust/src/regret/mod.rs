//! Regret machinery (Sec. 2.3, Thm. 1).
//!
//! The offline comparator y* (Eq. 10) is the best *stationary* decision
//! for the realized trajectory {x(t)}.  Because Eq. 8 is linear in x,
//!     Σ_t q(x(t), y) = Σ_l n_l · (gain_l(y) − penalty_l(y)),
//! with n_l = Σ_t x_l(t) — a weighted single-slot reward with "arrival
//! counts" n.  That is a concave program over the convex polytope Y, so
//! we solve it to (numerical) optimality with full-batch projected
//! gradient ascent re-using the exact same gradient/projection code the
//! online algorithm runs.

use crate::coordinator::sharded::{project_dirty_sharded, ArrivedPort, ShardPlan};
use crate::model::{KindIndex, Problem};
use crate::obs;
use crate::oga::gradient::{grad_norm, gradient_sparse, GradScratch};
use crate::oga::projection::project_instances;
use crate::oga::{ascend_ports_sharded, gradient_sparse_sharded};
use crate::reward::{
    slot_reward, slot_reward_kinds, slot_reward_ports_sharded, PortRewardScratch,
};
use crate::utils::pool::ExecBudget;

/// Result of the offline oracle solve.
#[derive(Clone, Debug)]
pub struct Oracle {
    /// y* — the optimal stationary decision.
    pub y_star: Vec<f64>,
    /// Σ_t q(x(t), y*) — the comparator's cumulative reward.
    pub cumulative_reward: f64,
    /// Iterations used.
    pub iters: usize,
}

/// Arrival counts n_l = Σ_t x_l(t) for a recorded trajectory.
pub fn arrival_counts(trajectory: &[Vec<f64>], num_ports: usize) -> Vec<f64> {
    let mut n = vec![0.0; num_ports];
    for x in trajectory {
        for l in 0..num_ports {
            n[l] += x[l];
        }
    }
    n
}

/// Solve Eq. 10 by projected full-gradient ascent with diminishing steps
/// (η_i = η₀/√(i+1)); tracks the best iterate seen (the objective is
/// concave but the ascent path need not be monotone at finite step size).
/// The arrival counts already encode the realized trajectory (n_l =
/// Σ_t x_l(t)), so there is no horizon parameter — the old `horizon:
/// usize` argument was dead weight (`let _ = horizon`).
///
/// §Perf-2: the gradient is zero on ports with n_l = 0 and y starts at
/// the origin, so every pass — gradient (kind-batched, via
/// [`gradient_sparse`]), ascent, projection, and objective — is
/// restricted to the arrived ports' slices and their adjacent
/// instances; ports that never arrive are never touched.
///
/// §Perf-4/§Perf-5: under a multi-shard [`ExecBudget`] (auto resolves
/// to the worker budget W) each iteration's gradient fill (its per-port
/// phase-A reductions included), ascent, projection **and objective
/// evaluation** fan out over a deterministic [`ShardPlan`] — the
/// objective through the same per-port reward kernels + ascending
/// serial merge the sharded leader scores slots with
/// ([`slot_reward_ports_sharded`]).  Only the ‖∇q‖ reduction replays
/// serially on the caller thread, so the sharded solve is
/// **bit-identical** to the serial one (pinned by
/// `tests/shard_parity.rs` at shard counts {1, 2, 3, 7} and the
/// {1×4, 2×2, 4×1} budget splits), the same discipline as
/// `coordinator::sharded`'s reward/ledger merges.
pub fn solve_oracle(
    problem: &Problem,
    counts: &[f64],
    iters: usize,
    budget: ExecBudget,
) -> Oracle {
    let k_n = problem.num_resources;
    let kinds = problem.kinds();
    let shards = budget.run_shards().clamp(1, problem.num_instances().max(1));
    let plan = if shards > 1 { Some(ShardPlan::build(problem, shards)) } else { None };
    let mut y = vec![0.0; problem.decision_len()];
    let mut grad = vec![0.0; problem.decision_len()];
    let mut scratch = GradScratch::default();
    let mut quota = vec![0.0; k_n];
    let mut reward_scratch = PortRewardScratch::default();
    let mut active_ports: Vec<usize> = Vec::new();
    let mut steps: Vec<ArrivedPort> = Vec::new();
    let mut parts: Vec<Vec<usize>> = Vec::new();

    // arrived ports (ascending) — fixed for the whole solve, the
    // objective's scatter list and serial merge order (§Perf-5)
    let arrived: Vec<usize> =
        (0..problem.num_ports()).filter(|&l| counts[l] != 0.0).collect();

    // instances adjacent to any arrived port: the only columns the
    // ascent can perturb, hence the only channels to re-project
    let mut seen = vec![false; problem.num_instances()];
    let mut active_instances = Vec::new();
    for &l in &arrived {
        for e in problem.graph.port_edges(l) {
            let r = problem.graph.edge_instance[e];
            if !seen[r] {
                seen[r] = true;
                active_instances.push(r);
            }
        }
    }

    // Σ_l n_l (gain_l − penalty_l) — sharded per-port fan-out with the
    // serial ascending merge when a plan is bound, the plain serial
    // loop otherwise; identical floats either way.
    fn objective(
        problem: &Problem,
        kinds: &KindIndex,
        counts: &[f64],
        y: &[f64],
        arrived: &[usize],
        plan: &Option<ShardPlan>,
        quota: &mut [f64],
        scratch: &mut PortRewardScratch,
    ) -> f64 {
        match plan {
            Some(plan) => slot_reward_ports_sharded(
                problem,
                kinds,
                counts,
                y,
                arrived,
                plan.num_shards(),
                scratch,
            )
            .q,
            None => slot_reward_kinds(problem, kinds, counts, y, quota).q,
        }
    }

    let mut best_y = y.clone();
    let mut best_obj = objective(
        problem,
        kinds,
        counts,
        &y,
        &arrived,
        &plan,
        &mut quota,
        &mut reward_scratch,
    );

    // Scale-free initial step: diam(Y) / ‖∇q(0)‖ keeps the first move
    // inside the polytope's order of magnitude.  (The sharded fill
    // writes the same floats into the same zero-initialized buffer, so
    // the flat full-buffer norm is identical either way.)
    match &plan {
        Some(plan) => gradient_sparse_sharded(
            problem,
            counts,
            &y,
            &mut grad,
            &mut active_ports,
            &mut steps,
            plan,
        ),
        None => gradient_sparse(
            problem,
            kinds,
            counts,
            &y,
            &mut grad,
            &mut scratch,
            &mut active_ports,
        ),
    }
    let g0 = grad_norm(&grad).max(1e-12);
    let eta0 = problem.diam_upper() / g0;

    for i in 0..iters {
        // span per projected-ascent iteration; the iteration index
        // rides in the span's slot field (there is no simulation slot
        // inside a solve)
        let _iter_span = obs::SpanTimer::start(obs::SpanKind::OracleIter, i as u64, 0);
        let eta = eta0 / ((i + 1) as f64).sqrt();
        match &plan {
            Some(plan) => {
                gradient_sparse_sharded(
                    problem,
                    counts,
                    &y,
                    &mut grad,
                    &mut active_ports,
                    &mut steps,
                    plan,
                );
                ascend_ports_sharded(problem, &mut y, &grad, &steps, eta, plan);
                project_dirty_sharded(problem, &mut y, &active_instances, plan, &mut parts);
            }
            None => {
                gradient_sparse(
                    problem,
                    kinds,
                    counts,
                    &y,
                    &mut grad,
                    &mut scratch,
                    &mut active_ports,
                );
                for &l in &active_ports {
                    let lo = problem.graph.port_ptr[l] * k_n;
                    let hi = problem.graph.port_ptr[l + 1] * k_n;
                    for j in lo..hi {
                        y[j] += eta * grad[j];
                    }
                }
                project_instances(problem, &mut y, &active_instances, 1);
            }
        }
        let obj = objective(
            problem,
            kinds,
            counts,
            &y,
            &arrived,
            &plan,
            &mut quota,
            &mut reward_scratch,
        );
        if obj > best_obj {
            best_obj = obj;
            // pre-sized: keep the improvement without a realloc
            best_y.copy_from_slice(&y);
        }
    }
    Oracle { y_star: best_y, cumulative_reward: best_obj, iters }
}

/// Σ_l n_l (gain_l(y) − penalty_l(y)) — the oracle objective.
pub fn weighted_reward(problem: &Problem, counts: &[f64], y: &[f64]) -> f64 {
    slot_reward(problem, counts, y).q
}

/// Regret of a realized online reward sequence against the oracle for
/// the same trajectory: R_T = Q(y*) − Q({y(t)}).
pub fn regret(oracle: &Oracle, online_cumulative: f64) -> f64 {
    oracle.cumulative_reward - online_cumulative
}

/// The Thm. 1 upper bound H_G · √T (Eq. 36/49).
pub fn theorem1_bound(problem: &Problem, horizon: usize) -> f64 {
    problem.h_g() * (horizon as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::coordinator::Leader;
    use crate::schedulers::OgaSched;
    use crate::sim::arrivals::{record_trajectory, Bernoulli, Replay};
    use crate::traces::synthesize;

    fn small_problem() -> (Scenario, crate::model::Problem) {
        let mut s = Scenario::small();
        s.horizon = 150;
        let p = synthesize(&s);
        (s, p)
    }

    #[test]
    fn oracle_beats_any_feasible_point_we_try() {
        let (_s, p) = small_problem();
        let counts = vec![100.0; p.num_ports()];
        let oracle = solve_oracle(&p, &counts, 300, ExecBudget::serial());
        p.check_feasible(&oracle.y_star, 1e-7).unwrap();
        // random feasible candidates never beat the oracle
        let mut rng = crate::utils::rng::Rng::new(5);
        for _ in 0..50 {
            let mut y: Vec<f64> =
                (0..p.decision_len()).map(|_| rng.uniform(0.0, 2.0)).collect();
            crate::oga::projection::project(&p, &mut y, 0);
            assert!(
                weighted_reward(&p, &counts, &y) <= oracle.cumulative_reward + 1e-6
            );
        }
    }

    #[test]
    fn oracle_solution_is_stationary_point() {
        // projecting one more ascent step from y* should barely move it
        let (_s, p) = small_problem();
        let counts = vec![50.0; p.num_ports()];
        let oracle = solve_oracle(&p, &counts, 500, ExecBudget::serial());
        let mut y = oracle.y_star.clone();
        let mut grad = vec![0.0; y.len()];
        let mut scratch = GradScratch::default();
        let kinds = KindIndex::build(&p);
        crate::oga::gradient::gradient(&p, &kinds, &counts, &y, &mut grad, &mut scratch);
        let tiny = 1e-4;
        for j in 0..y.len() {
            y[j] += tiny * grad[j];
        }
        crate::oga::projection::project(&p, &mut y, 0);
        let improve = weighted_reward(&p, &counts, &y) - oracle.cumulative_reward;
        assert!(
            improve <= 1e-3 * oracle.cumulative_reward.abs().max(1.0),
            "oracle not stationary: improve={improve}"
        );
    }

    #[test]
    fn online_regret_below_theorem1_bound() {
        let (s, p) = small_problem();
        let mut src = Bernoulli::uniform(p.num_ports(), s.arrival_prob, 77);
        let traj = record_trajectory(&mut src, p.num_ports(), s.horizon);
        let counts = arrival_counts(&traj, p.num_ports());
        let oracle = solve_oracle(&p, &counts, 400, ExecBudget::serial());

        let mut leader = Leader::new(&p);
        let mut pol = OgaSched::with_oracle_rate(&p, s.horizon, ExecBudget::auto());
        let mut replay = Replay::new(traj);
        let run = leader.run(&mut pol, &mut replay, s.horizon);
        let r = regret(&oracle, run.cumulative_reward);
        let bound = theorem1_bound(&p, s.horizon);
        assert!(r <= bound, "regret {r} exceeds Thm. 1 bound {bound}");
    }

    #[test]
    fn counts_accumulate() {
        let traj = vec![vec![1.0, 0.0], vec![1.0, 1.0], vec![0.0, 1.0]];
        assert_eq!(arrival_counts(&traj, 2), vec![2.0, 2.0]);
    }
}
