//! # OGASCHED — online multi-server job scheduling with sublinear regret
//!
//! A three-layer (Rust + JAX + Pallas) reproduction of
//! *"Scheduling Multi-Server Jobs with Sublinear Regrets via Online
//! Learning"* (Zhao et al., 2023).
//!
//! - **Layer 3 (this crate)** — the cluster coordinator: bipartite
//!   service-locality model, slot event loop, OGASCHED + the paper's four
//!   baselines, regret oracle, figure harnesses, CLI.
//! - **Layer 2/1 (`python/compile/`)** — the OGA step (Pallas gradient
//!   kernel + fused projection) AOT-lowered to HLO text.
//! - **Runtime bridge (`runtime/`)** — loads `artifacts/*.hlo.txt` via the
//!   PJRT CPU client and runs the compiled step from the slot loop; Python
//!   never executes on the request path.
//!
//! Quick start:
//! ```no_run
//! use ogasched::config::Scenario;
//! use ogasched::sim;
//!
//! let mut scenario = Scenario::small();
//! scenario.horizon = 200;
//! for run in sim::run_paper_lineup(&scenario) {
//!     println!("{:<10} avg reward {:.2}", run.policy, run.avg_reward());
//! }
//! ```

// §Perf-5: the `simd` feature routes `oga::kernels` through
// `std::simd` (nightly-only); the stable default build compiles the
// bit-identical scalar lane-tree path instead.
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod benchlib;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod figures;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod oga;
pub mod regret;
pub mod reward;
pub mod runtime;
pub mod schedulers;
pub mod sim;
pub mod traces;
pub mod utils;

/// The crate-wide execution-budget currency (re-exported from
/// [`utils::pool`]): every `workers`-shaped knob — scenario configs,
/// policy constructors, `run_lineup`, `solve_oracle` — takes this
/// two-level `runs × shards` split instead of a raw int.
pub use utils::pool::ExecBudget;
