//! The PJRT-backed OGA step: load an HLO-text artifact, compile it once
//! on the CPU PJRT client, and execute it every slot from the Rust hot
//! path.  This is the Layer-3 ↔ Layer-2/1 bridge — after `make
//! artifacts`, Python is never needed again.
//!
//! Calling convention (defined by `python/compile/model.py::
//! oga_step_export`, parameter order is load-bearing):
//!     x[L] f32, y[L,R,K] f32, mask[L,R] f32, alpha[R,K] f32,
//!     kind[R,K] i32, beta[K] f32, a[L,K] f32, c[R,K] f32, eta[] f32
//!   → tuple(y_next[L,R,K] f32, q f32, gain f32, penalty f32)
//!
//! Problems smaller than the artifact's shape bucket are zero-padded:
//! padded ports get x = 0 / mask = 0 and padded instances get c = 0, so
//! padding is reward- and decision-neutral (proved by
//! python/tests/test_model.py::test_export_shapes_and_padding_neutrality
//! and re-checked against the native path in rust/tests/runtime_parity.rs).

use anyhow::{Context, Result};

use crate::model::Problem;
use crate::runtime::artifact::{Bucket, Manifest};

/// Reward triple returned by the compiled step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepReward {
    pub q: f64,
    pub gain: f64,
    pub penalty: f64,
}

/// A compiled OGA step bound to one problem (static operands are padded
/// and converted once at construction).
pub struct OgaStepExecutor {
    exe: xla::PjRtLoadedExecutable,
    bucket: Bucket,
    /// Problem dims (unpadded).
    l: usize,
    r: usize,
    k: usize,
    /// Padded static literals, rebuilt only when the problem changes.
    mask: xla::Literal,
    alpha: xla::Literal,
    kind: xla::Literal,
    beta: xla::Literal,
    a: xla::Literal,
    c: xla::Literal,
    /// Current padded decision y(t) (f32, bucket shape).
    y: Vec<f32>,
    /// Scratch for padded arrivals.
    x: Vec<f32>,
    /// (l, r) per edge id, copied from the problem graph so the dense
    /// artifact tensor can be gathered into the edge-major layout.
    edges: Vec<(u32, u32)>,
}

impl OgaStepExecutor {
    /// Load the best-fitting artifact from `manifest` and bind `problem`.
    pub fn new(manifest: &Manifest, problem: &Problem) -> Result<Self> {
        let (l, r, k) =
            (problem.num_ports(), problem.num_instances(), problem.num_resources);
        let bucket = manifest
            .pick(l, r, k)
            .with_context(|| format!("no artifact bucket fits L={l} R={r} K={k}"))?
            .clone();
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(
            bucket.path.to_str().context("non-utf8 artifact path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;

        let (bl, br, bk) = (bucket.l, bucket.r, bucket.k);
        // --- pad static operands to the bucket shape ---
        let mut mask = vec![0.0f32; bl * br];
        for ll in 0..l {
            for rr in 0..r {
                mask[ll * br + rr] = problem.graph.mask[ll * r + rr];
            }
        }
        // alpha padded with 1.0: reciprocal-family lanes divide by alpha,
        // and padded lanes are masked out anyway.
        let mut alpha = vec![1.0f32; br * bk];
        let mut kind = vec![0i32; br * bk];
        let mut c = vec![0.0f32; br * bk];
        for rr in 0..r {
            for kk in 0..k {
                alpha[rr * bk + kk] = problem.alpha_at(rr, kk) as f32;
                kind[rr * bk + kk] = problem.kind_at(rr, kk).code();
                c[rr * bk + kk] = problem.capacity_at(rr, kk) as f32;
            }
        }
        let mut beta = vec![0.0f32; bk];
        for kk in 0..k {
            beta[kk] = problem.beta[kk] as f32;
        }
        let mut a = vec![0.0f32; bl * bk];
        for ll in 0..l {
            for kk in 0..k {
                a[ll * bk + kk] = problem.demand_at(ll, kk) as f32;
            }
        }

        Ok(OgaStepExecutor {
            exe,
            l,
            r,
            k,
            mask: lit2(&mask, bl, br)?,
            alpha: lit2(&alpha, br, bk)?,
            kind: lit2i(&kind, br, bk)?,
            beta: xla::Literal::vec1(&beta),
            a: lit2(&a, bl, bk)?,
            c: lit2(&c, br, bk)?,
            y: vec![0.0f32; bl * br * bk],
            x: vec![0.0f32; bl],
            edges: (0..problem.num_edges())
                .map(|e| {
                    (problem.graph.edge_port[e] as u32,
                     problem.graph.edge_instance[e] as u32)
                })
                .collect(),
            bucket,
        })
    }

    pub fn bucket(&self) -> &Bucket {
        &self.bucket
    }

    /// Reset the decision state to y(1) = 0.
    pub fn reset(&mut self) {
        self.y.fill(0.0);
    }

    /// Gather the current decision into `out`, edge-major [E, K] (f64).
    /// The artifact computes on the padded dense [L, R, K] tensor; this
    /// is the layout seam between the XLA side and the Rust CSR side.
    pub fn current_decision(&self, out: &mut [f64]) {
        let (br, bk) = (self.bucket.r, self.bucket.k);
        debug_assert_eq!(out.len(), self.edges.len() * self.k);
        for (e, &(l, r)) in self.edges.iter().enumerate() {
            let src = (l as usize * br + r as usize) * bk;
            let dst = e * self.k;
            for k in 0..self.k {
                out[dst + k] = self.y[src + k] as f64;
            }
        }
    }

    /// Run one compiled OGA step: y(t) ← y(t+1) given arrivals x and
    /// step size eta.  Returns the artifact-computed reward triple for
    /// the pre-step decision (f32 numerics).
    pub fn step(&mut self, x: &[f64], eta: f64) -> Result<StepReward> {
        debug_assert_eq!(x.len(), self.l);
        self.x.fill(0.0);
        for (i, &v) in x.iter().enumerate() {
            self.x[i] = v as f32;
        }
        let (bl, br, bk) = (self.bucket.l, self.bucket.r, self.bucket.k);
        let x_lit = xla::Literal::vec1(&self.x);
        let y_lit = xla::Literal::vec1(&self.y).reshape(&[bl as i64, br as i64, bk as i64])?;
        let eta_lit = xla::Literal::from(eta as f32);
        // execute::<Borrow<Literal>> — pass references so the static
        // operands are not deep-cloned every slot.
        let result = self.exe.execute::<&xla::Literal>(&[
            &x_lit,
            &y_lit,
            &self.mask,
            &self.alpha,
            &self.kind,
            &self.beta,
            &self.a,
            &self.c,
            &eta_lit,
        ])?[0][0]
            .to_literal_sync()?;
        let (y_next, q, gain, penalty) = result.to_tuple4()?;
        let y_vec = y_next.to_vec::<f32>()?;
        debug_assert_eq!(y_vec.len(), self.y.len());
        self.y.copy_from_slice(&y_vec);
        Ok(StepReward {
            q: q.get_first_element::<f32>()? as f64,
            gain: gain.get_first_element::<f32>()? as f64,
            penalty: penalty.get_first_element::<f32>()? as f64,
        })
    }
}

fn lit2(data: &[f32], d0: usize, d1: usize) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(&[d0 as i64, d1 as i64])?)
}

fn lit2i(data: &[i32], d0: usize, d1: usize) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(&[d0 as i64, d1 as i64])?)
}
