//! Runtime bridge: load the AOT'd OGA step (HLO text) via the PJRT CPU
//! client and run it from the slot loop.  `artifact` handles bucket
//! discovery, `executor` the compiled step, and [`HloOgaSched`] exposes
//! the whole thing as a drop-in [`Policy`].

pub mod artifact;
pub mod executor;

pub use artifact::{default_dir, Bucket, Manifest};
pub use executor::{OgaStepExecutor, StepReward};

use crate::model::Problem;
use crate::schedulers::Policy;

/// OGASCHED with its per-slot compute executed by the AOT-compiled
/// XLA artifact instead of the native Rust kernels — the production
/// hot path of the three-layer architecture.
pub struct HloOgaSched {
    exec: OgaStepExecutor,
    eta0: f64,
    decay: f64,
    t: usize,
    /// Last artifact-reported reward triple (pre-step decision).
    pub last_reward: StepReward,
}

impl HloOgaSched {
    pub fn new(manifest: &Manifest, problem: &Problem, eta0: f64, decay: f64)
        -> anyhow::Result<Self> {
        Ok(HloOgaSched {
            exec: OgaStepExecutor::new(manifest, problem)?,
            eta0,
            decay,
            t: 0,
            last_reward: StepReward::default(),
        })
    }

    /// Load from the default artifact directory.
    pub fn from_default_dir(problem: &Problem, eta0: f64, decay: f64)
        -> anyhow::Result<Self> {
        let manifest = Manifest::load(default_dir()).map_err(anyhow::Error::msg)?;
        Self::new(&manifest, problem, eta0, decay)
    }

    pub fn bucket_name(&self) -> &str {
        &self.exec.bucket().name
    }
}

impl Policy for HloOgaSched {
    fn name(&self) -> &'static str {
        "OGASCHED-HLO"
    }

    fn decide(&mut self, _problem: &Problem, x: &[f64], y: &mut [f64]) {
        // Reactive scoring, matching schedulers::OgaSched::new (see the
        // semantics note there): observe x(t), run the compiled Alg. 1
        // step, serve the arrivals with the updated allocation.
        let eta = self.eta0 * self.decay.powi(self.t as i32);
        self.last_reward = self.exec.step(x, eta).expect("PJRT step failed");
        self.exec.current_decision(y);
        self.t += 1;
    }

    fn reset(&mut self, _problem: &Problem) {
        self.exec.reset();
        self.t = 0;
    }
}
