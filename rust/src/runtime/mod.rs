//! Runtime bridge: load the AOT'd OGA step (HLO text) via the PJRT CPU
//! client and run it from the slot loop.  `artifact` handles bucket
//! discovery, `executor` the compiled step, and [`HloOgaSched`] exposes
//! the whole thing as a drop-in [`Policy`].
//!
//! The PJRT path needs the `xla` crate (and `anyhow`), which only the
//! closure-vendored build environment ships.  The crate therefore gates
//! the real executor behind the **`xla` cargo feature**; without it a
//! stub with the same API is compiled whose constructor returns an
//! error, so every caller (CLI `ogasched-hlo`, benches, the parity
//! tests) degrades gracefully instead of failing the build.  To enable
//! the real path, build with `--features xla` after adding the `xla`
//! dependency (vendored closure or registry) to rust/Cargo.toml.

pub mod artifact;

#[cfg(feature = "xla")]
pub mod executor;

/// Stub executor compiled when the `xla` feature is off: identical API,
/// constructor always errors (see module docs).
#[cfg(not(feature = "xla"))]
pub mod executor {
    use crate::model::Problem;
    use crate::runtime::artifact::{Bucket, Manifest};

    /// Reward triple returned by the compiled step.
    #[derive(Clone, Copy, Debug, Default)]
    pub struct StepReward {
        pub q: f64,
        pub gain: f64,
        pub penalty: f64,
    }

    /// Placeholder for the PJRT-backed step; cannot be constructed.
    pub struct OgaStepExecutor {
        never: std::convert::Infallible,
    }

    impl OgaStepExecutor {
        pub fn new(_manifest: &Manifest, _problem: &Problem) -> Result<Self, String> {
            Err("ogasched was built without the `xla` feature; the PJRT \
                 runtime bridge is unavailable (rebuild with --features xla \
                 and the vendored xla crate)"
                .into())
        }

        pub fn bucket(&self) -> &Bucket {
            match self.never {}
        }

        pub fn reset(&mut self) {
            match self.never {}
        }

        pub fn current_decision(&self, _out: &mut [f64]) {
            match self.never {}
        }

        pub fn step(&mut self, _x: &[f64], _eta: f64) -> Result<StepReward, String> {
            match self.never {}
        }
    }
}

pub use artifact::{default_dir, Bucket, Manifest};
pub use executor::{OgaStepExecutor, StepReward};

use crate::model::Problem;
use crate::schedulers::Policy;

/// Error type of the runtime bridge: `anyhow::Error` when the real PJRT
/// path is compiled in, a plain `String` for the stub.
#[cfg(feature = "xla")]
pub type RuntimeError = anyhow::Error;
#[cfg(not(feature = "xla"))]
pub type RuntimeError = String;

#[cfg(feature = "xla")]
fn runtime_err(msg: String) -> RuntimeError {
    anyhow::Error::msg(msg)
}
#[cfg(not(feature = "xla"))]
fn runtime_err(msg: String) -> RuntimeError {
    msg
}

/// OGASCHED with its per-slot compute executed by the AOT-compiled
/// XLA artifact instead of the native Rust kernels — the production
/// hot path of the three-layer architecture.
pub struct HloOgaSched {
    exec: OgaStepExecutor,
    eta0: f64,
    decay: f64,
    t: usize,
    /// Running η (η_{t+1} = λ·η_t), matching the native `OgaState`
    /// recurrence — the old `decay.powi(t as i32)` re-exponentiated per
    /// slot and truncated the exponent for horizons beyond i32::MAX.
    eta_run: f64,
    /// Last artifact-reported reward triple (pre-step decision).
    pub last_reward: StepReward,
}

impl HloOgaSched {
    pub fn new(manifest: &Manifest, problem: &Problem, eta0: f64, decay: f64)
        -> Result<Self, RuntimeError> {
        Ok(HloOgaSched {
            exec: OgaStepExecutor::new(manifest, problem)?,
            eta0,
            decay,
            t: 0,
            eta_run: eta0,
            last_reward: StepReward::default(),
        })
    }

    /// Load from the default artifact directory.
    pub fn from_default_dir(problem: &Problem, eta0: f64, decay: f64)
        -> Result<Self, RuntimeError> {
        let manifest = Manifest::load(default_dir()).map_err(runtime_err)?;
        Self::new(&manifest, problem, eta0, decay)
    }

    pub fn bucket_name(&self) -> &str {
        &self.exec.bucket().name
    }
}

impl Policy for HloOgaSched {
    fn name(&self) -> &'static str {
        "OGASCHED-HLO"
    }

    fn decide(&mut self, _problem: &Problem, x: &[f64], y: &mut [f64]) {
        // Reactive scoring, matching schedulers::OgaSched::new (see the
        // semantics note there): observe x(t), run the compiled Alg. 1
        // step, serve the arrivals with the updated allocation.
        let eta = self.eta_run;
        self.eta_run *= self.decay;
        self.last_reward = self.exec.step(x, eta).expect("PJRT step failed");
        self.exec.current_decision(y);
        self.t += 1;
    }

    fn reset(&mut self, _problem: &Problem) {
        self.exec.reset();
        self.t = 0;
        self.eta_run = self.eta0;
    }
}
