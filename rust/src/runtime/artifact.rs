//! AOT artifact discovery: parse `artifacts/manifest.txt` (written by
//! `python/compile/aot.py`) and pick the smallest shape bucket that fits
//! a problem.  Artifacts are HLO *text* — see aot.py for why text, not
//! serialized protos, is the interchange format.

use std::path::{Path, PathBuf};

/// One AOT'd shape bucket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bucket {
    pub name: String,
    pub l: usize,
    pub r: usize,
    pub k: usize,
    pub path: PathBuf,
}

impl Bucket {
    /// Can a problem of shape (l, r, k) run (zero-padded) in this bucket?
    pub fn fits(&self, l: usize, r: usize, k: usize) -> bool {
        l <= self.l && r <= self.r && k <= self.k
    }

    /// Padded tensor volume — the cost proxy used to pick a bucket.
    pub fn volume(&self) -> usize {
        self.l * self.r * self.k
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub buckets: Vec<Bucket>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text: `name L=10 R=128 K=6 file=...` per line.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest, String> {
        let mut buckets = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut name = None;
            let mut l = None;
            let mut r = None;
            let mut k = None;
            let mut file = None;
            for (i, tok) in line.split_whitespace().enumerate() {
                if i == 0 {
                    name = Some(tok.to_string());
                    continue;
                }
                let (key, val) = tok
                    .split_once('=')
                    .ok_or_else(|| format!("manifest line {}: bad token {tok}", lineno + 1))?;
                match key {
                    "L" => l = val.parse().ok(),
                    "R" => r = val.parse().ok(),
                    "K" => k = val.parse().ok(),
                    "file" => file = Some(val.to_string()),
                    _ => return Err(format!("manifest line {}: unknown key {key}", lineno + 1)),
                }
            }
            match (name, l, r, k, file) {
                (Some(name), Some(l), Some(r), Some(k), Some(file)) => {
                    buckets.push(Bucket { name, l, r, k, path: dir.join(file) });
                }
                _ => return Err(format!("manifest line {}: missing fields", lineno + 1)),
            }
        }
        if buckets.is_empty() {
            return Err("manifest has no buckets".into());
        }
        Ok(Manifest { buckets, dir })
    }

    /// Smallest-volume bucket that fits (l, r, k).
    pub fn pick(&self, l: usize, r: usize, k: usize) -> Option<&Bucket> {
        self.buckets
            .iter()
            .filter(|b| b.fits(l, r, k))
            .min_by_key(|b| b.volume())
    }

    pub fn by_name(&self, name: &str) -> Option<&Bucket> {
        self.buckets.iter().find(|b| b.name == name)
    }
}

/// Default artifact directory: `$OGASCHED_ARTIFACTS` or `artifacts/`
/// relative to the workspace root.
pub fn default_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("OGASCHED_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // walk up from CWD looking for artifacts/manifest.txt (covers running
    // from the workspace root, rust/, or target/)
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    for _ in 0..4 {
        let cand = cur.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return cand;
        }
        if !cur.pop() {
            break;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
small L=4 R=16 K=4 file=oga_step_small.hlo.txt
default L=10 R=128 K=6 file=oga_step_default.hlo.txt
large L=100 R=1024 K=6 file=oga_step_large.hlo.txt
";

    #[test]
    fn parses_and_picks_smallest_fit() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.buckets.len(), 3);
        assert_eq!(m.pick(4, 16, 4).unwrap().name, "small");
        assert_eq!(m.pick(5, 16, 4).unwrap().name, "default");
        assert_eq!(m.pick(10, 128, 6).unwrap().name, "default");
        assert_eq!(m.pick(11, 128, 6).unwrap().name, "large");
        assert!(m.pick(200, 1, 1).is_none());
        assert_eq!(m.by_name("large").unwrap().l, 100);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("x L=1", PathBuf::new()).is_err());
        assert!(Manifest::parse("", PathBuf::new()).is_err());
        assert!(Manifest::parse("x L=1 R=2 K=3 Z=9 file=f", PathBuf::new()).is_err());
    }

    #[test]
    fn real_manifest_loads_when_present() {
        let dir = default_dir();
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.pick(4, 16, 4).is_some());
            for b in &m.buckets {
                assert!(b.path.exists(), "missing artifact {}", b.path.display());
            }
        }
    }
}
