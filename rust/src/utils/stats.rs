//! Small statistics toolkit (no external crates): summaries, Welford
//! online moments, percentiles, linear regression (used by the regret
//! sublinearity fit), and moving averages for the figure harnesses.

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Percentile with linear interpolation; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Ordinary least squares y = a + b·x; returns (a, b, r²).
pub fn linregress(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mx = mean(x);
    let my = mean(y);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for i in 0..x.len() {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxx += dx * dx;
        sxy += dx * dy;
        syy += dy * dy;
    }
    if sxx == 0.0 {
        return (my, 0.0, 0.0);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    let _ = n;
    (a, b, r2)
}

/// Fit y ≈ C·x^p on log-log axes; returns (C, p, r²).  Used to verify the
/// Thm. 1 √T regret empirically (expect p ≈ 0.5, certainly < 1).
pub fn powerlaw_fit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    let lx: Vec<f64> = x.iter().map(|v| v.max(1e-12).ln()).collect();
    let ly: Vec<f64> = y.iter().map(|v| v.max(1e-12).ln()).collect();
    let (a, b, r2) = linregress(&lx, &ly);
    (a.exp(), b, r2)
}

/// Trailing moving average with window `w` (figure smoothing).
pub fn moving_avg(xs: &[f64], w: usize) -> Vec<f64> {
    let w = w.max(1);
    let mut out = Vec::with_capacity(xs.len());
    let mut sum = 0.0;
    for i in 0..xs.len() {
        sum += xs[i];
        if i >= w {
            sum -= xs[i - w];
        }
        out.push(sum / (i.min(w - 1) + 1) as f64);
    }
    out
}

/// Prefix-mean curve: out[t] = mean(xs[0..=t]) — the paper's Fig. 2(a)
/// "average reward until time t".
pub fn prefix_mean(xs: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut sum = 0.0;
    for (i, x) in xs.iter().enumerate() {
        sum += x;
        out.push(sum / (i + 1) as f64);
    }
    out
}

/// Cumulative-sum curve (Fig. 2(b)).
pub fn cumsum(xs: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut sum = 0.0;
    for x in xs {
        sum += x;
        out.push(sum);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linregress_recovers_line() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let (a, b, r2) = linregress(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn powerlaw_recovers_sqrt() {
        let x: Vec<f64> = (1..100).map(|i| i as f64 * 10.0).collect();
        let y: Vec<f64> = x.iter().map(|v| 4.0 * v.sqrt()).collect();
        let (c, p, r2) = powerlaw_fit(&x, &y);
        assert!((c - 4.0).abs() < 1e-6);
        assert!((p - 0.5).abs() < 1e-9);
        assert!(r2 > 0.999);
    }

    #[test]
    fn prefix_mean_and_cumsum() {
        let xs = [2.0, 4.0, 6.0];
        assert_eq!(prefix_mean(&xs), vec![2.0, 3.0, 4.0]);
        assert_eq!(cumsum(&xs), vec![2.0, 6.0, 12.0]);
    }

    #[test]
    fn moving_avg_window() {
        let xs = [1.0, 1.0, 4.0, 4.0];
        let ma = moving_avg(&xs, 2);
        assert_eq!(ma, vec![1.0, 1.0, 2.5, 4.0]);
    }
}
