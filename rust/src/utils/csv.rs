//! Minimal CSV reader/writer (no external crates).
//!
//! Used for two things: exporting figure/table series for plotting, and
//! loading machine/job trace files (`traces/` ships an embedded sample in
//! the same format as our Alibaba-like extraction).

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// A CSV table: a header row plus data rows of equal arity.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Csv {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn push_row<S: ToString>(&mut self, row: &[S]) {
        assert_eq!(row.len(), self.header.len(), "row arity != header arity");
        self.rows.push(row.iter().map(|s| s.to_string()).collect());
    }

    /// Convenience for numeric rows.
    pub fn push_f64(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.header.len());
        self.rows.push(row.iter().map(|v| format!("{v}")).collect());
    }

    pub fn col_index(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Column by name, parsed as f64 (non-numeric cells become NaN).
    pub fn col_f64(&self, name: &str) -> Option<Vec<f64>> {
        let i = self.col_index(name)?;
        Some(self.rows.iter().map(|r| r[i].parse().unwrap_or(f64::NAN)).collect())
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        writeln_row(&mut out, &self.header);
        for row in &self.rows {
            writeln_row(&mut out, row);
        }
        out
    }

    pub fn write_file<P: AsRef<Path>>(&self, path: P) -> io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_string())
    }

    pub fn parse(text: &str) -> Result<Csv, String> {
        let mut lines = text
            .lines()
            .map(str::trim_end)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        let header = match lines.next() {
            Some(h) => split_row(h),
            None => return Err("empty csv".into()),
        };
        let mut rows = Vec::new();
        for (i, line) in lines.enumerate() {
            let row = split_row(line);
            if row.len() != header.len() {
                return Err(format!(
                    "row {} has {} fields, header has {}",
                    i + 2,
                    row.len(),
                    header.len()
                ));
            }
            rows.push(row);
        }
        Ok(Csv { header, rows })
    }

    pub fn read_file<P: AsRef<Path>>(path: P) -> Result<Csv, String> {
        let text = fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e}", path.as_ref().display()))?;
        Csv::parse(&text)
    }
}

fn needs_quote(s: &str) -> bool {
    s.contains(',') || s.contains('"') || s.contains('\n')
}

fn writeln_row(out: &mut String, row: &[String]) {
    for (i, cell) in row.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if needs_quote(cell) {
            write!(out, "\"{}\"", cell.replace('"', "\"\"")).unwrap();
        } else {
            out.push_str(cell);
        }
    }
    out.push('\n');
}

/// Split one CSV line honoring double-quoted fields.
fn split_row(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(ch) = chars.next() {
        match ch {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(ch),
        }
    }
    out.push(cur);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let mut c = Csv::new(&["t", "reward"]);
        c.push_f64(&[1.0, 2.5]);
        c.push_f64(&[2.0, 3.5]);
        let parsed = Csv::parse(&c.to_string()).unwrap();
        assert_eq!(parsed, c);
    }

    #[test]
    fn quoted_fields() {
        let mut c = Csv::new(&["name", "v"]);
        c.push_row(&["has,comma", "x\"y"]);
        let parsed = Csv::parse(&c.to_string()).unwrap();
        assert_eq!(parsed.rows[0][0], "has,comma");
        assert_eq!(parsed.rows[0][1], "x\"y");
    }

    #[test]
    fn col_by_name() {
        let text = "a,b\n1,2\n3,4\n";
        let c = Csv::parse(text).unwrap();
        assert_eq!(c.col_f64("b").unwrap(), vec![2.0, 4.0]);
        assert!(c.col_f64("z").is_none());
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let text = "# comment\na,b\n\n1,2\n";
        let c = Csv::parse(text).unwrap();
        assert_eq!(c.rows.len(), 1);
    }

    #[test]
    fn arity_mismatch_rejected() {
        assert!(Csv::parse("a,b\n1\n").is_err());
    }
}
