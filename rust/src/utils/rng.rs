//! Deterministic PRNG + distributions.
//!
//! The offline image has no `rand` crate, so the simulator carries its own
//! generator: xoshiro256** (Blackman & Vigna) seeded through SplitMix64.
//! Deterministic seeding is load-bearing — every figure harness and test
//! reproduces bit-identically from a scenario seed.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/correlated seeds still produce
    /// well-distributed initial state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-policy / per-cell RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Snapshot the raw xoshiro256** state.  Together with
    /// [`Rng::from_state`] this lets `sim::checkpoint` freeze and
    /// resume a stream bit-identically mid-run — the generator is pure
    /// state, so a restored stream emits exactly the continuation the
    /// uninterrupted stream would have.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a stream from a [`Rng::state`] snapshot (no SplitMix64
    /// re-seeding: the words are the live state, not a seed).
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough for simulation use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with given log-mean and log-sigma (machine spec /
    /// job-demand heterogeneity in the trace generator).
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-like rank weight sampler over [0, n): rank i w.p. ∝ 1/(i+1)^s.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        let weights: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect();
        self.categorical(&weights)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = self.range(i, n - 1);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn bernoulli_rate_close() {
        let mut r = Rng::new(3);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.7)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.7).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..60_000 {
            counts[r.categorical(&[1.0, 2.0, 3.0])] += 1;
        }
        let total: usize = counts.iter().sum();
        let p: Vec<f64> = counts.iter().map(|&c| c as f64 / total as f64).collect();
        assert!((p[0] - 1.0 / 6.0).abs() < 0.02);
        assert!((p[1] - 2.0 / 6.0).abs() < 0.02);
        assert!((p[2] - 3.0 / 6.0).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn state_round_trips_bit_identically() {
        let mut r = Rng::new(101);
        for _ in 0..37 {
            r.next_u64();
        }
        let snap = r.state();
        let tail: Vec<u64> = (0..64).map(|_| r.next_u64()).collect();
        let mut resumed = Rng::from_state(snap);
        let resumed_tail: Vec<u64> = (0..64).map(|_| resumed.next_u64()).collect();
        assert_eq!(tail, resumed_tail);
    }

    // Checkpoint-exactness property (ISSUE 7 satellite): snapshot at an
    // arbitrary point in an arbitrary draw mix, then the restored
    // stream's continuation is bitwise the uninterrupted one's — across
    // every sampler, not just next_u64.
    #[test]
    fn prop_snapshot_restore_resumes_bit_identically() {
        use crate::utils::prop::{check, ensure, Size};
        check("rng_snapshot_resume", 40, |meta, size: Size| {
            let mut r = Rng::new(meta.next_u64());
            let warmup = meta.below(size.dim(200, 1));
            for _ in 0..warmup {
                // mixed draw kinds so the state isn't only next_u64-advanced
                match meta.below(4) {
                    0 => {
                        r.next_u64();
                    }
                    1 => {
                        r.f64();
                    }
                    2 => {
                        r.bernoulli(0.3);
                    }
                    _ => {
                        r.below(17);
                    }
                }
            }
            let mut resumed = Rng::from_state(r.state());
            for i in 0..64 {
                ensure(r.next_u64() == resumed.next_u64(), || {
                    format!("diverged at continuation draw {i}")
                })?;
            }
            Ok(())
        });
    }

    // Fork independence property (ISSUE 7 satellite): the child stream
    // is fixed at fork time — however much the parent draws *afterwards*
    // (and in whatever order siblings are forked), the child's output is
    // unchanged.  This is what makes per-policy checkpointed arrivals
    // exact: restoring a parent mid-run never perturbs live children.
    #[test]
    fn prop_fork_streams_independent_of_parent_consumption() {
        use crate::utils::prop::{check, ensure, Size};
        check("rng_fork_independent", 40, |meta, size: Size| {
            let seed = meta.next_u64();
            let tag = meta.next_u64();
            let pre = meta.below(size.dim(100, 0));
            let post = meta.below(size.dim(100, 1));

            // Reference: fork after `pre` parent draws, read the child.
            let mut parent = Rng::new(seed);
            for _ in 0..pre {
                parent.next_u64();
            }
            let mut child = parent.fork(tag);
            let want: Vec<u64> = (0..32).map(|_| child.next_u64()).collect();

            // Same fork point, but the parent keeps drawing afterwards
            // and forks further siblings — the child must not notice.
            let mut parent2 = Rng::new(seed);
            for _ in 0..pre {
                parent2.next_u64();
            }
            let mut child2 = parent2.fork(tag);
            for _ in 0..post {
                parent2.next_u64();
            }
            let _sibling = parent2.fork(tag ^ 0x5555);
            let got: Vec<u64> = (0..32).map(|_| child2.next_u64()).collect();
            ensure(want == got, || {
                "child stream depends on parent consumption".into()
            })?;
            Ok(())
        });
    }
}
