//! Hand-rolled property-testing harness (proptest is not in the offline
//! image).  `check` runs a predicate over many seeded random cases and, on
//! failure, reports the seed so the case replays deterministically; a
//! lightweight "shrink" retries the failing predicate with scaled-down
//! size hints to find a smaller reproduction.

use crate::utils::rng::Rng;

/// Size hints handed to generators: dimensions shrink before seeds.
#[derive(Clone, Copy, Debug)]
pub struct Size {
    pub scale: f64,
}

impl Size {
    /// Scale an upper bound, keeping at least `min`.
    pub fn dim(&self, max: usize, min: usize) -> usize {
        ((max as f64 * self.scale).round() as usize).max(min)
    }
}

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random trials of `prop(rng, size)`; panic with the seed
/// and (possibly shrunk) failure message if any trial fails.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng, Size) -> CaseResult,
{
    check_seeded(name, 0xC0FFEE, cases, prop)
}

pub fn check_seeded<F>(name: &str, base_seed: u64, cases: usize, prop: F)
where
    F: Fn(&mut Rng, Size) -> CaseResult,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, Size { scale: 1.0 }) {
            // try to shrink: re-run with smaller size hints on the same seed
            let mut best = (1.0f64, msg);
            for &scale in &[0.5, 0.25, 0.1] {
                let mut rng = Rng::new(seed);
                if let Err(m) = prop(&mut rng, Size { scale }) {
                    best = (scale, m);
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 shrunk scale {}): {}",
                best.0, best.1
            );
        }
    }
}

/// Assert two floats are close; returns a CaseResult for use in props.
pub fn close(label: &str, got: f64, want: f64, tol: f64) -> CaseResult {
    if (got - want).abs() <= tol + tol * want.abs() {
        Ok(())
    } else {
        Err(format!("{label}: got {got}, want {want} (tol {tol})"))
    }
}

/// Assert `cond` with a lazily formatted message.
pub fn ensure(cond: bool, msg: impl Fn() -> String) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |rng, _| {
            let a = rng.f64();
            let b = rng.f64();
            close("a+b", a + b, b + a, 1e-15)
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 3, |_, _| Err("nope".into()));
    }

    #[test]
    fn size_scales_dimensions() {
        let s = Size { scale: 0.25 };
        assert_eq!(s.dim(100, 2), 25);
        assert_eq!(s.dim(4, 2), 2);
    }

    #[test]
    fn ensure_and_close_helpers() {
        assert!(ensure(true, || "x".into()).is_ok());
        assert!(ensure(false, || "x".into()).is_err());
        assert!(close("v", 1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close("v", 1.0, 2.0, 1e-9).is_err());
    }
}
