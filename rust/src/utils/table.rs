//! Aligned ASCII table rendering for paper-style output (every figure
//! harness prints its rows through this, mirroring the paper's tables).

/// A column-aligned text table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    /// Row indices to render in bold-ish emphasis (`*value*`), used for
    /// the "two largest values per column in bold" convention of Tab. 3.
    emphasized: Vec<(usize, usize)>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            emphasized: Vec::new(),
        }
    }

    pub fn push<S: ToString>(&mut self, row: &[S]) {
        assert_eq!(row.len(), self.header.len());
        self.rows.push(row.iter().map(|s| s.to_string()).collect());
    }

    /// Push a row with a string label followed by numeric cells.
    pub fn push_labeled(&mut self, label: &str, values: &[f64], prec: usize) {
        let mut row = vec![label.to_string()];
        row.extend(values.iter().map(|v| format!("{v:.prec$}")));
        assert_eq!(row.len(), self.header.len());
        self.rows.push(row);
    }

    /// Emphasize the top-n numeric cells of every column except column 0
    /// (the Tab. 3 "two largest per column bold" rendering).
    pub fn emphasize_top_per_column(&mut self, n: usize) {
        self.emphasized.clear();
        for col in 1..self.header.len() {
            let mut vals: Vec<(usize, f64)> = self
                .rows
                .iter()
                .enumerate()
                .filter_map(|(i, r)| r[col].parse::<f64>().ok().map(|v| (i, v)))
                .collect();
            vals.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            for &(row, _) in vals.iter().take(n) {
                self.emphasized.push((row, col));
            }
        }
    }

    pub fn render(&self) -> String {
        let cell = |r: usize, c: usize| -> String {
            let raw = &self.rows[r][c];
            if self.emphasized.contains(&(r, c)) {
                format!("*{raw}*")
            } else {
                raw.clone()
            }
        };
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in 0..self.rows.len() {
            for c in 0..widths.len() {
                widths[c] = widths[c].max(cell(r, c).len());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            for (i, w) in widths.iter().enumerate() {
                out.push_str(if i == 0 { "+" } else { "+" });
                out.push_str(&"-".repeat(w + 2));
            }
            out.push_str("+\n");
        };
        sep(&mut out);
        for (i, h) in self.header.iter().enumerate() {
            out.push_str("| ");
            out.push_str(h);
            out.push_str(&" ".repeat(widths[i] - h.len() + 1));
        }
        out.push_str("|\n");
        sep(&mut out);
        for r in 0..self.rows.len() {
            for c in 0..widths.len() {
                let s = cell(r, c);
                out.push_str("| ");
                out.push_str(&s);
                out.push_str(&" ".repeat(widths[c] - s.len() + 1));
            }
            out.push_str("|\n");
        }
        sep(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["policy", "reward"]);
        t.push(&["OGASCHED", "2886.33"]);
        t.push(&["DRF", "2493.02"]);
        let s = t.render();
        assert!(s.contains("OGASCHED"));
        let lines: Vec<&str> = s.lines().collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "ragged table:\n{s}");
    }

    #[test]
    fn emphasizes_top_cells() {
        let mut t = Table::new(&["p", "v"]);
        t.push(&["a", "1.0"]);
        t.push(&["b", "3.0"]);
        t.push(&["c", "2.0"]);
        t.emphasize_top_per_column(2);
        let s = t.render();
        assert!(s.contains("*3.0*"));
        assert!(s.contains("*2.0*"));
        assert!(!s.contains("*1.0*"));
    }

    #[test]
    fn push_labeled_formats_precision() {
        let mut t = Table::new(&["x", "a", "b"]);
        t.push_labeled("row", &[1.23456, 2.0], 2);
        assert!(t.render().contains("1.23"));
    }
}
