//! Hierarchical budget-aware executor (no rayon in the offline image).
//!
//! Algorithm 1's projection is "for each (r, k) do in parallel".  The
//! seed provided that parallelism with `std::thread::scope`, which pays
//! ~100µs of spawn/join per worker per call — more than the projection
//! itself on mid-sized problems (measured in
//! benches/ablation_projection.rs, recorded in EXPERIMENTS.md §Perf).
//! This module keeps parked workers instead: a call publishes a job
//! (type-erased closure + atomic chunk cursor), wakes the workers,
//! participates in the work itself, and blocks until every index has
//! executed.  Steady-state dispatch cost is one mutex round-trip plus
//! condvar wakes — single-digit microseconds.
//!
//! §Perf-4 made the executor *two-level*.  The worker budget W
//! ([`global_workers`]: `PALLAS_WORKERS` or auto-detect) splits into an
//! [`ExecBudget`] of `runs × shards`: up to `runs` concurrent top-level
//! lanes (e.g. the policies of a `run_lineup` sweep), each owning a
//! private [`ShardGroup`] of `shards` workers that its *nested* scatters
//! dispatch to.  Dispatch is routed by a thread-local scope:
//!
//! * a plain thread scatters on the **global crew** (the flat pool);
//! * a lane driver inside [`ShardGroup::run`] scatters on its **leased
//!   group crew** — nested parallelism no longer degrades to inline
//!   execution when the budget grants it workers;
//! * a crew *worker* thread (global or group) runs nested scatters
//!   inline — the two levels are the hierarchy, there is no third.
//!
//! §Perf-5 widened what rides those scatters: the sharded Eq. 50 solve
//! fans its per-iteration objective (per-port reward kernels, merged
//! serially port-ascending) and the gradient's phase-A quota/k*
//! reductions over the same crews — worker-count-many scatters per
//! iteration whose floats never depend on the thread assignment.
//!
//! Work is chunked dynamically (atomic `fetch_add` on a shared cursor in
//! chunks of ~n/4·workers), which keeps near-uniform tasks balanced
//! without a work-stealing deque.  Concurrent submitters to the *same*
//! crew do not queue: whoever arrives second runs its loop inline on its
//! own thread, which is always correct and avoids same-crew nested-job
//! deadlocks by construction.  Which thread executes which index is
//! scheduling-dependent, but every caller in this crate either writes
//! disjoint coordinates or replays its float reductions serially, so
//! results never depend on the assignment.

use std::cell::{Cell, RefCell};
use std::collections::BTreeSet;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

use crate::obs;

/// Substring that tags *injected* execution-fault panics (see
/// [`ExecProbe`]).  The isolation layer retries exactly these: an
/// injected fault fires at task entry, before any writes, and disarms
/// itself, so a bounded re-dispatch always succeeds and never replays a
/// side effect.  Panics without the marker are real bugs (or strict-mode
/// asserts) and are re-raised on the submitting thread after the
/// scatter drains.
pub const EXEC_FAULT_MARKER: &str = "pallas-exec-fault";

/// Bounded deterministic retry schedule for marker-tagged failures:
/// attempt k backs off by `1 << k` cooperative yields (no wall-clock
/// randomness — the schedule is a pure function of the attempt index).
const MAX_RETRY_ATTEMPTS: u32 = 3;

/// Structured report of one isolated task panic: which index of the
/// scatter failed (for shard-shaped scatters this *is* the shard id),
/// at which simulation slot (from the submitter's [`set_slot`] context),
/// and the stringified panic payload.
#[derive(Clone, Debug)]
pub struct TaskFailure {
    pub shard: usize,
    pub slot: u64,
    pub payload: String,
}

/// "pool.task_failures" — total isolated task panics since process
/// start (injected + real); tests assert this moves instead of the
/// process dying.  Lives in the obs registry (so the run summary and
/// JSONL export see it); the `OnceLock` cache keeps the hot path at
/// one relaxed RMW per event, registry lock touched once.
fn task_failures() -> &'static obs::Counter {
    static C: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("pool.task_failures"))
}

/// See [`task_failures`].
pub fn task_failure_count() -> usize {
    task_failures().get() as usize
}

/// "pool.watchdog_trips" — scatters flagged overdue by the per-scatter
/// deadline watchdog (registry-backed, same pattern as
/// [`task_failures`]).
fn watchdog_trips() -> &'static obs::Counter {
    static C: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("pool.watchdog_trips"))
}

/// See [`watchdog_trips`].
pub fn watchdog_trip_count() -> u64 {
    watchdog_trips().get()
}

/// Per-scatter watchdog deadline.  Read per scatter (not once) so tests
/// and CI can tighten/loosen it at runtime; the default is generous —
/// the watchdog only *flags* (it never re-executes possibly-started
/// work, which would be unsound for the non-idempotent `+=` kernels),
/// so a trip is an observability signal, not a recovery action.
fn watchdog_ms() -> u64 {
    std::env::var("PALLAS_WATCHDOG_MS")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&ms| ms >= 1)
        .unwrap_or(10_000)
}

thread_local! {
    /// Simulation slot the calling thread is currently executing; the
    /// coordinator sets it once per slot so [`TaskFailure`]s carry it.
    static CURRENT_SLOT: Cell<u64> = const { Cell::new(0) };
}

/// Tag subsequent scatters from this thread with simulation slot `t`
/// (surfaced in [`TaskFailure::slot`]).
pub fn set_slot(t: u64) {
    CURRENT_SLOT.with(|s| s.set(t));
}

pub(crate) fn current_slot() -> u64 {
    CURRENT_SLOT.with(|s| s.get())
}

/// Seeded execution-fault injector (armed by `sim::faults`'
/// `ExecFaultPlan`).  Leaders carry an optional probe and call
/// [`ExecProbe::fire`] at the entry of every per-shard task — *before
/// any writes* — so a fired fault is always retry-safe.  Faults are
/// one-shot: firing disarms the (slot, shard) entry, so the bounded
/// retry's second attempt runs clean and the floats never change.
#[derive(Debug, Default)]
pub struct ExecProbe {
    panics: Mutex<BTreeSet<(u64, u32)>>,
    stalls: Mutex<BTreeSet<(u64, u32)>>,
    stall_ms: u64,
    fired: AtomicUsize,
}

impl ExecProbe {
    pub fn new(
        panics: BTreeSet<(u64, u32)>,
        stalls: BTreeSet<(u64, u32)>,
        stall_ms: u64,
    ) -> ExecProbe {
        ExecProbe {
            panics: Mutex::new(panics),
            stalls: Mutex::new(stalls),
            stall_ms,
            fired: AtomicUsize::new(0),
        }
    }

    /// Fire any fault armed for (slot, shard): an injected panic raises
    /// immediately; an injected stall sleeps past the watchdog deadline
    /// first, then raises (so the work still re-dispatches exactly once
    /// via the marker-retry path — a stalled worker costs latency,
    /// never floats).
    pub fn fire(&self, slot: u64, shard: u32) {
        if self.panics.lock().unwrap().remove(&(slot, shard)) {
            self.fired.fetch_add(1, Ordering::Relaxed);
            panic!("{EXEC_FAULT_MARKER}: injected worker panic at (slot {slot}, shard {shard})");
        }
        if self.stalls.lock().unwrap().remove(&(slot, shard)) {
            self.fired.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(self.stall_ms));
            panic!("{EXEC_FAULT_MARKER}: injected worker stall at (slot {slot}, shard {shard})");
        }
    }

    /// Faults fired so far (tests assert injection actually happened).
    pub fn fired_count(&self) -> usize {
        self.fired.load(Ordering::Relaxed)
    }
}

/// Stringify a caught panic payload (the two shapes `panic!` produces).
fn payload_string(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Deterministic backoff for retry attempt `attempt`: cooperative
/// yields only, count a pure function of the attempt index.
fn retry_backoff(attempt: u32) {
    for _ in 0..(1u32 << attempt) {
        std::thread::yield_now();
    }
}

/// Run `f` with injected-fault isolation: marker-tagged panics (see
/// [`EXEC_FAULT_MARKER`]) are caught and retried on the bounded
/// deterministic schedule; anything else propagates unchanged.  This is
/// the *inline* arm of the isolation layer — serial leaders and
/// single-worker fallbacks route their per-task calls through it so an
/// injected fault is survived identically whether or not a crew ran.
pub fn run_isolated<T>(mut f: impl FnMut() -> T) -> T {
    for attempt in 0..MAX_RETRY_ATTEMPTS {
        match std::panic::catch_unwind(AssertUnwindSafe(|| f())) {
            Ok(v) => return v,
            Err(p) => {
                let payload = payload_string(p.as_ref());
                task_failures().inc();
                obs::event(obs::SpanKind::TaskFault, current_slot(), 0, attempt);
                if !payload.contains(EXEC_FAULT_MARKER) {
                    // a real panic: re-raise with the stringified
                    // payload (expected-substring matching still works)
                    std::panic::resume_unwind(Box::new(payload));
                }
                retry_backoff(attempt);
            }
        }
    }
    // a fault that survives the bounded schedule is not an injected
    // one-shot — let it propagate as the bug it is
    f()
}

#[inline]
fn call_isolated(f: &(dyn Fn(usize) + Sync), i: usize) {
    run_isolated(|| f(i));
}

/// Process-wide parallelism budget W: `PALLAS_WORKERS` when set to a
/// positive integer (CI pins it so small runners still exercise the
/// multi-worker paths deterministically), otherwise the machine's
/// available parallelism.  Read once — the global crew and every
/// derived [`ExecBudget`] are sized from it.  This is the single place
/// that parses the env var; every other layer consumes the shared
/// [`ExecBudget`] type instead of re-reading the environment.
pub fn global_workers() -> usize {
    static CONF: OnceLock<usize> = OnceLock::new();
    *CONF.get_or_init(|| {
        std::env::var("PALLAS_WORKERS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Optional override of the auto-derived lane count (`PALLAS_RUNS`):
/// lets CI pin an explicit budget split (e.g. `PALLAS_WORKERS=4` with
/// `PALLAS_RUNS=2` → 2 lanes × 2 shards) without touching configs.
fn configured_runs() -> Option<usize> {
    static CONF: OnceLock<Option<usize>> = OnceLock::new();
    *CONF.get_or_init(|| {
        std::env::var("PALLAS_RUNS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
    })
}

/// Number of worker threads to use for `n_tasks` independent tasks.
pub fn default_workers(n_tasks: usize) -> usize {
    global_workers().min(n_tasks).max(1)
}

/// Split of the global worker budget into `runs × shards`: up to `runs`
/// concurrent top-level lanes, each owning `shards` workers for its
/// nested scatters.  `0` in either field means *auto*, resolved by the
/// deterministic rule in [`ExecBudget::resolve`].  This is the shared
/// currency every `workers`-shaped knob in the crate plumbs —
/// scenarios, policies, `run_lineup`, `solve_oracle` — instead of raw
/// ints with per-site env parsing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecBudget {
    /// Concurrent top-level lanes (0 = auto).
    pub runs: usize,
    /// Workers per lane — the shard-group size (0 = auto).
    pub shards: usize,
}

impl ExecBudget {
    /// Fully automatic split (the [`Default`]).
    pub const fn auto() -> ExecBudget {
        ExecBudget { runs: 0, shards: 0 }
    }

    /// One lane, one worker: everything runs serially.
    pub const fn serial() -> ExecBudget {
        ExecBudget { runs: 1, shards: 1 }
    }

    /// Explicit split (honored as given — an explicit budget may
    /// deliberately oversubscribe; auto-derived ones never do).
    pub const fn split(runs: usize, shards: usize) -> ExecBudget {
        ExecBudget { runs, shards }
    }

    /// One lane with `shards` workers (0 = auto) — the legacy shape of
    /// the crate's old `workers: usize` parameters.
    pub const fn shards_only(shards: usize) -> ExecBudget {
        ExecBudget { runs: 1, shards }
    }

    /// Resolve auto fields for a fan-out of `n_runs` candidate lanes.
    /// Deterministic rule: `runs = min(n_runs, W)` (or `PALLAS_RUNS`,
    /// clamped the same way; with an explicit `shards`, W is first
    /// divided by it so the lanes fit), then `shards = max(1, W / runs)`
    /// — so `runs × shards ≤ W` and the split never oversubscribes
    /// unless both fields were set explicitly.  Idempotent.
    pub fn resolve(self, n_runs: usize) -> ExecBudget {
        let n = n_runs.max(1);
        let w = global_workers();
        let runs = match self.runs {
            0 => {
                // an explicit per-run shard width consumes its slice of
                // the budget before the lane count is derived
                let lane_cap = match self.shards {
                    0 => w,
                    s => (w / s).max(1),
                };
                configured_runs().unwrap_or(n).min(lane_cap).min(n).max(1)
            }
            r => r.min(n).max(1),
        };
        let shards = match self.shards {
            0 => (w / runs).max(1),
            s => s,
        };
        ExecBudget { runs, shards }
    }

    /// Concrete shard count for a single run (no lane fan-out): the
    /// explicit `shards`, or the whole worker budget W when auto.
    pub fn run_shards(self) -> usize {
        if self.shards == 0 {
            global_workers()
        } else {
            self.shards
        }
    }

}

/// Legacy bridge: the crate's old `workers: usize` parameters meant
/// "workers inside this one run, 0 = auto" — exactly
/// [`ExecBudget::shards_only`].
impl From<usize> for ExecBudget {
    fn from(workers: usize) -> ExecBudget {
        ExecBudget::shards_only(workers)
    }
}

/// One published parallel-for job.
struct Job {
    /// Type-erased pointer to the caller's closure.  Only dereferenced
    /// while the submitting thread is blocked inside `Crew::scatter`, so
    /// the pointee outlives every use (raw pointers carry no lifetime).
    f: *const (dyn Fn(usize) + Sync),
    /// Next unclaimed index (claimed in `chunk`-sized strides).
    next: AtomicUsize,
    /// Indices fully executed; the job is done when this reaches `n`.
    completed: AtomicUsize,
    /// Crew threads that joined; capped at `max_entrants` so a caller's
    /// `workers` budget is honored even when the crew is larger.
    entrants: AtomicUsize,
    n: usize,
    chunk: usize,
    max_entrants: usize,
    /// Simulation slot the submitter was in (for [`TaskFailure`]s).
    slot_tag: u64,
    /// Per-index panics caught by the isolation layer; the submitter
    /// drains these after the scatter completes (retry or re-raise).
    failures: Mutex<Vec<TaskFailure>>,
    /// Set once by the watchdog when the scatter blew its deadline.
    overdue: AtomicBool,
}

// SAFETY: `f` points at a `Sync` closure owned by the submitting thread,
// which blocks until `completed == n`; workers never touch `f` after
// their final chunk completes.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct Slot {
    /// Bumped once per published job so parked workers can tell a new
    /// job from the one they already ran.
    seq: u64,
    job: Option<Arc<Job>>,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Workers park here waiting for `seq` to move.
    work_cv: Condvar,
    /// The submitter parks here waiting for `completed == n`.
    done_cv: Condvar,
    /// Set by [`shutdown`]: workers exit their loop instead of parking.
    quit: AtomicBool,
}

/// One dispatch unit: a job slot plus the parked worker threads that
/// drain it.  The flat global pool is a crew; every leased
/// [`ShardGroup`] wraps its own private crew — same machinery, so the
/// two hierarchy levels share one implementation.
struct Crew {
    shared: Arc<Shared>,
    /// Serializes submissions; `try_lock` losers run inline instead of
    /// queueing (see module docs).
    submit: Mutex<()>,
    /// Parked worker threads owned by this crew.  Count in `threads`
    /// (hot-path check), join handles in `handles` so
    /// [`shutdown`] can drain them cleanly between harness runs.
    threads: AtomicUsize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Serializes thread growth (leases and post-shutdown respawns can
    /// race on the same recycled crew).
    grow: Mutex<()>,
}

impl Crew {
    fn new() -> Crew {
        Crew {
            shared: Arc::new(Shared {
                slot: Mutex::new(Slot { seq: 0, job: None }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
                quit: AtomicBool::new(false),
            }),
            submit: Mutex::new(()),
            threads: AtomicUsize::new(0),
            handles: Mutex::new(Vec::new()),
            grow: Mutex::new(()),
        }
    }

    /// Grow to at least `want` parked workers.
    fn ensure_threads(&self, want: usize, tag: &str) {
        if self.threads.load(Ordering::Relaxed) >= want {
            return;
        }
        let _grow = self.grow.lock().unwrap();
        let have = self.threads.load(Ordering::Relaxed);
        for i in have..want {
            let shared = Arc::clone(&self.shared);
            if let Ok(handle) = std::thread::Builder::new()
                .name(format!("{tag}-{i}"))
                .spawn(move || worker_loop(shared))
            {
                self.handles.lock().unwrap().push(handle);
                self.threads.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Signal workers to exit, join them, and reset so a later scatter
    /// can respawn.  Used by [`shutdown`].
    fn drain(&self) {
        let handles: Vec<_> = std::mem::take(&mut *self.handles.lock().unwrap());
        if handles.is_empty() {
            return;
        }
        self.shared.quit.store(true, Ordering::Release);
        {
            let _slot = self.shared.slot.lock().unwrap();
            self.shared.work_cv.notify_all();
        }
        for h in handles {
            let _ = h.join();
        }
        self.shared.quit.store(false, Ordering::Release);
        self.threads.store(0, Ordering::Relaxed);
    }

    /// Publish `f` over `0..n` with up to `workers` entrants (the
    /// submitting thread counts as one) and block until done.  Returns
    /// `false` — caller must run inline — when the crew cannot help:
    /// one worker budget, no parked threads, or the submit lock is held
    /// (a concurrent or nested submission on this crew).
    fn scatter(&self, n: usize, workers: usize, f: &(dyn Fn(usize) + Sync)) -> bool {
        if workers <= 1 || self.threads.load(Ordering::Relaxed) == 0 {
            return false;
        }
        let Ok(_submit) = self.submit.try_lock() else {
            return false;
        };
        let job = Arc::new(Job {
            f: f as *const (dyn Fn(usize) + Sync),
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            entrants: AtomicUsize::new(0),
            n,
            chunk: n.div_ceil(workers * 4).max(1),
            max_entrants: workers,
            slot_tag: current_slot(),
            failures: Mutex::new(Vec::new()),
            overdue: AtomicBool::new(false),
        });
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.seq += 1;
            slot.job = Some(Arc::clone(&job));
            self.shared.work_cv.notify_all();
        }
        // The submitter works too — on small jobs it often finishes the
        // whole index space before a worker even wakes.
        run_job(&self.shared, &job);
        let deadline = Duration::from_millis(watchdog_ms());
        let mut slot = self.shared.slot.lock().unwrap();
        while job.completed.load(Ordering::Acquire) < job.n {
            // Deadline watchdog: a scatter past its deadline is flagged
            // (once) and counted, then we keep waiting — a wedged task
            // cannot be soundly re-executed (it may have started its
            // writes), so the watchdog observes rather than intervenes.
            let (s, timeout) =
                self.shared.done_cv.wait_timeout(slot, deadline).unwrap();
            slot = s;
            if timeout.timed_out()
                && job.completed.load(Ordering::Acquire) < job.n
                && !job.overdue.swap(true, Ordering::Relaxed)
            {
                watchdog_trips().inc();
                obs::event(obs::SpanKind::WatchdogTrip, job.slot_tag, 0, 0);
            }
        }
        slot.job = None;
        drop(slot);
        // Drain isolated panics outside every lock: marker-tagged
        // (injected) failures re-dispatch inline on the bounded
        // deterministic schedule; a real panic re-raises here on the
        // submitting thread — after the scatter fully drained, so no
        // worker still references `f`.
        let failures = std::mem::take(&mut *job.failures.lock().unwrap());
        if !failures.is_empty() {
            drain_failures(failures, f);
        }
        true
    }
}

/// Submitter-side failure handling (see `Crew::scatter`).  Injected
/// faults disarm on first fire, so their inline re-dispatch runs the
/// task's real work exactly once — same disjoint writes as the crew
/// path, hence bitwise-identical results; a flaky worker costs
/// throughput, never floats.
fn drain_failures(failures: Vec<TaskFailure>, f: &(dyn Fn(usize) + Sync)) {
    let mut real: Option<String> = None;
    for fail in failures {
        if fail.payload.contains(EXEC_FAULT_MARKER) {
            obs::event(obs::SpanKind::TaskRetry, fail.slot, fail.shard as u32, 0);
            call_isolated(f, fail.shard);
        } else if real.is_none() {
            real = Some(fail.payload);
        }
    }
    if let Some(payload) = real {
        std::panic::resume_unwind(Box::new(payload));
    }
}

fn worker_loop(shared: Arc<Shared>) {
    // Nested scatters submitted from inside a task run inline: the two
    // budget levels (lanes × shards) are the whole hierarchy.
    SCOPE.with(|s| *s.borrow_mut() = Scope::WorkerInline);
    let mut last_seq = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if shared.quit.load(Ordering::Acquire) {
                    return;
                }
                if slot.seq != last_seq {
                    last_seq = slot.seq;
                    if let Some(job) = slot.job.clone() {
                        break job;
                    }
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        run_job(&shared, &job);
    }
}

/// Claim and execute chunks of `job` until its index space is exhausted.
/// Whichever thread retires the final index wakes the submitter.
fn run_job(shared: &Shared, job: &Job) {
    if job.entrants.fetch_add(1, Ordering::Relaxed) >= job.max_entrants {
        return;
    }
    loop {
        let lo = job.next.fetch_add(job.chunk, Ordering::Relaxed);
        if lo >= job.n {
            break;
        }
        // SAFETY: we hold an unexecuted chunk, so `completed < n` and the
        // submitter is still blocked in `Crew::scatter` — the closure is
        // alive.  A late-waking worker on a finished job always sees
        // `lo >= n` above and never reaches this deref.
        let f = unsafe { &*job.f };
        let hi = (lo + job.chunk).min(job.n);
        for i in lo..hi {
            // Panic isolation: tasks run over disjoint chunks, so
            // catching here cannot observe broken shared invariants
            // (AssertUnwindSafe is justified by the same disjointness
            // every scatter caller already relies on).  A panicking
            // index is recorded — not re-run here: the `+=` kernels are
            // non-idempotent, so only the submitter may decide what is
            // safe to retry — and still counts toward `completed`, so
            // the scatter always drains and the worker thread survives.
            if let Err(p) = std::panic::catch_unwind(AssertUnwindSafe(|| f(i))) {
                task_failures().inc();
                obs::event(obs::SpanKind::TaskFault, job.slot_tag, i as u32, 0);
                job.failures.lock().unwrap().push(TaskFailure {
                    shard: i,
                    slot: job.slot_tag,
                    payload: payload_string(p.as_ref()),
                });
            }
        }
        let done = job.completed.fetch_add(hi - lo, Ordering::AcqRel) + (hi - lo);
        if done == job.n {
            // Lock before notifying so the wake cannot slip between the
            // submitter's predicate check and its wait.
            let _slot = shared.slot.lock().unwrap();
            shared.done_cv.notify_all();
            break;
        }
    }
}

static GLOBAL_CREW: OnceLock<Crew> = OnceLock::new();

/// The flat global crew: W − 1 parked workers (the submitter counts as
/// one), serving every scatter issued outside a shard-group scope.
/// Re-grows lazily after a [`shutdown`] drained it.
fn global_crew() -> &'static Crew {
    let crew = GLOBAL_CREW.get_or_init(Crew::new);
    crew.ensure_threads(global_workers().saturating_sub(1), "pallas-crew-global");
    crew
}

/// Pre-shutdown drain hooks.  Streaming subsystems (`sim::ingest`)
/// register a closure that flushes their in-flight work into
/// checkpointable state; [`shutdown`] runs every hook *before* the
/// crews drain, so nothing a later freeze needs is stranded in
/// lock-free buffers.  Hooks run in registration order and must be
/// idempotent (a freeze may have drained already).
fn drain_hooks() -> &'static Mutex<Vec<(u64, Arc<dyn Fn() + Send + Sync>)>> {
    static H: OnceLock<Mutex<Vec<(u64, Arc<dyn Fn() + Send + Sync>)>>> = OnceLock::new();
    H.get_or_init(|| Mutex::new(Vec::new()))
}

/// Register a drain hook; returns an id for [`unregister_drain_hook`].
pub fn register_drain_hook(hook: Box<dyn Fn() + Send + Sync>) -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let id = NEXT.fetch_add(1, Ordering::Relaxed);
    drain_hooks().lock().unwrap().push((id, Arc::from(hook)));
    id
}

/// Remove a hook registered by [`register_drain_hook`] (owners do this
/// on drop so a dead subsystem is never drained).
pub fn unregister_drain_hook(id: u64) {
    drain_hooks().lock().unwrap().retain(|(i, _)| *i != id);
}

/// Run every registered drain hook in registration order.  Called by
/// [`shutdown`]; checkpoint paths may call it directly to flush
/// in-flight ingest state before a freeze.  Hooks are cloned out of
/// the registry first and run unlocked, so a hook (or a concurrent
/// drop) may (un)register without deadlocking.
pub fn run_drain_hooks() {
    let hooks: Vec<Arc<dyn Fn() + Send + Sync>> = {
        let reg = drain_hooks().lock().unwrap();
        reg.iter().map(|(_, h)| Arc::clone(h)).collect()
    };
    for hook in hooks {
        hook();
    }
}

/// Cleanly drain every parked worker thread — the global crew and all
/// recycled shard-group crews — joining them so test harnesses and
/// embedding processes don't leak parked threads between runs.  Crews
/// stay registered: the next scatter or group lease respawns workers on
/// demand (and until then scatters degrade to inline execution, which
/// is always correct).  Must not be called while a scatter is in
/// flight; the quit flag is only checked between jobs, so in-flight
/// work completes first.  Drain hooks run first (see [`drain_hooks`]):
/// in-flight ingest batches land in checkpointable state before the
/// worker threads go away.
pub fn shutdown() {
    run_drain_hooks();
    if let Some(crew) = GLOBAL_CREW.get() {
        crew.drain();
    }
    let crews: Vec<Arc<Crew>> = {
        let reg = group_registry().lock().unwrap();
        reg.iter().map(Arc::clone).collect()
    };
    for crew in crews {
        crew.drain();
    }
}

/// Where this thread's scatters dispatch (see module docs).
#[derive(Clone)]
enum Scope {
    /// Plain thread: the global crew.
    Global,
    /// Crew worker thread: nested scatters run inline.
    WorkerInline,
    /// Lane driver inside [`ShardGroup::run`]: the leased crew, capped
    /// at the group's size.
    Group(Arc<Crew>, usize),
}

thread_local! {
    static SCOPE: RefCell<Scope> = RefCell::new(Scope::Global);
}

/// True when the calling thread is already inside a scatter (a crew
/// worker or a shard-group lane): callers that would lease sub-groups
/// should fan out over the enclosing scope instead — there is no third
/// level.
pub fn nested_scope() -> bool {
    SCOPE.with(|s| !matches!(&*s.borrow(), Scope::Global))
}

/// "pool.group_scatters" — scatters dispatched onto leased group crews
/// since process start: the observable proving that budgeted nested
/// parallelism actually executed on group workers instead of silently
/// degrading to inline (asserted by the shard-parity suite).
/// Registry-backed, same pattern as [`task_failures`].
fn group_scatters() -> &'static obs::Counter {
    static C: OnceLock<Arc<obs::Counter>> = OnceLock::new();
    C.get_or_init(|| obs::registry().counter("pool.group_scatters"))
}

/// See [`group_scatters`].
pub fn group_scatter_count() -> usize {
    group_scatters().get() as usize
}

/// A leased shard group: a private crew granting `size` workers (the
/// lane driver counts as one, so the crew parks `size − 1` threads) to
/// every scatter issued inside [`ShardGroup::run`].  Leases recycle
/// through a process-wide registry — steady-state cost is a mutex pop,
/// not thread spawns.
pub struct ShardGroup {
    crew: Arc<Crew>,
    size: usize,
}

fn group_registry() -> &'static Mutex<Vec<Arc<Crew>>> {
    static REG: OnceLock<Mutex<Vec<Arc<Crew>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

impl ShardGroup {
    /// Lease a group able to run `size`-wide scatters, growing a
    /// recycled crew's thread set if needed.
    pub fn lease(size: usize) -> ShardGroup {
        let crew = group_registry()
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| Arc::new(Crew::new()));
        crew.ensure_threads(size.saturating_sub(1), "pallas-crew-group");
        ShardGroup { crew, size }
    }

    /// Run `f` with this group as the thread's scatter target; the
    /// previous scope is restored afterwards (also on unwind).
    pub fn run<T>(&self, f: impl FnOnce() -> T) -> T {
        let prev = SCOPE.with(|s| {
            std::mem::replace(
                &mut *s.borrow_mut(),
                Scope::Group(Arc::clone(&self.crew), self.size),
            )
        });
        let _restore = ScopeRestore(Some(prev));
        f()
    }
}

impl Drop for ShardGroup {
    fn drop(&mut self) {
        group_registry().lock().unwrap().push(Arc::clone(&self.crew));
    }
}

struct ScopeRestore(Option<Scope>);

impl Drop for ScopeRestore {
    fn drop(&mut self) {
        if let Some(prev) = self.0.take() {
            let _ = SCOPE.try_with(|s| *s.borrow_mut() = prev);
        }
    }
}

/// One leased group per concurrent lane, plus a free-stack handing a
/// group to whichever lane task runs next.  The lane→group assignment
/// is scheduling-dependent; results are not (disjoint work per lane).
struct GroupSet {
    groups: Vec<ShardGroup>,
    free: Mutex<Vec<usize>>,
}

impl GroupSet {
    fn lease(budget: ExecBudget) -> GroupSet {
        let groups: Vec<ShardGroup> =
            (0..budget.runs.max(1)).map(|_| ShardGroup::lease(budget.shards)).collect();
        let free = Mutex::new((0..groups.len()).collect());
        GroupSet { groups, free }
    }

    fn run<T>(&self, f: impl FnOnce() -> T) -> T {
        // Never fails: the enclosing scatter admits at most `runs`
        // concurrent lane tasks and we leased exactly `runs` groups.
        let gi = self
            .free
            .lock()
            .unwrap()
            .pop()
            .expect("GroupSet: more concurrent lanes than leased groups");
        // Return the group on unwind too (a panicking lane task — e.g.
        // a strict-mode leader assert — must not starve later lanes
        // into the misleading expect above).
        struct Return<'a>(&'a GroupSet, usize);
        impl Drop for Return<'_> {
            fn drop(&mut self) {
                self.0.free.lock().unwrap().push(self.1);
            }
        }
        let ret = Return(self, gi);
        self.groups[ret.1].run(f)
    }
}

/// Run `f(i)` for every `i in 0..n`, in parallel over up to `workers`
/// threads of the scope's crew (the submitting thread counts as one).
/// `f` must be `Sync` (interior mutability / disjoint writes are the
/// caller's responsibility — see `for_each_mut_chunks` for slice
/// output).  Dispatch follows the thread's scope: global crew, leased
/// shard group (capped at the group size), or inline on crew workers.
pub fn parallel_for<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.min(n).max(1);
    let scope = SCOPE.with(|s| s.borrow().clone());
    // Every inline arm routes through `call_isolated`, so an injected
    // execution fault is survived identically at any worker budget —
    // including budget 1, where no crew ever runs.
    match scope {
        Scope::WorkerInline => {
            for i in 0..n {
                call_isolated(&f, i);
            }
        }
        Scope::Group(crew, size) => {
            if crew.scatter(n, workers.min(size), &f) {
                group_scatters().inc();
            } else {
                for i in 0..n {
                    call_isolated(&f, i);
                }
            }
        }
        Scope::Global => {
            if !global_crew().scatter(n, workers, &f) {
                for i in 0..n {
                    call_isolated(&f, i);
                }
            }
        }
    }
}

/// Parallel map over `0..n` producing a Vec<T> in index order.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SyncSlice::new(&mut out);
        parallel_for(n, workers, |i| {
            // SAFETY: each index written exactly once by exactly one task.
            unsafe { slots.write(i, f(i)) };
        });
    }
    out
}

/// Parallel map over a mutable slice: run `f(i, &mut items[i])` for
/// every index, collecting the results in index order.  Each item is
/// visited by exactly one worker, so `f` gets exclusive access — this
/// is the fan-out primitive for independent stateful tasks (e.g. one
/// scheduler run per policy in `coordinator::run_lineup`).
pub fn parallel_map_mut<T, U, F>(items: &mut [T], workers: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send + Default + Clone,
    F: Fn(usize, &mut T) -> U + Sync,
{
    let n = items.len();
    let mut out = vec![U::default(); n];
    if n == 0 {
        return out;
    }
    {
        let slots = SyncSlice::new(&mut out);
        let base = SyncSlice::new(items);
        parallel_for(n, workers.min(n).max(1), |i| {
            // SAFETY: parallel_for hands each index to exactly one task,
            // so item i and output slot i are touched by one thread.
            let item = unsafe { &mut base.slice_mut(i, i + 1)[0] };
            unsafe { slots.write(i, f(i, item)) };
        });
    }
    out
}

/// Budgeted two-level map over `0..n`: up to `budget.runs` concurrent
/// lanes, each running `f` inside a private `budget.shards`-wide
/// [`ShardGroup`] so the *nested* scatters `f` issues fan out instead
/// of degrading to inline execution.  Falls back to a flat
/// [`parallel_map`] when the resolved budget grants one worker per lane
/// or the caller is itself already inside a scatter scope.
pub fn scatter_map<T, F>(n: usize, budget: ExecBudget, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let b = budget.resolve(n);
    if b.shards <= 1 || nested_scope() {
        return parallel_map(n, b.runs, f);
    }
    let lanes = GroupSet::lease(b);
    parallel_map(n, b.runs, |i| lanes.run(|| f(i)))
}

/// Budgeted two-level variant of [`parallel_map_mut`] — the
/// `run_lineup` primitive: each item's task owns a private shard group
/// per the budget split.  See [`scatter_map`] for the fallbacks.
pub fn scatter_runs<T, U, F>(items: &mut [T], budget: ExecBudget, f: F) -> Vec<U>
where
    T: Send,
    U: Send + Default + Clone,
    F: Fn(usize, &mut T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let b = budget.resolve(n);
    if b.shards <= 1 || nested_scope() {
        return parallel_map_mut(items, b.runs, f);
    }
    let lanes = GroupSet::lease(b);
    parallel_map_mut(items, b.runs, |i, item| lanes.run(|| f(i, item)))
}

/// Scatter-gather over per-shard worker states: run `f(s, &mut
/// shards[s])` for every shard concurrently and return once all have
/// finished.  This is the single-slot fan-out primitive of
/// `coordinator::sharded`: the caller owns one long-lived state per
/// shard (ledger + scratch), so the steady-state dispatch allocates
/// nothing beyond the crew's one refcounted job header — results land
/// in the shard states, not in a fresh output Vec.  Inside a budgeted
/// lineup lane this dispatches to the lane's private shard group.
pub fn parallel_shards<T, F>(shards: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = shards.len();
    if n == 0 {
        return;
    }
    if n == 1 {
        // same isolation as the scattered path: a single-shard commit
        // with an injected fault retries instead of aborting
        run_isolated(|| f(0, &mut shards[0]));
        return;
    }
    let base = SyncSlice::new(shards);
    parallel_for(n, n, |s| {
        // SAFETY: parallel_for hands each index to exactly one task, so
        // shard state s is touched by exactly one thread.
        let shard = unsafe { &mut base.slice_mut(s, s + 1)[0] };
        f(s, shard);
    });
}

/// Split `data` into `chunks` contiguous mutable pieces and run
/// `f(chunk_index, start_offset, piece)` on each in parallel.
pub fn for_each_mut_chunks<T, F>(data: &mut [T], chunks: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let chunks = chunks.min(n).max(1);
    let chunk = n.div_ceil(chunks);
    let pieces = n.div_ceil(chunk);
    let base = SyncSlice::new(data);
    parallel_for(pieces, pieces, |i| {
        let lo = i * chunk;
        let hi = (lo + chunk).min(n);
        // SAFETY: pieces are disjoint ranges of the original slice, and
        // each piece index runs exactly once.
        let piece = unsafe { base.slice_mut(lo, hi) };
        f(i, lo, piece);
    });
}

/// A shared wrapper allowing disjoint-index writes into a slice from
/// multiple threads.  Callers must guarantee indices don't collide.
/// Crate-visible: the sharded coordinator and the OGA shard step use it
/// for their disjoint-ownership scatters (safety argued at each site).
pub(crate) struct SyncSlice<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Sync for SyncSlice<T> {}
unsafe impl<T: Send> Send for SyncSlice<T> {}

impl<T> SyncSlice<T> {
    pub(crate) fn new(slice: &mut [T]) -> Self {
        SyncSlice { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// SAFETY: caller guarantees `i < len` and that no two threads write
    /// the same index.
    pub(crate) unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        unsafe { self.ptr.add(i).write(value) };
    }

    /// SAFETY: caller guarantees `lo <= hi <= len` and that ranges
    /// handed out to concurrent users are disjoint.
    pub(crate) unsafe fn slice_mut(&self, lo: usize, hi: usize) -> &mut [T] {
        debug_assert!(lo <= hi && hi <= self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits = AtomicUsize::new(0);
        parallel_for(1000, 8, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn repeated_jobs_reuse_the_pool() {
        // the crew must stay consistent across many submissions
        for round in 0..50 {
            let hits = AtomicUsize::new(0);
            parallel_for(97 + round, 4, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 97 + round);
        }
    }

    #[test]
    fn concurrent_submitters_fall_back_inline() {
        // two threads submitting at once: one owns the global crew, the
        // other must run inline — both complete all indices
        let a = AtomicUsize::new(0);
        let b = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                parallel_for(10_000, 8, |_| {
                    a.fetch_add(1, Ordering::Relaxed);
                })
            });
            s.spawn(|| {
                parallel_for(10_000, 8, |_| {
                    b.fetch_add(1, Ordering::Relaxed);
                })
            });
        });
        assert_eq!(a.load(Ordering::Relaxed), 10_000);
        assert_eq!(b.load(Ordering::Relaxed), 10_000);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(257, 7, |i| i * 3);
        assert_eq!(out, (0..257).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_mut_mutates_and_collects() {
        let mut items: Vec<usize> = (0..123).collect();
        let out = parallel_map_mut(&mut items, 6, |i, item| {
            *item += 1;
            i * 2
        });
        assert_eq!(items, (1..124).collect::<Vec<_>>());
        assert_eq!(out, (0..123).map(|i| i * 2).collect::<Vec<_>>());
        // empty input is a no-op
        let mut empty: Vec<usize> = Vec::new();
        let out: Vec<usize> = parallel_map_mut(&mut empty, 4, |_, _| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_shards_gives_each_state_to_one_worker() {
        struct Shard {
            hits: usize,
            sum: usize,
        }
        let mut shards: Vec<Shard> =
            (0..7).map(|_| Shard { hits: 0, sum: 0 }).collect();
        for round in 0..20 {
            parallel_shards(&mut shards, |s, shard| {
                shard.hits += 1;
                shard.sum += s;
            });
            for (s, shard) in shards.iter().enumerate() {
                assert_eq!(shard.hits, round + 1);
                assert_eq!(shard.sum, (round + 1) * s);
            }
        }
        // degenerate shapes
        let mut empty: Vec<Shard> = Vec::new();
        parallel_shards(&mut empty, |_, _| unreachable!());
        let mut one = vec![Shard { hits: 0, sum: 0 }];
        parallel_shards(&mut one, |_, shard| shard.hits += 1);
        assert_eq!(one[0].hits, 1);
    }

    #[test]
    fn chunked_mut_writes_disjoint() {
        let mut data = vec![0usize; 100];
        for_each_mut_chunks(&mut data, 6, |_, off, piece| {
            for (j, v) in piece.iter_mut().enumerate() {
                *v = off + j;
            }
        });
        assert_eq!(data, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_degrades_to_serial() {
        let out = parallel_map(10, 1, |i| i);
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn zero_tasks_is_noop() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn drain_hooks_run_in_order_and_unregister() {
        // Counters, not an exact log: other tests may legitimately call
        // run_drain_hooks concurrently (it is process-global), and every
        // caller runs our hooks too — assertions must survive that.
        let a_runs = Arc::new(AtomicUsize::new(0));
        let b_runs = Arc::new(AtomicUsize::new(0));
        let order_ok = Arc::new(AtomicBool::new(true));
        let ida = register_drain_hook(Box::new({
            let a = Arc::clone(&a_runs);
            move || {
                a.fetch_add(1, Ordering::SeqCst);
            }
        }));
        let checking = Arc::new(AtomicBool::new(true));
        let idb = register_drain_hook(Box::new({
            let (a, b) = (Arc::clone(&a_runs), Arc::clone(&b_runs));
            let (ok, on) = (Arc::clone(&order_ok), Arc::clone(&checking));
            move || {
                // registration order: while both hooks are registered,
                // every pass runs `a` before `b`, so at `b`'s entry
                // completed-a must outnumber entered-b
                let nb = b.fetch_add(1, Ordering::SeqCst);
                if on.load(Ordering::SeqCst) && a.load(Ordering::SeqCst) < nb + 1 {
                    ok.store(false, Ordering::SeqCst);
                }
            }
        }));
        run_drain_hooks();
        assert!(a_runs.load(Ordering::SeqCst) >= 1);
        assert!(b_runs.load(Ordering::SeqCst) >= 1);
        assert!(order_ok.load(Ordering::SeqCst), "hooks must run in registration order");
        checking.store(false, Ordering::SeqCst);
        unregister_drain_hook(ida);
        let frozen = a_runs.load(Ordering::SeqCst);
        run_drain_hooks();
        assert_eq!(a_runs.load(Ordering::SeqCst), frozen, "unregistered hook ran");
        assert!(b_runs.load(Ordering::SeqCst) >= 2);
        assert!(order_ok.load(Ordering::SeqCst), "hooks must run in registration order");
        unregister_drain_hook(idb);
        // unregistering an unknown id is a no-op
        unregister_drain_hook(ida);
        run_drain_hooks();
    }

    #[test]
    fn budget_resolution_is_deterministic_and_bounded() {
        let w = global_workers();
        // auto split never oversubscribes and is idempotent
        for n in [1usize, 2, 5, 64] {
            let b = ExecBudget::auto().resolve(n);
            assert!(b.runs >= 1 && b.shards >= 1);
            assert!(b.runs <= n.max(1));
            if configured_runs().is_none() {
                assert!(b.runs * b.shards <= w.max(1), "{b:?} oversubscribes W={w}");
            }
            assert_eq!(b.resolve(n), b, "resolve must be idempotent");
        }
        // explicit fields are honored (clamped to the lane count only)
        let b = ExecBudget::split(2, 3).resolve(5);
        assert_eq!(b, ExecBudget::split(2, 3));
        assert_eq!(ExecBudget::split(8, 2).resolve(3).runs, 3);
        assert_eq!(ExecBudget::serial().resolve(9), ExecBudget::split(1, 1));
        // a legacy `workers = N` budget (explicit shards, auto runs)
        // caps the derived lane count so the split still fits W
        let b = ExecBudget { runs: 0, shards: 3 }.resolve(5);
        assert_eq!(b.shards, 3);
        if configured_runs().is_none() {
            assert_eq!(b.runs, (w / 3).max(1).min(5));
        }
        // legacy workers-int bridge
        assert_eq!(ExecBudget::from(4usize), ExecBudget::shards_only(4));
        assert_eq!(ExecBudget::shards_only(4).run_shards(), 4);
        assert_eq!(ExecBudget::auto().run_shards(), w);
    }

    #[test]
    fn scatter_runs_composes_lanes_and_groups() {
        // 4 items under an explicit 2×2 split: every item's nested
        // scatter must execute on its lane's private group (counted by
        // the pool.group_scatters counter), never silently inline, and
        // all indices of both levels must run exactly once.
        let before = group_scatter_count();
        let mut items = vec![0usize; 4];
        let inner_hits = AtomicUsize::new(0);
        let out = scatter_runs(&mut items, ExecBudget::split(2, 2), |i, item| {
            *item = i + 1;
            parallel_for(100, 2, |_| {
                inner_hits.fetch_add(1, Ordering::Relaxed);
            });
            i * 10
        });
        assert_eq!(out, vec![0, 10, 20, 30]);
        assert_eq!(items, vec![1, 2, 3, 4]);
        assert_eq!(inner_hits.load(Ordering::Relaxed), 400);
        assert!(
            group_scatter_count() >= before + 4,
            "nested scatters must dispatch to the leased groups, not inline"
        );
    }

    #[test]
    fn scatter_map_matches_serial_and_recycles_groups() {
        for round in 0..3 {
            let out = scatter_map(9, ExecBudget::split(3, 2), |i| {
                let part: Vec<usize> = parallel_map(8, 2, |j| i * 8 + j);
                part.iter().sum::<usize>()
            });
            let want: Vec<usize> =
                (0..9).map(|i| (0..8).map(|j| i * 8 + j).sum()).collect();
            assert_eq!(out, want, "round {round}");
        }
    }

    #[test]
    fn third_level_scatters_run_inline_but_complete() {
        // a scatter issued from inside a group worker's task has no
        // third budget level: it must run inline and still cover all
        // indices
        let hits = AtomicUsize::new(0);
        let mut items = vec![(); 2];
        scatter_runs(&mut items, ExecBudget::split(2, 2), |_, _| {
            parallel_for(4, 2, |_| {
                // third level: inline by scope
                parallel_for(25, 4, |_| {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 2 * 4 * 25);
    }

    #[test]
    fn injected_fault_is_retried_without_aborting_or_losing_indices() {
        // one index is armed to panic (with the marker) on its first
        // execution only — the isolation layer must retry it and the
        // scatter must still cover every index exactly once in the
        // output, whatever worker budget actually ran
        use std::sync::atomic::AtomicBool;
        for workers in [1usize, 2, 4, 8] {
            let armed = AtomicBool::new(true);
            let before = task_failure_count();
            let out = parallel_map(64, workers, |i| {
                if i == 7 && armed.swap(false, Ordering::Relaxed) {
                    panic!("{EXEC_FAULT_MARKER}: test fault at index 7");
                }
                i * 3
            });
            assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
            assert!(
                task_failure_count() > before,
                "the injected panic must be recorded, workers={workers}"
            );
        }
    }

    #[test]
    fn run_isolated_retries_one_shot_faults() {
        let probe = ExecProbe::new(
            [(3u64, 0u32)].into_iter().collect(),
            std::collections::BTreeSet::new(),
            0,
        );
        // armed (slot 3, shard 0): fires once, retry succeeds
        let v = run_isolated(|| {
            probe.fire(3, 0);
            41 + 1
        });
        assert_eq!(v, 42);
        assert_eq!(probe.fired_count(), 1);
        // unarmed coordinates never fire
        probe.fire(3, 0);
        probe.fire(4, 1);
        assert_eq!(probe.fired_count(), 1);
    }

    #[test]
    fn stall_probe_is_caught_and_retried() {
        let probe = ExecProbe::new(
            std::collections::BTreeSet::new(),
            [(0u64, 0u32)].into_iter().collect(),
            10,
        );
        let hits = AtomicUsize::new(0);
        run_isolated(|| {
            probe.fire(0, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(probe.fired_count(), 1);
        // the stall panicked before the increment; only the clean
        // retry executed the real work
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    #[should_panic(expected = "a real bug")]
    fn real_panics_still_propagate_to_the_submitter() {
        parallel_for(32, 4, |i| {
            if i == 11 {
                panic!("a real bug at index {i}");
            }
        });
    }

    #[test]
    fn worker_survives_a_task_panic() {
        // after a real panic drained through a scatter, the crew's
        // workers must still be alive and serving later scatters
        let r = std::panic::catch_unwind(|| {
            parallel_for(16, 4, |i| {
                if i == 3 {
                    panic!("one bad task");
                }
            })
        });
        assert!(r.is_err());
        let hits = AtomicUsize::new(0);
        parallel_for(500, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn watchdog_flags_overdue_scatters() {
        // tighten the deadline (read per scatter), stall one index past
        // it, and require a trip to be counted; the scatter still
        // completes with every index run
        std::env::set_var("PALLAS_WATCHDOG_MS", "25");
        let before = watchdog_trip_count();
        let hits = AtomicUsize::new(0);
        parallel_for(4, 2, |i| {
            if i == 0 {
                std::thread::sleep(Duration::from_millis(120));
            }
            hits.fetch_add(1, Ordering::Relaxed);
        });
        std::env::remove_var("PALLAS_WATCHDOG_MS");
        assert_eq!(hits.load(Ordering::Relaxed), 4);
        // the sleeping index may have run on the submitter itself (in
        // which case the submitter never waited); only require a trip
        // when the wait actually timed out — but with 2 workers and the
        // chunk cursor, some scatter of the loop below must trip.
        let mut tripped = watchdog_trip_count() > before;
        if !tripped {
            std::env::set_var("PALLAS_WATCHDOG_MS", "25");
            for _ in 0..4 {
                parallel_for(64, 4, |i| {
                    if i == 63 {
                        std::thread::sleep(Duration::from_millis(120));
                    }
                });
                if watchdog_trip_count() > before {
                    tripped = true;
                    break;
                }
            }
            std::env::remove_var("PALLAS_WATCHDOG_MS");
        }
        assert!(tripped, "an overdue scatter must trip the watchdog");
    }

    #[test]
    fn shutdown_drains_and_scatters_still_complete() {
        // prime the pool, drain it, then prove later scatters still
        // cover all indices (respawn or inline) and shutdown is
        // idempotent
        let hits = AtomicUsize::new(0);
        parallel_for(100, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        shutdown();
        shutdown();
        let hits = AtomicUsize::new(0);
        parallel_for(100, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn failure_reports_carry_slot_and_shard() {
        // drive a marker fault through the crew path and check the
        // TaskFailure surface via the counters + the slot tag round
        // trip (the structured record itself is consumed by the drain)
        set_slot(1234);
        let before = task_failure_count();
        use std::sync::atomic::AtomicBool;
        let armed = AtomicBool::new(true);
        parallel_for(32, 4, |i| {
            if i == 5 && armed.swap(false, Ordering::Relaxed) {
                panic!("{EXEC_FAULT_MARKER}: at slot {}", 1234);
            }
        });
        assert!(task_failure_count() > before);
        set_slot(0);
    }

    #[test]
    fn explicit_budget_engages_groups_even_on_small_machines() {
        // explicit splits are honored regardless of PALLAS_WORKERS /
        // core count — the lease spawns the group threads it needs
        let before = group_scatter_count();
        let hits = AtomicUsize::new(0);
        let mut items = vec![(); 1];
        scatter_runs(&mut items, ExecBudget::split(1, 3), |_, _| {
            parallel_for(30, 3, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), 30);
        assert!(group_scatter_count() > before);
    }
}
