//! Persistent worker pool (no rayon in the offline image).
//!
//! Algorithm 1's projection is "for each (r, k) do in parallel".  The
//! seed provided that parallelism with `std::thread::scope`, which pays
//! ~100µs of spawn/join per worker per call — more than the projection
//! itself on mid-sized problems (measured in
//! benches/ablation_projection.rs, recorded in EXPERIMENTS.md §Perf).
//! This module keeps one process-wide pool of parked workers instead:
//! a call publishes a job (type-erased closure + atomic chunk cursor),
//! wakes the workers, participates in the work itself, and blocks until
//! every index has executed.  Steady-state dispatch cost is one mutex
//! round-trip plus condvar wakes — single-digit microseconds.
//!
//! Work is chunked dynamically (atomic `fetch_add` on a shared cursor in
//! chunks of ~n/4·workers), which keeps near-uniform projection tasks
//! balanced without a work-stealing deque.  Concurrent submitters (e.g.
//! parallel test threads) do not queue: whoever arrives second runs its
//! loop inline on its own thread, which is always correct and avoids
//! nested-job deadlocks by construction.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Process-wide parallelism budget: `PALLAS_WORKERS` when set to a
/// positive integer (CI pins it so small runners still exercise the
/// multi-worker paths deterministically), otherwise the machine's
/// available parallelism.  Read once — the pool is sized from it.
fn configured_parallelism() -> usize {
    static CONF: OnceLock<usize> = OnceLock::new();
    *CONF.get_or_init(|| {
        std::env::var("PALLAS_WORKERS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Number of worker threads to use for `n_tasks` independent tasks.
pub fn default_workers(n_tasks: usize) -> usize {
    configured_parallelism().min(n_tasks).max(1)
}

/// One published parallel-for job.
struct Job {
    /// Type-erased pointer to the caller's closure.  Only dereferenced
    /// while the submitting thread is blocked inside `parallel_for`, so
    /// the pointee outlives every use (raw pointers carry no lifetime).
    f: *const (dyn Fn(usize) + Sync),
    /// Next unclaimed index (claimed in `chunk`-sized strides).
    next: AtomicUsize,
    /// Indices fully executed; the job is done when this reaches `n`.
    completed: AtomicUsize,
    /// Pool threads that joined; capped at `max_entrants` so a caller's
    /// `workers` budget is honored even when the pool is larger.
    entrants: AtomicUsize,
    n: usize,
    chunk: usize,
    max_entrants: usize,
}

// SAFETY: `f` points at a `Sync` closure owned by the submitting thread,
// which blocks until `completed == n`; workers never touch `f` after
// their final chunk completes.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct Slot {
    /// Bumped once per published job so parked workers can tell a new
    /// job from the one they already ran.
    seq: u64,
    job: Option<Arc<Job>>,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Workers park here waiting for `seq` to move.
    work_cv: Condvar,
    /// The submitter parks here waiting for `completed == n`.
    done_cv: Condvar,
}

struct Pool {
    shared: Arc<Shared>,
    /// Serializes submissions; `try_lock` losers run inline instead of
    /// queueing (see module docs).
    submit: Mutex<()>,
    /// Parked worker threads (detached; they live for the process).
    pool_threads: usize,
}

fn worker_loop(shared: Arc<Shared>) {
    let mut last_seq = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.seq != last_seq {
                    last_seq = slot.seq;
                    if let Some(job) = slot.job.clone() {
                        break job;
                    }
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        run_job(&shared, &job);
    }
}

/// Claim and execute chunks of `job` until its index space is exhausted.
/// Whichever thread retires the final index wakes the submitter.
fn run_job(shared: &Shared, job: &Job) {
    if job.entrants.fetch_add(1, Ordering::Relaxed) >= job.max_entrants {
        return;
    }
    loop {
        let lo = job.next.fetch_add(job.chunk, Ordering::Relaxed);
        if lo >= job.n {
            break;
        }
        // SAFETY: we hold an unexecuted chunk, so `completed < n` and the
        // submitter is still blocked in `parallel_for` — the closure is
        // alive.  A late-waking worker on a finished job always sees
        // `lo >= n` above and never reaches this deref.
        let f = unsafe { &*job.f };
        let hi = (lo + job.chunk).min(job.n);
        for i in lo..hi {
            f(i);
        }
        let done = job.completed.fetch_add(hi - lo, Ordering::AcqRel) + (hi - lo);
        if done == job.n {
            // Lock before notifying so the wake cannot slip between the
            // submitter's predicate check and its wait.
            let _slot = shared.slot.lock().unwrap();
            shared.done_cv.notify_all();
            break;
        }
    }
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot { seq: 0, job: None }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        // The submitter participates, so spawn cores − 1 parked workers.
        let pool_threads = default_workers(usize::MAX).saturating_sub(1);
        for i in 0..pool_threads {
            let shared = Arc::clone(&shared);
            let _ = std::thread::Builder::new()
                .name(format!("ogasched-pool-{i}"))
                .spawn(move || worker_loop(shared));
        }
        Pool { shared, submit: Mutex::new(()), pool_threads }
    })
}

/// Run `f(i)` for every `i in 0..n`, in parallel over up to `workers`
/// threads of the persistent pool (the submitting thread counts as one).
/// `f` must be `Sync` (interior mutability / disjoint writes are the
/// caller's responsibility — see `for_each_mut_chunks` for slice output).
pub fn parallel_for<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.min(n).max(1);
    let pool = pool();
    if workers == 1 || pool.pool_threads == 0 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    // Second concurrent submitter (or a nested call from inside a job)
    // runs inline rather than waiting for the pool.
    let Ok(_submit) = pool.submit.try_lock() else {
        for i in 0..n {
            f(i);
        }
        return;
    };
    let f_ref: &(dyn Fn(usize) + Sync) = &f;
    let job = Arc::new(Job {
        f: f_ref as *const (dyn Fn(usize) + Sync),
        next: AtomicUsize::new(0),
        completed: AtomicUsize::new(0),
        entrants: AtomicUsize::new(0),
        n,
        chunk: n.div_ceil(workers * 4).max(1),
        // total entrants: the submitting thread plus pool threads
        max_entrants: workers,
    });
    {
        let mut slot = pool.shared.slot.lock().unwrap();
        slot.seq += 1;
        slot.job = Some(Arc::clone(&job));
        pool.shared.work_cv.notify_all();
    }
    // The submitter works too — on small jobs it often finishes the
    // whole index space before a worker even wakes.
    run_job(&pool.shared, &job);
    let mut slot = pool.shared.slot.lock().unwrap();
    while job.completed.load(Ordering::Acquire) < job.n {
        slot = pool.shared.done_cv.wait(slot).unwrap();
    }
    slot.job = None;
}

/// Parallel map over `0..n` producing a Vec<T> in index order.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SyncSlice::new(&mut out);
        parallel_for(n, workers, |i| {
            // SAFETY: each index written exactly once by exactly one task.
            unsafe { slots.write(i, f(i)) };
        });
    }
    out
}

/// Parallel map over a mutable slice: run `f(i, &mut items[i])` for
/// every index, collecting the results in index order.  Each item is
/// visited by exactly one worker, so `f` gets exclusive access — this
/// is the fan-out primitive for independent stateful tasks (e.g. one
/// scheduler run per policy in `coordinator::run_lineup`).
pub fn parallel_map_mut<T, U, F>(items: &mut [T], workers: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send + Default + Clone,
    F: Fn(usize, &mut T) -> U + Sync,
{
    let n = items.len();
    let mut out = vec![U::default(); n];
    if n == 0 {
        return out;
    }
    {
        let slots = SyncSlice::new(&mut out);
        let base = SyncSlice::new(items);
        parallel_for(n, workers.min(n).max(1), |i| {
            // SAFETY: parallel_for hands each index to exactly one task,
            // so item i and output slot i are touched by one thread.
            let item = unsafe { &mut base.slice_mut(i, i + 1)[0] };
            unsafe { slots.write(i, f(i, item)) };
        });
    }
    out
}

/// Scatter-gather over per-shard worker states: run `f(s, &mut
/// shards[s])` for every shard concurrently on the persistent pool and
/// return once all have finished.  This is the single-slot fan-out
/// primitive of `coordinator::sharded`: the caller owns one long-lived
/// state per shard (ledger + scratch), so the steady-state dispatch
/// allocates nothing beyond the pool's one refcounted job header —
/// results land in the shard states, not in a fresh output Vec.
pub fn parallel_shards<T, F>(shards: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = shards.len();
    if n == 0 {
        return;
    }
    if n == 1 {
        f(0, &mut shards[0]);
        return;
    }
    let base = SyncSlice::new(shards);
    parallel_for(n, n, |s| {
        // SAFETY: parallel_for hands each index to exactly one task, so
        // shard state s is touched by exactly one thread.
        let shard = unsafe { &mut base.slice_mut(s, s + 1)[0] };
        f(s, shard);
    });
}

/// Split `data` into `chunks` contiguous mutable pieces and run
/// `f(chunk_index, start_offset, piece)` on each in parallel.
pub fn for_each_mut_chunks<T, F>(data: &mut [T], chunks: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let chunks = chunks.min(n).max(1);
    let chunk = n.div_ceil(chunks);
    let pieces = n.div_ceil(chunk);
    let base = SyncSlice::new(data);
    parallel_for(pieces, pieces, |i| {
        let lo = i * chunk;
        let hi = (lo + chunk).min(n);
        // SAFETY: pieces are disjoint ranges of the original slice, and
        // each piece index runs exactly once.
        let piece = unsafe { base.slice_mut(lo, hi) };
        f(i, lo, piece);
    });
}

/// A shared wrapper allowing disjoint-index writes into a slice from
/// multiple threads.  Callers must guarantee indices don't collide.
/// Crate-visible: the sharded coordinator and the OGA shard step use it
/// for their disjoint-ownership scatters (safety argued at each site).
pub(crate) struct SyncSlice<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Sync for SyncSlice<T> {}
unsafe impl<T: Send> Send for SyncSlice<T> {}

impl<T> SyncSlice<T> {
    pub(crate) fn new(slice: &mut [T]) -> Self {
        SyncSlice { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// SAFETY: caller guarantees `i < len` and that no two threads write
    /// the same index.
    pub(crate) unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        unsafe { self.ptr.add(i).write(value) };
    }

    /// SAFETY: caller guarantees `lo <= hi <= len` and that ranges
    /// handed out to concurrent users are disjoint.
    pub(crate) unsafe fn slice_mut(&self, lo: usize, hi: usize) -> &mut [T] {
        debug_assert!(lo <= hi && hi <= self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits = AtomicUsize::new(0);
        parallel_for(1000, 8, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn repeated_jobs_reuse_the_pool() {
        // the pool must stay consistent across many submissions
        for round in 0..50 {
            let hits = AtomicUsize::new(0);
            parallel_for(97 + round, 4, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 97 + round);
        }
    }

    #[test]
    fn concurrent_submitters_fall_back_inline() {
        // two threads submitting at once: one owns the pool, the other
        // must run inline — both complete all indices
        let a = AtomicUsize::new(0);
        let b = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                parallel_for(10_000, 8, |_| {
                    a.fetch_add(1, Ordering::Relaxed);
                })
            });
            s.spawn(|| {
                parallel_for(10_000, 8, |_| {
                    b.fetch_add(1, Ordering::Relaxed);
                })
            });
        });
        assert_eq!(a.load(Ordering::Relaxed), 10_000);
        assert_eq!(b.load(Ordering::Relaxed), 10_000);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(257, 7, |i| i * 3);
        assert_eq!(out, (0..257).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_mut_mutates_and_collects() {
        let mut items: Vec<usize> = (0..123).collect();
        let out = parallel_map_mut(&mut items, 6, |i, item| {
            *item += 1;
            i * 2
        });
        assert_eq!(items, (1..124).collect::<Vec<_>>());
        assert_eq!(out, (0..123).map(|i| i * 2).collect::<Vec<_>>());
        // empty input is a no-op
        let mut empty: Vec<usize> = Vec::new();
        let out: Vec<usize> = parallel_map_mut(&mut empty, 4, |_, _| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_shards_gives_each_state_to_one_worker() {
        struct Shard {
            hits: usize,
            sum: usize,
        }
        let mut shards: Vec<Shard> =
            (0..7).map(|_| Shard { hits: 0, sum: 0 }).collect();
        for round in 0..20 {
            parallel_shards(&mut shards, |s, shard| {
                shard.hits += 1;
                shard.sum += s;
            });
            for (s, shard) in shards.iter().enumerate() {
                assert_eq!(shard.hits, round + 1);
                assert_eq!(shard.sum, (round + 1) * s);
            }
        }
        // degenerate shapes
        let mut empty: Vec<Shard> = Vec::new();
        parallel_shards(&mut empty, |_, _| unreachable!());
        let mut one = vec![Shard { hits: 0, sum: 0 }];
        parallel_shards(&mut one, |_, shard| shard.hits += 1);
        assert_eq!(one[0].hits, 1);
    }

    #[test]
    fn chunked_mut_writes_disjoint() {
        let mut data = vec![0usize; 100];
        for_each_mut_chunks(&mut data, 6, |_, off, piece| {
            for (j, v) in piece.iter_mut().enumerate() {
                *v = off + j;
            }
        });
        assert_eq!(data, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_degrades_to_serial() {
        let out = parallel_map(10, 1, |i| i);
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn zero_tasks_is_noop() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }
}
