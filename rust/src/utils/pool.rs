//! Scoped thread-pool helpers (no rayon in the offline image).
//!
//! Algorithm 1's projection is "for each (r, k) do in parallel"; these
//! helpers provide that parallelism with `std::thread::scope`.  Work is
//! chunked statically — projection tasks per (r, k) are near-uniform, so
//! static chunking beats a work-stealing queue here and keeps the hot
//! loop allocation-free apart from thread spawn (amortized by chunk
//! size; see benches/ablation_projection.rs).

/// Number of worker threads to use for `n_tasks` independent tasks.
pub fn default_workers(n_tasks: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    cores.min(n_tasks).max(1)
}

/// Run `f(i)` for every `i in 0..n`, in parallel over `workers` threads.
/// `f` must be `Sync` (interior mutability / disjoint writes are the
/// caller's responsibility — see `for_each_mut_chunks` for slice output).
pub fn parallel_for<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.min(n).max(1);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let f = &f;
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                continue;
            }
            scope.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Parallel map over `0..n` producing a Vec<T> in index order.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SyncSlice::new(&mut out);
        parallel_for(n, workers, |i| {
            // SAFETY: each index written exactly once by exactly one task.
            unsafe { slots.write(i, f(i)) };
        });
    }
    out
}

/// Split `data` into `chunks` contiguous mutable pieces and run
/// `f(chunk_index, start_offset, piece)` on each in parallel.
pub fn for_each_mut_chunks<T, F>(data: &mut [T], chunks: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let chunks = chunks.min(n).max(1);
    let chunk = n.div_ceil(chunks);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut off = 0;
        let mut idx = 0;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (piece, tail) = rest.split_at_mut(take);
            let f = &f;
            let o = off;
            let i = idx;
            scope.spawn(move || f(i, o, piece));
            rest = tail;
            off += take;
            idx += 1;
        }
    });
}

/// A shared wrapper allowing disjoint-index writes into a slice from
/// multiple threads.  Callers must guarantee indices don't collide.
struct SyncSlice<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Sync for SyncSlice<T> {}
unsafe impl<T: Send> Send for SyncSlice<T> {}

impl<T> SyncSlice<T> {
    fn new(slice: &mut [T]) -> Self {
        SyncSlice { ptr: slice.as_mut_ptr(), len: slice.len() }
    }

    /// SAFETY: caller guarantees `i < len` and that no two threads write
    /// the same index.
    unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        unsafe { self.ptr.add(i).write(value) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits = AtomicUsize::new(0);
        parallel_for(1000, 8, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(257, 7, |i| i * 3);
        assert_eq!(out, (0..257).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn chunked_mut_writes_disjoint() {
        let mut data = vec![0usize; 100];
        for_each_mut_chunks(&mut data, 6, |_, off, piece| {
            for (j, v) in piece.iter_mut().enumerate() {
                *v = off + j;
            }
        });
        assert_eq!(data, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_degrades_to_serial() {
        let out = parallel_map(10, 1, |i| i);
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn zero_tasks_is_noop() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }
}
