//! Minimal binary snapshot codec (zero deps).
//!
//! `sim::checkpoint` serializes run state through this layer.  The
//! format is little-endian, length-prefixed, and *exact*: f64s round
//! trip through `to_bits`/`from_bits`, so a restored checkpoint replays
//! bit-identically — including NaN payloads and signed zeros.  A magic
//! tag plus a format version head every blob so stale snapshots fail
//! loudly instead of decoding garbage (see ROADMAP: checkpoint format
//! versioning).

/// Blob magic: "PLCK" (pallas checkpoint) as LE bytes.
pub const MAGIC: u32 = 0x4B434C50;
/// Bump on any incompatible layout change.  v2: appended the optional
/// streaming-ingest cursor/batch-state section (§SPerf-9).
pub const VERSION: u32 = 2;

/// Append-only encoder over an owned byte buffer.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// Fresh blob headed by the magic tag and format version.
    pub fn new() -> Writer {
        let mut w = Writer { buf: Vec::new() };
        w.put_u32(MAGIC);
        w.put_u32(VERSION);
        w
    }

    /// Headerless writer for nested sections (policy / arrival blobs
    /// embedded inside an outer checkpoint via [`Writer::put_bytes`]).
    pub fn section() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    /// Exact: the IEEE bit pattern, not a decimal round trip.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_usize(b.len());
        self.buf.extend_from_slice(b);
    }

    pub fn put_f64s(&mut self, xs: &[f64]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_f64(x);
        }
    }

    pub fn put_u64s(&mut self, xs: &[u64]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_u64(x);
        }
    }

    pub fn put_usizes(&mut self, xs: &[usize]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_usize(x);
        }
    }

    pub fn put_bools(&mut self, xs: &[bool]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_bool(x);
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Cursor-based decoder.  Every read is bounds-checked and returns
/// `Err` with the offset instead of panicking — a truncated or corrupt
/// checkpoint must surface as a recoverable error, not a crash, since
/// `run_resilient` injects checkpoint-write failures on purpose.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Open a headed blob, validating magic + version.
    pub fn new(buf: &'a [u8]) -> Result<Reader<'a>, String> {
        let mut r = Reader { buf, pos: 0 };
        let magic = r.get_u32()?;
        if magic != MAGIC {
            return Err(format!("checkpoint: bad magic {magic:#010x} (want {MAGIC:#010x})"));
        }
        let version = r.get_u32()?;
        if version != VERSION {
            return Err(format!("checkpoint: format version {version} (this build reads {VERSION})"));
        }
        Ok(r)
    }

    /// Open a headerless section (the payload of [`Writer::section`]).
    pub fn section(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                format!("checkpoint: truncated at byte {} (need {} more)", self.pos, n)
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn get_u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_usize(&mut self) -> Result<usize, String> {
        let v = self.get_u64()?;
        usize::try_from(v).map_err(|_| format!("checkpoint: length {v} overflows usize"))
    }

    pub fn get_bool(&mut self) -> Result<bool, String> {
        match self.take(1)?[0] {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(format!("checkpoint: bad bool byte {b:#04x} at {}", self.pos - 1)),
        }
    }

    pub fn get_f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_str(&mut self) -> Result<String, String> {
        let n = self.get_usize()?;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|e| format!("checkpoint: bad utf8: {e}"))
    }

    pub fn get_bytes(&mut self) -> Result<Vec<u8>, String> {
        let n = self.get_usize()?;
        Ok(self.take(n)?.to_vec())
    }

    pub fn get_f64s(&mut self) -> Result<Vec<f64>, String> {
        let n = self.get_usize()?;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 8));
        for _ in 0..n {
            out.push(self.get_f64()?);
        }
        Ok(out)
    }

    pub fn get_u64s(&mut self) -> Result<Vec<u64>, String> {
        let n = self.get_usize()?;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 8));
        for _ in 0..n {
            out.push(self.get_u64()?);
        }
        Ok(out)
    }

    pub fn get_usizes(&mut self) -> Result<Vec<usize>, String> {
        let n = self.get_usize()?;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 8));
        for _ in 0..n {
            out.push(self.get_usize()?);
        }
        Ok(out)
    }

    pub fn get_bools(&mut self) -> Result<Vec<bool>, String> {
        let n = self.get_usize()?;
        let mut out = Vec::with_capacity(n.min(self.remaining()));
        for _ in 0..n {
            out.push(self.get_bool()?);
        }
        Ok(out)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// All bytes consumed?  Decoders call this last so trailing garbage
    /// (e.g. a mis-versioned appendix) is caught.
    pub fn finish(self) -> Result<(), String> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(format!(
                "checkpoint: {} trailing bytes after decode",
                self.buf.len() - self.pos
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip_exactly() {
        let mut w = Writer::new();
        w.put_u64(u64::MAX);
        w.put_usize(12345);
        w.put_bool(true);
        w.put_bool(false);
        w.put_f64(-0.0);
        w.put_f64(f64::from_bits(0x7FF8_0000_DEAD_BEEF)); // NaN payload
        w.put_f64(1.0 / 3.0);
        w.put_str("pallas");
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes).unwrap();
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_usize().unwrap(), 12345);
        assert!(r.get_bool().unwrap());
        assert!(!r.get_bool().unwrap());
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.get_f64().unwrap().to_bits(), 0x7FF8_0000_DEAD_BEEF);
        assert_eq!(r.get_f64().unwrap(), 1.0 / 3.0);
        assert_eq!(r.get_str().unwrap(), "pallas");
        r.finish().unwrap();
    }

    #[test]
    fn vectors_round_trip() {
        let mut w = Writer::new();
        w.put_f64s(&[0.1, -2.5, f64::INFINITY]);
        w.put_u64s(&[1, 2, 3]);
        w.put_usizes(&[9, 8]);
        w.put_bools(&[true, false, true]);
        w.put_bytes(&[0xAB, 0xCD]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes).unwrap();
        assert_eq!(r.get_f64s().unwrap(), vec![0.1, -2.5, f64::INFINITY]);
        assert_eq!(r.get_u64s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.get_usizes().unwrap(), vec![9, 8]);
        assert_eq!(r.get_bools().unwrap(), vec![true, false, true]);
        assert_eq!(r.get_bytes().unwrap(), vec![0xAB, 0xCD]);
        r.finish().unwrap();
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let mut w = Writer::new();
        w.put_u64(7);
        let mut bytes = w.into_bytes();
        bytes[0] ^= 0xFF;
        assert!(Reader::new(&bytes).unwrap_err().contains("bad magic"));
        let mut w2 = Writer::section();
        w2.put_u32(MAGIC);
        w2.put_u32(VERSION + 1);
        let b2 = w2.into_bytes();
        assert!(Reader::new(&b2).unwrap_err().contains("version"));
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = Writer::new();
        w.put_f64s(&[1.0, 2.0, 3.0]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes[..bytes.len() - 4]).unwrap();
        assert!(r.get_f64s().unwrap_err().contains("truncated"));
    }

    #[test]
    fn finish_rejects_trailing_bytes() {
        let mut w = Writer::new();
        w.put_u64(1);
        w.put_u64(2);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes).unwrap();
        r.get_u64().unwrap();
        assert!(r.finish().is_err());
    }

    #[test]
    fn sections_nest_inside_headed_blobs() {
        let mut inner = Writer::section();
        inner.put_f64(2.5);
        inner.put_str("policy-state");
        let mut outer = Writer::new();
        outer.put_bytes(&inner.into_bytes());
        let bytes = outer.into_bytes();
        let mut r = Reader::new(&bytes).unwrap();
        let blob = r.get_bytes().unwrap();
        r.finish().unwrap();
        let mut s = Reader::section(&blob);
        assert_eq!(s.get_f64().unwrap(), 2.5);
        assert_eq!(s.get_str().unwrap(), "policy-state");
        s.finish().unwrap();
    }
}
