//! Minimal binary snapshot codec (zero deps) — PLCK v3.
//!
//! `sim::checkpoint` serializes run state through this layer.  The
//! format is little-endian, length-prefixed, and *exact*: f64s round
//! trip through `to_bits`/`from_bits`, so a restored checkpoint replays
//! bit-identically — including NaN payloads and signed zeros.  A magic
//! tag plus a format version head every blob so stale snapshots fail
//! loudly instead of decoding garbage.
//!
//! # PLCK v3 blob layout
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------------
//! 0       4     magic    0x4B434C50 ("PLCK" LE)
//! 4       4     version  3
//! 8       ...   body: a sequence of named sections (put_section)
//! len-4   4     trailer  crc32(bytes[0 .. len-4])
//! ```
//!
//! Each named section is framed as
//!
//! ```text
//! name     length-prefixed str  (section identity; rejects mis-splices)
//! crc      u32                  crc32 of the payload bytes
//! payload  length-prefixed [u8] (decoded by a nested Reader)
//! ```
//!
//! CRC coverage is two-level on purpose: the whole-blob trailer makes
//! *any* truncation or bit flip fail at [`Reader::new`] before a single
//! field is decoded (so a corrupt blob can never hand back partial
//! state), while the per-section checksums plus the stored section
//! names turn a blob assembled from mismatched pieces — a mis-splice
//! that recomputed the trailer — into a [`CodecErrorKind::WrongSection`]
//! or [`CodecErrorKind::SectionCrc`] error *naming the offending
//! section*.
//!
//! # Version gate
//!
//! | version | readable | notes                                        |
//! |---------|----------|----------------------------------------------|
//! | v1      | no       | PR-7 layout, no ingest section; rejected     |
//! | v2      | yes      | + optional ingest section; no checksums      |
//! | v3      | yes      | named + checksummed sections, blob trailer   |
//!
//! [`Writer::new`] always writes v3; v2 stays readable behind the gate
//! so durable chains written by the previous release still thaw
//! (without self-verification — their corruption surfaces as bounds /
//! semantic errors during decode, never as a panic).

/// Blob magic: "PLCK" (pallas checkpoint) as LE bytes.
pub const MAGIC: u32 = 0x4B434C50;
/// Bump on any incompatible layout change.  v2: appended the optional
/// streaming-ingest cursor/batch-state section (§SPerf-9).  v3: named,
/// CRC-32-checksummed sections plus a whole-blob trailer checksum
/// (§SStore).
pub const VERSION: u32 = 3;
/// Oldest version [`Reader::new`] still accepts.
pub const MIN_VERSION: u32 = 2;

const HEADER_LEN: usize = 8;
const TRAILER_LEN: usize = 4;

/// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) lookup table, built
/// at compile time — zero deps, zero runtime init.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// Hand-rolled CRC-32 (the zlib/PNG polynomial).  Detects every
/// single-bit flip and every burst error up to 32 bits — which covers
/// both storage-fault idioms `sim::store` injects (bit flips and torn
/// writes).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// What went wrong while decoding a blob.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CodecErrorKind {
    /// A read ran past the logical end of the buffer.
    Truncated { need: usize },
    /// The blob does not start with the PLCK magic.
    BadMagic { got: u32 },
    /// A version outside the `MIN_VERSION..=VERSION` gate.
    BadVersion { got: u32 },
    /// The whole-blob trailer checksum did not match (v3).
    BlobCrc { stored: u32, computed: u32 },
    /// A named section's payload checksum did not match (v3).
    SectionCrc { stored: u32, computed: u32 },
    /// The section at the cursor is not the one the decoder expected —
    /// the signature of a mis-spliced blob.
    WrongSection { want: String, got: String },
    /// A bool byte outside {0, 1}.
    BadBool { got: u8 },
    /// A length prefix that overflows usize.
    BadLength { got: u64 },
    /// A string payload that is not UTF-8.
    BadUtf8,
    /// Bytes left over after a decoder called [`Reader::finish`].
    Trailing { extra: usize },
}

/// Structured decode error: the kind, the byte offset it surfaced at,
/// and — when the reader was inside a named v3 section — the section's
/// name.  Converts into `String` so every `Result<_, String>` restore
/// path keeps using `?`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CodecError {
    pub kind: CodecErrorKind,
    pub offset: usize,
    /// Name of the section the reader was decoding, if any.
    pub section: Option<String>,
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.section {
            Some(s) => write!(f, "checkpoint[`{s}`]: ")?,
            None => write!(f, "checkpoint: ")?,
        }
        match &self.kind {
            CodecErrorKind::Truncated { need } => {
                write!(f, "truncated at byte {} (need {} more)", self.offset, need)
            }
            CodecErrorKind::BadMagic { got } => {
                write!(f, "bad magic {got:#010x} (want {MAGIC:#010x})")
            }
            CodecErrorKind::BadVersion { got } => write!(
                f,
                "format version {got} (this build reads v{MIN_VERSION}..v{VERSION})"
            ),
            CodecErrorKind::BlobCrc { stored, computed } => write!(
                f,
                "whole-blob crc mismatch (stored {stored:#010x}, computed {computed:#010x}) \
                 — the blob is truncated or corrupt"
            ),
            CodecErrorKind::SectionCrc { stored, computed } => write!(
                f,
                "section crc mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
            CodecErrorKind::WrongSection { want, got } => write!(
                f,
                "expected section `{want}`, found `{got}` (mis-spliced blob?)"
            ),
            CodecErrorKind::BadBool { got } => {
                write!(f, "bad bool byte {got:#04x} at {}", self.offset)
            }
            CodecErrorKind::BadLength { got } => write!(f, "length {got} overflows usize"),
            CodecErrorKind::BadUtf8 => write!(f, "bad utf8 at byte {}", self.offset),
            CodecErrorKind::Trailing { extra } => {
                write!(f, "{extra} trailing bytes after decode")
            }
        }
    }
}

impl From<CodecError> for String {
    fn from(e: CodecError) -> String {
        e.to_string()
    }
}

/// Structural self-verification: magic, version gate, and — for v3 —
/// the whole-blob trailer checksum.  Returns the blob's version.  This
/// is the cheap validity probe `sim::store` uses to walk a chain and
/// for GC's newest-valid pin: it reads no body fields, so it cannot
/// mutate any decoder state.
pub fn verify(buf: &[u8]) -> Result<u32, CodecError> {
    Reader::new(buf).map(|r| r.version())
}

/// Append-only encoder over an owned byte buffer.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// Fresh blob headed by the magic tag and the current format
    /// version (v3).  Finalize with [`Writer::finish`] — v3 blobs
    /// carry a trailing whole-blob checksum, so a headed writer's
    /// bytes are not a valid blob until the trailer is appended.
    pub fn new() -> Writer {
        Writer::with_version(VERSION)
    }

    /// Headed writer at an explicit format version — for the
    /// version-gate tests and legacy-layout (v2) fixtures.  Versions
    /// below 3 have no trailer: take their bytes via
    /// [`Writer::into_bytes`], not [`Writer::finish`].
    pub fn with_version(version: u32) -> Writer {
        let mut w = Writer { buf: Vec::new() };
        w.put_u32(MAGIC);
        w.put_u32(version);
        w
    }

    /// Headerless writer for nested sections (policy / arrival blobs
    /// embedded inside an outer checkpoint via [`Writer::put_bytes`]
    /// or [`Writer::put_section`]).
    pub fn section() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    /// Exact: the IEEE bit pattern, not a decimal round trip.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_usize(b.len());
        self.buf.extend_from_slice(b);
    }

    /// Frame `payload` as a named, checksummed v3 section: name,
    /// crc32(payload), then the length-prefixed payload itself.
    pub fn put_section(&mut self, name: &str, payload: &[u8]) {
        self.put_str(name);
        self.put_u32(crc32(payload));
        self.put_bytes(payload);
    }

    pub fn put_f64s(&mut self, xs: &[f64]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_f64(x);
        }
    }

    pub fn put_u64s(&mut self, xs: &[u64]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_u64(x);
        }
    }

    pub fn put_usizes(&mut self, xs: &[usize]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_usize(x);
        }
    }

    pub fn put_bools(&mut self, xs: &[bool]) {
        self.put_usize(xs.len());
        for &x in xs {
            self.put_bool(x);
        }
    }

    /// Raw bytes, no trailer — for sections and pre-v3 headed blobs.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Finalize a v3 headed blob: append the whole-blob crc32 trailer
    /// over everything written so far and return the bytes.
    pub fn finish(mut self) -> Vec<u8> {
        let crc = crc32(&self.buf);
        self.put_u32(crc);
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Cursor-based decoder.  Every read is bounds-checked and returns
/// `Err` with the offset instead of panicking — a truncated or corrupt
/// checkpoint must surface as a recoverable error, not a crash, since
/// `run_resilient` injects checkpoint-write failures and storage
/// corruption on purpose.  For v3 blobs the whole-blob trailer is
/// verified *before* any field is handed out, so no decoder downstream
/// of [`Reader::new`] can observe partial state from a damaged blob.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Logical end: excludes the v3 trailer.
    end: usize,
    /// Blob format version (sections report their parent's; bare
    /// sections report the current version).
    version: u32,
    /// Name of the v3 section this reader decodes, for error context.
    name: Option<String>,
}

impl<'a> Reader<'a> {
    /// Open a headed blob, validating magic + version gate and — for
    /// v3 — the whole-blob trailer checksum.
    pub fn new(buf: &'a [u8]) -> Result<Reader<'a>, CodecError> {
        let mut r = Reader { buf, pos: 0, end: buf.len(), version: VERSION, name: None };
        let magic = r.get_u32()?;
        if magic != MAGIC {
            return Err(r.err(CodecErrorKind::BadMagic { got: magic }));
        }
        let version = r.get_u32()?;
        if !(MIN_VERSION..=VERSION).contains(&version) {
            return Err(r.err(CodecErrorKind::BadVersion { got: version }));
        }
        r.version = version;
        if version >= 3 {
            if buf.len() < HEADER_LEN + TRAILER_LEN {
                return Err(CodecError {
                    kind: CodecErrorKind::Truncated {
                        need: HEADER_LEN + TRAILER_LEN - buf.len(),
                    },
                    offset: buf.len(),
                    section: None,
                });
            }
            let body = &buf[..buf.len() - TRAILER_LEN];
            let stored = u32::from_le_bytes(
                buf[buf.len() - TRAILER_LEN..].try_into().expect("4 trailer bytes"),
            );
            let computed = crc32(body);
            if stored != computed {
                return Err(r.err(CodecErrorKind::BlobCrc { stored, computed }));
            }
            r.end = buf.len() - TRAILER_LEN;
        }
        Ok(r)
    }

    /// Open a headerless section (the payload of [`Writer::section`]).
    pub fn section(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0, end: buf.len(), version: VERSION, name: None }
    }

    /// Like [`Reader::section`], but tagged with the section's name so
    /// decode errors identify which section they came from.
    pub fn named_section(buf: &'a [u8], name: &str) -> Reader<'a> {
        Reader { buf, pos: 0, end: buf.len(), version: VERSION, name: Some(name.to_string()) }
    }

    /// The blob's format version (from the header).
    pub fn version(&self) -> u32 {
        self.version
    }

    fn err(&self, kind: CodecErrorKind) -> CodecError {
        CodecError { kind, offset: self.pos, section: self.name.clone() }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.end)
            .ok_or_else(|| self.err(CodecErrorKind::Truncated { need: n - (self.end - self.pos) }))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn get_u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    pub fn get_u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    pub fn get_usize(&mut self) -> Result<usize, CodecError> {
        let v = self.get_u64()?;
        usize::try_from(v).map_err(|_| self.err(CodecErrorKind::BadLength { got: v }))
    }

    pub fn get_bool(&mut self) -> Result<bool, CodecError> {
        match self.take(1)?[0] {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(self.err(CodecErrorKind::BadBool { got: b })),
        }
    }

    pub fn get_f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_str(&mut self) -> Result<String, CodecError> {
        let n = self.get_usize()?;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| self.err(CodecErrorKind::BadUtf8))
    }

    pub fn get_bytes(&mut self) -> Result<Vec<u8>, CodecError> {
        let n = self.get_usize()?;
        Ok(self.take(n)?.to_vec())
    }

    /// Decode the v3 section frame at the cursor: the stored name must
    /// equal `want` (else the blob was spliced from mismatched pieces)
    /// and the payload must match its stored crc32.  Returns the
    /// verified payload slice; decode it with [`Reader::named_section`].
    pub fn get_section(&mut self, want: &str) -> Result<&'a [u8], CodecError> {
        let got = self.get_str()?;
        if got != want {
            return Err(self.err(CodecErrorKind::WrongSection {
                want: want.to_string(),
                got,
            }));
        }
        let stored = self.get_u32()?;
        let n = self.get_usize()?;
        let payload = self.take(n)?;
        let computed = crc32(payload);
        if stored != computed {
            return Err(CodecError {
                kind: CodecErrorKind::SectionCrc { stored, computed },
                offset: self.pos,
                section: Some(want.to_string()),
            });
        }
        Ok(payload)
    }

    pub fn get_f64s(&mut self) -> Result<Vec<f64>, CodecError> {
        let n = self.get_usize()?;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 8));
        for _ in 0..n {
            out.push(self.get_f64()?);
        }
        Ok(out)
    }

    pub fn get_u64s(&mut self) -> Result<Vec<u64>, CodecError> {
        let n = self.get_usize()?;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 8));
        for _ in 0..n {
            out.push(self.get_u64()?);
        }
        Ok(out)
    }

    pub fn get_usizes(&mut self) -> Result<Vec<usize>, CodecError> {
        let n = self.get_usize()?;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 8));
        for _ in 0..n {
            out.push(self.get_usize()?);
        }
        Ok(out)
    }

    pub fn get_bools(&mut self) -> Result<Vec<bool>, CodecError> {
        let n = self.get_usize()?;
        let mut out = Vec::with_capacity(n.min(self.remaining()));
        for _ in 0..n {
            out.push(self.get_bool()?);
        }
        Ok(out)
    }

    pub fn remaining(&self) -> usize {
        self.end - self.pos
    }

    /// All bytes consumed?  Decoders call this last so trailing garbage
    /// (e.g. a mis-versioned appendix) is caught.  The v3 trailer is
    /// outside the logical end and does not count as trailing.
    pub fn finish(self) -> Result<(), CodecError> {
        if self.pos == self.end {
            Ok(())
        } else {
            let extra = self.end - self.pos;
            Err(CodecError {
                kind: CodecErrorKind::Trailing { extra },
                offset: self.pos,
                section: self.name.clone(),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// zlib's published check value for the IEEE polynomial.
    #[test]
    fn crc32_matches_the_reference_check_value() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // incremental sanity: any single byte change moves the sum
        assert_ne!(crc32(b"pallas"), crc32(b"pallbs"));
    }

    #[test]
    fn scalars_round_trip_exactly() {
        let mut w = Writer::new();
        w.put_u64(u64::MAX);
        w.put_usize(12345);
        w.put_bool(true);
        w.put_bool(false);
        w.put_f64(-0.0);
        w.put_f64(f64::from_bits(0x7FF8_0000_DEAD_BEEF)); // NaN payload
        w.put_f64(1.0 / 3.0);
        w.put_str("pallas");
        let bytes = w.finish();
        let mut r = Reader::new(&bytes).unwrap();
        assert_eq!(r.version(), VERSION);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_usize().unwrap(), 12345);
        assert!(r.get_bool().unwrap());
        assert!(!r.get_bool().unwrap());
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.get_f64().unwrap().to_bits(), 0x7FF8_0000_DEAD_BEEF);
        assert_eq!(r.get_f64().unwrap(), 1.0 / 3.0);
        assert_eq!(r.get_str().unwrap(), "pallas");
        r.finish().unwrap();
    }

    #[test]
    fn vectors_round_trip() {
        let mut w = Writer::new();
        w.put_f64s(&[0.1, -2.5, f64::INFINITY]);
        w.put_u64s(&[1, 2, 3]);
        w.put_usizes(&[9, 8]);
        w.put_bools(&[true, false, true]);
        w.put_bytes(&[0xAB, 0xCD]);
        let bytes = w.finish();
        let mut r = Reader::new(&bytes).unwrap();
        assert_eq!(r.get_f64s().unwrap(), vec![0.1, -2.5, f64::INFINITY]);
        assert_eq!(r.get_u64s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.get_usizes().unwrap(), vec![9, 8]);
        assert_eq!(r.get_bools().unwrap(), vec![true, false, true]);
        assert_eq!(r.get_bytes().unwrap(), vec![0xAB, 0xCD]);
        r.finish().unwrap();
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let mut w = Writer::new();
        w.put_u64(7);
        let mut bytes = w.finish();
        bytes[0] ^= 0xFF;
        let e = Reader::new(&bytes).unwrap_err();
        assert!(matches!(e.kind, CodecErrorKind::BadMagic { .. }), "{e}");
        assert!(e.to_string().contains("bad magic"));
        // future version: rejected by the gate
        let w2 = Writer::with_version(VERSION + 1);
        let e2 = Reader::new(&w2.into_bytes()).unwrap_err();
        assert!(matches!(e2.kind, CodecErrorKind::BadVersion { got } if got == VERSION + 1));
        // v1 predates the gate floor: rejected loudly
        let w1 = Writer::with_version(1);
        let e1 = Reader::new(&w1.into_bytes()).unwrap_err();
        assert!(matches!(e1.kind, CodecErrorKind::BadVersion { got: 1 }), "{e1}");
        assert!(e1.to_string().contains("version 1"));
    }

    #[test]
    fn v2_blobs_stay_readable_behind_the_gate() {
        // the previous release's layout: headed, no checksums anywhere
        let mut w = Writer::with_version(2);
        w.put_u64(42);
        w.put_str("legacy");
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes).unwrap();
        assert_eq!(r.version(), 2);
        assert_eq!(r.get_u64().unwrap(), 42);
        assert_eq!(r.get_str().unwrap(), "legacy");
        r.finish().unwrap();
    }

    #[test]
    fn whole_blob_trailer_rejects_any_corruption() {
        let mut w = Writer::new();
        w.put_section("alpha", &[1, 2, 3]);
        w.put_f64s(&[1.0, 2.0]);
        let bytes = w.finish();
        assert!(Reader::new(&bytes).is_ok());
        // flip one bit of every byte in turn — including header, section
        // frames, payloads, and the trailer itself: all must be caught
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x10;
            assert!(Reader::new(&bad).is_err(), "bit flip at byte {i} went undetected");
        }
    }

    #[test]
    fn truncation_at_every_offset_is_an_error_not_a_panic() {
        // representative v3 blob: scalars, vectors, nested sections
        let mut inner = Writer::section();
        inner.put_f64(2.5);
        let mut w = Writer::new();
        w.put_u64(7);
        w.put_section("driver", &inner.into_bytes());
        w.put_section("records", &[0u8; 33]);
        w.put_bools(&[true, false]);
        let bytes = w.finish();
        assert!(Reader::new(&bytes).is_ok());
        for cut in 0..bytes.len() {
            assert!(
                Reader::new(&bytes[..cut]).is_err(),
                "truncation at byte {cut} of {} was not rejected",
                bytes.len()
            );
        }
    }

    #[test]
    fn sections_verify_names_and_payload_crcs() {
        let mut w = Writer::new();
        w.put_section("ledger", &[9, 9, 9]);
        w.put_section("policy", &[4, 5]);
        let bytes = w.finish();
        let mut r = Reader::new(&bytes).unwrap();
        assert_eq!(r.get_section("ledger").unwrap(), &[9, 9, 9]);
        assert_eq!(r.get_section("policy").unwrap(), &[4, 5]);
        r.finish().unwrap();
        // asking for sections in the wrong order names both sides
        let mut r2 = Reader::new(&bytes).unwrap();
        let e = r2.get_section("policy").unwrap_err();
        match e.kind {
            CodecErrorKind::WrongSection { ref want, ref got } => {
                assert_eq!(want, "policy");
                assert_eq!(got, "ledger");
            }
            ref k => panic!("unexpected error kind {k:?}"),
        }
        assert!(e.to_string().contains("`policy`"), "{e}");
    }

    #[test]
    fn mis_spliced_sections_are_rejected_by_name_or_crc() {
        // splice: take blob A's "policy" payload bytes and overwrite
        // blob B's "policy" payload in place, then recompute the
        // trailer (a storage layer that interleaved two writes).  The
        // section CRC still holds (payload + crc both spliced), but
        // swapping payload *without* its crc must fail, naming the
        // section.
        let payload_a = [1u8, 2, 3, 4];
        let payload_b = [9u8, 8, 7, 6];
        let mut w = Writer::new();
        w.put_section("policy", &payload_a);
        let blob_a = w.finish();
        // locate the payload: header(8) + name(8+6) + crc(4) + len(8)
        let off = 8 + 8 + "policy".len() + 4 + 8;
        let mut spliced = blob_a.clone();
        spliced[off..off + 4].copy_from_slice(&payload_b);
        // recompute the trailer so the whole-blob check passes and the
        // per-section crc is what catches the splice
        let body_len = spliced.len() - 4;
        let crc = crc32(&spliced[..body_len]);
        spliced[body_len..].copy_from_slice(&crc.to_le_bytes());
        let mut r = Reader::new(&spliced).unwrap();
        let e = r.get_section("policy").unwrap_err();
        assert!(matches!(e.kind, CodecErrorKind::SectionCrc { .. }), "{e}");
        assert_eq!(e.section.as_deref(), Some("policy"));
    }

    #[test]
    fn finish_rejects_trailing_bytes() {
        let mut w = Writer::new();
        w.put_u64(1);
        w.put_u64(2);
        let bytes = w.finish();
        let mut r = Reader::new(&bytes).unwrap();
        r.get_u64().unwrap();
        let e = r.finish().unwrap_err();
        assert!(matches!(e.kind, CodecErrorKind::Trailing { extra: 8 }), "{e}");
    }

    #[test]
    fn named_section_errors_carry_the_section_name() {
        let mut s = Writer::section();
        s.put_u64(1);
        let bytes = s.into_bytes();
        let mut r = Reader::named_section(&bytes, "arrivals");
        r.get_u64().unwrap();
        let e = r.get_u64().unwrap_err();
        assert_eq!(e.section.as_deref(), Some("arrivals"));
        assert!(e.to_string().contains("[`arrivals`]"), "{e}");
    }

    #[test]
    fn sections_nest_inside_headed_blobs() {
        let mut inner = Writer::section();
        inner.put_f64(2.5);
        inner.put_str("policy-state");
        let mut outer = Writer::new();
        outer.put_bytes(&inner.into_bytes());
        let bytes = outer.finish();
        let mut r = Reader::new(&bytes).unwrap();
        let blob = r.get_bytes().unwrap();
        r.finish().unwrap();
        let mut s = Reader::section(&blob);
        assert_eq!(s.get_f64().unwrap(), 2.5);
        assert_eq!(s.get_str().unwrap(), "policy-state");
        s.finish().unwrap();
    }

    #[test]
    fn verify_is_a_pure_structural_probe() {
        let mut w = Writer::new();
        w.put_section("driver", &[1, 2]);
        let bytes = w.finish();
        assert_eq!(verify(&bytes).unwrap(), VERSION);
        let mut torn = bytes.clone();
        torn.truncate(bytes.len() / 2);
        assert!(verify(&torn).is_err());
        let mut w2 = Writer::with_version(2);
        w2.put_u64(3);
        assert_eq!(verify(&w2.into_bytes()).unwrap(), 2);
    }
}
