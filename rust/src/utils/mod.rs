//! In-tree substrates: PRNG, statistics, CSV, thread pool, tables, and a
//! property-testing harness.  The offline build environment only ships
//! the `xla` crate closure, so these replace rand/rayon/csv/proptest.

pub mod codec;
pub mod csv;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
