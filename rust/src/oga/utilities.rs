//! The concave utility families of Eq. (51) and their calculus.
//!
//! Each channel's gain function `f_r^k` is one of four families, all
//! zero-startup (`f(0) = 0`), non-decreasing and concave on ℝ₊ — the
//! diminishing marginal effect of adding parallel workers:
//!
//! | family     | f(y)                  | f'(y)            | ϖ = f'(0) |
//! |------------|-----------------------|------------------|-----------|
//! | linear     | αy                    | α                | α         |
//! | log        | α·ln(y+1)             | α/(y+1)          | α         |
//! | reciprocal | 1/α − 1/(y+α)         | 1/(y+α)²         | 1/α²      |
//! | poly       | α·√(y+1) − α          | α/(2√(y+1))      | α/2       |

use crate::oga::kernels;

/// Utility family discriminant.  The numeric values match the `kind`
/// codes the Python kernels use (ref.py KIND_*), so the same i32 tensor
/// drives both implementations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(i32)]
pub enum UtilityKind {
    Linear = 0,
    Log = 1,
    Reciprocal = 2,
    Poly = 3,
}

impl UtilityKind {
    pub const ALL: [UtilityKind; 4] =
        [UtilityKind::Linear, UtilityKind::Log, UtilityKind::Reciprocal, UtilityKind::Poly];

    pub fn from_code(code: i32) -> Option<UtilityKind> {
        match code {
            0 => Some(UtilityKind::Linear),
            1 => Some(UtilityKind::Log),
            2 => Some(UtilityKind::Reciprocal),
            3 => Some(UtilityKind::Poly),
            _ => None,
        }
    }

    pub fn code(self) -> i32 {
        self as i32
    }

    pub fn name(self) -> &'static str {
        match self {
            UtilityKind::Linear => "linear",
            UtilityKind::Log => "log",
            UtilityKind::Reciprocal => "reciprocal",
            UtilityKind::Poly => "poly",
        }
    }

    pub fn from_name(name: &str) -> Option<UtilityKind> {
        match name {
            "linear" => Some(UtilityKind::Linear),
            "log" => Some(UtilityKind::Log),
            "reciprocal" => Some(UtilityKind::Reciprocal),
            "poly" => Some(UtilityKind::Poly),
            _ => None,
        }
    }

    /// f(y) — the parallel-computation gain of `y` units (Eq. 51).
    #[inline]
    pub fn value(self, y: f64, alpha: f64) -> f64 {
        debug_assert!(y >= -1e-9, "utility evaluated at negative y={y}");
        let y = y.max(0.0);
        match self {
            UtilityKind::Linear => alpha * y,
            UtilityKind::Log => alpha * (y + 1.0).ln(),
            UtilityKind::Reciprocal => 1.0 / alpha - 1.0 / (y + alpha),
            UtilityKind::Poly => alpha * (y + 1.0).sqrt() - alpha,
        }
    }

    /// f'(y) — marginal gain.
    #[inline]
    pub fn grad(self, y: f64, alpha: f64) -> f64 {
        let y = y.max(0.0);
        match self {
            UtilityKind::Linear => alpha,
            UtilityKind::Log => alpha / (y + 1.0),
            UtilityKind::Reciprocal => {
                let d = y + alpha;
                1.0 / (d * d)
            }
            UtilityKind::Poly => alpha / (2.0 * (y + 1.0).sqrt()),
        }
    }

    /// ϖ = f'(0), the gradient bound of Def. 1 (iii) used in Thm. 1.
    #[inline]
    pub fn varpi(self, alpha: f64) -> f64 {
        self.grad(0.0, alpha)
    }

    // --- kind-batched slice kernels (§Perf-2, §Perf-5) ----------------
    //
    // The hot loops dispatch on the family once per same-kind run (see
    // model::KindIndex) and then stream one of these over a contiguous
    // slice.  Each helper is monomorphic in the family at the call site,
    // so the inner `value`/`grad` match constant-folds away and the loop
    // body is branch-free; per-element semantics are identical to the
    // scalar calculus above (including the y ≥ 0 clamp).  The bodies
    // live in `oga::kernels` (§Perf-5): a fixed-width lane-tree layer
    // with a `std::simd` twin behind the `simd` feature, bit-identical
    // across both build paths; `kernels::*_ref` keep the sequential
    // pre-§Perf-5 loops as the parity reference.

    /// Σ_i f(y_i, α_i) over a run (lane-tree accumulation order —
    /// within a few ulps of, not bitwise equal to, the sequential
    /// [`kernels::value_sum_ref`]).
    pub fn value_sum(self, y: &[f64], alpha: &[f64]) -> f64 {
        match self {
            UtilityKind::Linear => kernels::value_sum(UtilityKind::Linear, y, alpha),
            UtilityKind::Log => kernels::value_sum(UtilityKind::Log, y, alpha),
            UtilityKind::Reciprocal => kernels::value_sum(UtilityKind::Reciprocal, y, alpha),
            UtilityKind::Poly => kernels::value_sum(UtilityKind::Poly, y, alpha),
        }
    }

    /// out_i = scale · f'(y_i, α_i) over a run (element-wise; floats
    /// independent of slice boundaries and build path).
    pub fn grad_into(self, y: &[f64], alpha: &[f64], scale: f64, out: &mut [f64]) {
        match self {
            UtilityKind::Linear => kernels::grad_into(UtilityKind::Linear, y, alpha, scale, out),
            UtilityKind::Log => kernels::grad_into(UtilityKind::Log, y, alpha, scale, out),
            UtilityKind::Reciprocal => {
                kernels::grad_into(UtilityKind::Reciprocal, y, alpha, scale, out)
            }
            UtilityKind::Poly => kernels::grad_into(UtilityKind::Poly, y, alpha, scale, out),
        }
    }

    /// y_i += scale · f'(y_i, α_i) over a run (the fused-ascent body;
    /// f' is evaluated at the pre-update y_i; element-wise).
    pub fn ascend_slice(self, y: &mut [f64], alpha: &[f64], scale: f64) {
        match self {
            UtilityKind::Linear => kernels::ascend_slice(UtilityKind::Linear, y, alpha, scale),
            UtilityKind::Log => kernels::ascend_slice(UtilityKind::Log, y, alpha, scale),
            UtilityKind::Reciprocal => {
                kernels::ascend_slice(UtilityKind::Reciprocal, y, alpha, scale)
            }
            UtilityKind::Poly => kernels::ascend_slice(UtilityKind::Poly, y, alpha, scale),
        }
    }
}

/// The per-experiment utility assignment policy (Fig. 7 sweeps these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UtilityMix {
    /// Uniform-random family per (r, k) — the default "hybrid" setting.
    Mixed,
    /// Every channel uses the same family.
    All(UtilityKind),
}

impl UtilityMix {
    pub fn name(self) -> String {
        match self {
            UtilityMix::Mixed => "mixed".to_string(),
            UtilityMix::All(k) => format!("all-{}", k.name()),
        }
    }

    pub fn from_name(name: &str) -> Option<UtilityMix> {
        if name == "mixed" {
            return Some(UtilityMix::Mixed);
        }
        name.strip_prefix("all-").and_then(UtilityKind::from_name).map(UtilityMix::All)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALPHAS: [f64; 3] = [1.0, 1.25, 1.5];

    #[test]
    fn zero_startup() {
        for kind in UtilityKind::ALL {
            for alpha in ALPHAS {
                assert!(
                    kind.value(0.0, alpha).abs() < 1e-12,
                    "{}: f(0) != 0",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn nondecreasing_and_concave() {
        for kind in UtilityKind::ALL {
            for alpha in ALPHAS {
                let mut prev_f = kind.value(0.0, alpha);
                let mut prev_g = kind.grad(0.0, alpha);
                for i in 1..200 {
                    let y = i as f64 * 0.25;
                    let f = kind.value(y, alpha);
                    let g = kind.grad(y, alpha);
                    assert!(f >= prev_f - 1e-12, "{} not nondecreasing", kind.name());
                    assert!(g <= prev_g + 1e-12, "{} grad not nonincreasing", kind.name());
                    assert!(g >= 0.0);
                    prev_f = f;
                    prev_g = g;
                }
            }
        }
    }

    #[test]
    fn grad_matches_finite_difference() {
        let h = 1e-6;
        for kind in UtilityKind::ALL {
            for alpha in ALPHAS {
                for i in 0..50 {
                    let y = 0.1 + i as f64 * 0.37;
                    let fd = (kind.value(y + h, alpha) - kind.value(y - h, alpha)) / (2.0 * h);
                    let an = kind.grad(y, alpha);
                    assert!(
                        (fd - an).abs() < 1e-5 * (1.0 + an.abs()),
                        "{}: fd={fd} an={an} at y={y}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn varpi_upper_bounds_grad() {
        for kind in UtilityKind::ALL {
            for alpha in ALPHAS {
                let w = kind.varpi(alpha);
                for i in 0..100 {
                    let y = i as f64 * 0.5;
                    assert!(kind.grad(y, alpha) <= w + 1e-12);
                }
            }
        }
    }

    #[test]
    fn code_roundtrip() {
        for kind in UtilityKind::ALL {
            assert_eq!(UtilityKind::from_code(kind.code()), Some(kind));
            assert_eq!(UtilityKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(UtilityKind::from_code(9), None);
    }

    #[test]
    fn mix_names_roundtrip() {
        for mix in [
            UtilityMix::Mixed,
            UtilityMix::All(UtilityKind::Log),
            UtilityMix::All(UtilityKind::Poly),
        ] {
            assert_eq!(UtilityMix::from_name(&mix.name()), Some(mix));
        }
        assert_eq!(UtilityMix::from_name("bogus"), None);
    }

    #[test]
    fn slice_kernels_match_scalar_calculus() {
        // value_sum / grad_into / ascend_slice are the batched forms of
        // value/grad — same numbers, element by element (value_sum's
        // §Perf-5 lane-tree order reassociates the sum by a few ulps).
        // The negative entry exercises the y ≥ 0 clamp of the gradient
        // kernels; `value` contracts y ≥ 0, so the sum row clamps first.
        let y = [0.0, 0.4, 1.7, 3.2, -0.3];
        let alpha = [1.0, 1.25, 1.5, 0.8, 2.0];
        let scale = 0.75;
        for kind in UtilityKind::ALL {
            let y_sum = [0.0, 0.4, 1.7, 3.2, 0.0];
            let want_sum: f64 =
                y_sum.iter().zip(&alpha).map(|(&v, &a)| kind.value(v, a)).sum();
            assert!(
                (kind.value_sum(&y_sum, &alpha) - want_sum).abs() < 1e-12,
                "{}",
                kind.name()
            );
            let mut out = [9.0; 5];
            kind.grad_into(&y, &alpha, scale, &mut out);
            for i in 0..y.len() {
                let want = scale * kind.grad(y[i], alpha[i]);
                assert!((out[i] - want).abs() < 1e-15, "{} grad at {i}", kind.name());
            }
            let mut asc = y;
            kind.ascend_slice(&mut asc, &alpha, scale);
            for i in 0..y.len() {
                let want = y[i] + scale * kind.grad(y[i], alpha[i]);
                assert!((asc[i] - want).abs() < 1e-15, "{} ascend at {i}", kind.name());
            }
        }
    }

    #[test]
    fn spot_values_match_eq51() {
        // mirrored by python/tests/test_kernel.py::test_utility_values_match_eq51
        assert!((UtilityKind::Linear.value(3.0, 2.0) - 6.0).abs() < 1e-12);
        assert!((UtilityKind::Log.value(3.0, 2.0) - 2.0 * 4.0f64.ln()).abs() < 1e-12);
        assert!((UtilityKind::Reciprocal.value(3.0, 2.0) - (0.5 - 0.2)).abs() < 1e-12);
        assert!((UtilityKind::Poly.value(3.0, 2.0) - 2.0).abs() < 1e-12);
    }
}
