//! OGASCHED's algorithmic core: utility calculus, the Eq. 30 gradient,
//! the Alg. 1 fast projection, the learning-rate schedule, and the
//! per-slot stepper that ties them together.

pub mod gradient;
pub mod projection;
pub mod utilities;

use crate::model::Problem;
use gradient::{gradient, GradScratch};
use projection::project;

/// Learning-rate schedule.  The paper's experiments use a multiplicative
/// decay η_{t+1} = λ·η_t (Alg. 1 step 32) around the Eq. 50 oracle rate;
/// `Oracle` implements Eq. 50 directly (diam(Y) / (‖∇q‖·√T)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LearningRate {
    /// η_t = η₀ · λ^t (Tab. 2 defaults: η₀ = 25, λ = 0.9999).
    Decay { eta0: f64, lambda: f64 },
    /// Eq. 50: η_t = diam(Y) / (‖∇q(t)‖ √T), with a cap for ‖∇q‖ → 0.
    Oracle { horizon: usize },
    /// Fixed rate (regret-theory setting of Thm. 1's proof).
    Constant(f64),
}

impl LearningRate {
    pub fn eta(&self, problem: &Problem, t: usize, grad_norm: f64) -> f64 {
        match *self {
            LearningRate::Decay { eta0, lambda } => eta0 * lambda.powi(t as i32),
            LearningRate::Oracle { horizon } => {
                let g = grad_norm.max(1e-9);
                problem.diam_upper() / (g * (horizon.max(1) as f64).sqrt())
            }
            LearningRate::Constant(eta) => eta,
        }
    }
}

/// Mutable OGA state: the current decision y(t) plus reusable scratch
/// buffers.  `step` performs Alg. 1 lines 3–32 for one slot without any
/// heap allocation after construction (scratch is pre-sized).
#[derive(Clone, Debug)]
pub struct OgaState {
    /// Current decision y(t), dense [L, R, K].
    pub y: Vec<f64>,
    /// Slot counter (t starts at 0 == paper's t = 1).
    pub t: usize,
    pub lr: LearningRate,
    /// Worker threads for the projection (0 = auto).
    pub workers: usize,
    grad: Vec<f64>,
    scratch: GradScratch,
    scratch_quota: Vec<f64>,
}

impl OgaState {
    /// y(1) = 0 is feasible (Y contains the origin) and is the paper's
    /// un-boosted initialization (Sec. 4.1 notes the early oscillation).
    pub fn new(problem: &Problem, lr: LearningRate, workers: usize) -> Self {
        OgaState {
            y: vec![0.0; problem.decision_len()],
            t: 0,
            lr,
            workers,
            grad: vec![0.0; problem.decision_len()],
            scratch: GradScratch::default(),
            scratch_quota: Vec::new(),
        }
    }

    /// One OGA slot: observe x(t), ascend the reward gradient at
    /// (x(t), y(t)), project back onto Y.  Returns the step size used.
    ///
    /// Hot-path note (§Perf): when η_t does not depend on ‖∇q‖ (decay /
    /// constant schedules) the gradient is *fused into the ascent* —
    /// only the arrived ports' coordinates are touched and no gradient
    /// buffer is materialized.  The Oracle schedule (Eq. 50) needs the
    /// norm first, so it keeps the two-pass path.
    pub fn step(&mut self, problem: &Problem, x: &[f64]) -> f64 {
        let eta = match self.lr {
            LearningRate::Oracle { .. } => {
                gradient(problem, x, &self.y, &mut self.grad, &mut self.scratch);
                let gnorm = gradient::grad_norm(&self.grad);
                let eta = self.lr.eta(problem, self.t, gnorm);
                for i in 0..self.y.len() {
                    self.y[i] += eta * self.grad[i];
                }
                eta
            }
            _ => {
                let eta = self.lr.eta(problem, self.t, 0.0);
                self.fused_ascent(problem, x, eta);
                eta
            }
        };
        project(problem, &mut self.y, self.workers);
        self.t += 1;
        eta
    }

    /// y += η·∇q(x, y) touching only the arrived ports (Eq. 30 inline).
    fn fused_ascent(&mut self, problem: &Problem, x: &[f64], eta: f64) {
        let k_n = problem.num_resources;
        self.scratch_quota.resize(k_n, 0.0);
        for l in 0..problem.num_ports() {
            let x_l = x[l];
            if x_l == 0.0 {
                continue;
            }
            let instances = &problem.graph.ports_to_instances[l];
            self.scratch_quota.fill(0.0);
            for &r in instances {
                let base = problem.idx(l, r, 0);
                for k in 0..k_n {
                    self.scratch_quota[k] += self.y[base + k];
                }
            }
            let mut kstar = 0;
            let mut best = f64::NEG_INFINITY;
            for k in 0..k_n {
                let v = problem.beta[k] * self.scratch_quota[k];
                if v > best {
                    best = v;
                    kstar = k;
                }
            }
            for &r in instances {
                let base = problem.idx(l, r, 0);
                let rk = r * k_n;
                for k in 0..k_n {
                    let yv = self.y[base + k];
                    let fp = problem.kind[rk + k].grad(yv, problem.alpha[rk + k]);
                    let pen = if k == kstar { problem.beta[k] } else { 0.0 };
                    self.y[base + k] = yv + eta * x_l * (fp - pen);
                }
            }
        }
    }

    /// Current gradient buffer (valid after `step`; exposed for tests
    /// and the Thm. 1 bound checks).
    pub fn last_grad(&self) -> &[f64] {
        &self.grad
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::reward::slot_reward;
    use crate::traces::synthesize;

    #[test]
    fn step_keeps_feasibility() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Decay { eta0: 25.0, lambda: 0.9999 }, 0);
        let x = vec![1.0; p.num_ports()];
        for _ in 0..20 {
            s.step(&p, &x);
            p.check_feasible(&s.y, 1e-7).unwrap();
        }
    }

    #[test]
    fn reward_climbs_under_stationary_arrivals() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Decay { eta0: 5.0, lambda: 0.999 }, 0);
        let x = vec![1.0; p.num_ports()];
        let r0 = slot_reward(&p, &x, &s.y).q;
        for _ in 0..100 {
            s.step(&p, &x);
        }
        let r1 = slot_reward(&p, &x, &s.y).q;
        assert!(r1 > r0, "reward did not improve: {r0} -> {r1}");
    }

    #[test]
    fn decay_schedule_matches_formula() {
        let p = synthesize(&Scenario::small());
        let lr = LearningRate::Decay { eta0: 25.0, lambda: 0.9 };
        assert!((lr.eta(&p, 0, 1.0) - 25.0).abs() < 1e-12);
        assert!((lr.eta(&p, 2, 1.0) - 25.0 * 0.81).abs() < 1e-9);
    }

    #[test]
    fn oracle_rate_uses_diam_and_gradnorm() {
        let p = synthesize(&Scenario::small());
        let lr = LearningRate::Oracle { horizon: 100 };
        let eta = lr.eta(&p, 0, 2.0);
        assert!((eta - p.diam_upper() / (2.0 * 10.0)).abs() < 1e-9);
    }

    #[test]
    fn zero_arrivals_leave_y_fixed() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Constant(1.0), 0);
        let x_on = vec![1.0; p.num_ports()];
        let x_off = vec![0.0; p.num_ports()];
        for _ in 0..5 {
            s.step(&p, &x_on);
        }
        let before = s.y.clone();
        s.step(&p, &x_off);
        // zero gradient => the step is a re-projection of a feasible
        // point; equal up to re-projection round-off on exactly-tight
        // capacity columns.
        for (a, b) in s.y.iter().zip(&before) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }
}
