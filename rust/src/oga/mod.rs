//! OGASCHED's algorithmic core: utility calculus, the Eq. 30 gradient,
//! the Alg. 1 fast projection, the learning-rate schedule, and the
//! per-slot stepper that ties them together.

pub mod dense_ref;
pub mod gradient;
pub mod kernels;
pub mod projection;
pub mod utilities;

use std::sync::Arc;

use crate::coordinator::sharded::{active_plan, project_dirty_sharded, ArrivedPort, ShardPlan};
use crate::model::Problem;
use crate::utils::pool::{self, ExecBudget, SyncSlice};
use gradient::{grad_edge, grad_norm_ports, gradient_sparse, GradScratch};
use kernels::ascend_edge;
use projection::{project, project_instances};

/// Learning-rate schedule.  The paper's experiments use a multiplicative
/// decay η_{t+1} = λ·η_t (Alg. 1 step 32) around the Eq. 50 oracle rate;
/// `Oracle` implements Eq. 50 directly (diam(Y) / (‖∇q‖·√T)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LearningRate {
    /// η_t = η₀ · λ^t (Tab. 2 defaults: η₀ = 25, λ = 0.9999).
    Decay { eta0: f64, lambda: f64 },
    /// Eq. 50: η_t = diam(Y) / (‖∇q(t)‖ √T), with a cap for ‖∇q‖ → 0.
    Oracle { horizon: usize },
    /// Fixed rate (regret-theory setting of Thm. 1's proof).
    Constant(f64),
}

impl LearningRate {
    /// Closed-form η_t.  For the Decay schedule this is the *reference*
    /// form only: the hot path maintains η multiplicatively
    /// (`OgaState::step`, η_{t+1} = λ·η_t — Alg. 1 step 32) because the
    /// closed form re-exponentiates from scratch every slot and its old
    /// `powi(t as i32)` cast truncated for horizons beyond i32::MAX.
    pub fn eta(&self, problem: &Problem, t: usize, grad_norm: f64) -> f64 {
        match *self {
            LearningRate::Decay { eta0, lambda } => eta0 * lambda.powf(t as f64),
            LearningRate::Oracle { horizon } => {
                let g = grad_norm.max(1e-9);
                problem.diam_upper() / (g * (horizon.max(1) as f64).sqrt())
            }
            LearningRate::Constant(eta) => eta,
        }
    }
}

/// Mutable OGA state: the current decision y(t) plus reusable scratch
/// buffers.  `step` performs Alg. 1 lines 3–32 for one slot without any
/// heap allocation after construction (scratch is pre-sized).
#[derive(Clone, Debug)]
pub struct OgaState {
    /// Current decision y(t), edge-major [E, K].
    ///
    /// Invariant relied on by the dirty-instance projection: between
    /// steps, `y` is feasible.  `step` only re-projects instances its
    /// own ascent perturbed, so after writing `y` directly (warm
    /// starts, tests) call [`OgaState::invalidate`] to make the next
    /// step re-project every instance.
    pub y: Vec<f64>,
    /// Slot counter (t starts at 0 == paper's t = 1).
    pub t: usize,
    pub lr: LearningRate,
    /// Execution budget; `budget.shards` bounds the projection workers
    /// of the unbound (plan-less) paths (0 = auto).
    pub budget: ExecBudget,
    grad: Vec<f64>,
    scratch: GradScratch,
    scratch_quota: Vec<f64>,
    /// Running η for the Decay schedule (η_{t+1} = λ·η_t, Alg. 1 l.32).
    /// Maintained multiplicatively: the closed form η₀λ^t costs a
    /// `powf` per slot and the seed's `powi(t as i32)` truncated the
    /// exponent for horizons beyond i32::MAX.
    eta_run: f64,
    /// Instances perturbed by the current slot's ascent (flags + list).
    dirty: Vec<bool>,
    dirty_list: Vec<usize>,
    /// Ports whose slices of `grad` are live (Oracle path; lets the
    /// next slot zero exactly those instead of the whole buffer).
    grad_ports: Vec<usize>,
    /// Set by `invalidate`: the next step projects globally because `y`
    /// was written from outside and may be infeasible anywhere.
    full_project_pending: bool,
    /// Shard plan bound by the sharded coordinator (§Perf-3): when set
    /// with > 1 shard, the fused ascent and the dirty projection fan out
    /// per shard instead of running serially — bit-identically, since
    /// per-coordinate math is unchanged and shards own disjoint
    /// coordinates.
    plan: Option<Arc<ShardPlan>>,
    /// Phase-A records of the sharded ascent (arrived ports' η·x, k*).
    port_steps: Vec<ArrivedPort>,
    /// Per-shard dirty partitions (projection scatter scratch).
    shard_dirty: Vec<Vec<usize>>,
}

impl OgaState {
    /// y(1) = 0 is feasible (Y contains the origin) and is the paper's
    /// un-boosted initialization (Sec. 4.1 notes the early oscillation).
    pub fn new(problem: &Problem, lr: LearningRate, budget: ExecBudget) -> Self {
        OgaState {
            y: vec![0.0; problem.decision_len()],
            t: 0,
            lr,
            budget,
            grad: vec![0.0; problem.decision_len()],
            scratch: GradScratch::default(),
            scratch_quota: Vec::new(),
            eta_run: match lr {
                LearningRate::Decay { eta0, .. } => eta0,
                _ => 0.0,
            },
            dirty: vec![false; problem.num_instances()],
            dirty_list: Vec::new(),
            grad_ports: Vec::new(),
            full_project_pending: false,
            plan: None,
            port_steps: Vec::new(),
            shard_dirty: Vec::new(),
        }
    }

    /// Bind a shard plan (see the `plan` field).  The sharded
    /// coordinator calls this through `Policy::bind_shards`; unbound
    /// states keep the serial paths.
    pub fn bind_shards(&mut self, plan: Arc<ShardPlan>) {
        self.shard_dirty = vec![Vec::new(); plan.num_shards()];
        self.plan = Some(plan);
    }

    /// Declare `y` externally modified: the next `step` re-projects
    /// every instance instead of only the arrived neighborhood.
    pub fn invalidate(&mut self) {
        self.full_project_pending = true;
    }

    /// Carry the learned decision across a topology edition
    /// (`sim::faults`): every edge id shifts when the edge set changes,
    /// so the tensor is re-gathered by `(l, r)` key — surviving channels
    /// keep their allocation, removed channels' coordinates cease to
    /// exist (no allocation can land on failed capacity), new channels
    /// start at the 0 the fresh-state initialization uses.  The carried
    /// tensor stays feasible: removals only shrink instance sums and
    /// additions contribute nothing, so no re-projection is needed.
    /// The learning clock (`t`, the running η) carries; the scratch,
    /// dirty tracking and any bound shard plan are dropped (the next
    /// sharded run re-binds against the new edition's plan).
    pub fn remap(&mut self, old_graph: &crate::graph::Bipartite, problem: &Problem) {
        let k_n = problem.num_resources;
        let g = &problem.graph;
        let mut y = vec![0.0; problem.decision_len()];
        for e in 0..g.num_edges() {
            let l = g.edge_port[e];
            let r = g.edge_instance[e];
            if let Some(old_e) = old_graph.edge_id(l, r) {
                let src = old_e * k_n;
                let dst = e * k_n;
                y[dst..dst + k_n].copy_from_slice(&self.y[src..src + k_n]);
            }
        }
        self.y = y;
        self.grad = vec![0.0; problem.decision_len()];
        self.grad_ports.clear();
        self.port_steps.clear();
        self.dirty.clear();
        self.dirty.resize(problem.num_instances(), false);
        self.dirty_list.clear();
        self.plan = None;
        self.shard_dirty.clear();
    }

    /// Serialize the resume-sufficient state (`sim::checkpoint`): the
    /// learned tensor y(t), the slot clock, and the running η.  Nothing
    /// else survives a cut on purpose — the gradient/dirty scratch is
    /// recomputed from scratch at every step's start, and checkpoints
    /// are taken *between* slots where y is feasible and no projection
    /// is pending.
    pub fn snapshot(&self, w: &mut crate::utils::codec::Writer) {
        w.put_f64s(&self.y);
        w.put_u64(self.t as u64);
        w.put_f64(self.eta_run);
    }

    /// Rebuild from [`OgaState::snapshot`] on top of a freshly
    /// constructed state for the restored problem (scratch, dirty
    /// tracking and plan binding all start clean, exactly like a new
    /// run's first slot).
    pub fn restore(
        &mut self,
        problem: &Problem,
        r: &mut crate::utils::codec::Reader,
    ) -> Result<(), String> {
        let y = r.get_f64s()?;
        if y.len() != problem.decision_len() {
            return Err(format!(
                "oga snapshot: y len {} vs decision len {} (wrong edition?)",
                y.len(),
                problem.decision_len()
            ));
        }
        self.y = y;
        self.t = r.get_u64()? as usize;
        self.eta_run = r.get_f64()?;
        self.full_project_pending = false;
        Ok(())
    }

    /// One OGA slot: observe x(t), ascend the reward gradient at
    /// (x(t), y(t)), project back onto Y.  Returns the step size used.
    ///
    /// Hot-path notes (§Perf):
    /// * When η_t does not depend on ‖∇q‖ (decay / constant schedules)
    ///   the gradient is *fused into the ascent* — only the arrived
    ///   ports' coordinates are touched and no gradient buffer is
    ///   materialized.  The Oracle schedule (Eq. 50) needs the norm
    ///   first, so it keeps the two-pass path.
    /// * The ascent only perturbs instances adjacent to arrived ports
    ///   (the *dirty* set); every other column of y was feasible before
    ///   the step and is untouched, so the projection re-runs only the
    ///   dirty channels.  With sparse graphs / sparse arrivals this is
    ///   the difference between O(|E_x|·K) and O(L·R·K) per slot.
    pub fn step(&mut self, problem: &Problem, x: &[f64]) -> f64 {
        for &r in &self.dirty_list {
            self.dirty[r] = false;
        }
        self.dirty_list.clear();
        let eta = match self.lr {
            LearningRate::Oracle { .. } => {
                match active_plan(&self.plan) {
                    // Sharded two-pass (§Perf-4): per-edge gradient
                    // fill and ascent fan out over the bound plan; the
                    // ‖∇q‖ reduction replays serially on this thread in
                    // the serial order, so η — and with it the whole
                    // trajectory — is bit-identical to the serial path.
                    Some(plan) => {
                        gradient_sparse_sharded(
                            problem,
                            x,
                            &self.y,
                            &mut self.grad,
                            &mut self.grad_ports,
                            &mut self.port_steps,
                            &plan,
                        );
                        let gnorm =
                            grad_norm_ports(problem, &self.grad, &self.grad_ports);
                        let eta = self.lr.eta(problem, self.t, gnorm);
                        ascend_ports_sharded(
                            problem,
                            &mut self.y,
                            &self.grad,
                            &self.port_steps,
                            eta,
                            &plan,
                        );
                        self.mark_dirty_from_grad_ports(problem);
                        eta
                    }
                    None => {
                        // Sparse two-pass path (§Perf-2): the gradient,
                        // its norm, and the ascent all touch only the
                        // arrived ports' slices — the gradient is zero
                        // everywhere else, so nothing here scales with
                        // |E|.
                        gradient_sparse(
                            problem,
                            problem.kinds(),
                            x,
                            &self.y,
                            &mut self.grad,
                            &mut self.scratch,
                            &mut self.grad_ports,
                        );
                        let gnorm =
                            grad_norm_ports(problem, &self.grad, &self.grad_ports);
                        let eta = self.lr.eta(problem, self.t, gnorm);
                        let k_n = problem.num_resources;
                        for &l in &self.grad_ports {
                            let lo = problem.graph.port_ptr[l] * k_n;
                            let hi = problem.graph.port_ptr[l + 1] * k_n;
                            for i in lo..hi {
                                self.y[i] += eta * self.grad[i];
                            }
                        }
                        // only the arrived ports' instances were perturbed
                        self.mark_dirty_from_grad_ports(problem);
                        eta
                    }
                }
            }
            LearningRate::Decay { lambda, .. } => {
                let eta = self.eta_run;
                self.eta_run *= lambda;
                self.ascend(problem, x, eta);
                eta
            }
            LearningRate::Constant(eta) => {
                self.ascend(problem, x, eta);
                eta
            }
        };
        if self.full_project_pending {
            project(problem, &mut self.y, self.budget.shards);
            self.full_project_pending = false;
        } else {
            match active_plan(&self.plan) {
                Some(plan) => project_dirty_sharded(
                    problem,
                    &mut self.y,
                    &self.dirty_list,
                    &plan,
                    &mut self.shard_dirty,
                ),
                None => project_instances(
                    problem,
                    &mut self.y,
                    &self.dirty_list,
                    self.budget.shards,
                ),
            }
        }
        self.t += 1;
        eta
    }

    /// Route the fused ascent: per-shard when a multi-shard plan is
    /// bound, the serial kernel otherwise.  Identical floats either way.
    fn ascend(&mut self, problem: &Problem, x: &[f64], eta: f64) {
        match active_plan(&self.plan) {
            Some(plan) => self.fused_ascent_sharded(problem, x, eta, &plan),
            None => self.fused_ascent(problem, x, eta),
        }
    }

    /// y += η·∇q(x, y) touching only the arrived ports (Eq. 30 inline).
    /// Public for the layout-parity suite and the hot-path bench; normal
    /// callers go through [`OgaState::step`].
    ///
    /// §Perf-2: the marginal-gain pass is kind-batched — one utility
    /// family dispatch per [`KindIndex`] run, then a branch-free
    /// contiguous sweep; the Eq. 27 penalty is a second strided pass
    /// over the k* lane (f' is evaluated at the pre-update y either
    /// way, so the two-pass split is exact up to rounding).
    pub fn fused_ascent(&mut self, problem: &Problem, x: &[f64], eta: f64) {
        let k_n = problem.num_resources;
        self.scratch_quota.resize(k_n, 0.0);
        let g = &problem.graph;
        for l in 0..problem.num_ports() {
            let x_l = x[l];
            if x_l == 0.0 {
                continue;
            }
            let edges = g.port_edges(l);
            let kstar = port_kstar(problem, l, &self.y, &mut self.scratch_quota);
            let kinds = problem.kinds();
            for run in kinds.port_runs(l) {
                run.kind.ascend_slice(
                    &mut self.y[run.lo..run.hi],
                    &kinds.alpha_flat[run.lo..run.hi],
                    eta * x_l,
                );
            }
            let pen = eta * x_l * problem.beta[kstar];
            for e in edges {
                let r = g.edge_instance[e];
                if !self.dirty[r] {
                    self.dirty[r] = true;
                    self.dirty_list.push(r);
                }
                self.y[e * k_n + kstar] -= pen;
            }
        }
    }

    /// Sharded fused ascent (§Perf-3).  Phase A (leader thread) runs
    /// the per-port quota/k* reductions — reads only, identical floats
    /// to the serial kernel since ports own disjoint slices — and marks
    /// the dirty instances in the serial discovery order.  Phase B fans
    /// the per-coordinate updates out over the pool: each shard applies
    /// every arrived port's recorded step to exactly the edges it owns,
    /// so writes are disjoint and each coordinate sees the same two
    /// operations (ascend, then k*-lane penalty) in the same order as
    /// the serial kernel.
    fn fused_ascent_sharded(
        &mut self,
        problem: &Problem,
        x: &[f64],
        eta: f64,
        plan: &ShardPlan,
    ) {
        let k_n = problem.num_resources;
        self.scratch_quota.resize(k_n, 0.0);
        self.port_steps.clear();
        let g = &problem.graph;
        for l in 0..problem.num_ports() {
            let x_l = x[l];
            if x_l == 0.0 {
                continue;
            }
            let edges = g.port_edges(l);
            let kstar = port_kstar(problem, l, &self.y, &mut self.scratch_quota);
            let scale = eta * x_l;
            self.port_steps.push(ArrivedPort {
                l,
                scale,
                kstar,
                pen: scale * problem.beta[kstar],
            });
            for e in edges {
                let r = g.edge_instance[e];
                if !self.dirty[r] {
                    self.dirty[r] = true;
                    self.dirty_list.push(r);
                }
            }
        }
        if self.port_steps.is_empty() {
            return;
        }
        let steps = &self.port_steps;
        let kinds = problem.kinds();
        let view = SyncSlice::new(&mut self.y);
        let y_len = view.len();
        pool::parallel_for(plan.num_shards(), plan.num_shards(), |s| {
            // SAFETY: every edge belongs to exactly one instance, and
            // the plan assigns each instance to exactly one shard — the
            // coordinate sets written by distinct shards are disjoint.
            let y = unsafe { view.slice_mut(0, y_len) };
            for step in steps {
                for &e in plan.port_edges(s, step.l) {
                    ascend_edge(problem, kinds, y, e, step.scale);
                    y[e * k_n + step.kstar] -= step.pen;
                }
            }
        });
    }

    fn mark_dirty_from_grad_ports(&mut self, problem: &Problem) {
        let g = &problem.graph;
        for &l in &self.grad_ports {
            for e in g.port_edges(l) {
                let r = g.edge_instance[e];
                if !self.dirty[r] {
                    self.dirty[r] = true;
                    self.dirty_list.push(r);
                }
            }
        }
    }

    /// Instances perturbed by the most recent ascent (valid between the
    /// ascent and the next `step`; exposed for tests and diagnostics).
    pub fn dirty_instances(&self) -> &[usize] {
        &self.dirty_list
    }

    /// Current gradient buffer (valid after `step`; exposed for tests
    /// and the Thm. 1 bound checks).
    pub fn last_grad(&self) -> &[f64] {
        &self.grad
    }
}

/// Port l's resource quota Σ_{r∈R_l} y (into `quota`) and the Eq. 27
/// argmax lane k*.  The single shared reduction behind the serial and
/// sharded OGA ascents *and* the mirror update — one implementation, so
/// plan-bound and unbound runs agree bit for bit by construction.
pub(crate) fn port_kstar(problem: &Problem, l: usize, y: &[f64], quota: &mut [f64]) -> usize {
    let k_n = problem.num_resources;
    debug_assert_eq!(quota.len(), k_n);
    quota.fill(0.0);
    for e in problem.graph.port_edges(l) {
        let base = e * k_n;
        kernels::accumulate(quota, &y[base..base + k_n]);
    }
    let mut kstar = 0;
    let mut best = f64::NEG_INFINITY;
    for k in 0..k_n {
        let v = problem.beta[k] * quota[k];
        if v > best {
            best = v;
            kstar = k;
        }
    }
    kstar
}

/// Sharded sparse gradient fill (§Perf-4, phase A sharded in §Perf-5) —
/// the two-pass companion of [`gradient::gradient_sparse`], shared by
/// the plan-bound Eq. 50 oracle-rate step and `regret::solve_oracle`.
/// Phase A re-zeroes the slices the *previous* call filled and collects
/// the arrived ports in the serial port order (caller thread), then
/// fans the per-port quota/k\* reductions out over the pool: each
/// arrived port's reduction is independent, reads only `y`, and is
/// replayed whole by exactly one worker through the same
/// [`port_kstar`] kernel — identical floats regardless of which worker
/// runs it.  Phase B fans the per-edge `grad` writes out over the
/// plan: each shard fills exactly the coordinates of the edges it owns
/// through the same element-wise `grad_into` kernel (cut at edge
/// boundaries, which the kernel cannot observe) and applies the Eq. 27
/// penalty on the k\* lane — so the resulting buffer equals the serial
/// `gradient_sparse` output bit for bit.
pub(crate) fn gradient_sparse_sharded(
    problem: &Problem,
    x: &[f64],
    y: &[f64],
    grad: &mut [f64],
    active: &mut Vec<usize>,
    steps: &mut Vec<ArrivedPort>,
    plan: &ShardPlan,
) {
    let k_n = problem.num_resources;
    for &l in active.iter() {
        let lo = problem.graph.port_ptr[l] * k_n;
        let hi = problem.graph.port_ptr[l + 1] * k_n;
        grad[lo..hi].fill(0.0);
    }
    active.clear();
    steps.clear();
    for l in 0..problem.num_ports() {
        let x_l = x[l];
        if x_l == 0.0 {
            continue;
        }
        steps.push(ArrivedPort { l, scale: x_l, kstar: 0, pen: 0.0 });
        active.push(l);
    }
    if steps.is_empty() {
        return;
    }
    // Phase A fan-out (§Perf-5): fill each recorded step's quota/k*.
    // Per-position writes are disjoint; the [K] quota scratch is
    // per-thread (`reward::with_quota`).
    {
        let view = SyncSlice::new(steps.as_mut_slice());
        let n = view.len();
        pool::parallel_for(n, plan.num_shards(), |i| {
            // SAFETY: position i is handed to exactly one task.
            let step = unsafe { &mut view.slice_mut(i, i + 1)[0] };
            let kstar = crate::reward::with_quota(k_n, |quota| {
                port_kstar(problem, step.l, y, quota)
            });
            step.kstar = kstar;
            step.pen = step.scale * problem.beta[kstar];
        });
    }
    let kinds = problem.kinds();
    let steps_ref: &[ArrivedPort] = steps;
    let view = SyncSlice::new(grad);
    let g_len = view.len();
    pool::parallel_for(plan.num_shards(), plan.num_shards(), |s| {
        // SAFETY: every edge belongs to exactly one instance, and the
        // plan assigns each instance to exactly one shard — the
        // coordinate sets written by distinct shards are disjoint.
        let grad = unsafe { view.slice_mut(0, g_len) };
        for step in steps_ref {
            for &e in plan.port_edges(s, step.l) {
                grad_edge(problem, kinds, y, grad, e, step.scale);
                grad[e * k_n + step.kstar] -= step.pen;
            }
        }
    });
}

/// Sharded ascent over the recorded arrived-port steps:
/// `y[j] += η·grad[j]` on every coordinate of every arrived port's
/// edges, each shard writing only the edges it owns.  One add per
/// coordinate — exactly the serial two-pass ascent, so the floats are
/// identical by construction.
pub(crate) fn ascend_ports_sharded(
    problem: &Problem,
    y: &mut [f64],
    grad: &[f64],
    steps: &[ArrivedPort],
    eta: f64,
    plan: &ShardPlan,
) {
    if steps.is_empty() {
        return;
    }
    let k_n = problem.num_resources;
    let view = SyncSlice::new(y);
    let y_len = view.len();
    pool::parallel_for(plan.num_shards(), plan.num_shards(), |s| {
        // SAFETY: disjoint edge ownership per shard, as above.
        let y = unsafe { view.slice_mut(0, y_len) };
        for step in steps {
            for &e in plan.port_edges(s, step.l) {
                let base = e * k_n;
                for j in base..base + k_n {
                    y[j] += eta * grad[j];
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::model::KindIndex;
    use crate::reward::slot_reward;
    use crate::traces::synthesize;

    #[test]
    fn step_keeps_feasibility() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Decay { eta0: 25.0, lambda: 0.9999 }, ExecBudget::auto());
        let x = vec![1.0; p.num_ports()];
        for _ in 0..20 {
            s.step(&p, &x);
            p.check_feasible(&s.y, 1e-7).unwrap();
        }
    }

    #[test]
    fn step_with_partial_arrivals_keeps_feasibility() {
        // only some ports arrive -> only their instances are dirty; the
        // result must still be globally feasible every slot
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Decay { eta0: 25.0, lambda: 0.999 }, ExecBudget::auto());
        let mut rng = crate::utils::rng::Rng::new(17);
        for _ in 0..40 {
            let x: Vec<f64> = (0..p.num_ports())
                .map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 })
                .collect();
            s.step(&p, &x);
            p.check_feasible(&s.y, 1e-7).unwrap();
        }
    }

    #[test]
    fn dirty_set_is_exactly_arrived_neighborhood() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Constant(1.0), ExecBudget::auto());
        let mut x = vec![0.0; p.num_ports()];
        x[0] = 1.0;
        s.step(&p, &x);
        let mut want: Vec<usize> = p.graph.ports_to_instances[0].clone();
        want.sort_unstable();
        let mut got = s.dirty_instances().to_vec();
        got.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn invalidate_forces_global_reprojection() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Constant(0.5), ExecBudget::auto());
        // plant an infeasible decision everywhere, then arrive only at
        // port 0: without invalidate(), instances outside port 0's
        // neighborhood would never be re-projected
        for v in s.y.iter_mut() {
            *v = 1e6;
        }
        s.invalidate();
        let mut x = vec![0.0; p.num_ports()];
        x[0] = 1.0;
        s.step(&p, &x);
        p.check_feasible(&s.y, 1e-6).unwrap();
    }

    #[test]
    fn reward_climbs_under_stationary_arrivals() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Decay { eta0: 5.0, lambda: 0.999 }, ExecBudget::auto());
        let x = vec![1.0; p.num_ports()];
        let r0 = slot_reward(&p, &x, &s.y).q;
        for _ in 0..100 {
            s.step(&p, &x);
        }
        let r1 = slot_reward(&p, &x, &s.y).q;
        assert!(r1 > r0, "reward did not improve: {r0} -> {r1}");
    }

    #[test]
    fn decay_schedule_matches_formula() {
        let p = synthesize(&Scenario::small());
        let lr = LearningRate::Decay { eta0: 25.0, lambda: 0.9 };
        assert!((lr.eta(&p, 0, 1.0) - 25.0).abs() < 1e-12);
        assert!((lr.eta(&p, 2, 1.0) - 25.0 * 0.81).abs() < 1e-9);
    }

    #[test]
    fn running_eta_matches_closed_form() {
        // the Decay schedule is maintained multiplicatively in step();
        // the closed form eta0 * lambda^t is the parity reference
        let p = synthesize(&Scenario::small());
        let lr = LearningRate::Decay { eta0: 2.0, lambda: 0.999 };
        let mut s = OgaState::new(&p, lr, ExecBudget::auto());
        let x = vec![1.0; p.num_ports()];
        for t in 0..500 {
            let used = s.step(&p, &x);
            let want = lr.eta(&p, t, 0.0);
            assert!(
                (used - want).abs() <= 1e-9 * want.max(1.0),
                "t={t}: recurrence {used} vs closed form {want}"
            );
        }
    }

    #[test]
    fn oracle_sparse_path_matches_full_reference() {
        // the Oracle branch computes gradient/norm/ascent only on the
        // arrived ports' slices; it must equal the naive full-buffer
        // two-pass step (gradient is zero off the arrived neighborhood)
        let p = synthesize(&Scenario::small());
        let kinds = KindIndex::build(&p);
        let horizon = 40;
        let lr = LearningRate::Oracle { horizon };
        let mut s = OgaState::new(&p, lr, ExecBudget::auto());
        let mut y_ref = vec![0.0; p.decision_len()];
        let mut grad = vec![0.0; p.decision_len()];
        let mut scratch = GradScratch::default();
        let mut rng = crate::utils::rng::Rng::new(11);
        for t in 0..12 {
            let x: Vec<f64> = (0..p.num_ports())
                .map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 })
                .collect();
            s.step(&p, &x);
            gradient::gradient(&p, &kinds, &x, &y_ref, &mut grad, &mut scratch);
            let eta = lr.eta(&p, t, gradient::grad_norm(&grad));
            for i in 0..y_ref.len() {
                y_ref[i] += eta * grad[i];
            }
            project(&p, &mut y_ref, 0);
            for i in 0..y_ref.len() {
                assert!(
                    (s.y[i] - y_ref[i]).abs() < 1e-9,
                    "t={t} i={i}: sparse {} vs full {}",
                    s.y[i],
                    y_ref[i]
                );
            }
        }
    }

    #[test]
    fn oracle_rate_uses_diam_and_gradnorm() {
        let p = synthesize(&Scenario::small());
        let lr = LearningRate::Oracle { horizon: 100 };
        let eta = lr.eta(&p, 0, 2.0);
        assert!((eta - p.diam_upper() / (2.0 * 10.0)).abs() < 1e-9);
    }

    #[test]
    fn sharded_step_matches_serial_bitwise() {
        // the §Perf-3/§Perf-4 invariant at the OgaState level: binding a
        // shard plan changes who computes each coordinate, never its
        // value — trajectories (and dirty-set discovery order) are
        // bit-identical for both the fused-ascent schedules and the
        // Eq. 50 oracle-rate two-pass (whose ‖∇q‖ reduction replays
        // serially on the driver).
        use crate::coordinator::sharded::ShardPlan;
        use std::sync::Arc;
        let p = synthesize(&Scenario::small());
        for lr in [
            LearningRate::Decay { eta0: 2.0, lambda: 0.999 },
            LearningRate::Oracle { horizon: 64 },
        ] {
            let mut rng = crate::utils::rng::Rng::new(23);
            for shards in [2, 3, 7] {
                let mut serial = OgaState::new(&p, lr, ExecBudget::auto());
                let mut sharded = OgaState::new(&p, lr, ExecBudget::auto());
                sharded.bind_shards(Arc::new(ShardPlan::build(&p, shards)));
                for t in 0..30 {
                    let x: Vec<f64> = (0..p.num_ports())
                        .map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 })
                        .collect();
                    let e1 = serial.step(&p, &x);
                    let e2 = sharded.step(&p, &x);
                    assert_eq!(e1, e2, "{lr:?} shards={shards} t={t}");
                    assert_eq!(serial.y, sharded.y, "{lr:?} shards={shards} t={t}");
                    assert_eq!(serial.dirty_instances(), sharded.dirty_instances());
                    assert_eq!(serial.last_grad(), sharded.last_grad());
                }
            }
        }
    }

    #[test]
    fn remap_carries_surviving_channels() {
        let p0 = synthesize(&Scenario::small());
        let mut p = p0.clone();
        let mut s =
            OgaState::new(&p0, LearningRate::Decay { eta0: 5.0, lambda: 0.999 }, ExecBudget::auto());
        let x = vec![1.0; p0.num_ports()];
        for _ in 0..10 {
            s.step(&p0, &x);
        }
        let y_old = s.y.clone();
        let t_old = s.t;
        let victim = 0;
        p.remove_instance_edges(victim).unwrap();
        s.remap(&p0.graph, &p);
        assert_eq!(s.t, t_old, "learning clock must carry");
        assert_eq!(s.y.len(), p.decision_len());
        p.check_feasible(&s.y, 1e-7).unwrap();
        let k_n = p.num_resources;
        for e in 0..p.num_edges() {
            let l = p.graph.edge_port[e];
            let r = p.graph.edge_instance[e];
            let old_e = p0.graph.edge_id(l, r).unwrap();
            assert_eq!(
                &s.y[e * k_n..(e + 1) * k_n],
                &y_old[old_e * k_n..(old_e + 1) * k_n],
                "channel ({l},{r}) lost its allocation"
            );
        }
        // learning continues on the new edition without issue
        for _ in 0..5 {
            s.step(&p, &x);
            p.check_feasible(&s.y, 1e-7).unwrap();
        }
    }

    #[test]
    fn zero_arrivals_leave_y_fixed() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Constant(1.0), ExecBudget::auto());
        let x_on = vec![1.0; p.num_ports()];
        let x_off = vec![0.0; p.num_ports()];
        for _ in 0..5 {
            s.step(&p, &x_on);
        }
        let before = s.y.clone();
        s.step(&p, &x_off);
        // zero gradient => empty dirty set => the step is a no-op (the
        // dirty-tracking projection doesn't even re-project)
        assert_eq!(s.y, before);
        assert!(s.dirty_instances().is_empty());
    }
}
