//! OGASCHED's algorithmic core: utility calculus, the Eq. 30 gradient,
//! the Alg. 1 fast projection, the learning-rate schedule, and the
//! per-slot stepper that ties them together.

pub mod dense_ref;
pub mod gradient;
pub mod projection;
pub mod utilities;

use crate::model::{KindIndex, Problem};
use gradient::{grad_norm_ports, gradient_sparse, GradScratch};
use projection::{project, project_instances};

/// Learning-rate schedule.  The paper's experiments use a multiplicative
/// decay η_{t+1} = λ·η_t (Alg. 1 step 32) around the Eq. 50 oracle rate;
/// `Oracle` implements Eq. 50 directly (diam(Y) / (‖∇q‖·√T)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LearningRate {
    /// η_t = η₀ · λ^t (Tab. 2 defaults: η₀ = 25, λ = 0.9999).
    Decay { eta0: f64, lambda: f64 },
    /// Eq. 50: η_t = diam(Y) / (‖∇q(t)‖ √T), with a cap for ‖∇q‖ → 0.
    Oracle { horizon: usize },
    /// Fixed rate (regret-theory setting of Thm. 1's proof).
    Constant(f64),
}

impl LearningRate {
    /// Closed-form η_t.  For the Decay schedule this is the *reference*
    /// form only: the hot path maintains η multiplicatively
    /// (`OgaState::step`, η_{t+1} = λ·η_t — Alg. 1 step 32) because the
    /// closed form re-exponentiates from scratch every slot and its old
    /// `powi(t as i32)` cast truncated for horizons beyond i32::MAX.
    pub fn eta(&self, problem: &Problem, t: usize, grad_norm: f64) -> f64 {
        match *self {
            LearningRate::Decay { eta0, lambda } => eta0 * lambda.powf(t as f64),
            LearningRate::Oracle { horizon } => {
                let g = grad_norm.max(1e-9);
                problem.diam_upper() / (g * (horizon.max(1) as f64).sqrt())
            }
            LearningRate::Constant(eta) => eta,
        }
    }
}

/// Mutable OGA state: the current decision y(t) plus reusable scratch
/// buffers.  `step` performs Alg. 1 lines 3–32 for one slot without any
/// heap allocation after construction (scratch is pre-sized).
#[derive(Clone, Debug)]
pub struct OgaState {
    /// Current decision y(t), edge-major [E, K].
    ///
    /// Invariant relied on by the dirty-instance projection: between
    /// steps, `y` is feasible.  `step` only re-projects instances its
    /// own ascent perturbed, so after writing `y` directly (warm
    /// starts, tests) call [`OgaState::invalidate`] to make the next
    /// step re-project every instance.
    pub y: Vec<f64>,
    /// Slot counter (t starts at 0 == paper's t = 1).
    pub t: usize,
    pub lr: LearningRate,
    /// Worker threads for the projection (0 = auto).
    pub workers: usize,
    grad: Vec<f64>,
    scratch: GradScratch,
    scratch_quota: Vec<f64>,
    /// Kind-grouped runs + flattened α for the batched kernels (§Perf-2).
    kinds: KindIndex,
    /// Running η for the Decay schedule (η_{t+1} = λ·η_t, Alg. 1 l.32).
    /// Maintained multiplicatively: the closed form η₀λ^t costs a
    /// `powf` per slot and the seed's `powi(t as i32)` truncated the
    /// exponent for horizons beyond i32::MAX.
    eta_run: f64,
    /// Instances perturbed by the current slot's ascent (flags + list).
    dirty: Vec<bool>,
    dirty_list: Vec<usize>,
    /// Ports whose slices of `grad` are live (Oracle path; lets the
    /// next slot zero exactly those instead of the whole buffer).
    grad_ports: Vec<usize>,
    /// Set by `invalidate`: the next step projects globally because `y`
    /// was written from outside and may be infeasible anywhere.
    full_project_pending: bool,
}

impl OgaState {
    /// y(1) = 0 is feasible (Y contains the origin) and is the paper's
    /// un-boosted initialization (Sec. 4.1 notes the early oscillation).
    pub fn new(problem: &Problem, lr: LearningRate, workers: usize) -> Self {
        OgaState {
            y: vec![0.0; problem.decision_len()],
            t: 0,
            lr,
            workers,
            grad: vec![0.0; problem.decision_len()],
            scratch: GradScratch::default(),
            scratch_quota: Vec::new(),
            kinds: KindIndex::build(problem),
            eta_run: match lr {
                LearningRate::Decay { eta0, .. } => eta0,
                _ => 0.0,
            },
            dirty: vec![false; problem.num_instances()],
            dirty_list: Vec::new(),
            grad_ports: Vec::new(),
            full_project_pending: false,
        }
    }

    /// Declare `y` externally modified: the next `step` re-projects
    /// every instance instead of only the arrived neighborhood.
    pub fn invalidate(&mut self) {
        self.full_project_pending = true;
    }

    /// One OGA slot: observe x(t), ascend the reward gradient at
    /// (x(t), y(t)), project back onto Y.  Returns the step size used.
    ///
    /// Hot-path notes (§Perf):
    /// * When η_t does not depend on ‖∇q‖ (decay / constant schedules)
    ///   the gradient is *fused into the ascent* — only the arrived
    ///   ports' coordinates are touched and no gradient buffer is
    ///   materialized.  The Oracle schedule (Eq. 50) needs the norm
    ///   first, so it keeps the two-pass path.
    /// * The ascent only perturbs instances adjacent to arrived ports
    ///   (the *dirty* set); every other column of y was feasible before
    ///   the step and is untouched, so the projection re-runs only the
    ///   dirty channels.  With sparse graphs / sparse arrivals this is
    ///   the difference between O(|E_x|·K) and O(L·R·K) per slot.
    pub fn step(&mut self, problem: &Problem, x: &[f64]) -> f64 {
        for &r in &self.dirty_list {
            self.dirty[r] = false;
        }
        self.dirty_list.clear();
        let eta = match self.lr {
            LearningRate::Oracle { .. } => {
                // Sparse two-pass path (§Perf-2): the gradient, its
                // norm, and the ascent all touch only the arrived
                // ports' slices — the gradient is zero everywhere else,
                // so nothing here scales with |E|.
                gradient_sparse(
                    problem,
                    &self.kinds,
                    x,
                    &self.y,
                    &mut self.grad,
                    &mut self.scratch,
                    &mut self.grad_ports,
                );
                let gnorm = grad_norm_ports(problem, &self.grad, &self.grad_ports);
                let eta = self.lr.eta(problem, self.t, gnorm);
                let k_n = problem.num_resources;
                for &l in &self.grad_ports {
                    let lo = problem.graph.port_ptr[l] * k_n;
                    let hi = problem.graph.port_ptr[l + 1] * k_n;
                    for i in lo..hi {
                        self.y[i] += eta * self.grad[i];
                    }
                }
                // only the arrived ports' instances were perturbed
                self.mark_dirty_from_grad_ports(problem);
                eta
            }
            LearningRate::Decay { lambda, .. } => {
                let eta = self.eta_run;
                self.eta_run *= lambda;
                self.fused_ascent(problem, x, eta);
                eta
            }
            LearningRate::Constant(eta) => {
                self.fused_ascent(problem, x, eta);
                eta
            }
        };
        if self.full_project_pending {
            project(problem, &mut self.y, self.workers);
            self.full_project_pending = false;
        } else {
            project_instances(problem, &mut self.y, &self.dirty_list, self.workers);
        }
        self.t += 1;
        eta
    }

    /// y += η·∇q(x, y) touching only the arrived ports (Eq. 30 inline).
    /// Public for the layout-parity suite and the hot-path bench; normal
    /// callers go through [`OgaState::step`].
    ///
    /// §Perf-2: the marginal-gain pass is kind-batched — one utility
    /// family dispatch per [`KindIndex`] run, then a branch-free
    /// contiguous sweep; the Eq. 27 penalty is a second strided pass
    /// over the k* lane (f' is evaluated at the pre-update y either
    /// way, so the two-pass split is exact up to rounding).
    pub fn fused_ascent(&mut self, problem: &Problem, x: &[f64], eta: f64) {
        let k_n = problem.num_resources;
        self.scratch_quota.resize(k_n, 0.0);
        let g = &problem.graph;
        for l in 0..problem.num_ports() {
            let x_l = x[l];
            if x_l == 0.0 {
                continue;
            }
            let edges = g.port_edges(l);
            self.scratch_quota.fill(0.0);
            for e in edges.clone() {
                let base = e * k_n;
                for k in 0..k_n {
                    self.scratch_quota[k] += self.y[base + k];
                }
            }
            let mut kstar = 0;
            let mut best = f64::NEG_INFINITY;
            for k in 0..k_n {
                let v = problem.beta[k] * self.scratch_quota[k];
                if v > best {
                    best = v;
                    kstar = k;
                }
            }
            for run in self.kinds.port_runs(l) {
                run.kind.ascend_slice(
                    &mut self.y[run.lo..run.hi],
                    &self.kinds.alpha_flat[run.lo..run.hi],
                    eta * x_l,
                );
            }
            let pen = eta * x_l * problem.beta[kstar];
            for e in edges {
                let r = g.edge_instance[e];
                if !self.dirty[r] {
                    self.dirty[r] = true;
                    self.dirty_list.push(r);
                }
                self.y[e * k_n + kstar] -= pen;
            }
        }
    }

    fn mark_dirty_from_grad_ports(&mut self, problem: &Problem) {
        let g = &problem.graph;
        for &l in &self.grad_ports {
            for e in g.port_edges(l) {
                let r = g.edge_instance[e];
                if !self.dirty[r] {
                    self.dirty[r] = true;
                    self.dirty_list.push(r);
                }
            }
        }
    }

    /// Instances perturbed by the most recent ascent (valid between the
    /// ascent and the next `step`; exposed for tests and diagnostics).
    pub fn dirty_instances(&self) -> &[usize] {
        &self.dirty_list
    }

    /// Current gradient buffer (valid after `step`; exposed for tests
    /// and the Thm. 1 bound checks).
    pub fn last_grad(&self) -> &[f64] {
        &self.grad
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::reward::slot_reward;
    use crate::traces::synthesize;

    #[test]
    fn step_keeps_feasibility() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Decay { eta0: 25.0, lambda: 0.9999 }, 0);
        let x = vec![1.0; p.num_ports()];
        for _ in 0..20 {
            s.step(&p, &x);
            p.check_feasible(&s.y, 1e-7).unwrap();
        }
    }

    #[test]
    fn step_with_partial_arrivals_keeps_feasibility() {
        // only some ports arrive -> only their instances are dirty; the
        // result must still be globally feasible every slot
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Decay { eta0: 25.0, lambda: 0.999 }, 0);
        let mut rng = crate::utils::rng::Rng::new(17);
        for _ in 0..40 {
            let x: Vec<f64> = (0..p.num_ports())
                .map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 })
                .collect();
            s.step(&p, &x);
            p.check_feasible(&s.y, 1e-7).unwrap();
        }
    }

    #[test]
    fn dirty_set_is_exactly_arrived_neighborhood() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Constant(1.0), 0);
        let mut x = vec![0.0; p.num_ports()];
        x[0] = 1.0;
        s.step(&p, &x);
        let mut want: Vec<usize> = p.graph.ports_to_instances[0].clone();
        want.sort_unstable();
        let mut got = s.dirty_instances().to_vec();
        got.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn invalidate_forces_global_reprojection() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Constant(0.5), 0);
        // plant an infeasible decision everywhere, then arrive only at
        // port 0: without invalidate(), instances outside port 0's
        // neighborhood would never be re-projected
        for v in s.y.iter_mut() {
            *v = 1e6;
        }
        s.invalidate();
        let mut x = vec![0.0; p.num_ports()];
        x[0] = 1.0;
        s.step(&p, &x);
        p.check_feasible(&s.y, 1e-6).unwrap();
    }

    #[test]
    fn reward_climbs_under_stationary_arrivals() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Decay { eta0: 5.0, lambda: 0.999 }, 0);
        let x = vec![1.0; p.num_ports()];
        let r0 = slot_reward(&p, &x, &s.y).q;
        for _ in 0..100 {
            s.step(&p, &x);
        }
        let r1 = slot_reward(&p, &x, &s.y).q;
        assert!(r1 > r0, "reward did not improve: {r0} -> {r1}");
    }

    #[test]
    fn decay_schedule_matches_formula() {
        let p = synthesize(&Scenario::small());
        let lr = LearningRate::Decay { eta0: 25.0, lambda: 0.9 };
        assert!((lr.eta(&p, 0, 1.0) - 25.0).abs() < 1e-12);
        assert!((lr.eta(&p, 2, 1.0) - 25.0 * 0.81).abs() < 1e-9);
    }

    #[test]
    fn running_eta_matches_closed_form() {
        // the Decay schedule is maintained multiplicatively in step();
        // the closed form eta0 * lambda^t is the parity reference
        let p = synthesize(&Scenario::small());
        let lr = LearningRate::Decay { eta0: 2.0, lambda: 0.999 };
        let mut s = OgaState::new(&p, lr, 0);
        let x = vec![1.0; p.num_ports()];
        for t in 0..500 {
            let used = s.step(&p, &x);
            let want = lr.eta(&p, t, 0.0);
            assert!(
                (used - want).abs() <= 1e-9 * want.max(1.0),
                "t={t}: recurrence {used} vs closed form {want}"
            );
        }
    }

    #[test]
    fn oracle_sparse_path_matches_full_reference() {
        // the Oracle branch computes gradient/norm/ascent only on the
        // arrived ports' slices; it must equal the naive full-buffer
        // two-pass step (gradient is zero off the arrived neighborhood)
        let p = synthesize(&Scenario::small());
        let kinds = KindIndex::build(&p);
        let horizon = 40;
        let lr = LearningRate::Oracle { horizon };
        let mut s = OgaState::new(&p, lr, 0);
        let mut y_ref = vec![0.0; p.decision_len()];
        let mut grad = vec![0.0; p.decision_len()];
        let mut scratch = GradScratch::default();
        let mut rng = crate::utils::rng::Rng::new(11);
        for t in 0..12 {
            let x: Vec<f64> = (0..p.num_ports())
                .map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 })
                .collect();
            s.step(&p, &x);
            gradient::gradient(&p, &kinds, &x, &y_ref, &mut grad, &mut scratch);
            let eta = lr.eta(&p, t, gradient::grad_norm(&grad));
            for i in 0..y_ref.len() {
                y_ref[i] += eta * grad[i];
            }
            project(&p, &mut y_ref, 0);
            for i in 0..y_ref.len() {
                assert!(
                    (s.y[i] - y_ref[i]).abs() < 1e-9,
                    "t={t} i={i}: sparse {} vs full {}",
                    s.y[i],
                    y_ref[i]
                );
            }
        }
    }

    #[test]
    fn oracle_rate_uses_diam_and_gradnorm() {
        let p = synthesize(&Scenario::small());
        let lr = LearningRate::Oracle { horizon: 100 };
        let eta = lr.eta(&p, 0, 2.0);
        assert!((eta - p.diam_upper() / (2.0 * 10.0)).abs() < 1e-9);
    }

    #[test]
    fn zero_arrivals_leave_y_fixed() {
        let p = synthesize(&Scenario::small());
        let mut s = OgaState::new(&p, LearningRate::Constant(1.0), 0);
        let x_on = vec![1.0; p.num_ports()];
        let x_off = vec![0.0; p.num_ports()];
        for _ in 0..5 {
            s.step(&p, &x_on);
        }
        let before = s.y.clone();
        s.step(&p, &x_off);
        // zero gradient => empty dirty set => the step is a no-op (the
        // dirty-tracking projection doesn't even re-project)
        assert_eq!(s.y, before);
        assert!(s.dirty_instances().is_empty());
    }
}
