//! The reward gradient ∇q of Eq. (30), over the edge-major layout.
//!
//! For each arrived port l (x_l > 0):
//!     ∂q/∂y_{(l,r)}^k = x_l · ( (f_r^k)'(y) − β_k · 1{k = k*_l} )
//! with k*_l = argmax_k β_k Σ_{r∈R_l} y_{(l,r)}^k (Eq. 27).  Ports with
//! x_l = 0 contribute zero gradient.  The decision and gradient tensors
//! are edge-major `[E, K]` (see `model`), so a port's coordinates are
//! one contiguous slice and off-edge coordinates don't exist — the loop
//! below touches exactly Σ_{l: x_l>0} |R_l| · K entries plus one memset
//! of the |E|·K buffer.

use crate::model::Problem;

/// Scratch space reused across slots so the hot loop never allocates.
#[derive(Clone, Debug, Default)]
pub struct GradScratch {
    /// [K] per-port resource quotas Σ_{r∈R_l} y.
    quota: Vec<f64>,
}

/// Compute ∇q(x, y) into `grad` (edge-major [E, K]; caller provides a
/// reusable buffer — rows of absent ports are zeroed via memset).
pub fn gradient(
    problem: &Problem,
    x: &[f64],
    y: &[f64],
    grad: &mut [f64],
    scratch: &mut GradScratch,
) {
    let k_n = problem.num_resources;
    debug_assert_eq!(x.len(), problem.num_ports());
    debug_assert_eq!(y.len(), problem.decision_len());
    debug_assert_eq!(grad.len(), problem.decision_len());
    grad.fill(0.0);
    scratch.quota.resize(k_n, 0.0);

    let g = &problem.graph;
    for l in 0..problem.num_ports() {
        let x_l = x[l];
        if x_l == 0.0 {
            continue;
        }
        // quota_k = Σ_{r∈R_l} y_{(l,r)}^k
        scratch.quota.fill(0.0);
        for e in g.port_edges(l) {
            let base = e * k_n;
            for k in 0..k_n {
                scratch.quota[k] += y[base + k];
            }
        }
        // k* = argmax_k β_k · quota_k  (Eq. 27)
        let mut kstar = 0;
        let mut best = f64::NEG_INFINITY;
        for k in 0..k_n {
            let v = problem.beta[k] * scratch.quota[k];
            if v > best {
                best = v;
                kstar = k;
            }
        }
        for e in g.port_edges(l) {
            let rk = g.edge_instance[e] * k_n;
            let base = e * k_n;
            for k in 0..k_n {
                let fp = problem.kind[rk + k].grad(y[base + k], problem.alpha[rk + k]);
                let pen = if k == kstar { problem.beta[k] } else { 0.0 };
                grad[base + k] = x_l * (fp - pen);
            }
        }
    }
}

/// Euclidean norm of the gradient (used for the Eq. 50 oracle step size
/// and the Thm. 1 bound check).
pub fn grad_norm(grad: &[f64]) -> f64 {
    grad.iter().map(|g| g * g).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Bipartite;
    use crate::oga::utilities::UtilityKind;

    fn problem() -> Problem {
        let graph = Bipartite::from_edges(2, 2, &[(0, 0), (0, 1), (1, 1)]);
        Problem {
            graph,
            num_resources: 2,
            demand: vec![5.0; 4],
            capacity: vec![10.0; 4],
            alpha: vec![1.0, 2.0, 3.0, 4.0],
            kind: vec![UtilityKind::Linear; 4],
            beta: vec![0.4, 0.6],
        }
    }

    #[test]
    fn decision_len_counts_edges_only() {
        let p = problem();
        // 3 edges × 2 resources, not 2·2·2
        assert_eq!(p.decision_len(), 6);
    }

    #[test]
    fn zero_arrivals_zero_gradient() {
        let p = problem();
        let y = vec![1.0; p.decision_len()];
        let mut g = vec![9.0; p.decision_len()];
        gradient(&p, &[0.0, 0.0], &y, &mut g, &mut GradScratch::default());
        assert!(g.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn penalty_applies_only_on_kstar() {
        let p = problem();
        // port 0 connects to r=0,1. Put all mass on k=1 so k*=1.
        let mut y = vec![0.0; p.decision_len()];
        y[p.idx(0, 0, 1)] = 2.0;
        let mut g = vec![0.0; p.decision_len()];
        gradient(&p, &[1.0, 0.0], &y, &mut g, &mut GradScratch::default());
        // linear utilities: f' = alpha
        assert!((g[p.idx(0, 0, 0)] - 1.0).abs() < 1e-12); // alpha(0,0)=1, no pen
        assert!((g[p.idx(0, 0, 1)] - (2.0 - 0.6)).abs() < 1e-12); // pen beta_1
        assert!((g[p.idx(0, 1, 0)] - 3.0).abs() < 1e-12);
        assert!((g[p.idx(0, 1, 1)] - (4.0 - 0.6)).abs() < 1e-12);
        // port 1 did not arrive
        assert_eq!(g[p.idx(1, 1, 0)], 0.0);
    }

    #[test]
    fn absent_port_rows_are_zeroed() {
        let p = problem();
        let y = vec![0.5; p.decision_len()];
        let mut g = vec![7.0; p.decision_len()];
        gradient(&p, &[1.0, 0.0], &y, &mut g, &mut GradScratch::default());
        // port 1's single edge (1,1) must be memset back to zero
        assert_eq!(g[p.idx(1, 1, 0)], 0.0);
        assert_eq!(g[p.idx(1, 1, 1)], 0.0);
    }

    #[test]
    fn matches_finite_difference_of_reward() {
        use crate::reward::slot_reward;
        let p = problem();
        let x = [1.0, 1.0];
        let y = vec![0.7; p.decision_len()];
        let mut g = vec![0.0; p.decision_len()];
        gradient(&p, &x, &y, &mut g, &mut GradScratch::default());
        let h = 1e-6;
        for l in 0..2 {
            for &r in &p.graph.ports_to_instances[l] {
                for k in 0..2 {
                    let i = p.idx(l, r, k);
                    let mut yp = y.clone();
                    yp[i] += h;
                    let mut ym = y.clone();
                    ym[i] -= h;
                    let fd = (slot_reward(&p, &x, &yp).q - slot_reward(&p, &x, &ym).q)
                        / (2.0 * h);
                    // finite differences straddle the argmax tie at equal
                    // quotas; tolerance covers the kink
                    assert!(
                        (fd - g[i]).abs() < 1e-4,
                        "fd={fd} grad={} at ({l},{r},{k})",
                        g[i]
                    );
                }
            }
        }
    }

    #[test]
    fn grad_norm_is_euclidean() {
        assert!((grad_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn multi_arrival_scales_gradient() {
        // Sec. 3.4: x_l ∈ ℕ scales the port gradient linearly.
        let p = problem();
        let y = vec![0.3; p.decision_len()];
        let mut g1 = vec![0.0; p.decision_len()];
        let mut g3 = vec![0.0; p.decision_len()];
        gradient(&p, &[1.0, 0.0], &y, &mut g1, &mut GradScratch::default());
        gradient(&p, &[3.0, 0.0], &y, &mut g3, &mut GradScratch::default());
        for i in 0..g1.len() {
            assert!((g3[i] - 3.0 * g1[i]).abs() < 1e-12);
        }
    }
}
