//! The reward gradient ∇q of Eq. (30), over the edge-major layout.
//!
//! For each arrived port l (x_l > 0):
//!     ∂q/∂y_{(l,r)}^k = x_l · ( (f_r^k)'(y) − β_k · 1{k = k*_l} )
//! with k*_l = argmax_k β_k Σ_{r∈R_l} y_{(l,r)}^k (Eq. 27).  Ports with
//! x_l = 0 contribute zero gradient.  The decision and gradient tensors
//! are edge-major `[E, K]` (see `model`), so a port's coordinates are
//! one contiguous slice and off-edge coordinates don't exist.
//!
//! §Perf-2: the (f_r^k)' evaluation is *kind-batched* — the utility
//! family `match` is hoisted out of the inner loop via the
//! [`KindIndex`] same-kind runs, and the Eq. 27 penalty is applied as a
//! separate strided pass over the k* lane.  [`gradient`] memsets the
//! whole buffer (the offline oracle's full-batch shape);
//! [`gradient_sparse`] instead zeroes only the slices it wrote on the
//! *previous* call, so a slot costs O(|E_x|·K) in the arrived
//! neighborhood with nothing proportional to |E|.

use crate::model::{KindIndex, Problem};

/// Scratch space reused across slots so the hot loop never allocates.
#[derive(Clone, Debug, Default)]
pub struct GradScratch {
    /// [K] per-port resource quotas Σ_{r∈R_l} y.
    quota: Vec<f64>,
}

/// Compute ∇q(x, y) into `grad` (edge-major [E, K]; caller provides a
/// reusable buffer — rows of absent ports are zeroed via memset).
pub fn gradient(
    problem: &Problem,
    kinds: &KindIndex,
    x: &[f64],
    y: &[f64],
    grad: &mut [f64],
    scratch: &mut GradScratch,
) {
    debug_assert_eq!(x.len(), problem.num_ports());
    debug_assert_eq!(y.len(), problem.decision_len());
    debug_assert_eq!(grad.len(), problem.decision_len());
    grad.fill(0.0);
    scratch.quota.resize(problem.num_resources, 0.0);
    for l in 0..problem.num_ports() {
        if x[l] != 0.0 {
            port_gradient(problem, kinds, l, x[l], y, grad, &mut scratch.quota);
        }
    }
}

/// Sparse variant for the per-slot hot path: `active` holds the ports
/// whose slices the *previous* call filled (state owned by the caller).
/// Those slices are zeroed, then this slot's arrived ports are filled
/// and recorded into `active` — after the call, `grad` equals the full
/// [`gradient`] output without the O(|E|·K) memset.
pub fn gradient_sparse(
    problem: &Problem,
    kinds: &KindIndex,
    x: &[f64],
    y: &[f64],
    grad: &mut [f64],
    scratch: &mut GradScratch,
    active: &mut Vec<usize>,
) {
    let k_n = problem.num_resources;
    debug_assert_eq!(x.len(), problem.num_ports());
    debug_assert_eq!(y.len(), problem.decision_len());
    debug_assert_eq!(grad.len(), problem.decision_len());
    for &l in active.iter() {
        let lo = problem.graph.port_ptr[l] * k_n;
        let hi = problem.graph.port_ptr[l + 1] * k_n;
        grad[lo..hi].fill(0.0);
    }
    active.clear();
    scratch.quota.resize(k_n, 0.0);
    for l in 0..problem.num_ports() {
        if x[l] != 0.0 {
            port_gradient(problem, kinds, l, x[l], y, grad, &mut scratch.quota);
            active.push(l);
        }
    }
}

/// Fill one arrived port's gradient slice (shared by both entry points).
fn port_gradient(
    problem: &Problem,
    kinds: &KindIndex,
    l: usize,
    x_l: f64,
    y: &[f64],
    grad: &mut [f64],
    quota: &mut [f64],
) {
    let k_n = problem.num_resources;
    let g = &problem.graph;
    // quota_k = Σ_{r∈R_l} y_{(l,r)}^k (element-wise §Perf-5 kernel —
    // same floats, vectorized K lane under the `simd` feature)
    quota.fill(0.0);
    for e in g.port_edges(l) {
        let base = e * k_n;
        crate::oga::kernels::accumulate(quota, &y[base..base + k_n]);
    }
    // k* = argmax_k β_k · quota_k  (Eq. 27)
    let mut kstar = 0;
    let mut best = f64::NEG_INFINITY;
    for k in 0..k_n {
        let v = problem.beta[k] * quota[k];
        if v > best {
            best = v;
            kstar = k;
        }
    }
    // kind-batched marginal gains: one family dispatch per run, then a
    // branch-free contiguous pass
    for run in kinds.port_runs(l) {
        run.kind.grad_into(
            &y[run.lo..run.hi],
            &kinds.alpha_flat[run.lo..run.hi],
            x_l,
            &mut grad[run.lo..run.hi],
        );
    }
    // Eq. 27 penalty on the k* lane only
    let pen = x_l * problem.beta[kstar];
    for e in g.port_edges(l) {
        grad[e * k_n + kstar] -= pen;
    }
}

/// One edge's gradient entries — `grad[e·K + k] = scale · f'(y, α)` —
/// cut into maximal same-kind sub-runs so the call streams through the
/// *same* element-wise [`UtilityKind::grad_into`] kernel as the serial
/// port-run pass; per-element semantics (and floats) are identical,
/// only the slice boundaries differ, which the kernel cannot observe.
/// The per-edge body of the sharded Eq. 50 two-pass (§Perf-4; mirrors
/// `oga::ascend_edge`).  The Eq. 27 k\*-lane penalty is the caller's
/// second pass.
pub(crate) fn grad_edge(
    problem: &Problem,
    kinds: &KindIndex,
    y: &[f64],
    grad: &mut [f64],
    e: usize,
    scale: f64,
) {
    let k_n = problem.num_resources;
    let base = e * k_n;
    let rk = problem.graph.edge_instance[e] * k_n;
    let mut k = 0;
    while k < k_n {
        let kind = problem.kind[rk + k];
        let start = k;
        k += 1;
        while k < k_n && problem.kind[rk + k] == kind {
            k += 1;
        }
        kind.grad_into(
            &y[base + start..base + k],
            &kinds.alpha_flat[base + start..base + k],
            scale,
            &mut grad[base + start..base + k],
        );
    }
}

/// Euclidean norm of the gradient (used for the Eq. 50 oracle step size
/// and the Thm. 1 bound check).
pub fn grad_norm(grad: &[f64]) -> f64 {
    grad.iter().map(|g| g * g).sum::<f64>().sqrt()
}

/// Norm restricted to the listed ports' slices.  Exact when the
/// gradient is zero elsewhere (it is, by Eq. 30, off the arrived
/// neighborhood) — the [`gradient_sparse`] companion that keeps the
/// Eq. 50 oracle rate from paying an O(|E|·K) reduction per slot.
pub fn grad_norm_ports(problem: &Problem, grad: &[f64], ports: &[usize]) -> f64 {
    let k_n = problem.num_resources;
    let mut acc = 0.0;
    for &l in ports {
        let lo = problem.graph.port_ptr[l] * k_n;
        let hi = problem.graph.port_ptr[l + 1] * k_n;
        for g in &grad[lo..hi] {
            acc += g * g;
        }
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Bipartite;
    use crate::oga::utilities::UtilityKind;

    fn problem() -> Problem {
        let graph = Bipartite::from_edges(2, 2, &[(0, 0), (0, 1), (1, 1)]);
        Problem::new(
            graph,
            2,
            vec![5.0; 4],
            vec![10.0; 4],
            vec![1.0, 2.0, 3.0, 4.0],
            vec![UtilityKind::Linear; 4],
            vec![0.4, 0.6],
        )
    }

    fn grad_of(p: &Problem, x: &[f64], y: &[f64]) -> Vec<f64> {
        let kinds = KindIndex::build(p);
        let mut g = vec![0.0; p.decision_len()];
        gradient(p, &kinds, x, y, &mut g, &mut GradScratch::default());
        g
    }

    #[test]
    fn decision_len_counts_edges_only() {
        let p = problem();
        // 3 edges × 2 resources, not 2·2·2
        assert_eq!(p.decision_len(), 6);
    }

    #[test]
    fn zero_arrivals_zero_gradient() {
        let p = problem();
        let kinds = KindIndex::build(&p);
        let y = vec![1.0; p.decision_len()];
        let mut g = vec![9.0; p.decision_len()];
        gradient(&p, &kinds, &[0.0, 0.0], &y, &mut g, &mut GradScratch::default());
        assert!(g.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn penalty_applies_only_on_kstar() {
        let p = problem();
        // port 0 connects to r=0,1. Put all mass on k=1 so k*=1.
        let mut y = vec![0.0; p.decision_len()];
        y[p.idx(0, 0, 1)] = 2.0;
        let g = grad_of(&p, &[1.0, 0.0], &y);
        // linear utilities: f' = alpha
        assert!((g[p.idx(0, 0, 0)] - 1.0).abs() < 1e-12); // alpha(0,0)=1, no pen
        assert!((g[p.idx(0, 0, 1)] - (2.0 - 0.6)).abs() < 1e-12); // pen beta_1
        assert!((g[p.idx(0, 1, 0)] - 3.0).abs() < 1e-12);
        assert!((g[p.idx(0, 1, 1)] - (4.0 - 0.6)).abs() < 1e-12);
        // port 1 did not arrive
        assert_eq!(g[p.idx(1, 1, 0)], 0.0);
    }

    #[test]
    fn absent_port_rows_are_zeroed() {
        let p = problem();
        let y = vec![0.5; p.decision_len()];
        let g = grad_of(&p, &[1.0, 0.0], &y);
        // port 1's single edge (1,1) must be memset back to zero
        assert_eq!(g[p.idx(1, 1, 0)], 0.0);
        assert_eq!(g[p.idx(1, 1, 1)], 0.0);
    }

    #[test]
    fn matches_finite_difference_of_reward() {
        use crate::reward::slot_reward;
        let p = problem();
        let x = [1.0, 1.0];
        let y = vec![0.7; p.decision_len()];
        let g = grad_of(&p, &x, &y);
        let h = 1e-6;
        for l in 0..2 {
            for &r in &p.graph.ports_to_instances[l] {
                for k in 0..2 {
                    let i = p.idx(l, r, k);
                    let mut yp = y.clone();
                    yp[i] += h;
                    let mut ym = y.clone();
                    ym[i] -= h;
                    let fd = (slot_reward(&p, &x, &yp).q - slot_reward(&p, &x, &ym).q)
                        / (2.0 * h);
                    // finite differences straddle the argmax tie at equal
                    // quotas; tolerance covers the kink
                    assert!(
                        (fd - g[i]).abs() < 1e-4,
                        "fd={fd} grad={} at ({l},{r},{k})",
                        g[i]
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_gradient_matches_full_across_changing_arrivals() {
        // the sparse path re-zeroes exactly its previous slices, so a
        // port that arrived at t but not at t+1 must read zero again
        let p = problem();
        let kinds = KindIndex::build(&p);
        let y = vec![0.8; p.decision_len()];
        let mut sparse = vec![0.0; p.decision_len()];
        let mut active = Vec::new();
        let mut scratch = GradScratch::default();
        for x in [[1.0, 0.0], [0.0, 2.0], [1.0, 1.0], [0.0, 0.0]] {
            gradient_sparse(&p, &kinds, &x, &y, &mut sparse, &mut scratch, &mut active);
            let full = grad_of(&p, &x, &y);
            assert_eq!(sparse, full, "x={x:?}");
            let want_ports: Vec<usize> =
                (0..2).filter(|&l| x[l] != 0.0).collect();
            assert_eq!(active, want_ports);
            let n_sparse = grad_norm_ports(&p, &sparse, &active);
            assert!((n_sparse - grad_norm(&full)).abs() < 1e-12);
        }
    }

    #[test]
    fn grad_norm_is_euclidean() {
        assert!((grad_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn multi_arrival_scales_gradient() {
        // Sec. 3.4: x_l ∈ ℕ scales the port gradient linearly.
        let p = problem();
        let y = vec![0.3; p.decision_len()];
        let g1 = grad_of(&p, &[1.0, 0.0], &y);
        let g3 = grad_of(&p, &[3.0, 0.0], &y);
        for i in 0..g1.len() {
            assert!((g3[i] - 3.0 * g1[i]).abs() < 1e-12);
        }
    }
}
