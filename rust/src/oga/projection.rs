//! The fast Euclidean projection onto Y (Algorithm 1, steps 6–31).
//!
//! The projection decomposes per (r, k) pair: each column
//! v = y[·, r, k] over the ports l ∈ L_r solves
//!
//! ```text
//! min ‖v − z‖²   s.t.   0 ≤ v_l ≤ a_l^k,   Σ_l v_l ≤ c_r^k .
//! ```
//!
//! KKT (Eq. 34) gives v_l = clip(z_l − ρ/2, 0, a_l) with water level
//! τ = ρ/2 ≥ 0, zero if the capacity constraint is slack.  The paper
//! finds τ by sorting the column and iterating the B¹/B²/B³ partition;
//! `project_channel` implements the equivalent exact *event sweep*:
//! g(τ) = Σ_l clip(z_l − τ, 0, a_l) is piecewise linear and decreasing
//! with breakpoints {z_l} ∪ {z_l − a_l}.  One descending sort of the
//! ≤ 2·|L_r| positive breakpoints plus a single prefix-maintained sweep
//! (interior count m, interior sum S, capped sum C, so g(τ) = S − m·τ + C
//! on each segment) pins the segment where g(τ) = c and solves for τ in
//! closed form — O(|L_r| log |L_r|) total, against the seed's
//! O(|L_r|²) worst case of re-evaluating g from scratch per breakpoint
//! (see EXPERIMENTS.md §Perf).
//!
//! Columns are independent, so `project` distributes instances over the
//! persistent worker pool (the "for each (r, k) in parallel" of Alg. 1).
//! `project_instances` projects only a caller-supplied subset — the
//! dirty-instance fast path of `OgaState::step`, which re-projects only
//! instances adjacent to arrived ports.
//!
//! Decisions are edge-major `[E, K]` (see `model`); every edge belongs
//! to exactly one instance, so parallelizing over instances is race-free
//! and there are no off-edge coordinates to re-zero.

use std::cell::RefCell;

use crate::model::Problem;
use crate::utils::pool;

/// Per-worker scratch for one channel projection (reused across columns).
#[derive(Clone, Debug, Default)]
pub struct ChannelScratch {
    vals: Vec<f64>,
    caps: Vec<f64>,
    events: Vec<(f64, u32)>,
}

/// Exact projection of one (r, k) column.
///
/// `vals[i]`/`caps[i]` are z and a for the i-th port of L_r; on return
/// `vals` holds the projected v.  `events` is reusable scratch.  Returns
/// the water level τ (= ρ/2 of Eq. 35), 0.0 when the capacity constraint
/// is slack.
pub fn project_channel(
    vals: &mut [f64],
    caps: &[f64],
    capacity: f64,
    events: &mut Vec<(f64, u32)>,
) -> f64 {
    debug_assert_eq!(vals.len(), caps.len());
    // Fast path: if the box-clipped point fits the capacity, τ = 0
    // (KKT: ρ > 0 only when the capacity constraint is tight).  The
    // original z must be kept for the sweep below — clipping first and
    // sweeping the clipped values changes the answer (a coordinate far
    // above its cap must stay pinned at the cap while others drain).
    let used: f64 = vals
        .iter()
        .zip(caps)
        .map(|(&z, &a)| z.clamp(0.0, a))
        .sum();
    if used <= capacity {
        for i in 0..vals.len() {
            vals[i] = vals[i].clamp(0.0, caps[i]);
        }
        return 0.0;
    }

    // Capacity binds: find τ with g(τ) = Σ clip(z−τ, 0, a) = capacity.
    // Events mark where a coordinate changes regime as τ decreases:
    // at τ = z_i it leaves the zero set and becomes interior, at
    // τ = z_i − a_i it leaves the interior and pins at its cap.  Only
    // positive event values matter since τ* > 0 here.
    events.clear();
    for i in 0..vals.len() {
        if vals[i] > 0.0 {
            events.push((vals[i], (i as u32) << 1));
        }
        if vals[i] - caps[i] > 0.0 {
            events.push((vals[i] - caps[i], ((i as u32) << 1) | 1));
        }
    }
    events.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap()); // descending τ

    // Sweep downward, maintaining m = |interior|, s = Σ_interior z,
    // c = Σ_capped a, so g(τ) = s − m·τ + c on the open segment below
    // the processed events.  g is continuous at the boundaries (an
    // entering coordinate contributes 0 at τ = z_i, a capping one
    // contributes exactly a_i at τ = z_i − a_i), so evaluating at the
    // segment's lower boundary with the upper segment's state is exact.
    let mut m = 0.0f64;
    let mut s = 0.0f64;
    let mut c = 0.0f64;
    let n_ev = events.len();
    let mut idx = 0usize;
    while idx < n_ev {
        let upper = events[idx].0;
        while idx < n_ev && events[idx].0 == upper {
            let tag = events[idx].1;
            let i = (tag >> 1) as usize;
            if tag & 1 == 0 {
                // zero set -> interior
                m += 1.0;
                s += vals[i];
            } else {
                // interior -> capped
                m -= 1.0;
                s -= vals[i];
                c += caps[i];
            }
            idx += 1;
        }
        let lower = if idx < n_ev { events[idx].0 } else { 0.0 };
        let g_low = s - m * lower + c;
        // The final segment (lower == 0) crosses unconditionally: the
        // fast path established g(0) = used > capacity, but its `used`
        // was a fresh sum while s/c accumulated incrementally (+z then
        // −z does not cancel exactly), so on a marginally-tight channel
        // the rounded s + c can land a few ulps below `capacity` — the
        // crossing must not be lost to that rounding.
        if g_low >= capacity || idx >= n_ev {
            // crossing inside (lower, upper]: solve s − m·τ + c = capacity
            let tau = if m > 0.0 { (s + c - capacity) / m } else { lower };
            let tau = tau.clamp(lower, upper);
            for i in 0..vals.len() {
                vals[i] = (vals[i] - tau).clamp(0.0, caps[i]);
            }
            return tau;
        }
    }
    // only reachable with no positive breakpoints at all, i.e. every
    // z_i ≤ 0 — then used = 0 ≤ capacity (capacities are nonnegative)
    // and the fast path already returned.
    unreachable!("event sweep failed to bracket the water level");
}

/// Reference projector for tests: bisection on τ (slow, obviously
/// correct).  Mirrors python/compile/kernels/ref.py::project_ref.
pub fn project_channel_bisect(vals: &mut [f64], caps: &[f64], capacity: f64) -> f64 {
    let used: f64 = vals.iter().zip(caps).map(|(&z, &a)| z.clamp(0.0, a)).sum();
    if used <= capacity {
        for i in 0..vals.len() {
            vals[i] = vals[i].clamp(0.0, caps[i]);
        }
        return 0.0;
    }
    let g = |tau: f64| -> f64 {
        vals.iter().zip(caps).map(|(&z, &a)| (z - tau).clamp(0.0, a)).sum()
    };
    let mut lo = 0.0;
    let mut hi = vals.iter().copied().fold(0.0, f64::max) + 1e-9;
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if g(mid) > capacity {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let tau = hi;
    for i in 0..vals.len() {
        vals[i] = (vals[i] - tau).clamp(0.0, caps[i]);
    }
    tau
}

thread_local! {
    /// Per-thread channel scratch so pool workers don't allocate per
    /// instance.
    static SCRATCH: RefCell<ChannelScratch> = RefCell::new(ChannelScratch::default());
}

/// Touched-coordinate threshold below which serial projection beats the
/// pool dispatch (the persistent pool costs a few µs per call, against
/// the ~100µs/worker of the seed's `thread::scope` spawns).
const SERIAL_THRESHOLD: usize = 8_192;

/// Project the edge-major decision tensor `z` [E, K] onto Y in place.
///
/// Channels are distributed over `workers` threads (0 = auto); each
/// instance r owns the disjoint coordinate set {(e, k) : edge e ∈ r},
/// so parallelizing over r is race-free.
pub fn project(problem: &Problem, z: &mut [f64], workers: usize) {
    let r_n = problem.num_instances();
    let touched = z.len();
    project_impl(problem, z, workers, touched, r_n, |i| i);
}

/// Project only the channels of the listed instances (the dirty set of
/// one OGA step).  Instances not listed are left untouched — an ascent
/// that only perturbed the listed instances leaves every other column
/// feasible, so skipping them is exact, not approximate.
pub fn project_instances(problem: &Problem, z: &mut [f64], instances: &[usize], workers: usize) {
    if instances.is_empty() {
        return;
    }
    let k_n = problem.num_resources;
    let touched: usize =
        instances.iter().map(|&r| problem.graph.instance_degree(r) * k_n).sum();
    project_impl(problem, z, workers, touched, instances.len(), |i| instances[i]);
}

/// Project exactly the listed instances on the calling thread,
/// bypassing the worker heuristics — the per-shard body of the sharded
/// slot (`coordinator::sharded`): each shard worker projects the dirty
/// instances it owns, so the parallelism lives one level up and must
/// not recurse into the pool.  Uses the same per-thread scratch as the
/// pooled paths, so a shard worker allocates nothing per slot.
pub fn project_instances_serial(problem: &Problem, z: &mut [f64], instances: &[usize]) {
    if instances.is_empty() {
        return;
    }
    SCRATCH.with(|s| {
        let scratch = &mut *s.borrow_mut();
        for &r in instances {
            project_instance(problem, r, z, scratch);
        }
    });
}

fn project_impl(
    problem: &Problem,
    z: &mut [f64],
    workers: usize,
    touched: usize,
    n: usize,
    instance_of: impl Fn(usize) -> usize + Sync,
) {
    // NB: the seed guarded with `workers <= 1`, which swallowed the
    // `workers == 0` auto mode entirely — auto never parallelized.
    if workers == 1 || (workers == 0 && touched < SERIAL_THRESHOLD) {
        SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            for i in 0..n {
                project_instance(problem, instance_of(i), z, scratch);
            }
        });
        return;
    }
    let workers = if workers == 0 {
        pool::default_workers(n).min((touched / 2_048).max(2))
    } else {
        workers
    };
    let shared = SharedTensor { ptr: z.as_mut_ptr(), len: z.len() };
    let shared = &shared; // capture the Sync wrapper, not the raw pointer field
    pool::parallel_for(n, workers, |i| {
        // SAFETY: instance r owns only its edges' coordinates — disjoint
        // across distinct r, and `instances` lists each r at most once.
        let z = unsafe { std::slice::from_raw_parts_mut(shared.ptr, shared.len) };
        SCRATCH.with(|s| {
            project_instance(problem, instance_of(i), z, &mut s.borrow_mut());
        });
    });
}

/// Serial variant (used by benches to measure the parallel speedup).
pub fn project_serial(problem: &Problem, z: &mut [f64]) {
    let mut scratch = ChannelScratch::default();
    for r in 0..problem.num_instances() {
        project_instance(problem, r, z, &mut scratch);
    }
}

/// Project all K channels of instance r.
fn project_instance(problem: &Problem, r: usize, z: &mut [f64], scratch: &mut ChannelScratch) {
    let k_n = problem.num_resources;
    let edges = problem.graph.instance_edge_ids(r);
    if edges.is_empty() {
        return;
    }
    for k in 0..k_n {
        scratch.vals.clear();
        scratch.caps.clear();
        for &e in edges {
            scratch.vals.push(z[e * k_n + k]);
            scratch.caps.push(problem.demand_at(problem.graph.edge_port[e], k));
        }
        project_channel(
            &mut scratch.vals,
            &scratch.caps,
            problem.capacity_at(r, k),
            &mut scratch.events,
        );
        for (i, &e) in edges.iter().enumerate() {
            z[e * k_n + k] = scratch.vals[i];
        }
    }
}

/// Pointer wrapper so the pool workers can share the tensor; safety is
/// argued at the call site (disjoint index ownership per instance).
struct SharedTensor {
    ptr: *mut f64,
    len: usize,
}
unsafe impl Sync for SharedTensor {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::traces::synthesize;
    use crate::utils::prop::{check, ensure};
    use crate::utils::rng::Rng;

    fn channel_case(rng: &mut Rng, n: usize) -> (Vec<f64>, Vec<f64>, f64) {
        let vals: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 6.0)).collect();
        let caps: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 3.0)).collect();
        let capacity = rng.uniform(0.2, 0.7 * caps.iter().sum::<f64>());
        (vals, caps, capacity)
    }

    #[test]
    fn channel_matches_bisection_reference() {
        check("channel-vs-bisect", 300, |rng, size| {
            let n = rng.range(1, size.dim(40, 1));
            let (vals, caps, capacity) = channel_case(rng, n);
            let mut fast = vals.clone();
            let mut slow = vals.clone();
            let mut events = Vec::new();
            project_channel(&mut fast, &caps, capacity, &mut events);
            project_channel_bisect(&mut slow, &caps, capacity);
            for i in 0..n {
                if (fast[i] - slow[i]).abs() > 1e-6 {
                    return Err(format!(
                        "i={i}: fast={} slow={} (vals={vals:?} caps={caps:?} c={capacity})",
                        fast[i], slow[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn channel_with_duplicate_values_matches_bisection() {
        // duplicate z's and z−a ties exercise the same-value event
        // grouping of the sweep
        check("channel-ties-vs-bisect", 200, |rng, size| {
            let n = rng.range(2, size.dim(24, 2));
            let base = rng.uniform(0.5, 3.0);
            let vals: Vec<f64> = (0..n)
                .map(|_| if rng.bernoulli(0.5) { base } else { rng.uniform(-1.0, 4.0) })
                .collect();
            let caps: Vec<f64> = (0..n)
                .map(|_| if rng.bernoulli(0.3) { base } else { rng.uniform(0.2, 2.0) })
                .collect();
            let capacity = rng.uniform(0.1, 0.6 * caps.iter().sum::<f64>());
            let mut fast = vals.clone();
            let mut slow = vals.clone();
            let mut events = Vec::new();
            project_channel(&mut fast, &caps, capacity, &mut events);
            project_channel_bisect(&mut slow, &caps, capacity);
            for i in 0..n {
                ensure((fast[i] - slow[i]).abs() < 1e-6, || {
                    format!(
                        "i={i}: fast={} slow={} (vals={vals:?} caps={caps:?} c={capacity})",
                        fast[i], slow[i]
                    )
                })?;
            }
            Ok(())
        });
    }

    #[test]
    fn channel_marginally_tight_capacity_does_not_panic() {
        // capacity one ulp below the clipped sum: the capacity binds by
        // rounding only, and the sweep's incrementally-accumulated
        // g(0) = s + c may land below `capacity` — the final segment
        // must still produce a (tiny) water level instead of panicking
        check("channel-marginal-tight", 300, |rng, size| {
            let n = rng.range(1, size.dim(30, 1));
            let (vals, caps, _) = channel_case(rng, n);
            let used: f64 =
                vals.iter().zip(&caps).map(|(&z, &a)| z.clamp(0.0, a)).sum();
            if used <= 0.0 {
                return Ok(());
            }
            let capacity = f64::from_bits(used.to_bits() - 1); // next below
            let mut v = vals.clone();
            let mut events = Vec::new();
            let tau = project_channel(&mut v, &caps, capacity, &mut events);
            let sum: f64 = v.iter().sum();
            ensure(tau >= 0.0, || format!("negative tau {tau}"))?;
            ensure(sum <= capacity + 1e-9, || {
                format!("sum {sum} > marginal capacity {capacity}")
            })
        });
    }

    #[test]
    fn channel_output_feasible_and_optimal_kkt() {
        check("channel-kkt", 300, |rng, size| {
            let n = rng.range(1, size.dim(30, 1));
            let (vals, caps, capacity) = channel_case(rng, n);
            let mut v = vals.clone();
            let mut events = Vec::new();
            let tau = project_channel(&mut v, &caps, capacity, &mut events);
            let sum: f64 = v.iter().sum();
            ensure(sum <= capacity + 1e-9, || format!("sum {sum} > cap {capacity}"))?;
            for i in 0..n {
                ensure(v[i] >= -1e-12 && v[i] <= caps[i] + 1e-12, || {
                    format!("box violated at {i}: {}", v[i])
                })?;
                // KKT stationarity: v_i = clip(z_i - tau, 0, a_i)
                let want = (vals[i] - tau).clamp(0.0, caps[i]);
                ensure((v[i] - want).abs() < 1e-9, || {
                    format!("stationarity at {i}: {} vs {want}", v[i])
                })?;
            }
            // complementary slackness: tau > 0 => capacity tight
            if tau > 1e-9 {
                ensure((sum - capacity).abs() < 1e-6, || {
                    format!("tau={tau} but sum={sum} != c={capacity}")
                })?;
            }
            Ok(())
        });
    }

    #[test]
    fn interior_point_untouched() {
        let mut v = vec![0.5, 0.25];
        let caps = [1.0, 1.0];
        let mut events = Vec::new();
        let tau = project_channel(&mut v, &caps, 10.0, &mut events);
        assert_eq!(tau, 0.0);
        assert_eq!(v, vec![0.5, 0.25]);
    }

    #[test]
    fn water_level_matches_eq35_hand_case() {
        // mirrors python test_water_level_matches_paper_rho: z=[3,2,1],
        // a=10, c=3 -> B3 = all, rho/2 = (6-3)/3 = 1.
        let mut v = vec![3.0, 2.0, 1.0];
        let caps = [10.0, 10.0, 10.0];
        let mut events = Vec::new();
        let tau = project_channel(&mut v, &caps, 3.0, &mut events);
        assert!((tau - 1.0).abs() < 1e-9, "tau={tau}");
        assert!((v[0] - 2.0).abs() < 1e-9);
        assert!((v[1] - 1.0).abs() < 1e-9);
        assert!(v[2].abs() < 1e-9);
    }

    #[test]
    fn caps_saturate_b1_set() {
        // largest value pinned at its cap (B1), rest water-filled
        let mut v = vec![5.0, 1.0, 0.8];
        let caps = [1.0, 2.0, 2.0];
        let mut events = Vec::new();
        let tau = project_channel(&mut v, &caps, 2.0, &mut events);
        assert!((v[0] - 1.0).abs() < 1e-9, "v={v:?} tau={tau}");
        let sum: f64 = v.iter().sum();
        assert!((sum - 2.0).abs() < 1e-8);
    }

    #[test]
    fn full_projection_feasible_parallel_equals_serial() {
        let scenario = Scenario::small();
        let p = synthesize(&scenario);
        let mut rng = Rng::new(9);
        let mut z: Vec<f64> =
            (0..p.decision_len()).map(|_| rng.uniform(-1.0, 8.0)).collect();
        let mut z_par = z.clone();
        project_serial(&p, &mut z);
        project(&p, &mut z_par, 4);
        assert_eq!(z, z_par, "parallel and serial projections must agree exactly");
        p.check_feasible(&z, 1e-7).unwrap();
    }

    #[test]
    fn dirty_subset_projection_matches_full() {
        // projecting a feasible point perturbed only on a subset of
        // instances: projecting just that subset equals the full pass
        let p = synthesize(&Scenario::small());
        let mut rng = Rng::new(33);
        let mut y: Vec<f64> =
            (0..p.decision_len()).map(|_| rng.uniform(0.0, 4.0)).collect();
        project(&p, &mut y, 0); // feasible baseline
        // perturb instances 0..R/2 only
        let dirty: Vec<usize> = (0..p.num_instances() / 2).collect();
        let k_n = p.num_resources;
        for &r in &dirty {
            for &e in p.graph.instance_edge_ids(r) {
                for k in 0..k_n {
                    y[e * k_n + k] += rng.uniform(0.0, 5.0);
                }
            }
        }
        let mut y_full = y.clone();
        project(&p, &mut y_full, 0);
        project_instances(&p, &mut y, &dirty, 0);
        for i in 0..y.len() {
            assert!(
                (y[i] - y_full[i]).abs() < 1e-12,
                "subset projection diverged at {i}: {} vs {}",
                y[i],
                y_full[i]
            );
        }
        p.check_feasible(&y, 1e-7).unwrap();
    }

    #[test]
    fn projection_idempotent() {
        let p = synthesize(&Scenario::small());
        let mut rng = Rng::new(21);
        let mut z: Vec<f64> =
            (0..p.decision_len()).map(|_| rng.uniform(-2.0, 10.0)).collect();
        project(&p, &mut z, 0);
        let once = z.clone();
        project(&p, &mut z, 0);
        for i in 0..z.len() {
            assert!((z[i] - once[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn projection_nonexpansive() {
        // ‖P(z1) − P(z2)‖ ≤ ‖z1 − z2‖ — step (i) of Eq. 37.  Under the
        // edge-major layout every coordinate is on-edge, so the distances
        // are plain Euclidean norms of the whole tensors.
        let p = synthesize(&Scenario::small());
        check("nonexpansive", 50, |rng, _| {
            let mut z1: Vec<f64> =
                (0..p.decision_len()).map(|_| rng.uniform(-1.0, 6.0)).collect();
            let mut z2: Vec<f64> =
                z1.iter().map(|v| v + rng.uniform(-0.5, 0.5)).collect();
            let d_in: f64 = z1.iter().zip(&z2).map(|(a, b)| (a - b) * (a - b)).sum();
            project(&p, &mut z1, 0);
            project(&p, &mut z2, 0);
            let d_out: f64 = z1.iter().zip(&z2).map(|(a, b)| (a - b) * (a - b)).sum();
            ensure(d_out <= d_in + 1e-9, || {
                format!("expansion: {} > {}", d_out.sqrt(), d_in.sqrt())
            })
        });
    }
}
