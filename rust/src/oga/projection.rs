//! The fast Euclidean projection onto Y (Algorithm 1, steps 6–31).
//!
//! The projection decomposes per (r, k) pair: each column
//! v = y[·, r, k] over the ports l ∈ L_r solves
//!
//! ```text
//! min ‖v − z‖²   s.t.   0 ≤ v_l ≤ a_l^k,   Σ_l v_l ≤ c_r^k .
//! ```
//!
//! KKT (Eq. 34) gives v_l = clip(z_l − ρ/2, 0, a_l) with water level
//! τ = ρ/2 ≥ 0, zero if the capacity constraint is slack.  The paper
//! finds τ by sorting the column and iterating the B¹/B²/B³ partition;
//! `project_channel` implements the equivalent exact *breakpoint scan*:
//! g(τ) = Σ_l clip(z_l − τ, 0, a_l) is piecewise linear and decreasing
//! with breakpoints {z_l} ∪ {z_l − a_l}, so one sort of the 2·|L_r|
//! breakpoints plus one linear scan pins the segment where g(τ) = c and
//! solves for τ in closed form — same O(|L_r| log |L_r|) complexity and
//! the same sorted structure as the paper's inner/outer loop, but with a
//! termination argument that doesn't rely on uniform caps.
//!
//! Columns are independent, so `project` runs them in parallel over a
//! scoped thread pool (the "for each (r, k) in parallel" of Alg. 1).

use crate::model::Problem;
use crate::utils::pool;

/// Per-worker scratch for one channel projection (reused across columns).
#[derive(Clone, Debug, Default)]
pub struct ChannelScratch {
    vals: Vec<f64>,
    caps: Vec<f64>,
    breaks: Vec<f64>,
}

/// Exact projection of one (r, k) column.
///
/// `vals[i]`/`caps[i]` are z and a for the i-th port of L_r; on return
/// `vals` holds the projected v.  Returns the water level τ (= ρ/2 of
/// Eq. 35), 0.0 when the capacity constraint is slack.
pub fn project_channel(vals: &mut [f64], caps: &[f64], capacity: f64,
                       breaks: &mut Vec<f64>) -> f64 {
    debug_assert_eq!(vals.len(), caps.len());
    // Fast path: if the box-clipped point fits the capacity, τ = 0
    // (KKT: ρ > 0 only when the capacity constraint is tight).  The
    // original z must be kept for the scan below — clipping first and
    // scanning the clipped values changes the answer (a coordinate far
    // above its cap must stay pinned at the cap while others drain).
    let used: f64 = vals
        .iter()
        .zip(caps)
        .map(|(&z, &a)| z.clamp(0.0, a))
        .sum();
    if used <= capacity {
        for i in 0..vals.len() {
            vals[i] = vals[i].clamp(0.0, caps[i]);
        }
        return 0.0;
    }

    // Capacity binds: find τ with g(τ) = Σ clip(z−τ, 0, a) = capacity.
    // g is piecewise linear, decreasing; its breakpoints are where any
    // coordinate enters/leaves the interior regime: τ = z_i (leaves zero
    // set) and τ = z_i − a_i (leaves the cap set).
    breaks.clear();
    for i in 0..vals.len() {
        breaks.push(vals[i]);
        breaks.push(vals[i] - caps[i]);
    }
    breaks.retain(|&b| b > 0.0);
    breaks.sort_by(|a, b| b.partial_cmp(a).unwrap()); // descending
    breaks.push(0.0);

    // g(τ) and the number of interior coordinates at level τ⁺.
    let g_at = |tau: f64| -> (f64, f64) {
        let mut g = 0.0;
        let mut interior = 0.0;
        for i in 0..vals.len() {
            let v = vals[i] - tau;
            if v <= 0.0 {
                // zero set
            } else if v >= caps[i] {
                g += caps[i];
            } else {
                g += v;
                interior += 1.0;
            }
        }
        (g, interior)
    };

    // Scan from the largest breakpoint (g smallest) downward; stop at the
    // first breakpoint where g(τ) ≥ capacity — the crossing lies in
    // [tau, prev_tau].  g is linear *inside* the segment; boundary
    // points belong to both adjacent regimes (a coordinate with
    // z_i − a_i == τ is "capped" at τ but interior just above), so the
    // slope must be sampled at the segment midpoint, not an endpoint.
    let mut prev_tau = breaks[0];
    for &tau in breaks.iter() {
        let (g, _) = g_at(tau);
        if g >= capacity {
            let mid = 0.5 * (tau + prev_tau);
            let (g_mid, interior) = g_at(mid);
            // solve g(mid) − interior·(τ* − mid) = capacity
            let tau_star = if interior > 0.0 {
                mid + (g_mid - capacity) / interior
            } else {
                tau
            };
            let tau_star = tau_star.clamp(tau, prev_tau);
            for i in 0..vals.len() {
                vals[i] = (vals[i] - tau_star).clamp(0.0, caps[i]);
            }
            return tau_star;
        }
        prev_tau = tau;
    }
    // g(0) > capacity was established, so we must have returned above.
    unreachable!("breakpoint scan failed to bracket the water level");
}

/// Reference projector for tests: bisection on τ (slow, obviously
/// correct).  Mirrors python/compile/kernels/ref.py::project_ref.
pub fn project_channel_bisect(vals: &mut [f64], caps: &[f64], capacity: f64) -> f64 {
    let used: f64 = vals.iter().zip(caps).map(|(&z, &a)| z.clamp(0.0, a)).sum();
    if used <= capacity {
        for i in 0..vals.len() {
            vals[i] = vals[i].clamp(0.0, caps[i]);
        }
        return 0.0;
    }
    let g = |tau: f64| -> f64 {
        vals.iter().zip(caps).map(|(&z, &a)| (z - tau).clamp(0.0, a)).sum()
    };
    let mut lo = 0.0;
    let mut hi = vals.iter().copied().fold(0.0, f64::max) + 1e-9;
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if g(mid) > capacity {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let tau = hi;
    for i in 0..vals.len() {
        vals[i] = (vals[i] - tau).clamp(0.0, caps[i]);
    }
    tau
}

/// Project the dense decision tensor `z` [L, R, K] onto Y in place.
///
/// Off-edge coordinates are zeroed.  Channels are distributed over
/// `workers` threads (0 = auto); each instance r owns the disjoint slice
/// of coordinates {(l, r, k) : l, k}, so parallelizing over r is race-free.
pub fn project(problem: &Problem, z: &mut [f64], workers: usize) {
    let r_n = problem.num_instances();
    // Thread-spawn costs ~100us per worker per call; below this tensor
    // size the serial scan wins outright (measured in
    // benches/ablation_projection.rs — see EXPERIMENTS.md §Perf).
    const SERIAL_THRESHOLD: usize = 65_536;
    if workers <= 1 || (workers == 0 && z.len() < SERIAL_THRESHOLD) {
        return project_serial(problem, z);
    }
    let workers = if workers == 0 {
        // one worker per ~64k tensor elements, capped by cores
        pool::default_workers(r_n).min((z.len() / 32_768).max(2))
    } else {
        workers
    };
    let shared = SharedTensor { ptr: z.as_mut_ptr(), len: z.len() };
    let shared = &shared; // capture the Sync wrapper, not the raw pointer field
    pool::parallel_for(r_n, workers, |r| {
        // SAFETY: instance r touches only indices (l*R + r)*K + k — disjoint
        // across distinct r.
        let z = unsafe { std::slice::from_raw_parts_mut(shared.ptr, shared.len) };
        let mut scratch = ChannelScratch::default();
        project_instance(problem, r, z, &mut scratch);
    });
}

/// Serial variant (used by benches to measure the parallel speedup).
pub fn project_serial(problem: &Problem, z: &mut [f64]) {
    let mut scratch = ChannelScratch::default();
    for r in 0..problem.num_instances() {
        project_instance(problem, r, z, &mut scratch);
    }
}

/// Project all K channels of instance r and zero its off-edge entries.
fn project_instance(problem: &Problem, r: usize, z: &mut [f64], scratch: &mut ChannelScratch) {
    let k_n = problem.num_resources;
    let ports = &problem.graph.instances_to_ports[r];
    // zero off-edge coordinates of this instance
    for l in 0..problem.num_ports() {
        if !problem.graph.has_edge(l, r) {
            let base = problem.idx(l, r, 0);
            z[base..base + k_n].fill(0.0);
        }
    }
    if ports.is_empty() {
        return;
    }
    for k in 0..k_n {
        scratch.vals.clear();
        scratch.caps.clear();
        for &l in ports {
            scratch.vals.push(z[problem.idx(l, r, k)]);
            scratch.caps.push(problem.demand_at(l, k));
        }
        project_channel(
            &mut scratch.vals,
            &scratch.caps,
            problem.capacity_at(r, k),
            &mut scratch.breaks,
        );
        for (i, &l) in ports.iter().enumerate() {
            z[problem.idx(l, r, k)] = scratch.vals[i];
        }
    }
}

/// Pointer wrapper so the scoped threads can share the tensor; safety is
/// argued at the call site (disjoint index ownership per instance).
struct SharedTensor {
    ptr: *mut f64,
    len: usize,
}
unsafe impl Sync for SharedTensor {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::traces::synthesize;
    use crate::utils::prop::{check, ensure};
    use crate::utils::rng::Rng;

    fn channel_case(rng: &mut Rng, n: usize) -> (Vec<f64>, Vec<f64>, f64) {
        let vals: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 6.0)).collect();
        let caps: Vec<f64> = (0..n).map(|_| rng.uniform(0.2, 3.0)).collect();
        let capacity = rng.uniform(0.2, 0.7 * caps.iter().sum::<f64>());
        (vals, caps, capacity)
    }

    #[test]
    fn channel_matches_bisection_reference() {
        check("channel-vs-bisect", 300, |rng, size| {
            let n = rng.range(1, size.dim(40, 1));
            let (vals, caps, capacity) = channel_case(rng, n);
            let mut fast = vals.clone();
            let mut slow = vals.clone();
            let mut breaks = Vec::new();
            project_channel(&mut fast, &caps, capacity, &mut breaks);
            project_channel_bisect(&mut slow, &caps, capacity);
            for i in 0..n {
                if (fast[i] - slow[i]).abs() > 1e-6 {
                    return Err(format!(
                        "i={i}: fast={} slow={} (vals={vals:?} caps={caps:?} c={capacity})",
                        fast[i], slow[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn channel_output_feasible_and_optimal_kkt() {
        check("channel-kkt", 300, |rng, size| {
            let n = rng.range(1, size.dim(30, 1));
            let (vals, caps, capacity) = channel_case(rng, n);
            let mut v = vals.clone();
            let mut breaks = Vec::new();
            let tau = project_channel(&mut v, &caps, capacity, &mut breaks);
            let sum: f64 = v.iter().sum();
            ensure(sum <= capacity + 1e-9, || format!("sum {sum} > cap {capacity}"))?;
            for i in 0..n {
                ensure(v[i] >= -1e-12 && v[i] <= caps[i] + 1e-12, || {
                    format!("box violated at {i}: {}", v[i])
                })?;
                // KKT stationarity: v_i = clip(z_i - tau, 0, a_i)
                let want = (vals[i] - tau).clamp(0.0, caps[i]);
                ensure((v[i] - want).abs() < 1e-9, || {
                    format!("stationarity at {i}: {} vs {want}", v[i])
                })?;
            }
            // complementary slackness: tau > 0 => capacity tight
            if tau > 1e-9 {
                ensure((sum - capacity).abs() < 1e-6, || {
                    format!("tau={tau} but sum={sum} != c={capacity}")
                })?;
            }
            Ok(())
        });
    }

    #[test]
    fn interior_point_untouched() {
        let mut v = vec![0.5, 0.25];
        let caps = [1.0, 1.0];
        let mut breaks = Vec::new();
        let tau = project_channel(&mut v, &caps, 10.0, &mut breaks);
        assert_eq!(tau, 0.0);
        assert_eq!(v, vec![0.5, 0.25]);
    }

    #[test]
    fn water_level_matches_eq35_hand_case() {
        // mirrors python test_water_level_matches_paper_rho: z=[3,2,1],
        // a=10, c=3 -> B3 = all, rho/2 = (6-3)/3 = 1.
        let mut v = vec![3.0, 2.0, 1.0];
        let caps = [10.0, 10.0, 10.0];
        let mut breaks = Vec::new();
        let tau = project_channel(&mut v, &caps, 3.0, &mut breaks);
        assert!((tau - 1.0).abs() < 1e-9, "tau={tau}");
        assert!((v[0] - 2.0).abs() < 1e-9);
        assert!((v[1] - 1.0).abs() < 1e-9);
        assert!(v[2].abs() < 1e-9);
    }

    #[test]
    fn caps_saturate_b1_set() {
        // largest value pinned at its cap (B1), rest water-filled
        let mut v = vec![5.0, 1.0, 0.8];
        let caps = [1.0, 2.0, 2.0];
        let mut breaks = Vec::new();
        let tau = project_channel(&mut v, &caps, 2.0, &mut breaks);
        assert!((v[0] - 1.0).abs() < 1e-9, "v={v:?} tau={tau}");
        let sum: f64 = v.iter().sum();
        assert!((sum - 2.0).abs() < 1e-8);
    }

    #[test]
    fn full_projection_feasible_parallel_equals_serial() {
        let scenario = Scenario::small();
        let p = synthesize(&scenario);
        let mut rng = Rng::new(9);
        let mut z: Vec<f64> =
            (0..p.decision_len()).map(|_| rng.uniform(-1.0, 8.0)).collect();
        let mut z_par = z.clone();
        project_serial(&p, &mut z);
        project(&p, &mut z_par, 4);
        assert_eq!(z, z_par, "parallel and serial projections must agree exactly");
        p.check_feasible(&z, 1e-7).unwrap();
    }

    #[test]
    fn projection_idempotent() {
        let p = synthesize(&Scenario::small());
        let mut rng = Rng::new(21);
        let mut z: Vec<f64> =
            (0..p.decision_len()).map(|_| rng.uniform(-2.0, 10.0)).collect();
        project(&p, &mut z, 0);
        let once = z.clone();
        project(&p, &mut z, 0);
        for i in 0..z.len() {
            assert!((z[i] - once[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn projection_nonexpansive() {
        // ‖P(z1) − P(z2)‖ ≤ ‖z1 − z2‖ on-edge — step (i) of Eq. 37.
        let p = synthesize(&Scenario::small());
        check("nonexpansive", 50, |rng, _| {
            let mut z1: Vec<f64> =
                (0..p.decision_len()).map(|_| rng.uniform(-1.0, 6.0)).collect();
            let mut z2: Vec<f64> =
                z1.iter().map(|v| v + rng.uniform(-0.5, 0.5)).collect();
            // distance over on-edge coords only (off-edge are clamped)
            let mut d_in = 0.0;
            for l in 0..p.num_ports() {
                for &r in &p.graph.ports_to_instances[l] {
                    for k in 0..p.num_resources {
                        let i = p.idx(l, r, k);
                        d_in += (z1[i] - z2[i]).powi(2);
                    }
                }
            }
            project(&p, &mut z1, 0);
            project(&p, &mut z2, 0);
            let d_out: f64 = z1.iter().zip(&z2).map(|(a, b)| (a - b) * (a - b)).sum();
            ensure(d_out <= d_in + 1e-9, || {
                format!("expansion: {} > {}", d_out.sqrt(), d_in.sqrt())
            })
        });
    }
}
