//! Dense `[L, R, K]` reference implementation of the OGA hot path — the
//! seed's original storage layout, kept for two jobs:
//!
//!  1. **Layout-parity oracle** — `tests/layout_parity.rs` checks that
//!     the edge-major CSR gradient, fused ascent, projection, and slot
//!     reward agree coordinate-wise with these dense versions on random
//!     bipartite graphs.
//!  2. **Before/after baseline** — `benches/hot_path.rs` times
//!     [`DenseOgaState::step`] next to the CSR `OgaState::step`, so the
//!     layout speedup is measured inside one binary (recorded in
//!     `BENCH_hot_path.json` and EXPERIMENTS.md §Perf).
//!
//! The dense step reproduces the seed's cost profile deliberately:
//! off-edge coordinates are stored and re-zeroed on every projection,
//! every instance is projected every slot (no dirty tracking), and the
//! parallel path spawns fresh `std::thread::scope` workers per call.
//! Only the channel projector is shared with the CSR path, so the bench
//! isolates the layout/pool effect rather than the projector algorithm.

use crate::model::Problem;
use crate::oga::projection::project_channel;
use crate::reward::SlotReward;

/// Per-worker scratch for one dense channel projection.
#[derive(Default)]
struct DenseScratch {
    vals: Vec<f64>,
    caps: Vec<f64>,
    events: Vec<(f64, u32)>,
}

/// Length of the dense decision tensor [L, R, K].
pub fn dense_len(problem: &Problem) -> usize {
    problem.num_ports() * problem.num_instances() * problem.num_resources
}

/// Dense flat index (l * R + r) * K + k.
#[inline]
pub fn dense_idx(problem: &Problem, l: usize, r: usize, k: usize) -> usize {
    (l * problem.num_instances() + r) * problem.num_resources + k
}

/// Scatter an edge-major decision into a fresh dense tensor
/// (off-edge coordinates zero).
pub fn to_dense(problem: &Problem, y_csr: &[f64]) -> Vec<f64> {
    debug_assert_eq!(y_csr.len(), problem.decision_len());
    let k_n = problem.num_resources;
    let mut out = vec![0.0; dense_len(problem)];
    for e in 0..problem.num_edges() {
        let l = problem.graph.edge_port[e];
        let r = problem.graph.edge_instance[e];
        for k in 0..k_n {
            out[dense_idx(problem, l, r, k)] = y_csr[e * k_n + k];
        }
    }
    out
}

/// Gather the on-edge coordinates of a dense tensor into the edge-major
/// layout (off-edge values are dropped).
pub fn from_dense(problem: &Problem, y_dense: &[f64]) -> Vec<f64> {
    debug_assert_eq!(y_dense.len(), dense_len(problem));
    let k_n = problem.num_resources;
    let mut out = vec![0.0; problem.decision_len()];
    for e in 0..problem.num_edges() {
        let l = problem.graph.edge_port[e];
        let r = problem.graph.edge_instance[e];
        for k in 0..k_n {
            out[e * k_n + k] = y_dense[dense_idx(problem, l, r, k)];
        }
    }
    out
}

/// Dense ∇q of Eq. 30 (the seed's `gradient`): zero the whole [L, R, K]
/// buffer, then fill the arrived ports' on-edge rows.
pub fn gradient_dense(problem: &Problem, x: &[f64], y: &[f64], grad: &mut [f64]) {
    let k_n = problem.num_resources;
    debug_assert_eq!(y.len(), dense_len(problem));
    debug_assert_eq!(grad.len(), dense_len(problem));
    grad.fill(0.0);
    let mut quota = vec![0.0; k_n];
    for l in 0..problem.num_ports() {
        let x_l = x[l];
        if x_l == 0.0 {
            continue;
        }
        let instances = &problem.graph.ports_to_instances[l];
        quota.fill(0.0);
        for &r in instances {
            let base = dense_idx(problem, l, r, 0);
            for k in 0..k_n {
                quota[k] += y[base + k];
            }
        }
        let mut kstar = 0;
        let mut best = f64::NEG_INFINITY;
        for k in 0..k_n {
            let v = problem.beta[k] * quota[k];
            if v > best {
                best = v;
                kstar = k;
            }
        }
        for &r in instances {
            let base = dense_idx(problem, l, r, 0);
            let rk = r * k_n;
            for k in 0..k_n {
                let fp = problem.kind[rk + k].grad(y[base + k], problem.alpha[rk + k]);
                let pen = if k == kstar { problem.beta[k] } else { 0.0 };
                grad[base + k] = x_l * (fp - pen);
            }
        }
    }
}

/// Dense fused ascent (the seed's `OgaState::fused_ascent`).
pub fn fused_ascent_dense(problem: &Problem, x: &[f64], eta: f64, y: &mut [f64]) {
    let k_n = problem.num_resources;
    let mut quota = vec![0.0; k_n];
    for l in 0..problem.num_ports() {
        let x_l = x[l];
        if x_l == 0.0 {
            continue;
        }
        let instances = &problem.graph.ports_to_instances[l];
        quota.fill(0.0);
        for &r in instances {
            let base = dense_idx(problem, l, r, 0);
            for k in 0..k_n {
                quota[k] += y[base + k];
            }
        }
        let mut kstar = 0;
        let mut best = f64::NEG_INFINITY;
        for k in 0..k_n {
            let v = problem.beta[k] * quota[k];
            if v > best {
                best = v;
                kstar = k;
            }
        }
        for &r in instances {
            let base = dense_idx(problem, l, r, 0);
            let rk = r * k_n;
            for k in 0..k_n {
                let yv = y[base + k];
                let fp = problem.kind[rk + k].grad(yv, problem.alpha[rk + k]);
                let pen = if k == kstar { problem.beta[k] } else { 0.0 };
                y[base + k] = yv + eta * x_l * (fp - pen);
            }
        }
    }
}

/// Dense projection (the seed's `project`): zero off-edge coordinates of
/// every instance, project every (r, k) channel, and — exactly like the
/// seed — spawn fresh scoped threads when the tensor is large.
pub fn project_dense(problem: &Problem, z: &mut [f64], workers: usize) {
    let r_n = problem.num_instances();
    const SERIAL_THRESHOLD: usize = 65_536;
    if workers == 1 || (workers == 0 && z.len() < SERIAL_THRESHOLD) {
        return project_dense_serial(problem, z);
    }
    let workers = if workers == 0 {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        cores.min(r_n).max(1).min((z.len() / 32_768).max(2))
    } else {
        workers
    };
    let shared = SharedTensor { ptr: z.as_mut_ptr(), len: z.len() };
    let shared = &shared;
    // the seed's per-call scoped spawn, preserved so the baseline pays
    // the same ~100µs/worker dispatch the issue calls out
    let chunk = r_n.div_ceil(workers.max(1));
    std::thread::scope(|scope| {
        for w in 0..workers {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(r_n);
            if lo >= hi {
                continue;
            }
            scope.spawn(move || {
                // SAFETY: instance r touches only indices (l*R + r)*K + k
                // — disjoint across distinct r.
                let z = unsafe { std::slice::from_raw_parts_mut(shared.ptr, shared.len) };
                let mut scratch = DenseScratch::default();
                for r in lo..hi {
                    project_instance_dense(problem, r, z, &mut scratch);
                }
            });
        }
    });
}

/// Serial dense projection.
pub fn project_dense_serial(problem: &Problem, z: &mut [f64]) {
    let mut scratch = DenseScratch::default();
    for r in 0..problem.num_instances() {
        project_instance_dense(problem, r, z, &mut scratch);
    }
}

fn project_instance_dense(
    problem: &Problem,
    r: usize,
    z: &mut [f64],
    scratch: &mut DenseScratch,
) {
    let k_n = problem.num_resources;
    let ports = &problem.graph.instances_to_ports[r];
    // the dense layout stores off-edge coordinates, so they must be
    // re-zeroed on every call — the O(L·R·K) term the CSR layout removes
    for l in 0..problem.num_ports() {
        if !problem.graph.has_edge(l, r) {
            let base = dense_idx(problem, l, r, 0);
            z[base..base + k_n].fill(0.0);
        }
    }
    if ports.is_empty() {
        return;
    }
    for k in 0..k_n {
        scratch.vals.clear();
        scratch.caps.clear();
        for &l in ports {
            scratch.vals.push(z[dense_idx(problem, l, r, k)]);
            scratch.caps.push(problem.demand_at(l, k));
        }
        project_channel(
            &mut scratch.vals,
            &scratch.caps,
            problem.capacity_at(r, k),
            &mut scratch.events,
        );
        for (i, &l) in ports.iter().enumerate() {
            z[dense_idx(problem, l, r, k)] = scratch.vals[i];
        }
    }
}

/// Dense slot reward (Eqs. 7–8 over the [L, R, K] tensor).
pub fn slot_reward_dense(problem: &Problem, x: &[f64], y: &[f64]) -> SlotReward {
    let k_n = problem.num_resources;
    let mut out = SlotReward::default();
    let mut quota = vec![0.0; k_n];
    for l in 0..problem.num_ports() {
        if x[l] == 0.0 {
            continue;
        }
        let mut gain = 0.0;
        quota.fill(0.0);
        for &r in &problem.graph.ports_to_instances[l] {
            let base = dense_idx(problem, l, r, 0);
            let rk = r * k_n;
            for k in 0..k_n {
                let v = y[base + k];
                gain += problem.kind[rk + k].value(v, problem.alpha[rk + k]);
                quota[k] += v;
            }
        }
        let mut penalty = 0.0f64;
        for k in 0..k_n {
            penalty = penalty.max(problem.beta[k] * quota[k]);
        }
        out.gain += x[l] * gain;
        out.penalty += x[l] * penalty;
        out.q += x[l] * (gain - penalty);
    }
    out
}

/// Dense OGA state: the seed's per-slot loop (fused ascent + full dense
/// projection), used as the hot-path baseline.
pub struct DenseOgaState {
    pub y: Vec<f64>,
    pub t: usize,
    pub workers: usize,
}

impl DenseOgaState {
    pub fn new(problem: &Problem, workers: usize) -> Self {
        DenseOgaState { y: vec![0.0; dense_len(problem)], t: 0, workers }
    }

    /// One dense OGA slot at a fixed step size.
    pub fn step(&mut self, problem: &Problem, x: &[f64], eta: f64) {
        fused_ascent_dense(problem, x, eta, &mut self.y);
        project_dense(problem, &mut self.y, self.workers);
        self.t += 1;
    }
}

struct SharedTensor {
    ptr: *mut f64,
    len: usize,
}
unsafe impl Sync for SharedTensor {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::traces::synthesize;
    use crate::utils::rng::Rng;

    #[test]
    fn dense_roundtrip_preserves_on_edge() {
        let p = synthesize(&Scenario::small());
        let mut rng = Rng::new(4);
        let y: Vec<f64> = (0..p.decision_len()).map(|_| rng.uniform(0.0, 3.0)).collect();
        let dense = to_dense(&p, &y);
        assert_eq!(dense.len(), dense_len(&p));
        assert_eq!(from_dense(&p, &dense), y);
    }

    #[test]
    fn dense_projection_serial_equals_parallel() {
        let p = synthesize(&Scenario::small());
        let mut rng = Rng::new(12);
        let z: Vec<f64> = (0..dense_len(&p)).map(|_| rng.uniform(-1.0, 6.0)).collect();
        let mut a = z.clone();
        let mut b = z;
        project_dense_serial(&p, &mut a);
        project_dense(&p, &mut b, 4);
        assert_eq!(a, b);
    }
}
