//! The vectorized kind-batched kernel layer (§Perf-5).
//!
//! PR 2 hoisted the utility-family `match` out of the hot loops
//! (`model::KindIndex` same-kind runs); the leaf kernels
//! `UtilityKind::{value_sum, grad_into, ascend_slice}` were left as
//! branch-free scalar loops "designed to auto-vectorize".  This module
//! makes the lane level explicit:
//!
//! * the **default (stable) build** runs scalar loops restructured into
//!   a fixed-width **lane-tree** accumulation order — reductions keep
//!   [`LANES`] independent accumulators over full blocks and combine
//!   them in a fixed binary tree, with the remainder summed sequentially
//!   and added last;
//! * the **`simd` feature** (nightly, `std::simd`) runs the same kernels
//!   on `f64x4`/`f32x8` lanes.  Because the SIMD twin reproduces the
//!   scalar path's block structure and combine tree exactly, and every
//!   per-lane operation (`+ - * / sqrt max`) is the identically-rounded
//!   IEEE op, **both paths produce bit-identical floats** — pinned by
//!   `tests/kernel_parity.rs` across all four families at slice lengths
//!   covering the remainder lanes.  (`ln` has no portable-SIMD form; the
//!   Log family evaluates it per lane through the same `f64::ln`, so
//!   parity holds there too, at lane-serial cost.)
//! * the sequential pre-§Perf-5 loops are kept as `*_ref` parity
//!   references (the role `oga::dense_ref` plays for the layout).
//!
//! Element-wise kernels (`grad_into`, `ascend_slice`, [`accumulate`])
//! have no accumulation order, so their scalar form *is* the reference
//! and the SIMD twin is bitwise-equal lane math; only the reduction
//! ([`value_sum`]) changes floats relative to the sequential reference —
//! by a few ulps, uniformly on both build paths.
//!
//! The `_f32` twins mirror the artifact path's numerics
//! (`runtime::executor` runs the PJRT-compiled step in f32): the same
//! Eq. 51 calculus evaluated entirely in f32, [`LANES_F32`]-wide.
//!
//! Shared per-edge kernels live here too: [`ascend_edge`] (the sharded
//! fused-ascent body) and [`mirror_edge`] (the sharded multiplicative
//! update) — both cut an edge's K lane into maximal same-kind sub-runs
//! and stream the same element-wise kernels, so per-element floats
//! cannot depend on who computes them.

use crate::model::{KindIndex, Problem};
use crate::oga::utilities::UtilityKind;

/// f64 lane width of the fixed accumulation tree (`f64x4` under `simd`).
pub const LANES: usize = 4;
/// f32 lane width (`f32x8` under `simd`) — the artifact-path numerics.
pub const LANES_F32: usize = 8;

// ------------------------------------------------------------------
// Sequential references (the pre-§Perf-5 scalar loops, kept as the
// parity oracle for tests and the scalar-vs-lane bench rows).
// ------------------------------------------------------------------

/// Σ_i f(y_i, α_i), sequential left-to-right (reference).
pub fn value_sum_ref(kind: UtilityKind, y: &[f64], alpha: &[f64]) -> f64 {
    debug_assert_eq!(y.len(), alpha.len());
    let mut acc = 0.0;
    for (v, &a) in y.iter().zip(alpha) {
        acc += kind.value(*v, a);
    }
    acc
}

/// out_i = scale · f'(y_i, α_i), plain loop (reference).
pub fn grad_into_ref(kind: UtilityKind, y: &[f64], alpha: &[f64], scale: f64, out: &mut [f64]) {
    debug_assert_eq!(y.len(), alpha.len());
    debug_assert_eq!(y.len(), out.len());
    for i in 0..y.len() {
        out[i] = scale * kind.grad(y[i], alpha[i]);
    }
}

/// y_i += scale · f'(y_i, α_i), plain loop (reference; f' at pre-update y).
pub fn ascend_slice_ref(kind: UtilityKind, y: &mut [f64], alpha: &[f64], scale: f64) {
    debug_assert_eq!(y.len(), alpha.len());
    for (v, &a) in y.iter_mut().zip(alpha) {
        *v += scale * kind.grad(*v, a);
    }
}

// ------------------------------------------------------------------
// f32 per-element calculus — Eq. 51 evaluated entirely in f32, the
// numerics of the PJRT artifact path (runtime::executor).
// ------------------------------------------------------------------

/// f(y) in f32 (artifact-path numerics; same clamp as the f64 calculus).
#[inline(always)]
pub fn value_f32(kind: UtilityKind, y: f32, alpha: f32) -> f32 {
    let y = y.max(0.0);
    match kind {
        UtilityKind::Linear => alpha * y,
        UtilityKind::Log => alpha * (y + 1.0).ln(),
        UtilityKind::Reciprocal => 1.0 / alpha - 1.0 / (y + alpha),
        UtilityKind::Poly => alpha * (y + 1.0).sqrt() - alpha,
    }
}

/// f'(y) in f32.
#[inline(always)]
pub fn grad_f32(kind: UtilityKind, y: f32, alpha: f32) -> f32 {
    let y = y.max(0.0);
    match kind {
        UtilityKind::Linear => alpha,
        UtilityKind::Log => alpha / (y + 1.0),
        UtilityKind::Reciprocal => {
            let d = y + alpha;
            1.0 / (d * d)
        }
        UtilityKind::Poly => alpha / (2.0 * (y + 1.0).sqrt()),
    }
}

/// Sequential f32 reference of [`value_sum_f32`].
pub fn value_sum_f32_ref(kind: UtilityKind, y: &[f32], alpha: &[f32]) -> f32 {
    debug_assert_eq!(y.len(), alpha.len());
    let mut acc = 0.0f32;
    for (v, &a) in y.iter().zip(alpha) {
        acc += value_f32(kind, *v, a);
    }
    acc
}

/// Plain-loop f32 reference of [`grad_into_f32`].
pub fn grad_into_f32_ref(
    kind: UtilityKind,
    y: &[f32],
    alpha: &[f32],
    scale: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(y.len(), alpha.len());
    debug_assert_eq!(y.len(), out.len());
    for i in 0..y.len() {
        out[i] = scale * grad_f32(kind, y[i], alpha[i]);
    }
}

// ------------------------------------------------------------------
// The hot kernels — scalar lane-tree path (default, stable).
// `#[inline(always)]` + the per-variant dispatch in `utilities.rs`
// keeps the `kind` match constant-folded out of the loop bodies,
// exactly like the pre-§Perf-5 `*_with` helpers.
// ------------------------------------------------------------------

#[cfg(not(feature = "simd"))]
#[inline(always)]
pub fn value_sum(kind: UtilityKind, y: &[f64], alpha: &[f64]) -> f64 {
    debug_assert_eq!(y.len(), alpha.len());
    let n = y.len();
    let blocks = n - n % LANES;
    let mut acc = [0.0f64; LANES];
    let mut i = 0;
    while i < blocks {
        for j in 0..LANES {
            acc[j] += kind.value(y[i + j], alpha[i + j]);
        }
        i += LANES;
    }
    let mut tail = 0.0;
    for j in blocks..n {
        tail += kind.value(y[j], alpha[j]);
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + tail
}

#[cfg(not(feature = "simd"))]
#[inline(always)]
pub fn grad_into(kind: UtilityKind, y: &[f64], alpha: &[f64], scale: f64, out: &mut [f64]) {
    // element-wise: the reference loop *is* the lane path (no
    // accumulation order to restructure)
    grad_into_ref(kind, y, alpha, scale, out);
}

#[cfg(not(feature = "simd"))]
#[inline(always)]
pub fn ascend_slice(kind: UtilityKind, y: &mut [f64], alpha: &[f64], scale: f64) {
    ascend_slice_ref(kind, y, alpha, scale);
}

/// acc_i += add_i — the quota-accumulation kernel shared by the per-port
/// reductions (`reward::port_reward_kinds`, `oga::port_kstar`, the
/// Eq. 30 gradient).  Element-wise across the K lane, sequential across
/// edges, so the lane width is unobservable in the floats.
#[cfg(not(feature = "simd"))]
#[inline(always)]
pub fn accumulate(acc: &mut [f64], add: &[f64]) {
    debug_assert_eq!(acc.len(), add.len());
    for i in 0..acc.len() {
        acc[i] += add[i];
    }
}

#[cfg(not(feature = "simd"))]
#[inline(always)]
pub fn value_sum_f32(kind: UtilityKind, y: &[f32], alpha: &[f32]) -> f32 {
    debug_assert_eq!(y.len(), alpha.len());
    let n = y.len();
    let blocks = n - n % LANES_F32;
    let mut acc = [0.0f32; LANES_F32];
    let mut i = 0;
    while i < blocks {
        for j in 0..LANES_F32 {
            acc[j] += value_f32(kind, y[i + j], alpha[i + j]);
        }
        i += LANES_F32;
    }
    let mut tail = 0.0f32;
    for j in blocks..n {
        tail += value_f32(kind, y[j], alpha[j]);
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

#[cfg(not(feature = "simd"))]
#[inline(always)]
pub fn grad_into_f32(kind: UtilityKind, y: &[f32], alpha: &[f32], scale: f32, out: &mut [f32]) {
    grad_into_f32_ref(kind, y, alpha, scale, out);
}

#[cfg(not(feature = "simd"))]
#[inline(always)]
pub fn ascend_slice_f32(kind: UtilityKind, y: &mut [f32], alpha: &[f32], scale: f32) {
    debug_assert_eq!(y.len(), alpha.len());
    for (v, &a) in y.iter_mut().zip(alpha) {
        *v += scale * grad_f32(kind, *v, a);
    }
}

// ------------------------------------------------------------------
// The hot kernels — portable-SIMD path (`--features simd`, nightly).
// Same block structure, same combine tree, identically-rounded lane
// ops ⇒ bit-identical to the scalar lane-tree path above.
// ------------------------------------------------------------------

#[cfg(feature = "simd")]
mod vector {
    use super::*;
    use std::simd::prelude::*;
    use std::simd::StdFloat;

    type F64s = Simd<f64, LANES>;
    type F32s = Simd<f32, LANES_F32>;

    /// Per-lane `ln` — no portable-SIMD transcendental exists; routing
    /// through the same `f64::ln` keeps bit parity with the scalar path
    /// (at lane-serial cost, see the §Perf-5 kernel table).
    #[inline(always)]
    fn ln_lanes(v: F64s) -> F64s {
        F64s::from_array(v.to_array().map(f64::ln))
    }

    #[inline(always)]
    fn ln_lanes_f32(v: F32s) -> F32s {
        F32s::from_array(v.to_array().map(f32::ln))
    }

    /// f(y) on a lane block — op-for-op the scalar `UtilityKind::value`.
    #[inline(always)]
    fn value_lanes(kind: UtilityKind, y: F64s, a: F64s) -> F64s {
        let y = y.simd_max(F64s::splat(0.0));
        let one = F64s::splat(1.0);
        match kind {
            UtilityKind::Linear => a * y,
            UtilityKind::Log => a * ln_lanes(y + one),
            UtilityKind::Reciprocal => one / a - one / (y + a),
            UtilityKind::Poly => a * (y + one).sqrt() - a,
        }
    }

    /// f'(y) on a lane block — op-for-op the scalar `UtilityKind::grad`.
    #[inline(always)]
    fn grad_lanes(kind: UtilityKind, y: F64s, a: F64s) -> F64s {
        let y = y.simd_max(F64s::splat(0.0));
        let one = F64s::splat(1.0);
        match kind {
            UtilityKind::Linear => a,
            UtilityKind::Log => a / (y + one),
            UtilityKind::Reciprocal => {
                let d = y + a;
                one / (d * d)
            }
            UtilityKind::Poly => a / (F64s::splat(2.0) * (y + one).sqrt()),
        }
    }

    #[inline(always)]
    pub fn value_sum(kind: UtilityKind, y: &[f64], alpha: &[f64]) -> f64 {
        debug_assert_eq!(y.len(), alpha.len());
        let n = y.len();
        let blocks = n - n % LANES;
        let mut acc = F64s::splat(0.0);
        let mut i = 0;
        while i < blocks {
            let yv = F64s::from_slice(&y[i..i + LANES]);
            let av = F64s::from_slice(&alpha[i..i + LANES]);
            acc += value_lanes(kind, yv, av);
            i += LANES;
        }
        let a = acc.to_array();
        let mut tail = 0.0;
        for j in blocks..n {
            tail += kind.value(y[j], alpha[j]);
        }
        ((a[0] + a[1]) + (a[2] + a[3])) + tail
    }

    #[inline(always)]
    pub fn grad_into(kind: UtilityKind, y: &[f64], alpha: &[f64], scale: f64, out: &mut [f64]) {
        debug_assert_eq!(y.len(), alpha.len());
        debug_assert_eq!(y.len(), out.len());
        let n = y.len();
        let blocks = n - n % LANES;
        let s = F64s::splat(scale);
        let mut i = 0;
        while i < blocks {
            let yv = F64s::from_slice(&y[i..i + LANES]);
            let av = F64s::from_slice(&alpha[i..i + LANES]);
            (s * grad_lanes(kind, yv, av)).copy_to_slice(&mut out[i..i + LANES]);
            i += LANES;
        }
        for j in blocks..n {
            out[j] = scale * kind.grad(y[j], alpha[j]);
        }
    }

    #[inline(always)]
    pub fn ascend_slice(kind: UtilityKind, y: &mut [f64], alpha: &[f64], scale: f64) {
        debug_assert_eq!(y.len(), alpha.len());
        let n = y.len();
        let blocks = n - n % LANES;
        let s = F64s::splat(scale);
        let mut i = 0;
        while i < blocks {
            let yv = F64s::from_slice(&y[i..i + LANES]);
            let av = F64s::from_slice(&alpha[i..i + LANES]);
            (yv + s * grad_lanes(kind, yv, av)).copy_to_slice(&mut y[i..i + LANES]);
            i += LANES;
        }
        for j in blocks..n {
            y[j] += scale * kind.grad(y[j], alpha[j]);
        }
    }

    #[inline(always)]
    pub fn accumulate(acc: &mut [f64], add: &[f64]) {
        debug_assert_eq!(acc.len(), add.len());
        let n = acc.len();
        let blocks = n - n % LANES;
        let mut i = 0;
        while i < blocks {
            let av = F64s::from_slice(&acc[i..i + LANES]);
            let bv = F64s::from_slice(&add[i..i + LANES]);
            (av + bv).copy_to_slice(&mut acc[i..i + LANES]);
            i += LANES;
        }
        for j in blocks..n {
            acc[j] += add[j];
        }
    }

    #[inline(always)]
    fn value_lanes_f32(kind: UtilityKind, y: F32s, a: F32s) -> F32s {
        let y = y.simd_max(F32s::splat(0.0));
        let one = F32s::splat(1.0);
        match kind {
            UtilityKind::Linear => a * y,
            UtilityKind::Log => a * ln_lanes_f32(y + one),
            UtilityKind::Reciprocal => one / a - one / (y + a),
            UtilityKind::Poly => a * (y + one).sqrt() - a,
        }
    }

    #[inline(always)]
    fn grad_lanes_f32(kind: UtilityKind, y: F32s, a: F32s) -> F32s {
        let y = y.simd_max(F32s::splat(0.0));
        let one = F32s::splat(1.0);
        match kind {
            UtilityKind::Linear => a,
            UtilityKind::Log => a / (y + one),
            UtilityKind::Reciprocal => {
                let d = y + a;
                one / (d * d)
            }
            UtilityKind::Poly => a / (F32s::splat(2.0) * (y + one).sqrt()),
        }
    }

    #[inline(always)]
    pub fn value_sum_f32(kind: UtilityKind, y: &[f32], alpha: &[f32]) -> f32 {
        debug_assert_eq!(y.len(), alpha.len());
        let n = y.len();
        let blocks = n - n % LANES_F32;
        let mut acc = F32s::splat(0.0);
        let mut i = 0;
        while i < blocks {
            let yv = F32s::from_slice(&y[i..i + LANES_F32]);
            let av = F32s::from_slice(&alpha[i..i + LANES_F32]);
            acc += value_lanes_f32(kind, yv, av);
            i += LANES_F32;
        }
        let a = acc.to_array();
        let mut tail = 0.0f32;
        for j in blocks..n {
            tail += value_f32(kind, y[j], alpha[j]);
        }
        (((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]))) + tail
    }

    #[inline(always)]
    pub fn grad_into_f32(
        kind: UtilityKind,
        y: &[f32],
        alpha: &[f32],
        scale: f32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(y.len(), alpha.len());
        debug_assert_eq!(y.len(), out.len());
        let n = y.len();
        let blocks = n - n % LANES_F32;
        let s = F32s::splat(scale);
        let mut i = 0;
        while i < blocks {
            let yv = F32s::from_slice(&y[i..i + LANES_F32]);
            let av = F32s::from_slice(&alpha[i..i + LANES_F32]);
            (s * grad_lanes_f32(kind, yv, av)).copy_to_slice(&mut out[i..i + LANES_F32]);
            i += LANES_F32;
        }
        for j in blocks..n {
            out[j] = scale * grad_f32(kind, y[j], alpha[j]);
        }
    }

    #[inline(always)]
    pub fn ascend_slice_f32(kind: UtilityKind, y: &mut [f32], alpha: &[f32], scale: f32) {
        debug_assert_eq!(y.len(), alpha.len());
        let n = y.len();
        let blocks = n - n % LANES_F32;
        let s = F32s::splat(scale);
        let mut i = 0;
        while i < blocks {
            let yv = F32s::from_slice(&y[i..i + LANES_F32]);
            let av = F32s::from_slice(&alpha[i..i + LANES_F32]);
            (yv + s * grad_lanes_f32(kind, yv, av)).copy_to_slice(&mut y[i..i + LANES_F32]);
            i += LANES_F32;
        }
        for j in blocks..n {
            y[j] += scale * grad_f32(kind, y[j], alpha[j]);
        }
    }
}

#[cfg(feature = "simd")]
pub use vector::{
    accumulate, ascend_slice, ascend_slice_f32, grad_into, grad_into_f32, value_sum,
    value_sum_f32,
};

// ------------------------------------------------------------------
// Shared per-edge kernels (relocated here so the serial, sharded and
// mirror steps all stream through one implementation).
// ------------------------------------------------------------------

/// y[e·K..] += scale · f'(y, α) for one edge, cut into maximal
/// same-kind sub-runs so the call streams through the *same*
/// element-wise [`UtilityKind::ascend_slice`] kernel as the serial
/// port-run ascent — per-element semantics (and floats) are identical;
/// only the slice boundaries differ, which an element-wise kernel
/// cannot observe.  (The reduction kernel [`value_sum`] *can* observe
/// boundaries — it is only ever called on whole port runs.)
pub(crate) fn ascend_edge(
    problem: &Problem,
    kinds: &KindIndex,
    y: &mut [f64],
    e: usize,
    scale: f64,
) {
    let k_n = problem.num_resources;
    let base = e * k_n;
    let rk = problem.graph.edge_instance[e] * k_n;
    let mut k = 0;
    while k < k_n {
        let kind = problem.kind[rk + k];
        let start = k;
        k += 1;
        while k < k_n && problem.kind[rk + k] == kind {
            k += 1;
        }
        kind.ascend_slice(
            &mut y[base + start..base + k],
            &kinds.alpha_flat[base + start..base + k],
            scale,
        );
    }
}

/// One edge's multiplicative (mirror) update — the shared per-edge
/// kernel of the serial and sharded mirror steps (identical floats by
/// construction).  `scale` is η_t · x_l; β_{k*} is folded into the
/// exponent.  `max_exponent` keeps exp() finite under aggressive rates.
#[inline]
pub(crate) fn mirror_edge(
    problem: &Problem,
    y: &mut [f64],
    e: usize,
    scale: f64,
    kstar: usize,
    max_exponent: f64,
) {
    let k_n = problem.num_resources;
    let base = e * k_n;
    let rk = problem.graph.edge_instance[e] * k_n;
    for k in 0..k_n {
        let yv = y[base + k];
        let fp = problem.kind[rk + k].grad(yv, problem.alpha[rk + k]);
        let pen = if k == kstar { problem.beta[k] } else { 0.0 };
        let expo = (scale * (fp - pen)).clamp(-max_exponent, max_exponent);
        y[base + k] = yv * expo.exp();
    }
}

// The lane-tree contract is pinned in ONE place — the integration
// suite `tests/kernel_parity.rs`, whose in-test scalar oracle is what
// both build paths (scalar lane-tree and `--features simd`) must
// reproduce bit for bit.  In-module copies of that oracle would be
// tautological on the stable build (code compared to its own text), so
// this module deliberately carries no unit tests.
