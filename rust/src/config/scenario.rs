//! Typed scenario schema: the experiment knobs of Tab. 2 plus graph
//! shape, utility mix and seeding.  Scenarios can be built from defaults,
//! programmatically tweaked by the figure harnesses, or loaded from a
//! TOML-subset config file (see `examples/configs/*.toml`).

use crate::config::value::Doc;
use crate::coordinator::ReleaseMode;
use crate::obs::ObsLevel;
use crate::oga::utilities::UtilityMix;
use crate::utils::pool::ExecBudget;

/// How the bipartite graph is generated.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GraphSpec {
    /// Complete bipartite (no locality constraints).
    Full,
    /// Right d-regular.
    RightRegular(usize),
    /// Random with target density Σ|L_r|/|R|.
    Density(f64),
}

impl GraphSpec {
    pub fn name(&self) -> String {
        match self {
            GraphSpec::Full => "full".into(),
            GraphSpec::RightRegular(d) => format!("regular-{d}"),
            GraphSpec::Density(d) => format!("density-{d}"),
        }
    }
}

/// Fault-injection severity knobs (`[faults]` in config files; consumed
/// by `sim::faults`).  All rates are per-slot probabilities; the default
/// config injects nothing, so plain scenarios are churn-free.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultConfig {
    /// Per-slot probability of a single instance crash.
    pub instance_rate: f64,
    /// Per-slot, per-failed-entity recovery / re-arrival probability.
    pub recover_rate: f64,
    /// Per-slot probability of a port-class departure.
    pub port_rate: f64,
    /// Per-slot probability of a correlated rack burst (a contiguous
    /// block of instances failing together).
    pub rack_rate: f64,
    /// Instances felled by one rack burst.
    pub rack_size: usize,
    /// What happens to a failed instance's in-flight units: `Drain`
    /// lets them expire with the slot cycle, `Release` frees them
    /// immediately (see `coordinator::ReleaseMode`).
    pub release: ReleaseMode,
    /// Re-plan epoch rule: after churn the shard plan is refreshed in
    /// place, and LPT is re-run from scratch only when the refreshed
    /// plan's load imbalance (max/mean) exceeds this threshold.
    pub replan_threshold: f64,
    /// Seed of the fault event stream (independent of the scenario
    /// seed, so the same workload can be replayed under many fault
    /// trajectories).
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            instance_rate: 0.0,
            recover_rate: 0.05,
            port_rate: 0.0,
            rack_rate: 0.0,
            rack_size: 4,
            release: ReleaseMode::Drain,
            replan_threshold: 1.5,
            seed: 77,
        }
    }
}

impl FaultConfig {
    /// Does this config inject any faults at all?
    pub fn enabled(&self) -> bool {
        self.instance_rate > 0.0 || self.port_rate > 0.0 || self.rack_rate > 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("faults.instance_rate", self.instance_rate),
            ("faults.recover_rate", self.recover_rate),
            ("faults.port_rate", self.port_rate),
            ("faults.rack_rate", self.rack_rate),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("{name} {v} outside [0,1]"));
            }
        }
        if self.rack_size == 0 {
            return Err("faults.rack_size must be > 0".into());
        }
        if self.replan_threshold < 1.0 {
            return Err(format!(
                "faults.replan_threshold {} below 1.0 (max/mean imbalance)",
                self.replan_threshold
            ));
        }
        Ok(())
    }
}

/// Crash-resilience knobs (`[recovery]` in config files; consumed by
/// `sim::checkpoint`).  The default config checkpoints nothing and
/// injects nothing, so plain scenarios pay zero overhead.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RecoveryConfig {
    /// Snapshot every this-many slots (0 = checkpointing off).
    pub checkpoint_epoch: usize,
    /// Per-slot probability of an injected worker panic (one shard of
    /// the slot's commit scatter panics at task entry, is retried).
    pub panic_rate: f64,
    /// Per-slot probability of an injected worker stall (sleeps past
    /// the watchdog deadline, then panics and is retried).
    pub stall_rate: f64,
    /// Per-slot probability that a process kill is scheduled at the
    /// slot boundary (the resilient driver discards live state and
    /// restores from the last durable checkpoint).
    pub kill_rate: f64,
    /// Per-checkpoint probability that the write fails (the snapshot is
    /// dropped; recovery then reaches further back).
    pub ckpt_fail_rate: f64,
    /// Injected stall duration in milliseconds.
    pub stall_ms: u64,
    /// Checkpoint-chain retention depth (§SStore): the blob store keeps
    /// the newest this-many blobs, plus the epoch-0 genesis blob and
    /// the newest *verifying* blob, so fallback thaw always has a valid
    /// floor to land on.  Must be ≥ 1; 1 reproduces the single-blob
    /// semantics of the pre-chain driver.
    pub chain_depth: usize,
    /// Per-slot probability that a checkpoint write at that slot is
    /// torn: only a seeded prefix of the blob's bytes is persisted.
    pub torn_write_rate: f64,
    /// Per-slot probability that one seeded bit of the persisted blob
    /// is flipped.
    pub bit_flip_rate: f64,
    /// Per-slot probability that the blob's atomic rename is lost: the
    /// temp file is written and synced but never enters the chain.
    pub lost_rename_rate: f64,
    /// Seed of the execution-fault stream (independent of both the
    /// workload seed and the topology-fault seed).
    pub seed: u64,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            checkpoint_epoch: 0,
            panic_rate: 0.0,
            stall_rate: 0.0,
            kill_rate: 0.0,
            ckpt_fail_rate: 0.0,
            stall_ms: 20,
            chain_depth: 1,
            torn_write_rate: 0.0,
            bit_flip_rate: 0.0,
            lost_rename_rate: 0.0,
            seed: 101,
        }
    }
}

impl RecoveryConfig {
    /// Does this config do anything (checkpoint or inject)?
    pub fn enabled(&self) -> bool {
        self.checkpoint_epoch > 0
            || self.panic_rate > 0.0
            || self.stall_rate > 0.0
            || self.kill_rate > 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("recovery.panic_rate", self.panic_rate),
            ("recovery.stall_rate", self.stall_rate),
            ("recovery.kill_rate", self.kill_rate),
            ("recovery.ckpt_fail_rate", self.ckpt_fail_rate),
            ("recovery.torn_write_rate", self.torn_write_rate),
            ("recovery.bit_flip_rate", self.bit_flip_rate),
            ("recovery.lost_rename_rate", self.lost_rename_rate),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("{name} {v} outside [0,1]"));
            }
        }
        if self.chain_depth == 0 {
            return Err("recovery.chain_depth must be > 0".into());
        }
        // kill_rate with checkpoint_epoch == 0 is legal: the driver
        // always holds the implicit slot-0 snapshot, so a kill replays
        // from the start — slow, but still bitwise.
        Ok(())
    }
}

/// Streaming-ingest knobs (`[ingest]` in config files; consumed by
/// `sim::ingest::StreamArrivals` and the `serve` CLI driver).  Off by
/// default: plain scenarios keep their Bernoulli arrivals.  The numeric
/// defaults mirror `sim::ingest::StreamParams::default` (pinned by a
/// test there — config stays a leaf layer, so the values are repeated
/// rather than imported).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IngestConfig {
    /// Route arrivals through the ingest queue + batcher.
    pub enabled: bool,
    /// Lane capacity (events).
    pub capacity: usize,
    /// Events per formed slot batch.
    pub batch_events: usize,
    /// Events generated ahead per refill round.
    pub burst: usize,
    /// External producers block (spin) at capacity instead of dropping
    /// newest (the `--backpressure` CLI knob).
    pub backpressure: bool,
    /// Per-port arrival-rate EWMA smoothing factor α ∈ [0, 1].
    pub ewma_alpha: f64,
    /// Batches per EWMA epoch.
    pub ewma_epoch: usize,
}

impl Default for IngestConfig {
    fn default() -> Self {
        IngestConfig {
            enabled: false,
            capacity: 1024,
            batch_events: 32,
            burst: 48,
            backpressure: true,
            ewma_alpha: 0.2,
            ewma_epoch: 16,
        }
    }
}

impl IngestConfig {
    /// Does this config route arrivals through the ingest queue?
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.capacity == 0 {
            return Err("ingest.capacity must be > 0".into());
        }
        if self.batch_events == 0 {
            return Err("ingest.batch_events must be > 0".into());
        }
        if self.burst == 0 {
            return Err("ingest.burst must be > 0".into());
        }
        if !(0.0..=1.0).contains(&self.ewma_alpha) {
            return Err(format!("ingest.ewma_alpha {} outside [0,1]", self.ewma_alpha));
        }
        if self.ewma_epoch == 0 {
            return Err("ingest.ewma_epoch must be > 0".into());
        }
        Ok(())
    }
}

/// Observability knobs (`[obs]` in config files; consumed by the CLI
/// drivers, which call `obs::set_level` before a run).  Off by default:
/// spans cost one relaxed-atomic branch and nothing is exported.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ObsConfig {
    /// `off` | `summary` | `trace` (see `obs::ObsLevel`).
    pub level: ObsLevel,
}

impl ObsConfig {
    /// Does this config record anything?
    pub fn enabled(&self) -> bool {
        self.level != ObsLevel::Off
    }
}

/// All knobs of one simulated experiment (defaults = paper Tab. 2).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    /// |L| — job types.
    pub num_ports: usize,
    /// |R| — computing instances.
    pub num_instances: usize,
    /// K — resource types.
    pub num_resources: usize,
    /// T — time horizon.
    pub horizon: usize,
    /// ρ — Bernoulli job-arrival probability per port per slot.
    pub arrival_prob: f64,
    /// Contention level: multiplier on job resource requirements.
    pub contention: f64,
    /// α sampled uniformly from this range per (r, k).
    pub alpha_range: (f64, f64),
    /// β sampled uniformly from this range per k.
    pub beta_range: (f64, f64),
    /// η₀ — initial learning rate.
    pub eta0: f64,
    /// λ — multiplicative learning-rate decay per slot.
    pub decay: f64,
    pub graph: GraphSpec,
    pub utility_mix: UtilityMix,
    pub seed: u64,
    /// Execution budget (`[parallel]` in config files): `runs`
    /// concurrent lineup lanes x `shards` workers per run, 0 = auto
    /// (derived from `PALLAS_WORKERS` / available parallelism by
    /// `ExecBudget::resolve`).
    pub parallel: ExecBudget,
    /// Fault-injection severity (`[faults]`; off by default).
    pub faults: FaultConfig,
    /// Crash-resilience knobs (`[recovery]`; off by default).
    pub recovery: RecoveryConfig,
    /// On-disk checkpoint store directory (`recovery.store_dir`); when
    /// unset the resilient driver keeps its blob chain in memory.  A
    /// sibling of `recovery` rather than a member so `RecoveryConfig`
    /// stays `Copy` for the struct-update construction idiom.
    pub store_dir: Option<String>,
    /// Observability level (`[obs]`; off by default).
    pub obs: ObsConfig,
    /// Streaming-ingest knobs (`[ingest]`; off by default).
    pub ingest: IngestConfig,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            name: "default".into(),
            num_ports: 10,
            num_instances: 128,
            num_resources: 6,
            horizon: 2000,
            arrival_prob: 0.7,
            contention: 10.0,
            alpha_range: (1.0, 1.5),
            beta_range: (0.3, 0.5),
            // Tab. 2 lists eta0 = 25 for the authors' raw trace units; our
            // device capacities are normalized (see traces::alibaba), which
            // shrinks gradient magnitudes — eta0 = 2 sits at the optimum of
            // the Fig. 4 sweep on this scaling (EXPERIMENTS.md §Fig4).
            eta0: 2.0,
            decay: 0.9999,
            graph: GraphSpec::Density(3.0),
            utility_mix: UtilityMix::Mixed,
            seed: 2023,
            parallel: ExecBudget::auto(),
            faults: FaultConfig::default(),
            recovery: RecoveryConfig::default(),
            store_dir: None,
            obs: ObsConfig::default(),
            ingest: IngestConfig::default(),
        }
    }
}

impl Scenario {
    /// The Sec. 4.3 large-scale validation setting (Fig. 5).
    ///
    /// The paper lists beta in [0.01, 0.015] for its raw trace units,
    /// where per-job quotas are ~30x larger than under our normalized
    /// allocation units (see traces::alibaba); what matters in Eq. 7 is
    /// the product beta_k * quota_k, so the unit-consistent penalty
    /// keeps the Tab. 2 default beta range here.  With the raw tiny
    /// beta the problem degenerates to penalty-free greedy saturation
    /// and every policy ties (measured in EXPERIMENTS.md §Fig5).
    pub fn large_scale() -> Self {
        Scenario {
            name: "large-scale".into(),
            num_ports: 100,
            num_instances: 1024,
            horizon: 10_000,
            contention: 5.0,
            ..Scenario::default()
        }
    }

    /// A small scenario for quickstart/tests/CI.
    pub fn small() -> Self {
        Scenario {
            name: "small".into(),
            num_ports: 4,
            num_instances: 16,
            num_resources: 4,
            horizon: 200,
            contention: 2.0,
            ..Scenario::default()
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.num_ports == 0 || self.num_instances == 0 || self.num_resources == 0 {
            return Err("ports/instances/resources must be > 0".into());
        }
        if self.horizon == 0 {
            return Err("horizon must be > 0".into());
        }
        if !(0.0..=1.0).contains(&self.arrival_prob) {
            return Err(format!("arrival_prob {} outside [0,1]", self.arrival_prob));
        }
        if self.contention <= 0.0 {
            return Err("contention must be > 0".into());
        }
        if self.alpha_range.0 > self.alpha_range.1 || self.alpha_range.0 <= 0.0 {
            return Err(format!("bad alpha_range {:?}", self.alpha_range));
        }
        if self.beta_range.0 > self.beta_range.1
            || self.beta_range.0 < 0.0
            || self.beta_range.1 > 1.0
        {
            return Err(format!("bad beta_range {:?} (β ∈ [0,1])", self.beta_range));
        }
        if self.eta0 <= 0.0 || self.decay <= 0.0 {
            return Err("eta0 and decay must be > 0".into());
        }
        if let GraphSpec::Density(d) = self.graph {
            if d < 0.0 || d > self.num_ports as f64 {
                return Err(format!("density {d} outside [0, |L|]"));
            }
        }
        if let GraphSpec::RightRegular(d) = self.graph {
            if d == 0 || d > self.num_ports {
                return Err(format!("regular degree {d} outside [1, |L|]"));
            }
        }
        self.faults.validate()?;
        self.recovery.validate()?;
        self.ingest.validate()?;
        Ok(())
    }

    /// Parse from a TOML-subset document.  Unknown keys are rejected so
    /// config typos fail loudly.
    pub fn from_doc(doc: &Doc) -> Result<Scenario, String> {
        const KNOWN: &[&str] = &[
            "name", "ports", "instances", "resources", "horizon", "arrival_prob",
            "contention", "alpha_range", "beta_range", "eta0", "decay", "graph",
            "graph_degree", "graph_density", "utility_mix", "seed", "workers",
            "parallel.runs", "parallel.shards",
            "faults.instance_rate", "faults.recover_rate", "faults.port_rate",
            "faults.rack_rate", "faults.rack_size", "faults.release",
            "faults.replan_threshold", "faults.seed",
            "recovery.checkpoint_epoch", "recovery.panic_rate",
            "recovery.stall_rate", "recovery.kill_rate",
            "recovery.ckpt_fail_rate", "recovery.stall_ms",
            "recovery.chain_depth", "recovery.store_dir",
            "recovery.torn_write_rate", "recovery.bit_flip_rate",
            "recovery.lost_rename_rate", "recovery.seed",
            "obs.level",
            "ingest.enabled", "ingest.capacity", "ingest.batch_events",
            "ingest.burst", "ingest.backpressure", "ingest.ewma_alpha",
            "ingest.ewma_epoch",
        ];
        for key in doc.entries.keys() {
            if !KNOWN.contains(&key.as_str()) {
                return Err(format!("unknown config key `{key}`"));
            }
        }
        let d = Scenario::default();
        let range = |key: &str, dv: (f64, f64)| -> Result<(f64, f64), String> {
            match doc.get(key) {
                None => Ok(dv),
                Some(_) => {
                    let v = doc.f64_array(key)?;
                    if v.len() != 2 {
                        return Err(format!("{key}: expected [lo, hi]"));
                    }
                    Ok((v[0], v[1]))
                }
            }
        };
        let graph = match doc.str_or("graph", "density")? {
            "full" => GraphSpec::Full,
            "regular" => GraphSpec::RightRegular(doc.usize_or("graph_degree", 3)?),
            "density" => GraphSpec::Density(doc.f64_or("graph_density", 3.0)?),
            other => return Err(format!("graph: unknown kind `{other}`")),
        };
        let mix_name = doc.str_or("utility_mix", "mixed")?;
        let utility_mix = UtilityMix::from_name(mix_name)
            .ok_or_else(|| format!("utility_mix: unknown `{mix_name}`"))?;
        let df = d.faults;
        let faults = FaultConfig {
            instance_rate: doc.f64_or("faults.instance_rate", df.instance_rate)?,
            recover_rate: doc.f64_or("faults.recover_rate", df.recover_rate)?,
            port_rate: doc.f64_or("faults.port_rate", df.port_rate)?,
            rack_rate: doc.f64_or("faults.rack_rate", df.rack_rate)?,
            rack_size: doc.usize_or("faults.rack_size", df.rack_size)?,
            release: match doc.str_or("faults.release", "drain")? {
                "drain" => ReleaseMode::Drain,
                "release" => ReleaseMode::Release,
                other => {
                    return Err(format!(
                        "faults.release: unknown mode `{other}` (drain|release)"
                    ))
                }
            },
            replan_threshold: doc.f64_or("faults.replan_threshold", df.replan_threshold)?,
            seed: doc.usize_or("faults.seed", df.seed as usize)? as u64,
        };
        let dr = d.recovery;
        let recovery = RecoveryConfig {
            checkpoint_epoch: doc.usize_or("recovery.checkpoint_epoch", dr.checkpoint_epoch)?,
            panic_rate: doc.f64_or("recovery.panic_rate", dr.panic_rate)?,
            stall_rate: doc.f64_or("recovery.stall_rate", dr.stall_rate)?,
            kill_rate: doc.f64_or("recovery.kill_rate", dr.kill_rate)?,
            ckpt_fail_rate: doc.f64_or("recovery.ckpt_fail_rate", dr.ckpt_fail_rate)?,
            stall_ms: doc.usize_or("recovery.stall_ms", dr.stall_ms as usize)? as u64,
            chain_depth: doc.usize_or("recovery.chain_depth", dr.chain_depth)?,
            torn_write_rate: doc.f64_or("recovery.torn_write_rate", dr.torn_write_rate)?,
            bit_flip_rate: doc.f64_or("recovery.bit_flip_rate", dr.bit_flip_rate)?,
            lost_rename_rate: doc.f64_or("recovery.lost_rename_rate", dr.lost_rename_rate)?,
            seed: doc.usize_or("recovery.seed", dr.seed as usize)? as u64,
        };
        let store_dir = match doc.get("recovery.store_dir") {
            None => None,
            Some(_) => Some(doc.str_or("recovery.store_dir", "")?.to_string()),
        };
        let obs = ObsConfig {
            level: ObsLevel::parse(doc.str_or("obs.level", d.obs.level.name())?)
                .map_err(|e| format!("obs.level: {e}"))?,
        };
        let di = d.ingest;
        let ingest = IngestConfig {
            enabled: doc.bool_or("ingest.enabled", di.enabled)?,
            capacity: doc.usize_or("ingest.capacity", di.capacity)?,
            batch_events: doc.usize_or("ingest.batch_events", di.batch_events)?,
            burst: doc.usize_or("ingest.burst", di.burst)?,
            backpressure: doc.bool_or("ingest.backpressure", di.backpressure)?,
            ewma_alpha: doc.f64_or("ingest.ewma_alpha", di.ewma_alpha)?,
            ewma_epoch: doc.usize_or("ingest.ewma_epoch", di.ewma_epoch)?,
        };
        let s = Scenario {
            name: doc.str_or("name", &d.name)?.to_string(),
            num_ports: doc.usize_or("ports", d.num_ports)?,
            num_instances: doc.usize_or("instances", d.num_instances)?,
            num_resources: doc.usize_or("resources", d.num_resources)?,
            horizon: doc.usize_or("horizon", d.horizon)?,
            arrival_prob: doc.f64_or("arrival_prob", d.arrival_prob)?,
            contention: doc.f64_or("contention", d.contention)?,
            alpha_range: range("alpha_range", d.alpha_range)?,
            beta_range: range("beta_range", d.beta_range)?,
            eta0: doc.f64_or("eta0", d.eta0)?,
            decay: doc.f64_or("decay", d.decay)?,
            graph,
            utility_mix,
            seed: doc.usize_or("seed", d.seed as usize)? as u64,
            // legacy flat `workers` = per-run shard budget; the
            // `[parallel]` section wins when present
            parallel: ExecBudget {
                runs: doc.usize_or("parallel.runs", d.parallel.runs)?,
                shards: doc.usize_or(
                    "parallel.shards",
                    doc.usize_or("workers", d.parallel.shards)?,
                )?,
            },
            faults,
            recovery,
            store_dir,
            obs,
            ingest,
        };
        s.validate()?;
        Ok(s)
    }

    pub fn from_toml(text: &str) -> Result<Scenario, String> {
        Scenario::from_doc(&Doc::parse(text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_tab2() {
        let s = Scenario::default();
        assert_eq!(s.num_ports, 10);
        assert_eq!(s.num_instances, 128);
        assert_eq!(s.num_resources, 6);
        assert_eq!(s.horizon, 2000);
        assert_eq!(s.arrival_prob, 0.7);
        assert_eq!(s.contention, 10.0);
        assert_eq!(s.alpha_range, (1.0, 1.5));
        assert_eq!(s.beta_range, (0.3, 0.5));
        assert_eq!(s.eta0, 2.0);
        assert_eq!(s.decay, 0.9999);
        s.validate().unwrap();
        Scenario::large_scale().validate().unwrap();
        Scenario::small().validate().unwrap();
    }

    #[test]
    fn toml_roundtrip_overrides() {
        let s = Scenario::from_toml(
            "name = \"exp\"\nports = 5\nhorizon = 100\narrival_prob = 0.5\n\
             alpha_range = [1.0, 2.0]\ngraph = \"regular\"\ngraph_degree = 2\n\
             utility_mix = \"all-log\"\n",
        )
        .unwrap();
        assert_eq!(s.name, "exp");
        assert_eq!(s.num_ports, 5);
        assert_eq!(s.horizon, 100);
        assert_eq!(s.alpha_range, (1.0, 2.0));
        assert_eq!(s.graph, GraphSpec::RightRegular(2));
        assert_eq!(s.utility_mix.name(), "all-log");
        // unspecified keys keep Tab. 2 defaults
        assert_eq!(s.num_instances, 128);
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(Scenario::from_toml("portz = 5\n").unwrap_err().contains("unknown"));
        assert!(Scenario::from_toml("[parallel]\nrunz = 2\n")
            .unwrap_err()
            .contains("unknown"));
    }

    #[test]
    fn parallel_section_parses_and_defaults_auto() {
        // default: fully automatic budget
        assert_eq!(Scenario::default().parallel, ExecBudget::auto());
        // explicit [parallel] split
        let s = Scenario::from_toml("[parallel]\nruns = 2\nshards = 4\n").unwrap();
        assert_eq!(s.parallel, ExecBudget::split(2, 4));
        // legacy flat `workers` maps to the per-run shard budget ...
        let s = Scenario::from_toml("workers = 3\n").unwrap();
        assert_eq!(s.parallel, ExecBudget { runs: 0, shards: 3 });
        // ... and the [parallel] section wins when both are present
        let s = Scenario::from_toml("workers = 3\n[parallel]\nshards = 5\n").unwrap();
        assert_eq!(s.parallel.shards, 5);
    }

    #[test]
    fn faults_section_parses_and_defaults_off() {
        let s = Scenario::default();
        assert!(!s.faults.enabled());
        let s = Scenario::from_toml(
            "[faults]\ninstance_rate = 0.02\nrack_rate = 0.005\nrack_size = 3\n\
             release = \"release\"\nreplan_threshold = 1.2\nseed = 9\n",
        )
        .unwrap();
        assert!(s.faults.enabled());
        assert_eq!(s.faults.instance_rate, 0.02);
        assert_eq!(s.faults.rack_size, 3);
        assert_eq!(s.faults.release, ReleaseMode::Release);
        assert_eq!(s.faults.replan_threshold, 1.2);
        assert_eq!(s.faults.seed, 9);
        // unspecified fault knobs keep their defaults
        assert_eq!(s.faults.recover_rate, FaultConfig::default().recover_rate);
        // bad values fail loudly
        assert!(Scenario::from_toml("[faults]\ninstance_rate = 1.5\n").is_err());
        assert!(Scenario::from_toml("[faults]\nrelease = \"maybe\"\n").is_err());
        assert!(Scenario::from_toml("[faults]\nreplan_threshold = 0.5\n").is_err());
        assert!(Scenario::from_toml("[faults]\nrack_size = 0\n").is_err());
    }

    #[test]
    fn recovery_section_parses_and_defaults_off() {
        let s = Scenario::default();
        assert!(!s.recovery.enabled());
        let s = Scenario::from_toml(
            "[recovery]\ncheckpoint_epoch = 5\npanic_rate = 0.02\nkill_rate = 0.01\n\
             ckpt_fail_rate = 0.1\nstall_ms = 15\nseed = 4\n",
        )
        .unwrap();
        assert!(s.recovery.enabled());
        assert_eq!(s.recovery.checkpoint_epoch, 5);
        assert_eq!(s.recovery.panic_rate, 0.02);
        assert_eq!(s.recovery.kill_rate, 0.01);
        assert_eq!(s.recovery.ckpt_fail_rate, 0.1);
        assert_eq!(s.recovery.stall_ms, 15);
        assert_eq!(s.recovery.seed, 4);
        assert_eq!(s.recovery.stall_rate, RecoveryConfig::default().stall_rate);
        // §SStore knobs default off / in-memory
        assert_eq!(s.recovery.chain_depth, 1);
        assert_eq!(s.recovery.torn_write_rate, 0.0);
        assert_eq!(s.recovery.bit_flip_rate, 0.0);
        assert_eq!(s.recovery.lost_rename_rate, 0.0);
        assert_eq!(s.store_dir, None);
        assert!(Scenario::from_toml("[recovery]\npanic_rate = 2.0\n").is_err());
        assert!(Scenario::from_toml("[recovery]\nepoch = 5\n").is_err());
    }

    #[test]
    fn recovery_storage_knobs_parse_and_validate() {
        let s = Scenario::from_toml(
            "[recovery]\ncheckpoint_epoch = 5\nchain_depth = 3\n\
             store_dir = \"/tmp/ogasched-ckpts\"\ntorn_write_rate = 0.1\n\
             bit_flip_rate = 0.05\nlost_rename_rate = 0.02\n",
        )
        .unwrap();
        assert_eq!(s.recovery.chain_depth, 3);
        assert_eq!(s.recovery.torn_write_rate, 0.1);
        assert_eq!(s.recovery.bit_flip_rate, 0.05);
        assert_eq!(s.recovery.lost_rename_rate, 0.02);
        assert_eq!(s.store_dir.as_deref(), Some("/tmp/ogasched-ckpts"));
        // bad values fail loudly
        assert!(Scenario::from_toml("[recovery]\nchain_depth = 0\n").is_err());
        assert!(Scenario::from_toml("[recovery]\ntorn_write_rate = 1.5\n").is_err());
        assert!(Scenario::from_toml("[recovery]\nbit_flip_rate = -0.1\n").is_err());
        assert!(Scenario::from_toml("[recovery]\nlost_rename_rate = 2.0\n").is_err());
    }

    #[test]
    fn obs_section_parses_and_defaults_off() {
        let s = Scenario::default();
        assert!(!s.obs.enabled());
        assert_eq!(s.obs.level, ObsLevel::Off);
        let s = Scenario::from_toml("[obs]\nlevel = \"summary\"\n").unwrap();
        assert!(s.obs.enabled());
        assert_eq!(s.obs.level, ObsLevel::Summary);
        let s = Scenario::from_toml("[obs]\nlevel = \"trace\"\n").unwrap();
        assert_eq!(s.obs.level, ObsLevel::Trace);
        // unknown levels and keys fail loudly
        assert!(Scenario::from_toml("[obs]\nlevel = \"verbose\"\n").is_err());
        assert!(Scenario::from_toml("[obs]\nring = 64\n").is_err());
    }

    #[test]
    fn ingest_section_parses_and_defaults_off() {
        let s = Scenario::default();
        assert!(!s.ingest.enabled());
        let s = Scenario::from_toml(
            "[ingest]\nenabled = true\ncapacity = 256\nbatch_events = 16\n\
             burst = 24\nbackpressure = false\newma_alpha = 0.5\newma_epoch = 8\n",
        )
        .unwrap();
        assert!(s.ingest.enabled());
        assert_eq!(s.ingest.capacity, 256);
        assert_eq!(s.ingest.batch_events, 16);
        assert_eq!(s.ingest.burst, 24);
        assert!(!s.ingest.backpressure);
        assert_eq!(s.ingest.ewma_alpha, 0.5);
        assert_eq!(s.ingest.ewma_epoch, 8);
        // unspecified ingest knobs keep their defaults
        let s = Scenario::from_toml("[ingest]\nenabled = true\n").unwrap();
        assert_eq!(s.ingest.capacity, IngestConfig::default().capacity);
        // bad values fail loudly
        assert!(Scenario::from_toml("[ingest]\ncapacity = 0\n").is_err());
        assert!(Scenario::from_toml("[ingest]\nbatch_events = 0\n").is_err());
        assert!(Scenario::from_toml("[ingest]\newma_alpha = 1.5\n").is_err());
        assert!(Scenario::from_toml("[ingest]\nqueue = 64\n").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(Scenario::from_toml("arrival_prob = 1.5\n").is_err());
        assert!(Scenario::from_toml("beta_range = [0.5, 2.0]\n").is_err());
        assert!(Scenario::from_toml("graph = \"hexagon\"\n").is_err());
        assert!(Scenario::from_toml("utility_mix = \"all-cubic\"\n").is_err());
        let mut s = Scenario::default();
        s.horizon = 0;
        assert!(s.validate().is_err());
    }
}
