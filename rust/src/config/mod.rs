//! Configuration system: a TOML-subset parser (`value`) and the typed
//! experiment schema (`scenario`).

pub mod scenario;
pub mod value;

pub use scenario::{
    FaultConfig, GraphSpec, IngestConfig, ObsConfig, RecoveryConfig, Scenario,
};
pub use value::{Doc, Value};
