//! A TOML-subset parser (the `toml`/`serde` crates are unavailable
//! offline).  Supported grammar — everything the scenario files need:
//!
//! ```toml
//! # comment
//! key = 1.5            # float / int
//! name = "string"      # basic strings with \" escapes
//! flag = true          # bool
//! xs = [1, 2, 3]       # homogeneous arrays (numbers or strings)
//!
//! [section]            # tables, one level deep
//! key = 2
//! [section.sub]        # dotted headers flatten to "section.sub"
//! ```
//!
//! Values are kept dynamically typed (`Value`), with typed accessors on
//! `Doc` that produce precise error messages (`section.key: expected
//! float, got string`).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Float(f64),
    Int(i64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Float(v) => write!(f, "{v}"),
            Value::Int(v) => write!(f, "{v}"),
            Value::Bool(v) => write!(f, "{v}"),
            Value::Str(v) => write!(f, "\"{v}\""),
            Value::Array(vs) => {
                write!(f, "[")?;
                for (i, v) in vs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Float(_) => "float",
            Value::Int(_) => "int",
            Value::Bool(_) => "bool",
            Value::Str(_) => "string",
            Value::Array(_) => "array",
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: flattened `section.key -> Value` map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(header) = line.strip_prefix('[') {
                let header = header
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?
                    .trim();
                if header.is_empty() {
                    return Err(err(lineno, "empty section header"));
                }
                section = header.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| err(lineno, &e))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if entries.insert(full.clone(), value).is_some() {
                return Err(err(lineno, &format!("duplicate key `{full}`")));
            }
        }
        Ok(Doc { entries })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn f64(&self, key: &str) -> Result<f64, String> {
        self.typed(key, "float", Value::as_f64)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(_) => self.f64(key),
        }
    }

    pub fn usize(&self, key: &str) -> Result<usize, String> {
        self.typed(key, "int", Value::as_usize)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(_) => self.usize(key),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_bool()
                .ok_or_else(|| format!("{key}: expected bool, got {}", v.type_name())),
        }
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> Result<&'a str, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_str()
                .ok_or_else(|| format!("{key}: expected string, got {}", v.type_name())),
        }
    }

    pub fn f64_array(&self, key: &str) -> Result<Vec<f64>, String> {
        match self.get(key) {
            None => Err(format!("{key}: missing")),
            Some(Value::Array(vs)) => vs
                .iter()
                .map(|v| {
                    v.as_f64()
                        .ok_or_else(|| format!("{key}: non-numeric array element {v}"))
                })
                .collect(),
            Some(v) => Err(format!("{key}: expected array, got {}", v.type_name())),
        }
    }

    fn typed<T>(
        &self,
        key: &str,
        want: &str,
        f: impl Fn(&Value) -> Option<T>,
    ) -> Result<T, String> {
        match self.get(key) {
            None => Err(format!("{key}: missing")),
            Some(v) => {
                f(v).ok_or_else(|| format!("{key}: expected {want}, got {}", v.type_name()))
            }
        }
    }

    /// Keys under a section prefix (e.g. all `jobs.*`).
    pub fn section_keys(&self, prefix: &str) -> Vec<&str> {
        let pre = format!("{prefix}.");
        self.entries
            .keys()
            .filter(|k| k.starts_with(&pre))
            .map(|k| k.as_str())
            .collect()
    }
}

fn err(lineno: usize, msg: &str) -> String {
    format!("line {}: {msg}", lineno + 1)
}

/// Strip a `#` comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if body.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items: Result<Vec<Value>, String> =
            split_top_level(body).into_iter().map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(v) = s.parse::<i64>() {
            return Ok(Value::Int(v));
        }
    }
    s.parse::<f64>()
        .map(Value::Float)
        .map_err(|_| format!("cannot parse value `{s}`"))
}

/// Split an array body on commas not inside strings/nested brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, ch) in s.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = Doc::parse(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\n[s]\ne = 3\n[s.t]\nf = [1, 2.5]\n",
        )
        .unwrap();
        assert_eq!(doc.get("a"), Some(&Value::Int(1)));
        assert_eq!(doc.f64("b").unwrap(), 2.5);
        assert_eq!(doc.str_or("c", "").unwrap(), "hi");
        assert!(doc.bool_or("d", false).unwrap());
        assert_eq!(doc.usize("s.e").unwrap(), 3);
        assert_eq!(doc.f64_array("s.t.f").unwrap(), vec![1.0, 2.5]);
    }

    #[test]
    fn comments_stripped_outside_strings() {
        let doc = Doc::parse("a = 1 # trailing\ns = \"x # y\"\n").unwrap();
        assert_eq!(doc.usize("a").unwrap(), 1);
        assert_eq!(doc.str_or("s", "").unwrap(), "x # y");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Doc::parse("ok = 1\nbroken\n").unwrap_err();
        assert!(e.contains("line 2"), "{e}");
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(Doc::parse("a = 1\na = 2\n").unwrap_err().contains("duplicate"));
    }

    #[test]
    fn typed_access_errors_are_precise() {
        let doc = Doc::parse("a = \"str\"\n").unwrap();
        let e = doc.f64("a").unwrap_err();
        assert!(e.contains("expected float, got string"), "{e}");
        assert!(doc.f64("missing").unwrap_err().contains("missing"));
    }

    #[test]
    fn defaults_apply_only_when_absent() {
        let doc = Doc::parse("a = 2\n").unwrap();
        assert_eq!(doc.usize_or("a", 7).unwrap(), 2);
        assert_eq!(doc.usize_or("b", 7).unwrap(), 7);
        // present but wrong type is still an error
        let doc = Doc::parse("a = \"x\"\n").unwrap();
        assert!(doc.usize_or("a", 7).is_err());
    }

    #[test]
    fn nested_arrays_and_strings_with_commas() {
        let doc = Doc::parse("a = [\"x,y\", \"z\"]\n").unwrap();
        match doc.get("a").unwrap() {
            Value::Array(vs) => {
                assert_eq!(vs[0], Value::Str("x,y".into()));
                assert_eq!(vs.len(), 2);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn section_keys_enumerates() {
        let doc = Doc::parse("[jobs]\na = 1\nb = 2\n[other]\nc = 3\n").unwrap();
        let keys = doc.section_keys("jobs");
        assert_eq!(keys, vec!["jobs.a", "jobs.b"]);
    }
}
