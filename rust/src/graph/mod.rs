//! The bipartite service-locality graph `G = (L, R, E)` of Sec. 2.1.
//!
//! Left vertices are job types ("ports"), right vertices are computing
//! instances; an edge (l, r) — a "channel" — means instance `r` satisfies
//! type-l's locality/affinity constraints and may serve it.

use crate::utils::rng::Rng;

/// Compressed bipartite graph with both adjacency directions and a dense
/// edge mask for the vectorized kernels.
#[derive(Clone, Debug)]
pub struct Bipartite {
    /// |L| — number of ports (job types).
    pub num_ports: usize,
    /// |R| — number of computing instances.
    pub num_instances: usize,
    /// R_l: instances adjacent to port l (sorted).
    pub ports_to_instances: Vec<Vec<usize>>,
    /// L_r: ports adjacent to instance r (sorted).
    pub instances_to_ports: Vec<Vec<usize>>,
    /// Dense row-major mask [L * R]: 1.0 iff (l, r) ∈ E.
    pub mask: Vec<f32>,
}

impl Bipartite {
    /// Build from an explicit edge list.
    pub fn from_edges(num_ports: usize, num_instances: usize, edges: &[(usize, usize)]) -> Self {
        let mut ports_to_instances = vec![Vec::new(); num_ports];
        let mut instances_to_ports = vec![Vec::new(); num_instances];
        let mut mask = vec![0.0f32; num_ports * num_instances];
        for &(l, r) in edges {
            assert!(l < num_ports && r < num_instances, "edge ({l},{r}) out of range");
            if mask[l * num_instances + r] == 0.0 {
                mask[l * num_instances + r] = 1.0;
                ports_to_instances[l].push(r);
                instances_to_ports[r].push(l);
            }
        }
        for v in &mut ports_to_instances {
            v.sort_unstable();
        }
        for v in &mut instances_to_ports {
            v.sort_unstable();
        }
        Bipartite { num_ports, num_instances, ports_to_instances, instances_to_ports, mask }
    }

    /// Complete bipartite graph (no locality constraints).
    pub fn full(num_ports: usize, num_instances: usize) -> Self {
        let edges: Vec<(usize, usize)> = (0..num_ports)
            .flat_map(|l| (0..num_instances).map(move |r| (l, r)))
            .collect();
        Self::from_edges(num_ports, num_instances, &edges)
    }

    /// Right d-regular graph: every instance serves exactly `d` ports
    /// (the structure the paper's proofs specialize to).  Ports are
    /// assigned round-robin with a random rotation so that port degrees
    /// stay balanced.
    pub fn right_regular(num_ports: usize, num_instances: usize, d: usize, rng: &mut Rng) -> Self {
        let d = d.min(num_ports);
        let mut edges = Vec::with_capacity(num_instances * d);
        for r in 0..num_instances {
            let start = rng.below(num_ports);
            for j in 0..d {
                edges.push(((start + j) % num_ports, r));
            }
        }
        Self::from_edges(num_ports, num_instances, &edges)
    }

    /// Random graph targeting an average instance indegree of
    /// `density` = Σ_r |L_r| / |R|  (the "graph dense" of Tab. 3).
    /// Every instance keeps ≥1 port and every port keeps ≥1 instance so
    /// no vertex is stranded.
    pub fn random_density(
        num_ports: usize,
        num_instances: usize,
        density: f64,
        rng: &mut Rng,
    ) -> Self {
        let p = (density / num_ports as f64).clamp(0.0, 1.0);
        let mut edges = Vec::new();
        for r in 0..num_instances {
            let mut any = false;
            for l in 0..num_ports {
                if rng.bernoulli(p) {
                    edges.push((l, r));
                    any = true;
                }
            }
            if !any {
                edges.push((rng.below(num_ports), r));
            }
        }
        // make sure no port is isolated
        let mut port_deg = vec![0usize; num_ports];
        for &(l, _) in &edges {
            port_deg[l] += 1;
        }
        for (l, &deg) in port_deg.iter().enumerate() {
            if deg == 0 {
                edges.push((l, rng.below(num_instances)));
            }
        }
        Self::from_edges(num_ports, num_instances, &edges)
    }

    #[inline]
    pub fn has_edge(&self, l: usize, r: usize) -> bool {
        self.mask[l * self.num_instances + r] != 0.0
    }

    pub fn num_edges(&self) -> usize {
        self.ports_to_instances.iter().map(Vec::len).sum()
    }

    /// Σ_r |L_r| / |R| — the "graph dense" metric of Tab. 3.
    pub fn density(&self) -> f64 {
        if self.num_instances == 0 {
            return 0.0;
        }
        self.num_edges() as f64 / self.num_instances as f64
    }

    /// Is every instance indegree exactly d?
    pub fn is_right_regular(&self, d: usize) -> bool {
        self.instances_to_ports.iter().all(|ls| ls.len() == d)
    }

    /// Internal-consistency check (used by tests and debug assertions):
    /// both adjacency directions and the mask describe the same edge set.
    pub fn validate(&self) -> Result<(), String> {
        let mut count = 0usize;
        for (l, rs) in self.ports_to_instances.iter().enumerate() {
            for &r in rs {
                if !self.has_edge(l, r) {
                    return Err(format!("mask missing edge ({l},{r})"));
                }
                if !self.instances_to_ports[r].contains(&l) {
                    return Err(format!("reverse adjacency missing ({l},{r})"));
                }
                count += 1;
            }
        }
        let mask_count = self.mask.iter().filter(|&&m| m != 0.0).count();
        if mask_count != count {
            return Err(format!("mask has {mask_count} edges, adjacency has {count}"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_graph_shape() {
        let g = Bipartite::full(3, 5);
        assert_eq!(g.num_edges(), 15);
        assert!((g.density() - 3.0).abs() < 1e-12);
        assert!(g.is_right_regular(3));
        g.validate().unwrap();
    }

    #[test]
    fn from_edges_dedups() {
        let g = Bipartite::from_edges(2, 2, &[(0, 1), (0, 1), (1, 0)]);
        assert_eq!(g.num_edges(), 2);
        g.validate().unwrap();
    }

    #[test]
    fn right_regular_has_exact_indegree() {
        let mut rng = Rng::new(1);
        let g = Bipartite::right_regular(10, 64, 4, &mut rng);
        assert!(g.is_right_regular(4));
        assert!((g.density() - 4.0).abs() < 1e-12);
        g.validate().unwrap();
    }

    #[test]
    fn random_density_hits_target_and_strands_nobody() {
        let mut rng = Rng::new(7);
        let g = Bipartite::random_density(10, 512, 3.0, &mut rng);
        assert!((g.density() - 3.0).abs() < 0.4, "density={}", g.density());
        assert!(g.ports_to_instances.iter().all(|v| !v.is_empty()));
        assert!(g.instances_to_ports.iter().all(|v| !v.is_empty()));
        g.validate().unwrap();
    }

    #[test]
    fn density_one_is_minimum_connectivity() {
        let mut rng = Rng::new(3);
        let g = Bipartite::random_density(5, 100, 0.0, &mut rng);
        // forced fallback edges keep each instance at exactly one port
        assert!(g.instances_to_ports.iter().all(|v| v.len() == 1));
        g.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_edge_panics() {
        Bipartite::from_edges(2, 2, &[(2, 0)]);
    }
}
