//! The bipartite service-locality graph `G = (L, R, E)` of Sec. 2.1.
//!
//! Left vertices are job types ("ports"), right vertices are computing
//! instances; an edge (l, r) — a "channel" — means instance `r` satisfies
//! type-l's locality/affinity constraints and may serve it.

use crate::utils::rng::Rng;

/// Compressed bipartite graph with both adjacency directions, a dense
/// edge mask for the vectorized kernels, and an **edge-major CSR index**
/// that the sparse decision layout is built on.
///
/// Every edge (l, r) ∈ E gets a stable id `e ∈ 0..|E|`, assigned in
/// port-major order (ascending l, then ascending r).  The decision
/// tensor stores `K` values per edge at `y[e*K .. (e+1)*K]`, so the
/// coordinates of one port are one contiguous slice and off-edge
/// coordinates simply do not exist.
#[derive(Clone, Debug)]
pub struct Bipartite {
    /// |L| — number of ports (job types).
    pub num_ports: usize,
    /// |R| — number of computing instances.
    pub num_instances: usize,
    /// R_l: instances adjacent to port l (sorted).
    pub ports_to_instances: Vec<Vec<usize>>,
    /// L_r: ports adjacent to instance r (sorted).
    pub instances_to_ports: Vec<Vec<usize>>,
    /// Dense row-major mask [L * R]: 1.0 iff (l, r) ∈ E.
    pub mask: Vec<f32>,
    /// Port-major CSR offsets: edges of port l are
    /// `port_ptr[l]..port_ptr[l+1]` (length |L| + 1).
    pub port_ptr: Vec<usize>,
    /// edge → instance (length |E|, port-major order).
    pub edge_instance: Vec<usize>,
    /// edge → port (length |E|).
    pub edge_port: Vec<usize>,
    /// Instance-major CSR offsets into `instance_edges` (length |R| + 1).
    pub instance_ptr: Vec<usize>,
    /// Edge ids grouped by instance, ascending port within an instance
    /// (length |E|).
    pub instance_edges: Vec<usize>,
}

impl Bipartite {
    /// Build from an explicit edge list.
    pub fn from_edges(num_ports: usize, num_instances: usize, edges: &[(usize, usize)]) -> Self {
        let mut ports_to_instances = vec![Vec::new(); num_ports];
        let mut instances_to_ports = vec![Vec::new(); num_instances];
        let mut mask = vec![0.0f32; num_ports * num_instances];
        for &(l, r) in edges {
            assert!(l < num_ports && r < num_instances, "edge ({l},{r}) out of range");
            if mask[l * num_instances + r] == 0.0 {
                mask[l * num_instances + r] = 1.0;
                ports_to_instances[l].push(r);
                instances_to_ports[r].push(l);
            }
        }
        for v in &mut ports_to_instances {
            v.sort_unstable();
        }
        for v in &mut instances_to_ports {
            v.sort_unstable();
        }

        let mut g = Bipartite {
            num_ports,
            num_instances,
            ports_to_instances,
            instances_to_ports,
            mask,
            port_ptr: Vec::new(),
            edge_instance: Vec::new(),
            edge_port: Vec::new(),
            instance_ptr: Vec::new(),
            instance_edges: Vec::new(),
        };
        g.rebuild_index();
        g
    }

    /// Rebuild the edge-major CSR index (port-major edge ids) from the
    /// adjacency lists.  The adjacency lists and the mask are the source
    /// of truth; every edge id shifts when the edge set changes, so any
    /// cached per-edge state (decisions, shard port CSRs) must be
    /// remapped by `(l, r)` key after a mutation.
    fn rebuild_index(&mut self) {
        self.port_ptr.clear();
        self.port_ptr.reserve(self.num_ports + 1);
        self.port_ptr.push(0);
        self.edge_instance.clear();
        self.edge_port.clear();
        for (l, rs) in self.ports_to_instances.iter().enumerate() {
            for &r in rs {
                self.edge_instance.push(r);
                self.edge_port.push(l);
            }
            self.port_ptr.push(self.edge_instance.len());
        }
        // counting sort of edge ids by instance; port-major iteration
        // keeps each instance's list ascending in port
        self.instance_ptr.clear();
        self.instance_ptr.resize(self.num_instances + 1, 0);
        for &r in &self.edge_instance {
            self.instance_ptr[r + 1] += 1;
        }
        for r in 0..self.num_instances {
            self.instance_ptr[r + 1] += self.instance_ptr[r];
        }
        let mut cursor = self.instance_ptr.clone();
        self.instance_edges.clear();
        self.instance_edges.resize(self.edge_instance.len(), 0);
        for (e, &r) in self.edge_instance.iter().enumerate() {
            self.instance_edges[cursor[r]] = e;
            cursor[r] += 1;
        }
    }

    /// Remove every edge incident to instance `r` (instance crash /
    /// drain).  Returns the removed edges so the caller can restore them
    /// on recovery.  The vertex itself stays — churn never renumbers the
    /// id spaces, only the edge set.
    pub fn remove_instance_edges(&mut self, r: usize) -> Result<Vec<(usize, usize)>, String> {
        if r >= self.num_instances {
            return Err(format!(
                "remove_instance_edges: instance {r} out of range (R={})",
                self.num_instances
            ));
        }
        let ports = std::mem::take(&mut self.instances_to_ports[r]);
        let removed: Vec<(usize, usize)> = ports.iter().map(|&l| (l, r)).collect();
        for &l in &ports {
            self.mask[l * self.num_instances + r] = 0.0;
            if let Ok(pos) = self.ports_to_instances[l].binary_search(&r) {
                self.ports_to_instances[l].remove(pos);
            }
        }
        self.rebuild_index();
        self.debug_validate();
        Ok(removed)
    }

    /// Remove every edge incident to port `l` (port-class departure).
    /// Returns the removed edges for later restoration.
    pub fn remove_port_edges(&mut self, l: usize) -> Result<Vec<(usize, usize)>, String> {
        if l >= self.num_ports {
            return Err(format!(
                "remove_port_edges: port {l} out of range (L={})",
                self.num_ports
            ));
        }
        let instances = std::mem::take(&mut self.ports_to_instances[l]);
        let removed: Vec<(usize, usize)> = instances.iter().map(|&r| (l, r)).collect();
        for &r in &instances {
            self.mask[l * self.num_instances + r] = 0.0;
            if let Ok(pos) = self.instances_to_ports[r].binary_search(&l) {
                self.instances_to_ports[r].remove(pos);
            }
        }
        self.rebuild_index();
        self.debug_validate();
        Ok(removed)
    }

    /// Insert edges (recovery / arrival).  Already-present edges are
    /// ignored, out-of-range endpoints are an error naming the vertex.
    pub fn add_edges(&mut self, edges: &[(usize, usize)]) -> Result<(), String> {
        for &(l, r) in edges {
            if l >= self.num_ports {
                return Err(format!("add_edges: port {l} out of range (L={})", self.num_ports));
            }
            if r >= self.num_instances {
                return Err(format!(
                    "add_edges: instance {r} out of range (R={})",
                    self.num_instances
                ));
            }
            if self.mask[l * self.num_instances + r] != 0.0 {
                continue;
            }
            self.mask[l * self.num_instances + r] = 1.0;
            if let Err(pos) = self.ports_to_instances[l].binary_search(&r) {
                self.ports_to_instances[l].insert(pos, r);
            }
            if let Err(pos) = self.instances_to_ports[r].binary_search(&l) {
                self.instances_to_ports[r].insert(pos, l);
            }
        }
        self.rebuild_index();
        self.debug_validate();
        Ok(())
    }

    /// Debug-build invariant gate at every mutation site (satellite-2):
    /// a bad incremental update fails here, not three slots later.
    #[inline]
    fn debug_validate(&self) {
        if cfg!(debug_assertions) {
            if let Err(e) = self.validate() {
                panic!("graph invariant broken after mutation: {e}");
            }
        }
    }

    /// Complete bipartite graph (no locality constraints).
    pub fn full(num_ports: usize, num_instances: usize) -> Self {
        let edges: Vec<(usize, usize)> = (0..num_ports)
            .flat_map(|l| (0..num_instances).map(move |r| (l, r)))
            .collect();
        Self::from_edges(num_ports, num_instances, &edges)
    }

    /// Right d-regular graph: every instance serves exactly `d` ports
    /// (the structure the paper's proofs specialize to).  Ports are
    /// assigned round-robin with a random rotation so that port degrees
    /// stay balanced.
    pub fn right_regular(num_ports: usize, num_instances: usize, d: usize, rng: &mut Rng) -> Self {
        let d = d.min(num_ports);
        let mut edges = Vec::with_capacity(num_instances * d);
        for r in 0..num_instances {
            let start = rng.below(num_ports);
            for j in 0..d {
                edges.push(((start + j) % num_ports, r));
            }
        }
        Self::from_edges(num_ports, num_instances, &edges)
    }

    /// Random graph targeting an average instance indegree of
    /// `density` = Σ_r |L_r| / |R|  (the "graph dense" of Tab. 3).
    /// Every instance keeps ≥1 port and every port keeps ≥1 instance so
    /// no vertex is stranded.
    pub fn random_density(
        num_ports: usize,
        num_instances: usize,
        density: f64,
        rng: &mut Rng,
    ) -> Self {
        let p = (density / num_ports as f64).clamp(0.0, 1.0);
        let mut edges = Vec::new();
        for r in 0..num_instances {
            let mut any = false;
            for l in 0..num_ports {
                if rng.bernoulli(p) {
                    edges.push((l, r));
                    any = true;
                }
            }
            if !any {
                edges.push((rng.below(num_ports), r));
            }
        }
        // make sure no port is isolated
        let mut port_deg = vec![0usize; num_ports];
        for &(l, _) in &edges {
            port_deg[l] += 1;
        }
        for (l, &deg) in port_deg.iter().enumerate() {
            if deg == 0 {
                edges.push((l, rng.below(num_instances)));
            }
        }
        Self::from_edges(num_ports, num_instances, &edges)
    }

    #[inline]
    pub fn has_edge(&self, l: usize, r: usize) -> bool {
        self.mask[l * self.num_instances + r] != 0.0
    }

    pub fn num_edges(&self) -> usize {
        self.edge_port.len()
    }

    /// Edge-id range of port l (edges are port-major, so this is also
    /// the contiguous slice `port_ptr[l]*K..port_ptr[l+1]*K` of the
    /// decision tensor).
    #[inline]
    pub fn port_edges(&self, l: usize) -> std::ops::Range<usize> {
        self.port_ptr[l]..self.port_ptr[l + 1]
    }

    /// Edge ids adjacent to instance r, ascending in port.
    #[inline]
    pub fn instance_edge_ids(&self, r: usize) -> &[usize] {
        &self.instance_edges[self.instance_ptr[r]..self.instance_ptr[r + 1]]
    }

    /// Degree of instance r (|L_r|).
    #[inline]
    pub fn instance_degree(&self, r: usize) -> usize {
        self.instance_ptr[r + 1] - self.instance_ptr[r]
    }

    /// Edge id of (l, r), if it is an edge (binary search in R_l).
    #[inline]
    pub fn edge_id(&self, l: usize, r: usize) -> Option<usize> {
        self.ports_to_instances[l]
            .binary_search(&r)
            .ok()
            .map(|pos| self.port_ptr[l] + pos)
    }

    /// Σ_r |L_r| / |R| — the "graph dense" metric of Tab. 3.
    pub fn density(&self) -> f64 {
        if self.num_instances == 0 {
            return 0.0;
        }
        self.num_edges() as f64 / self.num_instances as f64
    }

    /// Is every instance indegree exactly d?
    pub fn is_right_regular(&self, d: usize) -> bool {
        self.instances_to_ports.iter().all(|ls| ls.len() == d)
    }

    /// Internal-consistency check (used by tests and debug assertions):
    /// both adjacency directions and the mask describe the same edge set.
    pub fn validate(&self) -> Result<(), String> {
        let mut count = 0usize;
        for (l, rs) in self.ports_to_instances.iter().enumerate() {
            for &r in rs {
                if !self.has_edge(l, r) {
                    return Err(format!("mask missing edge ({l},{r})"));
                }
                if !self.instances_to_ports[r].contains(&l) {
                    return Err(format!("reverse adjacency missing ({l},{r})"));
                }
                count += 1;
            }
        }
        let mask_count = self.mask.iter().filter(|&&m| m != 0.0).count();
        if mask_count != count {
            return Err(format!("mask has {mask_count} edges, adjacency has {count}"));
        }
        // edge index consistency
        if self.edge_port.len() != count || self.edge_instance.len() != count {
            return Err("edge arrays disagree with adjacency edge count".into());
        }
        if self.port_ptr.len() != self.num_ports + 1
            || self.instance_ptr.len() != self.num_instances + 1
        {
            return Err("CSR pointer arrays have wrong length".into());
        }
        for l in 0..self.num_ports {
            let rs = &self.ports_to_instances[l];
            let range = self.port_edges(l);
            if range.len() != rs.len() {
                return Err(format!("port_ptr range of port {l} disagrees with R_l"));
            }
            for (j, e) in range.enumerate() {
                if self.edge_port[e] != l || self.edge_instance[e] != rs[j] {
                    return Err(format!("edge {e} maps to wrong endpoints"));
                }
                if self.edge_id(l, rs[j]) != Some(e) {
                    return Err(format!("edge_id({l},{}) != {e}", rs[j]));
                }
            }
        }
        for r in 0..self.num_instances {
            let ids = self.instance_edge_ids(r);
            if ids.len() != self.instances_to_ports[r].len() {
                return Err(format!("instance_edges of {r} disagrees with L_r"));
            }
            for (j, &e) in ids.iter().enumerate() {
                if self.edge_instance[e] != r
                    || self.edge_port[e] != self.instances_to_ports[r][j]
                {
                    return Err(format!("instance edge list of {r} is inconsistent at {j}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_graph_shape() {
        let g = Bipartite::full(3, 5);
        assert_eq!(g.num_edges(), 15);
        assert!((g.density() - 3.0).abs() < 1e-12);
        assert!(g.is_right_regular(3));
        g.validate().unwrap();
    }

    #[test]
    fn from_edges_dedups() {
        let g = Bipartite::from_edges(2, 2, &[(0, 1), (0, 1), (1, 0)]);
        assert_eq!(g.num_edges(), 2);
        g.validate().unwrap();
    }

    #[test]
    fn right_regular_has_exact_indegree() {
        let mut rng = Rng::new(1);
        let g = Bipartite::right_regular(10, 64, 4, &mut rng);
        assert!(g.is_right_regular(4));
        assert!((g.density() - 4.0).abs() < 1e-12);
        g.validate().unwrap();
    }

    #[test]
    fn random_density_hits_target_and_strands_nobody() {
        let mut rng = Rng::new(7);
        let g = Bipartite::random_density(10, 512, 3.0, &mut rng);
        assert!((g.density() - 3.0).abs() < 0.4, "density={}", g.density());
        assert!(g.ports_to_instances.iter().all(|v| !v.is_empty()));
        assert!(g.instances_to_ports.iter().all(|v| !v.is_empty()));
        g.validate().unwrap();
    }

    #[test]
    fn density_one_is_minimum_connectivity() {
        let mut rng = Rng::new(3);
        let g = Bipartite::random_density(5, 100, 0.0, &mut rng);
        // forced fallback edges keep each instance at exactly one port
        assert!(g.instances_to_ports.iter().all(|v| v.len() == 1));
        g.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_edge_panics() {
        Bipartite::from_edges(2, 2, &[(2, 0)]);
    }

    #[test]
    fn edge_index_is_port_major() {
        let g = Bipartite::from_edges(3, 3, &[(0, 2), (0, 0), (1, 1), (2, 0), (2, 2)]);
        assert_eq!(g.port_ptr, vec![0, 2, 3, 5]);
        assert_eq!(g.edge_instance, vec![0, 2, 1, 0, 2]);
        assert_eq!(g.edge_port, vec![0, 0, 1, 2, 2]);
        assert_eq!(g.edge_id(0, 2), Some(1));
        assert_eq!(g.edge_id(1, 0), None);
        assert_eq!(g.instance_edge_ids(0), &[0, 3]);
        assert_eq!(g.instance_edge_ids(1), &[2]);
        assert_eq!(g.instance_edge_ids(2), &[1, 4]);
        assert_eq!(g.instance_degree(0), 2);
        g.validate().unwrap();
    }

    #[test]
    fn edge_index_handles_isolated_vertices() {
        // port 1 and instance 0 have no edges at all
        let g = Bipartite::from_edges(2, 2, &[(0, 1)]);
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.port_edges(1).len(), 0);
        assert!(g.instance_edge_ids(0).is_empty());
        assert_eq!(g.edge_id(1, 1), None);
        g.validate().unwrap();
    }

    #[test]
    fn remove_and_restore_instance_round_trips() {
        let edges = [(0, 2), (0, 0), (1, 1), (2, 0), (2, 2)];
        let mut g = Bipartite::from_edges(3, 3, &edges);
        let reference = Bipartite::from_edges(3, 3, &edges);
        let removed = g.remove_instance_edges(0).unwrap();
        assert_eq!(removed, vec![(0, 0), (2, 0)]);
        assert!(g.instance_edge_ids(0).is_empty());
        assert!(!g.has_edge(0, 0));
        g.validate().unwrap();
        // edge ids re-pack port-major over the surviving edges
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.edge_id(0, 2), Some(0));
        g.add_edges(&removed).unwrap();
        assert_eq!(g.mask, reference.mask);
        assert_eq!(g.port_ptr, reference.port_ptr);
        assert_eq!(g.edge_instance, reference.edge_instance);
        assert_eq!(g.instance_edges, reference.instance_edges);
    }

    #[test]
    fn remove_port_edges_leaves_zero_degree_port() {
        let mut g = Bipartite::full(3, 4);
        let removed = g.remove_port_edges(1).unwrap();
        assert_eq!(removed.len(), 4);
        assert_eq!(g.port_edges(1).len(), 0);
        assert_eq!(g.num_edges(), 8);
        g.validate().unwrap();
        g.add_edges(&removed).unwrap();
        assert_eq!(g.num_edges(), 12);
        assert!(g.is_right_regular(3));
    }

    #[test]
    fn mutation_errors_name_the_vertex() {
        let mut g = Bipartite::full(2, 2);
        assert!(g.remove_instance_edges(5).unwrap_err().contains("instance 5"));
        assert!(g.remove_port_edges(7).unwrap_err().contains("port 7"));
        assert!(g.add_edges(&[(0, 9)]).unwrap_err().contains("instance 9"));
        assert!(g.add_edges(&[(4, 0)]).unwrap_err().contains("port 4"));
    }

    #[test]
    fn add_edges_is_idempotent() {
        let mut g = Bipartite::from_edges(2, 2, &[(0, 0)]);
        g.add_edges(&[(0, 0), (1, 1), (1, 1)]).unwrap();
        assert_eq!(g.num_edges(), 2);
        g.validate().unwrap();
    }
}
