//! The problem instance of Sec. 2: graph + resource model + utilities.
//!
//! Tensor conventions (row-major):
//!   - `[L, K]` demands `a`, indexed `l * K + k`
//!   - `[R, K]` capacities `c`, coefficients `alpha`, families `kind`
//!   - `[E, K]` decisions `y` in the **edge-major CSR layout**: the
//!     channel (l, r) with edge id `e = graph.edge_id(l, r)` lives at
//!     `y[e * K + k]`.  Edge ids are port-major, so port l's coordinates
//!     are the contiguous slice
//!     `y[graph.port_ptr[l] * K .. graph.port_ptr[l + 1] * K]`.
//!     Off-edge (l, r) pairs have no coordinates at all — feasibility's
//!     locality constraint holds by construction and the hot path scales
//!     with |E|·K instead of L·R·K.  (The Python/XLA side still works on
//!     the dense `[L, R, K]` tensor; `runtime::executor` converts at the
//!     boundary, and `oga::dense_ref` keeps a dense reference
//!     implementation for parity tests and benchmarks.)

use crate::graph::Bipartite;
use crate::oga::utilities::UtilityKind;

/// Names for the K=6 default device classes (Tab. 2).
pub const DEVICE_NAMES: [&str; 6] = ["CPU", "MEM", "GPU", "NPU", "TPU", "FPGA"];

/// A maximal run of decision coordinates `[lo, hi)` (edge-major flat
/// indices) whose utility family is the same `kind`.  Runs never span a
/// port boundary, so each run lies inside one port's contiguous slice.
#[derive(Clone, Copy, Debug)]
pub struct KindRun {
    pub lo: usize,
    pub hi: usize,
    pub kind: UtilityKind,
}

/// Kind-grouped view of the edge-major decision tensor (§Perf-2).
///
/// The hot kernels (gradient, fused ascent, slot reward, oracle solve)
/// evaluate `f_r^k` / `(f_r^k)'` per coordinate; matching on the
/// `UtilityKind` inside the innermost `K` loop costs a branch per
/// coordinate and blocks vectorization.  This index is built once per
/// problem: each port's contiguous `[E, K]` slice is cut into maximal
/// same-kind runs, and the per-coordinate α is gathered into a flat
/// array aligned with the decision layout.  A kernel then dispatches on
/// the family once per run and streams a branch-free contiguous loop
/// (`UtilityKind::{value_sum, grad_into, ascend_slice}`).
#[derive(Clone, Debug, Default)]
pub struct KindIndex {
    /// α per decision coordinate: `alpha_flat[e*K + k] = α[r(e)*K + k]`.
    pub alpha_flat: Vec<f64>,
    runs: Vec<KindRun>,
    /// Runs of port l are `runs[port_run_ptr[l]..port_run_ptr[l + 1]]`.
    port_run_ptr: Vec<usize>,
}

impl KindIndex {
    pub fn build(problem: &Problem) -> Self {
        let k_n = problem.num_resources;
        let g = &problem.graph;
        let mut alpha_flat = Vec::with_capacity(problem.decision_len());
        let mut runs: Vec<KindRun> = Vec::new();
        let mut port_run_ptr = Vec::with_capacity(problem.num_ports() + 1);
        port_run_ptr.push(0);
        for l in 0..problem.num_ports() {
            let mut open: Option<KindRun> = None;
            for e in g.port_edges(l) {
                let rk = g.edge_instance[e] * k_n;
                for k in 0..k_n {
                    let c = e * k_n + k;
                    let kind = problem.kind[rk + k];
                    alpha_flat.push(problem.alpha[rk + k]);
                    match open {
                        Some(ref mut run) if run.kind == kind => run.hi = c + 1,
                        ref mut slot => {
                            if let Some(done) = slot.take() {
                                runs.push(done);
                            }
                            *slot = Some(KindRun { lo: c, hi: c + 1, kind });
                        }
                    }
                }
            }
            if let Some(done) = open {
                runs.push(done);
            }
            port_run_ptr.push(runs.len());
        }
        KindIndex { alpha_flat, runs, port_run_ptr }
    }

    /// The same-kind runs covering port l's coordinate slice, in
    /// ascending coordinate order.
    #[inline]
    pub fn port_runs(&self, l: usize) -> &[KindRun] {
        &self.runs[self.port_run_ptr[l]..self.port_run_ptr[l + 1]]
    }

    /// Internal-consistency check used by tests: the runs of each port
    /// tile exactly its coordinate slice, and kind/α agree with the
    /// problem's `[R, K]` tables.
    pub fn validate(&self, problem: &Problem) -> Result<(), String> {
        let k_n = problem.num_resources;
        if self.alpha_flat.len() != problem.decision_len() {
            return Err("alpha_flat length disagrees with decision_len".into());
        }
        if self.port_run_ptr.len() != problem.num_ports() + 1 {
            return Err("port_run_ptr has wrong length".into());
        }
        for l in 0..problem.num_ports() {
            let lo = problem.graph.port_ptr[l] * k_n;
            let hi = problem.graph.port_ptr[l + 1] * k_n;
            let mut cursor = lo;
            for run in self.port_runs(l) {
                if run.lo != cursor || run.hi <= run.lo {
                    return Err(format!("runs of port {l} do not tile its slice"));
                }
                for c in run.lo..run.hi {
                    let e = c / k_n;
                    let k = c % k_n;
                    let rk = problem.graph.edge_instance[e] * k_n + k;
                    if problem.kind[rk] != run.kind {
                        return Err(format!("run kind mismatch at coordinate {c}"));
                    }
                    if self.alpha_flat[c] != problem.alpha[rk] {
                        return Err(format!("alpha_flat mismatch at coordinate {c}"));
                    }
                }
                cursor = run.hi;
            }
            if cursor != hi {
                return Err(format!("runs of port {l} stop at {cursor}, slice ends at {hi}"));
            }
        }
        Ok(())
    }
}

/// Monotone id handed to each `Problem::new` (clones share their
/// original's).  The sparse publishers key their buffer-identity checks
/// on it, so a *different* problem reusing a same-shaped buffer can
/// never be mistaken for the previous one (see
/// `schedulers::IncrementalPublisher`).
static PROBLEM_GENERATION: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

/// A fully specified scheduling problem instance.
///
/// Constructed through [`Problem::new`], which is the single owner of
/// the derived [`KindIndex`]: consumers (`coordinator::Leader`,
/// `oga::OgaState`, `regret::solve_oracle`, the benches) borrow it via
/// [`Problem::kinds`] instead of each rebuilding the O(|E|·K) index and
/// holding their own ~|E|·K copy of α.
#[derive(Clone, Debug)]
pub struct Problem {
    pub graph: Bipartite,
    /// K — number of resource types.
    pub num_resources: usize,
    /// [L, K] maximum per-channel requests a_l^k (already scaled by the
    /// contention-level multiplier).
    pub demand: Vec<f64>,
    /// [R, K] instance capacities c_r^k.
    pub capacity: Vec<f64>,
    /// [R, K] utility coefficients α of f_r^k.
    pub alpha: Vec<f64>,
    /// [R, K] utility family of f_r^k.
    pub kind: Vec<UtilityKind>,
    /// [K] communication-overhead coefficients β_k ∈ [0, 1].
    pub beta: Vec<f64>,
    /// Kind-grouped decision view (single owner; see [`Problem::kinds`]).
    kinds: KindIndex,
    /// Problem generation (see [`PROBLEM_GENERATION`]).
    generation: u64,
}

impl Problem {
    /// Build a problem and its derived kind index.  Panics on shape
    /// mismatches — a malformed instance would only fail later and
    /// further from the cause.
    pub fn new(
        graph: Bipartite,
        num_resources: usize,
        demand: Vec<f64>,
        capacity: Vec<f64>,
        alpha: Vec<f64>,
        kind: Vec<UtilityKind>,
        beta: Vec<f64>,
    ) -> Problem {
        assert_eq!(demand.len(), graph.num_ports * num_resources, "demand is [L, K]");
        assert_eq!(capacity.len(), graph.num_instances * num_resources, "capacity is [R, K]");
        assert_eq!(alpha.len(), capacity.len(), "alpha is [R, K]");
        assert_eq!(kind.len(), capacity.len(), "kind is [R, K]");
        assert_eq!(beta.len(), num_resources, "beta is [K]");
        let mut problem = Problem {
            graph,
            num_resources,
            demand,
            capacity,
            alpha,
            kind,
            beta,
            kinds: KindIndex::default(),
            generation: PROBLEM_GENERATION
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed),
        };
        problem.kinds = KindIndex::build(&problem);
        problem
    }

    /// The kind-grouped runs + flattened α for the batched kernels.
    #[inline]
    pub fn kinds(&self) -> &KindIndex {
        &self.kinds
    }

    /// Generation id assigned at [`Problem::new`] (clones share it).
    /// Topology mutations bump it, so publisher identity checks treat
    /// the mutated problem as a new one.
    #[inline]
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Re-derive everything downstream of a graph mutation: a fresh
    /// generation (so `IncrementalPublisher` identity goes stale and the
    /// first post-churn publish is a conservative full copy) and a
    /// rebuilt [`KindIndex`] (every edge id shifted).
    /// The debug panic carries the mutation site and the *new*
    /// generation, so a broken invariant names which churn edit of
    /// which edition produced it (editions are otherwise anonymous once
    /// the event stream has scrolled by).
    fn reindex(&mut self, site: impl FnOnce() -> String) {
        self.generation =
            PROBLEM_GENERATION.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let kinds = KindIndex::build(&*self);
        self.kinds = kinds;
        if cfg!(debug_assertions) {
            if let Err(e) = self.graph.validate() {
                panic!(
                    "graph invariant broken after {} (generation {}): {e}",
                    site(),
                    self.generation
                );
            }
            if let Err(e) = self.kinds.validate(self) {
                panic!(
                    "kind index invariant broken after {} (generation {}): {e}",
                    site(),
                    self.generation
                );
            }
        }
    }

    /// Drop every channel of instance `r` (crash).  Returns the removed
    /// edges so recovery can restore exactly them.
    pub fn remove_instance_edges(&mut self, r: usize) -> Result<Vec<(usize, usize)>, String> {
        let removed = self.graph.remove_instance_edges(r)?;
        self.reindex(|| format!("remove_instance_edges({r})"));
        Ok(removed)
    }

    /// Drop every channel of port `l` (port-class departure).
    pub fn remove_port_edges(&mut self, l: usize) -> Result<Vec<(usize, usize)>, String> {
        let removed = self.graph.remove_port_edges(l)?;
        self.reindex(|| format!("remove_port_edges({l})"));
        Ok(removed)
    }

    /// Restore previously removed channels (recovery / arrival).
    pub fn restore_edges(&mut self, edges: &[(usize, usize)]) -> Result<(), String> {
        self.graph.add_edges(edges)?;
        self.reindex(|| format!("restore_edges({} channels)", edges.len()));
        Ok(())
    }

    pub fn num_ports(&self) -> usize {
        self.graph.num_ports
    }

    pub fn num_instances(&self) -> usize {
        self.graph.num_instances
    }

    /// |E| — number of channels in the locality graph.
    pub fn num_edges(&self) -> usize {
        self.graph.num_edges()
    }

    /// Length of the edge-major decision tensor [E, K]
    /// (= Σ_l |R_l| · K).
    pub fn decision_len(&self) -> usize {
        self.num_edges() * self.num_resources
    }

    #[inline]
    pub fn demand_at(&self, l: usize, k: usize) -> f64 {
        self.demand[l * self.num_resources + k]
    }

    #[inline]
    pub fn capacity_at(&self, r: usize, k: usize) -> f64 {
        self.capacity[r * self.num_resources + k]
    }

    #[inline]
    pub fn alpha_at(&self, r: usize, k: usize) -> f64 {
        self.alpha[r * self.num_resources + k]
    }

    #[inline]
    pub fn kind_at(&self, r: usize, k: usize) -> UtilityKind {
        self.kind[r * self.num_resources + k]
    }

    /// Flat index of channel (l, r), resource k in the edge-major
    /// decision layout.  Panics when (l, r) is not an edge — off-edge
    /// coordinates do not exist under the CSR layout.
    ///
    /// The hit path inlines to a CSR lookup plus a multiply-add; the
    /// miss path is split out `#[cold]` so the panic's formatting
    /// machinery never lands in the hot loop's code.  Both paths stay
    /// fully bounds-checked — no `unsafe`, no UB — in release builds;
    /// the miss simply panics from an outlined shim.
    #[inline]
    pub fn idx(&self, l: usize, r: usize, k: usize) -> usize {
        match self.graph.edge_id(l, r) {
            Some(e) => e * self.num_resources + k,
            None => Self::idx_miss(l, r, k),
        }
    }

    #[cold]
    #[inline(never)]
    fn idx_miss(l: usize, r: usize, k: usize) -> ! {
        panic!("idx({l},{r},{k}): ({l},{r}) is not an edge")
    }

    /// Flat index of edge `e`, resource k.
    #[inline]
    pub fn edge_idx(&self, e: usize, k: usize) -> usize {
        e * self.num_resources + k
    }

    /// ā^k = max_l a_l^k (Thm. 1).
    pub fn max_demand(&self, k: usize) -> f64 {
        (0..self.num_ports())
            .map(|l| self.demand_at(l, k))
            .fold(0.0, f64::max)
    }

    /// β* = max_k β_k (Thm. 1).
    pub fn beta_star(&self) -> f64 {
        self.beta.iter().copied().fold(0.0, f64::max)
    }

    /// ϖ*_r = max_k ϖ_r^k (Thm. 1).
    pub fn varpi_star(&self, r: usize) -> f64 {
        (0..self.num_resources)
            .map(|k| self.kind_at(r, k).varpi(self.alpha_at(r, k)))
            .fold(0.0, f64::max)
    }

    /// The graph factor H_G of Eq. (49):
    /// sqrt(2 Σ_k Σ_r ā^k c_r^k) · sqrt(Σ_l Σ_{r∈R_l} ((β*)² + K(ϖ*_r)²)).
    pub fn h_g(&self) -> f64 {
        let k_n = self.num_resources;
        let mut cap_term = 0.0;
        for k in 0..k_n {
            let abar = self.max_demand(k);
            for r in 0..self.num_instances() {
                cap_term += abar * self.capacity_at(r, k);
            }
        }
        let beta2 = self.beta_star().powi(2);
        let mut grad_term = 0.0;
        for l in 0..self.num_ports() {
            for &r in &self.graph.ports_to_instances[l] {
                grad_term += beta2 + k_n as f64 * self.varpi_star(r).powi(2);
            }
        }
        (2.0 * cap_term).sqrt() * grad_term.sqrt()
    }

    /// diam(Y) upper bound of Eq. (48).
    pub fn diam_upper(&self) -> f64 {
        let mut cap_term = 0.0;
        for k in 0..self.num_resources {
            let abar = self.max_demand(k);
            for r in 0..self.num_instances() {
                cap_term += abar * self.capacity_at(r, k);
            }
        }
        (2.0 * cap_term).sqrt()
    }

    /// max ||∇q|| upper bound of Eq. (45).
    pub fn grad_norm_upper(&self) -> f64 {
        let beta2 = self.beta_star().powi(2);
        let mut sum = 0.0;
        for l in 0..self.num_ports() {
            for &r in &self.graph.ports_to_instances[l] {
                sum += beta2 + self.num_resources as f64 * self.varpi_star(r).powi(2);
            }
        }
        sum.sqrt()
    }

    /// Is the edge-major decision tensor `y` feasible (Eqs. 5-6)?  The
    /// locality constraint is structural: off-edge coordinates cannot be
    /// represented, so only the box and capacity constraints remain.
    pub fn check_feasible(&self, y: &[f64], tol: f64) -> Result<(), String> {
        let (r_n, k_n) = (self.num_instances(), self.num_resources);
        assert_eq!(y.len(), self.decision_len());
        for e in 0..self.num_edges() {
            let l = self.graph.edge_port[e];
            let r = self.graph.edge_instance[e];
            for k in 0..k_n {
                let v = y[e * k_n + k];
                if v < -tol {
                    return Err(format!("negative allocation y[{l},{r},{k}]={v}"));
                }
                if v > self.demand_at(l, k) + tol {
                    return Err(format!(
                        "y[{l},{r},{k}]={v} exceeds demand {}",
                        self.demand_at(l, k)
                    ));
                }
            }
        }
        for r in 0..r_n {
            let edges = self.graph.instance_edge_ids(r);
            for k in 0..k_n {
                let used: f64 = edges.iter().map(|&e| y[e * k_n + k]).sum();
                let cap = self.capacity_at(r, k);
                if used > cap + tol * (1.0 + edges.len() as f64) {
                    return Err(format!("capacity violated at (r={r},k={k}): {used} > {cap}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Bipartite;

    fn tiny() -> Problem {
        Problem::new(
            Bipartite::full(2, 3),
            2,
            vec![1.0, 2.0, 3.0, 4.0], // [2,2]
            vec![5.0; 6],             // [3,2]
            vec![1.0; 6],
            vec![UtilityKind::Linear; 6],
            vec![0.3, 0.5],
        )
    }

    #[test]
    fn index_math() {
        let p = tiny();
        // full graph: |E| = L·R, and CSR port-major ids coincide with the
        // dense (l·R + r) ordering
        assert_eq!(p.num_edges(), 2 * 3);
        assert_eq!(p.decision_len(), 2 * 3 * 2);
        assert_eq!(p.idx(1, 2, 1), (1 * 3 + 2) * 2 + 1);
        assert_eq!(p.edge_idx(5, 1), 11);
        assert_eq!(p.demand_at(1, 0), 3.0);
    }

    #[test]
    fn sparse_graph_shrinks_decision_len() {
        let graph = Bipartite::from_edges(2, 3, &[(0, 0), (1, 2)]);
        let p = Problem::new(
            graph,
            2,
            vec![1.0; 4],
            vec![5.0; 6],
            vec![1.0; 6],
            vec![UtilityKind::Linear; 6],
            vec![0.3, 0.5],
        );
        assert_eq!(p.decision_len(), 2 * 2); // |E|·K, not L·R·K
        assert_eq!(p.idx(0, 0, 1), 1);
        assert_eq!(p.idx(1, 2, 0), 2);
    }

    #[test]
    fn generations_are_distinct_but_shared_by_clones() {
        let a = tiny();
        let b = tiny();
        assert_ne!(a.generation(), b.generation());
        assert_eq!(a.generation(), a.clone().generation());
        assert!(a.generation() > 0);
    }

    #[test]
    #[should_panic(expected = "is not an edge")]
    fn off_edge_idx_panics() {
        let graph = Bipartite::from_edges(2, 3, &[(0, 0), (1, 2)]);
        let p = Problem::new(
            graph,
            2,
            vec![1.0; 4],
            vec![5.0; 6],
            vec![1.0; 6],
            vec![UtilityKind::Linear; 6],
            vec![0.3, 0.5],
        );
        p.idx(0, 1, 0);
    }

    #[test]
    fn theorem_quantities() {
        let p = tiny();
        assert_eq!(p.max_demand(0), 3.0);
        assert_eq!(p.max_demand(1), 4.0);
        assert!((p.beta_star() - 0.5).abs() < 1e-12);
        assert!((p.varpi_star(0) - 1.0).abs() < 1e-12);
        // H_G = sqrt(2*(3*5*3 + 4*5*3)) * sqrt(6*(0.25 + 2*1))
        let want = (2.0f64 * (45.0 + 60.0)).sqrt() * (6.0 * 2.25f64).sqrt();
        assert!((p.h_g() - want).abs() < 1e-9, "{} vs {want}", p.h_g());
        assert!((p.diam_upper() - (210.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn kind_index_tiles_every_port_slice() {
        let graph = Bipartite::from_edges(3, 3, &[(0, 0), (0, 2), (1, 1), (2, 0), (2, 1)]);
        let kinds = vec![
            UtilityKind::Linear,
            UtilityKind::Linear,
            UtilityKind::Log,
            UtilityKind::Poly,
            UtilityKind::Log,
            UtilityKind::Reciprocal,
        ];
        let p = Problem::new(
            graph,
            2,
            vec![1.0; 6],
            vec![5.0; 6],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            kinds,
            vec![0.3, 0.5],
        );
        let idx = KindIndex::build(&p);
        idx.validate(&p).unwrap();
        // the problem-owned index is the same construction
        p.kinds().validate(&p).unwrap();
        // port 0 -> instances 0 and 2: coordinate kinds are
        // [Linear, Linear, Log, Reciprocal] -> 3 runs
        assert_eq!(idx.port_runs(0).len(), 3);
        assert_eq!(idx.port_runs(0)[0].kind, UtilityKind::Linear);
        assert_eq!((idx.port_runs(0)[0].lo, idx.port_runs(0)[0].hi), (0, 2));
        // alpha gathered per coordinate: (l=0, r=2, k=0) -> alpha[2*2+0]
        assert_eq!(idx.alpha_flat[2], 5.0);
        // uniform-kind problem collapses to one run per port
        let uni = tiny();
        let idx = KindIndex::build(&uni);
        idx.validate(&uni).unwrap();
        for l in 0..uni.num_ports() {
            assert_eq!(idx.port_runs(l).len(), 1);
        }
    }

    #[test]
    fn churn_bumps_generation_and_rebuilds_kinds() {
        let graph = Bipartite::from_edges(3, 3, &[(0, 0), (0, 2), (1, 1), (2, 0), (2, 1)]);
        let mut p = Problem::new(
            graph,
            2,
            vec![1.0; 6],
            vec![5.0; 6],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            vec![UtilityKind::Linear; 6],
            vec![0.3, 0.5],
        );
        let g0 = p.generation();
        let removed = p.remove_instance_edges(0).unwrap();
        assert_eq!(removed, vec![(0, 0), (2, 0)]);
        assert!(p.generation() > g0);
        assert_eq!(p.decision_len(), 3 * 2);
        p.kinds().validate(&p).unwrap();
        // alpha_flat re-gathered over the surviving edges: edge 0 is now
        // (0, 2) -> alpha[2*2+k]
        assert_eq!(p.kinds().alpha_flat[0], 5.0);
        let g1 = p.generation();
        p.restore_edges(&removed).unwrap();
        assert!(p.generation() > g1);
        assert_eq!(p.decision_len(), 5 * 2);
        p.kinds().validate(&p).unwrap();
        // round trip matches a from-scratch build
        let rebuilt = Problem::new(
            p.graph.clone(),
            2,
            p.demand.clone(),
            p.capacity.clone(),
            p.alpha.clone(),
            p.kind.clone(),
            p.beta.clone(),
        );
        assert_eq!(p.kinds().alpha_flat, rebuilt.kinds().alpha_flat);
    }

    #[test]
    fn churn_errors_name_the_vertex() {
        let mut p = tiny();
        assert!(p.remove_instance_edges(9).unwrap_err().contains("instance 9"));
        assert!(p.remove_port_edges(9).unwrap_err().contains("port 9"));
        assert!(p.restore_edges(&[(0, 9)]).unwrap_err().contains("instance 9"));
    }

    #[test]
    fn feasibility_checks() {
        let p = tiny();
        let mut y = vec![0.0; p.decision_len()];
        assert!(p.check_feasible(&y, 1e-9).is_ok());
        y[p.idx(0, 0, 0)] = 0.5;
        assert!(p.check_feasible(&y, 1e-9).is_ok());
        y[p.idx(0, 0, 0)] = 1.5; // demand a_0^0 = 1.0
        assert!(p.check_feasible(&y, 1e-9).is_err());
        y[p.idx(0, 0, 0)] = -0.1;
        assert!(p.check_feasible(&y, 1e-9).is_err());
        // capacity violation
        let mut y = vec![0.0; p.decision_len()];
        y[p.idx(0, 0, 0)] = 1.0;
        y[p.idx(1, 0, 0)] = 3.0;
        // sums to 4.0 <= 5.0 ok
        assert!(p.check_feasible(&y, 1e-9).is_ok());
    }
}
