//! Trace-driven simulation: arrival models plus convenience drivers over
//! the L3 coordinator.  (The engine itself lives in `coordinator::leader`
//! — the simulator *is* the coordinator running against synthetic time.)

pub mod arrivals;
pub mod checkpoint;
pub mod faults;
pub mod ingest;
pub mod store;

use crate::config::Scenario;
use crate::coordinator::{Leader, RunResult};
use crate::model::Problem;
use crate::schedulers::Policy;
use crate::traces::synthesize;
use arrivals::{ArrivalModel, Bernoulli};

/// Run one policy on a scenario end to end (problem synthesis + Bernoulli
/// arrivals from the scenario seed).
pub fn run_scenario(scenario: &Scenario, policy: &mut dyn Policy) -> RunResult {
    let problem = synthesize(scenario);
    run_on_problem(scenario, &problem, policy)
}

/// Run one policy on an existing problem (avoids re-synthesis in sweeps).
pub fn run_on_problem(
    scenario: &Scenario,
    problem: &Problem,
    policy: &mut dyn Policy,
) -> RunResult {
    let mut leader = Leader::new(problem);
    let mut arrivals: Box<dyn ArrivalModel> = Box::new(Bernoulli::uniform(
        problem.num_ports(),
        scenario.arrival_prob,
        scenario.seed ^ 0xA5A5,
    ));
    policy.reset(problem);
    leader.run(policy, arrivals.as_mut(), scenario.horizon)
}

/// Run the full paper lineup on a scenario; every policy sees the same
/// arrival trajectory.  The scenario's `[parallel]` budget drives the
/// two-level split: concurrent runs × per-run shard groups (§Perf-4).
pub fn run_paper_lineup(scenario: &Scenario) -> Vec<RunResult> {
    let problem = synthesize(scenario);
    let mut lineup = crate::schedulers::paper_lineup(
        &problem,
        scenario.eta0,
        scenario.decay,
        scenario.parallel,
    );
    crate::coordinator::run_lineup(
        &problem,
        &mut lineup,
        || {
            Box::new(Bernoulli::uniform(
                problem.num_ports(),
                scenario.arrival_prob,
                scenario.seed ^ 0xA5A5,
            ))
        },
        scenario.horizon,
        scenario.parallel,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedulers::Fairness;

    #[test]
    fn scenario_run_is_deterministic() {
        let s = Scenario::small();
        let a = run_scenario(&s, &mut Fairness::new()).cumulative_reward;
        let b = run_scenario(&s, &mut Fairness::new()).cumulative_reward;
        assert_eq!(a, b);
    }

    #[test]
    fn paper_lineup_runs_all_five() {
        let mut s = Scenario::small();
        s.horizon = 80;
        let results = run_paper_lineup(&s);
        assert_eq!(results.len(), 5);
        for r in &results {
            assert_eq!(r.records.len(), 80);
        }
    }
}
