//! Durable checkpoint blob chain (§SStore).
//!
//! [`BlobStore`] persists `sim::checkpoint` PLCK blobs under
//! deterministic epoch-numbered names and retains a configurable chain
//! depth so recovery can *fall back* past a corrupt newest blob instead
//! of dying with it.  Two backends share one API:
//!
//! * **memory** — the default; blobs live in a `Vec` exactly like the
//!   pre-§SStore single-`Checkpoint` store, just `chain_depth` deep.
//! * **disk** — each put writes to `<name>.tmp`, fsyncs, then
//!   atomically renames to `ckpt-e<epoch>-s<slot>.plck`, so a crash at
//!   any instant leaves either the old chain or the old chain plus one
//!   complete new blob — never a half-written one under the final name.
//!   [`BlobStore::open`] enumerates an existing directory (a previous
//!   process's chain) and removes stray `.tmp` leftovers.
//!
//! **Storage faults** are injected *at the store boundary* in the same
//! deterministic (slot, seed) idiom as `sim::faults::ExecFaultPlan`:
//! a [`StorageFault::Torn`] write truncates the persisted bytes at a
//! seeded offset, [`StorageFault::BitFlip`] flips one seeded bit, and
//! [`StorageFault::LostRename`] persists the temp file but loses the
//! rename (the blob never enters the chain).  The driver's in-memory
//! state is never touched — exactly like real storage lying to you.
//!
//! **GC is deterministic**: after every put the store retains (a) the
//! oldest entry (the epoch-0 genesis blob — the floor every storm
//! recovery lands on), (b) the newest `chain_depth` entries, and (c)
//! the newest entry whose blob passes `utils::codec::verify` — so GC
//! can never delete the newest valid blob, even when everything newer
//! is corrupt.  Everything else is deleted, oldest first.

use std::collections::BTreeSet;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::obs;
use crate::utils::codec;

/// One storage-layer fault, applied to a single blob put.  Generated
/// per slot by `ExecFaultPlan` from seeded draws; the raw `seed` is
/// reduced against the blob length at apply time so the fault is
/// deterministic in (slot, seed) but independent of blob size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StorageFault {
    /// Persist only the first `seed % len` bytes (a torn write — power
    /// loss mid-write).
    Torn { seed: u64 },
    /// Flip bit `seed % (len * 8)` of the persisted bytes (bit rot).
    BitFlip { seed: u64 },
    /// Write the temp file but lose the rename: the blob never becomes
    /// durable under its final name.
    LostRename,
}

/// Index entry for one durable blob: its monotonically increasing
/// store epoch (put order) and the slot boundary it snapshots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChainEntry {
    pub epoch: u64,
    pub slot: u64,
}

impl ChainEntry {
    /// Deterministic on-disk name: epoch-major so lexicographic order
    /// is chain order.
    fn file_name(&self) -> String {
        format!("ckpt-e{:08}-s{:08}.plck", self.epoch, self.slot)
    }

    fn parse(name: &str) -> Option<ChainEntry> {
        let rest = name.strip_prefix("ckpt-e")?.strip_suffix(".plck")?;
        let (e, s) = rest.split_once("-s")?;
        Some(ChainEntry { epoch: e.parse().ok()?, slot: s.parse().ok()? })
    }
}

enum Backend {
    Memory(Vec<Vec<u8>>),
    Disk(PathBuf),
}

/// A chain of durable checkpoint blobs; see the module docs.
pub struct BlobStore {
    backend: Backend,
    /// Entries in ascending epoch order, parallel to `Memory`'s blob
    /// vec (disk entries index files).
    entries: Vec<ChainEntry>,
    depth: usize,
    next_epoch: u64,
}

impl BlobStore {
    /// In-memory chain (the default backend — no filesystem traffic,
    /// used by the parity suites and by `run_resilient` when no
    /// `store_dir` is configured).
    pub fn memory(depth: usize) -> BlobStore {
        BlobStore {
            backend: Backend::Memory(Vec::new()),
            entries: Vec::new(),
            depth: depth.max(1),
            next_epoch: 0,
        }
    }

    /// Open (or create) a disk-backed chain at `dir`.  Existing blobs
    /// are enumerated in epoch order and stray `.tmp` files — lost or
    /// torn renames from a previous process — are removed.
    pub fn open(dir: &Path, depth: usize) -> Result<BlobStore, String> {
        fs::create_dir_all(dir).map_err(|e| format!("store: create {}: {e}", dir.display()))?;
        let mut entries = Vec::new();
        let listing =
            fs::read_dir(dir).map_err(|e| format!("store: read {}: {e}", dir.display()))?;
        for item in listing {
            let item = item.map_err(|e| format!("store: read {}: {e}", dir.display()))?;
            let name = item.file_name();
            let name = name.to_string_lossy();
            if name.ends_with(".tmp") {
                // a rename that never landed: the blob was never
                // durable, so the leftover is garbage by definition
                let _ = fs::remove_file(item.path());
                continue;
            }
            if let Some(entry) = ChainEntry::parse(&name) {
                entries.push(entry);
            }
        }
        entries.sort_by_key(|e| e.epoch);
        let next_epoch = entries.last().map_or(0, |e| e.epoch + 1);
        Ok(BlobStore {
            backend: Backend::Disk(dir.to_path_buf()),
            entries,
            depth: depth.max(1),
            next_epoch,
        })
    }

    /// Retention depth (newest entries always kept).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The chain, newest first — the order recovery walks it.
    pub fn chain(&self) -> Vec<ChainEntry> {
        self.entries.iter().rev().copied().collect()
    }

    /// Slot of the newest durable entry (the driver's write-dedup key).
    pub fn newest_slot(&self) -> Option<u64> {
        self.entries.last().map(|e| e.slot)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Read one blob's bytes back (exactly as persisted — including any
    /// injected corruption).
    pub fn load(&self, entry: &ChainEntry) -> Result<Vec<u8>, String> {
        match &self.backend {
            Backend::Memory(blobs) => {
                let ix = self
                    .entries
                    .iter()
                    .position(|e| e == entry)
                    .ok_or_else(|| format!("store: epoch {} not in the chain", entry.epoch))?;
                Ok(blobs[ix].clone())
            }
            Backend::Disk(dir) => {
                let path = dir.join(entry.file_name());
                fs::read(&path).map_err(|e| format!("store: read {}: {e}", path.display()))
            }
        }
    }

    /// Persist one blob under the next epoch number, applying an
    /// injected storage fault if one is scheduled, then run GC.  The
    /// epoch counter advances even for a lost rename (the name was
    /// claimed; only the rename was lost), keeping the naming stream
    /// deterministic under replay.
    pub fn put(
        &mut self,
        slot: u64,
        bytes: &[u8],
        fault: Option<StorageFault>,
    ) -> Result<(), String> {
        let entry = ChainEntry { epoch: self.next_epoch, slot };
        self.next_epoch += 1;
        obs::registry().counter("store.puts").inc();
        let (persisted, lost) = match fault {
            None => (bytes.to_vec(), false),
            Some(StorageFault::Torn { seed }) => {
                let cut = (seed % bytes.len().max(1) as u64) as usize;
                (bytes[..cut].to_vec(), false)
            }
            Some(StorageFault::BitFlip { seed }) => {
                let mut b = bytes.to_vec();
                if !b.is_empty() {
                    let bit = (seed % (b.len() as u64 * 8)) as usize;
                    b[bit / 8] ^= 1 << (bit % 8);
                }
                (b, false)
            }
            Some(StorageFault::LostRename) => (bytes.to_vec(), true),
        };
        match &mut self.backend {
            Backend::Memory(blobs) => {
                if !lost {
                    blobs.push(persisted);
                    self.entries.push(entry);
                }
            }
            Backend::Disk(dir) => {
                let tmp = dir.join(format!("{}.tmp", entry.file_name()));
                {
                    let mut f = fs::File::create(&tmp)
                        .map_err(|e| format!("store: create {}: {e}", tmp.display()))?;
                    f.write_all(&persisted)
                        .map_err(|e| format!("store: write {}: {e}", tmp.display()))?;
                    // flush-to-durable before the rename publishes the
                    // name: the atomic-rename contract
                    f.sync_all()
                        .map_err(|e| format!("store: sync {}: {e}", tmp.display()))?;
                }
                if lost {
                    // the rename never happens; the tmp lingers exactly
                    // as a crash would leave it (open() sweeps it)
                    return Ok(());
                }
                let fin = dir.join(entry.file_name());
                fs::rename(&tmp, &fin)
                    .map_err(|e| format!("store: rename {}: {e}", fin.display()))?;
                self.entries.push(entry);
            }
        }
        if lost {
            return Ok(());
        }
        self.gc();
        Ok(())
    }

    /// Deterministic retention: keep the oldest entry, the newest
    /// `depth` entries, and the newest entry whose blob verifies;
    /// delete the rest (oldest first).  See the module docs for why
    /// each pin exists.
    fn gc(&mut self) {
        if self.entries.len() <= 1 {
            return;
        }
        let mut protect: BTreeSet<u64> = BTreeSet::new();
        protect.insert(self.entries[0].epoch);
        for e in self.entries.iter().rev().take(self.depth) {
            protect.insert(e.epoch);
        }
        let snapshot: Vec<ChainEntry> = self.entries.clone();
        for e in snapshot.iter().rev() {
            let valid = self
                .load(e)
                .map(|b| codec::verify(&b).is_ok())
                .unwrap_or(false);
            if valid {
                protect.insert(e.epoch);
                break;
            }
        }
        let doomed: Vec<ChainEntry> = self
            .entries
            .iter()
            .filter(|e| !protect.contains(&e.epoch))
            .copied()
            .collect();
        for e in &doomed {
            if let Backend::Disk(dir) = &self.backend {
                let _ = fs::remove_file(dir.join(e.file_name()));
            }
            obs::registry().counter("store.gc_deleted").inc();
        }
        match &mut self.backend {
            Backend::Memory(blobs) => {
                let mut keep_blobs = Vec::with_capacity(protect.len());
                let mut keep_entries = Vec::with_capacity(protect.len());
                for (e, b) in self.entries.iter().zip(blobs.drain(..)) {
                    if protect.contains(&e.epoch) {
                        keep_blobs.push(b);
                        keep_entries.push(*e);
                    }
                }
                *blobs = keep_blobs;
                self.entries = keep_entries;
            }
            Backend::Disk(_) => {
                self.entries.retain(|e| protect.contains(&e.epoch));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::codec::Writer;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn blob(tag: u64) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u64(tag);
        w.put_str("store-test");
        w.finish()
    }

    fn tmpdir(tag: &str) -> PathBuf {
        static NONCE: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "ogasched-store-{}-{}-{}",
            std::process::id(),
            tag,
            NONCE.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn chain_enumerates_newest_to_oldest() {
        let mut s = BlobStore::memory(8);
        for slot in [0u64, 5, 10] {
            s.put(slot, &blob(slot), None).unwrap();
        }
        let chain = s.chain();
        assert_eq!(chain.len(), 3);
        assert_eq!(chain[0], ChainEntry { epoch: 2, slot: 10 });
        assert_eq!(chain[2], ChainEntry { epoch: 0, slot: 0 });
        assert_eq!(s.newest_slot(), Some(10));
        assert_eq!(s.load(&chain[0]).unwrap(), blob(10));
    }

    #[test]
    fn gc_honours_depth_and_pins_the_genesis_blob() {
        let mut s = BlobStore::memory(2);
        for slot in 0u64..6 {
            s.put(slot, &blob(slot), None).unwrap();
        }
        // retained: genesis (epoch 0) + newest 2 (epochs 4, 5); the
        // newest-valid pin coincides with epoch 5
        let epochs: Vec<u64> = s.chain().iter().map(|e| e.epoch).collect();
        assert_eq!(epochs, vec![5, 4, 0]);
    }

    #[test]
    fn gc_never_deletes_the_newest_valid_blob() {
        let mut s = BlobStore::memory(1);
        s.put(0, &blob(0), None).unwrap();
        s.put(5, &blob(5), None).unwrap();
        // two corrupt puts: the newest depth-1 window only covers the
        // corrupt tail, so the valid epoch-1 blob survives via the
        // newest-valid pin
        s.put(10, &blob(10), Some(StorageFault::Torn { seed: 7 })).unwrap();
        s.put(15, &blob(15), Some(StorageFault::BitFlip { seed: 99 })).unwrap();
        let chain = s.chain();
        let valid: Vec<u64> = chain
            .iter()
            .filter(|e| codec::verify(&s.load(e).unwrap()).is_ok())
            .map(|e| e.slot)
            .collect();
        assert!(valid.contains(&5), "newest valid blob was GC'd: chain {chain:?}");
        assert!(valid.contains(&0), "genesis blob was GC'd");
        // and the injected corruption is detectable, not silent
        let newest = s.load(&chain[0]).unwrap();
        assert!(codec::verify(&newest).is_err());
    }

    #[test]
    fn lost_renames_never_enter_the_chain() {
        let mut s = BlobStore::memory(4);
        s.put(0, &blob(0), None).unwrap();
        s.put(5, &blob(5), Some(StorageFault::LostRename)).unwrap();
        assert_eq!(s.newest_slot(), Some(0));
        assert_eq!(s.len(), 1);
        // the epoch number was still consumed: naming stays deterministic
        s.put(10, &blob(10), None).unwrap();
        assert_eq!(s.chain()[0], ChainEntry { epoch: 2, slot: 10 });
    }

    #[test]
    fn disk_store_persists_across_reopen() {
        let dir = tmpdir("reopen");
        {
            let mut s = BlobStore::open(&dir, 4).unwrap();
            s.put(0, &blob(0), None).unwrap();
            s.put(7, &blob(7), None).unwrap();
            s.put(14, &blob(14), Some(StorageFault::LostRename)).unwrap();
        }
        // the lost rename left a .tmp; reopen sweeps it and resumes the
        // epoch stream past every name ever claimed durably
        let s = BlobStore::open(&dir, 4).unwrap();
        let chain = s.chain();
        assert_eq!(chain.len(), 2);
        assert_eq!(chain[0], ChainEntry { epoch: 1, slot: 7 });
        assert_eq!(chain[1], ChainEntry { epoch: 0, slot: 0 });
        assert_eq!(s.load(&chain[0]).unwrap(), blob(7));
        let stray: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .filter(|f| {
                f.as_ref().unwrap().file_name().to_string_lossy().ends_with(".tmp")
            })
            .collect();
        assert!(stray.is_empty(), "reopen left stray tmp files");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_names_are_deterministic_and_sorted() {
        let dir = tmpdir("names");
        let mut s = BlobStore::open(&dir, 8).unwrap();
        for slot in [0u64, 3, 6] {
            s.put(slot, &blob(slot), None).unwrap();
        }
        let mut names: Vec<String> = fs::read_dir(&dir)
            .unwrap()
            .map(|f| f.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        assert_eq!(
            names,
            vec![
                "ckpt-e00000000-s00000000.plck",
                "ckpt-e00000001-s00000003.plck",
                "ckpt-e00000002-s00000006.plck",
            ]
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_and_flipped_blobs_fail_verification() {
        let mut s = BlobStore::memory(8);
        s.put(0, &blob(0), None).unwrap();
        s.put(1, &blob(1), Some(StorageFault::Torn { seed: 13 })).unwrap();
        s.put(2, &blob(2), Some(StorageFault::BitFlip { seed: 12345 })).unwrap();
        let chain = s.chain();
        assert!(codec::verify(&s.load(&chain[0]).unwrap()).is_err(), "bit flip undetected");
        assert!(codec::verify(&s.load(&chain[1]).unwrap()).is_err(), "torn write undetected");
        assert!(codec::verify(&s.load(&chain[2]).unwrap()).is_ok());
    }
}
