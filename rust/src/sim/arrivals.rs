//! Job-arrival models.  The paper "does not make any assumption on the
//! arrival patterns"; experiments drive Bernoulli(ρ) thinning over
//! trace-derived base intensities (Tab. 2's ρ), and the regret ablation
//! needs adversarial and bursty trajectories too.

use crate::utils::codec::{Reader, Writer};
use crate::utils::rng::Rng;

/// Serialize an RNG stream position into a checkpoint blob.
fn put_rng(w: &mut Writer, rng: &Rng) {
    w.put_u64s(&rng.state());
}

/// Rebuild an RNG stream position from [`put_rng`]'s bytes.
fn get_rng(r: &mut Reader) -> Result<Rng, String> {
    let s = r.get_u64s()?;
    if s.len() != 4 {
        return Err(format!("arrival snapshot: rng state len {}", s.len()));
    }
    Ok(Rng::from_state([s[0], s[1], s[2], s[3]]))
}

/// A source of per-slot arrival vectors x(t) ∈ ℝ^|L| (0/1 in the base
/// model; counts in the Sec. 3.4 extension).
pub trait ArrivalModel: Send {
    fn name(&self) -> &'static str;

    /// Fill `x` for the next slot.
    fn next(&mut self, x: &mut [f64]);

    fn reset(&mut self, _seed: u64) {}

    /// Serialize the stream position for a mid-run resume
    /// (`sim::checkpoint`).  Models write exactly what `next` consumes —
    /// RNG state, phase counters — so a restored model emits the same
    /// continuation the uninterrupted one would.  The default no-op is
    /// only correct for stateless models; every model in this module
    /// overrides it.
    fn snapshot(&self, w: &mut Writer) {
        let _ = w;
    }

    /// Rebuild from [`ArrivalModel::snapshot`] (default: nothing).
    fn restore(&mut self, r: &mut Reader) -> Result<(), String> {
        let _ = r;
        Ok(())
    }

    /// Streaming sources (`sim::ingest::StreamArrivals`): drain every
    /// in-flight ingest event into checkpointable batch state, then
    /// serialize cursor + batch + EWMA state as a sub-versioned
    /// section for the checkpoint blob appendix.  `None` (the default)
    /// for slot-synchronous models — the blob then records only the
    /// absence flag.
    fn ingest_checkpoint(&self) -> Option<Vec<u8>> {
        None
    }

    /// Rebuild from [`ArrivalModel::ingest_checkpoint`] bytes.  Models
    /// without ingest state reject the call: a blob carrying an ingest
    /// section must be thawed onto a streaming model.
    fn ingest_restore(&mut self, bytes: &[u8]) -> Result<(), String> {
        let _ = bytes;
        Err(format!("arrival model `{}` carries no ingest state", self.name()))
    }
}

/// i.i.d. Bernoulli(ρ_l) per port, ρ_l = ρ · w_l with per-port weights
/// from the trace (uniform weights by default).
pub struct Bernoulli {
    pub rho: f64,
    weights: Vec<f64>,
    rng: Rng,
    seed: u64,
}

impl Bernoulli {
    pub fn uniform(num_ports: usize, rho: f64, seed: u64) -> Self {
        Bernoulli { rho, weights: vec![1.0; num_ports], rng: Rng::new(seed), seed }
    }

    /// Trace-weighted: port l arrives w.p. clamp(ρ·w_l·|L|/Σw, 0, 1) so
    /// the *average* rate stays ρ while ports keep trace-shaped skew.
    pub fn weighted(weights: &[f64], rho: f64, seed: u64) -> Self {
        let mean = weights.iter().sum::<f64>() / weights.len().max(1) as f64;
        let norm: Vec<f64> =
            weights.iter().map(|w| if mean > 0.0 { w / mean } else { 1.0 }).collect();
        Bernoulli { rho, weights: norm, rng: Rng::new(seed), seed }
    }
}

impl ArrivalModel for Bernoulli {
    fn name(&self) -> &'static str {
        "bernoulli"
    }

    fn next(&mut self, x: &mut [f64]) {
        for (l, v) in x.iter_mut().enumerate() {
            let p = (self.rho * self.weights[l]).clamp(0.0, 1.0);
            *v = if self.rng.bernoulli(p) { 1.0 } else { 0.0 };
        }
    }

    fn reset(&mut self, seed: u64) {
        self.seed = seed;
        self.rng = Rng::new(seed);
    }

    fn snapshot(&self, w: &mut Writer) {
        put_rng(w, &self.rng);
    }

    fn restore(&mut self, r: &mut Reader) -> Result<(), String> {
        self.rng = get_rng(r)?;
        Ok(())
    }
}

/// Markov-modulated on/off bursts: each port flips between an active
/// phase (arrival prob `rho_on`) and an idle phase with the given
/// transition probabilities — diurnal burstiness of the real traces.
pub struct Bursty {
    rho_on: f64,
    p_on_off: f64,
    p_off_on: f64,
    state_on: Vec<bool>,
    rng: Rng,
}

impl Bursty {
    pub fn new(num_ports: usize, rho_on: f64, p_on_off: f64, p_off_on: f64,
               seed: u64) -> Self {
        Bursty {
            rho_on,
            p_on_off,
            p_off_on,
            state_on: vec![true; num_ports],
            rng: Rng::new(seed),
        }
    }
}

impl ArrivalModel for Bursty {
    fn name(&self) -> &'static str {
        "bursty"
    }

    fn next(&mut self, x: &mut [f64]) {
        for (l, v) in x.iter_mut().enumerate() {
            let on = self.state_on[l];
            let flip = self.rng.bernoulli(if on { self.p_on_off } else { self.p_off_on });
            let on = if flip { !on } else { on };
            self.state_on[l] = on;
            *v = if on && self.rng.bernoulli(self.rho_on) { 1.0 } else { 0.0 };
        }
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
        self.state_on.fill(true);
    }

    fn snapshot(&self, w: &mut Writer) {
        put_rng(w, &self.rng);
        w.put_bools(&self.state_on);
    }

    fn restore(&mut self, r: &mut Reader) -> Result<(), String> {
        self.rng = get_rng(r)?;
        let on = r.get_bools()?;
        if on.len() != self.state_on.len() {
            return Err(format!(
                "bursty snapshot: {} phases vs {} ports",
                on.len(),
                self.state_on.len()
            ));
        }
        self.state_on = on;
        Ok(())
    }
}

/// Adversarial-ish trajectory for the regret supremum (Eq. 11): phases
/// of length `phase` alternate between complementary port subsets, so a
/// stationary allocation keeps being wrong for half the horizon.
pub struct Alternating {
    phase: usize,
    t: usize,
}

impl Alternating {
    pub fn new(phase: usize) -> Self {
        Alternating { phase: phase.max(1), t: 0 }
    }
}

impl ArrivalModel for Alternating {
    fn name(&self) -> &'static str {
        "alternating"
    }

    fn next(&mut self, x: &mut [f64]) {
        let even_phase = (self.t / self.phase) % 2 == 0;
        for (l, v) in x.iter_mut().enumerate() {
            let in_even_half = l % 2 == 0;
            *v = if in_even_half == even_phase { 1.0 } else { 0.0 };
        }
        self.t += 1;
    }

    fn reset(&mut self, _seed: u64) {
        self.t = 0;
    }

    fn snapshot(&self, w: &mut Writer) {
        w.put_u64(self.t as u64);
    }

    fn restore(&mut self, r: &mut Reader) -> Result<(), String> {
        self.t = r.get_u64()? as usize;
        Ok(())
    }
}

/// Multi-arrival counts (Sec. 3.4): Poisson-ish via summed Bernoulli
/// micro-trials, capped at `max_jobs`.
pub struct MultiCount {
    rho: f64,
    max_jobs: usize,
    rng: Rng,
}

impl MultiCount {
    pub fn new(rho: f64, max_jobs: usize, seed: u64) -> Self {
        MultiCount { rho, max_jobs: max_jobs.max(1), rng: Rng::new(seed) }
    }
}

impl ArrivalModel for MultiCount {
    fn name(&self) -> &'static str {
        "multi-count"
    }

    fn next(&mut self, x: &mut [f64]) {
        for v in x.iter_mut() {
            let mut n = 0usize;
            for _ in 0..self.max_jobs {
                if self.rng.bernoulli(self.rho) {
                    n += 1;
                }
            }
            *v = n as f64;
        }
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
    }

    fn snapshot(&self, w: &mut Writer) {
        put_rng(w, &self.rng);
    }

    fn restore(&mut self, r: &mut Reader) -> Result<(), String> {
        self.rng = get_rng(r)?;
        Ok(())
    }
}

/// Replay a fixed trajectory (tests, recorded traces).
pub struct Replay {
    trajectory: Vec<Vec<f64>>,
    t: usize,
}

impl Replay {
    pub fn new(trajectory: Vec<Vec<f64>>) -> Self {
        Replay { trajectory, t: 0 }
    }
}

impl ArrivalModel for Replay {
    fn name(&self) -> &'static str {
        "replay"
    }

    fn next(&mut self, x: &mut [f64]) {
        let row = &self.trajectory[self.t % self.trajectory.len()];
        x.copy_from_slice(row);
        self.t += 1;
    }

    fn reset(&mut self, _seed: u64) {
        self.t = 0;
    }

    fn snapshot(&self, w: &mut Writer) {
        w.put_u64(self.t as u64);
    }

    fn restore(&mut self, r: &mut Reader) -> Result<(), String> {
        self.t = r.get_u64()? as usize;
        Ok(())
    }
}

/// Record a model's full trajectory up front (the regret oracle needs
/// the whole {x(t)} sequence).
pub fn record_trajectory(
    model: &mut dyn ArrivalModel,
    num_ports: usize,
    horizon: usize,
) -> Vec<Vec<f64>> {
    let mut out = Vec::with_capacity(horizon);
    let mut x = vec![0.0; num_ports];
    for _ in 0..horizon {
        model.next(&mut x);
        out.push(x.clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bernoulli_rate() {
        let mut m = Bernoulli::uniform(10, 0.7, 1);
        let mut x = vec![0.0; 10];
        let mut hits = 0.0;
        for _ in 0..5000 {
            m.next(&mut x);
            hits += x.iter().sum::<f64>();
        }
        let rate = hits / 50_000.0;
        assert!((rate - 0.7).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn weighted_bernoulli_keeps_mean_rate_and_skew() {
        let w = vec![3.0, 1.0, 1.0, 1.0];
        let mut m = Bernoulli::weighted(&w, 0.5, 2);
        let mut x = vec![0.0; 4];
        let mut per_port = vec![0.0; 4];
        for _ in 0..20_000 {
            m.next(&mut x);
            for l in 0..4 {
                per_port[l] += x[l];
            }
        }
        let mean: f64 = per_port.iter().sum::<f64>() / (4.0 * 20_000.0);
        assert!((mean - 0.5).abs() < 0.03, "mean={mean}");
        assert!(per_port[0] > per_port[1] * 1.5, "skew lost: {per_port:?}");
    }

    #[test]
    fn reset_reproduces() {
        let mut m = Bernoulli::uniform(5, 0.6, 42);
        let mut a = vec![0.0; 5];
        let mut b = vec![0.0; 5];
        m.next(&mut a);
        m.reset(42);
        m.next(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn alternating_flips_subsets() {
        let mut m = Alternating::new(2);
        let mut x = vec![0.0; 4];
        m.next(&mut x);
        assert_eq!(x, vec![1.0, 0.0, 1.0, 0.0]);
        m.next(&mut x);
        assert_eq!(x, vec![1.0, 0.0, 1.0, 0.0]);
        m.next(&mut x); // phase boundary
        assert_eq!(x, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn multi_count_bounded() {
        let mut m = MultiCount::new(0.5, 4, 3);
        let mut x = vec![0.0; 8];
        for _ in 0..100 {
            m.next(&mut x);
            assert!(x.iter().all(|&v| (0.0..=4.0).contains(&v)));
        }
    }

    #[test]
    fn replay_and_record_roundtrip() {
        let mut src = Alternating::new(1);
        let traj = record_trajectory(&mut src, 3, 5);
        let mut rep = Replay::new(traj.clone());
        let mut x = vec![0.0; 3];
        for t in 0..5 {
            rep.next(&mut x);
            assert_eq!(x, traj[t]);
        }
    }

    #[test]
    fn snapshots_resume_every_model_bit_identically() {
        // (live model, fresh same-constructed model) pairs: snapshot the
        // live one mid-stream, restore onto the fresh one, and the
        // continuations must agree to the bit.
        let pairs: Vec<(Box<dyn ArrivalModel>, Box<dyn ArrivalModel>)> = vec![
            (
                Box::new(Bernoulli::uniform(6, 0.6, 11)),
                Box::new(Bernoulli::uniform(6, 0.6, 11)),
            ),
            (
                Box::new(Bursty::new(6, 0.8, 0.1, 0.2, 13)),
                Box::new(Bursty::new(6, 0.8, 0.1, 0.2, 13)),
            ),
            (Box::new(Alternating::new(3)), Box::new(Alternating::new(3))),
            (
                Box::new(MultiCount::new(0.4, 3, 17)),
                Box::new(MultiCount::new(0.4, 3, 17)),
            ),
            (
                Box::new(Replay::new(vec![vec![1.0; 6], vec![0.0; 6], vec![1.0; 6]])),
                Box::new(Replay::new(vec![vec![1.0; 6], vec![0.0; 6], vec![1.0; 6]])),
            ),
        ];
        for (mut live, mut fresh) in pairs {
            let mut x = vec![0.0; 6];
            for _ in 0..13 {
                live.next(&mut x);
            }
            let mut w = crate::utils::codec::Writer::new();
            live.snapshot(&mut w);
            let bytes = w.finish();
            let mut r = crate::utils::codec::Reader::new(&bytes).unwrap();
            fresh.restore(&mut r).unwrap();
            r.finish().unwrap();
            let mut got = vec![0.0; 6];
            for t in 0..20 {
                live.next(&mut x);
                fresh.next(&mut got);
                assert_eq!(x, got, "{} diverged at resumed slot {t}", live.name());
            }
        }
    }

    #[test]
    fn bursty_produces_runs() {
        let mut m = Bursty::new(1, 0.9, 0.05, 0.05, 7);
        let mut x = vec![0.0];
        let mut flips = 0;
        let mut prev = 1.0;
        let mut ones = 0.0;
        for _ in 0..2000 {
            m.next(&mut x);
            if x[0] != prev {
                flips += 1;
            }
            prev = x[0];
            ones += x[0];
        }
        // bursty: far fewer transitions than a fair coin would have
        assert!(flips < 900, "flips={flips}");
        assert!(ones > 100.0);
    }
}
