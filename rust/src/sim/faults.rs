//! Fault injection and elastic topology (the robustness layer).
//!
//! A [`FaultPlan`] is a seeded, deterministic stream of topology events
//! — instance crashes/recoveries, port-class departures/arrivals, and
//! correlated rack bursts — scheduled against slot indices.  The driver
//! [`run_churned`] plays a plan against the coordinator: the horizon is
//! cut into segments at the event slots, each segment runs on the
//! current topology *edition*, and between segments the problem mutates
//! (incrementally, or rebuilt from scratch — the two parity arms), the
//! ledger masks the failed instances, policies carry their learned
//! state across via [`Policy::remap`], and the sharded path refreshes
//! its [`ShardPlan`], re-running LPT only when the load imbalance
//! crosses the configured threshold (the re-plan epoch rule).
//!
//! **Churn parity is the pinned contract** (`tests/churn_parity.rs`):
//! a churned run in which every edition is produced by incremental
//! apply/undo must equal — bitwise, on records, ledgers and decisions —
//! the same run in which every edition is rebuilt from scratch, across
//! worker budgets.  The mechanism: the vertex id spaces never change
//! (only the edge set does), both arms share this one driver and differ
//! *only* in how the post-churn `Problem`/plan are produced, every
//! edition bumps `Problem::generation` so the sparse publishers'
//! identity goes stale and the first post-churn decide is a
//! conservative full publish (⇒ full-sweep ledger resync), and
//! sharded ≡ serial for *any* plan (the §Perf-3 invariant), so arms
//! arriving at different shard plans still agree bit for bit.
//!
//! Graceful degradation is structural: a failed instance's channels are
//! removed from the edge-major CSR, so no decision coordinate on a dead
//! edge can even be represented — policies cannot allocate onto a
//! failed instance, and their surviving coordinates carry over by
//! `(l, r)` key.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::config::{FaultConfig, RecoveryConfig, Scenario};
use crate::coordinator::{
    ClusterState, Leader, RunResult, ShardLedger, ShardPlan, ShardedLeader,
};
use crate::graph::Bipartite;
use crate::model::Problem;
use crate::obs;
use crate::schedulers::Policy;
use crate::sim::arrivals::{ArrivalModel, Bernoulli};
use crate::sim::store::StorageFault;
use crate::traces::synthesize;
use crate::utils::pool::ExecProbe;
use crate::utils::rng::Rng;

/// One topology event, applied at a slot boundary (before the slot's
/// arrivals are drawn).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultEvent {
    /// Instance crash: its channels vanish, its capacity masks to zero.
    InstanceFail(usize),
    /// Instance recovery: its surviving channels (against non-departed
    /// ports) are restored.
    InstanceRecover(usize),
    /// Port-class departure: its channels vanish and its arrivals are
    /// gated to zero.
    PortDepart(usize),
    /// Port-class arrival: channels against non-failed instances return.
    PortArrive(usize),
}

/// A deterministic fault event stream: `(slot, event)` pairs in
/// ascending slot order (events within a slot keep generation order —
/// recoveries first, then bursts, crashes, departures).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    events: Vec<(usize, FaultEvent)>,
}

impl FaultPlan {
    /// Generate the event stream for `horizon` slots over `l_n` ports
    /// and `r_n` instances.  Deterministic in `cfg.seed`; the generator
    /// never fails the last alive instance and never departs the last
    /// active port, so every edition keeps at least one live channel
    /// candidate on each side.
    pub fn generate(l_n: usize, r_n: usize, horizon: usize, cfg: &FaultConfig) -> FaultPlan {
        let mut rng = Rng::new(cfg.seed);
        let mut alive = vec![true; r_n];
        let mut active = vec![true; l_n];
        let mut alive_n = r_n;
        let mut active_n = l_n;
        let mut events = Vec::new();
        for t in 1..horizon {
            // recoveries first (ascending id, so the order is stable)
            for (r, a) in alive.iter_mut().enumerate() {
                if !*a && rng.bernoulli(cfg.recover_rate) {
                    *a = true;
                    alive_n += 1;
                    events.push((t, FaultEvent::InstanceRecover(r)));
                }
            }
            for (l, a) in active.iter_mut().enumerate() {
                if !*a && rng.bernoulli(cfg.recover_rate) {
                    *a = true;
                    active_n += 1;
                    events.push((t, FaultEvent::PortArrive(l)));
                }
            }
            // correlated rack burst: a contiguous block of alive
            // instances fails together
            if rng.bernoulli(cfg.rack_rate) {
                let start = rng.below(r_n);
                let mut felled = 0;
                for i in 0..r_n {
                    if felled >= cfg.rack_size || alive_n <= 1 {
                        break;
                    }
                    let r = (start + i) % r_n;
                    if alive[r] {
                        alive[r] = false;
                        alive_n -= 1;
                        felled += 1;
                        events.push((t, FaultEvent::InstanceFail(r)));
                    }
                }
            }
            // single instance crash
            if rng.bernoulli(cfg.instance_rate) && alive_n > 1 {
                let pick = rng.below(alive_n);
                let r = alive
                    .iter()
                    .enumerate()
                    .filter(|(_, &a)| a)
                    .nth(pick)
                    .map(|(r, _)| r)
                    .expect("alive_n tracks the alive mask");
                alive[r] = false;
                alive_n -= 1;
                events.push((t, FaultEvent::InstanceFail(r)));
            }
            // port-class departure
            if rng.bernoulli(cfg.port_rate) && active_n > 1 {
                let pick = rng.below(active_n);
                let l = active
                    .iter()
                    .enumerate()
                    .filter(|(_, &a)| a)
                    .nth(pick)
                    .map(|(l, _)| l)
                    .expect("active_n tracks the active mask");
                active[l] = false;
                active_n -= 1;
                events.push((t, FaultEvent::PortDepart(l)));
            }
        }
        FaultPlan { events }
    }

    /// Generate against a problem's dimensions.
    pub fn for_problem(problem: &Problem, horizon: usize, cfg: &FaultConfig) -> FaultPlan {
        FaultPlan::generate(problem.num_ports(), problem.num_instances(), horizon, cfg)
    }

    /// Build a plan from an explicit `(slot, event)` stream (the parity
    /// and degenerate-topology suites script exact failure choreography
    /// this way).  Slots should ascend; the driver tolerates (clamps)
    /// out-of-order slots but applies them late.
    pub fn from_events(events: Vec<(usize, FaultEvent)>) -> FaultPlan {
        FaultPlan { events }
    }

    /// The `(slot, event)` stream, ascending by slot.
    pub fn events(&self) -> &[(usize, FaultEvent)] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of distinct slots with at least one event.
    pub fn num_edition_slots(&self) -> usize {
        let mut n = 0;
        let mut last = usize::MAX;
        for &(t, _) in &self.events {
            if t != last {
                n += 1;
                last = t;
            }
        }
        n
    }
}

/// A seeded, deterministic stream of *execution* faults — crashes of
/// the machinery that runs the simulation, as opposed to the
/// [`FaultPlan`]'s crashes of the simulated cluster.  Three layers:
///
/// * **worker faults** (`panics`, `stalls`): at `(slot, shard)` the
///   commit task panics at entry — or sleeps past the watchdog deadline
///   first — is caught by the pool's panic isolation, and is retried
///   inline.  Fired *before* any write, so retries never change floats.
/// * **checkpoint-write failures** (`ckpt_fails`): the snapshot due at
///   that slot is dropped; recovery then reaches further back.
/// * **process kills** (`kills`): at the slot boundary the resilient
///   driver discards all live state and restores from the last durable
///   checkpoint (`sim::checkpoint::run_resilient`).
/// * **storage faults** (`torn_writes`, `bit_flips`, `lost_renames`,
///   §SStore): the checkpoint write at that slot reaches the store but
///   the *persisted* bytes are damaged (truncated / one bit flipped) or
///   the atomic rename is lost entirely; recovery must detect the
///   damage via the PLCK v3 checksums and fall back along the chain
///   (`sim::store::BlobStore`).
#[derive(Clone, Debug, Default)]
pub struct ExecFaultPlan {
    /// Worker panics at `(slot, shard)`, one-shot each.
    pub panics: BTreeSet<(u64, u32)>,
    /// Worker stalls at `(slot, shard)`, one-shot each.
    pub stalls: BTreeSet<(u64, u32)>,
    /// Slots whose checkpoint write fails.
    pub ckpt_fails: BTreeSet<u64>,
    /// Ascending, distinct process-kill slots (the kill fires at the
    /// boundary *before* the slot runs).
    pub kills: Vec<u64>,
    /// Injected stall duration (ms).
    pub stall_ms: u64,
    /// Storage faults (§SStore): checkpoint writes at these slots are
    /// torn — only the first `seed % len` bytes reach the store.
    pub torn_writes: BTreeMap<u64, u64>,
    /// Storage faults: one bit (`seed % (len * 8)`) of the persisted
    /// blob is flipped.
    pub bit_flips: BTreeMap<u64, u64>,
    /// Storage faults: the blob's temp file is written but the rename
    /// never lands — the chain gains no entry at this slot.
    pub lost_renames: BTreeSet<u64>,
}

impl ExecFaultPlan {
    /// Generate the stream for `horizon` slots against a `shards`-wide
    /// commit scatter.  Deterministic in `cfg.seed`; slot 0 is never
    /// targeted (the implicit initial checkpoint must exist before the
    /// first kill, and slot 0's scatter precedes any fault window).
    pub fn generate(horizon: usize, shards: usize, cfg: &RecoveryConfig) -> ExecFaultPlan {
        let mut rng = Rng::new(cfg.seed);
        let shards = shards.max(1);
        let mut plan = ExecFaultPlan { stall_ms: cfg.stall_ms, ..Default::default() };
        for t in 1..horizon as u64 {
            if rng.bernoulli(cfg.panic_rate) {
                plan.panics.insert((t, rng.below(shards) as u32));
            }
            if rng.bernoulli(cfg.stall_rate) {
                plan.stalls.insert((t, rng.below(shards) as u32));
            }
            if rng.bernoulli(cfg.ckpt_fail_rate) {
                plan.ckpt_fails.insert(t);
            }
            if rng.bernoulli(cfg.kill_rate) {
                plan.kills.push(t);
            }
            // Storage faults draw *after* the execution categories and
            // only when their rate is armed, so every pre-§SStore
            // stream (all storage rates zero) is reproduced bit-exactly
            // by the same seed.
            if cfg.torn_write_rate > 0.0 && rng.bernoulli(cfg.torn_write_rate) {
                plan.torn_writes.insert(t, rng.next_u64());
            }
            if cfg.bit_flip_rate > 0.0 && rng.bernoulli(cfg.bit_flip_rate) {
                plan.bit_flips.insert(t, rng.next_u64());
            }
            if cfg.lost_rename_rate > 0.0 && rng.bernoulli(cfg.lost_rename_rate) {
                plan.lost_renames.insert(t);
            }
        }
        plan
    }

    /// The storage fault scheduled at `slot`, if any.  Lost renames
    /// shadow torn writes shadow bit flips when a hand-built plan
    /// stacks several on one slot (generated plans may too; the
    /// precedence is part of the deterministic contract).
    pub fn storage_fault_at(&self, slot: u64) -> Option<StorageFault> {
        if self.lost_renames.contains(&slot) {
            return Some(StorageFault::LostRename);
        }
        if let Some(&seed) = self.torn_writes.get(&slot) {
            return Some(StorageFault::Torn { seed });
        }
        if let Some(&seed) = self.bit_flips.get(&slot) {
            return Some(StorageFault::BitFlip { seed });
        }
        None
    }

    /// The pool-side half of the plan: a shared probe the leaders arm,
    /// which fires (and disarms) each injected panic/stall exactly once.
    pub fn probe(&self) -> Arc<ExecProbe> {
        Arc::new(ExecProbe::new(self.panics.clone(), self.stalls.clone(), self.stall_ms))
    }

    pub fn is_empty(&self) -> bool {
        self.panics.is_empty()
            && self.stalls.is_empty()
            && self.ckpt_fails.is_empty()
            && self.kills.is_empty()
            && self.torn_writes.is_empty()
            && self.bit_flips.is_empty()
            && self.lost_renames.is_empty()
    }
}

/// Masks departed ports' arrivals to zero.  The inner model's RNG
/// advances identically whether or not a port is active, so churned and
/// from-scratch parity arms — and runs under different fault plans over
/// the same workload seed — all see the same underlying stream.
pub struct Gated<'a> {
    pub inner: &'a mut dyn ArrivalModel,
    pub active: &'a [bool],
}

impl ArrivalModel for Gated<'_> {
    fn name(&self) -> &'static str {
        "gated"
    }

    fn next(&mut self, x: &mut [f64]) {
        self.inner.next(x);
        for (l, v) in x.iter_mut().enumerate() {
            if !self.active[l] {
                *v = 0.0;
            }
        }
    }

    fn reset(&mut self, seed: u64) {
        self.inner.reset(seed);
    }
}

/// Outcome of a churned run: the concatenated per-slot series plus the
/// churn bookkeeping the figures and the parity suite inspect.
pub struct ChurnOutcome {
    pub result: RunResult,
    /// Final cluster ledger (parity suite compares `remaining_at`).
    pub state: ClusterState,
    /// Final topology edition.
    pub problem: Problem,
    /// Topology editions applied (event slots that changed the graph).
    pub editions: usize,
    /// Full LPT re-plans triggered by the imbalance threshold
    /// (incremental arm only; the rebuild arm always re-plans).
    pub replans: usize,
    /// Individual fault events applied.
    pub events: usize,
}

/// Drive `policy` over `horizon` slots under the fault plan.
///
/// `shards == 1` runs the serial [`Leader`]; any other value runs the
/// [`ShardedLeader`] (0 = auto-sized plan).  `rebuild` selects the
/// parity arm: `false` mutates the problem incrementally
/// (`Problem::remove_instance_edges` / `restore_edges`) and refreshes
/// the shard plan under the re-plan epoch rule; `true` rebuilds problem
/// and plan from scratch at every edition.  Both arms are driven by
/// this one function — everything else (segments, ledger carry, policy
/// remap, arrival gating) is shared, which is what makes the bitwise
/// churn parity contract testable rather than aspirational.
pub fn run_churned(
    base: &Problem,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalModel,
    horizon: usize,
    shards: usize,
    plan: &FaultPlan,
    cfg: &FaultConfig,
    rebuild: bool,
) -> Result<ChurnOutcome, String> {
    let l_n = base.num_ports();
    let r_n = base.num_instances();
    // the original channel set: recovery restores into it, never beyond
    let e0: Vec<(usize, usize)> = (0..base.num_edges())
        .map(|e| (base.graph.edge_port[e], base.graph.edge_instance[e]))
        .collect();
    let mut failed = vec![false; r_n];
    let mut departed = vec![false; l_n];
    let mut active = vec![true; l_n];

    let mut cur = base.clone();
    let serial = shards == 1;
    let mut state = ClusterState::new(&cur);
    let mut cur_plan: Option<Arc<ShardPlan>> =
        (!serial).then(|| Arc::new(ShardPlan::build(&cur, shards)));
    let mut carry: Option<(Arc<ShardPlan>, Vec<ShardLedger>)> = None;

    let mut result = RunResult {
        policy: policy.name().to_string(),
        records: Vec::with_capacity(horizon),
        ..Default::default()
    };
    let mut editions = 0usize;
    let mut replans = 0usize;
    let mut events_applied = 0usize;

    let mut cursor = 0usize;
    let mut next_event = 0usize; // index into plan.events
    while cursor < horizon {
        let seg_end = plan
            .events
            .get(next_event)
            .map(|&(t, _)| t.clamp(cursor, horizon))
            .unwrap_or(horizon);
        // run the segment [cursor, seg_end) on the current edition
        {
            let mut gated = Gated { inner: &mut *arrivals, active: &active };
            let seg = if serial {
                let mut leader = Leader::resume(&cur, state);
                let seg = leader.run(policy, &mut gated, seg_end - cursor);
                state = leader.into_state();
                seg
            } else {
                let plan_arc = cur_plan.as_ref().expect("sharded path has a plan");
                let mut leader =
                    ShardedLeader::resume(&cur, Arc::clone(plan_arc), state, carry.take());
                let seg = leader.run(policy, &mut gated, seg_end - cursor);
                let (s, p, ledgers) = leader.into_parts();
                state = s;
                carry = Some((p, ledgers));
                seg
            };
            result.clamped_total += seg.clamped_total;
            result.cumulative_reward += seg.cumulative_reward;
            result.elapsed_secs += seg.elapsed_secs;
            for mut rec in seg.records {
                rec.t += cursor; // segment-local t → run-global t
                result.records.push(rec);
            }
        }
        cursor = seg_end;
        if cursor >= horizon {
            break;
        }

        // apply every event scheduled at this slot, in stream order;
        // masks update per event so restore sets see in-order liveness
        let old_graph = cur.graph.clone();
        let mut touched = false;
        while let Some(&(t, ev)) = plan.events.get(next_event) {
            if t > cursor {
                break;
            }
            next_event += 1;
            events_applied += 1;
            let entity = match ev {
                FaultEvent::InstanceFail(r) | FaultEvent::InstanceRecover(r) => r,
                FaultEvent::PortDepart(l) | FaultEvent::PortArrive(l) => l,
            };
            obs::event(obs::SpanKind::FaultTopology, t as u64, entity as u32, editions as u32);
            let ctx = |e: String| format!("fault event at slot {t}: {e}");
            match ev {
                FaultEvent::InstanceFail(r) => {
                    if r >= r_n {
                        return Err(ctx(format!("instance {r} out of range (R={r_n})")));
                    }
                    failed[r] = true;
                    state.fail_instance(r, cfg.release).map_err(&ctx)?;
                    if !rebuild {
                        cur.remove_instance_edges(r).map_err(&ctx)?;
                    }
                    touched = true;
                }
                FaultEvent::InstanceRecover(r) => {
                    if r >= r_n {
                        return Err(ctx(format!("instance {r} out of range (R={r_n})")));
                    }
                    failed[r] = false;
                    state.recover_instance(r).map_err(&ctx)?;
                    if !rebuild {
                        let back: Vec<(usize, usize)> = e0
                            .iter()
                            .copied()
                            .filter(|&(l, rr)| rr == r && !departed[l])
                            .collect();
                        cur.restore_edges(&back).map_err(&ctx)?;
                    }
                    touched = true;
                }
                FaultEvent::PortDepart(l) => {
                    if l >= l_n {
                        return Err(ctx(format!("port {l} out of range (L={l_n})")));
                    }
                    departed[l] = true;
                    active[l] = false;
                    if !rebuild {
                        cur.remove_port_edges(l).map_err(&ctx)?;
                    }
                    touched = true;
                }
                FaultEvent::PortArrive(l) => {
                    if l >= l_n {
                        return Err(ctx(format!("port {l} out of range (L={l_n})")));
                    }
                    departed[l] = false;
                    active[l] = true;
                    if !rebuild {
                        let back: Vec<(usize, usize)> = e0
                            .iter()
                            .copied()
                            .filter(|&(ll, r)| ll == l && !failed[r])
                            .collect();
                        cur.restore_edges(&back).map_err(&ctx)?;
                    }
                    touched = true;
                }
            }
        }
        if !touched {
            continue;
        }
        editions += 1;
        if rebuild {
            // the from-scratch arm: live edges of the original channel
            // set, built as if the edition were day-one topology
            let live: Vec<(usize, usize)> = e0
                .iter()
                .copied()
                .filter(|&(l, r)| !departed[l] && !failed[r])
                .collect();
            cur = Problem::new(
                Bipartite::from_edges(l_n, r_n, &live),
                cur.num_resources,
                cur.demand.clone(),
                cur.capacity.clone(),
                cur.alpha.clone(),
                cur.kind.clone(),
                cur.beta.clone(),
            );
        }
        if cfg!(debug_assertions) {
            // graceful degradation is structural: a dead vertex keeps
            // no channels in the new edition
            for (r, &f) in failed.iter().enumerate() {
                assert!(
                    !f || cur.graph.instance_degree(r) == 0,
                    "failed instance {r} still has channels at slot {cursor}"
                );
            }
            for (l, &d) in departed.iter().enumerate() {
                assert!(
                    !d || cur.graph.port_edges(l).len() == 0,
                    "departed port {l} still has channels at slot {cursor}"
                );
            }
        }
        // carry the policy's learned state across the edition
        policy.remap(&old_graph, &cur);
        // re-plan epoch rule (sharded path)
        if let Some(plan_arc) = &mut cur_plan {
            if rebuild {
                *plan_arc = Arc::new(ShardPlan::build(&cur, shards));
            } else {
                let refreshed = plan_arc
                    .refresh(&cur)
                    .map_err(|e| format!("fault replan at slot {cursor}: {e}"))?;
                if refreshed.imbalance() > cfg.replan_threshold {
                    *plan_arc = Arc::new(ShardPlan::build(&cur, shards));
                    replans += 1;
                    obs::event(obs::SpanKind::Replan, cursor as u64, 0, editions as u32);
                } else {
                    *plan_arc = Arc::new(refreshed);
                }
            }
        }
    }

    Ok(ChurnOutcome {
        result,
        state,
        problem: cur,
        editions,
        replans,
        events: events_applied,
    })
}

/// Scenario-level convenience: synthesize the problem, generate the
/// fault plan from `scenario.faults`, and run one policy under churn
/// with the scenario's Bernoulli arrivals and shard budget.
pub fn run_churned_scenario(
    scenario: &Scenario,
    policy: &mut dyn Policy,
    rebuild: bool,
) -> Result<ChurnOutcome, String> {
    let problem = synthesize(scenario);
    let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
    let mut arrivals = Bernoulli::uniform(
        problem.num_ports(),
        scenario.arrival_prob,
        scenario.seed ^ 0xA5A5,
    );
    policy.reset(&problem);
    run_churned(
        &problem,
        policy,
        &mut arrivals,
        scenario.horizon,
        scenario.parallel.shards,
        &plan,
        &scenario.faults,
        rebuild,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedulers::{Fairness, OgaSched};
    use crate::utils::pool::ExecBudget;

    fn churny() -> FaultConfig {
        FaultConfig {
            instance_rate: 0.05,
            recover_rate: 0.2,
            port_rate: 0.03,
            rack_rate: 0.01,
            rack_size: 3,
            ..FaultConfig::default()
        }
    }

    #[test]
    fn fault_plan_is_deterministic_and_bounded() {
        let cfg = churny();
        let a = FaultPlan::generate(4, 16, 300, &cfg);
        let b = FaultPlan::generate(4, 16, 300, &cfg);
        assert_eq!(a.events(), b.events());
        assert!(!a.is_empty(), "churny config must inject something in 300 slots");
        // slots ascend and all ids are in range
        let mut last = 0;
        for &(t, ev) in a.events() {
            assert!(t >= last && t < 300);
            last = t;
            match ev {
                FaultEvent::InstanceFail(r) | FaultEvent::InstanceRecover(r) => {
                    assert!(r < 16)
                }
                FaultEvent::PortDepart(l) | FaultEvent::PortArrive(l) => assert!(l < 4),
            }
        }
        // replaying the mask evolution: never all-dead, never all-departed
        let mut alive = vec![true; 16];
        let mut active = vec![true; 4];
        for &(_, ev) in a.events() {
            match ev {
                FaultEvent::InstanceFail(r) => alive[r] = false,
                FaultEvent::InstanceRecover(r) => alive[r] = true,
                FaultEvent::PortDepart(l) => active[l] = false,
                FaultEvent::PortArrive(l) => active[l] = true,
            }
            assert!(alive.iter().any(|&a| a), "last instance was failed");
            assert!(active.iter().any(|&a| a), "last port was departed");
        }
        let different = FaultPlan::generate(4, 16, 300, &FaultConfig { seed: 78, ..cfg });
        assert_ne!(a.events(), different.events());
    }

    #[test]
    fn exec_fault_plan_is_deterministic_and_never_targets_slot_zero() {
        let cfg = RecoveryConfig {
            checkpoint_epoch: 5,
            panic_rate: 0.1,
            stall_rate: 0.05,
            kill_rate: 0.05,
            ckpt_fail_rate: 0.2,
            ..RecoveryConfig::default()
        };
        let a = ExecFaultPlan::generate(200, 4, &cfg);
        let b = ExecFaultPlan::generate(200, 4, &cfg);
        assert_eq!(a.panics, b.panics);
        assert_eq!(a.stalls, b.stalls);
        assert_eq!(a.ckpt_fails, b.ckpt_fails);
        assert_eq!(a.kills, b.kills);
        assert!(!a.is_empty());
        assert!(a.panics.iter().all(|&(t, s)| t >= 1 && t < 200 && s < 4));
        assert!(a.kills.iter().all(|&t| t >= 1));
        assert!(a.kills.windows(2).all(|w| w[0] < w[1]), "kills must ascend");
        let c = ExecFaultPlan::generate(200, 4, &RecoveryConfig { seed: 999, ..cfg });
        assert_ne!(a.kills, c.kills);
        // the probe half carries exactly the worker faults
        let probe = a.probe();
        assert_eq!(probe.fired_count(), 0);
        // Arming the §SStore rates must not disturb the execution
        // streams: the storage draws happen after the four execution
        // categories, so the same seed reproduces panics/kills exactly.
        let stormy = RecoveryConfig {
            torn_write_rate: 0.3,
            bit_flip_rate: 0.3,
            lost_rename_rate: 0.2,
            ..cfg
        };
        let s1 = ExecFaultPlan::generate(200, 4, &stormy);
        let s2 = ExecFaultPlan::generate(200, 4, &stormy);
        assert_eq!(s1.panics, a.panics, "storage draws shifted the panic stream");
        assert_eq!(s1.stalls, a.stalls);
        assert_eq!(s1.ckpt_fails, a.ckpt_fails);
        assert_eq!(s1.kills, a.kills);
        assert_eq!(s1.torn_writes, s2.torn_writes);
        assert_eq!(s1.bit_flips, s2.bit_flips);
        assert_eq!(s1.lost_renames, s2.lost_renames);
        assert!(!s1.torn_writes.is_empty());
        assert!(!s1.bit_flips.is_empty());
        assert!(!s1.lost_renames.is_empty());
        assert!(s1.torn_writes.keys().all(|&t| t >= 1 && t < 200));
        assert!(s1.bit_flips.keys().all(|&t| t >= 1 && t < 200));
        assert!(s1.lost_renames.iter().all(|&t| t >= 1 && t < 200));
    }

    #[test]
    fn storage_fault_lookup_honours_the_precedence_order() {
        let mut plan = ExecFaultPlan::default();
        plan.torn_writes.insert(3, 7);
        plan.bit_flips.insert(3, 9);
        plan.bit_flips.insert(4, 11);
        plan.lost_renames.insert(3);
        assert!(matches!(plan.storage_fault_at(3), Some(StorageFault::LostRename)));
        plan.lost_renames.clear();
        assert!(matches!(plan.storage_fault_at(3), Some(StorageFault::Torn { seed: 7 })));
        assert!(matches!(plan.storage_fault_at(4), Some(StorageFault::BitFlip { seed: 11 })));
        assert_eq!(plan.storage_fault_at(5), None);
        assert!(!plan.is_empty());
    }

    #[test]
    fn gated_arrivals_zero_departed_ports_without_desyncing() {
        let mut inner_a = Bernoulli::uniform(6, 0.9, 3);
        let mut inner_b = Bernoulli::uniform(6, 0.9, 3);
        let active = vec![true, false, true, true, false, true];
        let mut gated = Gated { inner: &mut inner_a, active: &active };
        let all = vec![true; 6];
        let mut open = Gated { inner: &mut inner_b, active: &all };
        let mut x = vec![0.0; 6];
        let mut y = vec![0.0; 6];
        for _ in 0..50 {
            gated.next(&mut x);
            open.next(&mut y);
            for l in 0..6 {
                if active[l] {
                    assert_eq!(x[l], y[l], "gating desynced the stream at port {l}");
                } else {
                    assert_eq!(x[l], 0.0);
                }
            }
        }
    }

    #[test]
    fn churned_run_applies_events_and_degrades_gracefully() {
        let scenario = {
            let mut s = Scenario::small();
            s.horizon = 120;
            s.faults = churny();
            s
        };
        let out = run_churned_scenario(&scenario, &mut Fairness::new(), false).unwrap();
        assert_eq!(out.result.records.len(), 120);
        // records carry run-global slot indices after concatenation
        for (t, rec) in out.result.records.iter().enumerate() {
            assert_eq!(rec.t, t);
        }
        assert!(out.events > 0, "churny config produced no events");
        assert!(out.editions > 0);
        assert_eq!(out.result.clamped_total, 0);
    }

    #[test]
    fn incremental_and_rebuild_arms_agree_smoke() {
        // the full matrix (policies x budgets x seeds) lives in
        // tests/churn_parity.rs; this is the in-crate seam check
        let scenario = {
            let mut s = Scenario::small();
            s.horizon = 100;
            s.faults = churny();
            s
        };
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let run = |rebuild: bool, shards: usize| {
            let mut pol = OgaSched::new(&problem, 2.0, 0.999, ExecBudget::serial());
            pol.reset(&problem);
            let mut arr = Bernoulli::uniform(problem.num_ports(), 0.7, 11);
            run_churned(
                &problem,
                &mut pol,
                &mut arr,
                scenario.horizon,
                shards,
                &plan,
                &scenario.faults,
                rebuild,
            )
            .unwrap()
        };
        let inc = run(false, 1);
        let reb = run(true, 1);
        assert_eq!(inc.result.cumulative_reward, reb.result.cumulative_reward);
        for (a, b) in inc.result.records.iter().zip(&reb.result.records) {
            assert_eq!((a.t, a.q, a.gain, a.penalty), (b.t, b.q, b.gain, b.penalty));
        }
        let sharded = run(false, 3);
        assert_eq!(sharded.result.cumulative_reward, inc.result.cumulative_reward);
        for r in 0..problem.num_instances() {
            for k in 0..problem.num_resources {
                assert_eq!(
                    inc.state.remaining_at(r, k),
                    reb.state.remaining_at(r, k),
                    "ledger diverged at ({r},{k})"
                );
                assert_eq!(
                    inc.state.remaining_at(r, k),
                    sharded.state.remaining_at(r, k),
                    "sharded ledger diverged at ({r},{k})"
                );
            }
        }
    }

    #[test]
    fn fault_errors_name_slot_and_vertex() {
        let scenario = Scenario::small();
        let problem = synthesize(&scenario);
        let plan = FaultPlan {
            events: vec![(5, FaultEvent::InstanceFail(999))],
        };
        let mut pol = Fairness::new();
        let mut arr = Bernoulli::uniform(problem.num_ports(), 0.5, 1);
        let err = run_churned(
            &problem,
            &mut pol,
            &mut arr,
            20,
            1,
            &plan,
            &scenario.faults,
            false,
        )
        .unwrap_err();
        assert!(err.contains("slot 5"), "{err}");
        assert!(err.contains("instance 999"), "{err}");
    }
}
