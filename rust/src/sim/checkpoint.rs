//! Deterministic checkpoints and the kill-and-resume driver (the
//! crash-resilience layer on top of `sim::faults`).
//!
//! A [`Checkpoint`] is a self-contained binary snapshot (`utils::codec`
//! framing — magic, version, bounds-checked sections, `f64::to_bits`
//! floats) of everything a churned run needs to continue mid-horizon.
//! **Blob v3** (§SStore) frames that state as named, CRC-tagged
//! sections plus a whole-blob trailer checksum, in this order:
//!
//! | section    | payload |
//! |------------|---------|
//! | `driver`   | cursor, fault-stream position, edition/replan/event counters, policy name, reward accumulators |
//! | `records`  | the concatenated [`SlotRecord`]s |
//! | `masks`    | failed / departed / active liveness masks |
//! | `ledger`   | the cluster ledger (`ClusterState::snapshot`) |
//! | `policy`   | the policy's learned state ([`Policy::snapshot_state`]) |
//! | `arrivals` | the arrival model's RNG stream position |
//! | `shards`   | (sharded path) instance→shard ownership map + per-shard worker ledgers |
//! | `ingest`   | (streaming models) the drained ingest cursor/batch state of `sim::ingest`, so a kill mid-batch resumes bitwise |
//!
//! The trailer CRC is verified by `Reader::new` *before* any field is
//! decoded and each section's own CRC before its payload is handed out,
//! so a truncated, bit-flipped or mis-spliced blob is rejected with a
//! structured error naming the offending section — never silently
//! thawed.  Version gate: v1 blobs are rejected, v2 blobs (the flat
//! pre-§SStore layout, same field order without section frames or
//! checksums) stay readable, v3 blobs get full verification.
//!
//! Blobs are persisted through a [`BlobStore`] chain (`sim::store`):
//! epoch-numbered atomic-rename puts, `recovery.chain_depth` retention,
//! and injected storage faults (`ExecFaultPlan::storage_fault_at`).  On
//! a kill, recovery walks the chain newest→oldest, **skips blobs that
//! fail verification** (surfaced as `recover.blobs_rejected` /
//! `recover.thaw_fallbacks`), thaws the newest intact one and replays
//! forward — bitwise-identical to the uninterrupted run even when every
//! blob but the slot-0 genesis is corrupt.
//!
//! What is deliberately *not* stored: the topology edition itself.  The
//! incremental churn arm's edge ordering is path-dependent (it is the
//! product of the exact remove/restore call sequence), so the snapshot
//! would have to serialize the whole CSR to capture it.  Instead the
//! blob stores the fault-stream cursor (`next_event`) and restore
//! *replays* `plan.events()[..next_event]` through the same mutation
//! arm ([`replay_graph`]) — bit-identical reconstruction at the cost of
//! a few graph edits, and the blob stays edition-size-independent.
//!
//! **Recovery parity is the pinned contract**
//! (`tests/recovery_parity.rs`): a run that is killed at injected slots
//! and resumed from its last durable checkpoint must equal — bitwise,
//! on records, cumulative reward, ledger grids and decisions — the same
//! run uninterrupted.  Two mechanisms make this hold: every segment cut
//! (kill, checkpoint epoch, or topology event) re-primes the sparse
//! publishers, and a full publish commits the *same* rows the
//! incremental path would have (the §Perf-3 replay invariant), so extra
//! cuts perturb only the low bits of the ledger's *diagnostic* running
//! totals (a fresh flat re-sum vs a compensated incremental
//! accumulation) — never the decision tensors, usage rows, or rewards
//! the parity suite compares.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::config::{FaultConfig, RecoveryConfig, Scenario};
use crate::coordinator::{
    ClusterState, Leader, RunResult, ShardLedger, ShardPlan, ShardedLeader, SlotRecord,
};
use crate::graph::Bipartite;
use crate::model::Problem;
use crate::obs;
use crate::schedulers::Policy;
use crate::sim::arrivals::{ArrivalModel, Bernoulli};
use crate::sim::faults::{ChurnOutcome, ExecFaultPlan, FaultEvent, FaultPlan, Gated};
use crate::sim::store::BlobStore;
use crate::traces::synthesize;
use crate::utils::codec::{self, Reader, Writer};

/// One durable snapshot: the slot boundary it was taken at, plus the
/// codec blob.  `bytes` is the wire format — hand it to an external
/// store as-is; [`Checkpoint::slot`] is recoverable from the blob
/// itself (first field), the struct field is a convenience index.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub slot: u64,
    pub bytes: Vec<u8>,
}

impl Checkpoint {
    pub fn size(&self) -> usize {
        self.bytes.len()
    }
}

/// Reconstruct the topology edition at fault-stream position
/// `events.len()` by replaying the prefix through the same mutation arm
/// the driver used.  The incremental arm's edge order is path-dependent
/// — replay is the *only* way to rebuild it bit-identically; the
/// rebuild arm is a pure function of the final masks.
pub fn replay_graph(
    base: &Problem,
    e0: &[(usize, usize)],
    events: &[(usize, FaultEvent)],
    rebuild: bool,
) -> Result<Problem, String> {
    let l_n = base.num_ports();
    let r_n = base.num_instances();
    let mut failed = vec![false; r_n];
    let mut departed = vec![false; l_n];
    if rebuild {
        if events.is_empty() {
            return Ok(base.clone());
        }
        for &(_, ev) in events {
            match ev {
                FaultEvent::InstanceFail(r) => failed[r] = true,
                FaultEvent::InstanceRecover(r) => failed[r] = false,
                FaultEvent::PortDepart(l) => departed[l] = true,
                FaultEvent::PortArrive(l) => departed[l] = false,
            }
        }
        let live: Vec<(usize, usize)> = e0
            .iter()
            .copied()
            .filter(|&(l, r)| !departed[l] && !failed[r])
            .collect();
        return Ok(Problem::new(
            Bipartite::from_edges(l_n, r_n, &live),
            base.num_resources,
            base.demand.clone(),
            base.capacity.clone(),
            base.alpha.clone(),
            base.kind.clone(),
            base.beta.clone(),
        ));
    }
    let mut cur = base.clone();
    for &(t, ev) in events {
        let ctx = |e: String| format!("checkpoint replay at slot {t}: {e}");
        match ev {
            FaultEvent::InstanceFail(r) => {
                failed[r] = true;
                cur.remove_instance_edges(r).map_err(&ctx)?;
            }
            FaultEvent::InstanceRecover(r) => {
                failed[r] = false;
                let back: Vec<(usize, usize)> = e0
                    .iter()
                    .copied()
                    .filter(|&(l, rr)| rr == r && !departed[l])
                    .collect();
                cur.restore_edges(&back).map_err(&ctx)?;
            }
            FaultEvent::PortDepart(l) => {
                departed[l] = true;
                cur.remove_port_edges(l).map_err(&ctx)?;
            }
            FaultEvent::PortArrive(l) => {
                departed[l] = false;
                let back: Vec<(usize, usize)> = e0
                    .iter()
                    .copied()
                    .filter(|&(ll, r)| ll == l && !failed[r])
                    .collect();
                cur.restore_edges(&back).map_err(&ctx)?;
            }
        }
    }
    Ok(cur)
}

/// Serialize the driver's full live state at a slot boundary.
#[allow(clippy::too_many_arguments)]
fn freeze(
    cursor: usize,
    next_event: usize,
    editions: usize,
    replans: usize,
    events_applied: usize,
    result: &RunResult,
    failed: &[bool],
    departed: &[bool],
    active: &[bool],
    state: &ClusterState,
    policy: &dyn Policy,
    arrivals: &dyn ArrivalModel,
    sharded: Option<(&ShardPlan, Option<&[ShardLedger]>)>,
) -> Checkpoint {
    let mut w = Writer::new();
    let mut d = Writer::section();
    d.put_u64(cursor as u64);
    d.put_u64(next_event as u64);
    d.put_u64(editions as u64);
    d.put_u64(replans as u64);
    d.put_u64(events_applied as u64);
    d.put_str(&result.policy);
    d.put_f64(result.cumulative_reward);
    d.put_u64(result.clamped_total as u64);
    // elapsed wall time is deliberately absent: the blob stays
    // bit-identical across reruns of the same trajectory
    w.put_section("driver", &d.into_bytes());
    let mut rs = Writer::section();
    rs.put_usize(result.records.len());
    for rec in &result.records {
        rs.put_u64(rec.t as u64);
        rs.put_f64(rec.q);
        rs.put_f64(rec.gain);
        rs.put_f64(rec.penalty);
        rs.put_f64(rec.arrivals);
    }
    w.put_section("records", &rs.into_bytes());
    let mut ms = Writer::section();
    ms.put_bools(failed);
    ms.put_bools(departed);
    ms.put_bools(active);
    w.put_section("masks", &ms.into_bytes());
    let mut ls = Writer::section();
    state.snapshot(&mut ls);
    w.put_section("ledger", &ls.into_bytes());
    let mut ps = Writer::section();
    policy.snapshot_state(&mut ps);
    w.put_section("policy", &ps.into_bytes());
    let mut ar = Writer::section();
    arrivals.snapshot(&mut ar);
    w.put_section("arrivals", &ar.into_bytes());
    let mut sh = Writer::section();
    match sharded {
        None => sh.put_bool(false),
        Some((plan, ledgers)) => {
            sh.put_bool(true);
            sh.put_usize(plan.num_shards());
            let owners: Vec<u64> = plan.owners().iter().map(|&s| s as u64).collect();
            sh.put_u64s(&owners);
            match ledgers {
                None => sh.put_bool(false),
                Some(ls) => {
                    sh.put_bool(true);
                    sh.put_usize(ls.len());
                    for l in ls {
                        l.snapshot(&mut sh);
                    }
                }
            }
        }
    }
    w.put_section("shards", &sh.into_bytes());
    // Streaming-ingest cursor/batch state (§SPerf-9).  The call
    // *drains* the model's in-flight queue into its batcher first
    // — the durability contract for mid-batch kills — then serializes
    // the sub-versioned section; non-streaming models write `absent`.
    let mut ing = Writer::section();
    match arrivals.ingest_checkpoint() {
        None => ing.put_bool(false),
        Some(section) => {
            ing.put_bool(true);
            ing.put_bytes(&section);
        }
    }
    w.put_section("ingest", &ing.into_bytes());
    Checkpoint { slot: cursor as u64, bytes: w.finish() }
}

/// The decoded half of [`freeze`], ready to be dropped into the
/// driver's locals.
struct Thawed {
    cursor: usize,
    next_event: usize,
    editions: usize,
    replans: usize,
    events_applied: usize,
    cumulative_reward: f64,
    clamped_total: usize,
    records: Vec<SlotRecord>,
    failed: Vec<bool>,
    departed: Vec<bool>,
    active: Vec<bool>,
    problem: Problem,
    state: ClusterState,
    plan: Option<Arc<ShardPlan>>,
    carry: Option<(Arc<ShardPlan>, Vec<ShardLedger>)>,
}

/// Decode one logical group of the blob.  A v3 blob frames the group as
/// a named, CRC-checked section (`get_section` verifies name + checksum
/// before `f` sees a byte, and `finish` rejects trailing bytes); a v2
/// blob stores the same fields flat, so `f` reads the outer stream
/// directly.
fn in_section<'a, T>(
    r: &mut Reader<'a>,
    name: &'static str,
    v3: bool,
    f: impl FnOnce(&mut Reader<'a>) -> Result<T, String>,
) -> Result<T, String> {
    if v3 {
        let payload = r.get_section(name)?;
        let mut sr = Reader::named_section(payload, name);
        let v = f(&mut sr)?;
        sr.finish()?;
        Ok(v)
    } else {
        f(r)
    }
}

/// Restore a [`Checkpoint`]: decode the blob, replay the graph to the
/// stored fault-stream position, and rebuild ledger/policy/arrival
/// state in place.  `policy` and `arrivals` are reset-then-restored —
/// the snapshot carries the minimal sufficient state, the reset
/// re-derives everything else (publisher identity in particular goes
/// fresh, so the first post-restore decide is a conservative full
/// publish, exactly as after a topology edition).
fn thaw(
    ck: &Checkpoint,
    base: &Problem,
    e0: &[(usize, usize)],
    plan: &FaultPlan,
    rebuild: bool,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalModel,
) -> Result<Thawed, String> {
    let mut r = Reader::new(&ck.bytes)?;
    let v3 = r.version() >= 3;
    #[allow(clippy::type_complexity)]
    let (cursor, next_event, editions, replans, events_applied, name, cumulative_reward, clamped_total): (usize, usize, usize, usize, usize, String, f64, usize) =
        in_section(&mut r, "driver", v3, |r| {
            Ok((
                r.get_u64()? as usize,
                r.get_u64()? as usize,
                r.get_u64()? as usize,
                r.get_u64()? as usize,
                r.get_u64()? as usize,
                r.get_str()?,
                r.get_f64()?,
                r.get_u64()? as usize,
            ))
        })?;
    if name != policy.name() {
        return Err(format!(
            "checkpoint policy mismatch: blob has {name:?}, resuming {:?}",
            policy.name()
        ));
    }
    if next_event > plan.events().len() {
        return Err(format!(
            "checkpoint fault cursor {next_event} beyond plan ({} events)",
            plan.events().len()
        ));
    }
    let records = in_section(&mut r, "records", v3, |r| {
        let n_rec = r.get_usize()?;
        if n_rec != cursor {
            return Err(format!(
                "checkpoint has {n_rec} slot records for cursor {cursor}"
            ));
        }
        let mut records = Vec::with_capacity(n_rec);
        for _ in 0..n_rec {
            records.push(SlotRecord {
                t: r.get_u64()? as usize,
                q: r.get_f64()?,
                gain: r.get_f64()?,
                penalty: r.get_f64()?,
                arrivals: r.get_f64()?,
            });
        }
        Ok(records)
    })?;
    let (failed, departed, active) = in_section(&mut r, "masks", v3, |r| {
        Ok((r.get_bools()?, r.get_bools()?, r.get_bools()?))
    })?;
    if failed.len() != base.num_instances()
        || departed.len() != base.num_ports()
        || active.len() != base.num_ports()
    {
        return Err("checkpoint liveness masks do not match the problem".into());
    }
    let problem = replay_graph(base, e0, &plan.events()[..next_event], rebuild)?;
    let state = in_section(&mut r, "ledger", v3, |r| {
        Ok(ClusterState::restore(&problem, r)?)
    })?;
    let pbytes = if v3 { r.get_section("policy")?.to_vec() } else { r.get_bytes()? };
    policy.reset(&problem);
    let mut pr = Reader::named_section(&pbytes, "policy");
    policy.restore_state(&problem, &mut pr)?;
    pr.finish()
        .map_err(|e| format!("policy snapshot section: {e}"))?;
    let abytes = if v3 { r.get_section("arrivals")?.to_vec() } else { r.get_bytes()? };
    let mut ar = Reader::named_section(&abytes, "arrivals");
    arrivals.restore(&mut ar)?;
    ar.finish()
        .map_err(|e| format!("arrival snapshot section: {e}"))?;
    let (plan_arc, carry) = in_section(&mut r, "shards", v3, |r| {
        if r.get_bool()? {
            let num_shards = r.get_usize()?;
            let owners64 = r.get_u64s()?;
            let mut owners = Vec::with_capacity(owners64.len());
            for o in owners64 {
                owners.push(
                    u32::try_from(o)
                        .map_err(|_| format!("checkpoint owner {o} overflows u32"))?,
                );
            }
            let plan_arc = Arc::new(ShardPlan::with_owners(&problem, num_shards, owners)?);
            let carry = if r.get_bool()? {
                let n = r.get_usize()?;
                if n != num_shards {
                    return Err(format!(
                        "checkpoint has {n} shard ledgers for {num_shards} shards"
                    ));
                }
                let mut ledgers = Vec::with_capacity(n);
                for _ in 0..n {
                    ledgers.push(ShardLedger::restore(&problem, r)?);
                }
                Some((Arc::clone(&plan_arc), ledgers))
            } else {
                None
            };
            Ok((Some(plan_arc), carry))
        } else {
            Ok((None, None))
        }
    })?;
    in_section(&mut r, "ingest", v3, |r| {
        if r.get_bool()? {
            let ibytes = r.get_bytes()?;
            arrivals
                .ingest_restore(&ibytes)
                .map_err(|e| format!("ingest section: {e}"))?;
        }
        Ok(())
    })?;
    r.finish()?;
    Ok(Thawed {
        cursor,
        next_event,
        editions,
        replans,
        events_applied,
        cumulative_reward,
        clamped_total,
        records,
        failed,
        departed,
        active,
        problem,
        state,
        plan: plan_arc,
        carry,
    })
}

/// Outcome of a resilient run: the churned result plus the recovery
/// telemetry.  `checkpoints_written` counts *writes*; the
/// `checkpoints_rewritten` share of it is boundary re-writes during
/// post-kill replay (bit-identical to the originals, so durability
/// semantics are unaffected) — `written - rewritten` is the fresh-write
/// count (`recover.ckpts_fresh` in the obs registry).
pub struct ResilientOutcome {
    pub churn: ChurnOutcome,
    /// Checkpoint blobs written (fresh writes + boundary re-writes on
    /// post-kill replay).
    pub checkpoints_written: usize,
    /// The subset of `checkpoints_written` that re-wrote a boundary the
    /// pre-kill run had already passed (replay re-freezes the
    /// bit-identical blob).
    pub checkpoints_rewritten: usize,
    /// Checkpoint writes dropped by injected `ckpt_fails`.
    pub checkpoints_failed: usize,
    /// Process kills taken (and recovered from).
    pub kills: usize,
    /// The checkpoint slot each kill restored from, in kill order.
    pub restored_from: Vec<u64>,
    /// Injected worker panics/stalls that actually fired.
    pub worker_faults: usize,
    /// Chain blobs that failed PLCK verification during recovery walks
    /// (§SStore) — every one of these was rejected, never thawed.
    pub blobs_rejected: usize,
    /// Recoveries that had to fall back past at least one rejected blob
    /// to an older checkpoint.
    pub thaw_fallbacks: usize,
}

/// Drive `policy` under *both* fault streams: the topology churn of
/// `plan` (identical semantics to [`run_churned`]) and the execution
/// faults of `exec` — worker panics/stalls (armed as a pool probe on
/// every segment), checkpoint-write failures, and process kills.  At a
/// kill slot the driver discards every live structure and resumes from
/// the last durable [`Checkpoint`]; `recovery.checkpoint_epoch` sets
/// the snapshot cadence (0 = only the implicit slot-0 snapshot, so a
/// kill replays from the start — legal, just slow).
///
/// The horizon is cut at topology-event slots, checkpoint boundaries
/// and kill slots; each boundary processes in a fixed order — kill,
/// checkpoint write, event drain, next segment — so a kill scheduled at
/// the same slot as a checkpoint fires *before* the write (the crash
/// you checkpoint through is the interesting one).
///
/// [`run_churned`]: crate::sim::faults::run_churned
#[allow(clippy::too_many_arguments)]
pub fn run_resilient(
    base: &Problem,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalModel,
    horizon: usize,
    shards: usize,
    plan: &FaultPlan,
    cfg: &FaultConfig,
    rebuild: bool,
    recovery: &RecoveryConfig,
    exec: &ExecFaultPlan,
) -> Result<ResilientOutcome, String> {
    let mut store = BlobStore::memory(recovery.chain_depth.max(1));
    run_resilient_with_store(
        base, policy, arrivals, horizon, shards, plan, cfg, rebuild, recovery, exec, &mut store,
    )
}

/// [`run_resilient`] against a caller-supplied [`BlobStore`] — the
/// §SStore entry point.  The store may be disk-backed (durable across
/// processes) or pre-populated (resuming a previous process's chain);
/// `run_resilient` itself delegates here with a fresh in-memory chain.
#[allow(clippy::too_many_arguments)]
pub fn run_resilient_with_store(
    base: &Problem,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalModel,
    horizon: usize,
    shards: usize,
    plan: &FaultPlan,
    cfg: &FaultConfig,
    rebuild: bool,
    recovery: &RecoveryConfig,
    exec: &ExecFaultPlan,
    store: &mut BlobStore,
) -> Result<ResilientOutcome, String> {
    let l_n = base.num_ports();
    let r_n = base.num_instances();
    let e0: Vec<(usize, usize)> = (0..base.num_edges())
        .map(|e| (base.graph.edge_port[e], base.graph.edge_instance[e]))
        .collect();
    let mut failed = vec![false; r_n];
    let mut departed = vec![false; l_n];
    let mut active = vec![true; l_n];

    let mut cur = base.clone();
    let serial = shards == 1;
    let mut state = ClusterState::new(&cur);
    let mut cur_plan: Option<Arc<ShardPlan>> =
        (!serial).then(|| Arc::new(ShardPlan::build(&cur, shards)));
    let mut carry: Option<(Arc<ShardPlan>, Vec<ShardLedger>)> = None;

    let mut result = RunResult {
        policy: policy.name().to_string(),
        records: Vec::with_capacity(horizon),
        ..Default::default()
    };
    let mut editions = 0usize;
    let mut replans = 0usize;
    let mut events_applied = 0usize;

    let epoch = recovery.checkpoint_epoch;
    let probe = exec.probe();
    let mut kills: VecDeque<u64> = exec.kills.iter().copied().collect();
    let mut checkpoints_written = 0usize;
    let mut checkpoints_rewritten = 0usize;
    let mut checkpoints_failed = 0usize;
    let mut kills_taken = 0usize;
    let mut restored_from = Vec::new();
    let mut blobs_rejected = 0usize;
    let mut thaw_fallbacks = 0usize;

    let mut cursor = 0usize;
    let mut next_event = 0usize; // index into plan.events
    // Highest slot reached before any kill: segments below it re-run
    // previously executed slots, which the obs layer marks as recovery
    // replay rather than fresh progress.
    let mut replay_target = 0u64;
    loop {
        // 1. process kill: discard every live structure, thaw the last
        //    durable blob (out-of-order hand-built kills fire late,
        //    mirroring run_churned's clamping of event slots)
        if kills.front().map_or(false, |&k| k as usize <= cursor) {
            kills.pop_front();
            kills_taken += 1;
            obs::registry().counter("recover.kills").inc();
            obs::event(obs::SpanKind::KillTaken, cursor as u64, 0, editions as u32);
            replay_target = replay_target.max(cursor as u64);
            if store.is_empty() {
                return Err("process kill precedes the initial checkpoint".to_string());
            }
            // Fallback thaw (§SStore): walk the chain newest→oldest,
            // verify each blob's checksums *before* any decode, and
            // thaw the first intact one.  Damaged blobs are counted and
            // skipped — never silently decoded (the v3 trailer CRC is
            // checked ahead of every field read, so a blob that passes
            // `verify` cannot leave partial state behind either).
            let mut rejected_here = 0u32;
            let mut thawed: Option<(u64, Thawed)> = None;
            for entry in store.chain() {
                let bytes = store.load(&entry)?;
                if codec::verify(&bytes).is_err() {
                    rejected_here += 1;
                    blobs_rejected += 1;
                    obs::registry().counter("recover.blobs_rejected").inc();
                    obs::event(obs::SpanKind::BlobRejected, entry.slot, 0, entry.epoch as u32);
                    continue;
                }
                let ck = Checkpoint { slot: entry.slot, bytes };
                let th = obs::with_span(obs::SpanKind::CkptThaw, ck.slot, 0, || {
                    thaw(&ck, base, &e0, plan, rebuild, policy, arrivals)
                })?;
                thawed = Some((ck.slot, th));
                break;
            }
            let (slot, th) = thawed.ok_or_else(|| {
                format!(
                    "kill at slot {cursor}: all {} checkpoint blobs in the chain failed verification",
                    store.len()
                )
            })?;
            if rejected_here > 0 {
                thaw_fallbacks += 1;
                obs::registry().counter("recover.thaw_fallbacks").inc();
                obs::event(obs::SpanKind::ThawFallback, slot, 0, rejected_here);
            }
            cursor = th.cursor;
            next_event = th.next_event;
            editions = th.editions;
            replans = th.replans;
            events_applied = th.events_applied;
            result.cumulative_reward = th.cumulative_reward;
            result.clamped_total = th.clamped_total;
            result.records = th.records;
            failed = th.failed;
            departed = th.departed;
            active = th.active;
            cur = th.problem;
            state = th.state;
            cur_plan = th.plan;
            carry = th.carry;
            restored_from.push(slot);
            continue;
        }

        // 2. checkpoint due at this boundary?  Slot 0 is the implicit,
        //    unconditional snapshot; epoch boundaries are skippable by
        //    injected write failures, and a boundary whose blob is the
        //    chain's newest (post-kill replay arriving back at the
        //    restore point) is not re-written.
        let due = cursor == 0 || (epoch > 0 && cursor % epoch == 0 && cursor < horizon);
        if due && store.newest_slot() != Some(cursor as u64) {
            if cursor > 0 && exec.ckpt_fails.contains(&(cursor as u64)) {
                checkpoints_failed += 1;
                obs::registry().counter("recover.ckpts_dropped").inc();
                obs::event(obs::SpanKind::CkptDropped, cursor as u64, 0, editions as u32);
            } else {
                debug_assert!(
                    match (&carry, &cur_plan) {
                        (Some((cp, _)), Some(p)) => Arc::ptr_eq(cp, p),
                        (Some(_), None) => false,
                        (None, _) => true,
                    },
                    "carry plan diverged from the live plan at a checkpoint boundary"
                );
                let ck = obs::with_span(obs::SpanKind::CkptFreeze, cursor as u64, 0, || {
                    freeze(
                        cursor,
                        next_event,
                        editions,
                        replans,
                        events_applied,
                        &result,
                        &failed,
                        &departed,
                        &active,
                        &state,
                        &*policy,
                        &*arrivals,
                        cur_plan
                            .as_deref()
                            .map(|p| (p, carry.as_ref().map(|(_, l)| l.as_slice()))),
                    )
                });
                store.put(ck.slot, &ck.bytes, exec.storage_fault_at(cursor as u64))?;
                checkpoints_written += 1;
                obs::registry().counter("recover.ckpts_written").inc();
                if (cursor as u64) < replay_target {
                    // a boundary the pre-kill run had already written:
                    // replay re-freezes the bit-identical blob
                    checkpoints_rewritten += 1;
                    obs::registry().counter("recover.ckpts_rewritten").inc();
                } else {
                    obs::registry().counter("recover.ckpts_fresh").inc();
                }
            }
        }

        // 3. apply every event scheduled at or before this boundary, in
        //    stream order (identical semantics to run_churned — the
        //    checkpoint above was written *pre-drain*, so a restore
        //    re-drains these events deterministically).  The old graph
        //    is only cloned when an event is actually pending: most
        //    boundaries here are checkpoint epochs, not editions.
        let pending = plan
            .events()
            .get(next_event)
            .map_or(false, |&(t, _)| t <= cursor);
        let old_graph = pending.then(|| cur.graph.clone());
        let mut touched = false;
        while let Some(&(t, ev)) = plan.events().get(next_event) {
            if t > cursor {
                break;
            }
            next_event += 1;
            events_applied += 1;
            let entity = match ev {
                FaultEvent::InstanceFail(r) | FaultEvent::InstanceRecover(r) => r,
                FaultEvent::PortDepart(l) | FaultEvent::PortArrive(l) => l,
            };
            obs::event(obs::SpanKind::FaultTopology, t as u64, entity as u32, editions as u32);
            let ctx = |e: String| format!("fault event at slot {t}: {e}");
            match ev {
                FaultEvent::InstanceFail(r) => {
                    if r >= r_n {
                        return Err(ctx(format!("instance {r} out of range (R={r_n})")));
                    }
                    failed[r] = true;
                    state.fail_instance(r, cfg.release).map_err(&ctx)?;
                    if !rebuild {
                        cur.remove_instance_edges(r).map_err(&ctx)?;
                    }
                    touched = true;
                }
                FaultEvent::InstanceRecover(r) => {
                    if r >= r_n {
                        return Err(ctx(format!("instance {r} out of range (R={r_n})")));
                    }
                    failed[r] = false;
                    state.recover_instance(r).map_err(&ctx)?;
                    if !rebuild {
                        let back: Vec<(usize, usize)> = e0
                            .iter()
                            .copied()
                            .filter(|&(l, rr)| rr == r && !departed[l])
                            .collect();
                        cur.restore_edges(&back).map_err(&ctx)?;
                    }
                    touched = true;
                }
                FaultEvent::PortDepart(l) => {
                    if l >= l_n {
                        return Err(ctx(format!("port {l} out of range (L={l_n})")));
                    }
                    departed[l] = true;
                    active[l] = false;
                    if !rebuild {
                        cur.remove_port_edges(l).map_err(&ctx)?;
                    }
                    touched = true;
                }
                FaultEvent::PortArrive(l) => {
                    if l >= l_n {
                        return Err(ctx(format!("port {l} out of range (L={l_n})")));
                    }
                    departed[l] = false;
                    active[l] = true;
                    if !rebuild {
                        let back: Vec<(usize, usize)> = e0
                            .iter()
                            .copied()
                            .filter(|&(ll, r)| ll == l && !failed[r])
                            .collect();
                        cur.restore_edges(&back).map_err(&ctx)?;
                    }
                    touched = true;
                }
            }
        }
        if touched {
            editions += 1;
            if rebuild {
                let live: Vec<(usize, usize)> = e0
                    .iter()
                    .copied()
                    .filter(|&(l, r)| !departed[l] && !failed[r])
                    .collect();
                cur = Problem::new(
                    Bipartite::from_edges(l_n, r_n, &live),
                    cur.num_resources,
                    cur.demand.clone(),
                    cur.capacity.clone(),
                    cur.alpha.clone(),
                    cur.kind.clone(),
                    cur.beta.clone(),
                );
            }
            if cfg!(debug_assertions) {
                for (r, &f) in failed.iter().enumerate() {
                    assert!(
                        !f || cur.graph.instance_degree(r) == 0,
                        "failed instance {r} still has channels at slot {cursor}"
                    );
                }
                for (l, &d) in departed.iter().enumerate() {
                    assert!(
                        !d || cur.graph.port_edges(l).len() == 0,
                        "departed port {l} still has channels at slot {cursor}"
                    );
                }
            }
            let old_graph = old_graph.as_ref().expect("touched implies a pending event");
            policy.remap(old_graph, &cur);
            if let Some(plan_arc) = &mut cur_plan {
                if rebuild {
                    *plan_arc = Arc::new(ShardPlan::build(&cur, shards));
                } else {
                    let refreshed = plan_arc
                        .refresh(&cur)
                        .map_err(|e| format!("fault replan at slot {cursor}: {e}"))?;
                    if refreshed.imbalance() > cfg.replan_threshold {
                        *plan_arc = Arc::new(ShardPlan::build(&cur, shards));
                        replans += 1;
                        obs::event(obs::SpanKind::Replan, cursor as u64, 0, editions as u32);
                    } else {
                        *plan_arc = Arc::new(refreshed);
                    }
                }
            }
        }
        if cursor >= horizon {
            break;
        }

        // 4. next boundary: topology event, checkpoint epoch, kill, or
        //    the horizon — whichever comes first.  Each candidate is
        //    strictly past the cursor (events ≤ cursor were drained,
        //    kills ≤ cursor were taken), so segments always progress.
        let mut seg_end = horizon;
        if let Some(&(t, _)) = plan.events().get(next_event) {
            seg_end = seg_end.min(t);
        }
        if epoch > 0 {
            seg_end = seg_end.min((cursor / epoch + 1) * epoch);
        }
        if let Some(&k) = kills.front() {
            seg_end = seg_end.min(k as usize);
        }
        debug_assert!(seg_end > cursor, "boundary scheduler failed to progress");

        // 5. run the segment [cursor, seg_end) on the current edition,
        //    with the worker-fault probe armed at the absolute slot base
        {
            // slots below the pre-kill high-water mark are re-executed
            // work: span them as recovery replay
            let _replay_span = if (cursor as u64) < replay_target {
                Some(obs::SpanTimer::start(
                    obs::SpanKind::RecoveryReplay,
                    cursor as u64,
                    0,
                ))
            } else {
                None
            };
            let mut gated = Gated { inner: &mut *arrivals, active: &active };
            let seg = if serial {
                let mut leader = Leader::resume(&cur, state);
                leader.arm_probe(Arc::clone(&probe), cursor as u64);
                let seg = leader.run(policy, &mut gated, seg_end - cursor);
                state = leader.into_state();
                seg
            } else {
                let plan_arc = cur_plan.as_ref().expect("sharded path has a plan");
                let mut leader =
                    ShardedLeader::resume(&cur, Arc::clone(plan_arc), state, carry.take());
                leader.arm_probe(Arc::clone(&probe), cursor as u64);
                let seg = leader.run(policy, &mut gated, seg_end - cursor);
                let (s, p, ledgers) = leader.into_parts();
                state = s;
                carry = Some((p, ledgers));
                seg
            };
            result.clamped_total += seg.clamped_total;
            result.cumulative_reward += seg.cumulative_reward;
            result.elapsed_secs += seg.elapsed_secs;
            for mut rec in seg.records {
                rec.t += cursor; // segment-local t → run-global t
                result.records.push(rec);
            }
        }
        cursor = seg_end;
    }

    Ok(ResilientOutcome {
        churn: ChurnOutcome {
            result,
            state,
            problem: cur,
            editions,
            replans,
            events: events_applied,
        },
        checkpoints_written,
        checkpoints_rewritten,
        checkpoints_failed,
        kills: kills_taken,
        restored_from,
        worker_faults: probe.fired_count(),
        blobs_rejected,
        thaw_fallbacks,
    })
}

/// Scenario-level convenience: synthesize the problem, generate both
/// fault streams from the scenario, and run one policy resiliently with
/// the scenario's Bernoulli arrivals and shard budget.  When the
/// scenario names a `recovery.store_dir` the blob chain is disk-backed
/// (durable across processes); otherwise it lives in memory.
pub fn run_resilient_scenario(
    scenario: &Scenario,
    policy: &mut dyn Policy,
    rebuild: bool,
) -> Result<ResilientOutcome, String> {
    let problem = synthesize(scenario);
    let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
    let exec = ExecFaultPlan::generate(
        scenario.horizon,
        scenario.parallel.shards.max(1),
        &scenario.recovery,
    );
    let mut arrivals = Bernoulli::uniform(
        problem.num_ports(),
        scenario.arrival_prob,
        scenario.seed ^ 0xA5A5,
    );
    policy.reset(&problem);
    let depth = scenario.recovery.chain_depth.max(1);
    let mut store = match &scenario.store_dir {
        Some(dir) => BlobStore::open(std::path::Path::new(dir), depth)?,
        None => BlobStore::memory(depth),
    };
    run_resilient_with_store(
        &problem,
        policy,
        &mut arrivals,
        scenario.horizon,
        scenario.parallel.shards,
        &plan,
        &scenario.faults,
        rebuild,
        &scenario.recovery,
        &exec,
        &mut store,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedulers::{Fairness, OgaSched};
    use crate::sim::faults::run_churned;
    use crate::utils::pool::ExecBudget;

    fn churny() -> FaultConfig {
        FaultConfig {
            instance_rate: 0.05,
            recover_rate: 0.2,
            port_rate: 0.03,
            rack_rate: 0.01,
            rack_size: 3,
            ..FaultConfig::default()
        }
    }

    fn small(horizon: usize) -> Scenario {
        let mut s = Scenario::small();
        s.horizon = horizon;
        s.faults = churny();
        s
    }

    fn baseline(
        scenario: &Scenario,
        problem: &Problem,
        plan: &FaultPlan,
        shards: usize,
    ) -> ChurnOutcome {
        let mut pol = OgaSched::new(problem, 2.0, 0.999, ExecBudget::serial());
        pol.reset(problem);
        let mut arr = Bernoulli::uniform(problem.num_ports(), 0.7, 11);
        run_churned(
            problem,
            &mut pol,
            &mut arr,
            scenario.horizon,
            shards,
            plan,
            &scenario.faults,
            false,
        )
        .unwrap()
    }

    fn resilient(
        scenario: &Scenario,
        problem: &Problem,
        plan: &FaultPlan,
        shards: usize,
        recovery: &RecoveryConfig,
        exec: &ExecFaultPlan,
    ) -> ResilientOutcome {
        let mut pol = OgaSched::new(problem, 2.0, 0.999, ExecBudget::serial());
        pol.reset(problem);
        let mut arr = Bernoulli::uniform(problem.num_ports(), 0.7, 11);
        run_resilient(
            problem,
            &mut pol,
            &mut arr,
            scenario.horizon,
            shards,
            plan,
            &scenario.faults,
            false,
            recovery,
            exec,
        )
        .unwrap()
    }

    fn assert_matches(got: &ResilientOutcome, want: &ChurnOutcome, problem: &Problem) {
        assert_eq!(got.churn.result.records, want.result.records);
        assert_eq!(
            got.churn.result.cumulative_reward,
            want.result.cumulative_reward
        );
        assert_eq!(got.churn.result.clamped_total, want.result.clamped_total);
        assert_eq!(got.churn.editions, want.editions);
        assert_eq!(got.churn.replans, want.replans);
        assert_eq!(got.churn.events, want.events);
        for r in 0..problem.num_instances() {
            for k in 0..problem.num_resources {
                assert_eq!(
                    got.churn.state.remaining_at(r, k),
                    want.state.remaining_at(r, k),
                    "ledger diverged at ({r},{k})"
                );
            }
        }
    }

    #[test]
    fn epoch_cuts_alone_do_not_change_results() {
        // checkpoint boundaries cut the horizon into extra segments;
        // the cut-invariance argument says that's float-invisible
        let scenario = small(90);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let recovery = RecoveryConfig { checkpoint_epoch: 7, ..RecoveryConfig::default() };
        let exec = ExecFaultPlan::default();
        for shards in [1usize, 3] {
            let want = baseline(&scenario, &problem, &plan, shards);
            let got = resilient(&scenario, &problem, &plan, shards, &recovery, &exec);
            assert!(got.checkpoints_written >= 90 / 7, "cadence not kept");
            assert_eq!(got.kills, 0);
            assert_matches(&got, &want, &problem);
        }
    }

    #[test]
    fn kill_and_resume_matches_uninterrupted_bitwise() {
        let scenario = small(80);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let recovery = RecoveryConfig { checkpoint_epoch: 5, ..RecoveryConfig::default() };
        let exec = ExecFaultPlan { kills: vec![7, 23, 61], ..ExecFaultPlan::default() };
        for shards in [1usize, 2, 4] {
            let want = baseline(&scenario, &problem, &plan, shards);
            let got = resilient(&scenario, &problem, &plan, shards, &recovery, &exec);
            assert_eq!(got.kills, 3);
            assert_eq!(got.restored_from, vec![5, 20, 60]);
            // every restore lands on the newest boundary, so nothing is
            // rejected, no fallback happens, and no boundary re-writes
            assert_eq!(got.blobs_rejected, 0);
            assert_eq!(got.thaw_fallbacks, 0);
            assert_eq!(got.checkpoints_rewritten, 0);
            assert_matches(&got, &want, &problem);
        }
    }

    #[test]
    fn failed_checkpoint_writes_reach_further_back() {
        let scenario = small(60);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let recovery = RecoveryConfig { checkpoint_epoch: 5, ..RecoveryConfig::default() };
        // both epoch boundaries under the kill are dropped, so the
        // restore reaches all the way back to the implicit slot 0
        let exec = ExecFaultPlan {
            kills: vec![12],
            ckpt_fails: [5u64, 10].into_iter().collect(),
            ..ExecFaultPlan::default()
        };
        let want = baseline(&scenario, &problem, &plan, 1);
        let got = resilient(&scenario, &problem, &plan, 1, &recovery, &exec);
        // 2 drops before the kill + the same 2 boundaries re-dropped on
        // the post-kill replay (injected drops re-fire deterministically)
        assert_eq!(got.checkpoints_failed, 4);
        assert_eq!(got.restored_from, vec![0]);
        // the written/rewritten split: slot 0 once (replay arrives back
        // at the restore point, which dedups) + the 9 fresh boundaries
        // 15..=55 — with both pre-kill boundaries dropped, nothing is
        // ever re-written
        assert_eq!(got.checkpoints_written, 10);
        assert_eq!(got.checkpoints_rewritten, 0);
        assert_matches(&got, &want, &problem);
    }

    #[test]
    fn storage_faults_fall_back_along_the_chain_bitwise() {
        let scenario = small(60);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let recovery = RecoveryConfig {
            checkpoint_epoch: 5,
            chain_depth: 3,
            ..RecoveryConfig::default()
        };
        // the newest blob at the kill is torn: recovery must reject it
        // and fall back to the intact slot-5 blob, then replay forward
        let mut exec = ExecFaultPlan { kills: vec![12], ..ExecFaultPlan::default() };
        exec.torn_writes.insert(10, 0xBEEF);
        let want = baseline(&scenario, &problem, &plan, 1);
        let got = resilient(&scenario, &problem, &plan, 1, &recovery, &exec);
        assert_eq!(got.kills, 1);
        assert_eq!(
            got.restored_from,
            vec![5],
            "fallback thaw must skip the torn slot-10 blob"
        );
        assert_eq!(got.blobs_rejected, 1);
        assert_eq!(got.thaw_fallbacks, 1);
        // replay re-writes the slot-5 and slot-10 boundaries (the
        // latter torn again, deterministically); 12 distinct boundaries
        // 0..=55 in total
        assert_eq!(got.checkpoints_written, 14);
        assert_eq!(got.checkpoints_rewritten, 2);
        assert_matches(&got, &want, &problem);
    }

    #[test]
    fn worker_faults_compose_with_kills_bitwise() {
        let scenario = small(70);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let recovery = RecoveryConfig { checkpoint_epoch: 10, ..RecoveryConfig::default() };
        let exec = ExecFaultPlan {
            kills: vec![31],
            panics: [(9u64, 0u32), (40, 2)].into_iter().collect(),
            stalls: [(17u64, 1u32)].into_iter().collect(),
            stall_ms: 5,
            ..ExecFaultPlan::default()
        };
        let want = baseline(&scenario, &problem, &plan, 4);
        let got = resilient(&scenario, &problem, &plan, 4, &recovery, &exec);
        assert_eq!(got.kills, 1);
        assert!(got.worker_faults >= 2, "injected worker faults never fired");
        assert_matches(&got, &want, &problem);
    }

    fn genesis_edges(problem: &Problem) -> Vec<(usize, usize)> {
        (0..problem.num_edges())
            .map(|e| (problem.graph.edge_port[e], problem.graph.edge_instance[e]))
            .collect()
    }

    #[test]
    fn v2_blobs_stay_thawable_behind_the_version_gate() {
        let scenario = small(10);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::from_events(vec![]);
        let e0 = genesis_edges(&problem);
        let mut pol = Fairness::new();
        pol.reset(&problem);
        let mut arr = Bernoulli::uniform(problem.num_ports(), 0.7, 11);
        // hand-build the flat v2 layout for the slot-0 state: the same
        // field order as v3, without section frames or checksums
        let mut w = Writer::with_version(2);
        for _ in 0..5 {
            w.put_u64(0); // cursor, next_event, editions, replans, events_applied
        }
        w.put_str(pol.name());
        w.put_f64(0.0);
        w.put_u64(0);
        w.put_usize(0); // no records yet
        w.put_bools(&vec![false; problem.num_instances()]);
        w.put_bools(&vec![false; problem.num_ports()]);
        w.put_bools(&vec![true; problem.num_ports()]);
        ClusterState::new(&problem).snapshot(&mut w);
        let mut ps = Writer::section();
        pol.snapshot_state(&mut ps);
        w.put_bytes(&ps.into_bytes());
        let mut ar = Writer::section();
        arr.snapshot(&mut ar);
        w.put_bytes(&ar.into_bytes());
        w.put_bool(false); // not sharded
        w.put_bool(false); // no ingest section
        let ck = Checkpoint { slot: 0, bytes: w.into_bytes() };
        let th = thaw(&ck, &problem, &e0, &plan, false, &mut pol, &mut arr).unwrap();
        assert_eq!(th.cursor, 0);
        assert!(th.records.is_empty());
        // v1 blobs are rejected by the gate
        let mut w1 = Writer::with_version(1);
        w1.put_u64(0);
        let ck1 = Checkpoint { slot: 0, bytes: w1.into_bytes() };
        assert!(thaw(&ck1, &problem, &e0, &plan, false, &mut pol, &mut arr).is_err());
    }

    #[test]
    fn damaged_real_blobs_never_thaw() {
        let scenario = small(10);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::from_events(vec![]);
        let e0 = genesis_edges(&problem);
        let mut pol = Fairness::new();
        pol.reset(&problem);
        let mut arr = Bernoulli::uniform(problem.num_ports(), 0.7, 11);
        let result = RunResult { policy: pol.name().to_string(), ..Default::default() };
        let failed = vec![false; problem.num_instances()];
        let departed = vec![false; problem.num_ports()];
        let active = vec![true; problem.num_ports()];
        let state = ClusterState::new(&problem);
        let ck = freeze(
            0, 0, 0, 0, 0, &result, &failed, &departed, &active, &state, &pol, &arr, None,
        );
        // the intact v3 blob round-trips
        thaw(&ck, &problem, &e0, &plan, false, &mut pol, &mut arr).unwrap();
        // truncated at every byte offset: a structured error, never a
        // panic or a partially-applied thaw
        for cut in 0..ck.bytes.len() {
            let damaged = Checkpoint { slot: 0, bytes: ck.bytes[..cut].to_vec() };
            assert!(
                thaw(&damaged, &problem, &e0, &plan, false, &mut pol, &mut arr).is_err(),
                "truncation at offset {cut} thawed"
            );
        }
        // ... and a bit flip at every byte is caught by the checksums
        for i in 0..ck.bytes.len() {
            let mut bytes = ck.bytes.clone();
            bytes[i] ^= 0x10;
            let damaged = Checkpoint { slot: 0, bytes };
            assert!(
                thaw(&damaged, &problem, &e0, &plan, false, &mut pol, &mut arr).is_err(),
                "bit flip at offset {i} thawed"
            );
        }
    }

    #[test]
    fn checkpoint_blobs_are_deterministic_and_round_trip() {
        let scenario = small(40);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let recovery = RecoveryConfig { checkpoint_epoch: 8, ..RecoveryConfig::default() };
        // same trajectory twice: every surviving blob must be
        // bit-identical, and a thaw of the final store must decode
        let run = || {
            let mut pol = Fairness::new();
            pol.reset(&problem);
            let mut arr = Bernoulli::uniform(problem.num_ports(), 0.7, 11);
            run_resilient(
                &problem,
                &mut pol,
                &mut arr,
                scenario.horizon,
                1,
                &plan,
                &scenario.faults,
                false,
                &recovery,
                &ExecFaultPlan { kills: vec![13], ..ExecFaultPlan::default() },
            )
            .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.churn.result.records, b.churn.result.records);
        assert_eq!(a.restored_from, b.restored_from);
        assert!(a.checkpoints_written >= b.restored_from.len());
    }

    #[test]
    fn replay_graph_matches_the_incremental_path() {
        let scenario = small(100);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        assert!(!plan.is_empty(), "churny plan must schedule events");
        let e0: Vec<(usize, usize)> = (0..problem.num_edges())
            .map(|e| (problem.graph.edge_port[e], problem.graph.edge_instance[e]))
            .collect();
        for cut in [0, 1, plan.events().len() / 2, plan.events().len()] {
            let inc = replay_graph(&problem, &e0, &plan.events()[..cut], false).unwrap();
            let reb = replay_graph(&problem, &e0, &plan.events()[..cut], true).unwrap();
            // both arms agree on the live edge *set*; the incremental
            // arm's ordering is path-dependent, so compare as sets
            let edges = |p: &Problem| {
                let mut es: Vec<(usize, usize)> = (0..p.num_edges())
                    .map(|e| (p.graph.edge_port[e], p.graph.edge_instance[e]))
                    .collect();
                es.sort_unstable();
                es
            };
            assert_eq!(edges(&inc), edges(&reb), "arms disagree at cut {cut}");
        }
    }

    #[test]
    fn scenario_driver_honours_the_recovery_section() {
        let mut scenario = small(50);
        scenario.recovery = RecoveryConfig {
            checkpoint_epoch: 6,
            kill_rate: 0.08,
            seed: 5,
            ..RecoveryConfig::default()
        };
        let out = run_resilient_scenario(&scenario, &mut Fairness::new(), false).unwrap();
        assert_eq!(out.churn.result.records.len(), 50);
        for (t, rec) in out.churn.result.records.iter().enumerate() {
            assert_eq!(rec.t, t);
        }
        assert!(out.checkpoints_written > 0);
    }
}
