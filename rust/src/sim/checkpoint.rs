//! Deterministic checkpoints and the kill-and-resume driver (the
//! crash-resilience layer on top of `sim::faults`).
//!
//! A [`Checkpoint`] is a self-contained binary snapshot (`utils::codec`
//! framing — magic, version, bounds-checked sections, `f64::to_bits`
//! floats) of everything a churned run needs to continue mid-horizon:
//! the driver cursor and fault-stream position, the concatenated slot
//! records and reward accumulators, the liveness masks, the cluster
//! ledger, the policy's learned state (via [`Policy::snapshot_state`]),
//! the arrival model's RNG stream position, — on the sharded path —
//! the instance→shard ownership map plus the per-shard worker ledgers,
//! and (blob v2, streaming models only) the drained ingest
//! cursor/batch-state section of `sim::ingest` so a kill mid-batch
//! resumes bitwise.
//!
//! What is deliberately *not* stored: the topology edition itself.  The
//! incremental churn arm's edge ordering is path-dependent (it is the
//! product of the exact remove/restore call sequence), so the snapshot
//! would have to serialize the whole CSR to capture it.  Instead the
//! blob stores the fault-stream cursor (`next_event`) and restore
//! *replays* `plan.events()[..next_event]` through the same mutation
//! arm ([`replay_graph`]) — bit-identical reconstruction at the cost of
//! a few graph edits, and the blob stays edition-size-independent.
//!
//! **Recovery parity is the pinned contract**
//! (`tests/recovery_parity.rs`): a run that is killed at injected slots
//! and resumed from its last durable checkpoint must equal — bitwise,
//! on records, cumulative reward, ledger grids and decisions — the same
//! run uninterrupted.  Two mechanisms make this hold: every segment cut
//! (kill, checkpoint epoch, or topology event) re-primes the sparse
//! publishers, and a full publish commits the *same* rows the
//! incremental path would have (the §Perf-3 replay invariant), so extra
//! cuts perturb only the low bits of the ledger's *diagnostic* running
//! totals (a fresh flat re-sum vs a compensated incremental
//! accumulation) — never the decision tensors, usage rows, or rewards
//! the parity suite compares.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::config::{FaultConfig, RecoveryConfig, Scenario};
use crate::coordinator::{
    ClusterState, Leader, RunResult, ShardLedger, ShardPlan, ShardedLeader, SlotRecord,
};
use crate::graph::Bipartite;
use crate::model::Problem;
use crate::obs;
use crate::schedulers::Policy;
use crate::sim::arrivals::{ArrivalModel, Bernoulli};
use crate::sim::faults::{ChurnOutcome, ExecFaultPlan, FaultEvent, FaultPlan, Gated};
use crate::traces::synthesize;
use crate::utils::codec::{Reader, Writer};

/// One durable snapshot: the slot boundary it was taken at, plus the
/// codec blob.  `bytes` is the wire format — hand it to an external
/// store as-is; [`Checkpoint::slot`] is recoverable from the blob
/// itself (first field), the struct field is a convenience index.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub slot: u64,
    pub bytes: Vec<u8>,
}

impl Checkpoint {
    pub fn size(&self) -> usize {
        self.bytes.len()
    }
}

/// Reconstruct the topology edition at fault-stream position
/// `events.len()` by replaying the prefix through the same mutation arm
/// the driver used.  The incremental arm's edge order is path-dependent
/// — replay is the *only* way to rebuild it bit-identically; the
/// rebuild arm is a pure function of the final masks.
pub fn replay_graph(
    base: &Problem,
    e0: &[(usize, usize)],
    events: &[(usize, FaultEvent)],
    rebuild: bool,
) -> Result<Problem, String> {
    let l_n = base.num_ports();
    let r_n = base.num_instances();
    let mut failed = vec![false; r_n];
    let mut departed = vec![false; l_n];
    if rebuild {
        if events.is_empty() {
            return Ok(base.clone());
        }
        for &(_, ev) in events {
            match ev {
                FaultEvent::InstanceFail(r) => failed[r] = true,
                FaultEvent::InstanceRecover(r) => failed[r] = false,
                FaultEvent::PortDepart(l) => departed[l] = true,
                FaultEvent::PortArrive(l) => departed[l] = false,
            }
        }
        let live: Vec<(usize, usize)> = e0
            .iter()
            .copied()
            .filter(|&(l, r)| !departed[l] && !failed[r])
            .collect();
        return Ok(Problem::new(
            Bipartite::from_edges(l_n, r_n, &live),
            base.num_resources,
            base.demand.clone(),
            base.capacity.clone(),
            base.alpha.clone(),
            base.kind.clone(),
            base.beta.clone(),
        ));
    }
    let mut cur = base.clone();
    for &(t, ev) in events {
        let ctx = |e: String| format!("checkpoint replay at slot {t}: {e}");
        match ev {
            FaultEvent::InstanceFail(r) => {
                failed[r] = true;
                cur.remove_instance_edges(r).map_err(&ctx)?;
            }
            FaultEvent::InstanceRecover(r) => {
                failed[r] = false;
                let back: Vec<(usize, usize)> = e0
                    .iter()
                    .copied()
                    .filter(|&(l, rr)| rr == r && !departed[l])
                    .collect();
                cur.restore_edges(&back).map_err(&ctx)?;
            }
            FaultEvent::PortDepart(l) => {
                departed[l] = true;
                cur.remove_port_edges(l).map_err(&ctx)?;
            }
            FaultEvent::PortArrive(l) => {
                departed[l] = false;
                let back: Vec<(usize, usize)> = e0
                    .iter()
                    .copied()
                    .filter(|&(ll, r)| ll == l && !failed[r])
                    .collect();
                cur.restore_edges(&back).map_err(&ctx)?;
            }
        }
    }
    Ok(cur)
}

/// Serialize the driver's full live state at a slot boundary.
#[allow(clippy::too_many_arguments)]
fn freeze(
    cursor: usize,
    next_event: usize,
    editions: usize,
    replans: usize,
    events_applied: usize,
    result: &RunResult,
    failed: &[bool],
    departed: &[bool],
    active: &[bool],
    state: &ClusterState,
    policy: &dyn Policy,
    arrivals: &dyn ArrivalModel,
    sharded: Option<(&ShardPlan, Option<&[ShardLedger]>)>,
) -> Checkpoint {
    let mut w = Writer::new();
    w.put_u64(cursor as u64);
    w.put_u64(next_event as u64);
    w.put_u64(editions as u64);
    w.put_u64(replans as u64);
    w.put_u64(events_applied as u64);
    w.put_str(&result.policy);
    w.put_f64(result.cumulative_reward);
    w.put_u64(result.clamped_total as u64);
    // elapsed wall time is deliberately absent: the blob stays
    // bit-identical across reruns of the same trajectory
    w.put_usize(result.records.len());
    for rec in &result.records {
        w.put_u64(rec.t as u64);
        w.put_f64(rec.q);
        w.put_f64(rec.gain);
        w.put_f64(rec.penalty);
        w.put_f64(rec.arrivals);
    }
    w.put_bools(failed);
    w.put_bools(departed);
    w.put_bools(active);
    state.snapshot(&mut w);
    let mut ps = Writer::section();
    policy.snapshot_state(&mut ps);
    w.put_bytes(&ps.into_bytes());
    let mut ar = Writer::section();
    arrivals.snapshot(&mut ar);
    w.put_bytes(&ar.into_bytes());
    match sharded {
        None => w.put_bool(false),
        Some((plan, ledgers)) => {
            w.put_bool(true);
            w.put_usize(plan.num_shards());
            let owners: Vec<u64> = plan.owners().iter().map(|&s| s as u64).collect();
            w.put_u64s(&owners);
            match ledgers {
                None => w.put_bool(false),
                Some(ls) => {
                    w.put_bool(true);
                    w.put_usize(ls.len());
                    for l in ls {
                        l.snapshot(&mut w);
                    }
                }
            }
        }
    }
    // Blob v2: streaming-ingest cursor/batch state (§SPerf-9).  The
    // call *drains* the model's in-flight queue into its batcher first
    // — the durability contract for mid-batch kills — then serializes
    // the sub-versioned section; non-streaming models write `absent`.
    match arrivals.ingest_checkpoint() {
        None => w.put_bool(false),
        Some(section) => {
            w.put_bool(true);
            w.put_bytes(&section);
        }
    }
    Checkpoint { slot: cursor as u64, bytes: w.into_bytes() }
}

/// The decoded half of [`freeze`], ready to be dropped into the
/// driver's locals.
struct Thawed {
    cursor: usize,
    next_event: usize,
    editions: usize,
    replans: usize,
    events_applied: usize,
    cumulative_reward: f64,
    clamped_total: usize,
    records: Vec<SlotRecord>,
    failed: Vec<bool>,
    departed: Vec<bool>,
    active: Vec<bool>,
    problem: Problem,
    state: ClusterState,
    plan: Option<Arc<ShardPlan>>,
    carry: Option<(Arc<ShardPlan>, Vec<ShardLedger>)>,
}

/// Restore a [`Checkpoint`]: decode the blob, replay the graph to the
/// stored fault-stream position, and rebuild ledger/policy/arrival
/// state in place.  `policy` and `arrivals` are reset-then-restored —
/// the snapshot carries the minimal sufficient state, the reset
/// re-derives everything else (publisher identity in particular goes
/// fresh, so the first post-restore decide is a conservative full
/// publish, exactly as after a topology edition).
fn thaw(
    ck: &Checkpoint,
    base: &Problem,
    e0: &[(usize, usize)],
    plan: &FaultPlan,
    rebuild: bool,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalModel,
) -> Result<Thawed, String> {
    let mut r = Reader::new(&ck.bytes)?;
    let cursor = r.get_u64()? as usize;
    let next_event = r.get_u64()? as usize;
    let editions = r.get_u64()? as usize;
    let replans = r.get_u64()? as usize;
    let events_applied = r.get_u64()? as usize;
    let name = r.get_str()?;
    if name != policy.name() {
        return Err(format!(
            "checkpoint policy mismatch: blob has {name:?}, resuming {:?}",
            policy.name()
        ));
    }
    if next_event > plan.events().len() {
        return Err(format!(
            "checkpoint fault cursor {next_event} beyond plan ({} events)",
            plan.events().len()
        ));
    }
    let cumulative_reward = r.get_f64()?;
    let clamped_total = r.get_u64()? as usize;
    let n_rec = r.get_usize()?;
    if n_rec != cursor {
        return Err(format!(
            "checkpoint has {n_rec} slot records for cursor {cursor}"
        ));
    }
    let mut records = Vec::with_capacity(n_rec);
    for _ in 0..n_rec {
        records.push(SlotRecord {
            t: r.get_u64()? as usize,
            q: r.get_f64()?,
            gain: r.get_f64()?,
            penalty: r.get_f64()?,
            arrivals: r.get_f64()?,
        });
    }
    let failed = r.get_bools()?;
    let departed = r.get_bools()?;
    let active = r.get_bools()?;
    if failed.len() != base.num_instances()
        || departed.len() != base.num_ports()
        || active.len() != base.num_ports()
    {
        return Err("checkpoint liveness masks do not match the problem".into());
    }
    let problem = replay_graph(base, e0, &plan.events()[..next_event], rebuild)?;
    let state = ClusterState::restore(&problem, &mut r)?;
    let pbytes = r.get_bytes()?;
    policy.reset(&problem);
    let mut pr = Reader::section(&pbytes);
    policy.restore_state(&problem, &mut pr)?;
    pr.finish()
        .map_err(|e| format!("policy snapshot section: {e}"))?;
    let abytes = r.get_bytes()?;
    let mut ar = Reader::section(&abytes);
    arrivals.restore(&mut ar)?;
    ar.finish()
        .map_err(|e| format!("arrival snapshot section: {e}"))?;
    let (plan_arc, carry) = if r.get_bool()? {
        let num_shards = r.get_usize()?;
        let owners64 = r.get_u64s()?;
        let mut owners = Vec::with_capacity(owners64.len());
        for o in owners64 {
            owners.push(
                u32::try_from(o).map_err(|_| format!("checkpoint owner {o} overflows u32"))?,
            );
        }
        let plan_arc = Arc::new(ShardPlan::with_owners(&problem, num_shards, owners)?);
        let carry = if r.get_bool()? {
            let n = r.get_usize()?;
            if n != num_shards {
                return Err(format!(
                    "checkpoint has {n} shard ledgers for {num_shards} shards"
                ));
            }
            let mut ledgers = Vec::with_capacity(n);
            for _ in 0..n {
                ledgers.push(ShardLedger::restore(&problem, &mut r)?);
            }
            Some((Arc::clone(&plan_arc), ledgers))
        } else {
            None
        };
        (Some(plan_arc), carry)
    } else {
        (None, None)
    };
    if r.get_bool()? {
        let ibytes = r.get_bytes()?;
        arrivals
            .ingest_restore(&ibytes)
            .map_err(|e| format!("ingest section: {e}"))?;
    }
    r.finish()?;
    Ok(Thawed {
        cursor,
        next_event,
        editions,
        replans,
        events_applied,
        cumulative_reward,
        clamped_total,
        records,
        failed,
        departed,
        active,
        problem,
        state,
        plan: plan_arc,
        carry,
    })
}

/// Outcome of a resilient run: the churned result plus the recovery
/// telemetry.  NB: `checkpoints_written` counts *writes*, and replayed
/// stretches re-write the boundaries they pass — after a kill the count
/// can exceed the number of distinct checkpoint slots (the re-written
/// blobs are bit-identical to the originals, so durability semantics
/// are unaffected).
pub struct ResilientOutcome {
    pub churn: ChurnOutcome,
    /// Checkpoint blobs written (including boundary re-writes on
    /// post-kill replay).
    pub checkpoints_written: usize,
    /// Checkpoint writes dropped by injected `ckpt_fails`.
    pub checkpoints_failed: usize,
    /// Process kills taken (and recovered from).
    pub kills: usize,
    /// The checkpoint slot each kill restored from, in kill order.
    pub restored_from: Vec<u64>,
    /// Injected worker panics/stalls that actually fired.
    pub worker_faults: usize,
}

/// Drive `policy` under *both* fault streams: the topology churn of
/// `plan` (identical semantics to [`run_churned`]) and the execution
/// faults of `exec` — worker panics/stalls (armed as a pool probe on
/// every segment), checkpoint-write failures, and process kills.  At a
/// kill slot the driver discards every live structure and resumes from
/// the last durable [`Checkpoint`]; `recovery.checkpoint_epoch` sets
/// the snapshot cadence (0 = only the implicit slot-0 snapshot, so a
/// kill replays from the start — legal, just slow).
///
/// The horizon is cut at topology-event slots, checkpoint boundaries
/// and kill slots; each boundary processes in a fixed order — kill,
/// checkpoint write, event drain, next segment — so a kill scheduled at
/// the same slot as a checkpoint fires *before* the write (the crash
/// you checkpoint through is the interesting one).
///
/// [`run_churned`]: crate::sim::faults::run_churned
#[allow(clippy::too_many_arguments)]
pub fn run_resilient(
    base: &Problem,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalModel,
    horizon: usize,
    shards: usize,
    plan: &FaultPlan,
    cfg: &FaultConfig,
    rebuild: bool,
    recovery: &RecoveryConfig,
    exec: &ExecFaultPlan,
) -> Result<ResilientOutcome, String> {
    let l_n = base.num_ports();
    let r_n = base.num_instances();
    let e0: Vec<(usize, usize)> = (0..base.num_edges())
        .map(|e| (base.graph.edge_port[e], base.graph.edge_instance[e]))
        .collect();
    let mut failed = vec![false; r_n];
    let mut departed = vec![false; l_n];
    let mut active = vec![true; l_n];

    let mut cur = base.clone();
    let serial = shards == 1;
    let mut state = ClusterState::new(&cur);
    let mut cur_plan: Option<Arc<ShardPlan>> =
        (!serial).then(|| Arc::new(ShardPlan::build(&cur, shards)));
    let mut carry: Option<(Arc<ShardPlan>, Vec<ShardLedger>)> = None;

    let mut result = RunResult {
        policy: policy.name().to_string(),
        records: Vec::with_capacity(horizon),
        ..Default::default()
    };
    let mut editions = 0usize;
    let mut replans = 0usize;
    let mut events_applied = 0usize;

    let epoch = recovery.checkpoint_epoch;
    let probe = exec.probe();
    let mut kills: VecDeque<u64> = exec.kills.iter().copied().collect();
    let mut store: Option<Checkpoint> = None;
    let mut checkpoints_written = 0usize;
    let mut checkpoints_failed = 0usize;
    let mut kills_taken = 0usize;
    let mut restored_from = Vec::new();

    let mut cursor = 0usize;
    let mut next_event = 0usize; // index into plan.events
    // Highest slot reached before any kill: segments below it re-run
    // previously executed slots, which the obs layer marks as recovery
    // replay rather than fresh progress.
    let mut replay_target = 0u64;
    loop {
        // 1. process kill: discard every live structure, thaw the last
        //    durable blob (out-of-order hand-built kills fire late,
        //    mirroring run_churned's clamping of event slots)
        if kills.front().map_or(false, |&k| k as usize <= cursor) {
            kills.pop_front();
            kills_taken += 1;
            obs::registry().counter("recover.kills").inc();
            obs::event(obs::SpanKind::KillTaken, cursor as u64, 0, editions as u32);
            replay_target = replay_target.max(cursor as u64);
            let ck = store.as_ref().ok_or_else(|| {
                "process kill precedes the initial checkpoint".to_string()
            })?;
            let th = obs::with_span(obs::SpanKind::CkptThaw, ck.slot, 0, || {
                thaw(ck, base, &e0, plan, rebuild, policy, arrivals)
            })?;
            cursor = th.cursor;
            next_event = th.next_event;
            editions = th.editions;
            replans = th.replans;
            events_applied = th.events_applied;
            result.cumulative_reward = th.cumulative_reward;
            result.clamped_total = th.clamped_total;
            result.records = th.records;
            failed = th.failed;
            departed = th.departed;
            active = th.active;
            cur = th.problem;
            state = th.state;
            cur_plan = th.plan;
            carry = th.carry;
            restored_from.push(ck.slot);
            continue;
        }

        // 2. checkpoint due at this boundary?  Slot 0 is the implicit,
        //    unconditional snapshot; epoch boundaries are skippable by
        //    injected write failures, and a boundary whose blob is
        //    already in the store (post-kill replay arriving back at
        //    the restore point) is not re-written.
        let due = cursor == 0 || (epoch > 0 && cursor % epoch == 0 && cursor < horizon);
        if due && store.as_ref().map(|c| c.slot) != Some(cursor as u64) {
            if cursor > 0 && exec.ckpt_fails.contains(&(cursor as u64)) {
                checkpoints_failed += 1;
                obs::registry().counter("recover.ckpts_dropped").inc();
                obs::event(obs::SpanKind::CkptDropped, cursor as u64, 0, editions as u32);
            } else {
                debug_assert!(
                    match (&carry, &cur_plan) {
                        (Some((cp, _)), Some(p)) => Arc::ptr_eq(cp, p),
                        (Some(_), None) => false,
                        (None, _) => true,
                    },
                    "carry plan diverged from the live plan at a checkpoint boundary"
                );
                let ck = obs::with_span(obs::SpanKind::CkptFreeze, cursor as u64, 0, || {
                    freeze(
                        cursor,
                        next_event,
                        editions,
                        replans,
                        events_applied,
                        &result,
                        &failed,
                        &departed,
                        &active,
                        &state,
                        &*policy,
                        &*arrivals,
                        cur_plan
                            .as_deref()
                            .map(|p| (p, carry.as_ref().map(|(_, l)| l.as_slice()))),
                    )
                });
                store = Some(ck);
                checkpoints_written += 1;
                obs::registry().counter("recover.ckpts_written").inc();
            }
        }

        // 3. apply every event scheduled at or before this boundary, in
        //    stream order (identical semantics to run_churned — the
        //    checkpoint above was written *pre-drain*, so a restore
        //    re-drains these events deterministically).  The old graph
        //    is only cloned when an event is actually pending: most
        //    boundaries here are checkpoint epochs, not editions.
        let pending = plan
            .events()
            .get(next_event)
            .map_or(false, |&(t, _)| t <= cursor);
        let old_graph = pending.then(|| cur.graph.clone());
        let mut touched = false;
        while let Some(&(t, ev)) = plan.events().get(next_event) {
            if t > cursor {
                break;
            }
            next_event += 1;
            events_applied += 1;
            let entity = match ev {
                FaultEvent::InstanceFail(r) | FaultEvent::InstanceRecover(r) => r,
                FaultEvent::PortDepart(l) | FaultEvent::PortArrive(l) => l,
            };
            obs::event(obs::SpanKind::FaultTopology, t as u64, entity as u32, editions as u32);
            let ctx = |e: String| format!("fault event at slot {t}: {e}");
            match ev {
                FaultEvent::InstanceFail(r) => {
                    if r >= r_n {
                        return Err(ctx(format!("instance {r} out of range (R={r_n})")));
                    }
                    failed[r] = true;
                    state.fail_instance(r, cfg.release).map_err(&ctx)?;
                    if !rebuild {
                        cur.remove_instance_edges(r).map_err(&ctx)?;
                    }
                    touched = true;
                }
                FaultEvent::InstanceRecover(r) => {
                    if r >= r_n {
                        return Err(ctx(format!("instance {r} out of range (R={r_n})")));
                    }
                    failed[r] = false;
                    state.recover_instance(r).map_err(&ctx)?;
                    if !rebuild {
                        let back: Vec<(usize, usize)> = e0
                            .iter()
                            .copied()
                            .filter(|&(l, rr)| rr == r && !departed[l])
                            .collect();
                        cur.restore_edges(&back).map_err(&ctx)?;
                    }
                    touched = true;
                }
                FaultEvent::PortDepart(l) => {
                    if l >= l_n {
                        return Err(ctx(format!("port {l} out of range (L={l_n})")));
                    }
                    departed[l] = true;
                    active[l] = false;
                    if !rebuild {
                        cur.remove_port_edges(l).map_err(&ctx)?;
                    }
                    touched = true;
                }
                FaultEvent::PortArrive(l) => {
                    if l >= l_n {
                        return Err(ctx(format!("port {l} out of range (L={l_n})")));
                    }
                    departed[l] = false;
                    active[l] = true;
                    if !rebuild {
                        let back: Vec<(usize, usize)> = e0
                            .iter()
                            .copied()
                            .filter(|&(ll, r)| ll == l && !failed[r])
                            .collect();
                        cur.restore_edges(&back).map_err(&ctx)?;
                    }
                    touched = true;
                }
            }
        }
        if touched {
            editions += 1;
            if rebuild {
                let live: Vec<(usize, usize)> = e0
                    .iter()
                    .copied()
                    .filter(|&(l, r)| !departed[l] && !failed[r])
                    .collect();
                cur = Problem::new(
                    Bipartite::from_edges(l_n, r_n, &live),
                    cur.num_resources,
                    cur.demand.clone(),
                    cur.capacity.clone(),
                    cur.alpha.clone(),
                    cur.kind.clone(),
                    cur.beta.clone(),
                );
            }
            if cfg!(debug_assertions) {
                for (r, &f) in failed.iter().enumerate() {
                    assert!(
                        !f || cur.graph.instance_degree(r) == 0,
                        "failed instance {r} still has channels at slot {cursor}"
                    );
                }
                for (l, &d) in departed.iter().enumerate() {
                    assert!(
                        !d || cur.graph.port_edges(l).len() == 0,
                        "departed port {l} still has channels at slot {cursor}"
                    );
                }
            }
            let old_graph = old_graph.as_ref().expect("touched implies a pending event");
            policy.remap(old_graph, &cur);
            if let Some(plan_arc) = &mut cur_plan {
                if rebuild {
                    *plan_arc = Arc::new(ShardPlan::build(&cur, shards));
                } else {
                    let refreshed = plan_arc
                        .refresh(&cur)
                        .map_err(|e| format!("fault replan at slot {cursor}: {e}"))?;
                    if refreshed.imbalance() > cfg.replan_threshold {
                        *plan_arc = Arc::new(ShardPlan::build(&cur, shards));
                        replans += 1;
                        obs::event(obs::SpanKind::Replan, cursor as u64, 0, editions as u32);
                    } else {
                        *plan_arc = Arc::new(refreshed);
                    }
                }
            }
        }
        if cursor >= horizon {
            break;
        }

        // 4. next boundary: topology event, checkpoint epoch, kill, or
        //    the horizon — whichever comes first.  Each candidate is
        //    strictly past the cursor (events ≤ cursor were drained,
        //    kills ≤ cursor were taken), so segments always progress.
        let mut seg_end = horizon;
        if let Some(&(t, _)) = plan.events().get(next_event) {
            seg_end = seg_end.min(t);
        }
        if epoch > 0 {
            seg_end = seg_end.min((cursor / epoch + 1) * epoch);
        }
        if let Some(&k) = kills.front() {
            seg_end = seg_end.min(k as usize);
        }
        debug_assert!(seg_end > cursor, "boundary scheduler failed to progress");

        // 5. run the segment [cursor, seg_end) on the current edition,
        //    with the worker-fault probe armed at the absolute slot base
        {
            // slots below the pre-kill high-water mark are re-executed
            // work: span them as recovery replay
            let _replay_span = if (cursor as u64) < replay_target {
                Some(obs::SpanTimer::start(
                    obs::SpanKind::RecoveryReplay,
                    cursor as u64,
                    0,
                ))
            } else {
                None
            };
            let mut gated = Gated { inner: &mut *arrivals, active: &active };
            let seg = if serial {
                let mut leader = Leader::resume(&cur, state);
                leader.arm_probe(Arc::clone(&probe), cursor as u64);
                let seg = leader.run(policy, &mut gated, seg_end - cursor);
                state = leader.into_state();
                seg
            } else {
                let plan_arc = cur_plan.as_ref().expect("sharded path has a plan");
                let mut leader =
                    ShardedLeader::resume(&cur, Arc::clone(plan_arc), state, carry.take());
                leader.arm_probe(Arc::clone(&probe), cursor as u64);
                let seg = leader.run(policy, &mut gated, seg_end - cursor);
                let (s, p, ledgers) = leader.into_parts();
                state = s;
                carry = Some((p, ledgers));
                seg
            };
            result.clamped_total += seg.clamped_total;
            result.cumulative_reward += seg.cumulative_reward;
            result.elapsed_secs += seg.elapsed_secs;
            for mut rec in seg.records {
                rec.t += cursor; // segment-local t → run-global t
                result.records.push(rec);
            }
        }
        cursor = seg_end;
    }

    Ok(ResilientOutcome {
        churn: ChurnOutcome {
            result,
            state,
            problem: cur,
            editions,
            replans,
            events: events_applied,
        },
        checkpoints_written,
        checkpoints_failed,
        kills: kills_taken,
        restored_from,
        worker_faults: probe.fired_count(),
    })
}

/// Scenario-level convenience: synthesize the problem, generate both
/// fault streams from the scenario, and run one policy resiliently with
/// the scenario's Bernoulli arrivals and shard budget.
pub fn run_resilient_scenario(
    scenario: &Scenario,
    policy: &mut dyn Policy,
    rebuild: bool,
) -> Result<ResilientOutcome, String> {
    let problem = synthesize(scenario);
    let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
    let exec = ExecFaultPlan::generate(
        scenario.horizon,
        scenario.parallel.shards.max(1),
        &scenario.recovery,
    );
    let mut arrivals = Bernoulli::uniform(
        problem.num_ports(),
        scenario.arrival_prob,
        scenario.seed ^ 0xA5A5,
    );
    policy.reset(&problem);
    run_resilient(
        &problem,
        policy,
        &mut arrivals,
        scenario.horizon,
        scenario.parallel.shards,
        &plan,
        &scenario.faults,
        rebuild,
        &scenario.recovery,
        &exec,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedulers::{Fairness, OgaSched};
    use crate::sim::faults::run_churned;
    use crate::utils::pool::ExecBudget;

    fn churny() -> FaultConfig {
        FaultConfig {
            instance_rate: 0.05,
            recover_rate: 0.2,
            port_rate: 0.03,
            rack_rate: 0.01,
            rack_size: 3,
            ..FaultConfig::default()
        }
    }

    fn small(horizon: usize) -> Scenario {
        let mut s = Scenario::small();
        s.horizon = horizon;
        s.faults = churny();
        s
    }

    fn baseline(
        scenario: &Scenario,
        problem: &Problem,
        plan: &FaultPlan,
        shards: usize,
    ) -> ChurnOutcome {
        let mut pol = OgaSched::new(problem, 2.0, 0.999, ExecBudget::serial());
        pol.reset(problem);
        let mut arr = Bernoulli::uniform(problem.num_ports(), 0.7, 11);
        run_churned(
            problem,
            &mut pol,
            &mut arr,
            scenario.horizon,
            shards,
            plan,
            &scenario.faults,
            false,
        )
        .unwrap()
    }

    fn resilient(
        scenario: &Scenario,
        problem: &Problem,
        plan: &FaultPlan,
        shards: usize,
        recovery: &RecoveryConfig,
        exec: &ExecFaultPlan,
    ) -> ResilientOutcome {
        let mut pol = OgaSched::new(problem, 2.0, 0.999, ExecBudget::serial());
        pol.reset(problem);
        let mut arr = Bernoulli::uniform(problem.num_ports(), 0.7, 11);
        run_resilient(
            problem,
            &mut pol,
            &mut arr,
            scenario.horizon,
            shards,
            plan,
            &scenario.faults,
            false,
            recovery,
            exec,
        )
        .unwrap()
    }

    fn assert_matches(got: &ResilientOutcome, want: &ChurnOutcome, problem: &Problem) {
        assert_eq!(got.churn.result.records, want.result.records);
        assert_eq!(
            got.churn.result.cumulative_reward,
            want.result.cumulative_reward
        );
        assert_eq!(got.churn.result.clamped_total, want.result.clamped_total);
        assert_eq!(got.churn.editions, want.editions);
        assert_eq!(got.churn.replans, want.replans);
        assert_eq!(got.churn.events, want.events);
        for r in 0..problem.num_instances() {
            for k in 0..problem.num_resources {
                assert_eq!(
                    got.churn.state.remaining_at(r, k),
                    want.state.remaining_at(r, k),
                    "ledger diverged at ({r},{k})"
                );
            }
        }
    }

    #[test]
    fn epoch_cuts_alone_do_not_change_results() {
        // checkpoint boundaries cut the horizon into extra segments;
        // the cut-invariance argument says that's float-invisible
        let scenario = small(90);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let recovery = RecoveryConfig { checkpoint_epoch: 7, ..RecoveryConfig::default() };
        let exec = ExecFaultPlan::default();
        for shards in [1usize, 3] {
            let want = baseline(&scenario, &problem, &plan, shards);
            let got = resilient(&scenario, &problem, &plan, shards, &recovery, &exec);
            assert!(got.checkpoints_written >= 90 / 7, "cadence not kept");
            assert_eq!(got.kills, 0);
            assert_matches(&got, &want, &problem);
        }
    }

    #[test]
    fn kill_and_resume_matches_uninterrupted_bitwise() {
        let scenario = small(80);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let recovery = RecoveryConfig { checkpoint_epoch: 5, ..RecoveryConfig::default() };
        let exec = ExecFaultPlan { kills: vec![7, 23, 61], ..ExecFaultPlan::default() };
        for shards in [1usize, 2, 4] {
            let want = baseline(&scenario, &problem, &plan, shards);
            let got = resilient(&scenario, &problem, &plan, shards, &recovery, &exec);
            assert_eq!(got.kills, 3);
            assert_eq!(got.restored_from, vec![5, 20, 60]);
            assert_matches(&got, &want, &problem);
        }
    }

    #[test]
    fn failed_checkpoint_writes_reach_further_back() {
        let scenario = small(60);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let recovery = RecoveryConfig { checkpoint_epoch: 5, ..RecoveryConfig::default() };
        // both epoch boundaries under the kill are dropped, so the
        // restore reaches all the way back to the implicit slot 0
        let exec = ExecFaultPlan {
            kills: vec![12],
            ckpt_fails: [5u64, 10].into_iter().collect(),
            ..ExecFaultPlan::default()
        };
        let want = baseline(&scenario, &problem, &plan, 1);
        let got = resilient(&scenario, &problem, &plan, 1, &recovery, &exec);
        // 2 drops before the kill + the same 2 boundaries re-dropped on
        // the post-kill replay (write telemetry double-counts on replay)
        assert_eq!(got.checkpoints_failed, 4);
        assert_eq!(got.restored_from, vec![0]);
        assert_matches(&got, &want, &problem);
    }

    #[test]
    fn worker_faults_compose_with_kills_bitwise() {
        let scenario = small(70);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let recovery = RecoveryConfig { checkpoint_epoch: 10, ..RecoveryConfig::default() };
        let exec = ExecFaultPlan {
            kills: vec![31],
            panics: [(9u64, 0u32), (40, 2)].into_iter().collect(),
            stalls: [(17u64, 1u32)].into_iter().collect(),
            stall_ms: 5,
            ..ExecFaultPlan::default()
        };
        let want = baseline(&scenario, &problem, &plan, 4);
        let got = resilient(&scenario, &problem, &plan, 4, &recovery, &exec);
        assert_eq!(got.kills, 1);
        assert!(got.worker_faults >= 2, "injected worker faults never fired");
        assert_matches(&got, &want, &problem);
    }

    #[test]
    fn checkpoint_blobs_are_deterministic_and_round_trip() {
        let scenario = small(40);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        let recovery = RecoveryConfig { checkpoint_epoch: 8, ..RecoveryConfig::default() };
        // same trajectory twice: every surviving blob must be
        // bit-identical, and a thaw of the final store must decode
        let run = || {
            let mut pol = Fairness::new();
            pol.reset(&problem);
            let mut arr = Bernoulli::uniform(problem.num_ports(), 0.7, 11);
            run_resilient(
                &problem,
                &mut pol,
                &mut arr,
                scenario.horizon,
                1,
                &plan,
                &scenario.faults,
                false,
                &recovery,
                &ExecFaultPlan { kills: vec![13], ..ExecFaultPlan::default() },
            )
            .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.churn.result.records, b.churn.result.records);
        assert_eq!(a.restored_from, b.restored_from);
        assert!(a.checkpoints_written >= b.restored_from.len());
    }

    #[test]
    fn replay_graph_matches_the_incremental_path() {
        let scenario = small(100);
        let problem = synthesize(&scenario);
        let plan = FaultPlan::for_problem(&problem, scenario.horizon, &scenario.faults);
        assert!(!plan.is_empty(), "churny plan must schedule events");
        let e0: Vec<(usize, usize)> = (0..problem.num_edges())
            .map(|e| (problem.graph.edge_port[e], problem.graph.edge_instance[e]))
            .collect();
        for cut in [0, 1, plan.events().len() / 2, plan.events().len()] {
            let inc = replay_graph(&problem, &e0, &plan.events()[..cut], false).unwrap();
            let reb = replay_graph(&problem, &e0, &plan.events()[..cut], true).unwrap();
            // both arms agree on the live edge *set*; the incremental
            // arm's ordering is path-dependent, so compare as sets
            let edges = |p: &Problem| {
                let mut es: Vec<(usize, usize)> = (0..p.num_edges())
                    .map(|e| (p.graph.edge_port[e], p.graph.edge_instance[e]))
                    .collect();
                es.sort_unstable();
                es
            };
            assert_eq!(edges(&inc), edges(&reb), "arms disagree at cut {cut}");
        }
    }

    #[test]
    fn scenario_driver_honours_the_recovery_section() {
        let mut scenario = small(50);
        scenario.recovery = RecoveryConfig {
            checkpoint_epoch: 6,
            kill_rate: 0.08,
            seed: 5,
            ..RecoveryConfig::default()
        };
        let out = run_resilient_scenario(&scenario, &mut Fairness::new(), false).unwrap();
        assert_eq!(out.churn.result.records.len(), 50);
        for (t, rec) in out.churn.result.records.iter().enumerate() {
            assert_eq!(rec.t, t);
        }
        assert!(out.checkpoints_written > 0);
    }
}
