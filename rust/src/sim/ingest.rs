//! Sustained-traffic ingest: a lock-free bounded MPSC event queue, a
//! count-threshold batcher that forms slot arrival vectors, per-port
//! arrival-rate EWMAs, and [`StreamArrivals`] — an [`ArrivalModel`]
//! whose slots are *formed from events* instead of drawn per slot.
//!
//! The queue follows the `obs::ring` idiom: fixed-capacity
//! `UnsafeCell` slots, monotonic seq counters published with
//! release/acquire pairs, drop-newest at capacity with a drop counter
//! (never overwrite), and a deterministic drain order.  Each producer
//! owns one single-producer lane; a global ticket counter stamps every
//! accepted event, and the consumer drains by popping the smallest
//! ticket among the lane heads.  Within a lane tickets are monotonic
//! (one producer), so per-producer FIFO always holds; once pushes are
//! quiesced, the drain order is the global ticket order — a pure
//! function of the queue contents, independent of drain timing
//! (`tests` pin both properties under contention).
//!
//! ## Checkpoint contract
//!
//! Streaming runs checkpoint through `sim::checkpoint`: the model's
//! [`ArrivalModel::ingest_checkpoint`] first *drains every in-flight
//! event* into the batcher (completed batches queue up, the partial
//! batch stays pending — batch boundaries are cut strictly at the
//! count threshold, so late draining never re-orders or re-mixes
//! batches), then serializes cursor + batch + EWMA state as a
//! sub-versioned section the checkpoint blob appends.  The same drain
//! runs as a `pool` shutdown hook (`pool::register_drain_hook`), so a
//! kill mid-batch freezes nothing in a non-checkpointable buffer and
//! resumes bitwise (`tests/recovery_parity.rs`).
//!
//! Bitwise rule of the module: with a single producer lane and
//! backpressure-safe refill (as [`StreamArrivals`] is driven), the
//! batch sequence is a pure function of the generator RNG stream —
//! queue occupancy at any instant (hence kill/freeze timing) cannot
//! change which events land in which batch or their in-batch
//! accumulation order.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::obs;
use crate::sim::arrivals::ArrivalModel;
use crate::utils::codec::{Reader, Writer};
use crate::utils::pool;
use crate::utils::rng::Rng;

/// Sub-format version of the ingest checkpoint section (independent of
/// the outer `PLCK` blob version; bump on layout change).
pub const INGEST_SECTION_VERSION: u32 = 1;

/// One arrival event: a job landing on a port.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ArrivalEvent {
    /// Global drain-order ticket, stamped when the push is accepted.
    pub ticket: u64,
    /// Arrival port `l`.
    pub port: u32,
    /// Job count added to `x[port]` (1.0 in the base model; the
    /// Sec. 3.4 multi-arrival extension uses larger weights).
    pub weight: f64,
}

/// One producer's bounded SPSC lane.  `head`/`tail` are monotonic
/// cursors (slot = cursor % capacity): the producer alone advances
/// `tail`, the consumer alone advances `head`, and a slot is fully
/// written before the release-store of `tail` publishes it.
struct Lane {
    buf: Box<[UnsafeCell<ArrivalEvent>]>,
    head: AtomicUsize,
    tail: AtomicUsize,
    taken: AtomicBool,
}

// SAFETY: single producer per lane (enforced by the `taken` flag on
// handle creation).  The producer writes slot `tail % cap` then
// release-stores `tail + 1`; consumers read only below an acquire-load
// of `tail` and *claim* an event with a CAS on `head`, copying the
// slot before the CAS — the producer can reuse a slot only after
// `head` has moved past it, so the winning consumer's copy is taken
// strictly before any overwrite, and a losing consumer discards its
// copy.  (The CAS tolerates the one legitimate second consumer: a
// `pool` shutdown drain hook firing from another thread.)
unsafe impl Send for Lane {}
unsafe impl Sync for Lane {}

impl Lane {
    fn new(capacity: usize) -> Lane {
        Lane {
            buf: (0..capacity).map(|_| UnsafeCell::new(ArrivalEvent::default())).collect(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            taken: AtomicBool::new(false),
        }
    }

    /// Producer-side: true iff the lane has no free slot right now.
    #[inline]
    fn full(&self) -> bool {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Acquire);
        tail - head >= self.buf.len()
    }

    /// Producer-side publish.  Caller has checked [`Lane::full`].
    #[inline]
    fn publish(&self, ev: ArrivalEvent) {
        let tail = self.tail.load(Ordering::Relaxed);
        // SAFETY: slot `tail % cap` is unpublished (consumer reads only
        // below `tail`) and free (producer checked occupancy).
        unsafe {
            *self.buf[tail % self.buf.len()].get() = ev;
        }
        self.tail.store(tail + 1, Ordering::Release);
    }

    /// Consumer-side: the lane head and its cursor, if any.
    #[inline]
    fn peek_at(&self) -> Option<(usize, ArrivalEvent)> {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        // SAFETY: index < tail was published by a release-store the
        // acquire above synchronizes with, and cannot be overwritten
        // until `head` advances past it (see the impl-level invariant).
        Some((head, unsafe { *self.buf[head % self.buf.len()].get() }))
    }

    /// Consumer-side: claim the event peeked at cursor `head`.  False
    /// means another consumer won the race — re-peek and retry.
    #[inline]
    fn claim(&self, head: usize) -> bool {
        self.head
            .compare_exchange(head, head + 1, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
    }

    fn len(&self) -> usize {
        let tail = self.tail.load(Ordering::Acquire);
        let head = self.head.load(Ordering::Acquire);
        tail - head
    }
}

/// State shared by the queue handle, its producers, and the registered
/// shutdown drain hook.
struct Shared {
    lanes: Box<[Lane]>,
    /// Global drain-order ticket source.
    ticket: AtomicU64,
    /// Accepted pushes (all lanes).
    pushed: AtomicU64,
    /// Drop-newest count: pushes refused at capacity (backpressure off).
    dropped: AtomicU64,
    /// Full-lane encounters that blocked a backpressuring producer.
    backpressure_waits: AtomicU64,
    /// Producers wait for space instead of dropping.
    backpressure: bool,
    /// Quiesced staging for [`IngestQueue::park_in_flight`]: events
    /// drained out of the lanes ahead of a shutdown/freeze, kept in
    /// ticket order.  `parked_len` lets the hot pop path skip the lock.
    parked: Mutex<VecDeque<ArrivalEvent>>,
    parked_len: AtomicUsize,
}

impl Shared {
    /// Claim the globally smallest-ticket lane head.  Restarts the
    /// k-way merge whenever another consumer wins the claim race.
    fn pop_lanes(&self) -> Option<ArrivalEvent> {
        loop {
            let mut best: Option<(usize, usize, ArrivalEvent)> = None;
            for (i, lane) in self.lanes.iter().enumerate() {
                if let Some((head, ev)) = lane.peek_at() {
                    if best.map_or(true, |(_, _, b)| ev.ticket < b.ticket) {
                        best = Some((i, head, ev));
                    }
                }
            }
            let (i, head, ev) = best?;
            if self.lanes[i].claim(head) {
                return Some(ev);
            }
        }
    }

    /// Consumer-side pop of the globally smallest ticket (parked events
    /// first — they always predate anything still in a lane, because
    /// parking empties every lane and tickets are monotonic).
    fn pop(&self) -> Option<ArrivalEvent> {
        if self.parked_len.load(Ordering::Relaxed) > 0 {
            let mut parked = self.parked.lock().unwrap();
            if let Some(ev) = parked.pop_front() {
                self.parked_len.store(parked.len(), Ordering::Relaxed);
                return Some(ev);
            }
        }
        self.pop_lanes()
    }

    /// Move every queued event into the parked staging (ticket order).
    /// Push-quiesced like `obs::ring::Ring::clear`: producers must not
    /// be racing a shutdown park.
    fn park_in_flight(&self) {
        let mut parked = self.parked.lock().unwrap();
        while let Some(ev) = self.pop_lanes() {
            parked.push_back(ev);
        }
        self.parked_len.store(parked.len(), Ordering::Relaxed);
    }

    fn len(&self) -> usize {
        self.parked_len.load(Ordering::Relaxed)
            + self.lanes.iter().map(Lane::len).sum::<usize>()
    }
}

/// The consumer handle of a bounded MPSC ingest queue.  Not `Clone`:
/// there is exactly one consumer; producers are separate
/// [`Producer`] handles (one per lane).
pub struct IngestQueue {
    shared: Arc<Shared>,
}

impl IngestQueue {
    /// `lanes` producer lanes of `capacity` slots each.  With
    /// `backpressure` true, producers spin for space; otherwise the
    /// newest event is dropped and counted.
    pub fn new(lanes: usize, capacity: usize, backpressure: bool) -> IngestQueue {
        assert!(lanes >= 1, "ingest: need at least one producer lane");
        assert!(capacity >= 1, "ingest: lane capacity must be >= 1");
        IngestQueue {
            shared: Arc::new(Shared {
                lanes: (0..lanes).map(|_| Lane::new(capacity)).collect(),
                ticket: AtomicU64::new(0),
                pushed: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
                backpressure_waits: AtomicU64::new(0),
                backpressure,
                parked: Mutex::new(VecDeque::new()),
                parked_len: AtomicUsize::new(0),
            }),
        }
    }

    /// The producer handle of `lane`.  Panics on a second take: the
    /// lane is single-producer by construction.
    pub fn producer(&self, lane: usize) -> Producer {
        let shared = Arc::clone(&self.shared);
        assert!(lane < shared.lanes.len(), "ingest: lane {lane} out of range");
        assert!(
            !shared.lanes[lane].taken.swap(true, Ordering::AcqRel),
            "ingest: lane {lane} already has a producer"
        );
        Producer { shared, lane }
    }

    /// Pop the globally smallest-ticket event (single consumer).
    pub fn pop(&self) -> Option<ArrivalEvent> {
        self.shared.pop()
    }

    /// Drain every queued event into the parked staging so nothing is
    /// stranded in lane buffers across a shutdown or freeze.
    /// Quiesced-only (no concurrent [`IngestQueue::pop`]).
    pub fn park_in_flight(&self) {
        self.shared.park_in_flight();
    }

    pub fn len(&self) -> usize {
        self.shared.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn pushed(&self) -> u64 {
        self.shared.pushed.load(Ordering::Relaxed)
    }

    pub fn dropped(&self) -> u64 {
        self.shared.dropped.load(Ordering::Relaxed)
    }

    pub fn backpressure_waits(&self) -> u64 {
        self.shared.backpressure_waits.load(Ordering::Relaxed)
    }

    /// Fold this queue's counters into the process-wide obs registry
    /// (called at report boundaries, not per event — queue-local
    /// counters stay exact for tests either way).
    pub fn publish_counters(&self) {
        let reg = obs::registry();
        reg.counter("ingest.events").add(self.pushed());
        reg.counter("ingest.dropped").add(self.dropped());
        reg.counter("ingest.backpressure_waits").add(self.backpressure_waits());
    }
}

/// A single lane's producer handle (`Send`, not `Clone`).
pub struct Producer {
    shared: Arc<Shared>,
    lane: usize,
}

impl Producer {
    /// Push an event.  Backpressure mode spins until space frees (never
    /// returns false); drop-newest mode refuses at capacity, counts the
    /// drop, and marks an `IngestDrop` obs instant.
    pub fn push(&self, port: u32, weight: f64) -> bool {
        let lane = &self.shared.lanes[self.lane];
        if lane.full() {
            if !self.shared.backpressure {
                self.shared.dropped.fetch_add(1, Ordering::Relaxed);
                obs::event(obs::SpanKind::IngestDrop, 0, self.lane as u32, 0);
                return false;
            }
            self.shared.backpressure_waits.fetch_add(1, Ordering::Relaxed);
            let mut spins = 0u32;
            while lane.full() {
                spins += 1;
                if spins % 64 == 0 {
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
            }
        }
        let ticket = self.shared.ticket.fetch_add(1, Ordering::Relaxed);
        lane.publish(ArrivalEvent { ticket, port, weight });
        self.shared.pushed.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Non-blocking push: false iff the lane is full right now (no drop
    /// is counted — the caller keeps the event and retries after the
    /// consumer drains).  [`StreamArrivals`] refills with this so a
    /// same-thread producer can never deadlock *or* lose events.
    pub fn try_push(&self, port: u32, weight: f64) -> bool {
        let lane = &self.shared.lanes[self.lane];
        if lane.full() {
            return false;
        }
        let ticket = self.shared.ticket.fetch_add(1, Ordering::Relaxed);
        lane.publish(ArrivalEvent { ticket, port, weight });
        self.shared.pushed.fetch_add(1, Ordering::Relaxed);
        true
    }
}

/// Count-threshold slot former: accumulates drained events into a
/// per-port arrival vector and cuts a batch every `batch_events`
/// events.  Completed batches queue until taken, so a full checkpoint
/// drain can outrun the slot loop without mixing batch boundaries.
#[derive(Debug)]
pub struct Batcher {
    batch_events: usize,
    ready: VecDeque<Vec<f64>>,
    x: Vec<f64>,
    in_batch: u64,
    events_total: u64,
    batches_total: u64,
}

impl Batcher {
    pub fn new(num_ports: usize, batch_events: usize) -> Batcher {
        assert!(batch_events >= 1, "ingest: batch_events must be >= 1");
        Batcher {
            batch_events,
            ready: VecDeque::new(),
            x: vec![0.0; num_ports],
            in_batch: 0,
            events_total: 0,
            batches_total: 0,
        }
    }

    /// Accumulate one drained event; cut a batch at the threshold.
    /// Accumulation order is drain order, so the per-port f64 sums are
    /// bit-reproducible for a given event sequence.
    pub fn push(&mut self, ev: &ArrivalEvent) {
        self.x[ev.port as usize] += ev.weight;
        self.in_batch += 1;
        self.events_total += 1;
        if self.in_batch as usize >= self.batch_events {
            let full = std::mem::replace(&mut self.x, vec![0.0; self.x.len()]);
            self.ready.push_back(full);
            self.in_batch = 0;
            self.batches_total += 1;
            obs::event(obs::SpanKind::BatchFormed, self.batches_total, 0, 0);
        }
    }

    /// Take the oldest completed batch into `x_out`.
    pub fn pop_batch(&mut self, x_out: &mut [f64]) -> bool {
        match self.ready.pop_front() {
            Some(b) => {
                x_out.copy_from_slice(&b);
                true
            }
            None => false,
        }
    }

    pub fn has_ready(&self) -> bool {
        !self.ready.is_empty()
    }

    /// Events accumulated into the pending (uncut) batch.
    pub fn pending_events(&self) -> u64 {
        self.in_batch
    }

    /// Total events drained through the batcher (the ingest cursor).
    pub fn events_total(&self) -> u64 {
        self.events_total
    }

    pub fn batches_total(&self) -> u64 {
        self.batches_total
    }

    /// Serialize cursor + completed-batch queue + pending partial batch
    /// (exact f64 bit patterns via the codec).
    pub fn snapshot(&self, w: &mut Writer) {
        w.put_usize(self.batch_events);
        w.put_usize(self.ready.len());
        for b in &self.ready {
            w.put_f64s(b);
        }
        w.put_f64s(&self.x);
        w.put_u64(self.in_batch);
        w.put_u64(self.events_total);
        w.put_u64(self.batches_total);
    }

    pub fn restore(&mut self, r: &mut Reader) -> Result<(), String> {
        let batch_events = r.get_usize()?;
        if batch_events != self.batch_events {
            return Err(format!(
                "ingest snapshot: batch_events {batch_events} vs configured {}",
                self.batch_events
            ));
        }
        let n_ready = r.get_usize()?;
        let mut ready = VecDeque::with_capacity(n_ready);
        for _ in 0..n_ready {
            let b = r.get_f64s()?;
            if b.len() != self.x.len() {
                return Err(format!(
                    "ingest snapshot: batch width {} vs {} ports",
                    b.len(),
                    self.x.len()
                ));
            }
            ready.push_back(b);
        }
        let x = r.get_f64s()?;
        if x.len() != self.x.len() {
            return Err(format!(
                "ingest snapshot: pending width {} vs {} ports",
                x.len(),
                self.x.len()
            ));
        }
        self.ready = ready;
        self.x = x;
        self.in_batch = r.get_u64()?;
        self.events_total = r.get_u64()?;
        self.batches_total = r.get_u64()?;
        Ok(())
    }

    fn reset(&mut self) {
        self.ready.clear();
        self.x.fill(0.0);
        self.in_batch = 0;
        self.events_total = 0;
        self.batches_total = 0;
    }
}

/// Per-port arrival-rate EWMAs over deterministic batch epochs — the
/// measurement hook the ROADMAP's arrival-aware shard re-plan needs.
/// Every `epoch_batches` batches: `rate_l = Σ x_l / epoch_batches`,
/// `ewma_l ← α·rate_l + (1−α)·ewma_l`, then the values are published
/// as fixed-point (×1e6) obs registry gauges `ingest.rate.port<l>`.
/// The update schedule is batch-counted, never wall-clock, so the EWMA
/// trajectory is bit-reproducible and checkpoint-exact.
#[derive(Debug)]
pub struct PortRates {
    alpha: f64,
    epoch_batches: u64,
    accum: Vec<f64>,
    batches_since: u64,
    ewma: Vec<f64>,
    epochs: u64,
}

/// Fixed-point scale of the published rate gauges (gauges are i64;
/// obs never records floats).
pub const RATE_GAUGE_SCALE: f64 = 1e6;

impl PortRates {
    pub fn new(num_ports: usize, alpha: f64, epoch_batches: usize) -> PortRates {
        assert!(epoch_batches >= 1, "ingest: ewma_epoch must be >= 1");
        assert!((0.0..=1.0).contains(&alpha), "ingest: ewma_alpha in [0, 1]");
        PortRates {
            alpha,
            epoch_batches: epoch_batches as u64,
            accum: vec![0.0; num_ports],
            batches_since: 0,
            ewma: vec![0.0; num_ports],
            epochs: 0,
        }
    }

    /// Fold one emitted batch; update + publish at epoch boundaries.
    pub fn observe_batch(&mut self, x: &[f64]) {
        for (a, &v) in self.accum.iter_mut().zip(x) {
            *a += v;
        }
        self.batches_since += 1;
        if self.batches_since < self.epoch_batches {
            return;
        }
        let inv = 1.0 / self.epoch_batches as f64;
        for (e, a) in self.ewma.iter_mut().zip(self.accum.iter_mut()) {
            let rate = *a * inv;
            *e = self.alpha * rate + (1.0 - self.alpha) * *e;
            *a = 0.0;
        }
        self.batches_since = 0;
        self.epochs += 1;
        self.publish();
    }

    /// Write the fixed-point gauges (idempotent; integer-only).
    pub fn publish(&self) {
        let reg = obs::registry();
        for (l, &e) in self.ewma.iter().enumerate() {
            reg.gauge(&format!("ingest.rate.port{l}")).set((e * RATE_GAUGE_SCALE).round() as i64);
        }
    }

    /// Completed EWMA epochs so far.
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    pub fn ewma(&self) -> &[f64] {
        &self.ewma
    }

    pub fn snapshot(&self, w: &mut Writer) {
        w.put_u64(self.epoch_batches);
        w.put_f64s(&self.accum);
        w.put_u64(self.batches_since);
        w.put_f64s(&self.ewma);
        w.put_u64(self.epochs);
    }

    pub fn restore(&mut self, r: &mut Reader) -> Result<(), String> {
        let epoch = r.get_u64()?;
        if epoch != self.epoch_batches {
            return Err(format!(
                "ingest snapshot: ewma epoch {epoch} vs configured {}",
                self.epoch_batches
            ));
        }
        let accum = r.get_f64s()?;
        let batches_since = r.get_u64()?;
        let ewma = r.get_f64s()?;
        let epochs = r.get_u64()?;
        if accum.len() != self.accum.len() || ewma.len() != self.ewma.len() {
            return Err("ingest snapshot: ewma width mismatch".to_string());
        }
        self.accum = accum;
        self.batches_since = batches_since;
        self.ewma = ewma;
        self.epochs = epochs;
        Ok(())
    }

    fn reset(&mut self) {
        self.accum.fill(0.0);
        self.batches_since = 0;
        self.ewma.fill(0.0);
        self.epochs = 0;
    }
}

/// Knobs of a [`StreamArrivals`] source (mirrors the `[ingest]` config
/// section; `config::Scenario` owns the parsed form).
#[derive(Clone, Copy, Debug)]
pub struct StreamParams {
    /// Lane capacity (events).
    pub capacity: usize,
    /// Events per formed slot batch.
    pub batch_events: usize,
    /// Events generated ahead per refill round — leftovers beyond one
    /// batch stay in flight in the queue, which is what makes the
    /// checkpoint drain contract non-trivial.
    pub burst: usize,
    /// Producer behavior at capacity for *external* producers; the
    /// model's own same-thread refill always uses the lossless
    /// `try_push` path regardless.
    pub backpressure: bool,
    /// EWMA smoothing factor α ∈ [0, 1].
    pub ewma_alpha: f64,
    /// Batches per EWMA epoch.
    pub ewma_epoch: usize,
}

impl Default for StreamParams {
    fn default() -> StreamParams {
        StreamParams {
            capacity: 1024,
            batch_events: 32,
            burst: 48,
            backpressure: true,
            ewma_alpha: 0.2,
            ewma_epoch: 16,
        }
    }
}

impl StreamParams {
    /// The parsed `[ingest]` config section as queue parameters.
    /// `config` stays a leaf layer, so its numeric defaults repeat the
    /// ones above; `config_defaults_mirror_stream_params` pins them
    /// equal.
    pub fn from_config(cfg: &crate::config::IngestConfig) -> StreamParams {
        StreamParams {
            capacity: cfg.capacity,
            batch_events: cfg.batch_events,
            burst: cfg.burst,
            backpressure: cfg.backpressure,
            ewma_alpha: cfg.ewma_alpha,
            ewma_epoch: cfg.ewma_epoch,
        }
    }
}

/// An [`ArrivalModel`] that forms each slot's x(t) by pushing a seeded
/// event stream through the real ingest queue + batcher.  Ports are
/// drawn uniformly per event, so x counts arrivals (the Sec. 3.4
/// multi-arrival shape).  Single lane, same-thread producer, lossless
/// refill: the batch sequence is a pure function of the RNG stream,
/// which keeps streaming runs inside every bitwise-parity contract
/// (worker budgets, kills, obs on/off).
pub struct StreamArrivals {
    rng: Rng,
    queue: IngestQueue,
    producer: Producer,
    batcher: Arc<Mutex<Batcher>>,
    rates: Mutex<PortRates>,
    params: StreamParams,
    num_ports: usize,
    hook: u64,
}

impl StreamArrivals {
    pub fn new(num_ports: usize, params: StreamParams, seed: u64) -> StreamArrivals {
        assert!(params.burst >= 1, "ingest: burst must be >= 1");
        let queue = IngestQueue::new(1, params.capacity, params.backpressure);
        let producer = queue.producer(0);
        let batcher = Arc::new(Mutex::new(Batcher::new(num_ports, params.batch_events)));
        // Kill/shutdown safety net: `pool::shutdown()` flushes every
        // in-flight event into checkpointable batch state before the
        // crews drain, so a freeze taken after shutdown sees no events
        // stranded in lane buffers.
        let hook = {
            let shared = Arc::clone(&queue.shared);
            let batcher = Arc::clone(&batcher);
            pool::register_drain_hook(Box::new(move || {
                let mut b = batcher.lock().unwrap();
                while let Some(ev) = shared.pop() {
                    b.push(&ev);
                }
            }))
        };
        StreamArrivals {
            rng: Rng::new(seed),
            queue,
            producer,
            batcher,
            rates: Mutex::new(PortRates::new(num_ports, params.ewma_alpha, params.ewma_epoch)),
            params,
            num_ports,
            hook,
        }
    }

    /// Drain every in-flight queue event into the batcher (the freeze
    /// path runs this before serializing, mirroring the shutdown hook).
    pub fn drain_in_flight(&self) {
        let mut b = self.batcher.lock().unwrap();
        while let Some(ev) = self.queue.pop() {
            b.push(&ev);
        }
    }

    /// The underlying queue (throughput harness + tests).
    pub fn queue(&self) -> &IngestQueue {
        &self.queue
    }

    /// Total batches emitted through [`ArrivalModel::next`] +
    /// checkpoint drains.
    pub fn batches_total(&self) -> u64 {
        self.batcher.lock().unwrap().batches_total()
    }

    /// Events drained through the batcher (the ingest cursor).
    pub fn events_total(&self) -> u64 {
        self.batcher.lock().unwrap().events_total()
    }

    /// Current per-port EWMA estimates (copied out).
    pub fn rate_ewma(&self) -> Vec<f64> {
        self.rates.lock().unwrap().ewma().to_vec()
    }
}

impl Drop for StreamArrivals {
    fn drop(&mut self) {
        pool::unregister_drain_hook(self.hook);
    }
}

impl ArrivalModel for StreamArrivals {
    fn name(&self) -> &'static str {
        "stream"
    }

    fn next(&mut self, x: &mut [f64]) {
        loop {
            {
                let mut b = self.batcher.lock().unwrap();
                if b.pop_batch(x) {
                    self.rates.lock().unwrap().observe_batch(x);
                    return;
                }
            }
            // refill a burst through the queue (lossless: a full lane
            // just ends the round early), then drain until a batch cuts
            for _ in 0..self.params.burst {
                let port = self.rng.below(self.num_ports) as u32;
                if !self.producer.try_push(port, 1.0) {
                    break;
                }
            }
            let mut b = self.batcher.lock().unwrap();
            while !b.has_ready() {
                match self.queue.pop() {
                    Some(ev) => b.push(&ev),
                    None => break,
                }
            }
        }
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
        self.drain_in_flight();
        self.batcher.lock().unwrap().reset();
        self.rates.lock().unwrap().reset();
    }

    fn snapshot(&self, w: &mut Writer) {
        w.put_u64s(&self.rng.state());
    }

    fn restore(&mut self, r: &mut Reader) -> Result<(), String> {
        let s = r.get_u64s()?;
        if s.len() != 4 {
            return Err(format!("stream snapshot: rng state len {}", s.len()));
        }
        self.rng = Rng::from_state([s[0], s[1], s[2], s[3]]);
        Ok(())
    }

    fn ingest_checkpoint(&self) -> Option<Vec<u8>> {
        self.drain_in_flight();
        let mut w = Writer::section();
        w.put_u32(INGEST_SECTION_VERSION);
        self.batcher.lock().unwrap().snapshot(&mut w);
        self.rates.lock().unwrap().snapshot(&mut w);
        Some(w.into_bytes())
    }

    fn ingest_restore(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = Reader::section(bytes);
        let v = r.get_u32()?;
        if v != INGEST_SECTION_VERSION {
            return Err(format!(
                "ingest section version {v} (this build reads {INGEST_SECTION_VERSION})"
            ));
        }
        // discard any live in-flight state: the checkpoint is the truth
        while self.queue.pop().is_some() {}
        self.batcher.lock().unwrap().restore(&mut r)?;
        self.rates.lock().unwrap().restore(&mut r)?;
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Producer-thread counts swept by the contention properties
    /// (mirrors the CI `PALLAS_WORKERS` axis).
    const PRODUCERS: [usize; 3] = [1, 2, 4];

    #[test]
    fn single_lane_fifo_and_drop_newest_accounting() {
        let q = IngestQueue::new(1, 4, false);
        let p = q.producer(0);
        for i in 0..7u32 {
            p.push(i, 1.0);
        }
        // capacity 4: events 0..4 kept, 4..7 dropped-newest
        assert_eq!(q.pushed(), 4);
        assert_eq!(q.dropped(), 3);
        let got: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|e| e.port).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
        // space freed: pushes succeed again, FIFO continues
        assert!(p.push(9, 1.0));
        assert_eq!(q.pop().unwrap().port, 9);
        assert!(q.is_empty());
    }

    #[test]
    fn quiesced_drain_order_is_the_global_ticket_order() {
        let q = IngestQueue::new(3, 8, false);
        let producers: Vec<Producer> = (0..3).map(|i| q.producer(i)).collect();
        // interleave pushes across lanes from one thread: tickets are
        // assigned in push order, so drain order must replay it
        let schedule = [0usize, 2, 1, 1, 0, 2, 2, 0, 1, 0];
        for (i, &lane) in schedule.iter().enumerate() {
            assert!(producers[lane].push(i as u32, 1.0));
        }
        let got: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|e| e.port).collect();
        assert_eq!(got, (0..10).collect::<Vec<u32>>());
    }

    #[test]
    fn second_producer_on_a_lane_panics() {
        let q = IngestQueue::new(1, 4, false);
        let _p = q.producer(0);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| q.producer(0)));
        assert!(err.is_err());
    }

    #[test]
    fn contended_producers_no_loss_no_duplication_below_capacity() {
        for &n in &PRODUCERS {
            let per = 500usize;
            // capacity >= per: below capacity, nothing may drop
            let q = IngestQueue::new(n, per, false);
            let handles: Vec<_> = (0..n)
                .map(|lane| {
                    let p = q.producer(lane);
                    std::thread::spawn(move || {
                        for i in 0..per {
                            assert!(p.push(lane as u32, (lane * per + i) as f64));
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(q.pushed(), (n * per) as u64);
            assert_eq!(q.dropped(), 0);
            let mut seen = vec![false; n * per];
            let mut last_ticket = None;
            let mut per_lane_prev: Vec<Option<f64>> = vec![None; n];
            while let Some(ev) = q.pop() {
                let id = ev.weight as usize;
                assert!(!seen[id], "duplicate event {id}");
                seen[id] = true;
                // quiesced drain: globally ascending tickets
                if let Some(t) = last_ticket {
                    assert!(ev.ticket > t);
                }
                last_ticket = Some(ev.ticket);
                // per-producer FIFO: within a lane, ids ascend
                let lane = ev.port as usize;
                if let Some(prev) = per_lane_prev[lane] {
                    assert!(ev.weight > prev, "lane {lane} reordered");
                }
                per_lane_prev[lane] = Some(ev.weight);
            }
            assert!(seen.iter().all(|&s| s), "lost events below capacity");
        }
    }

    #[test]
    fn contended_producers_at_capacity_account_every_event() {
        for &n in &PRODUCERS {
            let per = 300usize;
            let cap = 64usize;
            let q = IngestQueue::new(n, cap, false);
            let handles: Vec<_> = (0..n)
                .map(|lane| {
                    let p = q.producer(lane);
                    std::thread::spawn(move || {
                        let mut accepted = 0u64;
                        for i in 0..per {
                            if p.push(lane as u32, i as f64) {
                                accepted += 1;
                            }
                        }
                        accepted
                    })
                })
                .collect();
            let accepted: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            // deterministic accounting: accepted + dropped == attempted,
            // and the queue holds exactly the accepted survivors
            assert_eq!(accepted + q.dropped(), (n * per) as u64);
            assert_eq!(q.pushed(), accepted);
            let mut drained = 0u64;
            let mut per_lane_prev: Vec<Option<f64>> = vec![None; n];
            while let Some(ev) = q.pop() {
                drained += 1;
                let lane = ev.port as usize;
                if let Some(prev) = per_lane_prev[lane] {
                    assert!(ev.weight > prev, "drop-newest must keep lane prefix order");
                }
                per_lane_prev[lane] = Some(ev.weight);
            }
            assert_eq!(drained, accepted);
        }
    }

    #[test]
    fn backpressure_mode_never_drops_under_contention() {
        for &n in &PRODUCERS {
            let per = 400usize;
            let q = IngestQueue::new(n, 16, true);
            let handles: Vec<_> = (0..n)
                .map(|lane| {
                    let p = q.producer(lane);
                    std::thread::spawn(move || {
                        for i in 0..per {
                            assert!(p.push(lane as u32, i as f64));
                        }
                    })
                })
                .collect();
            // concurrent consumer keeps space freeing up
            let mut drained = 0u64;
            while drained < (n * per) as u64 {
                if q.pop().is_some() {
                    drained += 1;
                } else {
                    std::thread::yield_now();
                }
            }
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(q.dropped(), 0);
            assert_eq!(q.pushed(), (n * per) as u64);
            assert!(q.is_empty());
        }
    }

    #[test]
    fn park_in_flight_preserves_order_across_new_pushes() {
        let q = IngestQueue::new(2, 8, false);
        let p0 = q.producer(0);
        let p1 = q.producer(1);
        p0.push(0, 0.0);
        p1.push(1, 1.0);
        p0.push(0, 2.0);
        q.park_in_flight();
        assert_eq!(q.len(), 3);
        // later pushes carry larger tickets than anything parked
        p1.push(1, 3.0);
        let got: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.weight).collect();
        assert_eq!(got, vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn batcher_cuts_batches_exactly_at_the_threshold() {
        let mut b = Batcher::new(3, 2);
        let ev = |port: u32, t: u64| ArrivalEvent { ticket: t, port, weight: 1.0 };
        b.push(&ev(0, 0));
        assert!(!b.has_ready());
        b.push(&ev(2, 1));
        assert!(b.has_ready());
        b.push(&ev(1, 2)); // starts the *next* batch — no mixing
        let mut x = vec![0.0; 3];
        assert!(b.pop_batch(&mut x));
        assert_eq!(x, vec![1.0, 0.0, 1.0]);
        assert_eq!(b.pending_events(), 1);
        assert_eq!(b.events_total(), 3);
        assert_eq!(b.batches_total(), 1);
    }

    #[test]
    fn batcher_snapshot_round_trips_bitwise() {
        let mut b = Batcher::new(2, 3);
        for t in 0..8u64 {
            b.push(&ArrivalEvent { ticket: t, port: (t % 2) as u32, weight: 0.1 * t as f64 });
        }
        let mut w = Writer::section();
        b.snapshot(&mut w);
        let bytes = w.into_bytes();
        let mut fresh = Batcher::new(2, 3);
        let mut r = Reader::section(&bytes);
        fresh.restore(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(fresh.events_total(), b.events_total());
        assert_eq!(fresh.batches_total(), b.batches_total());
        assert_eq!(fresh.pending_events(), b.pending_events());
        let (mut xa, mut xb) = (vec![0.0; 2], vec![0.0; 2]);
        while b.pop_batch(&mut xa) {
            assert!(fresh.pop_batch(&mut xb));
            assert_eq!(xa, xb);
        }
        assert!(!fresh.pop_batch(&mut xb));
        // mismatched shape is rejected, not silently misread
        let mut other = Batcher::new(2, 4);
        assert!(other.restore(&mut Reader::section(&{
            let mut w = Writer::section();
            b.snapshot(&mut w);
            w.into_bytes()
        }))
        .is_err());
    }

    #[test]
    fn port_rates_update_on_deterministic_epochs() {
        let mut pr = PortRates::new(2, 0.5, 2);
        pr.observe_batch(&[2.0, 0.0]);
        assert_eq!(pr.epochs(), 0);
        assert_eq!(pr.ewma(), &[0.0, 0.0]);
        pr.observe_batch(&[0.0, 4.0]);
        // epoch: rates = (1.0, 2.0), ewma = 0.5·rate
        assert_eq!(pr.epochs(), 1);
        assert_eq!(pr.ewma(), &[0.5, 1.0]);
        pr.observe_batch(&[2.0, 2.0]);
        pr.observe_batch(&[2.0, 2.0]);
        assert_eq!(pr.epochs(), 2);
        assert_eq!(pr.ewma(), &[0.5 * 2.0 + 0.5 * 0.5, 0.5 * 2.0 + 0.5 * 1.0]);
        // gauges carry the fixed-point values — checked on a port index
        // no concurrent test publishes (the registry is process-global)
        let mut wide = PortRates::new(40, 1.0, 1);
        let mut batch = vec![0.0; 40];
        batch[39] = 3.5;
        wide.observe_batch(&batch);
        assert_eq!(
            obs::registry().gauge("ingest.rate.port39").get(),
            (3.5f64 * RATE_GAUGE_SCALE).round() as i64
        );
    }

    #[test]
    fn stream_arrivals_match_a_direct_rng_replay() {
        let params = StreamParams { batch_events: 8, burst: 13, ..StreamParams::default() };
        let mut s = StreamArrivals::new(5, params, 77);
        let mut rng = Rng::new(77);
        let mut x = vec![0.0; 5];
        for _ in 0..20 {
            s.next(&mut x);
            let mut want = vec![0.0; 5];
            for _ in 0..8 {
                want[rng.below(5)] += 1.0;
            }
            assert_eq!(x, want);
        }
    }

    #[test]
    fn stream_checkpoint_resumes_bitwise_mid_batch() {
        let params = StreamParams { batch_events: 8, burst: 13, ..StreamParams::default() };
        let mut live = StreamArrivals::new(4, params, 31);
        let mut fresh = StreamArrivals::new(4, params, 99);
        let mut x = vec![0.0; 4];
        for _ in 0..7 {
            live.next(&mut x);
        }
        // burst 13 vs batch 8: events accumulate in flight, so this
        // checkpoint lands mid-batch with a non-empty queue
        let mut w = Writer::section();
        live.snapshot(&mut w);
        let rng_bytes = w.into_bytes();
        let ingest_bytes = live.ingest_checkpoint().unwrap();
        assert!(live.queue().is_empty(), "ingest_checkpoint must drain in flight");
        let mut r = Reader::section(&rng_bytes);
        fresh.restore(&mut r).unwrap();
        r.finish().unwrap();
        fresh.ingest_restore(&ingest_bytes).unwrap();
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        for t in 0..25 {
            live.next(&mut a);
            fresh.next(&mut b);
            assert_eq!(a, b, "diverged at resumed batch {t}");
        }
        assert_eq!(live.rate_ewma(), fresh.rate_ewma());
    }

    #[test]
    fn pool_drain_hooks_flush_in_flight_events() {
        let params = StreamParams { batch_events: 8, burst: 13, ..StreamParams::default() };
        let mut s = StreamArrivals::new(4, params, 5);
        let mut x = vec![0.0; 4];
        s.next(&mut x); // pushes a 13-event burst, emits an 8-event batch
        pool::run_drain_hooks();
        assert!(s.queue().is_empty(), "drain hook must empty the queue");
        // every pushed event is now in checkpointable batch state, and
        // 13 % 8 != 0 proves the drain crossed a batch boundary mid-way
        assert_eq!(s.events_total(), s.queue().pushed());
        assert_eq!(s.events_total(), 13);
    }

    #[test]
    fn config_defaults_mirror_stream_params() {
        let c = StreamParams::from_config(&crate::config::IngestConfig::default());
        let d = StreamParams::default();
        assert_eq!(c.capacity, d.capacity);
        assert_eq!(c.batch_events, d.batch_events);
        assert_eq!(c.burst, d.burst);
        assert_eq!(c.backpressure, d.backpressure);
        assert_eq!(c.ewma_alpha, d.ewma_alpha);
        assert_eq!(c.ewma_epoch, d.ewma_epoch);
    }
}
