//! Overlapped slot pipeline (§SPerf-9).
//!
//! PR 4 sharded a single slot *across* cores; this module overlaps
//! *adjacent* slots.  A slot's wall time is decide (the policy's
//! gradient/quota reductions) followed by commit + reward merge — two
//! phases with no data dependency *between neighboring slots* beyond
//! the decision tensor itself: `Policy::decide` reads only
//! (problem, x, y, internal state), never the cluster ledger, so slot
//! t+1's decide can run while slot t's commit + reward merge is still
//! in flight.  The executor here does exactly that, depth-1:
//!
//! * the **leader thread** pulls arrivals, runs decide into the
//!   *permanent* front tensor `y_front` (same pointer every slot, so
//!   the sparse policies' incremental publishers see the identical
//!   buffer identity they see under lockstep), then copies the decision
//!   into one of two rotating back buffers and hands it — with an
//!   owned snapshot of the policy's `Touched` set — to
//! * the **committer thread**, which replays the exact tail of
//!   [`ShardedLeader::slot`] (`commit_and_reward`: sharded commit,
//!   sharded reward, release) in slot order over a bounded
//!   `sync_channel(1)`.
//!
//! **Bitwise parity with lockstep is a hard invariant**
//! (`tests/pipeline_parity.rs`).  It holds because commits stay
//! serially ordered (one committer, FIFO channel), arrivals are drawn
//! on the leader thread in serial order, and the only way commit could
//! feed *back* into decide — the ledger clamping an infeasible
//! decision in place — is outlawed here: the overlapped executor
//! asserts `clamped == 0` unconditionally (every lineup policy is
//! clamp-free by construction; a clamping policy must run lockstep).

use std::sync::mpsc;
use std::time::Instant;

use crate::coordinator::leader::{RunResult, SlotRecord};
use crate::coordinator::sharded::ShardedLeader;
use crate::obs;
use crate::reward::SlotReward;
use crate::schedulers::{Policy, Touched};
use crate::sim::arrivals::ArrivalModel;
use crate::utils::pool;

/// Execution mode of [`run_pipeline`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineMode {
    /// decide → commit → reward strictly in sequence per slot — the
    /// bitwise reference (the plain [`ShardedLeader::run`] schedule).
    Lockstep,
    /// Slot t+1's decide overlaps slot t's commit + reward merge.
    Overlapped,
}

impl PipelineMode {
    pub fn parse(s: &str) -> Result<PipelineMode, String> {
        match s {
            "lockstep" => Ok(PipelineMode::Lockstep),
            "overlapped" => Ok(PipelineMode::Overlapped),
            other => Err(format!(
                "unknown pipeline mode `{other}` (expected lockstep|overlapped)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PipelineMode::Lockstep => "lockstep",
            PipelineMode::Overlapped => "overlapped",
        }
    }
}

/// Owned snapshot of a policy's [`Touched`] report.  The borrowed form
/// points into the policy, which stays on the leader thread and mutates
/// on the very next decide — so the handoff to the committer captures
/// the dirty list by value (original order preserved: the Σ-delta
/// replay in `commit_list` is order-sensitive).
#[derive(Clone, Debug)]
pub enum TouchedOwned {
    All,
    Instances(Vec<usize>),
}

impl TouchedOwned {
    pub fn capture(t: Touched<'_>) -> TouchedOwned {
        match t {
            Touched::All => TouchedOwned::All,
            Touched::Instances(list) => TouchedOwned::Instances(list.to_vec()),
        }
    }

    pub fn as_touched(&self) -> Touched<'_> {
        match self {
            TouchedOwned::All => Touched::All,
            TouchedOwned::Instances(list) => Touched::Instances(list),
        }
    }
}

/// A pipeline run's outcome: the usual [`RunResult`] plus the final
/// decision tensor (the parity suite pins tensors across modes; plain
/// `run` paths drop it).
pub struct PipelineRun {
    pub result: RunResult,
    pub y: Vec<f64>,
}

/// One slot's handoff from leader to committer.
struct Work {
    t: usize,
    abs_slot: u64,
    /// `clock_ns` stamp at the slot's open (before decide) — the
    /// committer closes the "span.slot.ns" window with it.
    t0: u64,
    arrivals_sum: f64,
    x: Vec<f64>,
    y: Vec<f64>,
    touched: TouchedOwned,
}

/// One slot's results back from the committer (buffers ride along for
/// reuse).
struct Done {
    t: usize,
    clamped: usize,
    reward: SlotReward,
    arrivals_sum: f64,
    x: Vec<f64>,
    y: Vec<f64>,
}

/// Drive `policy` against `arrivals` for `horizon` slots under `mode`.
/// Both modes share [`ShardedLeader`]'s machinery slot-for-slot; the
/// parity suite pins them bit-to-bit on records, ledgers, and decision
/// tensors.
pub fn run_pipeline(
    leader: &mut ShardedLeader,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalModel,
    horizon: usize,
    mode: PipelineMode,
) -> PipelineRun {
    match mode {
        PipelineMode::Lockstep => run_lockstep(leader, policy, arrivals, horizon),
        PipelineMode::Overlapped => run_overlapped(leader, policy, arrivals, horizon),
    }
}

/// The reference schedule: [`ShardedLeader::run`]'s exact loop, driven
/// through [`ShardedLeader::slot`], with the final tensor kept.
fn run_lockstep(
    leader: &mut ShardedLeader,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalModel,
    horizon: usize,
) -> PipelineRun {
    crate::schedulers::begin_run_epoch();
    policy.bind_shards(leader.plan());
    let p = leader.problem();
    let mut x = vec![0.0; p.num_ports()];
    let mut y = vec![0.0; p.decision_len()];
    let mut result = RunResult {
        policy: policy.name().to_string(),
        records: Vec::with_capacity(horizon),
        ..Default::default()
    };
    let start = Instant::now();
    for t in 0..horizon {
        arrivals.next(&mut x);
        let (report, SlotReward { q, gain, penalty }) = leader.slot(policy, &x, &mut y);
        if leader.strict {
            assert_eq!(
                report.clamped, 0,
                "policy {} emitted an infeasible decision at t={t}",
                policy.name()
            );
        }
        result.clamped_total += report.clamped;
        result.cumulative_reward += q;
        result.records.push(SlotRecord { t, q, gain, penalty, arrivals: x.iter().sum() });
    }
    result.elapsed_secs = start.elapsed().as_secs_f64();
    if obs::enabled() {
        leader.publish_occupancy();
    }
    PipelineRun { result, y }
}

/// The overlapped schedule (module docs).  The committer owns
/// `&mut ShardedLeader` for the scope; the leader thread keeps only
/// the `'p` problem reference, the policy, and the arrival stream.
fn run_overlapped(
    leader: &mut ShardedLeader,
    policy: &mut dyn Policy,
    arrivals: &mut dyn ArrivalModel,
    horizon: usize,
) -> PipelineRun {
    crate::schedulers::begin_run_epoch();
    policy.bind_shards(leader.plan());
    let p = leader.problem();
    let base = leader.next_slot();
    let name = policy.name().to_string();
    let mut result = RunResult {
        policy: name.clone(),
        records: Vec::with_capacity(horizon),
        ..Default::default()
    };
    let mut y_front = vec![0.0; p.decision_len()];
    let start = Instant::now();
    if horizon > 0 {
        let (work_tx, work_rx) = mpsc::sync_channel::<Work>(1);
        let (done_tx, done_rx) = mpsc::channel::<Done>();
        std::thread::scope(|s| {
            let committer = {
                let leader = &mut *leader;
                s.spawn(move || {
                    while let Ok(mut w) = work_rx.recv() {
                        let (report, reward) = leader.commit_and_reward(
                            &w.x,
                            &mut w.y,
                            w.touched.as_touched(),
                            w.abs_slot,
                        );
                        obs::record_span_window(obs::SpanKind::Slot, w.abs_slot, 0, w.t0);
                        let done = Done {
                            t: w.t,
                            clamped: report.clamped,
                            reward,
                            arrivals_sum: w.arrivals_sum,
                            x: w.x,
                            y: w.y,
                        };
                        if done_tx.send(done).is_err() {
                            return; // leader unwound; stop quietly
                        }
                    }
                })
            };
            let mut collect = |d: Done, result: &mut RunResult| {
                assert_eq!(
                    d.t,
                    result.records.len(),
                    "committer results must arrive in slot order"
                );
                result.clamped_total += d.clamped;
                assert_eq!(
                    d.clamped, 0,
                    "overlapped pipeline requires clamp-free decisions \
                     (policy {name} clamped at t={}); run lockstep instead",
                    d.t
                );
                let SlotReward { q, gain, penalty } = d.reward;
                result.cumulative_reward += q;
                result.records.push(SlotRecord {
                    t: d.t,
                    q,
                    gain,
                    penalty,
                    arrivals: d.arrivals_sum,
                });
                (d.x, d.y)
            };
            // Two rotating buffer pairs: one can sit in the bounded
            // channel while the other is being committed; a third slot
            // is never needed at depth 1.
            let mut free: Vec<(Vec<f64>, Vec<f64>)> = (0..2)
                .map(|_| (vec![0.0; p.num_ports()], vec![0.0; p.decision_len()]))
                .collect();
            for t in 0..horizon {
                let (mut xb, mut yb) = match free.pop() {
                    Some(pair) => pair,
                    None => collect(done_rx.recv().expect("committer died"), &mut result),
                };
                arrivals.next(&mut xb);
                let abs_slot = base + t as u64;
                pool::set_slot(abs_slot);
                let t0 = obs::clock_ns();
                obs::with_span(obs::SpanKind::Decide, abs_slot, 0, || {
                    policy.decide(p, &xb, &mut y_front)
                });
                yb.copy_from_slice(&y_front);
                let touched = TouchedOwned::capture(policy.touched());
                let arrivals_sum = xb.iter().sum();
                let work =
                    Work { t, abs_slot, t0, arrivals_sum, x: xb, y: yb, touched };
                work_tx.send(work).expect("committer died");
            }
            drop(work_tx);
            while result.records.len() < horizon {
                let pair =
                    collect(done_rx.recv().expect("committer died"), &mut result);
                free.push(pair);
            }
            committer.join().expect("committer panicked");
        });
    }
    result.elapsed_secs = start.elapsed().as_secs_f64();
    if obs::enabled() {
        leader.publish_occupancy();
    }
    PipelineRun { result, y: y_front }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::schedulers::{Fairness, OgaSched};
    use crate::sim::arrivals::Bernoulli;
    use crate::sim::ingest::{StreamArrivals, StreamParams};
    use crate::traces::synthesize;
    use crate::utils::pool::ExecBudget;

    fn modes_agree(make: &dyn Fn(&crate::model::Problem) -> Box<dyn Policy>, seed: u64) {
        let p = synthesize(&Scenario::small());
        let horizon = 40;
        let run = |mode: PipelineMode| {
            let mut leader = ShardedLeader::new(&p, 3);
            let mut pol = make(&p);
            let mut arr = Bernoulli::uniform(p.num_ports(), 0.4, seed);
            let out = run_pipeline(&mut leader, pol.as_mut(), &mut arr, horizon, mode);
            let mut remaining = Vec::new();
            for r in 0..p.num_instances() {
                for k in 0..p.num_resources {
                    remaining.push(leader.state().remaining_at(r, k));
                }
            }
            (out, remaining)
        };
        let (lock, lock_rem) = run(PipelineMode::Lockstep);
        let (over, over_rem) = run(PipelineMode::Overlapped);
        assert_eq!(over.result.records, lock.result.records);
        assert_eq!(over.result.cumulative_reward, lock.result.cumulative_reward);
        assert_eq!(over.result.clamped_total, lock.result.clamped_total);
        assert_eq!(over.y, lock.y, "decision tensors diverged");
        assert_eq!(over_rem, lock_rem, "ledgers diverged");
    }

    #[test]
    fn overlapped_matches_lockstep_for_a_sparse_learner() {
        modes_agree(
            &|p| Box::new(OgaSched::new(p, 2.0, 0.999, ExecBudget::auto())),
            17,
        );
    }

    #[test]
    fn overlapped_matches_lockstep_for_a_reactive_baseline() {
        modes_agree(&|_| Box::new(Fairness::new()), 23);
    }

    #[test]
    fn overlapped_consumes_a_streaming_ingest_model() {
        let p = synthesize(&Scenario::small());
        let horizon = 30;
        let params = StreamParams { batch_events: 8, ..StreamParams::default() };
        let run = |mode: PipelineMode| {
            let mut leader = ShardedLeader::new(&p, 2);
            let mut pol = Fairness::new();
            let mut arr = StreamArrivals::new(p.num_ports(), params, 313);
            run_pipeline(&mut leader, &mut pol, &mut arr, horizon, mode)
        };
        let lock = run(PipelineMode::Lockstep);
        let over = run(PipelineMode::Overlapped);
        assert_eq!(over.result.records, lock.result.records);
        assert_eq!(over.y, lock.y);
    }

    #[test]
    fn mode_names_round_trip() {
        for mode in [PipelineMode::Lockstep, PipelineMode::Overlapped] {
            assert_eq!(PipelineMode::parse(mode.name()).unwrap(), mode);
        }
        assert!(PipelineMode::parse("eager").is_err());
    }

    #[test]
    fn touched_capture_preserves_the_dirty_order() {
        let list = [4usize, 1, 4, 2];
        let owned = TouchedOwned::capture(Touched::Instances(&list));
        match owned.as_touched() {
            Touched::Instances(got) => assert_eq!(got, &list),
            Touched::All => panic!("capture lost the list"),
        }
        assert!(matches!(TouchedOwned::capture(Touched::All).as_touched(), Touched::All));
    }

    #[test]
    fn zero_horizon_is_a_noop() {
        let p = synthesize(&Scenario::small());
        let mut leader = ShardedLeader::new(&p, 2);
        let mut pol = Fairness::new();
        let mut arr = Bernoulli::uniform(p.num_ports(), 0.5, 1);
        let out =
            run_pipeline(&mut leader, &mut pol, &mut arr, 0, PipelineMode::Overlapped);
        assert!(out.result.records.is_empty());
        assert_eq!(out.result.cumulative_reward, 0.0);
    }
}
