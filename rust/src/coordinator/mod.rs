//! Layer 3 — the Rust coordinator.  Owns the cluster ledger
//! ([`state::ClusterState`]), the slot event loop ([`leader::Leader`]),
//! the sharded single-slot pipeline ([`sharded::ShardedLeader`]), the
//! overlapped slot pipeline ([`pipeline::run_pipeline`]) and, through
//! `runtime/`, the PJRT-compiled OGA step on the hot path.

pub mod leader;
pub mod pipeline;
pub mod sharded;
pub mod state;

pub use leader::{run_lineup, Leader, RunResult, SlotRecord};
pub use pipeline::{run_pipeline, PipelineMode, PipelineRun, TouchedOwned};
pub use sharded::{ShardLedger, ShardPlan, ShardedLeader, OCCUPANCY_METRIC};
pub use state::{ClusterState, ReleaseMode};
