//! The leader event loop — Layer 3's request path.
//!
//! Per slot: ingest arrivals → ask the policy for a decision → commit it
//! to the cluster ledger → score the Eq. 8 reward → release.  The loop
//! is allocation-free in steady state (all buffers are pre-sized) and
//! records a full per-slot time series for the figure harnesses.
//!
//! §Perf-2: the whole slot is arrival-sparse.  The policy reports which
//! instances its decision changed ([`Policy::touched`]); the ledger
//! commits only those rows (`ClusterState::commit_instances`, with the
//! full sweep as fallback and parity oracle), release is lazy, and the
//! reward runs the kind-batched kernel over the arrived ports — so a
//! zero/sparse-arrival slot costs O(dirty), not O(|E|·K + R·K).
//! (§Perf-5: that kernel now streams through the `oga::kernels`
//! lane-tree layer — the same floats the sharded leader's scattered
//! reward merges, on either build path of the `simd` feature.)
//! [`run_lineup`] fans independent policy runs out under an
//! [`ExecBudget`] split of the worker budget (§Perf-4): up to
//! `budget.runs` concurrent runs, each owning a private
//! `budget.shards`-wide group that drives a sharded leader's
//! within-slot scatters — across-run and within-slot parallelism
//! compose instead of competing for one flat pool.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::sharded::{ShardPlan, ShardedLeader};
use crate::coordinator::state::ClusterState;
use crate::model::Problem;
use crate::obs;
use crate::reward::{slot_reward_kinds, SlotReward};
use crate::schedulers::{Policy, Touched};
use crate::sim::arrivals::ArrivalModel;
use crate::utils::pool;
use crate::utils::pool::ExecBudget;

/// Per-slot record (the recorder of sim/).  `PartialEq` is *bitwise*
/// (f64 ==) on purpose: recovery/churn parity tests assert records are
/// identical to the last bit, not merely close.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SlotRecord {
    pub t: usize,
    pub q: f64,
    pub gain: f64,
    pub penalty: f64,
    pub arrivals: f64,
}

/// Aggregated outcome of one run.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub policy: String,
    pub records: Vec<SlotRecord>,
    pub cumulative_reward: f64,
    pub clamped_total: usize,
    pub elapsed_secs: f64,
}

impl RunResult {
    /// Mean per-slot reward (the paper's "Avg. Reward" of Tab. 3).
    pub fn avg_reward(&self) -> f64 {
        if self.records.is_empty() {
            0.0
        } else {
            self.cumulative_reward / self.records.len() as f64
        }
    }

    pub fn rewards(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.q).collect()
    }

    /// Slots per second achieved by the whole loop.
    ///
    /// NB: for results produced by the parallel [`run_lineup`], wall
    /// clock includes contention with the other policies' runs (each
    /// run's inner scatters are confined to its own budget-granted
    /// shard group), so this measures sweep throughput, not isolated
    /// per-policy speed — time a direct [`Leader::run`] (e.g.
    /// `benches/hot_path.rs`) for that.
    pub fn throughput(&self) -> f64 {
        if self.elapsed_secs > 0.0 {
            self.records.len() as f64 / self.elapsed_secs
        } else {
            0.0
        }
    }
}

/// The L3 coordinator: owns the ledger, drives a policy over a horizon.
pub struct Leader<'p> {
    problem: &'p Problem,
    state: ClusterState,
    /// Assert that policies never need clamping (on in tests/debug).
    pub strict: bool,
    /// Execution-fault injector (`sim::checkpoint::run_resilient`):
    /// fired once per slot at a side-effect-free point, isolated by
    /// `pool::run_isolated` so an injected panic/stall is survived —
    /// identically to the sharded leader's per-shard fire sites.
    probe: Option<Arc<pool::ExecProbe>>,
    /// Global slot offset of this segment (resumed runs restart their
    /// local `t` at 0; probes and failure reports use absolute slots).
    slot_base: u64,
}

impl<'p> Leader<'p> {
    pub fn new(problem: &'p Problem) -> Self {
        Leader {
            problem,
            state: ClusterState::new(problem),
            strict: cfg!(debug_assertions),
            probe: None,
            slot_base: 0,
        }
    }

    /// Resume a run with a ledger carried over from an earlier segment
    /// (`sim::faults` drives segment-wise horizons across topology
    /// editions; the ledger's [R, K] shape is churn-invariant).
    pub fn resume(problem: &'p Problem, state: ClusterState) -> Self {
        Leader {
            problem,
            state,
            strict: cfg!(debug_assertions),
            probe: None,
            slot_base: 0,
        }
    }

    /// Arm an execution-fault probe and set the absolute slot of this
    /// segment's first local slot (see [`Leader::run`]).
    pub fn arm_probe(&mut self, probe: Arc<pool::ExecProbe>, slot_base: u64) {
        self.probe = Some(probe);
        self.slot_base = slot_base;
    }

    /// Hand the ledger to the next segment's leader.
    pub fn into_state(self) -> ClusterState {
        self.state
    }

    /// The cluster ledger (diagnostics and the shard-parity suite).
    pub fn state(&self) -> &ClusterState {
        &self.state
    }

    /// Mutable ledger access for the fault driver (`sim::faults` flags
    /// failed instances / forces releases between segments).
    pub fn state_mut(&mut self) -> &mut ClusterState {
        &mut self.state
    }

    /// Run `policy` against `arrivals` for `horizon` slots.  Does not
    /// reset the policy; it does bump the run epoch
    /// (`schedulers::begin_run_epoch`) so the sparse publishers
    /// re-prime against this run's fresh output buffer even when a
    /// policy is carried across runs without `reset`.
    pub fn run(
        &mut self,
        policy: &mut dyn Policy,
        arrivals: &mut dyn ArrivalModel,
        horizon: usize,
    ) -> RunResult {
        crate::schedulers::begin_run_epoch();
        let p = self.problem;
        let mut x = vec![0.0; p.num_ports()];
        let mut y = vec![0.0; p.decision_len()];
        let mut quota = vec![0.0; p.num_resources];
        let mut result = RunResult {
            policy: policy.name().to_string(),
            records: Vec::with_capacity(horizon),
            ..Default::default()
        };
        let start = Instant::now();
        for t in 0..horizon {
            let abs_slot = self.slot_base + t as u64;
            pool::set_slot(abs_slot);
            if let Some(probe) = &self.probe {
                // side-effect-free fire point (before decide): a caught
                // injected panic retries against unmodified state, so
                // the serial path survives faults without float drift
                pool::run_isolated(|| probe.fire(abs_slot, 0));
            }
            arrivals.next(&mut x);
            let _slot_span = obs::SpanTimer::start(obs::SpanKind::Slot, abs_slot, 0);
            obs::with_span(obs::SpanKind::Decide, abs_slot, 0, || {
                policy.decide(p, &x, &mut y)
            });
            // commit only what the policy changed (§Perf-2); the full
            // sweep remains the fallback for Touched::All policies
            let report = obs::with_span(obs::SpanKind::Commit, abs_slot, 0, || {
                match policy.touched() {
                    Touched::All => self.state.commit(p, &mut y),
                    Touched::Instances(instances) => {
                        self.state.commit_instances(p, &mut y, instances)
                    }
                }
            });
            if self.strict {
                assert_eq!(
                    report.clamped, 0,
                    "policy {} emitted an infeasible decision at t={t}",
                    policy.name()
                );
            }
            result.clamped_total += report.clamped;
            let SlotReward { q, gain, penalty } =
                obs::with_span(obs::SpanKind::Reward, abs_slot, 0, || {
                    slot_reward_kinds(p, p.kinds(), &x, &y, &mut quota)
                });
            self.state.release();
            result.cumulative_reward += q;
            result.records.push(SlotRecord {
                t,
                q,
                gain,
                penalty,
                arrivals: x.iter().sum(),
            });
        }
        result.elapsed_secs = start.elapsed().as_secs_f64();
        result
    }
}

/// Convenience: run a whole policy lineup on forked arrival streams
/// (every policy sees the *same* trajectory — seeded identically).
///
/// §Perf-4: the runs are independent (each gets its own leader, ledger
/// and arrival stream) and fan out under the [`ExecBudget`] split —
/// `budget.runs` concurrent runs, **each of which** owns a private
/// `budget.shards`-wide group driving a [`ShardedLeader`]'s within-slot
/// scatters.  A lineup of sharded leaders therefore uses both
/// parallelism levels at once; with a 1-shard budget the runs use plain
/// serial [`Leader`]s, and when the lineup is itself nested inside an
/// enclosing scatter (a figure sweep point) the runs fan over that
/// scope with serial insides.  All three shapes are bit-identical to
/// the serial loop (`ShardedLeader` ≡ `Leader` by the §Perf-3
/// invariant, pinned across budget splits by `tests/shard_parity.rs`);
/// per-run `elapsed_secs`/`throughput` however reflect the contended
/// sweep, not isolated policy speed (see [`RunResult::throughput`]).
pub fn run_lineup(
    problem: &Problem,
    policies: &mut [Box<dyn Policy + Send>],
    make_arrivals: impl Fn() -> Box<dyn ArrivalModel> + Sync,
    horizon: usize,
    budget: ExecBudget,
) -> Vec<RunResult> {
    let n = policies.len();
    if n == 0 {
        return Vec::new();
    }
    let serial_run = |policy: &mut Box<dyn Policy + Send>| {
        let mut leader = Leader::new(problem);
        let mut arrivals = make_arrivals();
        policy.reset(problem);
        leader.run(policy.as_mut(), arrivals.as_mut(), horizon)
    };
    if pool::nested_scope() {
        // already inside a scatter (e.g. a fig3 sweep point's shard
        // group): fan the runs over the enclosing scope's workers and
        // keep each run serial inside — no third budget level.
        return pool::parallel_map_mut(policies, n, |_, policy| serial_run(policy));
    }
    let b = budget.resolve(n);
    if b.shards <= 1 {
        return pool::parallel_map_mut(policies, b.runs, |_, policy| serial_run(policy));
    }
    // one deterministic plan shared by every run (same problem, same
    // shard count ⇒ same partition)
    let plan = Arc::new(ShardPlan::build(problem, b.shards));
    pool::scatter_runs(policies, b, |_, policy| {
        let mut leader = ShardedLeader::with_plan(problem, Arc::clone(&plan));
        let mut arrivals = make_arrivals();
        policy.reset(problem);
        leader.run(policy.as_mut(), arrivals.as_mut(), horizon)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::schedulers::{paper_lineup, Fairness, OgaSched};
    use crate::sim::arrivals::Bernoulli;
    use crate::traces::synthesize;

    #[test]
    fn leader_runs_and_records() {
        let p = synthesize(&Scenario::small());
        let mut leader = Leader::new(&p);
        let mut pol = Fairness::new();
        let mut arr = Bernoulli::uniform(p.num_ports(), 0.7, 1);
        let res = leader.run(&mut pol, &mut arr, 100);
        assert_eq!(res.records.len(), 100);
        assert_eq!(res.clamped_total, 0);
        assert!(res.cumulative_reward > 0.0);
        assert!((res.avg_reward() - res.cumulative_reward / 100.0).abs() < 1e-9);
    }

    #[test]
    fn identical_seeds_identical_trajectories() {
        let p = synthesize(&Scenario::small());
        let run = |seed| {
            let mut leader = Leader::new(&p);
            let mut pol = OgaSched::new(&p, 5.0, 0.999, ExecBudget::auto());
            let mut arr = Bernoulli::uniform(p.num_ports(), 0.7, seed);
            leader.run(&mut pol, &mut arr, 50).cumulative_reward
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn lineup_shares_the_trajectory() {
        let p = synthesize(&Scenario::small());
        let mut lineup = paper_lineup(&p, 5.0, 0.999, ExecBudget::auto());
        let results = run_lineup(
            &p,
            &mut lineup,
            || Box::new(Bernoulli::uniform(p.num_ports(), 0.7, 99)),
            60,
            ExecBudget::auto(),
        );
        assert_eq!(results.len(), 5);
        // identical arrival totals across policies
        let totals: Vec<f64> = results
            .iter()
            .map(|r| r.records.iter().map(|s| s.arrivals).sum::<f64>())
            .collect();
        for t in &totals[1..] {
            assert_eq!(*t, totals[0]);
        }
    }

    #[test]
    fn strict_mode_catches_infeasible_policies() {
        struct Evil;
        impl crate::schedulers::Policy for Evil {
            fn name(&self) -> &'static str {
                "EVIL"
            }
            fn decide(&mut self, p: &Problem, _x: &[f64], y: &mut [f64]) {
                y.fill(0.0);
                // grossly over-allocate the first edge
                let l = 0;
                let r = p.graph.ports_to_instances[0][0];
                y[p.idx(l, r, 0)] = p.capacity_at(r, 0) * 10.0;
            }
        }
        let p = synthesize(&Scenario::small());
        let mut leader = Leader::new(&p);
        leader.strict = true;
        let mut arr = Bernoulli::uniform(p.num_ports(), 1.0, 1);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            leader.run(&mut Evil, &mut arr, 2);
        }));
        assert!(result.is_err(), "strict leader must reject infeasible decisions");
    }
}
