//! Cluster-state ledger: the L3 coordinator's source of truth for what
//! is allocated where.  Decisions from a policy are *committed* for the
//! slot (validated against capacities, clamped if a buggy policy
//! overshoots) and *released* when the slot's jobs complete — multi-server
//! jobs hold their resources for the whole slot, which is exactly the
//! paper's one-slot occupancy model.
//!
//! §Perf-2 — incremental commits.  The ledger keeps the per-(r, k)
//! usage it derived from the last committed decision.  A policy that
//! knows which instances' columns changed since its previous decision
//! (`schedulers::Touched::Instances`) commits through
//! [`ClusterState::commit_instances`], which re-derives *only those
//! rows* — O(Σ_{r dirty} |L_r|·K) instead of the full |E|·K sweep — and
//! [`ClusterState::release`] is lazy (a flag flip, not an R·K capacity
//! copy), so a zero/sparse-arrival slot does O(dirty) ledger work end
//! to end.  The full-sweep [`ClusterState::commit`] remains both the
//! fallback for policies that rewrite their whole tensor and the parity
//! oracle for the property suite (`tests/ledger_parity.rs`): both paths
//! share [`ClusterState::commit_row`]'s gather order, so rows agree
//! bit-for-bit.

use crate::model::Problem;

/// Outcome of committing a decision tensor for one slot.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommitReport {
    /// Coordinates that had to be clamped to stay feasible.
    pub clamped: usize,
    /// Total resource units committed (Σ y).
    pub committed_units: f64,
}

/// One Neumaier (improved Kahan) step: add `v` into (`sum`, `comp`).
/// The delta-maintained running total accumulates one rounding error
/// per incremental commit; with compensation the reported Σ stays
/// exact to the last ulp over arbitrarily long horizons, so figure
/// harnesses can difference committed units across slots without the
/// 1e-9-relative drift the plain running sum allowed (ROADMAP "exact
/// committed-units").  The sharded leader replays the identical call
/// sequence when folding shard deltas, so serial and sharded totals
/// agree bit for bit.
#[inline]
pub(crate) fn kahan_add(sum: &mut f64, comp: &mut f64, v: f64) {
    let t = *sum + v;
    if sum.abs() >= v.abs() {
        *comp += (*sum - t) + v;
    } else {
        *comp += (v - t) + *sum;
    }
    *sum = t;
}

/// Re-derive instance r's usage row from `y`, clamping overshoot, and
/// store it into `usage[r*K..]`.  Shared by the serial ledger
/// ([`ClusterState`]) and the worker-owned shard ledgers
/// (`coordinator::sharded::ShardLedger`) so every path produces
/// bit-identical rows (same gather order over `instance_edge_ids`, same
/// clamp threshold).  Returns the number of clamped coordinates.
pub(crate) fn commit_row_into(
    problem: &Problem,
    y: &mut [f64],
    r: usize,
    usage: &mut [f64],
    row: &mut [f64],
    capacity: &[f64],
) -> usize {
    let k_n = problem.num_resources;
    let edges = problem.graph.instance_edge_ids(r);
    let mut clamped = 0;
    row.fill(0.0);
    for &e in edges {
        let base = e * k_n;
        for k in 0..k_n {
            row[k] += y[base + k];
        }
    }
    for k in 0..k_n {
        let used = row[k];
        let cap = capacity[r * k_n + k];
        // tolerance is relative: decisions produced by the f32
        // artifact path carry ~1e-6 relative rounding.
        if used > cap * (1.0 + 1e-5) + 1e-6 && used > 0.0 {
            // proportional clamp back to capacity
            let scale = cap / used;
            for &e in edges {
                let j = e * k_n + k;
                if y[j] != 0.0 {
                    y[j] *= scale;
                    clamped += 1;
                }
            }
            // re-gather the clamped column (≈ cap up to rounding):
            // the stored row must equal what a later sweep of the
            // unchanged tensor would derive, or the incremental and
            // full-sweep paths drift apart by ulps
            let mut clamped_used = 0.0;
            for &e in edges {
                clamped_used += y[e * k_n + k];
            }
            usage[r * k_n + k] = clamped_used;
        } else {
            usage[r * k_n + k] = used;
        }
    }
    clamped
}

/// What happens to a failed instance's in-flight units (`sim::faults`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReleaseMode {
    /// Let the current occupancy expire with the slot cycle: usage is
    /// retained until the next commit re-derives the row (which, with
    /// the instance's channels gone, derives zero).
    Drain,
    /// Forcibly release: zero the usage row immediately, folding the
    /// delta into the compensated running Σ.
    Release,
}

/// Capacity accounting for one slot at a time.
#[derive(Clone, Debug)]
pub struct ClusterState {
    /// Per-(r, k) units committed by the current (or, after `release`,
    /// the most recent) decision.  Persists across slots so the next
    /// commit can be driven by instance deltas.
    usage: Vec<f64>,
    /// Capacity snapshot for validation.
    capacity: Vec<f64>,
    /// Σ usage, maintained incrementally with Neumaier compensation
    /// ([`kahan_add`]; reported as committed_units, refreshed exactly on
    /// every full-sweep commit).
    total_units: f64,
    /// Compensation term of the running Σ.
    total_comp: f64,
    /// [K] scratch row for `commit_row`.
    row: Vec<f64>,
    /// [R] fault mask (`sim::faults`): a failed instance's remaining
    /// capacity reads zero until it recovers.
    failed: Vec<bool>,
    k_n: usize,
    in_slot: bool,
}

impl ClusterState {
    pub fn new(problem: &Problem) -> Self {
        ClusterState {
            usage: vec![0.0; problem.capacity.len()],
            capacity: problem.capacity.clone(),
            total_units: 0.0,
            total_comp: 0.0,
            row: vec![0.0; problem.num_resources],
            failed: vec![false; problem.num_instances()],
            k_n: problem.num_resources,
            in_slot: false,
        }
    }

    /// Mark instance `r` failed.  `Drain` only flags it (its stale usage
    /// expires at the next commit, which re-derives the row as zero once
    /// the instance's channels are gone); `Release` zeroes the usage row
    /// now, replaying the delta through the compensated Σ.  Errors name
    /// the instance so a bad fault event degrades with a diagnostic.
    pub fn fail_instance(&mut self, r: usize, mode: ReleaseMode) -> Result<(), String> {
        if r >= self.failed.len() {
            return Err(format!(
                "fail_instance: instance {r} out of range (R={})",
                self.failed.len()
            ));
        }
        self.failed[r] = true;
        if mode == ReleaseMode::Release {
            let base = r * self.k_n;
            for k in 0..self.k_n {
                let v = self.usage[base + k];
                if v != 0.0 {
                    kahan_add(&mut self.total_units, &mut self.total_comp, -v);
                    self.usage[base + k] = 0.0;
                }
            }
        }
        Ok(())
    }

    /// Clear instance `r`'s fault flag (recovery).
    pub fn recover_instance(&mut self, r: usize) -> Result<(), String> {
        if r >= self.failed.len() {
            return Err(format!(
                "recover_instance: instance {r} out of range (R={})",
                self.failed.len()
            ));
        }
        self.failed[r] = false;
        Ok(())
    }

    /// Is instance `r` currently failed?
    pub fn is_failed(&self, r: usize) -> bool {
        self.failed[r]
    }

    /// Commit a decision for the slot (full sweep over every instance).
    /// The ledger clamps any per-instance overshoot (defense against
    /// buggy policies) and reports how many coordinates were touched; a
    /// correct policy always reports `clamped == 0` (asserted by the
    /// engine in tests).
    pub fn commit(&mut self, problem: &Problem, y: &mut [f64]) -> CommitReport {
        assert!(!self.in_slot, "commit called twice without release");
        self.in_slot = true;
        let mut report = CommitReport::default();
        for r in 0..problem.num_instances() {
            self.commit_row(problem, y, r, &mut report);
        }
        // the full sweep refreshes the running total exactly
        self.refresh_total();
        report.committed_units = self.committed_units();
        report
    }

    /// Incremental commit: re-derive usage only for the listed
    /// instances' rows.  Correct iff `y` is unchanged outside the
    /// listed instances' columns since the previous commit — the
    /// `Touched::Instances` contract the policies uphold (and that
    /// `tests/ledger_parity.rs` checks against the full-sweep oracle).
    pub fn commit_instances(
        &mut self,
        problem: &Problem,
        y: &mut [f64],
        instances: &[usize],
    ) -> CommitReport {
        assert!(!self.in_slot, "commit called twice without release");
        self.in_slot = true;
        let mut report = CommitReport::default();
        let k_n = self.k_n;
        for &r in instances {
            let base = r * k_n;
            let old: f64 = self.usage[base..base + k_n].iter().sum();
            self.commit_row(problem, y, r, &mut report);
            let new: f64 = self.usage[base..base + k_n].iter().sum();
            kahan_add(&mut self.total_units, &mut self.total_comp, new - old);
        }
        report.committed_units = self.committed_units();
        report
    }

    /// Re-derive instance r's usage row from `y` (see [`commit_row_into`],
    /// the kernel shared with the shard ledgers).
    fn commit_row(
        &mut self,
        problem: &Problem,
        y: &mut [f64],
        r: usize,
        report: &mut CommitReport,
    ) {
        report.clamped +=
            commit_row_into(problem, y, r, &mut self.usage, &mut self.row, &self.capacity);
    }

    // --- sharded-commit seam (coordinator::sharded) --------------------
    //
    // The sharded leader commits rows in worker-owned `ShardLedger`s and
    // folds the results back here: `begin_merge` opens the slot,
    // `merge_row` copies an authoritative shard row, `add_total_delta`
    // replays the per-instance Σ deltas *in the policy's original dirty
    // order* through the same compensated accumulator the serial
    // `commit_instances` uses — which is what makes the folded total
    // bit-identical to the serial ledger's.

    /// Open the slot for an externally computed (sharded) commit.
    pub(crate) fn begin_merge(&mut self) {
        assert!(!self.in_slot, "commit called twice without release");
        self.in_slot = true;
    }

    /// Adopt instance r's usage row as computed by its owning shard.
    pub(crate) fn merge_row(&mut self, r: usize, row: &[f64]) {
        let base = r * self.k_n;
        self.usage[base..base + self.k_n].copy_from_slice(row);
    }

    /// Replay one incremental Σ-usage delta (Neumaier-compensated).
    pub(crate) fn add_total_delta(&mut self, delta: f64) {
        kahan_add(&mut self.total_units, &mut self.total_comp, delta);
    }

    /// Recompute Σ usage exactly (flat index order — the same reduction
    /// the serial full-sweep commit performs).
    pub(crate) fn refresh_total(&mut self) {
        self.total_units = self.usage.iter().sum();
        self.total_comp = 0.0;
    }

    /// The compensated running Σ usage.
    pub(crate) fn committed_units(&self) -> f64 {
        self.total_units + self.total_comp
    }

    /// Release the slot's resources (jobs completed).  Lazy: remaining
    /// capacity is recomputed from the retained usage on demand, so the
    /// release itself is O(1) instead of an R·K capacity copy.
    pub fn release(&mut self) {
        assert!(self.in_slot, "release without commit");
        self.in_slot = false;
    }

    pub fn remaining_at(&self, r: usize, k: usize) -> f64 {
        if self.failed[r] {
            return 0.0;
        }
        let i = r * self.k_n + k;
        if self.in_slot {
            self.capacity[i] - self.usage[i]
        } else {
            self.capacity[i]
        }
    }

    /// Serialize the ledger for `sim::checkpoint` — exact: the usage
    /// grid, the compensated running Σ (both words, so the Neumaier
    /// state resumes mid-stream without re-deriving), and the fault
    /// mask.  Capacity/scratch are rebuilt from the Problem on restore;
    /// `in_slot` is always false at a checkpoint boundary (snapshots
    /// are taken between slots, after release).
    pub fn snapshot(&self, w: &mut crate::utils::codec::Writer) {
        debug_assert!(!self.in_slot, "checkpoint mid-slot");
        w.put_f64s(&self.usage);
        w.put_f64(self.total_units);
        w.put_f64(self.total_comp);
        w.put_bools(&self.failed);
    }

    /// Rebuild a ledger from [`ClusterState::snapshot`] against the
    /// same topology edition the snapshot was taken on.
    pub fn restore(
        problem: &Problem,
        r: &mut crate::utils::codec::Reader,
    ) -> Result<ClusterState, String> {
        let usage = r.get_f64s()?;
        let total_units = r.get_f64()?;
        let total_comp = r.get_f64()?;
        let failed = r.get_bools()?;
        if usage.len() != problem.capacity.len() {
            return Err(format!(
                "ledger snapshot: usage len {} vs capacity len {} (wrong edition?)",
                usage.len(),
                problem.capacity.len()
            ));
        }
        if failed.len() != problem.num_instances() {
            return Err(format!(
                "ledger snapshot: fault mask len {} vs R={}",
                failed.len(),
                problem.num_instances()
            ));
        }
        Ok(ClusterState {
            usage,
            capacity: problem.capacity.clone(),
            total_units,
            total_comp,
            row: vec![0.0; problem.num_resources],
            failed,
            k_n: problem.num_resources,
            in_slot: false,
        })
    }

    /// Conservation invariant: remaining + committed == capacity, and
    /// remaining is never negative.
    pub fn check_conservation(&self) -> Result<(), String> {
        for (i, &used) in self.usage.iter().enumerate() {
            let cap = self.capacity[i];
            if used > cap + 1e-9 {
                return Err(format!(
                    "negative remaining at flat index {i}: {}",
                    cap - used
                ));
            }
            if used < -1e-9 {
                return Err(format!(
                    "remaining {} exceeds capacity {cap} at flat index {i}",
                    cap - used
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::traces::synthesize;

    #[test]
    fn commit_release_cycle() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let mut y = vec![0.0; p.decision_len()];
        y[p.idx(0, p.graph.ports_to_instances[0][0], 0)] = 0.5;
        let rep = st.commit(&p, &mut y);
        assert_eq!(rep.clamped, 0);
        assert!(rep.committed_units > 0.0);
        st.check_conservation().unwrap();
        st.release();
        st.check_conservation().unwrap();
        for r in 0..p.num_instances() {
            for k in 0..p.num_resources {
                assert_eq!(st.remaining_at(r, k), p.capacity_at(r, k));
            }
        }
    }

    #[test]
    fn overshoot_is_clamped_proportionally() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let r0 = p.graph.ports_to_instances[0][0];
        let mut y = vec![0.0; p.decision_len()];
        let cap = p.capacity_at(r0, 0);
        y[p.idx(0, r0, 0)] = cap * 2.0; // deliberate overshoot
        let rep = st.commit(&p, &mut y);
        assert!(rep.clamped > 0);
        assert!((y[p.idx(0, r0, 0)] - cap).abs() < 1e-9);
        st.check_conservation().unwrap();
    }

    #[test]
    fn incremental_commit_tracks_deltas() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let r0 = p.graph.ports_to_instances[0][0];
        let mut y = vec![0.0; p.decision_len()];
        // slot 1: commit the whole (zero) tensor via the dirty path
        let all: Vec<usize> = (0..p.num_instances()).collect();
        let rep = st.commit_instances(&p, &mut y, &all);
        assert_eq!(rep.committed_units, 0.0);
        st.release();
        // slot 2: only r0's column changes
        y[p.idx(0, r0, 0)] = 0.75;
        let rep = st.commit_instances(&p, &mut y, &[r0]);
        assert_eq!(rep.clamped, 0);
        assert!((rep.committed_units - 0.75).abs() < 1e-12);
        assert!((st.remaining_at(r0, 0) - (p.capacity_at(r0, 0) - 0.75)).abs() < 1e-12);
        st.release();
        // slot 3: nothing changes — empty dirty set, usage carries over
        let rep = st.commit_instances(&p, &mut y, &[]);
        assert!((rep.committed_units - 0.75).abs() < 1e-12);
        st.check_conservation().unwrap();
        st.release();
    }

    #[test]
    fn compensated_total_tracks_full_resum_over_long_horizons() {
        // The running Σ is maintained by per-instance deltas across the
        // whole horizon; Neumaier compensation keeps it pinned to the
        // fresh full-sweep re-sum far below the 1e-9-relative drift the
        // plain running sum allowed (ROADMAP "exact committed-units").
        let p = synthesize(&Scenario::small());
        let k_n = p.num_resources;
        let mut st = ClusterState::new(&p);
        let mut y = vec![0.0; p.decision_len()];
        let mut rng = crate::utils::rng::Rng::new(7);
        for t in 0..500 {
            let r = rng.below(p.num_instances());
            for &e in p.graph.instance_edge_ids(r) {
                for k in 0..k_n {
                    // magnitudes spanning ~9 decades stress the deltas
                    let v = if rng.bernoulli(0.3) {
                        rng.uniform(0.0, 1.0)
                    } else {
                        rng.uniform(0.0, 1e-9)
                    };
                    y[e * k_n + k] = v;
                }
            }
            let rep = st.commit_instances(&p, &mut y, &[r]);
            let mut y_oracle = y.clone();
            let mut oracle = ClusterState::new(&p);
            let rep_full = oracle.commit(&p, &mut y_oracle);
            let err = (rep.committed_units - rep_full.committed_units).abs();
            assert!(
                err <= 1e-12 * (1.0 + rep_full.committed_units.abs()),
                "t={t}: compensated {} vs full {}",
                rep.committed_units,
                rep_full.committed_units
            );
            st.release();
        }
    }

    #[test]
    fn kahan_add_recovers_cancelled_small_terms() {
        // 1e16 + 1 - 1e16 == 0 in plain f64; the compensated pair keeps
        // the 1.0
        let (mut sum, mut comp) = (0.0, 0.0);
        for v in [1e16, 1.0, -1e16] {
            kahan_add(&mut sum, &mut comp, v);
        }
        assert_eq!(sum + comp, 1.0);
    }

    #[test]
    fn release_is_lazy_but_exact() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let r0 = p.graph.ports_to_instances[0][0];
        let mut y = vec![0.0; p.decision_len()];
        y[p.idx(0, r0, 0)] = 1.0;
        st.commit_instances(&p, &mut y, &[r0]);
        assert!(st.remaining_at(r0, 0) < p.capacity_at(r0, 0));
        st.release();
        // after release every remaining reads full capacity again even
        // though usage is retained internally for the next delta commit
        assert_eq!(st.remaining_at(r0, 0), p.capacity_at(r0, 0));
    }

    #[test]
    fn fail_release_zeroes_usage_and_masks_remaining() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let r0 = p.graph.ports_to_instances[0][0];
        let mut y = vec![0.0; p.decision_len()];
        y[p.idx(0, r0, 0)] = 1.5;
        st.commit_instances(&p, &mut y, &[r0]);
        st.release();
        st.fail_instance(r0, ReleaseMode::Release).unwrap();
        assert!(st.is_failed(r0));
        assert_eq!(st.remaining_at(r0, 0), 0.0);
        assert_eq!(st.committed_units(), 0.0);
        st.recover_instance(r0).unwrap();
        assert!(!st.is_failed(r0));
        assert_eq!(st.remaining_at(r0, 0), p.capacity_at(r0, 0));
    }

    #[test]
    fn fail_drain_retains_usage_until_next_commit() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let r0 = p.graph.ports_to_instances[0][0];
        let mut y = vec![0.0; p.decision_len()];
        y[p.idx(0, r0, 0)] = 1.5;
        st.commit_instances(&p, &mut y, &[r0]);
        st.release();
        st.fail_instance(r0, ReleaseMode::Drain).unwrap();
        // draining: the units stay on the books ...
        assert!((st.committed_units() - 1.5).abs() < 1e-12);
        // ... but the failed instance offers no capacity
        assert_eq!(st.remaining_at(r0, 0), 0.0);
        // the next full sweep of a tensor without r0's units drains it
        let mut y2 = vec![0.0; p.decision_len()];
        st.commit(&p, &mut y2);
        st.release();
        assert_eq!(st.committed_units(), 0.0);
    }

    #[test]
    fn fault_errors_name_the_instance() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let r_n = p.num_instances();
        assert!(st
            .fail_instance(r_n + 3, ReleaseMode::Drain)
            .unwrap_err()
            .contains(&format!("instance {}", r_n + 3)));
        assert!(st
            .recover_instance(r_n)
            .unwrap_err()
            .contains(&format!("instance {r_n}")));
    }

    #[test]
    #[should_panic(expected = "commit called twice")]
    fn double_commit_panics() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let mut y = vec![0.0; p.decision_len()];
        st.commit(&p, &mut y);
        st.commit(&p, &mut y);
    }

    #[test]
    #[should_panic(expected = "commit called twice")]
    fn double_incremental_commit_panics() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let mut y = vec![0.0; p.decision_len()];
        st.commit_instances(&p, &mut y, &[]);
        st.commit_instances(&p, &mut y, &[]);
    }

    #[test]
    #[should_panic(expected = "release without commit")]
    fn release_without_commit_panics() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        st.release();
    }
}
