//! Cluster-state ledger: the L3 coordinator's source of truth for what
//! is allocated where.  Decisions from a policy are *committed* for the
//! slot (validated against capacities, clamped if a buggy policy
//! overshoots) and *released* when the slot's jobs complete — multi-server
//! jobs hold their resources for the whole slot, which is exactly the
//! paper's one-slot occupancy model.

use crate::model::Problem;

/// Outcome of committing a decision tensor for one slot.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommitReport {
    /// Coordinates that had to be clamped to stay feasible.
    pub clamped: usize,
    /// Total resource units committed (Σ y).
    pub committed_units: f64,
}

/// Capacity accounting for one slot at a time.
#[derive(Clone, Debug)]
pub struct ClusterState {
    /// Remaining capacity [R, K] within the current slot.
    remaining: Vec<f64>,
    /// Capacity snapshot for release/validation.
    capacity: Vec<f64>,
    k_n: usize,
    in_slot: bool,
}

impl ClusterState {
    pub fn new(problem: &Problem) -> Self {
        ClusterState {
            remaining: problem.capacity.clone(),
            capacity: problem.capacity.clone(),
            k_n: problem.num_resources,
            in_slot: false,
        }
    }

    /// Commit a decision for the slot.  The ledger clamps any
    /// per-instance overshoot (defense against buggy policies) and
    /// reports how many coordinates were touched; a correct policy
    /// always reports `clamped == 0` (asserted by the engine in tests).
    pub fn commit(&mut self, problem: &Problem, y: &mut [f64]) -> CommitReport {
        assert!(!self.in_slot, "commit called twice without release");
        self.in_slot = true;
        let mut report = CommitReport::default();
        let (r_n, k_n) = (problem.num_instances(), self.k_n);
        let g = &problem.graph;
        // Edge-major accumulation (§Perf): one sweep over y in memory
        // order, scattering per-(r, k) usage into `remaining` — O(|E|·K)
        // instead of the dense layout's L·R·K walk.
        self.remaining.fill(0.0);
        let rk = r_n * k_n;
        for e in 0..g.num_edges() {
            let rbase = g.edge_instance[e] * k_n;
            let base = e * k_n;
            for k in 0..k_n {
                self.remaining[rbase + k] += y[base + k];
            }
        }
        for i in 0..rk {
            let used = self.remaining[i];
            let cap = self.capacity[i];
            // tolerance is relative: decisions produced by the f32
            // artifact path carry ~1e-6 relative rounding.
            if used > cap * (1.0 + 1e-5) + 1e-6 && used > 0.0 {
                // proportional clamp back to capacity
                let scale = cap / used;
                let (r, k) = (i / k_n, i % k_n);
                for &e in g.instance_edge_ids(r) {
                    let j = e * k_n + k;
                    if y[j] != 0.0 {
                        y[j] *= scale;
                        report.clamped += 1;
                    }
                }
                report.committed_units += cap;
                self.remaining[i] = 0.0; // cap - cap
            } else {
                report.committed_units += used;
                self.remaining[i] = cap - used;
            }
        }
        report
    }

    /// Release the slot's resources (jobs completed).
    pub fn release(&mut self) {
        assert!(self.in_slot, "release without commit");
        self.remaining.copy_from_slice(&self.capacity);
        self.in_slot = false;
    }

    pub fn remaining_at(&self, r: usize, k: usize) -> f64 {
        self.remaining[r * self.k_n + k]
    }

    /// Conservation invariant: remaining + committed == capacity, and
    /// remaining is never negative.
    pub fn check_conservation(&self) -> Result<(), String> {
        for (i, &rem) in self.remaining.iter().enumerate() {
            if rem < -1e-9 {
                return Err(format!("negative remaining at flat index {i}: {rem}"));
            }
            if rem > self.capacity[i] + 1e-9 {
                return Err(format!(
                    "remaining {rem} exceeds capacity {} at flat index {i}",
                    self.capacity[i]
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::traces::synthesize;

    #[test]
    fn commit_release_cycle() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let mut y = vec![0.0; p.decision_len()];
        y[p.idx(0, p.graph.ports_to_instances[0][0], 0)] = 0.5;
        let rep = st.commit(&p, &mut y);
        assert_eq!(rep.clamped, 0);
        assert!(rep.committed_units > 0.0);
        st.check_conservation().unwrap();
        st.release();
        st.check_conservation().unwrap();
        for r in 0..p.num_instances() {
            for k in 0..p.num_resources {
                assert_eq!(st.remaining_at(r, k), p.capacity_at(r, k));
            }
        }
    }

    #[test]
    fn overshoot_is_clamped_proportionally() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let r0 = p.graph.ports_to_instances[0][0];
        let mut y = vec![0.0; p.decision_len()];
        let cap = p.capacity_at(r0, 0);
        y[p.idx(0, r0, 0)] = cap * 2.0; // deliberate overshoot
        let rep = st.commit(&p, &mut y);
        assert!(rep.clamped > 0);
        assert!((y[p.idx(0, r0, 0)] - cap).abs() < 1e-9);
        st.check_conservation().unwrap();
    }

    #[test]
    #[should_panic(expected = "commit called twice")]
    fn double_commit_panics() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        let mut y = vec![0.0; p.decision_len()];
        st.commit(&p, &mut y);
        st.commit(&p, &mut y);
    }

    #[test]
    #[should_panic(expected = "release without commit")]
    fn release_without_commit_panics() {
        let p = synthesize(&Scenario::small());
        let mut st = ClusterState::new(&p);
        st.release();
    }
}
