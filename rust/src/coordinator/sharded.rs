//! Sharded single-slot coordinator (§Perf-3).
//!
//! PR 2 made each slot's *work* scale with the arrived neighborhood,
//! but one slot still ran on one core — the only parallelism was
//! *across* runs (`run_lineup`).  The paper leans on "several parallel
//! sub-procedures" (Sec. 5) precisely so a single slot's latency keeps
//! dropping with cores; this module supplies that:
//!
//! * [`ShardPlan`] statically partitions the instances (and with them
//!   their edge-CSR columns — every edge belongs to exactly one
//!   instance) into per-worker shards balanced by Σ|E_r|·K, with a
//!   per-shard port→owned-edges CSR so a worker can walk an arrived
//!   port's slice restricted to its own coordinates.
//! * [`ShardLedger`] is a worker-owned copy of the incremental cluster
//!   ledger rows: each shard re-derives *its own* instances' usage rows
//!   (`coordinator::state::commit_row_into`, the same kernel the serial
//!   ledger runs) and reports mergeable per-row Σ deltas.
//! * [`ShardedLeader`] drives the whole slot through
//!   `utils::pool::parallel_shards`: decide (the OGA policies run their
//!   ascent/projection per shard when bound via `Policy::bind_shards`),
//!   commit (scatter the policy's `Touched` set by owner, commit rows in
//!   parallel, fold reports), reward (per-port kernels in parallel,
//!   merged serially), release.
//!
//! **Bitwise parity with the serial leader is a hard invariant**, kept
//! by construction and checked by `tests/shard_parity.rs`:
//! per-coordinate math is identical (shared kernels, disjoint writes),
//! and every floating-point *reduction* is replayed serially by the
//! leader in the serial code's order — per-port rewards merge in
//! ascending port order, ledger Σ deltas replay in the policy's
//! original dirty order through the same compensated accumulator, and
//! the full-sweep fallback re-sums usage in flat index order.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::leader::{RunResult, SlotRecord};
use crate::coordinator::state::{commit_row_into, ClusterState, CommitReport};
use crate::model::Problem;
use crate::obs;
use crate::oga::projection::project_instances_serial;
use crate::reward::{slot_reward_ports_sharded, PortRewardScratch, SlotReward};
use crate::schedulers::{Policy, Touched};
use crate::sim::arrivals::ArrivalModel;
use crate::utils::pool;
use crate::utils::pool::SyncSlice;

/// One arrived port's precomputed step parameters (phase A of a sharded
/// policy step): the per-port quota/k* reduction runs once on the leader
/// thread, then every shard worker replays the recorded step against
/// the edges it owns.
#[derive(Clone, Copy, Debug)]
pub struct ArrivedPort {
    pub l: usize,
    /// The per-coordinate scale of phase B: η_t · x_l for the fused
    /// ascent; plain x_l for the Eq. 50 two-pass gradient fill
    /// (`oga::gradient_sparse_sharded`), where η multiplies later in
    /// the sharded ascent.
    pub scale: f64,
    /// argmax_k β_k · quota_k (Eq. 27).
    pub kstar: usize,
    /// scale · β_{k*} — the additive penalty on the k* lane (OGA step;
    /// the mirror step folds β into its exponent instead).
    pub pen: f64,
}

/// Static partition of the instances into per-worker shards.
///
/// Built once per (problem, shard count); greedy LPT keeps the shards
/// balanced by column weight w_r = |E_r|·K: instances are placed
/// heaviest-first onto the currently lightest shard, which bounds
/// max load ≤ (Σw)/S + max_r w_r.  Assignment is deterministic (stable
/// ordering, lowest shard id wins ties), so a plan — and everything
/// scheduled through it — is reproducible.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    num_shards: usize,
    /// instance → owning shard.
    owner: Vec<u32>,
    /// Instances per shard, ascending.
    shard_instances: Vec<Vec<usize>>,
    /// Σ|E_r|·K per shard.
    loads: Vec<u64>,
    /// Per-shard port CSR: edges of port l owned by shard s are
    /// `port_edges[s][port_ptr[s][l]..port_ptr[s][l+1]]`.
    port_ptr: Vec<Vec<usize>>,
    port_edges: Vec<Vec<usize>>,
}

impl ShardPlan {
    /// Partition `problem`'s instances into `num_shards` shards
    /// (clamped to [1, R]; 0 means auto — the pool's worker budget).
    pub fn build(problem: &Problem, num_shards: usize) -> ShardPlan {
        let r_n = problem.num_instances();
        let auto = pool::default_workers(r_n.max(1));
        let want = if num_shards == 0 { auto } else { num_shards };
        let s_n = want.clamp(1, r_n.max(1));
        let k = problem.num_resources as u64;

        // LPT: heaviest instances first (stable, so ties keep id order).
        let mut order: Vec<usize> = (0..r_n).collect();
        order.sort_by_key(|&r| std::cmp::Reverse(problem.graph.instance_degree(r)));
        let mut owner = vec![0u32; r_n];
        let mut loads = vec![0u64; s_n];
        let mut shard_instances = vec![Vec::new(); s_n];
        for &r in &order {
            let mut s = 0;
            for c in 1..s_n {
                if loads[c] < loads[s] {
                    s = c;
                }
            }
            owner[r] = s as u32;
            loads[s] += problem.graph.instance_degree(r) as u64 * k;
            shard_instances[s].push(r);
        }
        for list in &mut shard_instances {
            list.sort_unstable();
        }

        // Per-shard port→owned-edges CSR (edges stay in port-major id
        // order inside each shard, matching the serial walk).
        let g = &problem.graph;
        let l_n = problem.num_ports();
        let mut port_ptr = Vec::with_capacity(s_n);
        let mut port_edges = Vec::with_capacity(s_n);
        for s in 0..s_n {
            let mut ptr = Vec::with_capacity(l_n + 1);
            let mut edges = Vec::new();
            ptr.push(0);
            for l in 0..l_n {
                for e in g.port_edges(l) {
                    if owner[g.edge_instance[e]] == s as u32 {
                        edges.push(e);
                    }
                }
                ptr.push(edges.len());
            }
            port_ptr.push(ptr);
            port_edges.push(edges);
        }

        ShardPlan { num_shards: s_n, owner, shard_instances, loads, port_ptr, port_edges }
    }

    /// Rebuild a plan from a snapshotted instance→shard assignment
    /// (`sim::checkpoint`).  Ownership is *path-dependent* — threshold
    /// re-plans re-run LPT against whatever topology edition triggered
    /// them — so a resumed run cannot re-derive it; the checkpoint
    /// carries the owner map and this reconstructs every derived
    /// structure against the restored topology.
    pub fn with_owners(
        problem: &Problem,
        num_shards: usize,
        owner: Vec<u32>,
    ) -> Result<ShardPlan, String> {
        let r_n = problem.num_instances();
        if num_shards == 0 {
            return Err("with_owners: zero shards".into());
        }
        if owner.len() != r_n {
            return Err(format!(
                "with_owners: owner map covers {} instances, problem has {r_n}",
                owner.len()
            ));
        }
        let mut shard_instances = vec![Vec::new(); num_shards];
        for (r, &s) in owner.iter().enumerate() {
            let s = s as usize;
            if s >= num_shards {
                return Err(format!(
                    "with_owners: instance {r} assigned to shard {s} (S={num_shards})"
                ));
            }
            shard_instances[s].push(r);
        }
        let skeleton = ShardPlan {
            num_shards,
            owner,
            shard_instances,
            loads: vec![0; num_shards],
            port_ptr: Vec::new(),
            port_edges: Vec::new(),
        };
        skeleton.refresh(problem)
    }

    /// The instance→shard assignment (snapshotted by `sim::checkpoint`).
    pub fn owners(&self) -> &[u32] {
        &self.owner
    }

    /// Rebuild the plan's *derived* structures against a mutated graph,
    /// keeping the instance→shard assignment (`sim::faults`' cheap
    /// re-plan path).  Every edge id shifts when the edge set changes,
    /// so the per-shard port CSRs and the loads must be re-derived even
    /// when ownership is unchanged.
    pub fn refresh(&self, problem: &Problem) -> Result<ShardPlan, String> {
        let r_n = problem.num_instances();
        if self.owner.len() != r_n {
            return Err(format!(
                "refresh: plan covers {} instances, problem has {r_n}",
                self.owner.len()
            ));
        }
        let k = problem.num_resources as u64;
        let mut loads = vec![0u64; self.num_shards];
        for r in 0..r_n {
            loads[self.owner[r] as usize] +=
                problem.graph.instance_degree(r) as u64 * k;
        }
        let g = &problem.graph;
        let l_n = problem.num_ports();
        let mut port_ptr = Vec::with_capacity(self.num_shards);
        let mut port_edges = Vec::with_capacity(self.num_shards);
        for s in 0..self.num_shards {
            let mut ptr = Vec::with_capacity(l_n + 1);
            let mut edges = Vec::new();
            ptr.push(0);
            for l in 0..l_n {
                for e in g.port_edges(l) {
                    if self.owner[g.edge_instance[e]] == s as u32 {
                        edges.push(e);
                    }
                }
                ptr.push(edges.len());
            }
            port_ptr.push(ptr);
            port_edges.push(edges);
        }
        let plan = ShardPlan {
            num_shards: self.num_shards,
            owner: self.owner.clone(),
            shard_instances: self.shard_instances.clone(),
            loads,
            port_ptr,
            port_edges,
        };
        if cfg!(debug_assertions) {
            if let Err(e) = plan.validate(problem) {
                return Err(format!("refresh produced an invalid plan: {e}"));
            }
        }
        Ok(plan)
    }

    /// Load imbalance: max shard load over the mean (1.0 = perfectly
    /// balanced).  `sim::faults` re-runs LPT only when churn pushes this
    /// past the configured threshold — the re-plan epoch rule.
    pub fn imbalance(&self) -> f64 {
        let max = self.loads.iter().copied().max().unwrap_or(0);
        let total: u64 = self.loads.iter().sum();
        if total == 0 {
            return 1.0;
        }
        max as f64 * self.num_shards as f64 / total as f64
    }

    #[inline]
    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    /// Owning shard of instance r.
    #[inline]
    pub fn owner(&self, r: usize) -> usize {
        self.owner[r] as usize
    }

    /// Instances owned by shard s (ascending).
    #[inline]
    pub fn instances(&self, s: usize) -> &[usize] {
        &self.shard_instances[s]
    }

    /// Σ|E_r|·K over shard s's instances.
    #[inline]
    pub fn load(&self, s: usize) -> u64 {
        self.loads[s]
    }

    /// Port l's edges owned by shard s, ascending edge id.
    #[inline]
    pub fn port_edges(&self, s: usize, l: usize) -> &[usize] {
        &self.port_edges[s][self.port_ptr[s][l]..self.port_ptr[s][l + 1]]
    }

    /// Internal-consistency check used by tests: the shards tile the
    /// instance set, the per-shard port CSRs tile every port's edge
    /// list, and the recorded loads match the weights.
    pub fn validate(&self, problem: &Problem) -> Result<(), String> {
        let r_n = problem.num_instances();
        if self.owner.len() != r_n {
            return Err("owner map has wrong length".into());
        }
        let mut seen = vec![false; r_n];
        for s in 0..self.num_shards {
            let mut load = 0u64;
            for &r in self.instances(s) {
                if seen[r] {
                    return Err(format!("instance {r} appears in two shards"));
                }
                seen[r] = true;
                if self.owner(r) != s {
                    return Err(format!("owner({r}) disagrees with shard {s}'s list"));
                }
                load += problem.graph.instance_degree(r) as u64
                    * problem.num_resources as u64;
            }
            if load != self.loads[s] {
                return Err(format!("shard {s} load {} != recorded {}", load, self.loads[s]));
            }
        }
        if seen.iter().any(|&s| !s) {
            return Err("some instance is unassigned".into());
        }
        for l in 0..problem.num_ports() {
            let mut count = 0;
            for s in 0..self.num_shards {
                for &e in self.port_edges(s, l) {
                    if problem.graph.edge_port[e] != l {
                        return Err(format!("edge {e} filed under wrong port {l}"));
                    }
                    if self.owner(problem.graph.edge_instance[e]) != s {
                        return Err(format!("edge {e} filed under wrong shard {s}"));
                    }
                    count += 1;
                }
            }
            if count != problem.graph.port_edges(l).len() {
                return Err(format!("shard port lists do not tile port {l}'s edges"));
            }
        }
        Ok(())
    }
}

/// Worker-owned rows of the incremental cluster ledger.  Only the
/// owning shard ever commits an instance, so its rows here are
/// authoritative between slots; the leader folds them into the global
/// [`ClusterState`] after each scatter.
#[derive(Clone, Debug)]
pub struct ShardLedger {
    /// [R, K]; only the owned rows are meaningful.
    usage: Vec<f64>,
    /// [K] scratch for `commit_row_into`.
    row: Vec<f64>,
}

impl ShardLedger {
    pub fn new(problem: &Problem) -> Self {
        ShardLedger {
            usage: vec![0.0; problem.capacity.len()],
            row: vec![0.0; problem.num_resources],
        }
    }

    /// Re-derive instance r's usage row from `y` (clamping overshoot
    /// exactly like the serial ledger) and return the row's Σ delta —
    /// the same `new − old` float the serial `commit_instances`
    /// accumulates.
    fn commit_instance(
        &mut self,
        problem: &Problem,
        y: &mut [f64],
        r: usize,
        clamped: &mut usize,
    ) -> f64 {
        let k_n = problem.num_resources;
        let base = r * k_n;
        let old: f64 = self.usage[base..base + k_n].iter().sum();
        *clamped +=
            commit_row_into(problem, y, r, &mut self.usage, &mut self.row, &problem.capacity);
        let new: f64 = self.usage[base..base + k_n].iter().sum();
        new - old
    }

    /// Instance r's usage row.
    #[inline]
    fn row_of(&self, r: usize, k_n: usize) -> &[f64] {
        &self.usage[r * k_n..(r + 1) * k_n]
    }

    /// Serialize the shard's rows (`sim::checkpoint`).  The full [R, K]
    /// grid is written — only the owned rows are meaningful, but the
    /// owner set is the plan's concern and writing the grid keeps the
    /// blob layout independent of it.
    pub fn snapshot(&self, w: &mut crate::utils::codec::Writer) {
        w.put_f64s(&self.usage);
    }

    /// Rebuild from [`ShardLedger::snapshot`] against the same edition.
    pub fn restore(
        problem: &Problem,
        r: &mut crate::utils::codec::Reader,
    ) -> Result<ShardLedger, String> {
        let usage = r.get_f64s()?;
        if usage.len() != problem.capacity.len() {
            return Err(format!(
                "shard ledger snapshot: usage len {} vs capacity len {}",
                usage.len(),
                problem.capacity.len()
            ));
        }
        Ok(ShardLedger { usage, row: vec![0.0; problem.num_resources] })
    }
}

/// Registry name of the per-(slot, shard) edges-touched occupancy
/// histogram published by [`ShardedLeader::publish_occupancy`].
pub const OCCUPANCY_METRIC: &str = "sharded.occupancy_edges";

/// Per-shard worker state: the ledger shard plus per-slot scratch.
struct ShardWorker {
    ledger: ShardLedger,
    /// Positions (indices into the slot's dirty list) routed to this
    /// shard for the current slot (see `ShardedLeader::commit_list`).
    assigned: Vec<usize>,
    clamped: usize,
}

/// The sharded L3 coordinator: same contract as [`super::Leader`], but a
/// single slot's decide/commit/reward fan out over the persistent
/// worker pool according to a [`ShardPlan`].
pub struct ShardedLeader<'p> {
    problem: &'p Problem,
    state: ClusterState,
    plan: Arc<ShardPlan>,
    workers: Vec<ShardWorker>,
    /// Σ-delta scratch indexed by *position in the slot's dirty list*
    /// (not by instance), so a duplicated instance id replays its
    /// per-occurrence deltas exactly like the serial ledger would.
    /// Grown on demand; positions are unique by construction.
    delta_of: Vec<f64>,
    /// Arrived ports of the current slot (ascending).
    arrived: Vec<usize>,
    /// Per-arrived-position reward slots of the scattered reward stage
    /// (`reward::slot_reward_ports_sharded`, §Perf-5).
    reward_scratch: PortRewardScratch,
    /// Execution-fault probe (`sim::faults::ExecFaultPlan`): fired at
    /// the entry of every per-shard commit closure, *before* any ledger
    /// or decision write, so an injected panic/stall is retried from a
    /// clean slate and can never change floats.
    probe: Option<Arc<pool::ExecProbe>>,
    /// Absolute slot of the next [`ShardedLeader::slot`] call.  Resumed
    /// segments restart their local `t` at 0; probes and failure reports
    /// key on absolute slots, so the driver re-bases this via
    /// [`ShardedLeader::arm_probe`].
    next_slot: u64,
    /// Per-(slot, shard) edges-touched telemetry accumulated by the
    /// reward stage into a leader-local log₂ histogram (surfaces LPT
    /// skew under sparse arrivals for the hot-path bench and `figure
    /// sparse`); [`ShardedLeader::publish_occupancy`] folds it into
    /// the obs registry under [`OCCUPANCY_METRIC`].  Leader-local so
    /// concurrent lineup lanes never mix their samples mid-run.
    occupancy: obs::Histogram,
    /// Assert that policies never need clamping (on in tests/debug).
    pub strict: bool,
}

impl<'p> ShardedLeader<'p> {
    /// `num_shards == 0` sizes the plan from the pool's worker budget
    /// (`PALLAS_WORKERS` / available parallelism).
    pub fn new(problem: &'p Problem, num_shards: usize) -> Self {
        Self::with_plan(problem, Arc::new(ShardPlan::build(problem, num_shards)))
    }

    /// Build on an existing plan — the budgeted `run_lineup` shares one
    /// deterministic plan across all of a lineup's sharded leaders
    /// instead of rebuilding it per run.
    pub fn with_plan(problem: &'p Problem, plan: Arc<ShardPlan>) -> Self {
        let workers = (0..plan.num_shards())
            .map(|_| ShardWorker {
                ledger: ShardLedger::new(problem),
                assigned: Vec::new(),
                clamped: 0,
            })
            .collect();
        ShardedLeader {
            problem,
            state: ClusterState::new(problem),
            plan,
            workers,
            delta_of: vec![0.0; problem.num_instances()],
            arrived: Vec::new(),
            reward_scratch: PortRewardScratch::default(),
            probe: None,
            next_slot: 0,
            occupancy: obs::Histogram::new(),
            strict: cfg!(debug_assertions),
        }
    }

    /// Arm an execution-fault probe and re-base the absolute slot
    /// counter (resumed segments run local `t = 0..` but injected
    /// faults key on absolute slots).
    pub fn arm_probe(&mut self, probe: Arc<pool::ExecProbe>, slot_base: u64) {
        self.probe = Some(probe);
        self.next_slot = slot_base;
    }

    /// Snapshot of the occupancy telemetry accumulated so far — one
    /// sample per (slot, shard), so `count / num_shards` is the slots
    /// sampled.  Reset-free; callers snapshot before/after a run window
    /// if they want a delta.
    pub fn occupancy(&self) -> obs::HistSnapshot {
        self.occupancy.snapshot()
    }

    /// Fold the leader-local occupancy histogram into the process-wide
    /// obs registry ([`OCCUPANCY_METRIC`]) and record the plan width on
    /// the "sharded.occupancy_shards" gauge.  [`ShardedLeader::run`]
    /// publishes automatically when obs is enabled; harnesses that
    /// drive [`ShardedLeader::slot`] directly (hot-path bench, `figure
    /// sparse`) call this at their window boundaries.
    pub fn publish_occupancy(&self) {
        self.occupancy.merge_into(&obs::registry().histogram(OCCUPANCY_METRIC));
        obs::registry()
            .gauge("sharded.occupancy_shards")
            .set(self.plan.num_shards() as i64);
    }

    /// Resume a run with a ledger and (optionally) the previous
    /// segment's shard ledgers carried across a topology edition
    /// (`sim::faults`).  When a previous plan is handed over, each
    /// instance's authoritative usage row migrates from its old owner's
    /// ledger to its new owner's in ascending instance order — a fixed
    /// hand-off sequence, so any two runs that carry the same rows
    /// produce bit-identical worker ledgers regardless of worker budget.
    pub fn resume(
        problem: &'p Problem,
        plan: Arc<ShardPlan>,
        state: ClusterState,
        previous: Option<(Arc<ShardPlan>, Vec<ShardLedger>)>,
    ) -> Self {
        let mut leader = Self::with_plan(problem, plan);
        leader.state = state;
        if let Some((old_plan, old_ledgers)) = previous {
            let k_n = problem.num_resources;
            for r in 0..problem.num_instances() {
                let from = &old_ledgers[old_plan.owner(r)];
                let s = leader.plan.owner(r);
                let to = &mut leader.workers[s].ledger;
                to.usage[r * k_n..(r + 1) * k_n]
                    .copy_from_slice(from.row_of(r, k_n));
            }
        }
        leader
    }

    /// Tear down into the carryable parts (ledger, plan, shard ledgers)
    /// for the next segment's [`ShardedLeader::resume`].
    pub fn into_parts(self) -> (ClusterState, Arc<ShardPlan>, Vec<ShardLedger>) {
        let ledgers = self.workers.into_iter().map(|w| w.ledger).collect();
        (self.state, self.plan, ledgers)
    }

    pub fn plan(&self) -> &Arc<ShardPlan> {
        &self.plan
    }

    /// The bound problem.  Returns the `'p` reference itself (not a
    /// reborrow of `self`), so callers — the overlapped pipeline's
    /// leader thread in particular — can keep using it while the
    /// committer thread holds `&mut self`.
    pub fn problem(&self) -> &'p Problem {
        self.problem
    }

    /// Absolute slot of the next [`ShardedLeader::slot`] call (the
    /// pipeline pre-computes its slot ids from this base because the
    /// committer thread owns `&mut self` for the run's duration).
    pub(crate) fn next_slot(&self) -> u64 {
        self.next_slot
    }

    pub fn state(&self) -> &ClusterState {
        &self.state
    }

    /// Mutable ledger access for the fault driver (`sim::faults` flags
    /// failed instances / forces releases between segments).
    pub fn state_mut(&mut self) -> &mut ClusterState {
        &mut self.state
    }

    /// One slot: decide → sharded commit → sharded reward → release.
    /// Exposed for the hot-path bench; [`ShardedLeader::run`] is the
    /// normal driver (and the one that binds the policy's shards and
    /// bumps the run epoch).
    pub fn slot(
        &mut self,
        policy: &mut dyn Policy,
        x: &[f64],
        y: &mut [f64],
    ) -> (CommitReport, SlotReward) {
        let abs_slot = self.next_slot;
        pool::set_slot(abs_slot);
        let _slot_span = obs::SpanTimer::start(obs::SpanKind::Slot, abs_slot, 0);
        let p = self.problem;
        obs::with_span(obs::SpanKind::Decide, abs_slot, 0, || policy.decide(p, x, y));
        self.commit_and_reward(x, y, policy.touched(), abs_slot)
    }

    /// The slot's phase after decide: sharded commit → sharded reward →
    /// release, exactly the tail of [`ShardedLeader::slot`].  Factored
    /// out so `coordinator::pipeline`'s committer thread can run slot
    /// t's tail while the leader thread decides slot t+1; the `touched`
    /// set is passed in because the policy (and its borrow) stays on
    /// the leader thread.  Advances the absolute slot counter past
    /// `abs_slot` and re-stamps the thread-local slot tag (`pool` tags
    /// are per-thread, and this may run off the deciding thread).
    pub(crate) fn commit_and_reward(
        &mut self,
        x: &[f64],
        y: &mut [f64],
        touched: Touched<'_>,
        abs_slot: u64,
    ) -> (CommitReport, SlotReward) {
        self.next_slot = abs_slot + 1;
        pool::set_slot(abs_slot);
        let report = obs::with_span(obs::SpanKind::Commit, abs_slot, 0, || match touched {
            Touched::All => self.commit_all(y, abs_slot),
            Touched::Instances(list) => self.commit_list(y, list, abs_slot),
        });
        let reward =
            obs::with_span(obs::SpanKind::Reward, abs_slot, 0, || self.reward(x, y));
        self.state.release();
        (report, reward)
    }

    /// Run `policy` against `arrivals` for `horizon` slots — the sharded
    /// mirror of [`super::Leader::run`], record-for-record bit-identical
    /// to it for every policy (`tests/shard_parity.rs`).
    pub fn run(
        &mut self,
        policy: &mut dyn Policy,
        arrivals: &mut dyn ArrivalModel,
        horizon: usize,
    ) -> RunResult {
        crate::schedulers::begin_run_epoch();
        policy.bind_shards(&self.plan);
        let p = self.problem;
        let mut x = vec![0.0; p.num_ports()];
        let mut y = vec![0.0; p.decision_len()];
        let mut result = RunResult {
            policy: policy.name().to_string(),
            records: Vec::with_capacity(horizon),
            ..Default::default()
        };
        let start = Instant::now();
        for t in 0..horizon {
            arrivals.next(&mut x);
            let (report, SlotReward { q, gain, penalty }) = self.slot(policy, &x, &mut y);
            if self.strict {
                assert_eq!(
                    report.clamped, 0,
                    "policy {} emitted an infeasible decision at t={t}",
                    policy.name()
                );
            }
            result.clamped_total += report.clamped;
            result.cumulative_reward += q;
            result.records.push(SlotRecord {
                t,
                q,
                gain,
                penalty,
                arrivals: x.iter().sum(),
            });
        }
        result.elapsed_secs = start.elapsed().as_secs_f64();
        if obs::enabled() {
            self.publish_occupancy();
        }
        result
    }

    /// Incremental sharded commit: route the dirty set by owner, commit
    /// rows in the worker-owned ledgers, fold rows + Σ deltas back.
    fn commit_list(&mut self, y: &mut [f64], list: &[usize], abs_slot: u64) -> CommitReport {
        let p = self.problem;
        self.state.begin_merge();
        if list.is_empty() {
            // zero/sparse-arrival fast path: nothing to scatter — match
            // the serial empty incremental commit (no dispatch cost)
            return CommitReport {
                clamped: 0,
                committed_units: self.state.committed_units(),
            };
        }
        for w in &mut self.workers {
            w.assigned.clear();
            w.clamped = 0;
        }
        // Route by owner, carrying the *position* in `list`: positions
        // are unique even if a policy lists an instance twice, and a
        // duplicated instance routes to one shard, which processes its
        // occurrences in list order — so the per-occurrence deltas equal
        // the serial ledger's (first d, then 0) exactly.
        for (i, &r) in list.iter().enumerate() {
            let s = self.plan.owner(r);
            self.workers[s].assigned.push(i);
        }
        if self.delta_of.len() < list.len() {
            self.delta_of.resize(list.len(), 0.0);
        }
        {
            let probe = self.probe.clone();
            let deltas = SyncSlice::new(&mut self.delta_of);
            let view = SyncSlice::new(y);
            let y_len = view.len();
            pool::parallel_shards(&mut self.workers, |s, w| {
                obs::with_span(obs::SpanKind::ShardCommit, abs_slot, s as u32, || {
                    // Fault-injection point: *before* any write, so a
                    // retried task replays against untouched state.
                    if let Some(probe) = &probe {
                        probe.fire(abs_slot, s as u32);
                    }
                    // SAFETY: shards own disjoint instance sets, so an
                    // instance's usage row and edge columns of `y` are
                    // touched only by its owner, and each list position is
                    // routed to exactly one shard.  The full-range view
                    // follows the crate's established disjoint-ownership
                    // pattern (`projection::SharedTensor`).
                    let y = unsafe { view.slice_mut(0, y_len) };
                    for &i in &w.assigned {
                        let r = list[i];
                        let delta = w.ledger.commit_instance(p, y, r, &mut w.clamped);
                        unsafe { deltas.write(i, delta) };
                    }
                });
            });
        }
        let mut report = CommitReport::default();
        let k_n = p.num_resources;
        for w in &self.workers {
            report.clamped += w.clamped;
            for &i in &w.assigned {
                let r = list[i];
                self.state.merge_row(r, w.ledger.row_of(r, k_n));
            }
        }
        // Σ deltas replay in the policy's original dirty order — the
        // serial `commit_instances` accumulation sequence, bit for bit.
        for i in 0..list.len() {
            self.state.add_total_delta(self.delta_of[i]);
        }
        report.committed_units = self.state.committed_units();
        report
    }

    /// Full-sweep fallback (`Touched::All`): every shard re-derives all
    /// of its rows; the folded total is re-summed in flat index order,
    /// exactly like the serial full-sweep commit.
    fn commit_all(&mut self, y: &mut [f64], abs_slot: u64) -> CommitReport {
        let p = self.problem;
        self.state.begin_merge();
        for w in &mut self.workers {
            w.clamped = 0;
        }
        {
            let probe = self.probe.clone();
            let plan = &self.plan;
            let view = SyncSlice::new(y);
            let y_len = view.len();
            pool::parallel_shards(&mut self.workers, |s, w| {
                obs::with_span(obs::SpanKind::ShardCommit, abs_slot, s as u32, || {
                    // Fault-injection point — before any write (see
                    // `commit_list`).
                    if let Some(probe) = &probe {
                        probe.fire(abs_slot, s as u32);
                    }
                    // SAFETY: as in `commit_list` — disjoint instance sets,
                    // full-range view per the crate's `projection::SharedTensor`
                    // disjoint-ownership pattern.
                    let y = unsafe { view.slice_mut(0, y_len) };
                    for &r in plan.instances(s) {
                        w.clamped += commit_row_into(
                            p,
                            y,
                            r,
                            &mut w.ledger.usage,
                            &mut w.ledger.row,
                            &p.capacity,
                        );
                    }
                });
            });
        }
        let mut report = CommitReport::default();
        let k_n = p.num_resources;
        for (s, w) in self.workers.iter().enumerate() {
            report.clamped += w.clamped;
            for &r in self.plan.instances(s) {
                self.state.merge_row(r, w.ledger.row_of(r, k_n));
            }
        }
        self.state.refresh_total();
        report.committed_units = self.state.committed_units();
        report
    }

    /// Sharded slot reward: per-port kernels fan out over the pool,
    /// then the components merge serially in ascending port order — the
    /// exact accumulation sequence of `reward::slot_reward_kinds`.
    /// §Perf-5 factored the machinery into
    /// `reward::slot_reward_ports_sharded` so the Eq. 50 oracle solve
    /// shards its per-iteration objective through the same code.
    fn reward(&mut self, x: &[f64], y: &[f64]) -> SlotReward {
        let p = self.problem;
        self.arrived.clear();
        self.arrived.extend((0..p.num_ports()).filter(|&l| x[l] != 0.0));
        // Occupancy telemetry: edges each shard would touch in this
        // slot's arrived neighborhood — one histogram sample per
        // (slot, shard).  CSR ptr arithmetic only — O(shards × arrived)
        // per slot, no edge walk, and integer-only (obs parity).
        let shards = self.plan.num_shards();
        for s in 0..shards {
            let edges: u64 = self
                .arrived
                .iter()
                .map(|&l| self.plan.port_edges(s, l).len() as u64)
                .sum();
            self.occupancy.record(edges);
        }
        slot_reward_ports_sharded(
            p,
            p.kinds(),
            x,
            y,
            &self.arrived,
            self.plan.num_shards(),
            &mut self.reward_scratch,
        )
    }
}

/// The bound plan when it actually shards (> 1 shard) — the single
/// activation predicate behind every plan-routed step (OGA fused
/// ascent, oracle two-pass, mirror update, dirty projection).  Cloned
/// (one refcount bump) so the caller can keep borrowing its own fields
/// mutably for the step's duration.
pub(crate) fn active_plan(plan: &Option<Arc<ShardPlan>>) -> Option<Arc<ShardPlan>> {
    plan.clone().filter(|plan| plan.num_shards() > 1)
}

/// Project exactly the listed dirty instances, scattered by shard owner
/// over the pool (each shard projects its own instances serially on its
/// own thread).  The per-instance projection is independent, so any
/// partition yields the serial result bit for bit.  `parts` is caller
/// scratch (one list per shard, reused across slots).
pub fn project_dirty_sharded(
    problem: &Problem,
    y: &mut [f64],
    dirty: &[usize],
    plan: &ShardPlan,
    parts: &mut Vec<Vec<usize>>,
) {
    if dirty.is_empty() {
        return;
    }
    if parts.len() != plan.num_shards() {
        *parts = vec![Vec::new(); plan.num_shards()];
    }
    for &r in dirty {
        parts[plan.owner(r)].push(r);
    }
    {
        let view = SyncSlice::new(y);
        let y_len = view.len();
        let parts_ref = &*parts;
        pool::parallel_for(plan.num_shards(), plan.num_shards(), |s| {
            // SAFETY: instance r owns only its edges' coordinates —
            // disjoint across distinct r, and the owner partition lists
            // each dirty r exactly once.
            let y = unsafe { view.slice_mut(0, y_len) };
            project_instances_serial(problem, y, &parts_ref[s]);
        });
    }
    for part in parts.iter_mut() {
        part.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::coordinator::Leader;
    use crate::schedulers::{Fairness, OgaSched};
    use crate::sim::arrivals::Bernoulli;
    use crate::traces::synthesize;

    #[test]
    fn plan_partitions_and_balances() {
        let p = synthesize(&Scenario::small());
        for s_n in [1, 2, 3, 7] {
            let plan = ShardPlan::build(&p, s_n);
            plan.validate(&p).unwrap();
            assert_eq!(plan.num_shards(), s_n.min(p.num_instances()));
            let total: u64 = (0..plan.num_shards()).map(|s| plan.load(s)).sum();
            let expect: u64 = (0..p.num_instances())
                .map(|r| p.graph.instance_degree(r) as u64 * p.num_resources as u64)
                .sum();
            assert_eq!(total, expect);
            // LPT guarantee: max load ≤ mean + max single weight
            let max_load = (0..plan.num_shards()).map(|s| plan.load(s)).max().unwrap();
            let max_w = (0..p.num_instances())
                .map(|r| p.graph.instance_degree(r) as u64 * p.num_resources as u64)
                .max()
                .unwrap();
            assert!(
                max_load <= total / plan.num_shards() as u64 + max_w,
                "unbalanced plan: max {max_load}, total {total}, w* {max_w}"
            );
        }
    }

    #[test]
    fn plan_clamps_shard_count_to_instances() {
        let p = synthesize(&Scenario::small());
        let plan = ShardPlan::build(&p, 10 * p.num_instances());
        assert_eq!(plan.num_shards(), p.num_instances());
        plan.validate(&p).unwrap();
    }

    #[test]
    fn sharded_leader_matches_serial_smoke() {
        // the full property matrix lives in tests/shard_parity.rs; this
        // is the in-crate smoke check for the seam
        let p = synthesize(&Scenario::small());
        let horizon = 40;
        let serial = {
            let mut leader = Leader::new(&p);
            let mut pol = OgaSched::new(&p, 2.0, 0.999, crate::utils::pool::ExecBudget::auto());
            let mut arr = Bernoulli::uniform(p.num_ports(), 0.4, 11);
            leader.run(&mut pol, &mut arr, horizon)
        };
        for shards in [1, 3] {
            let mut leader = ShardedLeader::new(&p, shards);
            let mut pol = OgaSched::new(&p, 2.0, 0.999, crate::utils::pool::ExecBudget::auto());
            let mut arr = Bernoulli::uniform(p.num_ports(), 0.4, 11);
            let run = leader.run(&mut pol, &mut arr, horizon);
            assert_eq!(run.cumulative_reward, serial.cumulative_reward, "shards={shards}");
            for (a, b) in run.records.iter().zip(&serial.records) {
                assert_eq!(a.q, b.q);
                assert_eq!(a.gain, b.gain);
                assert_eq!(a.penalty, b.penalty);
            }
        }
    }

    #[test]
    fn sharded_ledger_tracks_remaining_capacity() {
        let p = synthesize(&Scenario::small());
        let mut leader = ShardedLeader::new(&p, 3);
        let mut pol = Fairness::new();
        let mut arr = Bernoulli::uniform(p.num_ports(), 1.0, 5);
        let mut serial = Leader::new(&p);
        let mut pol2 = Fairness::new();
        let mut arr2 = Bernoulli::uniform(p.num_ports(), 1.0, 5);
        leader.run(&mut pol, &mut arr, 10);
        serial.run(&mut pol2, &mut arr2, 10);
        leader.state().check_conservation().unwrap();
        for r in 0..p.num_instances() {
            for k in 0..p.num_resources {
                assert_eq!(
                    leader.state().remaining_at(r, k),
                    serial.state().remaining_at(r, k),
                    "remaining({r},{k}) diverged"
                );
            }
        }
    }

    #[test]
    fn refresh_keeps_owners_and_rebuilds_edges() {
        let mut p = synthesize(&Scenario::small());
        let plan = ShardPlan::build(&p, 3);
        let removed = p.remove_instance_edges(0).unwrap();
        let refreshed = plan.refresh(&p).unwrap();
        refreshed.validate(&p).unwrap();
        assert_eq!(refreshed.num_shards(), plan.num_shards());
        for r in 0..p.num_instances() {
            assert_eq!(refreshed.owner(r), plan.owner(r));
        }
        // the failed instance contributes no load or edges any more
        let s0 = refreshed.owner(0);
        assert!(refreshed.load(s0) < plan.load(s0));
        p.restore_edges(&removed).unwrap();
        let back = refreshed.refresh(&p).unwrap();
        back.validate(&p).unwrap();
        for s in 0..plan.num_shards() {
            assert_eq!(back.load(s), plan.load(s));
        }
        assert!((back.imbalance() - plan.imbalance()).abs() < 1e-12);
    }

    #[test]
    fn resume_migrates_ledger_rows_deterministically() {
        let p = synthesize(&Scenario::small());
        let horizon = 15;
        // segment 1 under one plan
        let mut leader = ShardedLeader::new(&p, 2);
        let mut pol = Fairness::new();
        let mut arr = Bernoulli::uniform(p.num_ports(), 0.8, 9);
        leader.run(&mut pol, &mut arr, horizon);
        let (state, old_plan, ledgers) = leader.into_parts();
        // hand off to a differently sharded plan: remaining capacity is
        // unchanged and a continued run matches the serial continuation
        let new_plan = Arc::new(ShardPlan::build(&p, 5));
        let mut resumed = ShardedLeader::resume(
            &p,
            Arc::clone(&new_plan),
            state,
            Some((old_plan, ledgers)),
        );
        let run2 = resumed.run(&mut pol, &mut arr, horizon);

        let mut serial = Leader::new(&p);
        let mut pol_s = Fairness::new();
        let mut arr_s = Bernoulli::uniform(p.num_ports(), 0.8, 9);
        serial.run(&mut pol_s, &mut arr_s, horizon);
        let want = serial.run(&mut pol_s, &mut arr_s, horizon);
        assert_eq!(run2.cumulative_reward, want.cumulative_reward);
        for r in 0..p.num_instances() {
            for k in 0..p.num_resources {
                assert_eq!(
                    resumed.state().remaining_at(r, k),
                    serial.state().remaining_at(r, k),
                    "remaining({r},{k}) diverged after hand-off"
                );
            }
        }
    }

    #[test]
    fn project_dirty_sharded_matches_serial() {
        use crate::oga::projection::project_instances;
        let p = synthesize(&Scenario::small());
        let mut rng = crate::utils::rng::Rng::new(3);
        let base: Vec<f64> =
            (0..p.decision_len()).map(|_| rng.uniform(0.0, 6.0)).collect();
        let dirty: Vec<usize> = (0..p.num_instances()).filter(|r| r % 2 == 0).collect();
        let plan = ShardPlan::build(&p, 3);
        let mut parts = Vec::new();
        let mut y_sharded = base.clone();
        let mut y_serial = base;
        project_dirty_sharded(&p, &mut y_sharded, &dirty, &plan, &mut parts);
        project_instances(&p, &mut y_serial, &dirty, 1);
        assert_eq!(y_sharded, y_serial);
        // scratch lists are drained for the next slot
        assert!(parts.iter().all(|p| p.is_empty()));
    }

    #[test]
    fn with_owners_round_trips_a_plan() {
        let p = synthesize(&Scenario::small());
        let plan = ShardPlan::build(&p, 3);
        let rebuilt =
            ShardPlan::with_owners(&p, plan.num_shards(), plan.owners().to_vec()).unwrap();
        rebuilt.validate(&p).unwrap();
        assert_eq!(rebuilt.owners(), plan.owners());
        for s in 0..plan.num_shards() {
            assert_eq!(rebuilt.instances(s), plan.instances(s));
            assert_eq!(rebuilt.load(s), plan.load(s));
        }
        for l in 0..p.num_ports() {
            for s in 0..plan.num_shards() {
                assert_eq!(rebuilt.port_edges(s, l), plan.port_edges(s, l));
            }
        }
    }

    #[test]
    fn with_owners_rejects_malformed_maps() {
        let p = synthesize(&Scenario::small());
        let plan = ShardPlan::build(&p, 2);
        assert!(ShardPlan::with_owners(&p, 0, plan.owners().to_vec()).is_err());
        assert!(ShardPlan::with_owners(&p, 2, vec![0; 3]).is_err());
        let mut bad = plan.owners().to_vec();
        bad[0] = 7; // out of range for S=2
        assert!(ShardPlan::with_owners(&p, 2, bad).is_err());
    }

    #[test]
    fn shard_ledger_snapshot_round_trips() {
        let p = synthesize(&Scenario::small());
        let mut leader = ShardedLeader::new(&p, 2);
        let mut pol = Fairness::new();
        let mut arr = Bernoulli::uniform(p.num_ports(), 0.8, 21);
        leader.run(&mut pol, &mut arr, 10);
        let (_, _, ledgers) = leader.into_parts();
        for ledger in &ledgers {
            let mut w = crate::utils::codec::Writer::new();
            ledger.snapshot(&mut w);
            let bytes = w.finish();
            let mut r = crate::utils::codec::Reader::new(&bytes).unwrap();
            let back = ShardLedger::restore(&p, &mut r).unwrap();
            r.finish().unwrap();
            assert_eq!(back.usage, ledger.usage);
        }
    }

    #[test]
    fn occupancy_counts_arrived_neighborhood_edges() {
        let p = synthesize(&Scenario::small());
        let mut leader = ShardedLeader::new(&p, 3);
        let mut pol = Fairness::new();
        let mut arr = Bernoulli::uniform(p.num_ports(), 0.8, 5);
        let horizon = 12;
        leader.run(&mut pol, &mut arr, horizon);
        let occ = leader.occupancy();
        let shards = leader.plan().num_shards() as u64;
        // one histogram sample per (slot, shard)
        assert_eq!(occ.count, horizon as u64 * shards);
        assert!(occ.min_or_zero() <= occ.max);
        assert!(occ.mean() >= occ.min_or_zero() as f64);
        assert!(occ.mean() <= occ.max as f64);
        assert!(occ.p50() <= occ.p99());
        // every edge of every arrived port lands in exactly one shard,
        // so the per-slot shard sum telescopes into the total
        assert!(occ.sum > 0, "dense arrivals must touch edges");
    }

    #[test]
    fn armed_probe_fault_is_survived_and_bitwise_invisible() {
        use std::collections::BTreeSet;
        let p = synthesize(&Scenario::small());
        let horizon = 10;
        let mut clean = ShardedLeader::new(&p, 2);
        let mut pol = Fairness::new();
        let mut arr = Bernoulli::uniform(p.num_ports(), 0.8, 33);
        let want = clean.run(&mut pol, &mut arr, horizon);

        let mut faulty = ShardedLeader::new(&p, 2);
        let panics: BTreeSet<(u64, u32)> = [(3u64, 1u32), (7, 0)].into();
        let probe = Arc::new(pool::ExecProbe::new(panics, BTreeSet::new(), 5));
        faulty.arm_probe(Arc::clone(&probe), 0);
        let mut pol2 = Fairness::new();
        let mut arr2 = Bernoulli::uniform(p.num_ports(), 0.8, 33);
        let got = faulty.run(&mut pol2, &mut arr2, horizon);

        assert_eq!(probe.fired_count(), 2, "both injected faults must fire");
        assert_eq!(got.cumulative_reward, want.cumulative_reward);
        assert_eq!(got.records, want.records);
        for r in 0..p.num_instances() {
            for k in 0..p.num_resources {
                assert_eq!(
                    faulty.state().remaining_at(r, k),
                    clean.state().remaining_at(r, k),
                );
            }
        }
    }
}
