//! The obs metrics registry: counters, gauges, and log₂-bucketed
//! histograms behind a process-wide name → handle table.
//!
//! Everything here is integer-only relaxed atomics — recording a metric
//! can never perturb simulation floats or RNG streams, which is the
//! obs-on/off bitwise-parity contract (`tests/obs_parity.rs`).  Handles
//! are `Arc`s resolved once per call site (hot paths cache them in a
//! `OnceLock`), so steady-state cost is one atomic RMW per update; the
//! registry lock is touched only at registration and export.
//!
//! Snapshot order is the `BTreeMap` name order — deterministic for the
//! exporters regardless of registration interleaving.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonic event count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-write-wins signed level (queue depth, plan width, ...).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicI64::new(0))
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Bucket count of [`Histogram`]: bucket 0 holds exact zeros, bucket
/// `i ≥ 1` holds values in `[2^(i-1), 2^i)`, up to `i = 64` for the
/// top of the u64 range.
pub const NUM_BUCKETS: usize = 65;

/// Log₂-bucketed u64 histogram with exact count/sum/min/max.
///
/// Percentiles are bucket-resolution (the p50/p99 columns of the run
/// summary and the slot-latency substrate of the ROADMAP throughput
/// item); count, sum (hence mean), min and max are exact, which is what
/// the occupancy telemetry migrated from `OccupancyStats` needs.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Bucket of value `v`: 0 for 0, else `floor(log2 v) + 1` — so every
    /// power of two starts a new bucket (`2^k` lands in bucket `k + 1`,
    /// `2^k − 1` in bucket `k`).
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros()) as usize
        }
    }

    /// Lower edge of bucket `i` (0, 1, 2, 4, 8, ...).
    pub fn bucket_floor(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            1u64 << (i - 1)
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Fold this histogram's samples into `other` (used by per-leader
    /// occupancy histograms publishing into the global registry).
    pub fn merge_into(&self, other: &Histogram) {
        for (from, to) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = from.load(Ordering::Relaxed);
            if n > 0 {
                to.fetch_add(n, Ordering::Relaxed);
            }
        }
        let count = self.count.load(Ordering::Relaxed);
        if count == 0 {
            return;
        }
        other.count.fetch_add(count, Ordering::Relaxed);
        other.sum.fetch_add(self.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        other.min.fetch_min(self.min.load(Ordering::Relaxed), Ordering::Relaxed);
        other.max.fetch_max(self.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
        }
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

/// Owned copy of a [`Histogram`]'s state at one instant.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    /// `u64::MAX` sentinel while empty — use [`HistSnapshot::min_or_zero`].
    pub min: u64,
    pub max: u64,
    pub buckets: [u64; NUM_BUCKETS],
}

impl HistSnapshot {
    pub fn min_or_zero(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Exact mean (export-time float formatting only; the hot path
    /// never computes this).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Integer-rank quantile `num/den`: the lower edge of the bucket
    /// holding sample rank `⌊(count−1)·num/den⌋`, clamped into the
    /// observed `[min, max]` so one-sample histograms return the sample's
    /// bucket floor exactly.  Empty histograms return 0.
    pub fn quantile(&self, num: u64, den: u64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (self.count - 1).saturating_mul(num) / den.max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Histogram::bucket_floor(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(1, 2)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(99, 100)
    }
}

/// The process-wide metric table.  One instance (see [`registry`]);
/// handles are shared `Arc`s, so a name always resolves to the same
/// metric no matter which layer registered it first.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(self.counters.lock().unwrap().entry(name.to_string()).or_default())
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(self.gauges.lock().unwrap().entry(name.to_string()).or_default())
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(self.hists.lock().unwrap().entry(name.to_string()).or_default())
    }

    /// Counter values in name order.
    pub fn counters(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(n, c)| (n.clone(), c.get()))
            .collect()
    }

    /// Gauge values in name order.
    pub fn gauges(&self) -> Vec<(String, i64)> {
        self.gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(n, g)| (n.clone(), g.get()))
            .collect()
    }

    /// Histogram snapshots in name order.
    pub fn histograms(&self) -> Vec<(String, HistSnapshot)> {
        self.hists
            .lock()
            .unwrap()
            .iter()
            .map(|(n, h)| (n.clone(), h.snapshot()))
            .collect()
    }

    /// Zero every registered metric (handles stay valid).  Benches and
    /// figure harnesses call this between measurement windows; tests
    /// that difference counters across a window must not run
    /// concurrently with a reset (the parity/bench binaries run with
    /// `--test-threads=1` or single-threaded mains).
    pub fn reset(&self) {
        for c in self.counters.lock().unwrap().values() {
            c.reset();
        }
        for g in self.gauges.lock().unwrap().values() {
            g.reset();
        }
        for h in self.hists.lock().unwrap().values() {
            h.reset();
        }
    }
}

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_at_powers_of_two() {
        // 0 is its own bucket; every 2^k starts bucket k+1.
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(7), 3);
        assert_eq!(Histogram::bucket_index(8), 4);
        for k in 0..63 {
            let v = 1u64 << k;
            assert_eq!(Histogram::bucket_index(v), k as usize + 1, "2^{k}");
            assert_eq!(Histogram::bucket_floor(k as usize + 1), v);
            if v > 1 {
                assert_eq!(Histogram::bucket_index(v - 1), k as usize, "2^{k}-1");
            }
        }
        assert_eq!(Histogram::bucket_index(u64::MAX), 64);
    }

    #[test]
    fn histogram_exact_counts_at_powers_of_two() {
        let h = Histogram::new();
        for v in [4u64, 4, 5, 7] {
            h.record(v);
        }
        h.record(8);
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.buckets[3], 4); // [4, 8)
        assert_eq!(s.buckets[4], 1); // [8, 16)
        assert_eq!(s.sum, 28);
        assert_eq!(s.min, 4);
        assert_eq!(s.max, 8);
    }

    #[test]
    fn empty_and_one_sample_percentiles() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p50(), 0);
        assert_eq!(s.p99(), 0);
        assert_eq!(s.min_or_zero(), 0);
        assert_eq!(s.mean(), 0.0);

        h.record(5);
        let s = h.snapshot();
        // one sample: every quantile collapses to the sample's bucket
        // floor clamped into [min, max] = [5, 5]
        assert_eq!(s.p50(), 5);
        assert_eq!(s.p99(), 5);
        assert_eq!(s.quantile(0, 1), 5);
        assert_eq!(s.min_or_zero(), 5);
        assert_eq!(s.mean(), 5.0);

        // an exact power of two is its own bucket floor
        let h = Histogram::new();
        h.record(16);
        assert_eq!(h.snapshot().p50(), 16);
    }

    #[test]
    fn quantiles_walk_the_cumulative_counts() {
        let h = Histogram::new();
        for _ in 0..99 {
            h.record(2); // bucket 2, floor 2
        }
        h.record(1024); // bucket 11, floor 1024
        let s = h.snapshot();
        assert_eq!(s.p50(), 2);
        // rank ⌊99·99/100⌋ = 98 — the 99th of the hundred samples, still 2
        assert_eq!(s.p99(), 2);
        // the max-rank quantile reaches the tail bucket
        assert_eq!(s.quantile(1, 1), 1024);
        assert_eq!(s.max, 1024);
    }

    #[test]
    fn merge_folds_everything() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(3);
        a.record(100);
        b.record(7);
        a.merge_into(&b);
        let s = b.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.sum, 110);
        assert_eq!(s.min, 3);
        assert_eq!(s.max, 100);
        // empty merge is a no-op (min sentinel must not leak)
        Histogram::new().merge_into(&b);
        assert_eq!(b.snapshot().min, 3);
    }

    #[test]
    fn registry_dedups_by_name_and_resets() {
        let r = Registry::default();
        let c1 = r.counter("x.hits");
        let c2 = r.counter("x.hits");
        c1.inc();
        c2.add(2);
        assert_eq!(r.counters(), vec![("x.hits".to_string(), 3)]);
        r.gauge("x.level").set(-4);
        r.histogram("x.lat").record(9);
        r.reset();
        assert_eq!(r.counters()[0].1, 0);
        assert_eq!(r.gauges()[0].1, 0);
        assert_eq!(r.histograms()[0].1.count, 0);
        // handle still live after reset
        c1.inc();
        assert_eq!(r.counters()[0].1, 1);
    }
}
