//! Lock-free per-thread event rings for `--obs trace`.
//!
//! Each thread that records a trace event lazily registers one bounded
//! single-producer append log.  The producer is the owning thread only;
//! readers (the exporters) see a consistent prefix via the
//! release-published length.  When a ring fills, new events are dropped
//! and counted — never overwritten — so the exported prefix stays
//! deterministic under any reader/writer interleaving.
//!
//! Merge order is by (group, idx, registration-seq): the group and idx
//! are parsed from the `pallas-crew-{tag}-{i}` thread names assigned by
//! `utils::pool::Crew::ensure_threads`, so a trace taken under
//! `PALLAS_WORKERS=4` lists `global-0..3` in the same order every run.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// One recorded span or instant event.  Pure integers — recording is a
/// slot write plus one atomic store, and can never perturb simulation
/// floats or RNG streams.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Event {
    /// `SpanKind as u8`.
    pub kind: u8,
    /// Shard (or scatter-task) index; 0 where not meaningful.
    pub shard: u32,
    /// Topology generation / edition context; 0 where not meaningful.
    pub gen: u32,
    /// Absolute slot (or oracle iteration) the event belongs to.
    pub slot: u64,
    /// Start time, ns since the process obs epoch.
    pub t0_ns: u64,
    /// Duration in ns; 0 for instant events.
    pub dur_ns: u64,
}

/// Bounded single-producer event log owned by one thread.
pub struct Ring {
    buf: Box<[UnsafeCell<Event>]>,
    len: AtomicUsize,
    dropped: AtomicU64,
    group: String,
    idx: u32,
    seq: u32,
}

// SAFETY: `push` is called only by the owning thread (the ring lives in
// a thread-local and is reached through it), so there is a single
// producer.  A slot is written before `len` is release-stored past it,
// and readers copy only indices below an acquire-load of `len`, so they
// never observe a partially written event.  `clear` is documented as
// quiesced-only (no concurrent producer).
unsafe impl Send for Ring {}
unsafe impl Sync for Ring {}

impl Ring {
    fn new(group: String, idx: u32, seq: u32, cap: usize) -> Ring {
        Ring {
            buf: (0..cap).map(|_| UnsafeCell::new(Event::default())).collect(),
            len: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
            group,
            idx,
            seq,
        }
    }

    #[inline]
    fn push(&self, ev: Event) {
        let len = self.len.load(Ordering::Relaxed);
        if len >= self.buf.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        // SAFETY: single producer; index `len` is unpublished, so no
        // reader can be looking at it (see the impl-level invariant).
        unsafe {
            *self.buf[len].get() = ev;
        }
        self.len.store(len + 1, Ordering::Release);
    }

    fn events(&self) -> Vec<Event> {
        let len = self.len.load(Ordering::Acquire);
        // SAFETY: indices < len are fully written and never mutated
        // again (append-only until a quiesced clear).
        (0..len).map(|i| unsafe { *self.buf[i].get() }).collect()
    }

    /// Quiesced-only: callers must guarantee the owning thread is not
    /// pushing (the bench/CLI reset points run between scatters).
    fn clear(&self) {
        self.len.store(0, Ordering::Release);
        self.dropped.store(0, Ordering::Relaxed);
    }
}

/// Ring capacity: `PALLAS_OBS_RING` events per thread (default 65536).
fn ring_capacity() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        std::env::var("PALLAS_OBS_RING")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 16)
            .unwrap_or(1 << 16)
    })
}

fn rings() -> &'static Mutex<Vec<Arc<Ring>>> {
    static R: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Vec::new()))
}

/// Merge key parsed from a thread name: `pallas-crew-{tag}-{i}` becomes
/// `({tag}, i)`; anything else keeps its whole name with idx 0 (`main`
/// for the main thread, the test name under the test harness).
pub(crate) fn parse_thread_key(name: &str) -> (String, u32) {
    if let Some(rest) = name.strip_prefix("pallas-crew-") {
        if let Some((group, idx)) = rest.rsplit_once('-') {
            if let Ok(i) = idx.parse::<u32>() {
                return (group.to_string(), i);
            }
        }
    }
    if name.is_empty() {
        ("anon".to_string(), 0)
    } else {
        (name.to_string(), 0)
    }
}

thread_local! {
    static LOCAL: std::cell::OnceCell<Arc<Ring>> = const { std::cell::OnceCell::new() };
}

/// Append an event to the calling thread's ring, registering the ring
/// on first use.  Only called while `obs::tracing()`.
pub(crate) fn record(ev: Event) {
    LOCAL.with(|cell| {
        cell.get_or_init(|| {
            let thread = std::thread::current();
            let (group, idx) = parse_thread_key(thread.name().unwrap_or(""));
            let mut reg = rings().lock().unwrap();
            let ring = Arc::new(Ring::new(group, idx, reg.len() as u32, ring_capacity()));
            reg.push(Arc::clone(&ring));
            ring
        })
        .push(ev);
    });
}

/// One ring's events plus its merge key, copied out for export.
#[derive(Clone, Debug)]
pub struct RingSnap {
    pub group: String,
    pub idx: u32,
    pub seq: u32,
    pub events: Vec<Event>,
    pub dropped: u64,
}

/// Every registered ring, in deterministic (group, idx, seq) order.
/// seq (registration order) only breaks ties between same-named
/// threads across pool rebuilds.
pub fn snapshot_all() -> Vec<RingSnap> {
    let mut snaps: Vec<RingSnap> = rings()
        .lock()
        .unwrap()
        .iter()
        .map(|r| RingSnap {
            group: r.group.clone(),
            idx: r.idx,
            seq: r.seq,
            events: r.events(),
            dropped: r.dropped.load(Ordering::Relaxed),
        })
        .collect();
    snaps.sort_by(|a, b| {
        (a.group.as_str(), a.idx, a.seq).cmp(&(b.group.as_str(), b.idx, b.seq))
    });
    snaps
}

/// Total events dropped to full rings.
pub fn dropped_total() -> u64 {
    rings()
        .lock()
        .unwrap()
        .iter()
        .map(|r| r.dropped.load(Ordering::Relaxed))
        .sum()
}

/// Drop every recorded event.  Quiesced-only, like [`Ring::clear`].
pub fn clear_all() {
    for r in rings().lock().unwrap().iter() {
        r.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_key_parses_crew_names() {
        assert_eq!(parse_thread_key("pallas-crew-global-3"), ("global".into(), 3));
        assert_eq!(parse_thread_key("pallas-crew-group-0"), ("group".into(), 0));
        assert_eq!(parse_thread_key("pallas-crew-group-12"), ("group".into(), 12));
        // non-numeric tail keeps the whole name
        assert_eq!(parse_thread_key("pallas-crew-odd"), ("pallas-crew-odd".into(), 0));
        assert_eq!(parse_thread_key("main"), ("main".into(), 0));
        assert_eq!(parse_thread_key(""), ("anon".into(), 0));
    }

    #[test]
    fn ring_push_read_and_drop_counting() {
        let r = Ring::new("t".into(), 0, 0, 4);
        for i in 0..6u64 {
            r.push(Event { slot: i, ..Event::default() });
        }
        let evs = r.events();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[3].slot, 3, "drop-newest keeps the prefix");
        assert_eq!(r.dropped.load(Ordering::Relaxed), 2);
        r.clear();
        assert!(r.events().is_empty());
        assert_eq!(r.dropped.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn merge_order_is_group_then_idx_then_seq() {
        // Registered out of order on purpose: seq reflects registration,
        // but the merge sorts by (group, idx) first.
        let mk = |g: &str, i: u32, s: u32| RingSnap {
            group: g.into(),
            idx: i,
            seq: s,
            events: Vec::new(),
            dropped: 0,
        };
        let mut snaps = vec![
            mk("group", 1, 0),
            mk("global", 2, 1),
            mk("global", 0, 2),
            mk("group", 0, 4),
            mk("group", 0, 3),
        ];
        snaps.sort_by(|a, b| {
            (a.group.as_str(), a.idx, a.seq).cmp(&(b.group.as_str(), b.idx, b.seq))
        });
        let keys: Vec<(String, u32, u32)> =
            snaps.iter().map(|s| (s.group.clone(), s.idx, s.seq)).collect();
        assert_eq!(
            keys,
            vec![
                ("global".into(), 0, 2),
                ("global".into(), 2, 1),
                ("group".into(), 0, 3),
                ("group".into(), 0, 4),
                ("group".into(), 1, 0),
            ]
        );
    }

    #[test]
    fn named_threads_register_with_parsed_keys() {
        // Use a group name no other test emits so we can pick our rings
        // out of the process-global registry.
        let spawn = |i: u32| {
            std::thread::Builder::new()
                .name(format!("pallas-crew-zobstest-{i}"))
                .spawn(move || {
                    super::record(Event { slot: u64::from(i), ..Event::default() });
                })
                .unwrap()
        };
        // spawn high index first: merge order must not be registration order
        for h in [spawn(1), spawn(0)] {
            h.join().unwrap();
        }
        let ours: Vec<RingSnap> = snapshot_all()
            .into_iter()
            .filter(|s| s.group == "zobstest")
            .collect();
        assert_eq!(ours.len(), 2);
        assert_eq!(ours[0].idx, 0);
        assert_eq!(ours[1].idx, 1);
        assert_eq!(ours[0].events[0].slot, 0);
        assert_eq!(ours[1].events[0].slot, 1);
    }
}
