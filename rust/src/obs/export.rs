//! Deterministic exporters for the obs layer.
//!
//! Three formats, all rendered from the same (ring snapshot, registry
//! snapshot) pair and therefore mutually consistent:
//!
//! * **JSON-lines** — a `meta` header line (schema + version, the
//!   `utils::codec` versioning idiom), then one line per trace event in
//!   merged (group, idx) ring order, then every metric in registry name
//!   order.  Validated by `scripts/check_obs.py` in CI.
//! * **Chrome trace-event JSON** — loadable in Perfetto / `chrome://
//!   tracing`; one `tid` per ring with a `thread_name` metadata record,
//!   `ph:"X"` duration spans and `ph:"i"` instant events.
//! * **Run-summary table** — the `--obs summary` table printed by
//!   `run`/`figure`: histograms with count/p50/p99/max/mean, then
//!   non-zero counters and gauges.
//!
//! Determinism: given the same recorded events and metric values, every
//! byte of output is a pure function of the snapshots — iteration
//! orders are sorted, floats appear only in fixed-precision `mean`
//! cells, timestamps are integer nanoseconds (formatted as exact
//! microsecond decimals for Chrome).

use std::fmt::Write as _;
use std::path::Path;

use super::{metrics::registry, ring, SpanKind};
use crate::utils::table::Table;

pub const SCHEMA: &str = "ogasched-obs";
pub const VERSION: u32 = 1;

fn kind_name(k: u8) -> &'static str {
    SpanKind::from_u8(k).map(SpanKind::name).unwrap_or("unknown")
}

/// Minimal JSON string escaping (quotes, backslash, control chars) for
/// thread/metric names; the names we emit are ASCII identifiers, but
/// test-harness thread names can contain arbitrary text.
fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Exact ns → µs decimal (e.g. 1530 ns → "1.530") without any float
/// arithmetic, for the Chrome `ts`/`dur` fields.
fn micros(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

/// Render the JSON-lines export.
pub fn render_jsonl() -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{{\"record\":\"meta\",\"schema\":\"{SCHEMA}\",\"version\":{VERSION}}}"
    );
    let mut seq = 0u64;
    for snap in ring::snapshot_all() {
        let thread = escape_json(&format!("{}-{}", snap.group, snap.idx));
        for ev in &snap.events {
            let _ = writeln!(
                out,
                "{{\"record\":\"span\",\"seq\":{},\"thread\":\"{}\",\"kind\":\"{}\",\
                 \"slot\":{},\"shard\":{},\"gen\":{},\"ts_ns\":{},\"dur_ns\":{}}}",
                seq, thread, kind_name(ev.kind), ev.slot, ev.shard, ev.gen, ev.t0_ns, ev.dur_ns
            );
            seq += 1;
        }
        if snap.dropped > 0 {
            let _ = writeln!(
                out,
                "{{\"record\":\"dropped\",\"thread\":\"{}\",\"count\":{}}}",
                thread, snap.dropped
            );
        }
    }
    for (name, v) in registry().counters() {
        let _ = writeln!(
            out,
            "{{\"record\":\"counter\",\"name\":\"{}\",\"value\":{}}}",
            escape_json(&name),
            v
        );
    }
    for (name, v) in registry().gauges() {
        let _ = writeln!(
            out,
            "{{\"record\":\"gauge\",\"name\":\"{}\",\"value\":{}}}",
            escape_json(&name),
            v
        );
    }
    for (name, h) in registry().histograms() {
        let _ = writeln!(
            out,
            "{{\"record\":\"histogram\",\"name\":\"{}\",\"count\":{},\"sum\":{},\
             \"min\":{},\"max\":{},\"p50\":{},\"p99\":{}}}",
            escape_json(&name),
            h.count,
            h.sum,
            h.min_or_zero(),
            h.max,
            h.p50(),
            h.p99()
        );
    }
    out
}

/// Render the Chrome trace-event JSON (the `traceEvents` array form
/// that Perfetto and `chrome://tracing` load directly).
pub fn render_chrome_trace() -> String {
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |out: &mut String, line: String| {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&line);
    };
    for (pos, snap) in ring::snapshot_all().iter().enumerate() {
        let tid = pos + 1;
        let thread = escape_json(&format!("{}-{}", snap.group, snap.idx));
        push(
            &mut out,
            format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
                 \"args\":{{\"name\":\"{thread}\"}}}}"
            ),
        );
        for ev in &snap.events {
            let name = kind_name(ev.kind);
            let args = format!(
                "{{\"slot\":{},\"shard\":{},\"gen\":{}}}",
                ev.slot, ev.shard, ev.gen
            );
            let instant = SpanKind::from_u8(ev.kind).map(SpanKind::is_instant).unwrap_or(true);
            let line = if instant {
                format!(
                    "{{\"name\":\"{name}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":1,\"tid\":{tid},\
                     \"ts\":{},\"args\":{args}}}",
                    micros(ev.t0_ns)
                )
            } else {
                format!(
                    "{{\"name\":\"{name}\",\"ph\":\"X\",\"pid\":1,\"tid\":{tid},\
                     \"ts\":{},\"dur\":{},\"args\":{args}}}",
                    micros(ev.t0_ns),
                    micros(ev.dur_ns)
                )
            };
            push(&mut out, line);
        }
    }
    out.push_str("\n]}\n");
    out
}

/// The `--obs summary` table: histograms first (count/p50/p99/max/mean
/// in ns or edges), then non-zero counters and gauges.
pub fn summary_table() -> Table {
    let mut t = Table::new(&["metric", "count", "p50", "p99", "max", "mean"]);
    for (name, h) in registry().histograms() {
        if h.count == 0 {
            continue;
        }
        t.push(&[
            name,
            h.count.to_string(),
            h.p50().to_string(),
            h.p99().to_string(),
            h.max.to_string(),
            format!("{:.1}", h.mean()),
        ]);
    }
    for (name, v) in registry().counters() {
        if v == 0 {
            continue;
        }
        t.push(&[name, v.to_string(), "-".into(), "-".into(), "-".into(), "-".into()]);
    }
    for (name, v) in registry().gauges() {
        if v == 0 {
            continue;
        }
        t.push(&[name, v.to_string(), "-".into(), "-".into(), "-".into(), "-".into()]);
    }
    t
}

/// Write the JSON-lines export to `path`.
pub fn write_jsonl(path: &Path) -> Result<(), String> {
    std::fs::write(path, render_jsonl()).map_err(|e| format!("{}: {e}", path.display()))
}

/// Write the Chrome trace export to `path`.
pub fn write_chrome_trace(path: &Path) -> Result<(), String> {
    std::fs::write(path, render_chrome_trace()).map_err(|e| format!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_has_versioned_meta_first() {
        let out = render_jsonl();
        let first = out.lines().next().unwrap();
        assert_eq!(
            first,
            format!("{{\"record\":\"meta\",\"schema\":\"{SCHEMA}\",\"version\":{VERSION}}}")
        );
    }

    #[test]
    fn chrome_trace_is_balanced_json_shape() {
        let out = render_chrome_trace();
        assert!(out.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
        assert!(out.trim_end().ends_with("]}"));
        // crude but dependency-free balance check
        let opens = out.matches('{').count();
        let closes = out.matches('}').count();
        assert_eq!(opens, closes);
    }

    #[test]
    fn escaping_and_micros_are_exact() {
        assert_eq!(escape_json("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(escape_json("t\n"), "t\\u000a");
        assert_eq!(micros(0), "0.000");
        assert_eq!(micros(999), "0.999");
        assert_eq!(micros(1_530), "1.530");
        assert_eq!(micros(2_000_007), "2000.007");
    }

    #[test]
    fn summary_table_skips_zero_metrics() {
        let t = summary_table();
        let rendered = t.render();
        assert!(rendered.contains("metric"));
    }
}
