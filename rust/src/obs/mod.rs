//! `obs` — crate-wide zero-dependency observability.
//!
//! Three pieces, in the hand-rolled/versioned/deterministic spirit of
//! `utils::codec`:
//!
//! * **Spans** ([`with_span`], [`SpanTimer`], [`event`]) over the hot
//!   path — slot → phase (decide/commit/reward) → per-shard scatter —
//!   plus oracle iterations, checkpoint freeze/thaw, recovery replay,
//!   fault-plan notices, and pool retries/watchdog trips.  Trace events
//!   land in lock-free per-thread rings ([`ring`]) merged in
//!   deterministic (group, idx) order.
//! * **Metrics** ([`metrics`]) — counters, gauges, and log₂-bucketed
//!   latency histograms (p50/p99/max) in a process-wide registry.
//! * **Exporters** ([`export`]) — JSON-lines events, a run-summary
//!   table, and a Chrome trace-event (Perfetto-loadable) file.
//!
//! ## The parity contract
//!
//! Observability must never change what the engine computes:
//!
//! * no floats, no RNG — every recorded value is an integer; means and
//!   percentiles are derived at export time only;
//! * when the level is [`ObsLevel::Off`], every span call compiles down
//!   to a single relaxed atomic load and branch;
//! * sharded, budgeted, and resilient runs are bitwise identical with
//!   obs on vs off (`tests/obs_parity.rs` pins this across
//!   `PALLAS_WORKERS` ∈ {1, 2, 4}).
//!
//! Counters that replaced always-on ad-hoc telemetry (pool task
//! failures, watchdog trips, group scatters, recovery ckpt/kill
//! counts, occupancy) record unconditionally — they are the crate's
//! bookkeeping, not optional tracing — while spans and ring events gate
//! on the level.

pub mod export;
pub mod metrics;
pub mod ring;

pub use metrics::{registry, Counter, Gauge, HistSnapshot, Histogram, Registry};
pub use ring::Event;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// How much the obs layer records.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
#[repr(u8)]
pub enum ObsLevel {
    /// Spans are a single relaxed-atomic branch; no rings, no span
    /// histograms.  (Registry counters still count — see module docs.)
    #[default]
    Off = 0,
    /// Span latency histograms + event counters; no per-event rings.
    Summary = 1,
    /// Summary plus per-thread ring capture for the JSONL/Chrome
    /// exporters.
    Trace = 2,
}

impl ObsLevel {
    pub fn parse(s: &str) -> Result<ObsLevel, String> {
        match s {
            "off" => Ok(ObsLevel::Off),
            "summary" => Ok(ObsLevel::Summary),
            "trace" => Ok(ObsLevel::Trace),
            other => Err(format!("obs level `{other}` (expected off|summary|trace)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ObsLevel::Off => "off",
            ObsLevel::Summary => "summary",
            ObsLevel::Trace => "trace",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(0);

pub fn set_level(level: ObsLevel) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> ObsLevel {
    match LEVEL.load(Ordering::Relaxed) {
        1 => ObsLevel::Summary,
        2 => ObsLevel::Trace,
        _ => ObsLevel::Off,
    }
}

/// The one hot-path branch: false ⇒ every span/event call returns
/// immediately.
#[inline(always)]
pub fn enabled() -> bool {
    LEVEL.load(Ordering::Relaxed) != 0
}

/// Ring capture on (`--obs trace`).
#[inline(always)]
pub fn tracing() -> bool {
    LEVEL.load(Ordering::Relaxed) == 2
}

/// Everything the obs layer knows how to time (spans) or mark
/// (instant events).  Discriminants are the `Event::kind` wire values
/// and the index into the per-kind histogram/counter caches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// Whole slot: decide + commit + reward.
    Slot = 0,
    /// Policy decision phase.
    Decide = 1,
    /// Commit phase (serial or sharded).
    Commit = 2,
    /// Reward + release phase.
    Reward = 3,
    /// One shard's slice of a commit scatter.
    ShardCommit = 4,
    /// One scatter task of the sharded reward reduction.
    ShardReward = 5,
    /// One projected-ascent iteration of `regret::solve_oracle`.
    OracleIter = 6,
    /// `sim::checkpoint` freeze (codec encode + write).
    CkptFreeze = 7,
    /// `sim::checkpoint` thaw (read + codec decode).
    CkptThaw = 8,
    /// Post-kill replay segment from a restored checkpoint.
    RecoveryReplay = 9,
    /// Instant: a pool task panicked and was queued for retry.
    TaskFault = 10,
    /// Instant: a faulted task was re-run via the isolated path.
    TaskRetry = 11,
    /// Instant: the pool watchdog declared a scatter overdue.
    WatchdogTrip = 12,
    /// Instant: a checkpoint write failed and was dropped.
    CkptDropped = 13,
    /// Instant: a fault-plan topology event was applied.
    FaultTopology = 14,
    /// Instant: a threshold re-plan was triggered.
    Replan = 15,
    /// Instant: a kill fault took the run down mid-slot.
    KillTaken = 16,
    /// Instant: the ingest queue dropped a newest event at capacity.
    IngestDrop = 17,
    /// Instant: the batcher completed a slot batch.
    BatchFormed = 18,
    /// Instant: a chain blob failed PLCK verification during a
    /// recovery walk and was skipped (§SStore).
    BlobRejected = 19,
    /// Instant: a recovery fell back past rejected blob(s) to an older
    /// checkpoint (§SStore); `gen` carries the rejected count.
    ThawFallback = 20,
}

impl SpanKind {
    pub const ALL: [SpanKind; 21] = [
        SpanKind::Slot,
        SpanKind::Decide,
        SpanKind::Commit,
        SpanKind::Reward,
        SpanKind::ShardCommit,
        SpanKind::ShardReward,
        SpanKind::OracleIter,
        SpanKind::CkptFreeze,
        SpanKind::CkptThaw,
        SpanKind::RecoveryReplay,
        SpanKind::TaskFault,
        SpanKind::TaskRetry,
        SpanKind::WatchdogTrip,
        SpanKind::CkptDropped,
        SpanKind::FaultTopology,
        SpanKind::Replan,
        SpanKind::KillTaken,
        SpanKind::IngestDrop,
        SpanKind::BatchFormed,
        SpanKind::BlobRejected,
        SpanKind::ThawFallback,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Slot => "slot",
            SpanKind::Decide => "slot.decide",
            SpanKind::Commit => "slot.commit",
            SpanKind::Reward => "slot.reward",
            SpanKind::ShardCommit => "shard.commit",
            SpanKind::ShardReward => "shard.reward",
            SpanKind::OracleIter => "oracle.iter",
            SpanKind::CkptFreeze => "ckpt.freeze",
            SpanKind::CkptThaw => "ckpt.thaw",
            SpanKind::RecoveryReplay => "recover.replay",
            SpanKind::TaskFault => "pool.task_fault",
            SpanKind::TaskRetry => "pool.task_retry",
            SpanKind::WatchdogTrip => "pool.watchdog_trip",
            SpanKind::CkptDropped => "ckpt.dropped",
            SpanKind::FaultTopology => "fault.topology",
            SpanKind::Replan => "fault.replan",
            SpanKind::KillTaken => "recover.kill",
            SpanKind::IngestDrop => "ingest.drop",
            SpanKind::BatchFormed => "ingest.batch",
            SpanKind::BlobRejected => "store.blob_rejected",
            SpanKind::ThawFallback => "recover.thaw_fallback",
        }
    }

    /// Instant events mark a moment (Chrome `ph:"i"`); everything else
    /// is a duration span (`ph:"X"`).
    pub fn is_instant(self) -> bool {
        (self as u8) >= SpanKind::TaskFault as u8
    }

    pub fn from_u8(v: u8) -> Option<SpanKind> {
        Self::ALL.get(v as usize).copied()
    }
}

/// Nanoseconds since the first obs clock read of the process.  A
/// monotonic epoch (not wall time) keeps exported timestamps small and
/// keeps obs off the system-clock path, matching the checkpoint
/// codec's no-wall-time rule.
pub(crate) fn clock_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Per-kind span latency histograms ("span.<kind>.ns"), resolved once.
fn span_hists() -> &'static [Arc<Histogram>] {
    static H: OnceLock<Vec<Arc<Histogram>>> = OnceLock::new();
    H.get_or_init(|| {
        SpanKind::ALL
            .iter()
            .map(|k| registry().histogram(&format!("span.{}.ns", k.name())))
            .collect()
    })
}

/// Per-kind instant-event counters ("event.<kind>"), resolved once.
fn event_counters() -> &'static [Arc<Counter>] {
    static C: OnceLock<Vec<Arc<Counter>>> = OnceLock::new();
    C.get_or_init(|| {
        SpanKind::ALL
            .iter()
            .map(|k| registry().counter(&format!("event.{}", k.name())))
            .collect()
    })
}

fn record_span(kind: SpanKind, slot: u64, shard: u32, gen: u32, t0: u64, dur: u64) {
    span_hists()[kind as usize].record(dur);
    if tracing() {
        ring::record(Event {
            kind: kind as u8,
            shard,
            gen,
            slot,
            t0_ns: t0,
            dur_ns: dur,
        });
    }
}

/// Record a completed span from an explicitly captured start stamp —
/// the overlapped pipeline opens a slot's wall window on the leader
/// thread (`clock_ns` before decide) and closes it on the committer
/// thread after the reward merge, so neither `with_span` nor
/// [`SpanTimer`] fits.  Inert when obs is off.
#[inline]
pub(crate) fn record_span_window(kind: SpanKind, slot: u64, shard: u32, t0: u64) {
    if !enabled() {
        return;
    }
    let dur = clock_ns().saturating_sub(t0);
    record_span(kind, slot, shard, 0, t0, dur);
}

/// Time `f` as a `kind` span.  Off ⇒ one relaxed load + branch, then
/// straight into `f`.
#[inline]
pub fn with_span<T>(kind: SpanKind, slot: u64, shard: u32, f: impl FnOnce() -> T) -> T {
    if !enabled() {
        return f();
    }
    let t0 = clock_ns();
    let out = f();
    let dur = clock_ns().saturating_sub(t0);
    record_span(kind, slot, shard, 0, t0, dur);
    out
}

/// Scope-shaped span for regions that don't fit a closure (e.g. the
/// whole slot body around early returns).  Inert when obs is off.
pub struct SpanTimer {
    kind: SpanKind,
    slot: u64,
    shard: u32,
    t0: u64,
    armed: bool,
}

impl SpanTimer {
    #[inline]
    pub fn start(kind: SpanKind, slot: u64, shard: u32) -> SpanTimer {
        if !enabled() {
            return SpanTimer { kind, slot, shard, t0: 0, armed: false };
        }
        SpanTimer { kind, slot, shard, t0: clock_ns(), armed: true }
    }
}

impl Drop for SpanTimer {
    fn drop(&mut self) {
        if self.armed {
            let dur = clock_ns().saturating_sub(self.t0);
            record_span(self.kind, self.slot, self.shard, 0, self.t0, dur);
        }
    }
}

/// Record a structured instant event with (slot, shard, generation)
/// context: counted at summary level, captured into the rings at trace
/// level, a single branch when off.
#[inline]
pub fn event(kind: SpanKind, slot: u64, shard: u32, gen: u32) {
    if !enabled() {
        return;
    }
    event_counters()[kind as usize].inc();
    if tracing() {
        ring::record(Event {
            kind: kind as u8,
            shard,
            gen,
            slot,
            t0_ns: clock_ns(),
            dur_ns: 0,
        });
    }
}

/// Zero all metrics and drop all captured trace events.  Quiesced-only
/// (no concurrent scatter in flight), like [`ring::clear_all`].
pub fn reset() {
    registry().reset();
    ring::clear_all();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_round_trips() {
        for l in [ObsLevel::Off, ObsLevel::Summary, ObsLevel::Trace] {
            assert_eq!(ObsLevel::parse(l.name()), Ok(l));
        }
        assert!(ObsLevel::parse("verbose").is_err());
    }

    #[test]
    fn span_kind_wire_values_round_trip() {
        for (i, k) in SpanKind::ALL.iter().enumerate() {
            assert_eq!(*k as usize, i);
            assert_eq!(SpanKind::from_u8(*k as u8), Some(*k));
        }
        assert_eq!(SpanKind::from_u8(SpanKind::ALL.len() as u8), None);
        assert!(SpanKind::WatchdogTrip.is_instant());
        assert!(!SpanKind::OracleIter.is_instant());
        // PR 9 kinds are appended instants: existing wire values (and the
        // `is_instant` threshold at TaskFault) must not shift.
        assert_eq!(SpanKind::KillTaken as u8, 16);
        assert_eq!(SpanKind::IngestDrop as u8, 17);
        assert_eq!(SpanKind::BatchFormed as u8, 18);
        assert_eq!(SpanKind::BlobRejected as u8, 19);
        assert_eq!(SpanKind::ThawFallback as u8, 20);
        assert!(SpanKind::IngestDrop.is_instant());
        assert!(SpanKind::BatchFormed.is_instant());
        assert!(SpanKind::BlobRejected.is_instant());
        assert!(SpanKind::ThawFallback.is_instant());
    }

    #[test]
    fn with_span_passes_through_when_off() {
        // Tests share one process: other suites may flip the level, so
        // assert only the pass-through value here.
        let v = with_span(SpanKind::Decide, 1, 0, || 41 + 1);
        assert_eq!(v, 42);
        let t = SpanTimer::start(SpanKind::Slot, 1, 0);
        drop(t);
    }
}
